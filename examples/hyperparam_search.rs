//! §IV.C hyperparameter search, end to end.
//!
//! Three levels:
//!
//! 1. **Fleet level (simulated):** the paper's 12-binary-parameter grid —
//!    4096 combinations × 10 min each = 28.4 days sequentially — scheduled
//!    on a growing cluster until the whole sweep fits in ~10 minutes.
//! 2. **Trial level (search/):** the same sweep idea upgraded to
//!    checkpointable trials with ASHA early stopping on the preemptible
//!    fleet — a fraction of the grid's trial-steps for the same best
//!    loss, surviving a storm that reclaims most of the fleet.
//! 3. **Real level (PJRT):** a small lr × batch-interpretation search over
//!    the AOT `tiny` transformer, each trial actually trained for a few
//!    steps, ranked by final loss — the "log results of hyperparameter
//!    search" interface the paper describes.
//!
//! Run with: `cargo run --release --example hyperparam_search`

use std::sync::Arc;

use hyper_dist::baselines::sequential_makespan;
use hyper_dist::cloud::StormEvent;
use hyper_dist::cluster::Master;
use hyper_dist::config::{artifacts_available, default_artifacts_dir, SearchAlgo, SearchConfig};
use hyper_dist::runtime::Runtime;
use hyper_dist::scheduler::{SimDriver, SimDriverConfig};
use hyper_dist::search::{CurveConfig, SearchDriver, SearchDriverConfig};
use hyper_dist::storage::MemStore;
use hyper_dist::workflow::{sample_assignments, ParamSpec, ParamValue};

fn fleet_level() -> anyhow::Result<()> {
    println!("== fleet level: the paper's 4096-combination sweep ==");
    // 12 binary parameters -> 4096 combos (§IV.C)
    let params: String = (0..12)
        .map(|i| format!("      p{i:02}: {{ range: [0, 1] }}\n"))
        .collect();
    let seq_days = sequential_makespan(4096, 600.0) / 86_400.0;
    println!("sequential baseline: 4096 x 10 min = {seq_days:.1} days");

    for workers in [64usize, 256, 1024, 4096] {
        let recipe = format!(
            r#"
name: xgboost-sweep
experiments:
  - name: sweep
    instance: m5.xlarge
    workers: {workers}
    spot: true
    command: "xgboost-train {{p00}}{{p01}}{{p02}}{{p03}}{{p04}}{{p05}}{{p06}}{{p07}}{{p08}}{{p09}}{{p10}}{{p11}}"
    params:
{params}    work: {{ duration_s: 600.0 }}
"#
        );
        let master = Master::new();
        let name = master.submit(&recipe, 1)?;
        let mut wf = master.workflow(&name)?;
        assert_eq!(wf.total_tasks(), 4096);
        let mut driver = SimDriver::new(SimDriverConfig { seed: 1, ..Default::default() });
        let r = driver.run(&mut wf)?;
        println!(
            "workers={workers:>5}  makespan={:>7.1} min  cost=${:<8.2} speedup={:>6.0}x",
            r.makespan_s / 60.0,
            r.total_cost_usd,
            sequential_makespan(4096, 600.0) / r.makespan_s
        );
    }
    Ok(())
}

fn trial_level() -> anyhow::Result<()> {
    println!("\n== trial level: ASHA early stopping on the preemptible fleet ==");
    // a structured space: the lr optimum sits at 3e-3, so the search has
    // something real to find
    let mut space = std::collections::BTreeMap::new();
    space.insert("lr".to_string(), ParamSpec::LogUniform([1e-4, 1e-1]));
    space.insert(
        "bs".to_string(),
        ParamSpec::Choice(vec![ParamValue::Int(32), ParamValue::Int(64), ParamValue::Int(128)]),
    );
    let cfg = |algo| SearchDriverConfig {
        search: SearchConfig {
            trials: 64,
            max_steps: 81,
            rung_first_steps: 3,
            eta: 3,
            workers: 8,
            algo,
            seed: 7,
            ..SearchConfig::default()
        },
        curve: CurveConfig { lr_optimum: Some(3e-3), noise: 0.01, ..Default::default() },
        ..Default::default()
    };
    for algo in [SearchAlgo::Grid, SearchAlgo::Asha, SearchAlgo::Hyperband, SearchAlgo::Median] {
        let store = Arc::new(MemStore::new());
        let mut d =
            SearchDriver::new(cfg(algo), store, &space, "python train.py --lr {lr} --bs {bs}")?;
        let r = d.run()?;
        println!(
            "{:9}  steps {:>6}  best loss {:.4}  makespan {:>6.0}s  cost ${:<7.2} \
             completed {:>2} stopped {:>2}",
            r.algo, r.total_steps, r.best_loss, r.makespan_s, r.cost_usd, r.completed, r.stopped
        );
    }

    // now the §III.D story: a storm reclaims 6 of the 8 nodes mid-search
    let mut storm_cfg = cfg(SearchAlgo::Asha);
    storm_cfg.storm = vec![StormEvent { at_s: 120.0, kills: 6, notice_s: 5.0 }];
    let mut d = SearchDriver::new(
        storm_cfg,
        Arc::new(MemStore::new()),
        &space,
        "python train.py --lr {lr} --bs {bs}",
    )?;
    let r = d.run()?;
    println!(
        "asha+storm  preemptions {}  pauses {}  resumes {}  full restarts {}  lost {} \
         (every trial resumed from its checkpoint on another node)",
        r.preemptions, r.pauses, r.resumes, r.full_restarts, r.lost
    );
    assert_eq!(r.lost, 0, "zero lost trials through the storm");
    if let Some(best) = &r.best_assignment {
        let rendered: Vec<String> = best.iter().map(|(k, v)| format!("{k}={v}")).collect();
        println!("best assignment: {}", rendered.join(" "));
    }
    Ok(())
}

fn real_level() -> anyhow::Result<()> {
    println!("\n== real level: lr search over the AOT transformer (PJRT) ==");
    let dir = default_artifacts_dir();
    if !artifacts_available(&dir, "tiny") {
        println!("artifacts missing — run `make artifacts` first; skipping real level");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    // §II.C sampling: continuous lr log-range matched with discrete seeds
    let mut space = std::collections::BTreeMap::new();
    space.insert("lr".to_string(), ParamSpec::LogUniform([1e-4, 3e-2]));
    space.insert(
        "seed".to_string(),
        ParamSpec::Choice(vec![ParamValue::Int(0), ParamValue::Int(1)]),
    );
    let trials = sample_assignments(&space, Some(6), 7);

    let mut results = Vec::new();
    for (t, a) in trials.iter().enumerate() {
        let ParamValue::Float(lr) = a["lr"] else { panic!("lr type") };
        let ParamValue::Int(seed) = a["seed"] else { panic!("seed type") };
        let mut sess = rt.train_session("tiny", seed as i32)?;
        let nt = sess.batch_tokens();
        let vocab = sess.preset().vocab as i32;
        let tokens: Vec<i32> = (0..nt).map(|i| (i as i32 * 13 + 7) % vocab).collect();
        let mut loss = f32::NAN;
        for _ in 0..12 {
            loss = sess.step(&tokens, lr as f32)?;
        }
        println!("trial {t}: lr={lr:.5} seed={seed} -> loss {loss:.4}");
        results.push((loss, lr, seed));
    }
    results.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite loss"));
    let best = results.first().expect("has trials");
    println!("best: loss={:.4} at lr={:.5} (seed {})", best.0, best.1, best.2);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    fleet_level()?;
    trial_level()?;
    real_level()
}
