//! §IV.C hyperparameter search, end to end.
//!
//! Two levels:
//!
//! 1. **Fleet level (simulated):** the paper's 12-binary-parameter grid —
//!    4096 combinations × 10 min each = 28.4 days sequentially — scheduled
//!    on a growing cluster until the whole sweep fits in ~10 minutes.
//! 2. **Real level (PJRT):** a small lr × batch-interpretation search over
//!    the AOT `tiny` transformer, each trial actually trained for a few
//!    steps, ranked by final loss — the "log results of hyperparameter
//!    search" interface the paper describes.
//!
//! Run with: `cargo run --release --example hyperparam_search`

use hyper_dist::baselines::sequential_makespan;
use hyper_dist::cluster::Master;
use hyper_dist::config::{artifacts_available, default_artifacts_dir};
use hyper_dist::runtime::Runtime;
use hyper_dist::scheduler::{SimDriver, SimDriverConfig};
use hyper_dist::workflow::{sample_assignments, ParamSpec, ParamValue};

fn fleet_level() -> anyhow::Result<()> {
    println!("== fleet level: the paper's 4096-combination sweep ==");
    // 12 binary parameters -> 4096 combos (§IV.C)
    let params: String = (0..12)
        .map(|i| format!("      p{i:02}: {{ range: [0, 1] }}\n"))
        .collect();
    let seq_days = sequential_makespan(4096, 600.0) / 86_400.0;
    println!("sequential baseline: 4096 x 10 min = {seq_days:.1} days");

    for workers in [64usize, 256, 1024, 4096] {
        let recipe = format!(
            r#"
name: xgboost-sweep
experiments:
  - name: sweep
    instance: m5.xlarge
    workers: {workers}
    spot: true
    command: "xgboost-train {{p00}}{{p01}}{{p02}}{{p03}}{{p04}}{{p05}}{{p06}}{{p07}}{{p08}}{{p09}}{{p10}}{{p11}}"
    params:
{params}    work: {{ duration_s: 600.0 }}
"#
        );
        let master = Master::new();
        let name = master.submit(&recipe, 1)?;
        let mut wf = master.workflow(&name)?;
        assert_eq!(wf.total_tasks(), 4096);
        let mut driver = SimDriver::new(SimDriverConfig { seed: 1, ..Default::default() });
        let r = driver.run(&mut wf)?;
        println!(
            "workers={workers:>5}  makespan={:>7.1} min  cost=${:<8.2} speedup={:>6.0}x",
            r.makespan_s / 60.0,
            r.total_cost_usd,
            sequential_makespan(4096, 600.0) / r.makespan_s
        );
    }
    Ok(())
}

fn real_level() -> anyhow::Result<()> {
    println!("\n== real level: lr search over the AOT transformer (PJRT) ==");
    let dir = default_artifacts_dir();
    if !artifacts_available(&dir, "tiny") {
        println!("artifacts missing — run `make artifacts` first; skipping real level");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    // §II.C sampling: continuous lr log-range matched with discrete seeds
    let mut space = std::collections::BTreeMap::new();
    space.insert("lr".to_string(), ParamSpec::LogUniform([1e-4, 3e-2]));
    space.insert(
        "seed".to_string(),
        ParamSpec::Choice(vec![ParamValue::Int(0), ParamValue::Int(1)]),
    );
    let trials = sample_assignments(&space, Some(6), 7);

    let mut results = Vec::new();
    for (t, a) in trials.iter().enumerate() {
        let ParamValue::Float(lr) = a["lr"] else { panic!("lr type") };
        let ParamValue::Int(seed) = a["seed"] else { panic!("seed type") };
        let mut sess = rt.train_session("tiny", seed as i32)?;
        let nt = sess.batch_tokens();
        let vocab = sess.preset().vocab as i32;
        let tokens: Vec<i32> = (0..nt).map(|i| (i as i32 * 13 + 7) % vocab).collect();
        let mut loss = f32::NAN;
        for _ in 0..12 {
            loss = sess.step(&tokens, lr as f32)?;
        }
        println!("trial {t}: lr={lr:.5} seed={seed} -> loss {loss:.4}");
        results.push((loss, lr, seed));
    }
    results.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite loss"));
    let best = results.first().expect("has trials");
    println!("best: loss={:.4} at lr={:.5} (seed {})", best.0, best.1, best.2);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    fleet_level()?;
    real_level()
}
