//! End-to-end validation driver (DESIGN.md §End-to-end validation).
//!
//! Exercises the FULL stack on a real workload:
//!
//!   recipe -> master -> workflow -> HFS-stored synthetic corpus ->
//!   async DataLoader over HFS -> PJRT train_step (AOT Pallas kernels) ->
//!   periodic checkpoints -> injected preemption -> resume -> loss curve.
//!
//! Run with: `cargo run --release --example train_e2e -- [preset] [steps]`
//! Defaults: preset=small, steps=300. Results recorded in EXPERIMENTS.md.

use std::sync::Arc;
use std::time::Instant;

use hyper_dist::cluster::Master;
use hyper_dist::config::{artifacts_available, default_artifacts_dir};
use hyper_dist::dataloader::DataLoader;
use hyper_dist::hfs::{HyperFs, Uploader};
use hyper_dist::runtime::Runtime;
use hyper_dist::scheduler::CheckpointStore;
use hyper_dist::sim::SimRng;
use hyper_dist::storage::{MemStore, StoreHandle};
use hyper_dist::workflow::TaskId;

/// Deterministic synthetic corpus with learnable structure: Zipf-ish
/// unigrams + strong bigram transitions (a Markov chain), so the loss
/// curve has real signal (falls well below the uniform log V).
fn gen_corpus(vocab: i32, n_files: usize, tokens_per_file: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = SimRng::new(seed);
    (0..n_files)
        .map(|_| {
            let mut toks = Vec::with_capacity(tokens_per_file);
            let mut cur = rng.gen_range(vocab as u64) as i32;
            for _ in 0..tokens_per_file {
                toks.push(cur);
                cur = if rng.gen_bool(0.85) {
                    // deterministic bigram successor
                    (cur * 31 + 7) % vocab
                } else {
                    rng.gen_range(vocab as u64) as i32
                };
            }
            toks
        })
        .collect()
}

fn encode(tokens: &[i32]) -> Vec<u8> {
    tokens.iter().flat_map(|t| t.to_le_bytes()).collect()
}

fn decode(bytes: &[u8]) -> Vec<i32> {
    bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().expect("4 bytes")))
        .collect()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let preset = args.get(1).cloned().unwrap_or_else(|| "small".into());
    let steps: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(300);

    let dir = default_artifacts_dir();
    if !artifacts_available(&dir, &preset) {
        anyhow::bail!("artifacts for {preset:?} missing — run `make artifacts PRESETS=tiny,{preset}`");
    }

    // ---- control plane: recipe + master --------------------------------
    let recipe = format!(
        r#"
name: train-e2e
experiments:
  - name: train
    instance: p3.2xlarge
    workers: 1
    spot: true
    command: "hyper train --preset {preset} --lr {{lr}}"
    samples: 1
    params: {{ lr: {{ choice: [0.001] }} }}
"#
    );
    let master = Master::new();
    let name = master.submit(&recipe, 0)?;
    let wf = master.workflow(&name)?;
    let task_id = TaskId { experiment: 0, index: 0 };
    println!("workflow {name:?}: task {} -> {:?}", task_id, wf.task(task_id).command);

    // ---- data plane: corpus through HFS --------------------------------
    let rt = Runtime::new(&dir)?;
    let pm = rt.manifest.preset(&preset)?.clone();
    let vocab = pm.vocab as i32;
    let tokens_per_file = pm.batch * pm.seq_len;
    let n_files = 512;
    println!(
        "preset {}: {} params, batch {}x{} tokens, corpus {} files",
        pm.name, pm.param_count, pm.batch, pm.seq_len, n_files
    );
    let store: StoreHandle = Arc::new(MemStore::new());
    let corpus = gen_corpus(vocab, n_files, tokens_per_file, 1234);
    let mut up = Uploader::new(store.clone(), "corpus", 8 << 20);
    for (i, doc) in corpus.iter().enumerate() {
        up.add_file(&format!("train/{i:06}.tok"), &encode(doc))?;
    }
    let manifest = up.seal()?;
    println!(
        "corpus: {} chunks, {:.1} MB through HFS",
        manifest.chunks.len(),
        manifest.total_bytes() as f64 / 1e6
    );
    let fs = Arc::new(HyperFs::mount(store.clone(), "corpus", 128 << 20)?);

    // ---- training with checkpoints + injected preemption ----------------
    let ckpts = CheckpointStore::new(store.clone(), "wf/train-e2e");
    let mut sess = rt.train_session(&preset, 0)?;
    let lr = 1e-3;
    let ckpt_every = 50u64;
    let preempt_at = steps / 2; // inject a §III.D node failure mid-run

    let mut losses: Vec<(u64, f32)> = Vec::new();
    let t0 = Instant::now();
    let mut paths: Vec<String> = fs.list("train/")?;
    let mut epoch_rng = SimRng::new(99);

    'outer: loop {
        epoch_rng.shuffle(&mut paths);
        let loader = DataLoader::start(fs.clone(), paths.clone(), 1, 2, 4);
        while let Some(batch) = loader.next_batch() {
            let batch = batch.map_err(|e| anyhow::anyhow!("loader: {e}"))?;
            let tokens = decode(&batch.files[0]);
            let loss = sess.step(&tokens, lr)?;
            let s = sess.steps_done;
            if s % 10 == 0 || s == 1 {
                println!(
                    "step {s:>5}  loss {loss:.4}  ({:.2} steps/s, hfs hit-rate {:.0}%)",
                    s as f64 / t0.elapsed().as_secs_f64(),
                    100.0 * fs.stats.hit_rate()
                );
            }
            losses.push((s, loss));
            if s % ckpt_every == 0 {
                sess.checkpoint(&ckpts, task_id)?;
            }
            if s == preempt_at {
                println!("!! injecting spot preemption at step {s} (node killed)");
                // node dies: session dropped; scheduler reschedules the task
                let resumed_step = {
                    let mut fresh = rt.train_session(&preset, 0)?;
                    let r = fresh.resume(&ckpts, task_id)?;
                    sess = fresh;
                    r
                };
                println!(
                    "!! rescheduled on a new node; resumed from checkpoint step {:?}",
                    resumed_step
                );
                assert!(resumed_step.is_some(), "checkpoint must exist");
                continue 'outer; // restart the loader (new node mounts HFS)
            }
            if s >= steps {
                break 'outer;
            }
        }
    }

    // ---- report ----------------------------------------------------------
    let wall = t0.elapsed().as_secs_f64();
    let first = losses.first().expect("nonempty").1;
    let last = losses.last().expect("nonempty").1;
    let uniform = (vocab as f32).ln();
    let tok_per_s = (sess.steps_done as f64 * tokens_per_file as f64) / wall;
    println!("\n=== train_e2e report ===");
    println!("preset            {}", pm.name);
    println!("params            {}", pm.param_count);
    println!("steps             {}", sess.steps_done);
    println!("wallclock         {wall:.1} s");
    println!("throughput        {tok_per_s:.0} tokens/s");
    println!("flops/step        {:.2e}", pm.flops_per_step());
    println!("achieved flops    {:.2e}/s", pm.flops_per_step() * sess.steps_done as f64 / wall);
    println!("loss              {first:.3} -> {last:.3} (uniform = {uniform:.3})");
    println!("hfs reads         {} (hit-rate {:.1}%)", fs.stats.reads.get(), 100.0 * fs.stats.hit_rate());
    println!("loss curve (every 25 steps):");
    for (s, l) in losses.iter().filter(|(s, _)| s % 25 == 0 || *s == 1) {
        println!("  step {s:>5}  loss {l:.4}");
    }
    assert!(last < first, "loss must decrease: {first} -> {last}");
    Ok(())
}
