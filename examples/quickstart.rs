//! Quickstart: the whole Hyper stack in one file.
//!
//! 1. upload a dataset through the chunked Hyper File System;
//! 2. submit a YAML recipe to the master;
//! 3. run the workflow on a simulated spot fleet with fault tolerance;
//! 4. run a few *real* PJRT training steps of the AOT transformer.
//!
//! Run with: `cargo run --release --example quickstart`

use std::sync::Arc;

use hyper_dist::cluster::Master;
use hyper_dist::config::{artifacts_available, default_artifacts_dir};
use hyper_dist::hfs::{HyperFs, Uploader};
use hyper_dist::runtime::Runtime;
use hyper_dist::scheduler::{SimDriver, SimDriverConfig};
use hyper_dist::storage::{MemStore, StoreHandle};

const RECIPE: &str = r#"
name: quickstart
experiments:
  - name: preprocess
    instance: m5.24xlarge
    workers: 8
    spot: true
    command: "python prep.py --shard {shard}"
    params: { shard: { range: [0, 63] } }
    work: { duration_s: 20.0, input_bytes: 1000000000 }
  - name: train
    instance: p3.2xlarge
    workers: 4
    spot: true
    command: "python train.py --lr {lr} --bs {bs}"
    samples: 8
    params:
      lr: { log_uniform: [1.0e-4, 1.0e-2] }
      bs: { choice: [32, 64] }
    work: { flops_per_task: 1.0e15 }
    depends_on: [preprocess]
"#;

fn main() -> anyhow::Result<()> {
    // --- 1. Hyper File System ------------------------------------------
    println!("== HFS upload + mount ==");
    let store: StoreHandle = Arc::new(MemStore::new());
    let mut up = Uploader::new(store.clone(), "corpus", 4 << 20);
    for i in 0..256 {
        up.add_file(&format!("docs/{i:04}.txt"), format!("document {i} body\n").as_bytes())?;
    }
    let manifest = up.seal()?;
    println!(
        "uploaded {} files into {} chunks ({} bytes)",
        manifest.file_count(),
        manifest.chunks.len(),
        manifest.total_bytes()
    );
    let fs = HyperFs::mount(store, "corpus", 64 << 20)?;
    let doc = fs.read_file("docs/0042.txt")?;
    println!("read back: {:?}", String::from_utf8_lossy(&doc).trim());

    // --- 2 + 3. recipe -> DAG -> simulated spot fleet ------------------
    println!("\n== workflow on simulated spot fleet ==");
    let master = Master::new();
    let name = master.submit(RECIPE, 42)?;
    let mut wf = master.workflow(&name)?;
    println!("{} experiments, {} tasks", wf.n_experiments(), wf.total_tasks());
    let mut driver = SimDriver::new(SimDriverConfig {
        spot_market: hyper_dist::cloud::SpotMarketConfig {
            mean_ttp_s: 600.0, // aggressive market to show fault tolerance
            notice_s: 120.0,
        },
        seed: 42,
        ..Default::default()
    });
    let r = driver.run(&mut wf)?;
    println!(
        "complete={} makespan={:.0}s cost=${:.2} preemptions={} reschedules={}",
        r.workflow_complete, r.makespan_s, r.total_cost_usd, r.preemptions, r.reschedules
    );
    assert!(r.workflow_complete, "fault tolerance must finish the workflow");

    // --- 4. real PJRT training steps -----------------------------------
    println!("\n== real AOT training (PJRT) ==");
    let dir = default_artifacts_dir();
    if !artifacts_available(&dir, "tiny") {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let mut sess = rt.train_session("tiny", 0)?;
    let nt = sess.batch_tokens();
    let vocab = sess.preset().vocab as i32;
    for step in 0..10 {
        let tokens: Vec<i32> = (0..nt).map(|i| (i as i32 * 7 + step) % vocab).collect();
        let loss = sess.step(&tokens, 1e-2)?;
        println!("step {step}  loss {loss:.4}");
    }
    Ok(())
}
