//! Serving under an SLO on unstable cheap resources, end to end.
//!
//! The paper's §IV.D inference fleet is throughput-oriented (fan a model
//! over a dataset); this example is its latency-oriented sibling — the
//! ROADMAP's "heavy traffic from millions of users" scenario, run
//! deterministically in virtual time:
//!
//! 1. steady 1200 req/s against 8 warm spot replicas with dynamic
//!    batching (close at 8 requests or 5 ms);
//! 2. at t=60 s a preemption storm reclaims 7 of the 8 replicas with no
//!    notice — in-flight batches requeue at the front of the queue;
//! 3. admission control sheds the overload the lone survivor cannot
//!    carry, which is exactly what keeps the p99 of *admitted* requests
//!    inside the 250 ms SLO;
//! 4. the autoscaler's floor repair + backlog signal provision
//!    replacements through the cloud provisioner (~1 min to readiness),
//!    and the system converges back to steady state.
//!
//! Run with: `cargo run --release --example serve_slo`

use hyper_dist::serve::{AutoscalerConfig, BatchPolicy, Load, ServeSim, ServeSimConfig,
                        StormEvent};
use hyper_dist::sim::OpenLoop;

fn main() -> anyhow::Result<()> {
    let slo_s = 0.25;
    let cfg = ServeSimConfig {
        batch: BatchPolicy { max_batch: 8, max_delay_s: 0.005 },
        queue_depth: 128,
        service_base_s: 0.002,
        service_per_item_s: 0.001,
        initial_replicas: 8,
        warm_start: true,
        autoscaler: AutoscalerConfig {
            min_replicas: 2,
            max_replicas: 16,
            slo_p99_s: slo_s,
            up_step: 2,
            up_cooldown_s: 10.0,
            down_cooldown_s: 60.0,
            ..Default::default()
        },
        scale_interval_s: 5.0,
        storm: vec![StormEvent { at_s: 60.0, kills: 7, notice_s: 0.0 }],
        seed: 42,
        trace: true,
        ..Default::default()
    };
    println!(
        "scenario: 1200 req/s, 8 spot replicas, storm kills 7/8 at t=60s, p99 SLO {} ms",
        slo_s * 1e3
    );

    let report = ServeSim::new(cfg).run(Load::Open(OpenLoop::poisson(1200.0)), 180.0)?;

    println!("\n   t    live  prov  queue   win-p99    shed(cum)");
    for t in &report.trace {
        let marker = if t.t_s == 60.0 { "  <- storm" } else { "" };
        println!(
            "{:>5.0}s  {:>4}  {:>4}  {:>5}  {:>7.1}ms  {:>10}{}",
            t.t_s,
            t.live,
            t.provisioning,
            t.queue_depth,
            t.window_p99_s * 1e3,
            t.shed,
            marker
        );
    }

    println!(
        "\noffered {}  admitted {}  shed {} ({:.1}%)  completed {}",
        report.offered,
        report.admitted,
        report.shed,
        100.0 * report.shed as f64 / report.offered.max(1) as f64,
        report.completed
    );
    println!(
        "latency p50 {:.1} ms  p99 {:.1} ms (SLO {:.0} ms)  max {:.1} ms",
        report.latency.p50 * 1e3,
        report.latency.p99 * 1e3,
        slo_s * 1e3,
        report.latency.max * 1e3
    );
    println!(
        "storm: {} preemptions, {} in-flight requests requeued, {} replicas autoscaled in",
        report.preemptions, report.requeued, report.scale_ups
    );
    println!(
        "fleet: {} launched, peak {} live, {} live at end, spot cost ${:.2}",
        report.replicas_launched, report.max_live, report.final_live, report.cost_usd
    );

    assert_eq!(report.completed, report.admitted, "zero dropped requests");
    assert!(report.latency.p99 <= slo_s, "SLO held through the storm");
    println!("\nserve_slo OK: SLO held through the storm, zero admitted requests dropped");
    Ok(())
}
