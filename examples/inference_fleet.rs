//! §IV.D large-scale inference, end to end.
//!
//! The paper splits ImageNet into 300 folders of 1500 images and fans the
//! Yolo model out to 300 GPU instances (~2 PFLOPs aggregate). Here:
//!
//! 1. **Real anchor (PJRT):** run the AOT `tiny` transformer's infer step
//!    on this machine to measure per-batch inference cost.
//! 2. **Fleet level (simulated):** 300 folders × 1500 items on 300
//!    simulated p3.2xlarge spot nodes, per-task work anchored to the real
//!    measurement scaled by the device model.
//!
//! Run with: `cargo run --release --example inference_fleet`

use hyper_dist::cloud::InstanceType;
use hyper_dist::cluster::Master;
use hyper_dist::config::{artifacts_available, default_artifacts_dir};
use hyper_dist::runtime::Runtime;
use hyper_dist::scheduler::{SimDriver, SimDriverConfig};
use hyper_dist::sim::SimRng;

fn main() -> anyhow::Result<()> {
    // ---- real anchor ---------------------------------------------------
    let dir = default_artifacts_dir();
    let mut per_item_flops = 2.0e9; // fallback: ~Yolo-like per-image cost
    if artifacts_available(&dir, "tiny") {
        let rt = Runtime::new(&dir)?;
        let sess = rt.infer_session("tiny", 0)?;
        let pm = sess.preset().clone();
        let nt = pm.batch * pm.seq_len;
        let mut rng = SimRng::new(5);
        let tokens: Vec<i32> = (0..nt).map(|_| rng.gen_range(pm.vocab as u64) as i32).collect();
        sess.next_tokens(&tokens)?; // warm
        let t0 = std::time::Instant::now();
        let reps = 10;
        for _ in 0..reps {
            sess.next_tokens(&tokens)?;
        }
        let per_batch_s = t0.elapsed().as_secs_f64() / reps as f64;
        // infer is ~1/3 of train flops (fwd only)
        let batch_flops = pm.flops_per_step() / 3.0;
        println!(
            "real anchor: {:.1} ms/batch on CPU PJRT ({:.2e} FLOP/batch, {:.2e} FLOP/s)",
            per_batch_s * 1e3,
            batch_flops,
            batch_flops / per_batch_s
        );
        per_item_flops = batch_flops / pm.batch as f64;
    } else {
        println!("artifacts missing; using default per-item FLOPs");
    }

    // ---- fleet level -----------------------------------------------------
    // paper: 300 folders x 1500 images; one task per folder; 300 GPU nodes
    let folders = 300usize;
    let images_per_folder = 1500u64;
    // scale the real per-item cost to a Yolo-on-ImageNet-sized workload
    let yolo_scale = (2.0e9 / per_item_flops).max(1.0);
    let task_flops = per_item_flops * yolo_scale * images_per_folder as f64;
    let image_bytes = 110_000u64; // mean ImageNet JPEG
    let recipe = format!(
        r#"
name: imagenet-inference
experiments:
  - name: infer
    instance: p3.2xlarge
    workers: {folders}
    spot: true
    command: "yolo-infer --folder {{folder}}"
    params: {{ folder: {{ range: [0, {}] }} }}
    work: {{ flops_per_task: {task_flops:.3e}, input_bytes: {} }}
"#,
        folders - 1,
        image_bytes * images_per_folder
    );
    let master = Master::new();
    let name = master.submit(&recipe, 2)?;
    let mut wf = master.workflow(&name)?;
    println!(
        "fleet: {} tasks x {} images, {:.2} PFLOPs aggregate demand",
        wf.total_tasks(),
        images_per_folder,
        task_flops * folders as f64 / 1e15
    );
    let agg_flops = InstanceType::P3_2xlarge.spec().flops * folders as f64;
    println!("fleet compute: {:.2} PFLOP/s across {folders} nodes", agg_flops / 1e15);

    let mut driver = SimDriver::new(SimDriverConfig { seed: 2, ..Default::default() });
    let r = driver.run(&mut wf)?;
    let images = folders as u64 * images_per_folder;
    println!(
        "complete={} makespan={:.1}s images={} throughput={:.0} img/s cost=${:.2} \
         preemptions={} (all recovered: {} succeeded)",
        r.workflow_complete,
        r.makespan_s,
        images,
        images as f64 / r.makespan_s,
        r.total_cost_usd,
        r.preemptions,
        r.tasks_succeeded,
    );
    assert!(r.workflow_complete);
    Ok(())
}
