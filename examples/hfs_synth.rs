//! Synthesize an N-file HFS namespace into a directory-backed store.
//!
//! The on-disk counterpart of the generator behind the `hfs_metadata`
//! bench: writes a sharded, content-addressed namespace (root manifest +
//! file-table shards + chunk table + chunk objects) into a `DiskStore`
//! root, then mounts it and spot-checks a few reads. Useful for poking
//! at the metadata plane with real files, or seeding a directory for
//! other tools.
//!
//! Run with:
//!   cargo run --release --example hfs_synth -- \
//!     [DIR] [N_FILES] [FILE_BYTES] [DISTINCT] [NS]
//!
//! Defaults: DIR=target/hfs_synth N_FILES=10000 FILE_BYTES=4096
//! DISTINCT=0 (all files distinct; pass a smaller number to create
//! dedup pressure) NS=synth. Also driven by `scripts/hfs_synth`.

use std::sync::Arc;

use hyper_dist::hfs::{synthesize_namespace, HyperFs, UploadConfig};
use hyper_dist::storage::{DiskStore, StoreHandle};

fn arg<T: std::str::FromStr>(n: usize, default: T) -> T {
    std::env::args().nth(n).and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let dir: String = arg(1, "target/hfs_synth".to_string());
    let n_files: usize = arg(2, 10_000);
    let file_bytes: usize = arg(3, 4096);
    let distinct: usize = arg(4, 0);
    let ns: String = arg(5, "synth".to_string());

    let store: StoreHandle = Arc::new(DiskStore::new(&dir)?);
    let cfg = UploadConfig::default();
    let t0 = std::time::Instant::now();
    let (paths, stats) = synthesize_namespace(&store, &ns, n_files, file_bytes, distinct, cfg)?;
    println!(
        "synthesized {n_files} files x {file_bytes} B into {dir}/{ns} in {:.2}s",
        t0.elapsed().as_secs_f64()
    );
    println!(
        "  chunks written {}  deduped {}  shards {}  bytes written {}  bytes deduped {}",
        stats.chunks_written,
        stats.chunks_deduped,
        stats.shards_written,
        stats.bytes_written,
        stats.bytes_deduped
    );

    let t1 = std::time::Instant::now();
    let fs = HyperFs::mount(store, &ns, 256 << 20)?;
    println!(
        "mounted {} files / {} chunks / {} B in {:.1}ms (root manifest only)",
        fs.file_count(),
        fs.chunk_count(),
        fs.total_bytes(),
        t1.elapsed().as_secs_f64() * 1e3
    );
    for p in [&paths[0], &paths[n_files / 2], &paths[n_files - 1]] {
        let v = fs.read_file(p)?;
        assert_eq!(v.len(), file_bytes);
        println!("  read {p}: {} B ok", v.len());
    }
    println!("lazy shard loads so far: {}", fs.stats.shard_loads.get());
    Ok(())
}
