//! §IV.A preprocessing, end to end.
//!
//! The paper uploads 100M CommonCrawl text files (10 TB) to HFS and runs
//! a spaCy tokenize/filter/split pipeline on 110 × 96-core spot
//! instances. Here:
//!
//! 1. **Real pipeline:** a synthetic text corpus goes through HFS and the
//!    rust ETL pipeline (paragraph split → filter → tokenize → records),
//!    measured for real on this machine.
//! 2. **Fleet level (simulated):** the full 10 TB / 110-node run with per-
//!    shard cost anchored to the real measurement, spot preemptions on.
//!
//! Run with: `cargo run --release --example preprocess_etl`

use std::sync::Arc;

use hyper_dist::cluster::Master;
use hyper_dist::etl::{preprocess_shard, RecordReader};
use hyper_dist::hfs::{HyperFs, Uploader};
use hyper_dist::scheduler::{SimDriver, SimDriverConfig};
use hyper_dist::sim::SimRng;
use hyper_dist::storage::{MemStore, StoreHandle};

const WORDS: &[&str] = &[
    "stream", "tensor", "cloud", "shard", "model", "train", "batch", "cache", "spot",
    "chunk", "object", "storage", "worker", "deep", "learning", "data",
];

fn synth_doc(rng: &mut SimRng, paragraphs: usize) -> String {
    let mut out = String::new();
    for _ in 0..paragraphs {
        let words = 5 + rng.gen_range(60) as usize;
        for _ in 0..words {
            out.push_str(WORDS[rng.gen_range(WORDS.len() as u64) as usize]);
            out.push(' ');
        }
        out.push_str("\n\n");
    }
    out
}

fn main() -> anyhow::Result<()> {
    // ---- real pipeline over HFS ----------------------------------------
    println!("== real ETL over HFS ==");
    let store: StoreHandle = Arc::new(MemStore::new());
    let mut rng = SimRng::new(42);
    let mut up = Uploader::new(store.clone(), "cc", 2 << 20);
    let n_files = 2000;
    for i in 0..n_files {
        up.add_file(&format!("crawl/{i:06}.txt"), synth_doc(&mut rng, 6).as_bytes())?;
    }
    let manifest = up.seal()?;
    println!(
        "corpus: {} files, {:.1} MB, {} chunks",
        manifest.file_count(),
        manifest.total_bytes() as f64 / 1e6,
        manifest.chunks.len()
    );
    let fs = HyperFs::mount(store.clone(), "cc", 64 << 20)?;
    let t0 = std::time::Instant::now();
    let (shard, report) = preprocess_shard(&fs, "crawl/", 8)?;
    let dt = t0.elapsed().as_secs_f64();
    let mb_per_s = report.bytes_in as f64 / 1e6 / dt;
    println!(
        "processed {} files / {} paragraphs / {} tokens in {:.2}s ({:.0} MB/s/core)",
        report.files_in, report.paragraphs, report.tokens, dt, mb_per_s
    );
    println!(
        "filtered {} short paragraphs; shard: {} records, {:.1} MB",
        report.filtered,
        RecordReader::trailer_count(&shard).unwrap_or(0),
        report.bytes_out as f64 / 1e6
    );
    store.put("tfrecords/shard-000", &shard)?;

    // ---- fleet level -----------------------------------------------------
    println!("\n== simulated 10 TB fleet run (110 x m5.24xlarge spot) ==");
    // paper: 100M files / 10 TB; script takes 100k files per task -> 1000 tasks
    let tasks = 1000u64;
    let bytes_per_task = 10_000_000_000_000u64 / tasks;
    // anchor: measured single-core MB/s, 96 cores per node, one task/node-slot
    let task_cpu_s = bytes_per_task as f64 / 1e6 / mb_per_s / 96.0;
    let recipe = format!(
        r#"
name: commoncrawl-etl
experiments:
  - name: preprocess
    instance: m5.24xlarge
    workers: 110
    spot: true
    command: "spacy-prep --shard {{shard}}"
    params: {{ shard: {{ range: [0, {}] }} }}
    work: {{ duration_s: {task_cpu_s:.1}, input_bytes: {bytes_per_task} }}
"#,
        tasks - 1
    );
    let master = Master::new();
    let name = master.submit(&recipe, 3)?;
    let mut wf = master.workflow(&name)?;
    let mut driver = SimDriver::new(SimDriverConfig {
        slots_per_node: 4, // 4 concurrent 24-core shard tasks per box
        seed: 3,
        ..Default::default()
    });
    let r = driver.run(&mut wf)?;
    println!(
        "complete={} makespan={:.1} min cost=${:.0} preemptions={} reschedules={} \
         throughput={:.2} GB/s aggregate",
        r.workflow_complete,
        r.makespan_s / 60.0,
        r.total_cost_usd,
        r.preemptions,
        r.reschedules,
        10_000.0 / r.makespan_s
    );
    assert!(r.workflow_complete);
    Ok(())
}
