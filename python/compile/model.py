"""Layer-2: the training / inference compute graph, in JAX, on the L1 kernels.

This is the "client workload" of the Hyper paper — the deep-learning job
that the rust coordination plane schedules, feeds from the Hyper File
System, and checkpoints across spot preemptions.  The paper's evaluation
uses PyTorch models (YoloV3, VGG, ResNet, DenseNet); per DESIGN.md
§Substitutions we use a decoder-only transformer LM whose forward pass is
built entirely from the Pallas kernels, so the same HLO exercises L1.

Exports per preset, AOT-lowered by ``aot.py``:

* ``init_fn(seed)``                    -> flat params (+ Adam m/v zeros, step)
* ``train_step(state..., tokens, lr)`` -> new state... + loss
* ``eval_step(params..., tokens)``     -> loss
* ``infer_step(params..., tokens)``    -> last-position logits

State crosses the rust boundary as a *flat ordered tuple* of arrays; the
ordering is fixed by ``param_names()`` and recorded in the manifest so
the rust runtime can address individual tensors (e.g. for checkpoints).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import fused_attention, fused_layernorm, fused_linear


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer hyperparameters (a preset of the model zoo)."""

    name: str
    vocab: int
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int
    seq_len: int
    batch: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        """Exact trainable-parameter count (embeddings tied to the head)."""
        per_layer = (
            2 * self.d_model  # ln1 gamma/beta
            + self.d_model * 3 * self.d_model + 3 * self.d_model  # qkv
            + self.d_model * self.d_model + self.d_model  # attn proj
            + 2 * self.d_model  # ln2
            + self.d_model * self.d_ff + self.d_ff  # ff up
            + self.d_ff * self.d_model + self.d_model  # ff down
        )
        return (
            self.vocab * self.d_model  # tied token embedding / head
            + self.seq_len * self.d_model  # learned positions
            + self.n_layers * per_layer
            + 2 * self.d_model  # final ln
        )

    def flops_per_token(self) -> int:
        """~6N fwd+bwd FLOPs per token (standard decoder estimate) + attention."""
        attn = 12 * self.n_layers * self.d_model * self.seq_len
        return 6 * self.param_count() + attn


PRESETS: Dict[str, ModelConfig] = {
    # test-scale: fast enough for pytest / quickstart
    "tiny": ModelConfig("tiny", vocab=512, d_model=64, n_heads=4, n_layers=2,
                        d_ff=256, seq_len=64, batch=8),
    # e2e training preset (~4.9M params)
    "small": ModelConfig("small", vocab=4096, d_model=256, n_heads=8, n_layers=4,
                         d_ff=1024, seq_len=128, batch=8),
    # ~33M params; same code path, used for anchored scaling runs
    "base": ModelConfig("base", vocab=16384, d_model=512, n_heads=8, n_layers=8,
                        d_ff=2048, seq_len=128, batch=4),
    # ~110M params; manifest-only by default (AOT on demand)
    "large": ModelConfig("large", vocab=32768, d_model=768, n_heads=12, n_layers=12,
                         d_ff=3072, seq_len=128, batch=2),
}


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the contract with the rust runtime."""
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos", (cfg.seq_len, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1_g", (cfg.d_model,)),
            (p + "ln1_b", (cfg.d_model,)),
            (p + "qkv_w", (cfg.d_model, 3 * cfg.d_model)),
            (p + "qkv_b", (3 * cfg.d_model,)),
            (p + "proj_w", (cfg.d_model, cfg.d_model)),
            (p + "proj_b", (cfg.d_model,)),
            (p + "ln2_g", (cfg.d_model,)),
            (p + "ln2_b", (cfg.d_model,)),
            (p + "ff1_w", (cfg.d_model, cfg.d_ff)),
            (p + "ff1_b", (cfg.d_ff,)),
            (p + "ff2_w", (cfg.d_ff, cfg.d_model)),
            (p + "ff2_b", (cfg.d_model,)),
        ]
    specs += [("lnf_g", (cfg.d_model,)), ("lnf_b", (cfg.d_model,))]
    return specs


def param_names(cfg: ModelConfig) -> List[str]:
    return [n for n, _ in param_specs(cfg)]


def init_params(seed, cfg: ModelConfig) -> List[jax.Array]:
    """Initialize the flat parameter list from an int32 seed (pure-HLO RNG)."""
    key = jax.random.PRNGKey(seed)
    out: List[jax.Array] = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        base = name.rsplit(".", 1)[-1]
        if base.endswith("_g"):
            out.append(jnp.ones(shape, jnp.float32))
        elif base.endswith("_b"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = 0.02 if name in ("embed", "pos") else (1.0 / jnp.sqrt(fan_in))
            out.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return out


def _as_dict(cfg: ModelConfig, flat) -> Dict[str, jax.Array]:
    return dict(zip(param_names(cfg), flat))


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------

def forward(flat_params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Logits ``(B, S, V)`` for int32 tokens ``(B, S)``."""
    p = _as_dict(cfg, flat_params)
    b, s = tokens.shape
    h = p["embed"][tokens] + p["pos"][None, :s, :]
    for i in range(cfg.n_layers):
        lp = f"layer{i}."
        x = fused_layernorm(h, p[lp + "ln1_g"], p[lp + "ln1_b"])
        qkv = fused_linear(x.reshape(b * s, -1), p[lp + "qkv_w"], p[lp + "qkv_b"])
        qkv = qkv.reshape(b, s, 3, cfg.n_heads, cfg.head_dim)
        q = qkv[:, :, 0].transpose(0, 2, 1, 3)
        k = qkv[:, :, 1].transpose(0, 2, 1, 3)
        v = qkv[:, :, 2].transpose(0, 2, 1, 3)
        a = fused_attention(q, k, v, causal=True)
        a = a.transpose(0, 2, 1, 3).reshape(b * s, cfg.d_model)
        a = fused_linear(a, p[lp + "proj_w"], p[lp + "proj_b"]).reshape(b, s, -1)
        h = h + a
        x = fused_layernorm(h, p[lp + "ln2_g"], p[lp + "ln2_b"])
        f = fused_linear(x.reshape(b * s, -1), p[lp + "ff1_w"], p[lp + "ff1_b"],
                         activation="gelu")
        f = fused_linear(f, p[lp + "ff2_w"], p[lp + "ff2_b"]).reshape(b, s, -1)
        h = h + f
    h = fused_layernorm(h, p["lnf_g"], p["lnf_b"])
    return jnp.einsum("bsd,vd->bsv", h, p["embed"])  # tied head


def loss_fn(flat_params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Mean next-token cross-entropy over ``(B, S)`` int32 tokens."""
    logits = forward(flat_params, tokens, cfg)[:, :-1, :]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


# --------------------------------------------------------------------------
# step functions (the AOT exports)
# --------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def train_step(params, m, v, step, tokens, lr, cfg: ModelConfig):
    """One fused fwd+bwd+Adam step.

    Args:
        params / m / v: flat lists in ``param_names`` order.
        step: f32 scalar Adam timestep (pre-increment).
        tokens: int32 ``(B, S)`` batch.
        lr: f32 scalar learning rate.

    Returns:
        (new_params, new_m, new_v, new_step, loss)
    """
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(ps, tokens, cfg))(list(params))
    t = step + 1.0
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_p, new_m, new_v = [], [], []
    for pi, mi, vi, gi in zip(params, m, v, grads):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * gi
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * gi * gi
        update = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        new_p.append(pi - update)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, t, loss


def eval_step(params, tokens, cfg: ModelConfig):
    """Loss only — used for validation passes from rust."""
    return loss_fn(list(params), tokens, cfg)


def infer_step(params, tokens, cfg: ModelConfig):
    """Last-position logits ``(B, V)`` — the serving/inference export."""
    logits = forward(list(params), tokens, cfg)
    return logits[:, -1, :]
