"""AOT compiler: lower every step function of every preset to HLO text.

Interchange format is HLO *text*, not serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/gen_hlo.py for the reference wiring.

Outputs, per preset P in ``--presets``:

    artifacts/P_init.hlo.txt    (seed:i32[])                  -> (params..., m..., v..., step)
    artifacts/P_train.hlo.txt   (params...,m...,v...,step,tokens,lr) -> (params...,m...,v...,step,loss)
    artifacts/P_eval.hlo.txt    (params..., tokens)           -> (loss,)
    artifacts/P_infer.hlo.txt   (params..., tokens)           -> (logits,)
    artifacts/manifest.json     shapes / ordering / flops — the rust contract

Run via ``make artifacts``; never imported at runtime.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from typing import Callable, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _flat_shapes(cfg: M.ModelConfig):
    return [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in M.param_specs(cfg)]


def lower_init(cfg: M.ModelConfig):
    def init(seed):
        params = M.init_params(seed, cfg)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        return (*params, *m, *v, jnp.zeros((), jnp.float32))

    return jax.jit(init).lower(jax.ShapeDtypeStruct((), jnp.int32))


def lower_train(cfg: M.ModelConfig):
    n = len(M.param_specs(cfg))

    def step(*args):
        params = list(args[:n])
        m = list(args[n : 2 * n])
        v = list(args[2 * n : 3 * n])
        t, tokens, lr = args[3 * n], args[3 * n + 1], args[3 * n + 2]
        new_p, new_m, new_v, new_t, loss = M.train_step(params, m, v, t, tokens, lr, cfg)
        return (*new_p, *new_m, *new_v, new_t, loss)

    flat = _flat_shapes(cfg)
    args = (
        *flat,
        *flat,
        *flat,
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    return jax.jit(step).lower(*args)


def lower_eval(cfg: M.ModelConfig):
    def step(*args):
        return (M.eval_step(list(args[:-1]), args[-1], cfg),)

    args = (*_flat_shapes(cfg), jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32))
    return jax.jit(step).lower(*args)


def lower_infer(cfg: M.ModelConfig):
    def step(*args):
        return (M.infer_step(list(args[:-1]), args[-1], cfg),)

    args = (*_flat_shapes(cfg), jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32))
    return jax.jit(step).lower(*args)


LOWERINGS: dict[str, Callable] = {
    "init": lower_init,
    "train": lower_train,
    "eval": lower_eval,
    "infer": lower_infer,
}


def preset_manifest(cfg: M.ModelConfig) -> dict:
    specs = M.param_specs(cfg)
    return {
        "name": cfg.name,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers,
        "d_ff": cfg.d_ff,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "param_count": cfg.param_count(),
        "flops_per_token": cfg.flops_per_token(),
        "params": [{"name": n, "shape": list(s)} for n, s in specs],
        "n_tensors": len(specs),
        "artifacts": {fn: f"{cfg.name}_{fn}.hlo.txt" for fn in LOWERINGS},
        # train io layout: params(n) m(n) v(n) step tokens lr -> params(n) m(n) v(n) step loss
        "train_inputs": 3 * len(specs) + 3,
        "train_outputs": 3 * len(specs) + 2,
    }


def _inputs_fingerprint() -> str:
    """Hash of the compile-path sources, for Makefile-level staleness."""
    import hashlib

    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--presets", default="tiny,small",
                    help="comma-separated preset names (see model.PRESETS)")
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    presets = [p.strip() for p in args.presets.split(",") if p.strip()]
    manifest = {"presets": {}, "fingerprint": _inputs_fingerprint()}

    for name in presets:
        cfg = M.PRESETS[name]
        for fn, lower in LOWERINGS.items():
            path = os.path.join(out_dir, f"{name}_{fn}.hlo.txt")
            text = to_hlo_text(lower(cfg))
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text) / 1e6:.2f} MB)")
        manifest["presets"][name] = preset_manifest(cfg)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
