"""Build-time Python for the Hyper reproduction (never on the request path)."""
