"""Fused scaled-dot-product attention as a Pallas kernel (flash-style).

TPU adaptation of the paper-era CUDA attention pattern: rather than
relying on warp shuffles + shared-memory softmax, each grid step owns one
``(block_q, head_dim)`` query tile resident in VMEM and streams the K/V
sequence through it in ``block_k`` chunks with an *online softmax*
(running max ``m`` and normalizer ``l``), so logits never materialize in
HBM.  The causal variant masks with block-level iota comparisons instead
of a materialized (S, S) mask.

VMEM footprint per grid step (f32):
    block_q*d + S*d (K stripe) + S*d (V stripe) + block_q*block_k + acc
For S<=1024, d<=128, block_q=128: <= ~1.2 MB — far under the VMEM budget,
so the whole K/V stripe for one (batch, head) is kept resident and the
online-softmax loop walks it in registers-equivalent blocks.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool, block_k: int):
    """One (1, block_q, d) query tile against the full (1, S, d) K/V stripe."""
    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (S, d)
    v = v_ref[0]  # (S, d)
    bq, d = q.shape
    s = k.shape[0]
    n_blocks = s // block_k

    q_ids = jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
    q_start = pl.program_id(1) * bq

    def body(kb, carry):
        acc, m_i, l_i = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k, kb * block_k, block_k, axis=0)
        v_blk = jax.lax.dynamic_slice_in_dim(v, kb * block_k, block_k, axis=0)
        logits = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32) * scale
        if causal:
            k_ids = jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
            mask = (q_start + q_ids) >= (kb * block_k + k_ids)
            logits = jnp.where(mask, logits, _NEG_INF)
        m_new = jnp.maximum(m_i, logits.max(axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + jnp.dot(p, v_blk.astype(jnp.float32))
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc, _, l_i = jax.lax.fori_loop(0, n_blocks, body, (acc0, m0, l0))
    o_ref[0] = (acc / l_i).astype(o_ref.dtype)


def _attn_pallas(q, k, v, causal: bool, scale: float, block_q: int, block_k: int):
    b, h, s, d = q.shape
    bq = min(block_q, s)
    while s % bq:
        bq //= 2
    bk = min(block_k, s)
    while s % bk:
        bk //= 2

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    grid = (b * h, s // bq)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal, block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, s, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, s, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _attn_vjp(causal, scale, block_q, block_k, q, k, v):
    return _attn_pallas(q, k, v, causal, scale, block_q, block_k)


def _attn_fwd(causal, scale, block_q, block_k, q, k, v):
    out = _attn_pallas(q, k, v, causal, scale, block_q, block_k)
    return out, (q, k, v)


def _attn_bwd(causal, scale, block_q, block_k, res, do):
    # Exact VJP of softmax attention with rematerialized (masked) logits.
    # The fwd hot path stays fully kernelized; at training-time seq lengths
    # the (S, S) recompute is a single fused XLA matmul chain.
    q, k, v = res
    s = q.shape[2]
    qf, kf, vf, dof = (t.astype(jnp.float32) for t in (q, k, v, do))
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask, logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
    dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vf)
    ds = p * (dp - (dp * p).sum(axis=-1, keepdims=True))
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, kf) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, qf) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_attn_vjp.defvjp(_attn_fwd, _attn_bwd)


def fused_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """``softmax(q @ k^T * scale) @ v`` fused, per (batch, head).

    Differentiable: forward is the flash-style Pallas kernel; backward is
    the exact softmax-attention VJP with rematerialized logits (see
    ``_attn_bwd``).

    Args:
        q, k, v: ``(B, H, S, D)`` tensors (same S for q and k/v).
        causal: apply a causal (lower-triangular) mask.
        scale: logit scale; defaults to ``1/sqrt(D)``.
        block_q / block_k: VMEM tile sizes along the two sequence axes.

    Returns:
        ``(B, H, S, D)`` attention output, dtype of ``q``.
    """
    if q.ndim != 4 or k.shape != q.shape or v.shape != q.shape:
        raise ValueError(f"expected matching (B,H,S,D); got {q.shape} {k.shape} {v.shape}")
    d = q.shape[3]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    return _attn_vjp(causal, float(scale), block_q, block_k, q, k, v)
