"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

These are deliberately the most direct possible translations of the math
(no tiling, no online softmax, no padding tricks) so that any divergence
in the kernels is a kernel bug, not an oracle bug.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gelu_ref(x: jax.Array) -> jax.Array:
    """tanh-approximate GELU (matches fused_linear's epilogue)."""
    c = jnp.asarray(0.7978845608028654, x.dtype)  # sqrt(2/pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def linear_ref(x, w, b, *, activation: str = "none") -> jax.Array:
    """``act(x @ w + b)`` in full precision."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "gelu":
        y = gelu_ref(y)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y.astype(x.dtype)


def attention_ref(q, k, v, *, causal: bool = False, scale: float | None = None) -> jax.Array:
    """Materialized-logits softmax attention over (B, H, S, D)."""
    b, h, s, d = q.shape
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def layernorm_ref(x, gamma, beta, *, eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the last axis in full precision."""
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mean) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return y.astype(x.dtype)
