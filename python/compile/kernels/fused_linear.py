"""Fused linear layer: ``act(x @ w + b)`` as a single tiled Pallas kernel.

TPU adaptation of the CUDA "GEMM + epilogue fusion" the paper's frameworks
rely on: instead of threadblock tiles in shared memory, the (M, N) output
is tiled into MXU-aligned ``block_m x block_n`` blocks; BlockSpec index
maps express the HBM->VMEM schedule (each grid step stages one
``(block_m, K)`` stripe of ``x`` and one ``(K, block_n)`` stripe of ``w``
into VMEM), and the bias add + activation run in the same VMEM-resident
pass so the epilogue never round-trips through HBM.

Autodiff: ``pallas_call`` has no VJP rule, so the public entry point is a
``jax.custom_vjp``.  The backward pass is *also* kernelized — dx and dw
are tiled Pallas matmuls (``dx = dpre @ w^T``, ``dw = x^T @ dpre``); the
activation derivative rematerializes the pre-activation with one extra
kernel call (flash-style remat: cheaper than saving the (M, N) buffer).

VMEM footprint per grid step (f32):
    block_m*K + K*block_n + block_m*block_n + block_n  floats
With the default 128x128 blocks and K<=4096 this is <= 4.3 MB, well under
the ~16 MB/core VMEM budget, leaving room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, preferred: int) -> int:
    """Largest power-of-two block <= preferred (>=1)."""
    b = preferred
    while b > dim:
        b //= 2
    return max(b, 1)


def _gelu(y):
    c = jnp.asarray(0.7978845608028654, y.dtype)  # sqrt(2/pi)
    return 0.5 * y * (1.0 + jnp.tanh(c * (y + 0.044715 * y * y * y)))


def _gelu_grad(y):
    """d gelu(y) / dy for the tanh approximation."""
    c = 0.7978845608028654
    t = jnp.tanh(c * (y + 0.044715 * y**3))
    dt = (1.0 - t * t) * c * (1.0 + 3 * 0.044715 * y * y)
    return 0.5 * (1.0 + t) + 0.5 * y * dt


def _apply_activation(y, activation: str):
    if activation == "none":
        return y
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "gelu":
        return _gelu(y)
    raise ValueError(f"unknown activation {activation!r}")


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One (block_m, block_n) output tile: full-K contraction + epilogue."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)
    acc = _apply_activation(acc, activation)
    o_ref[...] = acc.astype(o_ref.dtype)


def _pallas_matmul_bias(x2, w, b, activation: str, block_m: int, block_n: int):
    """act(x2 @ w + b) on 2-D operands via the tiled kernel (with padding)."""
    m, k = x2.shape
    _, n = w.shape
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    m_pad = (-m) % bm
    n_pad = (-n) % bn
    xp = jnp.pad(x2, ((0, m_pad), (0, 0))) if m_pad else x2
    wp = jnp.pad(w, ((0, 0), (0, n_pad))) if n_pad else w
    bp = jnp.pad(b, (0, n_pad)) if n_pad else b
    mp, np_ = m + m_pad, n + n_pad

    out = pl.pallas_call(
        functools.partial(_linear_kernel, activation=activation),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x2.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, bp.reshape(1, -1))
    return out[:m, :n]


def matmul(a: jax.Array, b: jax.Array, *, block_m: int = 128, block_n: int = 128) -> jax.Array:
    """Plain tiled Pallas matmul (zero bias, no activation) — bwd workhorse."""
    zero = jnp.zeros((b.shape[1],), a.dtype)
    return _pallas_matmul_bias(a, b, zero, "none", block_m, block_n)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _linear_vjp(activation, block_m, block_n, x2, w, b):
    return _pallas_matmul_bias(x2, w, b, activation, block_m, block_n)


def _linear_fwd(activation, block_m, block_n, x2, w, b):
    out = _pallas_matmul_bias(x2, w, b, activation, block_m, block_n)
    return out, (x2, w, b)


def _linear_bwd(activation, block_m, block_n, res, dy):
    x2, w, b = res
    if activation == "none":
        dpre = dy
    else:
        # rematerialize the pre-activation with one kernel call
        pre = _pallas_matmul_bias(x2, w, b, "none", block_m, block_n)
        if activation == "relu":
            dpre = dy * (pre > 0).astype(dy.dtype)
        else:  # gelu
            dpre = dy * _gelu_grad(pre.astype(jnp.float32)).astype(dy.dtype)
    dx = matmul(dpre, w.T, block_m=block_m, block_n=block_n)
    dw = matmul(x2.T, dpre, block_m=block_m, block_n=block_n)
    db = dpre.sum(axis=0)
    return dx, dw, db


_linear_vjp.defvjp(_linear_fwd, _linear_bwd)


def fused_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    activation: str = "none",
    block_m: int = 128,
    block_n: int = 128,
) -> jax.Array:
    """``act(x @ w + b)`` with a tiled Pallas kernel (differentiable).

    Args:
        x: ``(..., K)`` input (leading dims are flattened into M).
        w: ``(K, N)`` weights.
        b: ``(N,)`` bias.
        activation: ``"none" | "relu" | "gelu"`` fused epilogue.
        block_m / block_n: output tile shape; clamped to the problem size
            and padded up so arbitrary M, N are supported.

    Returns:
        ``(..., N)`` with the same leading dims as ``x``.
    """
    if x.ndim < 1:
        raise ValueError("x must have at least 1 dim")
    if w.ndim != 2 or b.ndim != 1:
        raise ValueError("w must be (K, N), b must be (N,)")
    k = x.shape[-1]
    if w.shape[0] != k:
        raise ValueError(f"contraction mismatch: x K={k} vs w K={w.shape[0]}")
    if b.shape[0] != w.shape[1]:
        raise ValueError(f"bias mismatch: N={w.shape[1]} vs b={b.shape[0]}")
    if activation not in ("none", "relu", "gelu"):
        raise ValueError(f"unknown activation {activation!r}")
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    out = _linear_vjp(activation, block_m, block_n, x2, w, b)
    return out.reshape(*lead, w.shape[1])
