"""Layer-1 Pallas kernels for the Hyper reproduction.

Every kernel here is authored for TPU idioms (MXU-aligned tiles staged
through VMEM via BlockSpec) but executed with ``interpret=True`` on this
image: the CPU PJRT plugin cannot run Mosaic custom-calls, so interpret
mode lowers each kernel to plain HLO that any backend executes.  TPU
efficiency is estimated analytically in DESIGN.md / EXPERIMENTS.md §Perf.

Correctness for every kernel is pinned against the pure-jnp oracles in
``kernels.ref`` by ``python/tests/test_kernels.py``.
"""

from .fused_linear import fused_linear
from .attention import fused_attention
from .layernorm import fused_layernorm

__all__ = ["fused_linear", "fused_attention", "fused_layernorm"]
