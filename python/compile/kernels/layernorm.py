"""Fused LayerNorm as a Pallas kernel (differentiable).

TPU adaptation: one grid step owns a ``(block_rows, F)`` tile in VMEM and
computes mean / variance / normalize / scale / shift in a single pass —
the role CUDA implementations give to a blockwide Welford reduction in
shared memory.  Keeping the full feature axis in the tile means the row
statistics never leave VMEM.

Autodiff: public entry point is a ``jax.custom_vjp``.  ``dx`` is computed
by a second Pallas kernel that rematerializes the row statistics in VMEM
(cheaper than saving mean/inv); ``dgamma``/``dbeta`` are column
reductions across all rows and are left to XLA (a single fused reduce).

VMEM per grid step (f32): ``block_rows * F * 2 + 2*F`` floats; with the
default 128 rows and F<=4096 that is <= 4.2 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mean = x.mean(axis=-1, keepdims=True)
    xc = x - mean
    var = (xc * xc).mean(axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = xc * inv * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _ln_bwd_kernel(x_ref, g_ref, dy_ref, dx_ref, *, eps: float):
    """dx for one row tile, rematerializing mean/inv in VMEM."""
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    mean = x.mean(axis=-1, keepdims=True)
    xc = x - mean
    var = (xc * xc).mean(axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = xc * inv
    dxhat = dy * g
    mdxhat = dxhat.mean(axis=-1, keepdims=True)
    mdxx = (dxhat * xhat).mean(axis=-1, keepdims=True)
    dx = inv * (dxhat - mdxhat - xhat * mdxx)
    dx_ref[...] = dx.astype(dx_ref.dtype)


def _pick_rows(r: int, preferred: int) -> int:
    br = min(preferred, r)
    while br > 1 and r % br:
        br //= 2
    return max(br, 1)


def _ln_call(kernel, x2, g, extra, eps: float, block_rows: int):
    """Shared pallas_call plumbing for fwd (extra=beta) and bwd (extra=dy)."""
    r, f = x2.shape
    br = _pick_rows(r, block_rows)
    pad = (-r) % br
    xp = jnp.pad(x2, ((0, pad), (0, 0))) if pad else x2
    ep = jnp.pad(extra, ((0, pad), (0, 0))) if (pad and extra.shape[0] == r) else extra
    rp = r + pad
    row_spec = pl.BlockSpec((br, f), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((1, f), lambda i: (0, 0))
    extra_spec = row_spec if extra.shape[0] in (r, rp) else vec_spec
    out = pl.pallas_call(
        functools.partial(kernel, eps=eps),
        grid=(rp // br,),
        in_specs=[row_spec, vec_spec, extra_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((rp, f), x2.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, g.reshape(1, f), ep)
    return out[:r]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _ln_vjp(eps, block_rows, x2, gamma, beta):
    return _ln_call(_ln_kernel, x2, gamma, beta.reshape(1, -1), eps, block_rows)


def _ln_fwd(eps, block_rows, x2, gamma, beta):
    out = _ln_call(_ln_kernel, x2, gamma, beta.reshape(1, -1), eps, block_rows)
    return out, (x2, gamma)


def _ln_bwd(eps, block_rows, res, dy):
    x2, gamma = res
    dx = _ln_call(_ln_bwd_kernel, x2, gamma, dy, eps, block_rows)
    # row statistics for dgamma: xhat recomputed once in fused XLA ops
    xf = x2.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    xc = xf - mean
    var = (xc * xc).mean(axis=-1, keepdims=True)
    xhat = xc * jax.lax.rsqrt(var + eps)
    dyf = dy.astype(jnp.float32)
    dgamma = (dyf * xhat).sum(axis=0).astype(gamma.dtype)
    dbeta = dyf.sum(axis=0).astype(gamma.dtype)
    return dx, dgamma, dbeta


_ln_vjp.defvjp(_ln_fwd, _ln_bwd)


def fused_layernorm(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = 128,
) -> jax.Array:
    """LayerNorm over the last axis with a fused Pallas kernel.

    Args:
        x: ``(..., F)`` input; leading dims are flattened into rows.
        gamma, beta: ``(F,)`` scale and shift.
        eps: numerical stabilizer inside ``rsqrt``.
        block_rows: rows per VMEM tile.

    Returns:
        Same shape/dtype as ``x``.
    """
    if gamma.ndim != 1 or beta.ndim != 1:
        raise ValueError("gamma/beta must be 1-D (F,)")
    f = x.shape[-1]
    if gamma.shape[0] != f or beta.shape[0] != f:
        raise ValueError(f"feature mismatch: x F={f}, gamma={gamma.shape}, beta={beta.shape}")
    orig = x.shape
    out = _ln_vjp(eps, block_rows, x.reshape(-1, f), gamma, beta)
    return out.reshape(orig)
