"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes / dtypes / block sizes; explicit cases pin the
shapes the model actually uses.  This is the CORE correctness signal for
the compute layer — the AOT HLO contains exactly these kernels.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_attention, fused_layernorm, fused_linear, ref
from compile.kernels.fused_linear import matmul

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------- fused_linear

class TestFusedLinear:
    @pytest.mark.parametrize("activation", ["none", "relu", "gelu"])
    @pytest.mark.parametrize("shape", [(8, 16, 32), (128, 256, 64), (100, 96, 80)])
    def test_matches_ref(self, activation, shape):
        m, k, n = shape
        x, w, b = rand(0, (m, k)), rand(1, (k, n)), rand(2, (n,))
        out = fused_linear(x, w, b, activation=activation)
        np.testing.assert_allclose(out, ref.linear_ref(x, w, b, activation=activation),
                                   rtol=2e-5, atol=2e-5)

    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 200),
        k=st.integers(1, 96),
        n=st.integers(1, 120),
        bm=st.sampled_from([8, 32, 128]),
        bn=st.sampled_from([8, 32, 128]),
        act=st.sampled_from(["none", "relu", "gelu"]),
    )
    def test_hypothesis_shapes_blocks(self, m, k, n, bm, bn, act):
        x, w, b = rand(0, (m, k)), rand(1, (k, n)), rand(2, (n,))
        out = fused_linear(x, w, b, activation=act, block_m=bm, block_n=bn)
        np.testing.assert_allclose(out, ref.linear_ref(x, w, b, activation=act),
                                   rtol=3e-5, atol=3e-5)

    def test_leading_dims_flattened(self):
        x, w, b = rand(0, (4, 6, 32)), rand(1, (32, 16)), rand(2, (16,))
        out = fused_linear(x, w, b)
        assert out.shape == (4, 6, 16)
        np.testing.assert_allclose(out.reshape(24, 16),
                                   ref.linear_ref(x.reshape(24, 32), w, b),
                                   rtol=2e-5, atol=2e-5)

    def test_block_size_invariance(self):
        x, w, b = rand(0, (64, 48), scale=2.0), rand(1, (48, 64)), rand(2, (64,))
        a = fused_linear(x, w, b, activation="gelu", block_m=8, block_n=8)
        c = fused_linear(x, w, b, activation="gelu", block_m=128, block_n=128)
        # tile shape changes the f32 reduction order; agreement is to ~1e-5
        np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("argnum", [0, 1, 2])
    def test_gradients_match_ref(self, argnum):
        x, w, b = rand(0, (32, 24)), rand(1, (24, 40)), rand(2, (40,))

        def f_k(*args):
            return (fused_linear(*args, activation="gelu", block_m=16, block_n=16) ** 2).sum()

        def f_r(*args):
            return (ref.linear_ref(*args, activation="gelu") ** 2).sum()

        gk = jax.grad(f_k, argnums=argnum)(x, w, b)
        gr = jax.grad(f_r, argnums=argnum)(x, w, b)
        np.testing.assert_allclose(gk, gr, rtol=1e-3, atol=1e-3)

    def test_relu_gradient(self):
        x, w, b = rand(0, (16, 8)), rand(1, (8, 8)), rand(2, (8,))
        gk = jax.grad(lambda x: fused_linear(x, w, b, activation="relu").sum())(x)
        gr = jax.grad(lambda x: ref.linear_ref(x, w, b, activation="relu").sum())(x)
        np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-4)

    def test_bf16(self):
        x = rand(0, (32, 32), jnp.bfloat16)
        w = rand(1, (32, 32), jnp.bfloat16)
        b = rand(2, (32,), jnp.bfloat16)
        out = fused_linear(x, w, b)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(out.astype(np.float32),
                                   ref.linear_ref(x, w, b).astype(np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_shape_errors(self):
        x, w, b = rand(0, (8, 8)), rand(1, (8, 8)), rand(2, (8,))
        with pytest.raises(ValueError, match="contraction"):
            fused_linear(rand(0, (8, 4)), w, b)
        with pytest.raises(ValueError, match="bias"):
            fused_linear(x, w, rand(2, (4,)))
        with pytest.raises(ValueError, match="activation"):
            fused_linear(x, w, b, activation="swish")

    def test_matmul_helper(self):
        a, b = rand(0, (33, 17)), rand(1, (17, 29))
        np.testing.assert_allclose(matmul(a, b), a @ b, rtol=2e-5, atol=2e-5)

    def test_jit_composes(self):
        x, w, b = rand(0, (32, 16)), rand(1, (16, 16)), rand(2, (16,))
        f = jax.jit(lambda x: fused_linear(x, w, b, activation="gelu"))
        np.testing.assert_allclose(f(x), ref.linear_ref(x, w, b, activation="gelu"),
                                   rtol=2e-5, atol=2e-5)


# -------------------------------------------------------------- fused_attention

class TestFusedAttention:
    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize("shape", [(1, 1, 16, 8), (2, 4, 64, 32), (2, 8, 128, 32)])
    def test_matches_ref(self, causal, shape):
        q, k, v = rand(0, shape), rand(1, shape), rand(2, shape)
        out = fused_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(out, ref.attention_ref(q, k, v, causal=causal),
                                   rtol=2e-5, atol=2e-5)

    @settings(**SETTINGS)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 4),
        s=st.sampled_from([8, 16, 32, 64, 96]),
        d=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
        bq=st.sampled_from([8, 32, 128]),
        bk=st.sampled_from([8, 32, 128]),
    )
    def test_hypothesis(self, b, h, s, d, causal, bq, bk):
        shape = (b, h, s, d)
        q, k, v = rand(0, shape), rand(1, shape), rand(2, shape)
        out = fused_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
        np.testing.assert_allclose(out, ref.attention_ref(q, k, v, causal=causal),
                                   rtol=3e-5, atol=3e-5)

    def test_custom_scale(self):
        shape = (1, 2, 32, 16)
        q, k, v = rand(0, shape), rand(1, shape), rand(2, shape)
        out = fused_attention(q, k, v, scale=0.5)
        np.testing.assert_allclose(out, ref.attention_ref(q, k, v, scale=0.5),
                                   rtol=2e-5, atol=2e-5)

    def test_causal_is_actually_causal(self):
        """Perturbing future keys/values must not change earlier outputs."""
        shape = (1, 1, 32, 8)
        q, k, v = rand(0, shape), rand(1, shape), rand(2, shape)
        out1 = fused_attention(q, k, v, causal=True)
        k2 = k.at[:, :, 20:, :].set(99.0)
        v2 = v.at[:, :, 20:, :].set(-99.0)
        out2 = fused_attention(q, k2, v2, causal=True)
        np.testing.assert_allclose(out1[:, :, :20], out2[:, :, :20], rtol=1e-5, atol=1e-5)
        assert not np.allclose(out1[:, :, 20:], out2[:, :, 20:])

    def test_softmax_rows_sum_to_one(self):
        """With v = ones, attention output must be exactly ones."""
        shape = (2, 2, 64, 16)
        q, k = rand(0, shape, scale=3.0), rand(1, shape, scale=3.0)
        out = fused_attention(q, k, jnp.ones(shape), causal=True, block_k=16)
        np.testing.assert_allclose(out, np.ones(shape), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("causal", [False, True])
    def test_gradients_match_ref(self, causal):
        shape = (2, 2, 32, 16)
        q, k, v = rand(0, shape), rand(1, shape), rand(2, shape)

        def f_k(q, k, v):
            return (fused_attention(q, k, v, causal=causal, block_q=16, block_k=8) ** 2).sum()

        def f_r(q, k, v):
            return (ref.attention_ref(q, k, v, causal=causal) ** 2).sum()

        gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)

    def test_large_logits_stable(self):
        """Online softmax must survive logits that overflow naive exp."""
        shape = (1, 1, 32, 8)
        q = rand(0, shape, scale=30.0)
        k = rand(1, shape, scale=30.0)
        v = rand(2, shape)
        out = fused_attention(q, k, v, scale=1.0, block_k=8)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(out, ref.attention_ref(q, k, v, scale=1.0),
                                   rtol=1e-4, atol=1e-4)

    def test_shape_errors(self):
        q = rand(0, (2, 2, 16, 8))
        with pytest.raises(ValueError):
            fused_attention(q, rand(1, (2, 2, 8, 8)), q)


# -------------------------------------------------------------- fused_layernorm

class TestFusedLayernorm:
    @pytest.mark.parametrize("shape", [(8, 16), (128, 256), (100, 96), (4, 6, 64)])
    def test_matches_ref(self, shape):
        x = rand(0, shape, scale=4.0)
        g, b = rand(1, (shape[-1],)), rand(2, (shape[-1],))
        out = fused_layernorm(x, g, b)
        np.testing.assert_allclose(out, ref.layernorm_ref(x, g, b), rtol=2e-5, atol=2e-5)

    @settings(**SETTINGS)
    @given(
        r=st.integers(1, 300),
        f=st.sampled_from([8, 32, 64, 100, 256]),
        br=st.sampled_from([1, 16, 128]),
    )
    def test_hypothesis(self, r, f, br):
        x = rand(0, (r, f), scale=2.0)
        g, b = rand(1, (f,)), rand(2, (f,))
        out = fused_layernorm(x, g, b, block_rows=br)
        np.testing.assert_allclose(out, ref.layernorm_ref(x, g, b), rtol=3e-5, atol=3e-5)

    def test_normalization_invariants(self):
        """gamma=1, beta=0 => rows have ~zero mean, ~unit variance."""
        x = rand(0, (64, 128), scale=7.0) + 3.0
        out = fused_layernorm(x, jnp.ones(128), jnp.zeros(128))
        np.testing.assert_allclose(np.asarray(out).mean(-1), 0.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(out).std(-1), 1.0, atol=1e-2)

    def test_gradients_match_ref(self):
        x = rand(0, (48, 32), scale=2.0)
        g, b = rand(1, (32,)), rand(2, (32,))
        wvec = jnp.arange(32, dtype=jnp.float32)

        def f_k(x, g, b):
            return (fused_layernorm(x, g, b, block_rows=16) * wvec).sum()

        def f_r(x, g, b):
            return (ref.layernorm_ref(x, g, b) * wvec).sum()

        gk = jax.grad(f_k, argnums=(0, 1, 2))(x, g, b)
        gr = jax.grad(f_r, argnums=(0, 1, 2))(x, g, b)
        for a, bb in zip(gk, gr):
            np.testing.assert_allclose(a, bb, rtol=1e-3, atol=1e-3)

    def test_eps_used(self):
        x = jnp.zeros((4, 16))
        out = fused_layernorm(x, jnp.ones(16), jnp.zeros(16), eps=1e-5)
        assert np.isfinite(np.asarray(out)).all()

    def test_shape_errors(self):
        with pytest.raises(ValueError, match="feature"):
            fused_layernorm(rand(0, (8, 16)), jnp.ones(8), jnp.zeros(8))
        with pytest.raises(ValueError, match="1-D"):
            fused_layernorm(rand(0, (8, 16)), jnp.ones((1, 16)), jnp.zeros(16))
