"""AOT pipeline: lowering produces valid HLO text + a consistent manifest."""

import json
import os

import jax
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.PRESETS["tiny"]


def entry_param_count(text: str) -> int:
    """Count parameters of the ENTRY computation only (fusions nest more)."""
    body = text.split("ENTRY", 1)[1].split("\n}", 1)[0]
    return body.count(" parameter(")


@pytest.fixture(scope="module")
def train_hlo():
    return aot.to_hlo_text(aot.lower_train(CFG))


class TestLowering:
    def test_train_hlo_text_valid(self, train_hlo):
        assert "ENTRY" in train_hlo
        assert "HloModule" in train_hlo

    def test_train_io_arity(self, train_hlo):
        n = len(M.param_specs(CFG))
        # parameter count: 3n (params, m, v) + step + tokens + lr
        assert entry_param_count(train_hlo) == 3 * n + 3

    def test_roundtrips_through_xla_parser(self, train_hlo):
        """The exact check the rust side performs: parse HLO text back."""
        from jax._src.lib import xla_client as xc
        mod = xc._xla.hlo_module_from_text(train_hlo)
        assert mod is not None

    def test_init_lowering(self):
        text = aot.to_hlo_text(aot.lower_init(CFG))
        assert "ENTRY" in text
        assert entry_param_count(text) == 1  # just the seed

    def test_eval_and_infer_lowering(self):
        n = len(M.param_specs(CFG))
        for lower in (aot.lower_eval, aot.lower_infer):
            text = aot.to_hlo_text(lower(CFG))
            assert entry_param_count(text) == n + 1


class TestManifest:
    def test_manifest_fields(self):
        man = aot.preset_manifest(CFG)
        assert man["n_tensors"] == len(M.param_specs(CFG))
        assert man["param_count"] == CFG.param_count()
        assert man["train_inputs"] == 3 * man["n_tensors"] + 3
        assert man["train_outputs"] == 3 * man["n_tensors"] + 2
        assert set(man["artifacts"]) == {"init", "train", "eval", "infer"}

    def test_manifest_param_order_matches_specs(self):
        man = aot.preset_manifest(CFG)
        for entry, (name, shape) in zip(man["params"], M.param_specs(CFG)):
            assert entry["name"] == name
            assert tuple(entry["shape"]) == shape

    def test_written_manifest_consistent(self):
        """If `make artifacts` has run, the on-disk manifest matches code."""
        path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            man = json.load(f)
        for name, entry in man["presets"].items():
            cfg = M.PRESETS[name]
            assert entry["param_count"] == cfg.param_count()
            assert entry["n_tensors"] == len(M.param_specs(cfg))

    def test_fingerprint_stable(self):
        assert aot._inputs_fingerprint() == aot._inputs_fingerprint()
