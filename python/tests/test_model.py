"""L2 correctness: the transformer model built on the Pallas kernels."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(0, CFG)


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(jax.random.PRNGKey(1), (CFG.batch, CFG.seq_len), 0, CFG.vocab)


class TestConfig:
    def test_param_count_matches_init(self, params):
        total = sum(int(np.prod(p.shape)) for p in params)
        assert total == CFG.param_count()

    @pytest.mark.parametrize("name", list(M.PRESETS))
    def test_param_specs_consistent(self, name):
        cfg = M.PRESETS[name]
        specs = M.param_specs(cfg)
        assert len(specs) == len(M.param_names(cfg))
        assert len({n for n, _ in specs}) == len(specs)  # unique names
        total = sum(int(np.prod(s)) for _, s in specs)
        assert total == cfg.param_count()

    def test_preset_scale_ordering(self):
        counts = [M.PRESETS[n].param_count() for n in ("tiny", "small", "base", "large")]
        assert counts == sorted(counts)
        assert M.PRESETS["large"].param_count() > 100_000_000

    def test_head_dim_divides(self):
        for cfg in M.PRESETS.values():
            assert cfg.d_model % cfg.n_heads == 0


class TestInit:
    def test_deterministic(self, params):
        again = M.init_params(0, CFG)
        for a, b in zip(params, again):
            np.testing.assert_array_equal(a, b)

    def test_seed_changes_params(self, params):
        other = M.init_params(1, CFG)
        names = M.param_names(CFG)
        diffs = [not np.allclose(a, b) for n, a, b in zip(names, params, other)
                 if not (n.endswith("_g") or n.endswith("_b"))]
        assert all(diffs)

    def test_ln_init_values(self, params):
        d = dict(zip(M.param_names(CFG), params))
        np.testing.assert_array_equal(d["layer0.ln1_g"], np.ones(CFG.d_model))
        np.testing.assert_array_equal(d["layer0.ln1_b"], np.zeros(CFG.d_model))


class TestForward:
    def test_logits_shape(self, params, tokens):
        logits = M.forward(params, tokens, CFG)
        assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)

    def test_causality(self, params, tokens):
        """Changing token t must not change logits at positions < t."""
        logits1 = M.forward(params, tokens, CFG)
        toks2 = tokens.at[:, 32].set((tokens[:, 32] + 1) % CFG.vocab)
        logits2 = M.forward(params, toks2, CFG)
        np.testing.assert_allclose(logits1[:, :32], logits2[:, :32], rtol=1e-4, atol=1e-4)
        assert not np.allclose(logits1[:, 32:], logits2[:, 32:])

    def test_initial_loss_near_uniform(self, params, tokens):
        loss = M.loss_fn(params, tokens, CFG)
        assert abs(float(loss) - np.log(CFG.vocab)) < 0.5

    def test_infer_step_is_last_position(self, params, tokens):
        logits = M.forward(params, tokens, CFG)
        last = M.infer_step(params, tokens, CFG)
        np.testing.assert_allclose(last, logits[:, -1, :], rtol=1e-5, atol=1e-5)

    def test_eval_step_equals_loss(self, params, tokens):
        np.testing.assert_allclose(M.eval_step(params, tokens, CFG),
                                   M.loss_fn(params, tokens, CFG), rtol=1e-6)


class TestTrainStep:
    def test_loss_decreases(self, params, tokens):
        ts = jax.jit(functools.partial(M.train_step, cfg=CFG))
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        state = (params, m, v, 0.0)
        losses = []
        for _ in range(15):
            *state, loss = ts(*state, tokens, 1e-2)
            state = tuple(state)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.5, losses

    def test_step_counter_increments(self, params, tokens):
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        _, _, _, t, _ = M.train_step(params, m, v, 0.0, tokens, 1e-3, CFG)
        assert float(t) == 1.0

    def test_adam_matches_reference(self, params, tokens):
        """One step of our inlined Adam vs a standalone numpy Adam."""
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        lr = 1e-3
        loss, grads = jax.value_and_grad(lambda ps: M.loss_fn(ps, tokens, CFG))(list(params))
        new_p, new_m, new_v, t, loss2 = M.train_step(params, m, v, 0.0, tokens, lr, CFG)
        np.testing.assert_allclose(loss, loss2, rtol=1e-6)
        i = 2  # spot-check one tensor
        g = np.asarray(grads[i])
        m_ref = 0.1 * g
        v_ref = 0.001 * g * g
        upd = lr * (m_ref / (1 - 0.9)) / (np.sqrt(v_ref / (1 - 0.999)) + 1e-8)
        np.testing.assert_allclose(new_p[i], np.asarray(params[i]) - upd, rtol=1e-4, atol=1e-6)

    def test_zero_lr_freezes_params(self, params, tokens):
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        new_p, *_ = M.train_step(params, m, v, 0.0, tokens, 0.0, CFG)
        for a, b in zip(params, new_p):
            np.testing.assert_allclose(a, b, atol=1e-7)

    def test_gradients_flow_to_all_params(self, params, tokens):
        grads = jax.grad(lambda ps: M.loss_fn(ps, tokens, CFG))(list(params))
        for name, g in zip(M.param_names(CFG), grads):
            assert float(jnp.abs(g).max()) > 0, f"zero grad for {name}"


class TestFlops:
    def test_flops_positive_and_monotone(self):
        f = [M.PRESETS[n].flops_per_token() for n in ("tiny", "small", "base", "large")]
        assert all(x > 0 for x in f) and f == sorted(f)
