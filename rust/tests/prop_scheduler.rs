//! Property tests on the scheduler state machine and HFS invariants
//! (via the crate's own `util::prop` harness — this image has no
//! proptest).

use std::collections::BTreeMap;
use std::sync::Arc;

use hyper_dist::hfs::{HyperFs, Uploader};
use hyper_dist::scheduler::SchedulerState;
use hyper_dist::sim::SimRng;
use hyper_dist::storage::{MemStore, StoreHandle};
use hyper_dist::util::prop::run_prop;
use hyper_dist::workflow::{sample_assignments, ExperimentSpec, ParamSpec, Task, WorkSpec};

fn mk_tasks(n: u32, max_retries: u32) -> Vec<Task> {
    let spec = ExperimentSpec {
        name: "e".into(),
        image: "i".into(),
        instance: "m5.xlarge".into(),
        workers: 1,
        spot: false,
        command: "c".into(),
        samples: None,
        params: Default::default(),
        depends_on: vec![],
        max_retries,
        work: WorkSpec::default(),
        search: None,
    };
    (0..n).map(|i| Task::materialize(0, i, &spec, Default::default())).collect()
}

/// A random trace of scheduler events; invariants must hold throughout
/// and every task must reach a terminal state by the time we drain.
#[test]
fn prop_scheduler_invariants_under_random_traces() {
    run_prop(
        "scheduler invariants",
        150,
        |rng: &mut SimRng| {
            let n_tasks = 1 + rng.gen_range(40) as u32;
            let retries = rng.gen_range(4) as u32;
            let ops: Vec<u64> = (0..200).map(|_| rng.next_u64()).collect();
            (n_tasks, retries, ops)
        },
        |(n_tasks, retries, ops)| {
            let mut s = SchedulerState::new();
            s.enqueue(mk_tasks(n_tasks, retries));
            let mut next_node: u32 = 0;
            let mut live_nodes: Vec<u32> = Vec::new();
            let mut running: Vec<(hyper_dist::workflow::TaskId, u32)> = Vec::new();
            for op in ops {
                match op % 5 {
                    0 => {
                        // add a node
                        s.add_node(next_node, 1 + (op % 3) as u32);
                        live_nodes.push(next_node);
                        next_node += 1;
                    }
                    1 => {
                        // kill a random node
                        if !live_nodes.is_empty() {
                            let idx = (op / 7) as usize % live_nodes.len();
                            let victim = live_nodes.swap_remove(idx);
                            s.remove_node(victim);
                            running.retain(|(_, n)| *n != victim);
                        }
                    }
                    2 => {
                        // a running task succeeds
                        if !running.is_empty() {
                            let idx = (op / 11) as usize % running.len();
                            let (tid, _) = running.swap_remove(idx);
                            s.on_task_success(tid);
                        }
                    }
                    3 => {
                        // a running task errors
                        if !running.is_empty() {
                            let idx = (op / 13) as usize % running.len();
                            let (tid, _) = running.swap_remove(idx);
                            s.on_task_error(tid);
                        }
                    }
                    _ => {
                        running.extend(s.assign());
                    }
                }
                s.check_invariants();
            }
            // drain: finish everything that can still run
            loop {
                for (tid, _) in std::mem::take(&mut running) {
                    s.on_task_success(tid);
                }
                if s.pending() > 0 && s.node_count() == 0 {
                    s.add_node(next_node, 4);
                    next_node += 1;
                }
                let assigned = s.assign();
                if assigned.is_empty() && s.running() == 0 {
                    break;
                }
                running.extend(assigned);
            }
            s.check_invariants();
            assert!(s.is_idle());
            assert_eq!(
                s.succeeded.len() + s.failed.len(),
                n_tasks as usize,
                "every task reaches a terminal state"
            );
        },
    );
}

/// Uploader/HyperFs roundtrip: any file set survives chunking bit-exact,
/// under any chunk size and cache budget.
#[test]
fn prop_hfs_roundtrip_any_sizes() {
    run_prop(
        "hfs roundtrip",
        60,
        |rng: &mut SimRng| {
            let chunk_size = 1 + rng.gen_range(4096);
            let cache = 1 + rng.gen_range(1 << 16);
            let n = 1 + rng.gen_range(40) as usize;
            let files: Vec<(String, Vec<u8>)> = (0..n)
                .map(|i| {
                    let len = rng.gen_range(3000) as usize;
                    let seed = rng.next_u64();
                    let data: Vec<u8> =
                        (0..len).map(|j| ((seed >> (j % 8)) as u8).wrapping_add(j as u8)).collect();
                    (format!("f/{i:04}"), data)
                })
                .collect();
            (chunk_size, cache, files)
        },
        |(chunk_size, cache, files)| {
            let store: StoreHandle = Arc::new(MemStore::new());
            let mut up = Uploader::new(store.clone(), "p", chunk_size);
            for (path, data) in &files {
                up.add_file(path, data).unwrap();
            }
            let manifest = up.seal().unwrap();
            assert_eq!(manifest.file_count(), files.len());
            assert_eq!(
                manifest.total_bytes(),
                files.iter().map(|(_, d)| d.len() as u64).sum::<u64>()
            );
            let fs = HyperFs::mount(store, "p", cache).unwrap();
            for (path, data) in &files {
                assert_eq!(&fs.read_file(path).unwrap(), data, "{path}");
            }
        },
    );
}

/// §II.C sampling: for any parameter space, minimal repetition holds —
/// discrete combo counts never differ by more than 1.
#[test]
fn prop_sampling_minimal_repetition() {
    run_prop(
        "minimal repetition",
        80,
        |rng: &mut SimRng| {
            let n_params = 1 + rng.gen_range(3) as usize;
            let card = 1 + rng.gen_range(5);
            let n = 1 + rng.gen_range(200) as usize;
            (n_params, card as i64, n, rng.next_u64())
        },
        |(n_params, card, n, seed)| {
            let space: BTreeMap<String, ParamSpec> = (0..n_params)
                .map(|i| (format!("p{i}"), ParamSpec::Range([0, card - 1])))
                .collect();
            let out = sample_assignments(&space, Some(n), seed);
            assert_eq!(out.len(), n);
            let mut counts: BTreeMap<String, usize> = BTreeMap::new();
            for a in &out {
                *counts.entry(format!("{a:?}")).or_default() += 1;
            }
            let min = counts.values().min().copied().unwrap_or(0);
            let max = counts.values().max().copied().unwrap_or(0);
            let cart = (card as usize).pow(n_params as u32);
            if counts.len() == cart {
                assert!(max - min <= 1, "minimal repetition violated: {min}..{max}");
            } else {
                // n < cartesian: sampled without replacement
                assert!(n <= cart && max == 1, "no repeats allowed while n <= |C|");
            }
        },
    );
}

/// JSON roundtrip fuzz through the crate's own parser.
#[test]
fn prop_json_roundtrip() {
    use hyper_dist::util::Json;
    fn gen_value(rng: &mut SimRng, depth: u32) -> Json {
        match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.gen_bool(0.5)),
            2 => Json::Num((rng.gen_range(2_000_001) as f64 - 1e6) / 8.0),
            3 => Json::Str(
                (0..rng.gen_range(12))
                    .map(|_| ['a', '"', '\\', 'é', '\n', 'z'][rng.gen_range(6) as usize])
                    .collect(),
            ),
            4 => Json::Arr((0..rng.gen_range(5)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.gen_range(5))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    run_prop(
        "json roundtrip",
        200,
        |rng: &mut SimRng| gen_value(rng, 3),
        |v| {
            let text = v.to_string();
            let back = Json::parse(&text).unwrap();
            assert_eq!(v, back, "roundtrip through {text}");
        },
    );
}
