//! Property tests for the HFS metadata plane: randomized upload ->
//! mount -> read-back across manifest formats (legacy monolithic vs
//! sharded), shard geometries, small-file packing, and dedup pressure.
//!
//! Each case generates a namespace from a seeded RNG, uploads it, mounts
//! it cold, and demands byte-identical read-back plus consistent
//! stat/list/accounting — the invariants every layout must share. On
//! failure `run_prop` prints the generating seed for deterministic
//! replay.

use std::sync::Arc;

use hyper_dist::hfs::{FsManifest, HyperFs, UploadConfig, Uploader};
use hyper_dist::sim::SimRng;
use hyper_dist::storage::{MemStore, StoreHandle};
use hyper_dist::util::prop::run_prop;

#[derive(Debug)]
struct Case {
    legacy: bool,
    chunk_size: u64,
    shard_files: usize,
    pack_threshold: u64,
    /// `(path, content)` pairs, unique paths, possibly duplicate contents.
    files: Vec<(String, Vec<u8>)>,
    cache_bytes: u64,
}

fn gen_case(rng: &mut SimRng) -> Case {
    let chunk_size = 64 + rng.gen_range(1985); // 64..=2048
    let n_files = 1 + rng.gen_range(48) as usize;
    let distinct = 1 + rng.gen_range(n_files as u64) as usize;
    let mut files = Vec::with_capacity(n_files);
    for i in 0..n_files {
        let variant = i % distinct;
        // same variant -> same length and bytes, so duplicate contents
        // really are duplicates (dedup pressure on the CAS layout)
        let len = 1 + (variant * 211 + 37) % (chunk_size as usize + chunk_size as usize / 2);
        let body: Vec<u8> = (0..len).map(|k| ((variant * 131 + k * 7) & 0xff) as u8).collect();
        files.push((format!("d{:02}/f{i:04}.bin", i % 7), body));
    }
    Case {
        legacy: rng.gen_bool(0.3),
        chunk_size,
        shard_files: 1 + rng.gen_range(16) as usize,
        pack_threshold: if rng.gen_bool(0.5) { rng.gen_range(chunk_size / 2) } else { 0 },
        files,
        cache_bytes: if rng.gen_bool(0.3) {
            // tiny cache: thrash eviction on the read-back pass
            chunk_size * 2
        } else {
            1 << 20
        },
    }
}

fn upload(case: &Case) -> StoreHandle {
    let store: StoreHandle = Arc::new(MemStore::new());
    let cfg = UploadConfig {
        chunk_size: case.chunk_size,
        shard_files: case.shard_files,
        pack_threshold: case.pack_threshold,
        legacy_layout: case.legacy,
    };
    let mut up = Uploader::with_config(store.clone(), "prop", cfg);
    for (path, body) in &case.files {
        up.add_file(path, body).unwrap();
    }
    up.seal().unwrap();
    store
}

fn check_mount(case: &Case, fs: &HyperFs) {
    assert_eq!(fs.is_sharded(), !case.legacy);
    assert_eq!(fs.file_count(), case.files.len() as u64);
    let logical: u64 = case.files.iter().map(|(_, b)| b.len() as u64).sum();
    assert_eq!(fs.total_bytes(), logical);
    for (path, body) in &case.files {
        assert_eq!(fs.stat(path).unwrap(), body.len() as u64, "stat {path}");
        let got = fs.read_file(path).unwrap();
        assert_eq!(&got[..], &body[..], "read {path}");
    }
    // a second pass re-reads through whatever the cache kept or evicted
    for (path, body) in case.files.iter().rev() {
        assert_eq!(&fs.read_file(path).unwrap()[..], &body[..], "re-read {path}");
    }
    let mut expect: Vec<String> = case.files.iter().map(|(p, _)| p.clone()).collect();
    expect.sort();
    assert_eq!(fs.list("").unwrap(), expect, "full listing");
    let prefix = "d03/";
    let narrowed: Vec<String> =
        expect.iter().filter(|p| p.starts_with(prefix)).cloned().collect();
    assert_eq!(fs.list(prefix).unwrap(), narrowed, "prefix listing");
    assert!(fs.read_file("no/such/file").is_err());
    assert!(fs.stat("no/such/file").is_err());
}

#[test]
fn prop_upload_mount_readback_across_layouts() {
    run_prop("hfs upload/mount/read round-trip", 40, gen_case, |case| {
        let store = upload(&case);
        let fs = HyperFs::mount(store, "prop", case.cache_bytes).unwrap();
        check_mount(&case, &fs);
    });
}

#[test]
fn prop_legacy_and_sharded_layouts_read_identical() {
    run_prop("legacy vs sharded byte-identical", 25, gen_case, |mut case| {
        case.legacy = false;
        let sharded = HyperFs::mount(upload(&case), "prop", case.cache_bytes).unwrap();
        case.legacy = true;
        let legacy = HyperFs::mount(upload(&case), "prop", case.cache_bytes).unwrap();
        for (path, _) in &case.files {
            assert_eq!(
                &sharded.read_file(path).unwrap()[..],
                &legacy.read_file(path).unwrap()[..],
                "layouts must serve identical bytes for {path}"
            );
        }
        assert_eq!(sharded.list("").unwrap(), legacy.list("").unwrap());
        assert_eq!(sharded.total_bytes(), legacy.total_bytes());
    });
}

#[test]
fn prop_legacy_manifest_json_roundtrips() {
    run_prop("legacy manifest to_json/from_json", 25, gen_case, |mut case| {
        case.legacy = true;
        let store = upload(&case);
        let raw = store.get(&FsManifest::manifest_key("prop")).unwrap();
        let m = FsManifest::from_json(&raw).unwrap();
        let back = FsManifest::from_json(&m.to_json().unwrap()).unwrap();
        assert_eq!(m.files, back.files);
        assert_eq!(m.chunks, back.chunks);
        assert_eq!(m.chunk_size, back.chunk_size);
    });
}

/// A sharded namespace's root manifest must never parse as a legacy
/// monolithic manifest: an old reader pointed at a new namespace has to
/// fail loudly instead of mounting an empty or garbled file table.
#[test]
fn sharded_root_rejected_by_legacy_parser() {
    let store: StoreHandle = Arc::new(MemStore::new());
    let mut up = Uploader::new(store.clone(), "prop", 256);
    up.add_file("a.bin", &[7u8; 100]).unwrap();
    up.add_file("b.bin", &[9u8; 300]).unwrap();
    up.seal().unwrap();
    let raw = store.get(&FsManifest::manifest_key("prop")).unwrap();
    let err = FsManifest::from_json(&raw);
    assert!(err.is_err(), "format-2 root must not parse as a legacy manifest");
    let msg = format!("{}", err.unwrap_err());
    assert!(msg.contains("format"), "error should name the format mismatch: {msg}");
}
