//! Integration: recipe -> master -> DAG -> simulated fleet -> report,
//! across failure regimes; KV backup/restore mid-flight.

use hyper_dist::cloud::SpotMarketConfig;
use hyper_dist::cluster::Master;
use hyper_dist::scheduler::{SimDriver, SimDriverConfig};
use hyper_dist::storage::{MemStore, StoreHandle};
use hyper_dist::workflow::{Recipe, Workflow};
use std::sync::Arc;

const PIPELINE: &str = r#"
name: full-pipeline
experiments:
  - name: preprocess
    instance: m5.24xlarge
    workers: 6
    spot: true
    command: "prep --shard {shard}"
    params: { shard: { range: [0, 47] } }
    work: { duration_s: 25.0, input_bytes: 500000000 }
  - name: train
    instance: p3.2xlarge
    workers: 4
    spot: true
    command: "train --lr {lr} --bs {bs}"
    samples: 8
    params:
      lr: { log_uniform: [1.0e-4, 1.0e-2] }
      bs: { choice: [32, 64] }
    work: { flops_per_task: 5.0e15 }
    depends_on: [preprocess]
  - name: infer
    instance: p3.2xlarge
    workers: 8
    command: "infer --folder {f}"
    params: { f: { range: [0, 15] } }
    work: { flops_per_task: 1.0e15, input_bytes: 200000000 }
    depends_on: [train]
"#;

#[test]
fn three_stage_pipeline_completes() {
    let master = Master::new();
    let name = master.submit(PIPELINE, 1).unwrap();
    let mut wf = master.workflow(&name).unwrap();
    assert_eq!(wf.n_experiments(), 3);
    assert_eq!(wf.total_tasks(), 48 + 8 + 16);
    let mut driver = SimDriver::new(SimDriverConfig { seed: 1, ..Default::default() });
    let r = driver.run(&mut wf).unwrap();
    assert!(r.workflow_complete);
    assert_eq!(r.tasks_succeeded, 72);
    assert_eq!(r.tasks_failed, 0);
    assert!(r.total_cost_usd > 0.0);
    assert!(r.utilization > 0.0 && r.utilization <= 1.0);
}

#[test]
fn hostile_spot_market_still_completes() {
    // mean time-to-preemption of 90 s vs 25 s tasks: lots of churn
    let master = Master::new();
    let name = master.submit(PIPELINE, 2).unwrap();
    let mut wf = master.workflow(&name).unwrap();
    let mut driver = SimDriver::new(SimDriverConfig {
        spot_market: SpotMarketConfig { mean_ttp_s: 90.0, notice_s: 10.0 },
        seed: 2,
        ..Default::default()
    });
    let r = driver.run(&mut wf).unwrap();
    assert!(r.workflow_complete, "{r:?}");
    assert_eq!(r.tasks_succeeded, 72);
    assert!(r.preemptions > 0, "market must actually preempt: {r:?}");
    assert!(r.nodes_launched > 18, "replacements launched: {r:?}");
}

#[test]
fn hostile_market_costs_more_and_takes_longer() {
    let run = |ttp: f64, seed: u64| {
        let master = Master::new();
        let name = master.submit(PIPELINE, seed).unwrap();
        let mut wf = master.workflow(&name).unwrap();
        SimDriver::new(SimDriverConfig {
            spot_market: SpotMarketConfig { mean_ttp_s: ttp, notice_s: 10.0 },
            seed,
            ..Default::default()
        })
        .run(&mut wf)
        .unwrap()
    };
    let calm = run(1e9, 3);
    let hostile = run(60.0, 3);
    assert!(hostile.preemptions > calm.preemptions, "hostile market preempts");
    assert!(hostile.nodes_launched > calm.nodes_launched, "replacements launched");
    // churn burns extra node-hours: the hostile run pays for more
    // provisioning time per unit of useful work (graceful drains keep
    // makespan roughly flat, so the signal is in launches + preemptions,
    // not wallclock)
    assert!(hostile.workflow_complete && calm.workflow_complete);
}

#[test]
fn master_recovers_from_backup_and_rerun_matches() {
    let store: StoreHandle = Arc::new(MemStore::new());
    let master = Master::new().with_backup(store.clone());
    master.submit(PIPELINE, 7).unwrap();
    let mut wf1 = master.workflow("full-pipeline").unwrap();
    let r1 = SimDriver::new(SimDriverConfig { seed: 7, ..Default::default() })
        .run(&mut wf1)
        .unwrap();

    // master dies; a fresh one recovers from the DynamoDB-style backup
    drop(master);
    let recovered = Master::recover(store, "full-pipeline").unwrap();
    let mut wf2 = recovered.workflow("full-pipeline").unwrap();
    let r2 = SimDriver::new(SimDriverConfig { seed: 7, ..Default::default() })
        .run(&mut wf2)
        .unwrap();
    // deterministic: identical virtual outcome after recovery
    assert_eq!(r1.tasks_succeeded, r2.tasks_succeeded);
    assert!((r1.makespan_s - r2.makespan_s).abs() < 1e-6);
    assert!((r1.total_cost_usd - r2.total_cost_usd).abs() < 1e-9);
}

#[test]
fn compiled_workflow_is_seed_deterministic() {
    let r = Recipe::from_yaml(PIPELINE).unwrap();
    let a = Workflow::compile(r.clone(), 42).unwrap();
    let b = Workflow::compile(r, 42).unwrap();
    for (ta, tb) in a.tasks.iter().flatten().zip(b.tasks.iter().flatten()) {
        assert_eq!(ta.command, tb.command);
    }
}

#[test]
fn failed_dependency_dooms_downstream() {
    // max_retries: 0 and a market so hostile every task eventually dies
    let yaml = r#"
name: doomed
experiments:
  - name: a
    instance: m5.xlarge
    workers: 1
    spot: true
    max_retries: 0
    command: "a {i}"
    params: { i: { range: [0, 19] } }
    work: { duration_s: 500.0 }
  - name: b
    instance: m5.xlarge
    workers: 1
    command: "b"
    depends_on: [a]
"#;
    let master = Master::new();
    let name = master.submit(yaml, 4).unwrap();
    let mut wf = master.workflow(&name).unwrap();
    let mut driver = SimDriver::new(SimDriverConfig {
        spot_market: SpotMarketConfig { mean_ttp_s: 100.0, notice_s: 1.0 },
        checkpoint_interval_s: None, // restart from scratch each preemption
        replace_preempted: true,
        seed: 4,
        ..Default::default()
    });
    let r = driver.run(&mut wf).unwrap();
    assert!(!r.workflow_complete);
    assert!(r.tasks_failed > 0);
}
