//! Integration over the PJRT runtime: the AOT artifacts really execute,
//! train, checkpoint, resume, and serve — the §III.D story on real state.
//!
//! Skipped gracefully when `make artifacts` has not produced the tiny
//! preset (CI without python).

use std::sync::Arc;

use hyper_dist::config::{artifacts_available, default_artifacts_dir};
use hyper_dist::runtime::Runtime;
use hyper_dist::scheduler::CheckpointStore;
use hyper_dist::storage::{MemStore, StoreHandle};
use hyper_dist::workflow::TaskId;

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !artifacts_available(&dir, "tiny") {
        eprintln!("artifacts missing — skipping runtime integration test");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

fn fixed_tokens(n: usize, vocab: i32) -> Vec<i32> {
    (0..n).map(|i| (i as i32 * 31 + 7) % vocab).collect()
}

#[test]
fn train_loss_decreases_on_fixed_batch() {
    let Some(rt) = runtime() else { return };
    let mut sess = rt.train_session("tiny", 0).unwrap();
    let tokens = fixed_tokens(sess.batch_tokens(), sess.preset().vocab as i32);
    let first = sess.step(&tokens, 1e-2).unwrap();
    let mut last = first;
    for _ in 0..10 {
        last = sess.step(&tokens, 1e-2).unwrap();
    }
    assert!(last < first * 0.8, "loss {first} -> {last}");
    assert_eq!(sess.steps_done, 11);
    assert_eq!(sess.device_step().unwrap(), 11.0);
}

#[test]
fn eval_matches_training_state() {
    let Some(rt) = runtime() else { return };
    let mut sess = rt.train_session("tiny", 0).unwrap();
    let tokens = fixed_tokens(sess.batch_tokens(), sess.preset().vocab as i32);
    let e0 = sess.eval(&rt, &tokens).unwrap();
    // initial loss ~ ln(vocab)
    let uniform = (sess.preset().vocab as f32).ln();
    assert!((e0 - uniform).abs() < 1.0, "eval {e0} vs uniform {uniform}");
    for _ in 0..8 {
        sess.step(&tokens, 1e-2).unwrap();
    }
    let e1 = sess.eval(&rt, &tokens).unwrap();
    assert!(e1 < e0, "eval must improve after training: {e0} -> {e1}");
}

#[test]
fn checkpoint_resume_reproduces_state() {
    let Some(rt) = runtime() else { return };
    let store: StoreHandle = Arc::new(MemStore::new());
    let ckpts = CheckpointStore::new(store, "it");
    let task = TaskId { experiment: 0, index: 0 };

    let mut a = rt.train_session("tiny", 0).unwrap();
    let tokens = fixed_tokens(a.batch_tokens(), a.preset().vocab as i32);
    for _ in 0..5 {
        a.step(&tokens, 1e-2).unwrap();
    }
    a.checkpoint(&ckpts, task).unwrap();
    let loss_a = a.step(&tokens, 1e-2).unwrap(); // one step past the ckpt

    // "node failure": fresh session resumes and replays the same step
    let mut b = rt.train_session("tiny", 99).unwrap(); // different init seed
    let resumed = b.resume(&ckpts, task).unwrap();
    assert_eq!(resumed, Some(5));
    let loss_b = b.step(&tokens, 1e-2).unwrap();
    assert!(
        (loss_a - loss_b).abs() < 1e-5,
        "resumed replay must match: {loss_a} vs {loss_b}"
    );
}

#[test]
fn infer_session_serves_and_loads_trained_params() {
    let Some(rt) = runtime() else { return };
    // train a few steps, hand the params to an infer session
    let mut tr = rt.train_session("tiny", 0).unwrap();
    let vocab = tr.preset().vocab as i32;
    let tokens = fixed_tokens(tr.batch_tokens(), vocab);
    for _ in 0..10 {
        tr.step(&tokens, 1e-2).unwrap();
    }
    let blob = tr.state_blob().unwrap();

    let mut inf = rt.infer_session("tiny", 0).unwrap();
    let logits_fresh = inf.logits(&tokens).unwrap();
    inf.load_params_blob(&blob).unwrap();
    let logits_trained = inf.logits(&tokens).unwrap();
    assert_eq!(logits_fresh.len(), inf.preset().batch * inf.preset().vocab);
    assert_ne!(logits_fresh, logits_trained, "training must change the logits");

    let next = inf.next_tokens(&tokens).unwrap();
    assert_eq!(next.len(), inf.preset().batch);
    assert!(next.iter().all(|&t| t >= 0 && (t as usize) < inf.preset().vocab));
}

#[test]
fn restore_rejects_corrupt_blob() {
    let Some(rt) = runtime() else { return };
    let mut sess = rt.train_session("tiny", 0).unwrap();
    let mut blob = sess.state_blob().unwrap();
    blob.truncate(blob.len() / 2);
    assert!(sess.restore_blob(&blob).is_err());
    // session still usable after the failed restore
    let tokens = fixed_tokens(sess.batch_tokens(), sess.preset().vocab as i32);
    sess.step(&tokens, 1e-3).unwrap();
}

#[test]
fn different_seeds_different_params() {
    let Some(rt) = runtime() else { return };
    let a = rt.train_session("tiny", 0).unwrap();
    let b = rt.train_session("tiny", 1).unwrap();
    assert_ne!(a.state_blob().unwrap(), b.state_blob().unwrap());
    // same seed: identical
    let c = rt.train_session("tiny", 0).unwrap();
    assert_eq!(a.state_blob().unwrap(), c.state_blob().unwrap());
}
