//! Property tests on the shared [`FleetEngine`] (via the crate's own
//! `util::prop` harness — this image has no proptest), plus the
//! cross-driver storm-timing regression test.
//!
//! The conservation property is workload-agnostic: under any mix of
//! storms, Poisson markets, and price traces, every dispatched work unit
//! either completes or is explicitly requeued (never silently lost), the
//! lifecycle classes partition the fleet (the live count can never go
//! negative), and a preemption notice always precedes its kill — all
//! checked by [`FleetEngine::check_invariants`] inside every hook.

use std::collections::BTreeMap;

use hyper_dist::cloud::{PriceTrace, ProvisionerConfig, SpotMarketConfig, StormEvent};
use hyper_dist::fleet::{FleetConfig, FleetEngine, PriceTraceConfig, UnitsWorkload as Units};
use hyper_dist::sim::SimRng;
use hyper_dist::util::prop::run_prop;

/// After any run: nothing was silently lost.
fn assert_conserved(engine: &FleetEngine, w: &Units) {
    engine.check_invariants();
    assert_eq!(w.completed, w.total, "every unit completed");
    assert!(w.queue.is_empty(), "no unit left queued after completion");
    assert_eq!(
        w.dispatched,
        w.completed as u64 + w.requeued as u64,
        "every dispatched unit completed or was explicitly requeued"
    );
    assert!(
        engine.stats().preemptions as usize <= engine.stats().nodes_launched,
        "preemptions counted at most once per node"
    );
}

/// Storms + an optional background Poisson market, random shapes.
#[test]
fn prop_fleet_conservation_under_storms_and_market() {
    run_prop(
        "fleet conservation (storms + market)",
        60,
        |rng: &mut SimRng| {
            let total = 1 + rng.gen_range(30) as usize;
            let unit_s = 1.0 + rng.gen_range(25) as f64;
            let workers = 1 + rng.gen_range(5) as usize;
            let market = rng.gen_bool(0.5);
            let mean_ttp = 120.0 + rng.gen_range(2000) as f64;
            let n_storms = rng.gen_range(3) as usize;
            let storms: Vec<(f64, usize, f64)> = (0..n_storms)
                .map(|_| {
                    (
                        rng.gen_range(300) as f64,
                        rng.gen_range(6) as usize,
                        if rng.gen_bool(0.5) { 0.0 } else { 2.0 + rng.gen_range(20) as f64 },
                    )
                })
                .collect();
            (total, unit_s, workers, market, mean_ttp, storms, rng.next_u64())
        },
        |(total, unit_s, workers, market, mean_ttp, storms, seed)| {
            let mut engine = FleetEngine::new(FleetConfig {
                spot_market: market.then(|| SpotMarketConfig {
                    mean_ttp_s: mean_ttp,
                    notice_s: 30.0,
                }),
                storm: storms
                    .iter()
                    .map(|&(at_s, kills, notice_s)| StormEvent { at_s, kills, notice_s })
                    .collect(),
                seed,
                ..FleetConfig::default()
            });
            let mut w = Units::new(total, unit_s, workers, true);
            engine.run(&mut w).unwrap();
            let end = engine.now().as_secs_f64();
            engine.shutdown(engine.now());
            assert_conserved(&engine, &w);
            // storms fire in time order, each at exactly its scripted
            // engine-start time; every storm due before the run ended fired
            let fired = engine.stats().storms_fired_at_s.clone();
            assert!(fired.windows(2).all(|p| p[0] <= p[1]), "{fired:?}");
            let mut cfg_times: Vec<f64> = storms.iter().map(|&(t, _, _)| t).collect();
            cfg_times.sort_by(f64::total_cmp);
            for at in &fired {
                assert!(cfg_times.contains(at), "storm fired off-schedule: {at}");
            }
            let due = cfg_times.iter().filter(|t| **t < end).count();
            assert!(fired.len() >= due, "a due storm never fired: {fired:?} vs {cfg_times:?}");
        },
    );
}

/// Price-trace preemption with random spikes and a bid the trace always
/// eventually recovers below (so deferred capacity can provision).
#[test]
fn prop_fleet_conservation_under_price_traces() {
    run_prop(
        "fleet conservation (price trace)",
        60,
        |rng: &mut SimRng| {
            let total = 1 + rng.gen_range(20) as usize;
            let unit_s = 1.0 + rng.gen_range(20) as f64;
            let workers = 1 + rng.gen_range(4) as usize;
            // random step series ending low, so the market always recovers
            let n = 2 + rng.gen_range(5) as usize;
            let mut points: Vec<(f64, f64)> = Vec::with_capacity(n + 1);
            let mut t = 0.0;
            for _ in 0..n {
                points.push((t, rng.gen_range(100) as f64 / 100.0));
                t += 20.0 + rng.gen_range(200) as f64;
            }
            points.push((t, 0.01));
            let bid = 0.02 + rng.gen_range(80) as f64 / 100.0;
            let notice_s = if rng.gen_bool(0.5) { 0.0 } else { rng.gen_range(30) as f64 };
            (total, unit_s, workers, points, bid, notice_s, rng.next_u64())
        },
        |(total, unit_s, workers, points, bid, notice_s, seed)| {
            let trace = PriceTrace::new(points).unwrap();
            let mut engine = FleetEngine::new(FleetConfig {
                price_trace: Some(PriceTraceConfig { trace, bid_usd: bid, notice_s }),
                seed,
                ..FleetConfig::default()
            });
            let mut w = Units::new(total, unit_s, workers, true);
            engine.run(&mut w).unwrap();
            engine.shutdown(engine.now());
            assert_conserved(&engine, &w);
        },
    );
}

/// One driver's flight-recorder trace, checked for the full preemption
/// protocol: the storm lands at exactly `t=60 s`, exactly two nodes get
/// the notice at that instant, and every victim either ends in a hard
/// kill — with `node.notice` → `node.drain` → `node.kill` in record
/// order, the drain span stretching from the notice to the kill at
/// `t=65 s` — or exits early through a voluntary `node.release` inside
/// the notice window (a drained replica finishing its last batch).
fn assert_storm_protocol(records: &[hyper_dist::obs::Record], label: &str) {
    use hyper_dist::obs::RecordKind;

    let storm: Vec<_> = records.iter().filter(|r| r.name == "fleet.storm").collect();
    assert_eq!(storm.len(), 1, "{label}: exactly one storm record");
    assert_eq!(storm[0].ts_ns, 60_000_000_000, "{label}: storm fired off engine start");
    assert_eq!(storm[0].arg("kills").and_then(|a| a.as_u64()), Some(2), "{label}");

    let victims: Vec<u32> = records
        .iter()
        .filter(|r| r.name == "node.notice")
        .map(|r| {
            assert_eq!(r.ts_ns, 60_000_000_000, "{label}: notices land with the storm");
            r.pid
        })
        .collect();
    assert_eq!(victims.len(), 2, "{label}: the wave noticed 2 nodes");

    for pid in victims {
        let find = |name: &str| records.iter().find(|r| r.name == name && r.pid == pid);
        let notice = find("node.notice").expect("victim has a notice");
        match find("node.kill") {
            Some(kill) => {
                let drain = find("node.drain")
                    .unwrap_or_else(|| panic!("{label}: node {pid} killed without drain"));
                assert!(
                    notice.seq < drain.seq && drain.seq < kill.seq,
                    "{label}: node {pid} must record notice -> drain -> kill in order"
                );
                assert_eq!(kill.ts_ns, 65_000_000_000, "{label}: hard kill after 5s notice");
                assert_eq!(drain.ts_ns, notice.ts_ns, "{label}: drain opens at the notice");
                assert_eq!(drain.end_ns(), kill.ts_ns, "{label}: drain closes at the kill");
                assert_eq!(drain.kind, RecordKind::Span { dur_ns: 5_000_000_000 });
                assert_eq!(drain.arg("noticed").and_then(|a| a.as_u64()), Some(1));
            }
            None => {
                // drained to completion before the hard kill landed
                let release = find("node.release").unwrap_or_else(|| {
                    panic!("{label}: noticed node {pid} neither killed nor released")
                });
                assert!(release.seq > notice.seq, "{label}: release follows the notice");
                assert!(
                    release.ts_ns <= 65_000_000_000,
                    "{label}: a voluntary exit beats the hard kill"
                );
            }
        }
    }
}

/// The storm-timing bugfix pinned end to end — now from the flight
/// recorder itself: all four virtual-time drivers schedule a `t=60 s`
/// storm against the SAME origin (engine start), so each driver's trace
/// must carry the identical `fleet.storm` instant and the full
/// notice→drain→kill protocol for every victim; the search trace must
/// additionally prove (by command hash) that every resume continued the
/// byte-identical command its trial ran before the preemption.
#[test]
fn storm_at_60s_fires_at_the_same_instant_in_all_four_drivers() {
    use hyper_dist::config::{GangMode, TrainConfig};
    use hyper_dist::obs::{FlightRecorder, Record};
    use hyper_dist::scheduler::{SimDriver, SimDriverConfig};
    use hyper_dist::search::{CurveConfig, SearchDriver, SearchDriverConfig};
    use hyper_dist::serve::{Load, ServeSim, ServeSimConfig};
    use hyper_dist::sim::{OpenLoop, SimClock};
    use hyper_dist::train::{TrainDriver, TrainDriverConfig};
    use hyper_dist::workflow::{Recipe, Workflow};

    let recorder = || FlightRecorder::sim(1 << 16, SimClock::new());
    // a 5s notice makes the drain window observable: notice at 60,
    // hard kill at 65, voluntary exits allowed in between
    let storm = vec![StormEvent { at_s: 60.0, kills: 2, notice_s: 5.0 }];
    // deliberately slow, exact provisioning: nodes are only ready at
    // t=55 and first dispatch follows — a "time since dispatch" or
    // "time since ready" origin would skew the firing time
    let exact = ProvisionerConfig { warm_cache_prob: 1.0, jitter: 0.0, ..Default::default() };

    // 1. SimDriver (DAG tasks)
    let yaml = r#"
name: storm-origin
experiments:
  - name: etl
    instance: m5.xlarge
    workers: 4
    spot: true
    command: "p {i}"
    params: { i: { range: [0, 15] } }
    work: { duration_s: 20.0 }
"#;
    let mut wf = Workflow::compile(Recipe::from_yaml(yaml).unwrap(), 1).unwrap();
    let mut dag = SimDriver::new(SimDriverConfig {
        provisioner: exact.clone(),
        spot_market: SpotMarketConfig { mean_ttp_s: 1e9, notice_s: 120.0 },
        storm: storm.clone(),
        ..Default::default()
    });
    let dag_rec = recorder();
    dag.set_obs(dag_rec.clone());
    let r = dag.run(&mut wf).unwrap();
    assert!(r.workflow_complete);

    // 2. ServeSim (batching replicas), cold start: replicas ready at 55
    let mut serve = ServeSim::new(ServeSimConfig {
        initial_replicas: 4,
        warm_start: false,
        provisioner: exact.clone(),
        storm: storm.clone(),
        ..Default::default()
    });
    let serve_rec = recorder();
    serve.set_obs(serve_rec.clone());
    let sr = serve.run(Load::Open(OpenLoop::poisson(50.0)), 90.0).unwrap();
    assert_eq!(sr.completed, sr.admitted);

    // 3. SearchDriver (checkpointable trials)
    let mut scfg = SearchDriverConfig {
        curve: CurveConfig { noise: 0.0, ..Default::default() },
        provisioner: exact.clone(),
        storm: storm.clone(),
        ..Default::default()
    };
    scfg.search.trials = 8;
    scfg.search.max_steps = 30;
    scfg.search.step_time_s = 1.0;
    scfg.search.workers = 4;
    let mut search = SearchDriver::new(
        scfg,
        std::sync::Arc::new(hyper_dist::storage::MemStore::new()),
        &{
            let mut m = BTreeMap::new();
            m.insert("p".to_string(), hyper_dist::workflow::ParamSpec::Range([0, 7]));
            m
        },
        "t {p}",
    )
    .unwrap();
    let search_rec = recorder();
    search.set_obs(search_rec.clone());
    let xr = search.run().unwrap();
    assert_eq!(xr.lost, 0);

    // 4. TrainDriver (elastic gang): the gang drain-checkpoints at the
    // notice and keeps stepping at the surviving world size — it never
    // voluntarily releases a noticed member, so every victim's trace ends
    // in the hard notice → drain → kill sequence
    let tcfg = TrainDriverConfig {
        train: TrainConfig {
            world_size: 4,
            gang_min: 2,
            total_steps: 30,
            partitions: 8,
            sample_time_s: 1.0,
            model_bytes: 0,
            checkpoint_every_steps: 5,
            keep_last_k: 2,
            mode: GangMode::Elastic,
            spot: true,
            instance: "p3.2xlarge".into(),
            seed: 0,
        },
        net: hyper_dist::cloud::NetworkModel { intra_vpc_latency_s: 0.0, node_bw: 1.0 },
        provisioner: exact,
        storm,
        ..Default::default()
    };
    let mut train =
        TrainDriver::new(tcfg, std::sync::Arc::new(hyper_dist::storage::MemStore::new()))
            .unwrap();
    let train_rec = recorder();
    train.set_obs(train_rec.clone());
    let tr = train.run().unwrap();
    assert_eq!(tr.lost_steps, 0, "the gang lost no steps through the storm: {tr:?}");

    // every driver's trace shows the same wave at the same instant, with
    // the full preemption protocol per victim
    let dag_records = dag_rec.snapshot();
    let serve_records = serve_rec.snapshot();
    let search_records = search_rec.snapshot();
    let train_records = train_rec.snapshot();
    assert_storm_protocol(&dag_records, "dag");
    assert_storm_protocol(&serve_records, "serve");
    assert_storm_protocol(&search_records, "search");
    assert_storm_protocol(&train_records, "train");
    let storm_ts = |records: &[Record]| {
        records.iter().find(|r| r.name == "fleet.storm").expect("storm record").ts_ns
    };
    assert_eq!(storm_ts(&dag_records), storm_ts(&serve_records));
    assert_eq!(storm_ts(&serve_records), storm_ts(&search_records));
    assert_eq!(storm_ts(&search_records), storm_ts(&train_records));

    // checkpoint/resume integrity, proven from the trace alone: every
    // resume carries the command hash of the byte-identical command its
    // trial's run segments carry — a resume never continues someone
    // else's command
    let resumes: Vec<_> =
        search_records.iter().filter(|r| r.name == "trial.resume").collect();
    assert!(
        !resumes.is_empty(),
        "the storm paused trials that must resume ({} pauses recorded)",
        xr.pauses
    );
    for resume in resumes {
        let hash = resume.arg("command_hash").and_then(|a| a.as_u64()).unwrap();
        let runs: Vec<_> = search_records
            .iter()
            .filter(|r| r.name == "trial.run" && r.tid == resume.tid)
            .collect();
        assert!(!runs.is_empty(), "resumed trial {} has run segments", resume.tid);
        for run in runs {
            assert_eq!(
                run.arg("command_hash").and_then(|a| a.as_u64()),
                Some(hash),
                "trial {}: resume must continue the byte-identical command",
                resume.tid
            );
        }
    }
}

/// The elastic-resize protocol, proven from the flight recorder alone: a
/// W4 gang hit by a 2-node notice storm must record, per victim,
/// `node.notice` → `gang.checkpoint` → `gang.shrink` in sequence order
/// with the shrink inside the notice window; every `gang.step` span
/// between the shrink and the `gang.grow` carries the surviving world
/// size, and every span after the grow is full-world again.
#[test]
fn elastic_resize_protocol_is_visible_in_the_trace() {
    use hyper_dist::cloud::NetworkModel;
    use hyper_dist::config::{GangMode, TrainConfig};
    use hyper_dist::obs::{FlightRecorder, RecordKind};
    use hyper_dist::sim::SimClock;
    use hyper_dist::train::{TrainDriver, TrainDriverConfig};

    let cfg = TrainDriverConfig {
        train: TrainConfig {
            world_size: 4,
            gang_min: 2,
            total_steps: 30,
            partitions: 8,
            sample_time_s: 1.0,
            model_bytes: 0,
            checkpoint_every_steps: 5,
            keep_last_k: 2,
            mode: GangMode::Elastic,
            spot: true,
            instance: "p3.2xlarge".into(),
            seed: 0,
        },
        net: NetworkModel { intra_vpc_latency_s: 0.0, node_bw: 1.0 },
        provisioner: ProvisionerConfig { warm_cache_prob: 1.0, jitter: 0.0, ..Default::default() },
        storm: vec![StormEvent { at_s: 60.0, kills: 2, notice_s: 5.0 }],
        ..Default::default()
    };
    let mut d =
        TrainDriver::new(cfg, std::sync::Arc::new(hyper_dist::storage::MemStore::new())).unwrap();
    let rec = FlightRecorder::sim(1 << 16, SimClock::new());
    d.set_obs(rec.clone());
    d.run().unwrap();
    let records = rec.snapshot();

    // per victim: notice -> drain checkpoint -> shrink, in record order,
    // the shrink landing inside the 5 s notice window
    let notices: Vec<_> = records.iter().filter(|r| r.name == "node.notice").collect();
    assert_eq!(notices.len(), 2, "the storm noticed two members");
    for notice in &notices {
        let shrink = records
            .iter()
            .find(|r| r.name == "gang.shrink" && r.pid == notice.pid)
            .unwrap_or_else(|| panic!("noticed node {} never shrank the gang", notice.pid));
        let banked = records
            .iter()
            .any(|r| r.name == "gang.checkpoint" && notice.seq < r.seq && r.seq < shrink.seq);
        assert!(
            banked,
            "node {}: state must be drain-checkpointed between its notice and its shrink",
            notice.pid
        );
        assert!(
            (notice.ts_ns..=notice.ts_ns + 5_000_000_000).contains(&shrink.ts_ns),
            "node {}: shrink must land inside the notice window",
            notice.pid
        );
    }

    // the fleet heals: exactly one grow back to full world
    let grow = records.iter().find(|r| r.name == "gang.grow").expect("the gang grew back");
    assert_eq!(grow.arg("world_size").and_then(|a| a.as_u64()), Some(4));
    let last_shrink_seq =
        records.iter().filter(|r| r.name == "gang.shrink").map(|r| r.seq).max().unwrap();
    assert!(last_shrink_seq < grow.seq, "shrinks precede the grow");

    // step spans: full world before the storm, the surviving world
    // between shrink and grow, full world after
    let steps: Vec<_> = records.iter().filter(|r| r.name == "gang.step").collect();
    assert!(!steps.is_empty());
    for s in &steps {
        assert!(matches!(s.kind, RecordKind::Span { .. }), "gang.step is a span");
        assert!(
            s.arg("allreduce_us").and_then(|a| a.as_f64()).is_some(),
            "step spans carry the allreduce cost"
        );
        let w = s.arg("world_size").and_then(|a| a.as_u64()).unwrap();
        if s.seq < last_shrink_seq {
            assert_eq!(w, 4, "pre-storm steps are full-world");
        } else if s.seq < grow.seq {
            assert_eq!(w, 2, "between shrink and grow the gang steps at the surviving world");
        } else {
            assert_eq!(w, 4, "after gang.grow the steps are full-world again");
        }
    }
}

/// Workload-agnostic gang conservation: under random storms, Poisson
/// markets, and price traces, committed work is exactly accounted —
/// every commit's world size sums to precisely the member completions
/// the engine delivered (a stale-epoch completion can never be counted
/// into a commit), every committed step covers each data partition
/// exactly once at its committed world size, the committed sample count
/// is `committed × partitions`, and a rigid gang never commits below
/// full world.
#[test]
fn prop_gang_conservation_under_storms_markets_and_price_traces() {
    use hyper_dist::cloud::NetworkModel;
    use hyper_dist::config::{GangMode, TrainConfig};
    use hyper_dist::train::{shard_partitions, TrainDriver, TrainDriverConfig};

    run_prop(
        "gang conservation (storms + market + price traces)",
        40,
        |rng: &mut SimRng| {
            let world = 2 + rng.gen_range(7) as usize;
            let gang_min = 1 + rng.gen_range(world as u64) as usize;
            let total = 1 + rng.gen_range(60);
            let partitions = 1 + rng.gen_range(64);
            let rigid = rng.gen_bool(0.3);
            let ckpt_every = 1 + rng.gen_range(10);
            let market = rng.gen_bool(0.4);
            let mean_ttp = 200.0 + rng.gen_range(2000) as f64;
            let n_storms = rng.gen_range(3) as usize;
            let storms: Vec<(f64, usize, f64)> = (0..n_storms)
                .map(|_| {
                    (
                        rng.gen_range(400) as f64,
                        1 + rng.gen_range(world as u64 + 2) as usize,
                        if rng.gen_bool(0.5) { 0.0 } else { 2.0 + rng.gen_range(20) as f64 },
                    )
                })
                .collect();
            // optional price trace ending low, so deferred capacity can
            // always provision eventually
            let trace = rng.gen_bool(0.4).then(|| {
                let mut points: Vec<(f64, f64)> = Vec::new();
                let mut t = 0.0;
                for _ in 0..(2 + rng.gen_range(4)) {
                    points.push((t, rng.gen_range(100) as f64 / 100.0));
                    t += 30.0 + rng.gen_range(300) as f64;
                }
                points.push((t, 0.01));
                let bid = 0.02 + rng.gen_range(80) as f64 / 100.0;
                let notice_s = if rng.gen_bool(0.5) { 0.0 } else { rng.gen_range(30) as f64 };
                (points, bid, notice_s)
            });
            (world, gang_min, total, partitions, rigid, ckpt_every, market, mean_ttp, storms,
             trace, rng.next_u64())
        },
        |(world, gang_min, total, partitions, rigid, ckpt_every, market, mean_ttp, storms,
          trace, seed)| {
            let cfg = TrainDriverConfig {
                train: TrainConfig {
                    world_size: world,
                    gang_min,
                    total_steps: total,
                    partitions,
                    sample_time_s: 0.5,
                    model_bytes: 1 << 20,
                    checkpoint_every_steps: ckpt_every,
                    keep_last_k: 2,
                    mode: if rigid { GangMode::Rigid } else { GangMode::Elastic },
                    spot: true,
                    instance: "p3.2xlarge".into(),
                    seed,
                },
                net: NetworkModel::default(),
                spot_market: market
                    .then(|| SpotMarketConfig { mean_ttp_s: mean_ttp, notice_s: 15.0 }),
                price_trace: trace.map(|(points, bid, notice_s)| PriceTraceConfig {
                    trace: PriceTrace::new(points).unwrap(),
                    bid_usd: bid,
                    notice_s,
                }),
                storm: storms
                    .iter()
                    .map(|&(at_s, kills, notice_s)| StormEvent { at_s, kills, notice_s })
                    .collect(),
                // hostile markets may never let the job finish — box the
                // run; conservation must hold wherever it stops
                deadline_s: Some(1500.0),
                ..Default::default()
            };
            let mut d =
                TrainDriver::new(cfg, std::sync::Arc::new(hyper_dist::storage::MemStore::new()))
                    .unwrap();
            let r = d.run().unwrap();

            let log = d.commit_log();
            let units: u64 = log.iter().map(|c| c.world as u64).sum();
            assert_eq!(r.step_node_units, units);
            assert_eq!(
                r.member_completions, units,
                "conservation violated: completions != committed units: {r:?}"
            );
            assert_eq!(r.samples_processed, r.committed_steps * partitions);
            assert!(r.committed_steps <= total);
            assert_eq!(r.lost_steps, total - r.committed_steps);
            for c in log {
                assert!((1..=world).contains(&c.world));
                if rigid {
                    assert_eq!(c.world, world, "rigid gang never commits below full world");
                } else {
                    assert!(c.world >= gang_min, "elastic gang floor respected");
                }
                let mut seen = vec![0u32; partitions as usize];
                for shard in shard_partitions(c.step, c.world, partitions) {
                    for i in shard {
                        seen[i as usize] += 1;
                    }
                }
                assert!(
                    seen.iter().all(|&n| n == 1),
                    "step {} at world {}: every partition exactly once",
                    c.step,
                    c.world
                );
            }
            // step numbers never jump forward: each commit is +1 from its
            // predecessor, or a checkpoint-rollback replay
            for w in log.windows(2) {
                assert!(
                    w[1].step == w[0].step + 1 || w[1].step <= w[0].step,
                    "a step was skipped: {w:?}"
                );
            }
            let stats = d.fleet_stats();
            assert!(stats.preemptions as usize <= stats.nodes_launched);
        },
    );
}
