//! Property tests on the shared [`FleetEngine`] (via the crate's own
//! `util::prop` harness — this image has no proptest), plus the
//! cross-driver storm-timing regression test.
//!
//! The conservation property is workload-agnostic: under any mix of
//! storms, Poisson markets, and price traces, every dispatched work unit
//! either completes or is explicitly requeued (never silently lost), the
//! lifecycle classes partition the fleet (the live count can never go
//! negative), and a preemption notice always precedes its kill — all
//! checked by [`FleetEngine::check_invariants`] inside every hook.

use std::collections::BTreeMap;

use hyper_dist::cloud::{PriceTrace, ProvisionerConfig, SpotMarketConfig, StormEvent};
use hyper_dist::fleet::{FleetConfig, FleetEngine, PriceTraceConfig, UnitsWorkload as Units};
use hyper_dist::sim::SimRng;
use hyper_dist::util::prop::run_prop;

/// After any run: nothing was silently lost.
fn assert_conserved(engine: &FleetEngine, w: &Units) {
    engine.check_invariants();
    assert_eq!(w.completed, w.total, "every unit completed");
    assert!(w.queue.is_empty(), "no unit left queued after completion");
    assert_eq!(
        w.dispatched,
        w.completed as u64 + w.requeued as u64,
        "every dispatched unit completed or was explicitly requeued"
    );
    assert!(
        engine.stats().preemptions as usize <= engine.stats().nodes_launched,
        "preemptions counted at most once per node"
    );
}

/// Storms + an optional background Poisson market, random shapes.
#[test]
fn prop_fleet_conservation_under_storms_and_market() {
    run_prop(
        "fleet conservation (storms + market)",
        60,
        |rng: &mut SimRng| {
            let total = 1 + rng.gen_range(30) as usize;
            let unit_s = 1.0 + rng.gen_range(25) as f64;
            let workers = 1 + rng.gen_range(5) as usize;
            let market = rng.gen_bool(0.5);
            let mean_ttp = 120.0 + rng.gen_range(2000) as f64;
            let n_storms = rng.gen_range(3) as usize;
            let storms: Vec<(f64, usize, f64)> = (0..n_storms)
                .map(|_| {
                    (
                        rng.gen_range(300) as f64,
                        rng.gen_range(6) as usize,
                        if rng.gen_bool(0.5) { 0.0 } else { 2.0 + rng.gen_range(20) as f64 },
                    )
                })
                .collect();
            (total, unit_s, workers, market, mean_ttp, storms, rng.next_u64())
        },
        |(total, unit_s, workers, market, mean_ttp, storms, seed)| {
            let mut engine = FleetEngine::new(FleetConfig {
                spot_market: market.then(|| SpotMarketConfig {
                    mean_ttp_s: mean_ttp,
                    notice_s: 30.0,
                }),
                storm: storms
                    .iter()
                    .map(|&(at_s, kills, notice_s)| StormEvent { at_s, kills, notice_s })
                    .collect(),
                seed,
                ..FleetConfig::default()
            });
            let mut w = Units::new(total, unit_s, workers, true);
            engine.run(&mut w).unwrap();
            let end = engine.now().as_secs_f64();
            engine.shutdown(engine.now());
            assert_conserved(&engine, &w);
            // storms fire in time order, each at exactly its scripted
            // engine-start time; every storm due before the run ended fired
            let fired = engine.stats().storms_fired_at_s.clone();
            assert!(fired.windows(2).all(|p| p[0] <= p[1]), "{fired:?}");
            let mut cfg_times: Vec<f64> = storms.iter().map(|&(t, _, _)| t).collect();
            cfg_times.sort_by(f64::total_cmp);
            for at in &fired {
                assert!(cfg_times.contains(at), "storm fired off-schedule: {at}");
            }
            let due = cfg_times.iter().filter(|t| **t < end).count();
            assert!(fired.len() >= due, "a due storm never fired: {fired:?} vs {cfg_times:?}");
        },
    );
}

/// Price-trace preemption with random spikes and a bid the trace always
/// eventually recovers below (so deferred capacity can provision).
#[test]
fn prop_fleet_conservation_under_price_traces() {
    run_prop(
        "fleet conservation (price trace)",
        60,
        |rng: &mut SimRng| {
            let total = 1 + rng.gen_range(20) as usize;
            let unit_s = 1.0 + rng.gen_range(20) as f64;
            let workers = 1 + rng.gen_range(4) as usize;
            // random step series ending low, so the market always recovers
            let n = 2 + rng.gen_range(5) as usize;
            let mut points: Vec<(f64, f64)> = Vec::with_capacity(n + 1);
            let mut t = 0.0;
            for _ in 0..n {
                points.push((t, rng.gen_range(100) as f64 / 100.0));
                t += 20.0 + rng.gen_range(200) as f64;
            }
            points.push((t, 0.01));
            let bid = 0.02 + rng.gen_range(80) as f64 / 100.0;
            let notice_s = if rng.gen_bool(0.5) { 0.0 } else { rng.gen_range(30) as f64 };
            (total, unit_s, workers, points, bid, notice_s, rng.next_u64())
        },
        |(total, unit_s, workers, points, bid, notice_s, seed)| {
            let trace = PriceTrace::new(points).unwrap();
            let mut engine = FleetEngine::new(FleetConfig {
                price_trace: Some(PriceTraceConfig { trace, bid_usd: bid, notice_s }),
                seed,
                ..FleetConfig::default()
            });
            let mut w = Units::new(total, unit_s, workers, true);
            engine.run(&mut w).unwrap();
            engine.shutdown(engine.now());
            assert_conserved(&engine, &w);
        },
    );
}

/// One driver's flight-recorder trace, checked for the full preemption
/// protocol: the storm lands at exactly `t=60 s`, exactly two nodes get
/// the notice at that instant, and every victim either ends in a hard
/// kill — with `node.notice` → `node.drain` → `node.kill` in record
/// order, the drain span stretching from the notice to the kill at
/// `t=65 s` — or exits early through a voluntary `node.release` inside
/// the notice window (a drained replica finishing its last batch).
fn assert_storm_protocol(records: &[hyper_dist::obs::Record], label: &str) {
    use hyper_dist::obs::RecordKind;

    let storm: Vec<_> = records.iter().filter(|r| r.name == "fleet.storm").collect();
    assert_eq!(storm.len(), 1, "{label}: exactly one storm record");
    assert_eq!(storm[0].ts_ns, 60_000_000_000, "{label}: storm fired off engine start");
    assert_eq!(storm[0].arg("kills").and_then(|a| a.as_u64()), Some(2), "{label}");

    let victims: Vec<u32> = records
        .iter()
        .filter(|r| r.name == "node.notice")
        .map(|r| {
            assert_eq!(r.ts_ns, 60_000_000_000, "{label}: notices land with the storm");
            r.pid
        })
        .collect();
    assert_eq!(victims.len(), 2, "{label}: the wave noticed 2 nodes");

    for pid in victims {
        let find = |name: &str| records.iter().find(|r| r.name == name && r.pid == pid);
        let notice = find("node.notice").expect("victim has a notice");
        match find("node.kill") {
            Some(kill) => {
                let drain = find("node.drain")
                    .unwrap_or_else(|| panic!("{label}: node {pid} killed without drain"));
                assert!(
                    notice.seq < drain.seq && drain.seq < kill.seq,
                    "{label}: node {pid} must record notice -> drain -> kill in order"
                );
                assert_eq!(kill.ts_ns, 65_000_000_000, "{label}: hard kill after 5s notice");
                assert_eq!(drain.ts_ns, notice.ts_ns, "{label}: drain opens at the notice");
                assert_eq!(drain.end_ns(), kill.ts_ns, "{label}: drain closes at the kill");
                assert_eq!(drain.kind, RecordKind::Span { dur_ns: 5_000_000_000 });
                assert_eq!(drain.arg("noticed").and_then(|a| a.as_u64()), Some(1));
            }
            None => {
                // drained to completion before the hard kill landed
                let release = find("node.release").unwrap_or_else(|| {
                    panic!("{label}: noticed node {pid} neither killed nor released")
                });
                assert!(release.seq > notice.seq, "{label}: release follows the notice");
                assert!(
                    release.ts_ns <= 65_000_000_000,
                    "{label}: a voluntary exit beats the hard kill"
                );
            }
        }
    }
}

/// The storm-timing bugfix pinned end to end — now from the flight
/// recorder itself: all three virtual-time drivers schedule a `t=60 s`
/// storm against the SAME origin (engine start), so each driver's trace
/// must carry the identical `fleet.storm` instant and the full
/// notice→drain→kill protocol for every victim; the search trace must
/// additionally prove (by command hash) that every resume continued the
/// byte-identical command its trial ran before the preemption.
#[test]
fn storm_at_60s_fires_at_the_same_instant_in_all_three_drivers() {
    use hyper_dist::obs::{FlightRecorder, Record};
    use hyper_dist::scheduler::{SimDriver, SimDriverConfig};
    use hyper_dist::search::{CurveConfig, SearchDriver, SearchDriverConfig};
    use hyper_dist::serve::{Load, ServeSim, ServeSimConfig};
    use hyper_dist::sim::{OpenLoop, SimClock};
    use hyper_dist::workflow::{Recipe, Workflow};

    let recorder = || FlightRecorder::sim(1 << 16, SimClock::new());
    // a 5s notice makes the drain window observable: notice at 60,
    // hard kill at 65, voluntary exits allowed in between
    let storm = vec![StormEvent { at_s: 60.0, kills: 2, notice_s: 5.0 }];
    // deliberately slow, exact provisioning: nodes are only ready at
    // t=55 and first dispatch follows — a "time since dispatch" or
    // "time since ready" origin would skew the firing time
    let exact = ProvisionerConfig { warm_cache_prob: 1.0, jitter: 0.0, ..Default::default() };

    // 1. SimDriver (DAG tasks)
    let yaml = r#"
name: storm-origin
experiments:
  - name: etl
    instance: m5.xlarge
    workers: 4
    spot: true
    command: "p {i}"
    params: { i: { range: [0, 15] } }
    work: { duration_s: 20.0 }
"#;
    let mut wf = Workflow::compile(Recipe::from_yaml(yaml).unwrap(), 1).unwrap();
    let mut dag = SimDriver::new(SimDriverConfig {
        provisioner: exact.clone(),
        spot_market: SpotMarketConfig { mean_ttp_s: 1e9, notice_s: 120.0 },
        storm: storm.clone(),
        ..Default::default()
    });
    let dag_rec = recorder();
    dag.set_obs(dag_rec.clone());
    let r = dag.run(&mut wf).unwrap();
    assert!(r.workflow_complete);

    // 2. ServeSim (batching replicas), cold start: replicas ready at 55
    let mut serve = ServeSim::new(ServeSimConfig {
        initial_replicas: 4,
        warm_start: false,
        provisioner: exact.clone(),
        storm: storm.clone(),
        ..Default::default()
    });
    let serve_rec = recorder();
    serve.set_obs(serve_rec.clone());
    let sr = serve.run(Load::Open(OpenLoop::poisson(50.0)), 90.0).unwrap();
    assert_eq!(sr.completed, sr.admitted);

    // 3. SearchDriver (checkpointable trials)
    let mut scfg = SearchDriverConfig {
        curve: CurveConfig { noise: 0.0, ..Default::default() },
        provisioner: exact,
        storm,
        ..Default::default()
    };
    scfg.search.trials = 8;
    scfg.search.max_steps = 30;
    scfg.search.step_time_s = 1.0;
    scfg.search.workers = 4;
    let mut search = SearchDriver::new(
        scfg,
        std::sync::Arc::new(hyper_dist::storage::MemStore::new()),
        &{
            let mut m = BTreeMap::new();
            m.insert("p".to_string(), hyper_dist::workflow::ParamSpec::Range([0, 7]));
            m
        },
        "t {p}",
    )
    .unwrap();
    let search_rec = recorder();
    search.set_obs(search_rec.clone());
    let xr = search.run().unwrap();
    assert_eq!(xr.lost, 0);

    // every driver's trace shows the same wave at the same instant, with
    // the full preemption protocol per victim
    let dag_records = dag_rec.snapshot();
    let serve_records = serve_rec.snapshot();
    let search_records = search_rec.snapshot();
    assert_storm_protocol(&dag_records, "dag");
    assert_storm_protocol(&serve_records, "serve");
    assert_storm_protocol(&search_records, "search");
    let storm_ts = |records: &[Record]| {
        records.iter().find(|r| r.name == "fleet.storm").expect("storm record").ts_ns
    };
    assert_eq!(storm_ts(&dag_records), storm_ts(&serve_records));
    assert_eq!(storm_ts(&serve_records), storm_ts(&search_records));

    // checkpoint/resume integrity, proven from the trace alone: every
    // resume carries the command hash of the byte-identical command its
    // trial's run segments carry — a resume never continues someone
    // else's command
    let resumes: Vec<_> =
        search_records.iter().filter(|r| r.name == "trial.resume").collect();
    assert!(
        !resumes.is_empty(),
        "the storm paused trials that must resume ({} pauses recorded)",
        xr.pauses
    );
    for resume in resumes {
        let hash = resume.arg("command_hash").and_then(|a| a.as_u64()).unwrap();
        let runs: Vec<_> = search_records
            .iter()
            .filter(|r| r.name == "trial.run" && r.tid == resume.tid)
            .collect();
        assert!(!runs.is_empty(), "resumed trial {} has run segments", resume.tid);
        for run in runs {
            assert_eq!(
                run.arg("command_hash").and_then(|a| a.as_u64()),
                Some(hash),
                "trial {}: resume must continue the byte-identical command",
                resume.tid
            );
        }
    }
}
