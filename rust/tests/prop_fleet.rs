//! Property tests on the shared [`FleetEngine`] (via the crate's own
//! `util::prop` harness — this image has no proptest), plus the
//! cross-driver storm-timing regression test.
//!
//! The conservation property is workload-agnostic: under any mix of
//! storms, Poisson markets, and price traces, every dispatched work unit
//! either completes or is explicitly requeued (never silently lost), the
//! lifecycle classes partition the fleet (the live count can never go
//! negative), and a preemption notice always precedes its kill — all
//! checked by [`FleetEngine::check_invariants`] inside every hook.

use std::collections::BTreeMap;

use hyper_dist::cloud::{PriceTrace, ProvisionerConfig, SpotMarketConfig, StormEvent};
use hyper_dist::fleet::{FleetConfig, FleetEngine, PriceTraceConfig, UnitsWorkload as Units};
use hyper_dist::sim::SimRng;
use hyper_dist::util::prop::run_prop;

/// After any run: nothing was silently lost.
fn assert_conserved(engine: &FleetEngine, w: &Units) {
    engine.check_invariants();
    assert_eq!(w.completed, w.total, "every unit completed");
    assert!(w.queue.is_empty(), "no unit left queued after completion");
    assert_eq!(
        w.dispatched,
        w.completed as u64 + w.requeued as u64,
        "every dispatched unit completed or was explicitly requeued"
    );
    assert!(
        engine.stats().preemptions as usize <= engine.stats().nodes_launched,
        "preemptions counted at most once per node"
    );
}

/// Storms + an optional background Poisson market, random shapes.
#[test]
fn prop_fleet_conservation_under_storms_and_market() {
    run_prop(
        "fleet conservation (storms + market)",
        60,
        |rng: &mut SimRng| {
            let total = 1 + rng.gen_range(30) as usize;
            let unit_s = 1.0 + rng.gen_range(25) as f64;
            let workers = 1 + rng.gen_range(5) as usize;
            let market = rng.gen_bool(0.5);
            let mean_ttp = 120.0 + rng.gen_range(2000) as f64;
            let n_storms = rng.gen_range(3) as usize;
            let storms: Vec<(f64, usize, f64)> = (0..n_storms)
                .map(|_| {
                    (
                        rng.gen_range(300) as f64,
                        rng.gen_range(6) as usize,
                        if rng.gen_bool(0.5) { 0.0 } else { 2.0 + rng.gen_range(20) as f64 },
                    )
                })
                .collect();
            (total, unit_s, workers, market, mean_ttp, storms, rng.next_u64())
        },
        |(total, unit_s, workers, market, mean_ttp, storms, seed)| {
            let mut engine = FleetEngine::new(FleetConfig {
                spot_market: market.then(|| SpotMarketConfig {
                    mean_ttp_s: mean_ttp,
                    notice_s: 30.0,
                }),
                storm: storms
                    .iter()
                    .map(|&(at_s, kills, notice_s)| StormEvent { at_s, kills, notice_s })
                    .collect(),
                seed,
                ..FleetConfig::default()
            });
            let mut w = Units::new(total, unit_s, workers, true);
            engine.run(&mut w).unwrap();
            let end = engine.now().as_secs_f64();
            engine.shutdown(engine.now());
            assert_conserved(&engine, &w);
            // storms fire in time order, each at exactly its scripted
            // engine-start time; every storm due before the run ended fired
            let fired = engine.stats().storms_fired_at_s.clone();
            assert!(fired.windows(2).all(|p| p[0] <= p[1]), "{fired:?}");
            let mut cfg_times: Vec<f64> = storms.iter().map(|&(t, _, _)| t).collect();
            cfg_times.sort_by(f64::total_cmp);
            for at in &fired {
                assert!(cfg_times.contains(at), "storm fired off-schedule: {at}");
            }
            let due = cfg_times.iter().filter(|t| **t < end).count();
            assert!(fired.len() >= due, "a due storm never fired: {fired:?} vs {cfg_times:?}");
        },
    );
}

/// Price-trace preemption with random spikes and a bid the trace always
/// eventually recovers below (so deferred capacity can provision).
#[test]
fn prop_fleet_conservation_under_price_traces() {
    run_prop(
        "fleet conservation (price trace)",
        60,
        |rng: &mut SimRng| {
            let total = 1 + rng.gen_range(20) as usize;
            let unit_s = 1.0 + rng.gen_range(20) as f64;
            let workers = 1 + rng.gen_range(4) as usize;
            // random step series ending low, so the market always recovers
            let n = 2 + rng.gen_range(5) as usize;
            let mut points: Vec<(f64, f64)> = Vec::with_capacity(n + 1);
            let mut t = 0.0;
            for _ in 0..n {
                points.push((t, rng.gen_range(100) as f64 / 100.0));
                t += 20.0 + rng.gen_range(200) as f64;
            }
            points.push((t, 0.01));
            let bid = 0.02 + rng.gen_range(80) as f64 / 100.0;
            let notice_s = if rng.gen_bool(0.5) { 0.0 } else { rng.gen_range(30) as f64 };
            (total, unit_s, workers, points, bid, notice_s, rng.next_u64())
        },
        |(total, unit_s, workers, points, bid, notice_s, seed)| {
            let trace = PriceTrace::new(points).unwrap();
            let mut engine = FleetEngine::new(FleetConfig {
                price_trace: Some(PriceTraceConfig { trace, bid_usd: bid, notice_s }),
                seed,
                ..FleetConfig::default()
            });
            let mut w = Units::new(total, unit_s, workers, true);
            engine.run(&mut w).unwrap();
            engine.shutdown(engine.now());
            assert_conserved(&engine, &w);
        },
    );
}

/// The storm-timing bugfix pinned end to end: all three virtual-time
/// drivers schedule a `t=60 s` storm against the SAME origin — engine
/// start — so the wave lands at the identical virtual instant in every
/// scenario, regardless of provisioning latency or first dispatch.
#[test]
fn storm_at_60s_fires_at_the_same_instant_in_all_three_drivers() {
    use hyper_dist::scheduler::{SimDriver, SimDriverConfig};
    use hyper_dist::search::{CurveConfig, SearchDriver, SearchDriverConfig};
    use hyper_dist::serve::{Load, ServeSim, ServeSimConfig};
    use hyper_dist::sim::OpenLoop;
    use hyper_dist::workflow::{Recipe, Workflow};

    let storm = vec![StormEvent { at_s: 60.0, kills: 2, notice_s: 0.0 }];
    // deliberately slow, exact provisioning: nodes are only ready at
    // t=55 and first dispatch follows — a "time since dispatch" or
    // "time since ready" origin would skew the firing time
    let exact = ProvisionerConfig { warm_cache_prob: 1.0, jitter: 0.0, ..Default::default() };

    // 1. SimDriver (DAG tasks)
    let yaml = r#"
name: storm-origin
experiments:
  - name: etl
    instance: m5.xlarge
    workers: 4
    spot: true
    command: "p {i}"
    params: { i: { range: [0, 15] } }
    work: { duration_s: 20.0 }
"#;
    let mut wf = Workflow::compile(Recipe::from_yaml(yaml).unwrap(), 1).unwrap();
    let mut dag = SimDriver::new(SimDriverConfig {
        provisioner: exact.clone(),
        spot_market: SpotMarketConfig { mean_ttp_s: 1e9, notice_s: 120.0 },
        storm: storm.clone(),
        ..Default::default()
    });
    let r = dag.run(&mut wf).unwrap();
    assert!(r.workflow_complete);

    // 2. ServeSim (batching replicas), cold start: replicas ready at 55
    let mut serve = ServeSim::new(ServeSimConfig {
        initial_replicas: 4,
        warm_start: false,
        provisioner: exact.clone(),
        storm: storm.clone(),
        ..Default::default()
    });
    let sr = serve.run(Load::Open(OpenLoop::poisson(50.0)), 90.0).unwrap();
    assert_eq!(sr.completed, sr.admitted);

    // 3. SearchDriver (checkpointable trials)
    let mut scfg = SearchDriverConfig {
        curve: CurveConfig { noise: 0.0, ..Default::default() },
        provisioner: exact,
        storm,
        ..Default::default()
    };
    scfg.search.trials = 8;
    scfg.search.max_steps = 30;
    scfg.search.step_time_s = 1.0;
    scfg.search.workers = 4;
    let mut search = SearchDriver::new(
        scfg,
        std::sync::Arc::new(hyper_dist::storage::MemStore::new()),
        &{
            let mut m = BTreeMap::new();
            m.insert("p".to_string(), hyper_dist::workflow::ParamSpec::Range([0, 7]));
            m
        },
        "t {p}",
    )
    .unwrap();
    let xr = search.run().unwrap();
    assert_eq!(xr.lost, 0);

    let fired = [
        dag.fleet_stats().storms_fired_at_s.clone(),
        serve.fleet_stats().storms_fired_at_s.clone(),
        search.fleet_stats().storms_fired_at_s.clone(),
    ];
    for (i, f) in fired.iter().enumerate() {
        assert_eq!(f, &vec![60.0], "driver {i} fired its storm off the shared origin");
    }
    assert!(
        fired[0] == fired[1] && fired[1] == fired[2],
        "all three scenarios see the wave at the same virtual instant: {fired:?}"
    );
}
