//! Tasks: the execution unit (§II.A).
//!
//! "Task is the execution unit, which encapsulates a process. Each Task
//! has assigned Node … Single Node might execute multiple Tasks."


use super::params::{render_command, Assignment};
use super::recipe::ExperimentSpec;

/// Stable identity: (experiment index, task index within experiment).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId {
    pub experiment: u32,
    pub index: u32,
}

impl std::fmt::Display for TaskId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "e{}t{}", self.experiment, self.index)
    }
}

/// Scheduler-visible lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    Pending,
    Running,
    Succeeded,
    /// Exhausted retries.
    Failed,
}

/// A concrete task: rendered command + its parameter binding.
#[derive(Debug, Clone)]
pub struct Task {
    pub id: TaskId,
    pub experiment_name: String,
    pub command: String,
    pub assignment: Assignment,
    pub state: TaskState,
    /// How many times this task has been (re)started.
    pub attempts: u32,
    pub max_retries: u32,
    /// Work model copied from the experiment (virtual-time executors).
    pub flops: Option<f64>,
    pub duration_s: Option<f64>,
    pub input_bytes: Option<u64>,
}

impl Task {
    /// Materialize the `index`-th task of an experiment from an assignment.
    pub fn materialize(
        experiment: u32,
        index: u32,
        spec: &ExperimentSpec,
        assignment: Assignment,
    ) -> Self {
        Self {
            id: TaskId { experiment, index },
            experiment_name: spec.name.clone(),
            command: render_command(&spec.command, &assignment),
            assignment,
            state: TaskState::Pending,
            attempts: 0,
            max_retries: spec.max_retries,
            flops: spec.work.flops_per_task,
            duration_s: spec.work.duration_s,
            input_bytes: spec.work.input_bytes,
        }
    }

    /// Can this task be retried after a failure? (§III.D: "the task with
    /// exact command arguments gets rescheduled on a different node".)
    pub fn can_retry(&self) -> bool {
        self.attempts <= self.max_retries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::params::ParamValue;
    use crate::workflow::recipe::WorkSpec;

    fn spec() -> ExperimentSpec {
        ExperimentSpec {
            name: "train".into(),
            image: "img".into(),
            instance: "p3.2xlarge".into(),
            workers: 2,
            spot: true,
            command: "run --lr {lr}".into(),
            samples: None,
            params: Default::default(),
            depends_on: vec![],
            max_retries: 2,
            work: WorkSpec { flops_per_task: Some(1e12), duration_s: None, input_bytes: None },
            search: None,
        }
    }

    #[test]
    fn materialize_renders_command() {
        let mut a = Assignment::new();
        a.insert("lr".into(), ParamValue::Float(0.1));
        let t = Task::materialize(3, 7, &spec(), a.clone());
        assert_eq!(t.command, "run --lr 0.1");
        assert_eq!(t.id, TaskId { experiment: 3, index: 7 });
        assert_eq!(t.assignment, a);
        assert_eq!(t.flops, Some(1e12));
        assert_eq!(t.state, TaskState::Pending);
    }

    #[test]
    fn retry_budget() {
        let mut t = Task::materialize(0, 0, &spec(), Assignment::new());
        assert!(t.can_retry());
        t.attempts = 3; // max_retries = 2 -> 3rd attempt exhausted
        assert!(!t.can_retry());
    }

    #[test]
    fn task_id_display() {
        assert_eq!(TaskId { experiment: 1, index: 42 }.to_string(), "e1t42");
    }
}
