//! Workflows: YAML recipes -> DAG of experiments -> tasks (§II).
//!
//! "Workflow is a directed acyclic graph consisting of Experiment nodes
//! and their dependency as edges. Single Experiment contains multiple
//! Tasks. Tasks within the same experiment execute the same command with
//! different arguments."

pub mod dag;
pub mod params;
pub mod recipe;
pub mod task;

pub use dag::Workflow;
pub use params::{render_command, sample_assignments, Assignment, ParamSpec, ParamValue};
pub use recipe::{ExperimentSpec, Recipe, SearchSpec, TrainSpec, WorkSpec};
pub use task::{Task, TaskId, TaskState};
