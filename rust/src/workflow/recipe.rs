//! YAML recipe parsing — the paper's code-as-infrastructure interface
//! (§II.B): environment, hardware, worker count, parameters and
//! parameterized commands.
//!
//! ```yaml
//! name: yolo-train
//! experiments:
//!   - name: train
//!     image: horovod/horovod:0.16
//!     instance: p3.2xlarge
//!     workers: 8
//!     spot: true
//!     command: "python train.py --lr {lr} --bs {bs}"
//!     samples: 16
//!     params:
//!       lr: { log_uniform: [1.0e-4, 1.0e-2] }
//!       bs: { choice: [32, 64] }
//!     work: { flops_per_task: 1.0e15 }
//!     depends_on: [preprocess]
//! ```

use std::collections::{BTreeMap, BTreeSet};


use crate::cloud::InstanceType;
use crate::config::{GangMode, SearchAlgo, TrainConfig};
use crate::util::{yamlite, Json};
use crate::{Error, Result};

use super::params::ParamSpec;

/// How much work one task represents — used by the virtual-time executors
/// (`duration_s` wins if both are given; `flops_per_task` divides by the
/// node's device throughput).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkSpec {
    pub flops_per_task: Option<f64>,
    pub duration_s: Option<f64>,
    /// Input bytes each task reads through HFS.
    pub input_bytes: Option<u64>,
}

/// The `search:` stanza of an experiment: turns its parameter sweep into
/// a trial-based hyperparameter search driven by [`crate::search`].
///
/// ```yaml
///     search: { algo: asha, max_steps: 81, rung_steps: 3, eta: 3 }
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct SearchSpec {
    /// Early-stopping policy (default `asha`).
    pub algo: SearchAlgo,
    /// Steps a trial runs to completion (`R`). Required.
    pub max_steps: u64,
    /// First rung milestone in steps (default 1).
    pub rung_steps: u64,
    /// Successive-halving reduction factor (default 3).
    pub eta: u32,
    /// Virtual seconds per training step (default 1.0).
    pub step_time_s: f64,
    /// Checkpoint cadence in steps; 0 = at rung milestones only
    /// (default = `rung_steps`).
    pub checkpoint_every_steps: u64,
}

impl SearchSpec {
    fn from_json(v: &Json, exp: &str) -> Result<Self> {
        let bad =
            |field: &str| Error::Recipe(format!("experiment {exp:?}: invalid search.{field}"));
        let algo = match v.get("algo") {
            None | Some(Json::Null) => SearchAlgo::Asha,
            Some(a) => a.as_str().ok_or_else(|| bad("algo"))?.parse()?,
        };
        let max_steps = v.req_u64("max_steps").map_err(|_| bad("max_steps"))?;
        let rung_steps = v.get("rung_steps").and_then(Json::as_u64).unwrap_or(1);
        Ok(SearchSpec {
            algo,
            max_steps,
            rung_steps,
            eta: v.get("eta").and_then(Json::as_u64).unwrap_or(3) as u32,
            step_time_s: v.get("step_time_s").and_then(Json::as_f64).unwrap_or(1.0),
            checkpoint_every_steps: v
                .get("checkpoint_every_steps")
                .and_then(Json::as_u64)
                .unwrap_or(rung_steps),
        })
    }
}

/// The `train:` stanza of an experiment: run it as one elastic
/// gang-scheduled data-parallel training job driven by
/// [`crate::train::TrainDriver`] (the experiment's `instance`/`spot`
/// supply the fleet; `workers` is ignored — the gang size is
/// `world_size`).
///
/// ```yaml
///     train: { world_size: 8, gang_min: 2, total_steps: 100 }
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct TrainSpec {
    /// Full gang size. Required, must be > 0.
    pub world_size: usize,
    /// Smallest world an elastic gang keeps stepping at (default 1).
    pub gang_min: usize,
    /// Steps to commit before the job is done.
    pub total_steps: u64,
    /// Data partitions resharded over the gang every step.
    pub partitions: u64,
    /// Virtual seconds one node spends computing one partition.
    pub sample_time_s: f64,
    /// Gradient bytes ring-allreduced per step.
    pub model_bytes: u64,
    /// Periodic checkpoint cadence in steps (0 = drain checkpoints only).
    pub checkpoint_every_steps: u64,
    /// `elastic` (default) or `rigid` recovery.
    pub mode: GangMode,
}

impl TrainSpec {
    fn from_json(v: &Json, exp: &str) -> Result<Self> {
        let bad =
            |field: &str| Error::Recipe(format!("experiment {exp:?}: invalid train.{field}"));
        let mode = match v.get("mode") {
            None | Some(Json::Null) => GangMode::Elastic,
            Some(m) => m.as_str().ok_or_else(|| bad("mode"))?.parse()?,
        };
        let world_size = v.req_u64("world_size").map_err(|_| bad("world_size"))? as usize;
        let d = TrainConfig::default();
        Ok(TrainSpec {
            world_size,
            gang_min: v.get("gang_min").and_then(Json::as_u64).unwrap_or(1) as usize,
            total_steps: v.get("total_steps").and_then(Json::as_u64).unwrap_or(d.total_steps),
            partitions: v.get("partitions").and_then(Json::as_u64).unwrap_or(d.partitions),
            sample_time_s: v
                .get("sample_time_s")
                .and_then(Json::as_f64)
                .unwrap_or(d.sample_time_s),
            model_bytes: v.get("model_bytes").and_then(Json::as_u64).unwrap_or(d.model_bytes),
            checkpoint_every_steps: v
                .get("checkpoint_every_steps")
                .and_then(Json::as_u64)
                .unwrap_or(d.checkpoint_every_steps),
            mode,
        })
    }
}

/// One experiment block of the recipe.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    pub name: String,
    /// Container image (opaque string; pull cost modeled by the provisioner).
    pub image: String,
    /// Instance type name from the catalog (e.g. "p3.2xlarge").
    pub instance: String,
    pub workers: usize,
    pub spot: bool,
    /// Templated command; `{param}` placeholders are substituted per task.
    pub command: String,
    /// Number of tasks to sample (§II.C `n`); default = full grid.
    pub samples: Option<usize>,
    pub params: BTreeMap<String, ParamSpec>,
    pub depends_on: Vec<String>,
    /// Max reschedules per task after node failures.
    pub max_retries: u32,
    pub work: WorkSpec,
    /// Optional `search:` stanza — run this experiment's sweep as a
    /// trial-based hyperparameter search (ASHA & friends) instead of a
    /// fixed-duration task fan-out.
    pub search: Option<SearchSpec>,
    /// Optional `train:` stanza — run this experiment as one elastic
    /// gang-scheduled training job instead of a task fan-out.
    pub train: Option<TrainSpec>,
}

fn default_image() -> String {
    "pytorch/pytorch:latest".to_string()
}

fn default_workers() -> usize {
    1
}

fn default_retries() -> u32 {
    5
}

impl ExperimentSpec {
    /// Build one experiment block from the parsed document.
    fn from_json(v: &Json) -> Result<Self> {
        let name = v
            .req_str("name")
            .map_err(|_| Error::Recipe("experiment needs a name".into()))?
            .to_string();
        let bad = |field: &str| Error::Recipe(format!("experiment {name:?}: invalid {field}"));
        let params = match v.get("params") {
            None | Some(Json::Null) => BTreeMap::new(),
            Some(p) => p
                .as_obj()
                .ok_or_else(|| bad("params"))?
                .iter()
                .map(|(k, spec)| Ok((k.clone(), ParamSpec::from_json(spec)?)))
                .collect::<Result<BTreeMap<_, _>>>()?,
        };
        let depends_on = match v.get("depends_on") {
            None | Some(Json::Null) => Vec::new(),
            Some(d) => d
                .as_arr()
                .ok_or_else(|| bad("depends_on"))?
                .iter()
                .map(|x| x.as_str().map(str::to_string).ok_or_else(|| bad("depends_on")))
                .collect::<Result<Vec<_>>>()?,
        };
        let work = match v.get("work") {
            None | Some(Json::Null) => WorkSpec::default(),
            Some(w) => WorkSpec {
                flops_per_task: w.get("flops_per_task").and_then(Json::as_f64),
                duration_s: w.get("duration_s").and_then(Json::as_f64),
                input_bytes: w.get("input_bytes").and_then(Json::as_u64),
            },
        };
        let search = match v.get("search") {
            None | Some(Json::Null) => None,
            Some(s) => Some(SearchSpec::from_json(s, &name)?),
        };
        let train = match v.get("train") {
            None | Some(Json::Null) => None,
            Some(t) => Some(TrainSpec::from_json(t, &name)?),
        };
        Ok(ExperimentSpec {
            image: v
                .get("image")
                .and_then(Json::as_str)
                .map(str::to_string)
                .unwrap_or_else(default_image),
            instance: v.req_str("instance").map_err(|_| bad("instance"))?.to_string(),
            workers: v.get("workers").and_then(Json::as_u64).map(|w| w as usize).unwrap_or_else(default_workers),
            spot: v.get("spot").and_then(Json::as_bool).unwrap_or(false),
            command: v.req_str("command").map_err(|_| bad("command"))?.to_string(),
            samples: v.get("samples").and_then(Json::as_u64).map(|s| s as usize),
            max_retries: v
                .get("max_retries")
                .and_then(Json::as_u64)
                .map(|r| r as u32)
                .unwrap_or_else(default_retries),
            params,
            depends_on,
            work,
            search,
            train,
            name,
        })
    }

    pub fn instance_type(&self) -> Result<InstanceType> {
        InstanceType::by_name(&self.instance)
            .map(|s| s.ty)
            .ok_or_else(|| Error::Recipe(format!("unknown instance type {:?}", self.instance)))
    }
}

/// A full parsed recipe.
#[derive(Debug, Clone)]
pub struct Recipe {
    pub name: String,
    pub version: u32,
    pub experiments: Vec<ExperimentSpec>,
}

impl Recipe {
    /// Parse and validate a YAML recipe (via the crate's YAML subset).
    /// Duplicate keys anywhere in the document — most commonly a parameter
    /// name written twice under `params:` — surface as [`Error::Recipe`].
    pub fn from_yaml(text: &str) -> Result<Self> {
        let doc = yamlite::parse(text).map_err(|e| match e {
            Error::Yaml(msg) if msg.contains("duplicate key") => Error::Recipe(msg),
            other => other,
        })?;
        let recipe = Self::from_json(&doc)?;
        recipe.validate()?;
        Ok(recipe)
    }

    /// Build a Recipe from the parsed document.
    fn from_json(doc: &Json) -> Result<Self> {
        let name = doc.req_str("name").map_err(|_| Error::Recipe("recipe needs a name".into()))?;
        let version = doc.get("version").and_then(Json::as_u64).unwrap_or(1) as u32;
        let exps = doc
            .get("experiments")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Recipe("recipe needs an experiments list".into()))?;
        let experiments =
            exps.iter().map(ExperimentSpec::from_json).collect::<Result<Vec<_>>>()?;
        Ok(Recipe { name: name.to_string(), version, experiments })
    }

    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::from_yaml(&std::fs::read_to_string(path)?)
    }

    /// Structural validation: unique names, known deps, known instances,
    /// positive workers, acyclicity (checked again when the DAG is built).
    pub fn validate(&self) -> Result<()> {
        if self.experiments.is_empty() {
            return Err(Error::Recipe("recipe has no experiments".into()));
        }
        let mut names = BTreeSet::new();
        for e in &self.experiments {
            if !names.insert(e.name.as_str()) {
                return Err(Error::Recipe(format!("duplicate experiment name {:?}", e.name)));
            }
            if e.workers == 0 {
                return Err(Error::Recipe(format!("{:?}: workers must be > 0", e.name)));
            }
            e.instance_type()?;
            if e.command.trim().is_empty() {
                return Err(Error::Recipe(format!("{:?}: empty command", e.name)));
            }
            if let Some(s) = &e.search {
                if s.rung_steps == 0 {
                    return Err(Error::Recipe(format!(
                        "{:?}: search.rung_steps must be > 0",
                        e.name
                    )));
                }
                if s.max_steps < s.rung_steps {
                    return Err(Error::Recipe(format!(
                        "{:?}: search.max_steps must be >= rung_steps",
                        e.name
                    )));
                }
                if s.eta < 2 {
                    return Err(Error::Recipe(format!("{:?}: search.eta must be >= 2", e.name)));
                }
                if s.step_time_s <= 0.0 || s.step_time_s.is_nan() {
                    return Err(Error::Recipe(format!(
                        "{:?}: search.step_time_s must be > 0",
                        e.name
                    )));
                }
            }
            if let Some(t) = &e.train {
                if t.world_size == 0 {
                    return Err(Error::Recipe(format!(
                        "{:?}: train.world_size must be > 0",
                        e.name
                    )));
                }
                if t.gang_min == 0 || t.gang_min > t.world_size {
                    return Err(Error::Recipe(format!(
                        "{:?}: train.gang_min must be in 1..=world_size ({})",
                        e.name, t.world_size
                    )));
                }
                if t.total_steps == 0 {
                    return Err(Error::Recipe(format!(
                        "{:?}: train.total_steps must be > 0",
                        e.name
                    )));
                }
                if t.partitions == 0 {
                    return Err(Error::Recipe(format!(
                        "{:?}: train.partitions must be > 0",
                        e.name
                    )));
                }
                if t.sample_time_s <= 0.0 || t.sample_time_s.is_nan() {
                    return Err(Error::Recipe(format!(
                        "{:?}: train.sample_time_s must be > 0",
                        e.name
                    )));
                }
            }
        }
        for e in &self.experiments {
            for d in &e.depends_on {
                if !names.contains(d.as_str()) {
                    return Err(Error::Recipe(format!(
                        "{:?} depends on unknown experiment {:?}",
                        e.name, d
                    )));
                }
                if d == &e.name {
                    return Err(Error::Recipe(format!("{:?} depends on itself", e.name)));
                }
            }
        }
        Ok(())
    }

    pub fn experiment(&self, name: &str) -> Option<&ExperimentSpec> {
        self.experiments.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const YAML: &str = r#"
name: demo
experiments:
  - name: prep
    instance: m5.24xlarge
    workers: 4
    command: "prep --shard {shard}"
    params:
      shard: { range: [0, 7] }
    work: { duration_s: 10.0 }
  - name: train
    instance: p3.2xlarge
    workers: 2
    spot: true
    command: "train --lr {lr}"
    samples: 4
    params:
      lr: { log_uniform: [1.0e-4, 1.0e-2] }
    depends_on: [prep]
"#;

    #[test]
    fn parses_full_recipe() {
        let r = Recipe::from_yaml(YAML).unwrap();
        assert_eq!(r.name, "demo");
        assert_eq!(r.experiments.len(), 2);
        let train = r.experiment("train").unwrap();
        assert!(train.spot);
        assert_eq!(train.samples, Some(4));
        assert_eq!(train.depends_on, vec!["prep"]);
        assert_eq!(train.max_retries, 5); // default
        let prep = r.experiment("prep").unwrap();
        assert_eq!(prep.work.duration_s, Some(10.0));
        assert_eq!(prep.params["shard"], ParamSpec::Range([0, 7]));
    }

    #[test]
    fn rejects_unknown_instance() {
        let bad = YAML.replace("p3.2xlarge", "quantum.9000");
        assert!(matches!(Recipe::from_yaml(&bad), Err(Error::Recipe(_))));
    }

    #[test]
    fn rejects_unknown_dependency() {
        let bad = YAML.replace("depends_on: [prep]", "depends_on: [ghost]");
        assert!(Recipe::from_yaml(&bad).is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        let bad = YAML.replace("name: train", "name: prep");
        assert!(Recipe::from_yaml(&bad).is_err());
    }

    #[test]
    fn rejects_self_dependency() {
        let bad = YAML.replace("depends_on: [prep]", "depends_on: [train]");
        assert!(Recipe::from_yaml(&bad).is_err());
    }

    #[test]
    fn rejects_zero_workers() {
        let bad = YAML.replace("workers: 4", "workers: 0");
        assert!(Recipe::from_yaml(&bad).is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(Recipe::from_yaml("name: x\nexperiments: []").is_err());
    }

    #[test]
    fn rejects_duplicate_parameter_names() {
        let bad = YAML.replace(
            "      lr: { log_uniform: [1.0e-4, 1.0e-2] }",
            "      lr: { log_uniform: [1.0e-4, 1.0e-2] }\n      lr: { uniform: [0.1, 0.9] }",
        );
        match Recipe::from_yaml(&bad) {
            Err(Error::Recipe(msg)) => {
                assert!(msg.contains("duplicate key \"lr\""), "{msg}")
            }
            other => panic!("expected Error::Recipe for a duplicated param, got {other:?}"),
        }
    }

    #[test]
    fn parses_search_stanza_with_defaults() {
        let yaml = YAML.replace(
            "    depends_on: [prep]",
            "    depends_on: [prep]\n    search: { max_steps: 81, rung_steps: 3 }",
        );
        let r = Recipe::from_yaml(&yaml).unwrap();
        let s = r.experiment("train").unwrap().search.clone().unwrap();
        assert_eq!(s.algo, SearchAlgo::Asha, "asha is the default algo");
        assert_eq!(s.max_steps, 81);
        assert_eq!(s.rung_steps, 3);
        assert_eq!(s.eta, 3);
        assert_eq!(s.step_time_s, 1.0);
        assert_eq!(s.checkpoint_every_steps, 3, "defaults to rung_steps");
        assert!(r.experiment("prep").unwrap().search.is_none());
    }

    #[test]
    fn parses_train_stanza_with_defaults() {
        let yaml = YAML.replace(
            "    depends_on: [prep]",
            "    depends_on: [prep]\n    train: { world_size: 8 }",
        );
        let r = Recipe::from_yaml(&yaml).unwrap();
        let t = r.experiment("train").unwrap().train.clone().unwrap();
        assert_eq!(t.world_size, 8);
        assert_eq!(t.gang_min, 1, "any surviving member keeps stepping");
        assert_eq!(t.mode, GangMode::Elastic, "elastic is the default");
        assert_eq!(t.total_steps, TrainConfig::default().total_steps);
        assert_eq!(t.partitions, TrainConfig::default().partitions);
        assert!(r.experiment("prep").unwrap().train.is_none());
    }

    #[test]
    fn train_stanza_validation() {
        let with = |stanza: &str| {
            YAML.replace(
                "    depends_on: [prep]",
                &format!("    depends_on: [prep]\n    train: {stanza}"),
            )
        };
        let rejects_naming = |stanza: &str, field: &str| match Recipe::from_yaml(&with(stanza)) {
            Err(Error::Recipe(msg)) => {
                assert!(msg.contains(field), "{stanza}: {msg} should name {field}")
            }
            other => panic!("{stanza}: expected Error::Recipe, got {other:?}"),
        };
        // missing and zero world_size both name the field
        rejects_naming("{ gang_min: 2 }", "train.world_size");
        rejects_naming("{ world_size: 0 }", "train.world_size");
        // gang_min out of 1..=world_size on both sides
        rejects_naming("{ world_size: 4, gang_min: 5 }", "train.gang_min");
        rejects_naming("{ world_size: 4, gang_min: 0 }", "train.gang_min");
        rejects_naming("{ world_size: 4, total_steps: 0 }", "train.total_steps");
        rejects_naming("{ world_size: 4, partitions: 0 }", "train.partitions");
        rejects_naming("{ world_size: 4, sample_time_s: 0.0 }", "train.sample_time_s");
        // unknown mode string
        assert!(Recipe::from_yaml(&with("{ world_size: 4, mode: floppy }")).is_err());
        // explicit full form parses
        let r = Recipe::from_yaml(&with(
            "{ world_size: 8, gang_min: 2, total_steps: 50, partitions: 64, \
             sample_time_s: 0.5, model_bytes: 1000000, checkpoint_every_steps: 5, \
             mode: rigid }",
        ))
        .unwrap();
        let t = r.experiment("train").unwrap().train.clone().unwrap();
        assert_eq!(t.mode, GangMode::Rigid);
        assert_eq!(t.gang_min, 2);
        assert_eq!(t.checkpoint_every_steps, 5);
        assert_eq!(t.model_bytes, 1_000_000);
    }

    #[test]
    fn search_stanza_validation() {
        let with = |stanza: &str| {
            YAML.replace(
                "    depends_on: [prep]",
                &format!("    depends_on: [prep]\n    search: {stanza}"),
            )
        };
        // required max_steps
        assert!(Recipe::from_yaml(&with("{ algo: asha }")).is_err());
        // unknown algo
        assert!(Recipe::from_yaml(&with("{ algo: annealing, max_steps: 10 }")).is_err());
        // eta < 2
        assert!(Recipe::from_yaml(&with("{ max_steps: 10, eta: 1 }")).is_err());
        // max_steps below the first rung
        assert!(Recipe::from_yaml(&with("{ max_steps: 2, rung_steps: 4 }")).is_err());
        // zero rung
        assert!(Recipe::from_yaml(&with("{ max_steps: 10, rung_steps: 0 }")).is_err());
        // explicit full form parses
        let r = Recipe::from_yaml(&with(
            "{ algo: median, max_steps: 27, rung_steps: 3, eta: 4, step_time_s: 0.5, \
             checkpoint_every_steps: 9 }",
        ))
        .unwrap();
        let s = r.experiment("train").unwrap().search.clone().unwrap();
        assert_eq!(s.algo, SearchAlgo::Median);
        assert_eq!(s.eta, 4);
        assert_eq!(s.checkpoint_every_steps, 9);
    }
}
