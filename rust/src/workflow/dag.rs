//! The workflow DAG: experiments as nodes, dependencies as edges.

use std::collections::{BTreeMap, BTreeSet};


use crate::{Error, Result};

use super::params::sample_assignments;
use super::recipe::Recipe;
use super::task::{Task, TaskId};

/// Experiment progress within a workflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExperimentState {
    /// Waiting on dependencies.
    Blocked,
    /// Dependencies satisfied; tasks may run.
    Runnable,
    /// Every task succeeded.
    Complete,
    /// At least one task permanently failed.
    Failed,
}

/// A compiled workflow: topological order, per-experiment tasks.
#[derive(Debug, Clone)]
pub struct Workflow {
    pub name: String,
    pub recipe: Recipe,
    /// experiments[i] corresponds to recipe.experiments[i]
    pub states: Vec<ExperimentState>,
    pub tasks: Vec<Vec<Task>>,
    /// adjacency: deps[i] = indices of experiments i depends on
    deps: Vec<Vec<usize>>,
    topo: Vec<usize>,
}

impl Workflow {
    /// Compile a recipe: sample §II.C assignments for every experiment,
    /// materialize tasks, topologically sort, detect cycles.
    pub fn compile(recipe: Recipe, seed: u64) -> Result<Self> {
        recipe.validate()?;
        let name_to_idx: BTreeMap<&str, usize> = recipe
            .experiments
            .iter()
            .enumerate()
            .map(|(i, e)| (e.name.as_str(), i))
            .collect();
        let deps: Vec<Vec<usize>> = recipe
            .experiments
            .iter()
            .map(|e| e.depends_on.iter().map(|d| name_to_idx[d.as_str()]).collect())
            .collect();
        let topo = topo_sort(&deps)
            .ok_or_else(|| Error::Workflow("dependency cycle in recipe".into()))?;

        let tasks: Vec<Vec<Task>> = recipe
            .experiments
            .iter()
            .enumerate()
            .map(|(ei, spec)| {
                let assignments =
                    sample_assignments(&spec.params, spec.samples, seed ^ (ei as u64) << 17);
                assignments
                    .into_iter()
                    .enumerate()
                    .map(|(ti, a)| Task::materialize(ei as u32, ti as u32, spec, a))
                    .collect()
            })
            .collect();

        let states = deps
            .iter()
            .map(|d| if d.is_empty() { ExperimentState::Runnable } else { ExperimentState::Blocked })
            .collect();

        Ok(Self { name: recipe.name.clone(), recipe, states, tasks, deps, topo })
    }

    pub fn n_experiments(&self) -> usize {
        self.recipe.experiments.len()
    }

    pub fn total_tasks(&self) -> usize {
        self.tasks.iter().map(Vec::len).sum()
    }

    /// Topological order of experiment indices.
    pub fn topo_order(&self) -> &[usize] {
        &self.topo
    }

    /// Experiments currently runnable.
    pub fn runnable(&self) -> Vec<usize> {
        (0..self.states.len())
            .filter(|&i| self.states[i] == ExperimentState::Runnable)
            .collect()
    }

    /// Mark an experiment complete and unblock dependents whose deps are
    /// all complete. Returns newly-runnable experiment indices.
    pub fn mark_complete(&mut self, exp: usize) -> Vec<usize> {
        self.states[exp] = ExperimentState::Complete;
        let mut newly = Vec::new();
        for i in 0..self.states.len() {
            if self.states[i] == ExperimentState::Blocked
                && self.deps[i].iter().all(|&d| self.states[d] == ExperimentState::Complete)
            {
                self.states[i] = ExperimentState::Runnable;
                newly.push(i);
            }
        }
        newly
    }

    /// Mark an experiment failed; dependents transitively fail too
    /// (their tasks never become runnable).
    pub fn mark_failed(&mut self, exp: usize) -> Vec<usize> {
        let mut failed = vec![exp];
        self.states[exp] = ExperimentState::Failed;
        // transitive closure over dependents
        loop {
            let mut changed = false;
            for i in 0..self.states.len() {
                if self.states[i] != ExperimentState::Failed
                    && self.deps[i].iter().any(|&d| self.states[d] == ExperimentState::Failed)
                {
                    self.states[i] = ExperimentState::Failed;
                    failed.push(i);
                    changed = true;
                }
            }
            if !changed {
                break;
            }
        }
        failed
    }

    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id.experiment as usize][id.index as usize]
    }

    pub fn task_mut(&mut self, id: TaskId) -> &mut Task {
        &mut self.tasks[id.experiment as usize][id.index as usize]
    }

    /// True when every experiment is complete.
    pub fn is_complete(&self) -> bool {
        self.states.iter().all(|s| *s == ExperimentState::Complete)
    }
}

/// Kahn's algorithm; None if cyclic.
fn topo_sort(deps: &[Vec<usize>]) -> Option<Vec<usize>> {
    let n = deps.len();
    let mut indegree = vec![0usize; n];
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, ds) in deps.iter().enumerate() {
        indegree[i] = ds.len();
        for &d in ds {
            dependents[d].push(i);
        }
    }
    let mut queue: BTreeSet<usize> =
        (0..n).filter(|&i| indegree[i] == 0).collect();
    let mut out = Vec::with_capacity(n);
    while let Some(&i) = queue.iter().next() {
        queue.remove(&i);
        out.push(i);
        for &j in &dependents[i] {
            indegree[j] -= 1;
            if indegree[j] == 0 {
                queue.insert(j);
            }
        }
    }
    (out.len() == n).then_some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recipe(yaml: &str) -> Recipe {
        Recipe::from_yaml(yaml).unwrap()
    }

    const CHAIN: &str = r#"
name: chain
experiments:
  - name: a
    instance: m5.xlarge
    command: "a --i {i}"
    params: { i: { range: [0, 3] } }
  - name: b
    instance: m5.xlarge
    command: "b"
    depends_on: [a]
  - name: c
    instance: m5.xlarge
    command: "c"
    depends_on: [b]
"#;

    #[test]
    fn compile_chain() {
        let wf = Workflow::compile(recipe(CHAIN), 0).unwrap();
        assert_eq!(wf.n_experiments(), 3);
        assert_eq!(wf.tasks[0].len(), 4); // grid over i
        assert_eq!(wf.tasks[1].len(), 1);
        assert_eq!(wf.total_tasks(), 6);
        assert_eq!(wf.topo_order(), &[0, 1, 2]);
        assert_eq!(wf.runnable(), vec![0]);
    }

    #[test]
    fn unblocking_cascade() {
        let mut wf = Workflow::compile(recipe(CHAIN), 0).unwrap();
        assert_eq!(wf.mark_complete(0), vec![1]);
        assert_eq!(wf.mark_complete(1), vec![2]);
        assert_eq!(wf.mark_complete(2), Vec::<usize>::new());
        assert!(wf.is_complete());
    }

    #[test]
    fn failure_propagates_to_dependents() {
        let mut wf = Workflow::compile(recipe(CHAIN), 0).unwrap();
        let failed = wf.mark_failed(0);
        assert_eq!(failed.len(), 3, "a's failure dooms b and c");
        assert!(!wf.is_complete());
    }

    #[test]
    fn diamond_topology() {
        let yaml = r#"
name: diamond
experiments:
  - name: src
    instance: m5.xlarge
    command: "s"
  - name: left
    instance: m5.xlarge
    command: "l"
    depends_on: [src]
  - name: right
    instance: m5.xlarge
    command: "r"
    depends_on: [src]
  - name: sink
    instance: m5.xlarge
    command: "k"
    depends_on: [left, right]
"#;
        let mut wf = Workflow::compile(recipe(yaml), 0).unwrap();
        wf.mark_complete(0);
        wf.mark_complete(1);
        assert_eq!(wf.runnable(), vec![2], "sink still blocked on right");
        assert_eq!(wf.mark_complete(2), vec![3]);
    }

    #[test]
    fn cycle_detected() {
        // construct a cyclic recipe by hand (validate() only checks names)
        let mut r = recipe(CHAIN);
        r.experiments[0].depends_on = vec!["c".into()];
        assert!(Workflow::compile(r, 0).is_err());
    }

    #[test]
    fn sampling_is_seeded() {
        let w1 = Workflow::compile(recipe(CHAIN), 7).unwrap();
        let w2 = Workflow::compile(recipe(CHAIN), 7).unwrap();
        for (a, b) in w1.tasks[0].iter().zip(&w2.tasks[0]) {
            assert_eq!(a.command, b.command);
        }
    }
}
