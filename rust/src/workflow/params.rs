//! §II.C parameter sampling.
//!
//! "To compute parameters for each Task, the algorithm generates the
//! Cartesian product of all discrete parameters and samples from the set
//! n times with minimal repetition. Then, it samples n times from each
//! continuous parameter range and randomly matches with discrete sampled
//! parameters."

use std::collections::BTreeMap;

use crate::sim::SimRng;
use crate::util::Json;
use crate::{Error, Result};

/// A parameter's sampling space, as written in the recipe.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamSpec {
    /// Discrete class: explicit values.
    Choice(Vec<ParamValue>),
    /// Discrete integer range `[lo, hi]` inclusive.
    Range([i64; 2]),
    /// Continuous uniform `[lo, hi)`.
    Uniform([f64; 2]),
    /// Continuous log-uniform `[lo, hi)`, lo > 0.
    LogUniform([f64; 2]),
}

/// A concrete sampled value.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamValue {
    Int(i64),
    Float(f64),
    Str(String),
}

impl std::fmt::Display for ParamValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParamValue::Int(i) => write!(f, "{i}"),
            ParamValue::Float(x) => write!(f, "{x}"),
            ParamValue::Str(s) => write!(f, "{s}"),
        }
    }
}

/// One task's parameter binding.
pub type Assignment = BTreeMap<String, ParamValue>;

impl ParamValue {
    /// From a recipe scalar.
    pub fn from_json(v: &Json) -> Result<ParamValue> {
        match v {
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 9.0e15 => {
                Ok(ParamValue::Int(*x as i64))
            }
            Json::Num(x) => Ok(ParamValue::Float(*x)),
            Json::Str(s) => Ok(ParamValue::Str(s.clone())),
            Json::Bool(b) => Ok(ParamValue::Int(*b as i64)),
            other => Err(Error::Recipe(format!("invalid parameter value {other:?}"))),
        }
    }
}

impl ParamSpec {
    /// Parse a recipe param spec: `{ choice: [...] } | { range: [lo, hi] } |
    /// { uniform: [lo, hi] } | { log_uniform: [lo, hi] }`.
    pub fn from_json(v: &Json) -> Result<ParamSpec> {
        let obj = v
            .as_obj()
            .ok_or_else(|| Error::Recipe(format!("param spec must be a map, got {v:?}")))?;
        if obj.len() != 1 {
            return Err(Error::Recipe(format!("param spec needs exactly one kind: {v:?}")));
        }
        let (kind, body) = obj.iter().next().expect("len 1");
        let arr = body
            .as_arr()
            .ok_or_else(|| Error::Recipe(format!("param {kind:?} body must be a list")))?;
        let pair = |what: &str| -> Result<[f64; 2]> {
            if arr.len() != 2 {
                return Err(Error::Recipe(format!("{what} needs [lo, hi]")));
            }
            let lo = arr[0].as_f64().ok_or_else(|| Error::Recipe(format!("{what} lo")))?;
            let hi = arr[1].as_f64().ok_or_else(|| Error::Recipe(format!("{what} hi")))?;
            if lo >= hi {
                return Err(Error::Recipe(format!("{what}: lo must be < hi")));
            }
            Ok([lo, hi])
        };
        match kind.as_str() {
            "choice" => {
                if arr.is_empty() {
                    return Err(Error::Recipe("choice must be non-empty".into()));
                }
                Ok(ParamSpec::Choice(
                    arr.iter().map(ParamValue::from_json).collect::<Result<_>>()?,
                ))
            }
            "range" => {
                // inclusive integer range: [0, 0] (a single value) is legal
                if arr.len() != 2 {
                    return Err(Error::Recipe("range needs [lo, hi]".into()));
                }
                let lo = arr[0].as_i64().ok_or_else(|| Error::Recipe("range lo".into()))?;
                let hi = arr[1].as_i64().ok_or_else(|| Error::Recipe("range hi".into()))?;
                if lo > hi {
                    return Err(Error::Recipe("range: lo must be <= hi".into()));
                }
                Ok(ParamSpec::Range([lo, hi]))
            }
            "uniform" => Ok(ParamSpec::Uniform(pair("uniform")?)),
            "log_uniform" => {
                let [lo, hi] = pair("log_uniform")?;
                if lo <= 0.0 {
                    return Err(Error::Recipe("log_uniform lo must be > 0".into()));
                }
                Ok(ParamSpec::LogUniform([lo, hi]))
            }
            other => Err(Error::Recipe(format!("unknown param kind {other:?}"))),
        }
    }
}

impl ParamSpec {
    /// Discrete cardinality (None for continuous).
    pub fn cardinality(&self) -> Option<usize> {
        match self {
            ParamSpec::Choice(vs) => Some(vs.len()),
            ParamSpec::Range([lo, hi]) => Some((hi - lo + 1).max(0) as usize),
            _ => None,
        }
    }

    fn discrete_value(&self, idx: usize) -> ParamValue {
        match self {
            ParamSpec::Choice(vs) => vs[idx].clone(),
            ParamSpec::Range([lo, _]) => ParamValue::Int(lo + idx as i64),
            _ => unreachable!("discrete_value on continuous spec"),
        }
    }

    fn sample_continuous(&self, rng: &mut SimRng) -> ParamValue {
        match self {
            ParamSpec::Uniform([lo, hi]) => ParamValue::Float(rng.gen_range_f64(*lo, *hi)),
            ParamSpec::LogUniform([lo, hi]) => {
                let x = rng.gen_range_f64(lo.ln(), hi.ln());
                ParamValue::Float(x.exp())
            }
            _ => unreachable!("sample_continuous on discrete spec"),
        }
    }
}

/// The §II.C algorithm. Returns `n` assignments; if `n` is `None` it
/// defaults to the full discrete Cartesian size (grid iteration), or 1 if
/// every parameter is continuous.
pub fn sample_assignments(
    params: &BTreeMap<String, ParamSpec>,
    n: Option<usize>,
    seed: u64,
) -> Vec<Assignment> {
    let mut rng = SimRng::new(seed ^ 0x9A9A_0CE1);
    let discrete: Vec<(&String, &ParamSpec)> =
        params.iter().filter(|(_, s)| s.cardinality().is_some()).collect();
    let continuous: Vec<(&String, &ParamSpec)> =
        params.iter().filter(|(_, s)| s.cardinality().is_none()).collect();

    let cart: usize = discrete
        .iter()
        .map(|(_, s)| s.cardinality().expect("discrete"))
        .product::<usize>()
        .max(1);
    let n = n.unwrap_or(if discrete.is_empty() { 1 } else { cart }).max(1);

    // --- minimal-repetition sampling of the Cartesian product ---------
    // every combo appears floor(n/cart) times, plus a without-replacement
    // sample of the remainder.
    let mut combo_ids: Vec<usize> = Vec::with_capacity(n);
    let full_rounds = n / cart;
    for _ in 0..full_rounds {
        combo_ids.extend(0..cart);
    }
    let rem = n - full_rounds * cart;
    if rem > 0 {
        let mut pool: Vec<usize> = (0..cart).collect();
        rng.shuffle(&mut pool);
        combo_ids.extend(pool.into_iter().take(rem));
    }
    rng.shuffle(&mut combo_ids);

    // --- continuous samples, randomly matched -------------------------
    let mut cont_samples: Vec<Vec<ParamValue>> = continuous
        .iter()
        .map(|(_, s)| (0..n).map(|_| s.sample_continuous(&mut rng)).collect())
        .collect();
    for col in cont_samples.iter_mut() {
        rng.shuffle(col);
    }

    combo_ids
        .into_iter()
        .enumerate()
        .map(|(row, mut combo)| {
            let mut a = Assignment::new();
            for (name, spec) in &discrete {
                let card = spec.cardinality().expect("discrete");
                a.insert((*name).clone(), spec.discrete_value(combo % card));
                combo /= card;
            }
            for (ci, (name, _)) in continuous.iter().enumerate() {
                a.insert((*name).clone(), cont_samples[ci][row].clone());
            }
            a
        })
        .collect()
}

/// Render a `{param}` template with an assignment.
pub fn render_command(template: &str, a: &Assignment) -> String {
    let mut out = template.to_string();
    for (k, v) in a {
        out = out.replace(&format!("{{{k}}}"), &v.to_string());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(pairs: Vec<(&str, ParamSpec)>) -> BTreeMap<String, ParamSpec> {
        pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
    }

    #[test]
    fn grid_default_covers_cartesian() {
        let p = spec(vec![
            ("a", ParamSpec::Choice(vec![ParamValue::Int(1), ParamValue::Int(2)])),
            ("b", ParamSpec::Range([0, 2])),
        ]);
        let out = sample_assignments(&p, None, 0);
        assert_eq!(out.len(), 6);
        let mut unique: Vec<String> = out.iter().map(|a| format!("{a:?}")).collect();
        unique.sort();
        unique.dedup();
        assert_eq!(unique.len(), 6, "grid must enumerate every combo once");
    }

    #[test]
    fn minimal_repetition_under_sampling() {
        let p = spec(vec![("a", ParamSpec::Range([0, 9]))]); // card 10
        let out = sample_assignments(&p, Some(25), 1);
        assert_eq!(out.len(), 25);
        // each of the 10 values must appear 2 or 3 times (25 = 2*10 + 5)
        let mut counts = BTreeMap::new();
        for a in &out {
            *counts.entry(format!("{:?}", a["a"])).or_insert(0) += 1;
        }
        assert!(counts.values().all(|&c| c == 2 || c == 3), "{counts:?}");
    }

    #[test]
    fn without_replacement_when_n_below_cartesian() {
        let p = spec(vec![("a", ParamSpec::Range([0, 99]))]);
        let out = sample_assignments(&p, Some(50), 2);
        let mut seen: Vec<String> = out.iter().map(|a| format!("{:?}", a["a"])).collect();
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 50, "no repeats while n <= cardinality");
    }

    #[test]
    fn continuous_within_bounds_and_matched() {
        let p = spec(vec![
            ("lr", ParamSpec::LogUniform([1e-4, 1e-1])),
            ("mom", ParamSpec::Uniform([0.5, 0.99])),
            ("bs", ParamSpec::Choice(vec![ParamValue::Int(32), ParamValue::Int(64)])),
        ]);
        let out = sample_assignments(&p, Some(40), 3);
        assert_eq!(out.len(), 40);
        for a in &out {
            let ParamValue::Float(lr) = a["lr"] else { panic!("lr type") };
            let ParamValue::Float(mom) = a["mom"] else { panic!("mom type") };
            assert!((1e-4..1e-1).contains(&lr));
            assert!((0.5..0.99).contains(&mom));
        }
        // discrete part still balanced: 20 each
        let c32 = out.iter().filter(|a| a["bs"] == ParamValue::Int(32)).count();
        assert_eq!(c32, 20);
    }

    #[test]
    fn deterministic_per_seed() {
        let p = spec(vec![("x", ParamSpec::Uniform([0.0, 1.0]))]);
        assert_eq!(sample_assignments(&p, Some(5), 9), sample_assignments(&p, Some(5), 9));
        assert_ne!(sample_assignments(&p, Some(5), 9), sample_assignments(&p, Some(5), 10));
    }

    #[test]
    fn same_seed_same_assignments_on_mixed_space() {
        // seeded determinism must hold across discrete + continuous
        // together (trial resumes after preemption depend on it: the same
        // seed must regenerate the exact same trial set)
        let p = spec(vec![
            ("bs", ParamSpec::Choice(vec![ParamValue::Int(32), ParamValue::Int(64)])),
            ("depth", ParamSpec::Range([2, 5])),
            ("lr", ParamSpec::LogUniform([1e-4, 1e-1])),
            ("mom", ParamSpec::Uniform([0.5, 0.99])),
        ]);
        for n in [None, Some(3), Some(17), Some(40)] {
            assert_eq!(sample_assignments(&p, n, 21), sample_assignments(&p, n, 21));
        }
        assert_ne!(sample_assignments(&p, Some(17), 21), sample_assignments(&p, Some(17), 22));
    }

    #[test]
    fn no_duplicate_discrete_tuples_until_cartesian_exhausted() {
        // card = 4 * 5 = 20; sampling n < 20 must yield n distinct
        // (a, b) tuples even with continuous params mixed in
        let p = spec(vec![
            ("a", ParamSpec::Range([0, 3])),
            ("b", ParamSpec::Range([10, 14])),
            ("lr", ParamSpec::Uniform([0.0, 1.0])),
        ]);
        for n in [1usize, 7, 13, 19, 20] {
            let out = sample_assignments(&p, Some(n), 4);
            assert_eq!(out.len(), n);
            let mut tuples: Vec<(ParamValue, ParamValue)> =
                out.iter().map(|x| (x["a"].clone(), x["b"].clone())).collect();
            tuples.sort_by(|x, y| format!("{x:?}").cmp(&format!("{y:?}")));
            tuples.dedup();
            assert_eq!(tuples.len(), n, "discrete tuples repeated before the grid was spent");
        }
    }

    #[test]
    fn all_continuous_defaults_to_one() {
        let p = spec(vec![("x", ParamSpec::Uniform([0.0, 1.0]))]);
        assert_eq!(sample_assignments(&p, None, 0).len(), 1);
    }

    #[test]
    fn render_command_substitutes() {
        let mut a = Assignment::new();
        a.insert("lr".into(), ParamValue::Float(0.01));
        a.insert("tag".into(), ParamValue::Str("v1".into()));
        let cmd = render_command("train --lr {lr} --tag {tag} --keep {other}", &a);
        assert_eq!(cmd, "train --lr 0.01 --tag v1 --keep {other}");
    }

    #[test]
    fn paper_hyperparam_scale() {
        // §IV.C: 12 binary parameters -> 4096 combinations
        let p: BTreeMap<String, ParamSpec> = (0..12)
            .map(|i| (format!("p{i:02}"), ParamSpec::Range([0, 1])))
            .collect();
        let out = sample_assignments(&p, None, 0);
        assert_eq!(out.len(), 4096);
    }
}
