//! [`ServeStack`]: the real-time serving pipeline — admission queue,
//! dynamic batcher, replica worker pool.
//!
//! One [`BoundedQueue`] feeds `workers` replica threads, each owning a
//! [`BatchBackend`]. A worker collects a batch (size- or deadline-closed),
//! runs it, and answers each request through its response channel. The
//! whole stack is synchronous building blocks — no async runtime exists in
//! this image — which keeps the hot path at one lock + one condvar wait
//! per batch.
//!
//! Elastic capacity is *not* handled here: real replica churn (provision,
//! preempt, requeue) is the virtual-time [`super::ServeSim`]'s domain,
//! where it can be driven deterministically. The threaded stack serves a
//! fixed worker pool as fast as the host allows — the `serve_batching`
//! bench and the `hyper serve` CLI demo sit on it.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::metrics::{Counter, Gauge, Histogram, MetricsRegistry};
use crate::{Error, Result};

use super::backend::BatchBackend;
use super::batcher::{AdaptiveBatchConfig, BatchController, BatchPolicy};
use super::queue::{Admit, BoundedQueue, Priority};
use crate::obs::FlightRecorder;

/// Configuration of a threaded serving stack.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Admission limit: requests waiting beyond this are shed.
    pub queue_depth: usize,
    /// Batch close: size limit.
    pub max_batch: usize,
    /// Batch close: deadline from batch open.
    pub max_batch_delay: Duration,
    /// Replica worker threads.
    pub workers: usize,
    /// Adaptive close-window controller: a background thread retunes
    /// `max_batch` / `max_batch_delay` (within the config's bounds) from
    /// the windowed p99, exactly like the virtual-time sim's controller.
    /// `None` keeps the policy fixed.
    pub adaptive: Option<AdaptiveBatchConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            queue_depth: 1024,
            max_batch: 16,
            max_batch_delay: Duration::from_millis(5),
            workers: 2,
            adaptive: None,
        }
    }
}

/// Observable serving counters (all cheap to clone; shared with workers).
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Requests accepted past admission control.
    pub admitted: Counter,
    /// Requests rejected at the door (queue at capacity) or displaced
    /// from the queue by a higher class.
    pub shed: Counter,
    /// Requests answered successfully.
    pub completed: Counter,
    /// Requests whose batch errored.
    pub failed: Counter,
    /// Batches dispatched to backends.
    pub batches: Counter,
    /// Per-class admitted counters, indexed like [`Priority::ALL`].
    pub admitted_class: [Counter; Priority::COUNT],
    /// Per-class shed counters (door sheds and displacements), indexed
    /// like [`Priority::ALL`].
    pub shed_class: [Counter; Priority::COUNT],
    /// Requests per closed batch.
    pub batch_fill: Histogram,
    /// Seconds from admission to batch close.
    pub queue_wait_s: Histogram,
    /// Seconds from admission to response.
    pub latency_s: Histogram,
    /// Windowed admission-to-response latency: the adaptive controller
    /// snapshots and resets this every tick. Mirrors `latency_s`.
    pub window_latency_s: Histogram,
    /// Requests waiting at the last observation.
    pub queue_depth: Gauge,
}

impl ServeStats {
    /// Register every counter/gauge/histogram under `serve.*` names so
    /// `MetricsRegistry::report()` and the Prometheus exposition carry
    /// the live serving state (per-class counters included:
    /// `serve.admitted.paid`, `serve.shed.batch`, ...).
    pub fn register_metrics(&self, reg: &MetricsRegistry) {
        reg.register_counter("serve.admitted", self.admitted.clone());
        reg.register_counter("serve.shed", self.shed.clone());
        reg.register_counter("serve.completed", self.completed.clone());
        reg.register_counter("serve.failed", self.failed.clone());
        reg.register_counter("serve.batches", self.batches.clone());
        for p in Priority::ALL {
            reg.register_counter(
                &format!("serve.admitted.{}", p.name()),
                self.admitted_class[p.index()].clone(),
            );
            reg.register_counter(
                &format!("serve.shed.{}", p.name()),
                self.shed_class[p.index()].clone(),
            );
        }
        reg.register_histogram("serve.batch_fill", self.batch_fill.clone());
        reg.register_histogram("serve.queue_wait_s", self.queue_wait_s.clone());
        reg.register_histogram("serve.latency_s", self.latency_s.clone());
        reg.register_gauge("serve.queue_depth", self.queue_depth.clone());
    }
}

struct Pending {
    tokens: Vec<i32>,
    admitted_at: Instant,
    class: Priority,
    resp: mpsc::Sender<Result<i32>>,
}

/// The live batching policy, shared lock-free between the workers and
/// the adaptive controller thread.
struct SharedPolicy {
    max_batch: AtomicUsize,
    delay_ns: AtomicU64,
}

impl SharedPolicy {
    fn new(p: BatchPolicy) -> Self {
        Self {
            max_batch: AtomicUsize::new(p.max_batch.max(1)),
            delay_ns: AtomicU64::new((p.max_delay_s.max(0.0) * 1e9) as u64),
        }
    }

    fn store(&self, p: BatchPolicy) {
        self.max_batch.store(p.max_batch.max(1), Ordering::Relaxed);
        self.delay_ns.store((p.max_delay_s.max(0.0) * 1e9) as u64, Ordering::Relaxed);
    }

    fn load(&self) -> (usize, Duration) {
        (
            self.max_batch.load(Ordering::Relaxed),
            Duration::from_nanos(self.delay_ns.load(Ordering::Relaxed)),
        )
    }
}

/// Handle to one submitted request; blocks on [`ResponseHandle::wait`].
pub struct ResponseHandle {
    rx: mpsc::Receiver<Result<i32>>,
}

impl ResponseHandle {
    /// Block until the replica answers (or the stack shuts down).
    pub fn wait(self) -> Result<i32> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(Error::Serve("server shut down before reply".into())))
    }
}

/// The running stack: submit requests, read stats, shut down.
pub struct ServeStack {
    queue: Arc<BoundedQueue<Pending>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    policy: Arc<SharedPolicy>,
    ctrl_stop: Arc<AtomicBool>,
    ctrl: Option<std::thread::JoinHandle<()>>,
    /// Live serving counters (shared with the worker threads).
    pub stats: ServeStats,
}

impl ServeStack {
    /// Start `cfg.workers` replica threads; `make_backend(i)` builds the
    /// i-th worker's model replica.
    pub fn start<F>(cfg: ServerConfig, make_backend: F) -> Self
    where
        F: Fn(usize) -> Box<dyn BatchBackend>,
    {
        Self::start_with_obs(cfg, make_backend, FlightRecorder::disabled())
    }

    /// [`ServeStack::start`] with a flight recorder attached: each worker
    /// records a `serve.batch` assembly event (fill, close reason, oldest
    /// queue wait) and a `serve.batch_execute` span around the backend
    /// call, on its own pid track (replica `i` → pid `i + 1`).
    pub fn start_with_obs<F>(cfg: ServerConfig, make_backend: F, obs: FlightRecorder) -> Self
    where
        F: Fn(usize) -> Box<dyn BatchBackend>,
    {
        let queue = Arc::new(BoundedQueue::new(cfg.queue_depth.max(1)));
        let stats = ServeStats::default();
        let initial = BatchPolicy {
            max_batch: cfg.max_batch,
            max_delay_s: cfg.max_batch_delay.as_secs_f64(),
        };
        // the controller clamps the starting policy into its bounds, so
        // workers and controller agree from the first batch
        let ctrl_state = cfg.adaptive.clone().map(|a| BatchController::new(a, initial));
        let policy = Arc::new(SharedPolicy::new(
            ctrl_state.as_ref().map_or(initial, |c| c.policy()),
        ));
        let ctrl_stop = Arc::new(AtomicBool::new(false));
        let ctrl = ctrl_state.map(|mut c| {
            let window = stats.window_latency_s.clone();
            let policy = policy.clone();
            let stop = ctrl_stop.clone();
            let obs = obs.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    // sleep the tick in short slices so shutdown stays fast
                    let mut left = c.config().tick_s.max(0.001);
                    while left > 0.0 && !stop.load(Ordering::Relaxed) {
                        let slice = left.min(0.02);
                        std::thread::sleep(Duration::from_secs_f64(slice));
                        left -= slice;
                    }
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let snap = window.snapshot_and_reset();
                    if c.observe(snap.p99, snap.count) {
                        let p = c.policy();
                        policy.store(p);
                        if obs.is_enabled() {
                            obs.event("serve.batch_adapt", 0, 0, vec![
                                ("max_batch", p.max_batch.into()),
                                ("max_delay_s", p.max_delay_s.into()),
                                ("window_p99_s", snap.p99.into()),
                            ]);
                        }
                    }
                }
            })
        });
        let mut workers = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers.max(1) {
            let mut backend = make_backend(i);
            let queue = queue.clone();
            let stats = stats.clone();
            let obs = obs.clone();
            let policy = policy.clone();
            let pid = (i + 1) as u32;
            let backend_max = backend.max_batch().max(1);
            workers.push(std::thread::spawn(move || {
                loop {
                    let (mb, delay) = policy.load();
                    let max_batch = mb.min(backend_max).max(1);
                    let Some(batch) = queue.next_batch(max_batch, delay) else { break };
                    if batch.is_empty() {
                        continue;
                    }
                    let closed_at = Instant::now();
                    stats.queue_depth.set(queue.len() as i64);
                    stats.batches.inc();
                    stats.batch_fill.record(batch.len() as f64);
                    let mut oldest_wait_s: f64 = 0.0;
                    for p in &batch {
                        let wait = closed_at.duration_since(p.admitted_at).as_secs_f64();
                        oldest_wait_s = oldest_wait_s.max(wait);
                        stats.queue_wait_s.record(wait);
                    }
                    if obs.is_enabled() {
                        obs.event("serve.batch", pid, 0, vec![
                            ("fill", batch.len().into()),
                            (
                                "close",
                                if batch.len() >= max_batch { "size" } else { "deadline" }
                                    .into(),
                            ),
                            ("oldest_wait_s", oldest_wait_s.into()),
                        ]);
                    }
                    let rows: Vec<&[i32]> =
                        batch.iter().map(|p| p.tokens.as_slice()).collect();
                    let outcome = {
                        let _exec = obs.is_enabled().then(|| {
                            obs.span("serve.batch_execute", pid, 0, vec![
                                ("fill", batch.len().into()),
                            ])
                        });
                        backend.infer(&rows)
                    };
                    match outcome {
                        Ok(outs) => {
                            let done = Instant::now();
                            for (p, out) in batch.into_iter().zip(outs) {
                                stats.completed.inc();
                                let lat = done.duration_since(p.admitted_at).as_secs_f64();
                                stats.latency_s.record(lat);
                                stats.window_latency_s.record(lat);
                                let _ = p.resp.send(Ok(out));
                            }
                        }
                        Err(e) => {
                            // fail the whole batch; the error is not Clone,
                            // so each rider gets the rendered message
                            let msg = e.to_string();
                            for p in batch {
                                stats.failed.inc();
                                let _ = p.resp.send(Err(Error::Serve(msg.clone())));
                            }
                        }
                    }
                }
            }));
        }
        Self { queue, workers, policy, ctrl_stop, ctrl, stats }
    }

    /// Submit one request at the top ([`Priority::Paid`]) class. Returns
    /// [`Error::Shed`] immediately when the queue is at its admission
    /// limit and holds no lower-class waiter to displace.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<ResponseHandle> {
        self.submit_class(tokens, Priority::Paid)
    }

    /// Submit one request at an explicit priority class. A full queue
    /// sheds the youngest waiter of the lowest class below `class` to
    /// make room (the displaced waiter's handle resolves to
    /// [`Error::Shed`]); with nothing below to displace, the submission
    /// itself is shed.
    pub fn submit_class(&self, tokens: Vec<i32>, class: Priority) -> Result<ResponseHandle> {
        let (tx, rx) = mpsc::channel();
        let pending = Pending { tokens, admitted_at: Instant::now(), class, resp: tx };
        match self.queue.offer_at(pending, class) {
            Ok(admit) => {
                if let Admit::Displaced(victim) = admit {
                    self.stats.shed.inc();
                    self.stats.shed_class[victim.class.index()].inc();
                    let _ = victim.resp.send(Err(Error::Shed));
                }
                self.stats.admitted.inc();
                self.stats.admitted_class[class.index()].inc();
                self.stats.queue_depth.set(self.queue.len() as i64);
                Ok(ResponseHandle { rx })
            }
            Err(_) => {
                self.stats.shed.inc();
                self.stats.shed_class[class.index()].inc();
                Err(Error::Shed)
            }
        }
    }

    /// Requests accepted so far (admitted only).
    pub fn submitted(&self) -> u64 {
        self.stats.admitted.get()
    }

    /// The batching policy currently in force (moves over time when the
    /// adaptive controller is configured).
    pub fn batch_policy(&self) -> BatchPolicy {
        let (max_batch, delay) = self.policy.load();
        BatchPolicy { max_batch, max_delay_s: delay.as_secs_f64() }
    }

    /// Drain and stop: in-queue requests are still served, then workers
    /// (and the adaptive controller, if any) exit and are joined.
    pub fn shutdown(self) {
        self.ctrl_stop.store(true, Ordering::Relaxed);
        if let Some(c) = self.ctrl {
            let _ = c.join();
        }
        self.queue.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::{BatchBackend, SyntheticBackend};
    use super::*;

    fn stack(workers: usize, max_batch: usize, depth: usize) -> ServeStack {
        ServeStack::start(
            ServerConfig {
                queue_depth: depth,
                max_batch,
                max_batch_delay: Duration::from_millis(2),
                workers,
                adaptive: None,
            },
            move |_| -> Box<dyn BatchBackend> {
                Box::new(SyntheticBackend::new(0.0, 0.0, max_batch, false))
            },
        )
    }

    #[test]
    fn serves_correct_tokens() {
        let s = stack(2, 8, 64);
        let rows: Vec<Vec<i32>> = (0..20).map(|i| vec![i, i + 1, i + 2]).collect();
        let handles: Vec<_> = rows.iter().map(|r| s.submit(r.clone()).unwrap()).collect();
        for (row, h) in rows.iter().zip(handles) {
            assert_eq!(h.wait().unwrap(), SyntheticBackend::token_for(row));
        }
        assert_eq!(s.stats.completed.get(), 20);
        assert_eq!(s.stats.failed.get(), 0);
        assert!(s.stats.batches.get() >= 3, "20 reqs / batch<=8 needs >=3 batches");
        s.shutdown();
    }

    #[test]
    fn sheds_beyond_queue_depth() {
        // no workers consuming yet: start with a slow backend so the queue
        // actually fills. base 50ms blocks the single worker long enough.
        let s = ServeStack::start(
            ServerConfig {
                queue_depth: 4,
                max_batch: 1,
                max_batch_delay: Duration::from_millis(1),
                workers: 1,
                adaptive: None,
            },
            |_| -> Box<dyn BatchBackend> {
                Box::new(SyntheticBackend::new(0.05, 0.0, 1, true))
            },
        );
        let mut shed = 0;
        let mut handles = Vec::new();
        // worker takes 1 into service; 4 queue slots; the rest shed
        for i in 0..32 {
            match s.submit(vec![i]) {
                Ok(h) => handles.push(h),
                Err(Error::Shed) => shed += 1,
                Err(e) => panic!("unexpected error {e}"),
            }
        }
        assert!(shed > 0, "admission control must engage");
        assert_eq!(s.stats.shed.get(), shed);
        for h in handles {
            h.wait().unwrap(); // everything admitted is served
        }
        assert_eq!(s.stats.completed.get() + s.stats.failed.get(), s.stats.admitted.get());
        s.shutdown();
    }

    #[test]
    fn shutdown_drains_admitted_requests() {
        let s = stack(1, 4, 1024);
        let handles: Vec<_> = (0..50).map(|i| s.submit(vec![i]).unwrap()).collect();
        s.shutdown();
        // all 50 were answered before workers exited
        for h in handles {
            assert!(h.wait().is_ok());
        }
    }

    #[test]
    fn batches_actually_form() {
        let s = stack(1, 16, 1024);
        let handles: Vec<_> = (0..64).map(|i| s.submit(vec![i]).unwrap()).collect();
        for h in handles {
            h.wait().unwrap();
        }
        let fill = s.stats.batch_fill.snapshot();
        assert!(
            fill.max > 1.0,
            "with 64 queued and a single worker, batches must exceed size 1: {fill:?}"
        );
        s.shutdown();
    }

    #[test]
    fn register_metrics_surfaces_per_class_counters() {
        let s = stack(1, 4, 64);
        let reg = MetricsRegistry::new();
        s.stats.register_metrics(&reg);
        s.submit_class(vec![1], Priority::Free).unwrap().wait().unwrap();
        s.submit(vec![2]).unwrap().wait().unwrap();
        let report = reg.report();
        assert!(report.contains("serve.admitted 2\n"), "{report}");
        assert!(report.contains("serve.admitted.free 1\n"), "{report}");
        assert!(report.contains("serve.admitted.paid 1\n"), "{report}");
        assert!(report.contains("serve.shed.batch 0\n"), "{report}");
        assert!(report.contains("serve.latency_s count=2"), "{report}");
        let prom = reg.report_prometheus();
        assert!(prom.contains("# TYPE serve_admitted_free counter\nserve_admitted_free 1\n"));
        assert!(prom.contains("# TYPE serve_shed_paid counter\nserve_shed_paid 0\n"));
        s.shutdown();
    }

    #[test]
    fn paid_submit_displaces_a_best_effort_waiter() {
        // one worker stuck 100 ms per request; fill the 4-slot queue with
        // best-effort work, then submit paid: the youngest best-effort
        // waiter is displaced (its handle resolves Shed) and paid serves.
        let s = ServeStack::start(
            ServerConfig {
                queue_depth: 4,
                max_batch: 1,
                max_batch_delay: Duration::from_millis(1),
                workers: 1,
                adaptive: None,
            },
            |_| -> Box<dyn BatchBackend> {
                Box::new(SyntheticBackend::new(0.1, 0.0, 1, true))
            },
        );
        let mut batch_handles = Vec::new();
        let mut door_shed = 0u64;
        for i in 0..16 {
            match s.submit_class(vec![i], Priority::Batch) {
                Ok(h) => batch_handles.push(h),
                Err(Error::Shed) => door_shed += 1,
                Err(e) => panic!("unexpected error {e}"),
            }
        }
        assert!(door_shed > 0, "the queue must be full before the paid submit");
        let paid = s.submit_class(vec![99], Priority::Paid).expect("paid displaces, never sheds");
        assert_eq!(s.stats.admitted_class[Priority::Paid.index()].get(), 1);
        assert_eq!(
            s.stats.shed_class[Priority::Batch.index()].get(),
            door_shed + 1,
            "exactly one waiter was displaced on top of the door sheds"
        );
        assert_eq!(paid.wait().unwrap(), SyntheticBackend::token_for(&[99]));
        let displaced = batch_handles
            .into_iter()
            .map(|h| h.wait())
            .filter(|r| matches!(r, Err(Error::Shed)))
            .count();
        assert_eq!(displaced, 1, "the displaced waiter's handle resolves to Shed");
        let stats = s.stats.clone();
        s.shutdown();
        assert_eq!(
            stats.completed.get(),
            stats.admitted.get() - 1,
            "everything admitted except the displaced waiter was served"
        );
    }

    #[test]
    fn adaptive_controller_retunes_the_live_policy() {
        // an SLO of 1 µs is unmeetable, so every tick with samples
        // shrinks the window until the policy sits at its floor
        let s = ServeStack::start(
            ServerConfig {
                queue_depth: 1024,
                max_batch: 16,
                max_batch_delay: Duration::from_millis(5),
                workers: 1,
                adaptive: Some(AdaptiveBatchConfig {
                    slo_p99_s: 1e-6,
                    min_delay_s: 0.0005,
                    max_delay_s: 0.005,
                    min_batch: 2,
                    max_batch: 16,
                    tick_s: 0.01,
                    ..Default::default()
                }),
            },
            |_| -> Box<dyn BatchBackend> {
                Box::new(SyntheticBackend::new(0.0, 0.0, 16, false))
            },
        );
        assert_eq!(s.batch_policy().max_batch, 16, "starts at the configured policy");
        let at_floor =
            |p: BatchPolicy| p.max_batch == 2 && (p.max_delay_s - 0.0005).abs() < 1e-9;
        let deadline = Instant::now() + Duration::from_secs(10);
        while !at_floor(s.batch_policy()) && Instant::now() < deadline {
            s.submit(vec![1]).unwrap().wait().unwrap();
        }
        let p = s.batch_policy();
        assert_eq!(p.max_batch, 2, "controller walked the policy to its floor");
        assert!((p.max_delay_s - 0.0005).abs() < 1e-9, "delay at its floor: {}", p.max_delay_s);
        s.shutdown();
    }

    /// Gated behind `HYPER_STRESS=1`: seconds of wallclock, 8 producers
    /// hammering mixed classes through a shedding stack — conservation
    /// must hold exactly (admitted = completed + displaced; offered =
    /// admitted + door sheds).
    #[test]
    fn stress_stack_serves_mixed_classes_without_loss() {
        if std::env::var("HYPER_STRESS").is_err() {
            eprintln!("stress_stack_serves_mixed_classes_without_loss: set HYPER_STRESS=1 to run");
            return;
        }
        let s = Arc::new(ServeStack::start(
            ServerConfig {
                queue_depth: 64,
                max_batch: 8,
                max_batch_delay: Duration::from_millis(1),
                workers: 2,
                adaptive: Some(AdaptiveBatchConfig::default()),
            },
            |_| -> Box<dyn BatchBackend> {
                Box::new(SyntheticBackend::new(0.0002, 0.0, 8, true))
            },
        ));
        let producers = 8u64;
        let per = 5_000u64;
        let door_shed = Arc::new(AtomicU64::new(0));
        let completed = Arc::new(AtomicU64::new(0));
        let displaced = Arc::new(AtomicU64::new(0));
        let threads: Vec<_> = (0..producers)
            .map(|t| {
                let s = s.clone();
                let door_shed = door_shed.clone();
                let completed = completed.clone();
                let displaced = displaced.clone();
                std::thread::spawn(move || {
                    let mut handles = Vec::new();
                    for i in 0..per {
                        let class = Priority::from_index(((t + i) % 3) as usize);
                        match s.submit_class(vec![t as i32, i as i32], class) {
                            Ok(h) => handles.push(h),
                            Err(Error::Shed) => {
                                door_shed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(e) => panic!("unexpected error {e}"),
                        }
                    }
                    for h in handles {
                        match h.wait() {
                            Ok(_) => {
                                completed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(Error::Shed) => {
                                displaced.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(e) => panic!("unexpected response {e}"),
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let (door, done, disp) = (
            door_shed.load(Ordering::Relaxed),
            completed.load(Ordering::Relaxed),
            displaced.load(Ordering::Relaxed),
        );
        assert_eq!(s.stats.admitted.get() + door, producers * per, "every submit accounted");
        assert_eq!(done + disp, s.stats.admitted.get(), "admitted = completed + displaced");
        assert_eq!(s.stats.completed.get(), done);
        assert_eq!(s.stats.shed.get(), door + disp);
        assert_eq!(s.stats.failed.get(), 0);
        assert!(disp > 0, "mixed classes under overload must displace");
        let by_class: u64 = (0..Priority::COUNT)
            .map(|c| s.stats.admitted_class[c].get())
            .sum();
        assert_eq!(by_class, s.stats.admitted.get(), "class counters partition admissions");
        Arc::try_unwrap(s).ok().expect("all clones dropped").shutdown();
    }

    #[test]
    fn workers_record_batch_assembly_and_execute_spans() {
        let rec = FlightRecorder::wallclock(4096);
        let s = ServeStack::start_with_obs(
            ServerConfig {
                queue_depth: 1024,
                max_batch: 8,
                max_batch_delay: Duration::from_millis(2),
                workers: 2,
                adaptive: None,
            },
            |_| -> Box<dyn BatchBackend> {
                Box::new(SyntheticBackend::new(0.0, 0.0, 8, false))
            },
            rec.clone(),
        );
        let handles: Vec<_> = (0..40).map(|i| s.submit(vec![i]).unwrap()).collect();
        for h in handles {
            h.wait().unwrap();
        }
        let batches = s.stats.batches.get();
        s.shutdown();
        let records = rec.snapshot();
        let count = |n: &str| records.iter().filter(|r| r.name == n).count() as u64;
        assert_eq!(count("serve.batch"), batches);
        assert_eq!(count("serve.batch_execute"), batches);
        for r in records.iter().filter(|r| r.name == "serve.batch") {
            assert!(r.pid >= 1 && r.pid <= 2, "replica pids start at 1: {}", r.pid);
            let close = r.arg("close").and_then(|a| a.as_str()).unwrap().to_string();
            assert!(close == "size" || close == "deadline");
            assert!(r.arg("fill").and_then(|a| a.as_u64()).unwrap() >= 1);
            assert!(r.arg("oldest_wait_s").and_then(|a| a.as_f64()).unwrap() >= 0.0);
        }
    }
}
