//! [`ServeStack`]: the real-time serving pipeline — admission queue,
//! dynamic batcher, replica worker pool.
//!
//! One [`BoundedQueue`] feeds `workers` replica threads, each owning a
//! [`BatchBackend`]. A worker collects a batch (size- or deadline-closed),
//! runs it, and answers each request through its response channel. The
//! whole stack is synchronous building blocks — no async runtime exists in
//! this image — which keeps the hot path at one lock + one condvar wait
//! per batch.
//!
//! Elastic capacity is *not* handled here: real replica churn (provision,
//! preempt, requeue) is the virtual-time [`super::ServeSim`]'s domain,
//! where it can be driven deterministically. The threaded stack serves a
//! fixed worker pool as fast as the host allows — the `serve_batching`
//! bench and the `hyper serve` CLI demo sit on it.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::metrics::{Counter, Gauge, Histogram};
use crate::{Error, Result};

use super::backend::BatchBackend;
use super::queue::BoundedQueue;
use crate::obs::FlightRecorder;

/// Configuration of a threaded serving stack.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Admission limit: requests waiting beyond this are shed.
    pub queue_depth: usize,
    /// Batch close: size limit.
    pub max_batch: usize,
    /// Batch close: deadline from batch open.
    pub max_batch_delay: Duration,
    /// Replica worker threads.
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            queue_depth: 1024,
            max_batch: 16,
            max_batch_delay: Duration::from_millis(5),
            workers: 2,
        }
    }
}

/// Observable serving counters (all cheap to clone; shared with workers).
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Requests accepted past admission control.
    pub admitted: Counter,
    /// Requests rejected at the door (queue at capacity).
    pub shed: Counter,
    /// Requests answered successfully.
    pub completed: Counter,
    /// Requests whose batch errored.
    pub failed: Counter,
    /// Batches dispatched to backends.
    pub batches: Counter,
    /// Requests per closed batch.
    pub batch_fill: Histogram,
    /// Seconds from admission to batch close.
    pub queue_wait_s: Histogram,
    /// Seconds from admission to response.
    pub latency_s: Histogram,
    /// Requests waiting at the last observation.
    pub queue_depth: Gauge,
}

struct Pending {
    tokens: Vec<i32>,
    admitted_at: Instant,
    resp: mpsc::Sender<Result<i32>>,
}

/// Handle to one submitted request; blocks on [`ResponseHandle::wait`].
pub struct ResponseHandle {
    rx: mpsc::Receiver<Result<i32>>,
}

impl ResponseHandle {
    /// Block until the replica answers (or the stack shuts down).
    pub fn wait(self) -> Result<i32> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err(Error::Serve("server shut down before reply".into())))
    }
}

/// The running stack: submit requests, read stats, shut down.
pub struct ServeStack {
    queue: Arc<BoundedQueue<Pending>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Live serving counters (shared with the worker threads).
    pub stats: ServeStats,
}

impl ServeStack {
    /// Start `cfg.workers` replica threads; `make_backend(i)` builds the
    /// i-th worker's model replica.
    pub fn start<F>(cfg: ServerConfig, make_backend: F) -> Self
    where
        F: Fn(usize) -> Box<dyn BatchBackend>,
    {
        Self::start_with_obs(cfg, make_backend, FlightRecorder::disabled())
    }

    /// [`ServeStack::start`] with a flight recorder attached: each worker
    /// records a `serve.batch` assembly event (fill, close reason, oldest
    /// queue wait) and a `serve.batch_execute` span around the backend
    /// call, on its own pid track (replica `i` → pid `i + 1`).
    pub fn start_with_obs<F>(cfg: ServerConfig, make_backend: F, obs: FlightRecorder) -> Self
    where
        F: Fn(usize) -> Box<dyn BatchBackend>,
    {
        let queue = Arc::new(BoundedQueue::new(cfg.queue_depth.max(1)));
        let stats = ServeStats::default();
        let mut workers = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers.max(1) {
            let mut backend = make_backend(i);
            let queue = queue.clone();
            let stats = stats.clone();
            let obs = obs.clone();
            let pid = (i + 1) as u32;
            let max_batch = cfg.max_batch.min(backend.max_batch()).max(1);
            let delay = cfg.max_batch_delay;
            workers.push(std::thread::spawn(move || {
                while let Some(batch) = queue.next_batch(max_batch, delay) {
                    if batch.is_empty() {
                        continue;
                    }
                    let closed_at = Instant::now();
                    stats.queue_depth.set(queue.len() as i64);
                    stats.batches.inc();
                    stats.batch_fill.record(batch.len() as f64);
                    let mut oldest_wait_s: f64 = 0.0;
                    for p in &batch {
                        let wait = closed_at.duration_since(p.admitted_at).as_secs_f64();
                        oldest_wait_s = oldest_wait_s.max(wait);
                        stats.queue_wait_s.record(wait);
                    }
                    if obs.is_enabled() {
                        obs.event("serve.batch", pid, 0, vec![
                            ("fill", batch.len().into()),
                            (
                                "close",
                                if batch.len() >= max_batch { "size" } else { "deadline" }
                                    .into(),
                            ),
                            ("oldest_wait_s", oldest_wait_s.into()),
                        ]);
                    }
                    let rows: Vec<&[i32]> =
                        batch.iter().map(|p| p.tokens.as_slice()).collect();
                    let outcome = {
                        let _exec = obs.is_enabled().then(|| {
                            obs.span("serve.batch_execute", pid, 0, vec![
                                ("fill", batch.len().into()),
                            ])
                        });
                        backend.infer(&rows)
                    };
                    match outcome {
                        Ok(outs) => {
                            let done = Instant::now();
                            for (p, out) in batch.into_iter().zip(outs) {
                                stats.completed.inc();
                                stats
                                    .latency_s
                                    .record(done.duration_since(p.admitted_at).as_secs_f64());
                                let _ = p.resp.send(Ok(out));
                            }
                        }
                        Err(e) => {
                            // fail the whole batch; the error is not Clone,
                            // so each rider gets the rendered message
                            let msg = e.to_string();
                            for p in batch {
                                stats.failed.inc();
                                let _ = p.resp.send(Err(Error::Serve(msg.clone())));
                            }
                        }
                    }
                }
            }));
        }
        Self { queue, workers, stats }
    }

    /// Submit one request. Returns [`Error::Shed`] immediately when the
    /// queue is at its admission limit.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<ResponseHandle> {
        let (tx, rx) = mpsc::channel();
        let pending = Pending { tokens, admitted_at: Instant::now(), resp: tx };
        match self.queue.offer(pending) {
            Ok(()) => {
                self.stats.admitted.inc();
                self.stats.queue_depth.set(self.queue.len() as i64);
                Ok(ResponseHandle { rx })
            }
            Err(_) => {
                self.stats.shed.inc();
                Err(Error::Shed)
            }
        }
    }

    /// Requests accepted so far (admitted only).
    pub fn submitted(&self) -> u64 {
        self.stats.admitted.get()
    }

    /// Drain and stop: in-queue requests are still served, then workers
    /// exit and are joined.
    pub fn shutdown(self) {
        self.queue.close();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::{BatchBackend, SyntheticBackend};
    use super::*;

    fn stack(workers: usize, max_batch: usize, depth: usize) -> ServeStack {
        ServeStack::start(
            ServerConfig {
                queue_depth: depth,
                max_batch,
                max_batch_delay: Duration::from_millis(2),
                workers,
            },
            move |_| -> Box<dyn BatchBackend> {
                Box::new(SyntheticBackend::new(0.0, 0.0, max_batch, false))
            },
        )
    }

    #[test]
    fn serves_correct_tokens() {
        let s = stack(2, 8, 64);
        let rows: Vec<Vec<i32>> = (0..20).map(|i| vec![i, i + 1, i + 2]).collect();
        let handles: Vec<_> = rows.iter().map(|r| s.submit(r.clone()).unwrap()).collect();
        for (row, h) in rows.iter().zip(handles) {
            assert_eq!(h.wait().unwrap(), SyntheticBackend::token_for(row));
        }
        assert_eq!(s.stats.completed.get(), 20);
        assert_eq!(s.stats.failed.get(), 0);
        assert!(s.stats.batches.get() >= 3, "20 reqs / batch<=8 needs >=3 batches");
        s.shutdown();
    }

    #[test]
    fn sheds_beyond_queue_depth() {
        // no workers consuming yet: start with a slow backend so the queue
        // actually fills. base 50ms blocks the single worker long enough.
        let s = ServeStack::start(
            ServerConfig {
                queue_depth: 4,
                max_batch: 1,
                max_batch_delay: Duration::from_millis(1),
                workers: 1,
            },
            |_| -> Box<dyn BatchBackend> {
                Box::new(SyntheticBackend::new(0.05, 0.0, 1, true))
            },
        );
        let mut shed = 0;
        let mut handles = Vec::new();
        // worker takes 1 into service; 4 queue slots; the rest shed
        for i in 0..32 {
            match s.submit(vec![i]) {
                Ok(h) => handles.push(h),
                Err(Error::Shed) => shed += 1,
                Err(e) => panic!("unexpected error {e}"),
            }
        }
        assert!(shed > 0, "admission control must engage");
        assert_eq!(s.stats.shed.get(), shed);
        for h in handles {
            h.wait().unwrap(); // everything admitted is served
        }
        assert_eq!(s.stats.completed.get() + s.stats.failed.get(), s.stats.admitted.get());
        s.shutdown();
    }

    #[test]
    fn shutdown_drains_admitted_requests() {
        let s = stack(1, 4, 1024);
        let handles: Vec<_> = (0..50).map(|i| s.submit(vec![i]).unwrap()).collect();
        s.shutdown();
        // all 50 were answered before workers exited
        for h in handles {
            assert!(h.wait().is_ok());
        }
    }

    #[test]
    fn batches_actually_form() {
        let s = stack(1, 16, 1024);
        let handles: Vec<_> = (0..64).map(|i| s.submit(vec![i]).unwrap()).collect();
        for h in handles {
            h.wait().unwrap();
        }
        let fill = s.stats.batch_fill.snapshot();
        assert!(
            fill.max > 1.0,
            "with 64 queued and a single worker, batches must exceed size 1: {fill:?}"
        );
        s.shutdown();
    }

    #[test]
    fn workers_record_batch_assembly_and_execute_spans() {
        let rec = FlightRecorder::wallclock(4096);
        let s = ServeStack::start_with_obs(
            ServerConfig {
                queue_depth: 1024,
                max_batch: 8,
                max_batch_delay: Duration::from_millis(2),
                workers: 2,
            },
            |_| -> Box<dyn BatchBackend> {
                Box::new(SyntheticBackend::new(0.0, 0.0, 8, false))
            },
            rec.clone(),
        );
        let handles: Vec<_> = (0..40).map(|i| s.submit(vec![i]).unwrap()).collect();
        for h in handles {
            h.wait().unwrap();
        }
        let batches = s.stats.batches.get();
        s.shutdown();
        let records = rec.snapshot();
        let count = |n: &str| records.iter().filter(|r| r.name == n).count() as u64;
        assert_eq!(count("serve.batch"), batches);
        assert_eq!(count("serve.batch_execute"), batches);
        for r in records.iter().filter(|r| r.name == "serve.batch") {
            assert!(r.pid >= 1 && r.pid <= 2, "replica pids start at 1: {}", r.pid);
            let close = r.arg("close").and_then(|a| a.as_str()).unwrap().to_string();
            assert!(close == "size" || close == "deadline");
            assert!(r.arg("fill").and_then(|a| a.as_u64()).unwrap() >= 1);
            assert!(r.arg("oldest_wait_s").and_then(|a| a.as_f64()).unwrap() >= 0.0);
        }
    }
}
