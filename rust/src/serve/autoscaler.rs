//! Replica autoscaler: queue-depth + p99-latency driven scaling policy.
//!
//! The paper's serving claim is economic: heavy traffic is served from
//! "unstable cheap resources" (spot), with elasticity absorbing both load
//! swings *and* preemption losses. The controller here is deliberately
//! boring — hysteresis around two observable signals:
//!
//! * **hot** — windowed p99 latency near the SLO, or backlog per live
//!   replica above a watermark → add replicas (bounded step, cooldown).
//! * **cold** — p99 far below the SLO and negligible backlog → drain one
//!   replica (slow bleed, longer cooldown).
//!
//! Provisioning in flight counts toward capacity so a scale-up burst is
//! not re-ordered every tick while nodes boot ("provisioning debt").
//! The policy is pure (no clocks, no I/O): the virtual-time serving sim
//! drives it with sampled [`ScaleSignal`]s, and unit tests hit every
//! branch directly.

/// Controller configuration.
#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    /// Fleet floor: preemption losses below this trigger immediate repair.
    pub min_replicas: usize,
    /// Fleet ceiling: scale-ups never push capacity past this.
    pub max_replicas: usize,
    /// The latency objective the controller defends (p99, seconds).
    pub slo_p99_s: f64,
    /// Scale up when windowed p99 exceeds this fraction of the SLO.
    pub hot_p99_frac: f64,
    /// Scale down only when windowed p99 is below this fraction.
    pub cold_p99_frac: f64,
    /// Scale up when queue depth exceeds this many requests per live
    /// replica (capacity-normalized backlog watermark).
    pub backlog_per_replica: f64,
    /// Replicas added per scale-up decision.
    pub up_step: usize,
    /// Minimum seconds between scale-ups.
    pub up_cooldown_s: f64,
    /// Minimum seconds between scale-downs (also held after a scale-up).
    pub down_cooldown_s: f64,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        Self {
            min_replicas: 1,
            max_replicas: 64,
            slo_p99_s: 0.25,
            hot_p99_frac: 0.8,
            cold_p99_frac: 0.3,
            backlog_per_replica: 4.0,
            up_step: 2,
            up_cooldown_s: 10.0,
            down_cooldown_s: 30.0,
        }
    }
}

/// Weight-swap policy for multi-model replica fleets.
///
/// A replica serves exactly one model at a time; converting it to
/// another model streams new weights for `swap_s` virtual seconds during
/// which it serves nothing. Swapping an *idle* replica is still far
/// cheaper than provisioning a new node (seconds vs the better part of a
/// minute, and no extra instance on the bill), so when per-model demand
/// shifts, the controller converts capacity before it buys capacity.
#[derive(Debug, Clone)]
pub struct SwapConfig {
    /// Virtual seconds a weight swap occupies a replica (no serving).
    pub swap_s: f64,
    /// Starved-model backlog required before a swap is considered.
    pub min_backlog: usize,
    /// Starved backlog must exceed the donor model's backlog by this
    /// factor — swaps chase real imbalance, not noise.
    pub imbalance: f64,
    /// Minimum seconds between swap decisions (one replica converts at a
    /// time; the next tick re-evaluates with the swap's effect visible).
    pub cooldown_s: f64,
}

impl Default for SwapConfig {
    fn default() -> Self {
        Self { swap_s: 8.0, min_backlog: 8, imbalance: 4.0, cooldown_s: 5.0 }
    }
}

/// One control-tick observation.
#[derive(Debug, Clone, Copy)]
pub struct ScaleSignal {
    /// Tick timestamp, seconds.
    pub now_s: f64,
    /// Requests waiting for a batch.
    pub queue_depth: usize,
    /// p99 latency over the window since the previous tick (0 when the
    /// window saw no completions).
    pub window_p99_s: f64,
    /// Replicas currently able to serve.
    pub live: usize,
    /// Replicas requested but not yet ready.
    pub provisioning: usize,
}

/// What the control loop should do this tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Leave the fleet as it is.
    Hold,
    /// Provision this many additional replicas.
    Up(usize),
    /// Drain this many replicas (graceful: finish in-flight, then release).
    Down(usize),
}

/// The stateful controller (cooldown bookkeeping only).
#[derive(Debug)]
pub struct Autoscaler {
    cfg: AutoscalerConfig,
    last_up_s: f64,
    last_down_s: f64,
    last_swap_s: f64,
}

impl Autoscaler {
    /// Cooldowns are measured from t=0: the fleet was just sized, so the
    /// first scale decision must also wait out its cooldown (otherwise a
    /// `down_cooldown_s` of e.g. 1e9 — the "never scale down" idiom —
    /// would still allow one initial drain).
    pub fn new(cfg: AutoscalerConfig) -> Self {
        Self { cfg, last_up_s: 0.0, last_down_s: 0.0, last_swap_s: 0.0 }
    }

    /// The configuration this controller runs.
    pub fn config(&self) -> &AutoscalerConfig {
        &self.cfg
    }

    /// Decide this tick's action. Mutates only cooldown state.
    pub fn decide(&mut self, sig: &ScaleSignal) -> ScaleDecision {
        let cfg = &self.cfg;
        let capacity = sig.live + sig.provisioning;

        // floor repair runs regardless of cooldowns: preemptions must not
        // leave the fleet below the configured minimum
        if capacity < cfg.min_replicas {
            let n = cfg.min_replicas - capacity;
            self.last_up_s = sig.now_s;
            return ScaleDecision::Up(n);
        }

        let hot_latency = sig.window_p99_s >= cfg.hot_p99_frac * cfg.slo_p99_s;
        let hot_backlog =
            sig.queue_depth as f64 >= cfg.backlog_per_replica * sig.live.max(1) as f64;
        if (hot_latency || hot_backlog)
            && capacity < cfg.max_replicas
            && sig.now_s - self.last_up_s >= cfg.up_cooldown_s
        {
            let n = cfg.up_step.max(1).min(cfg.max_replicas - capacity);
            self.last_up_s = sig.now_s;
            return ScaleDecision::Up(n);
        }

        let cold_latency = sig.window_p99_s < cfg.cold_p99_frac * cfg.slo_p99_s;
        let cold_backlog =
            (sig.queue_depth as f64) < 0.5 * cfg.backlog_per_replica * sig.live.max(1) as f64;
        if cold_latency
            && cold_backlog
            && capacity > cfg.min_replicas
            && sig.now_s - self.last_down_s >= cfg.down_cooldown_s
            && sig.now_s - self.last_up_s >= cfg.up_cooldown_s
        {
            self.last_down_s = sig.now_s;
            return ScaleDecision::Down(1);
        }

        ScaleDecision::Hold
    }

    /// Swap-vs-scale: pick a `(donor, starved)` model pair whose backlog
    /// imbalance justifies converting an existing replica instead of
    /// provisioning a new one. `backlog[m]` is the requests waiting for
    /// model `m`; `replicas[m]` is the capacity already committed to `m`
    /// (serving, plus swaps already converting toward it). Returns the
    /// `(from, to)` models, or `None` when demand is balanced, the
    /// starved backlog is below `min_backlog`, no donor model has a
    /// replica to give, or the swap cooldown is still running. Mutates
    /// only cooldown state.
    pub fn decide_swap(
        &mut self,
        swap: &SwapConfig,
        now_s: f64,
        backlog: &[usize],
        replicas: &[usize],
    ) -> Option<(usize, usize)> {
        let models = backlog.len().min(replicas.len());
        if models < 2 || now_s - self.last_swap_s < swap.cooldown_s {
            return None;
        }
        let to = (0..models).max_by_key(|&m| backlog[m])?;
        if backlog[to] < swap.min_backlog.max(1) {
            return None;
        }
        let from = (0..models).filter(|&m| m != to && replicas[m] > 0).min_by_key(|&m| backlog[m])?;
        if (backlog[to] as f64) < swap.imbalance * (backlog[from] as f64).max(1.0) {
            return None;
        }
        self.last_swap_s = now_s;
        Some((from, to))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(now_s: f64, depth: usize, p99: f64, live: usize, prov: usize) -> ScaleSignal {
        ScaleSignal { now_s, queue_depth: depth, window_p99_s: p99, live, provisioning: prov }
    }

    fn ctl() -> Autoscaler {
        Autoscaler::new(AutoscalerConfig {
            min_replicas: 2,
            max_replicas: 8,
            slo_p99_s: 1.0,
            up_cooldown_s: 10.0,
            down_cooldown_s: 30.0,
            ..Default::default()
        })
    }

    #[test]
    fn scales_up_on_backlog() {
        let mut a = ctl();
        // depth 40 over 4 live >> 4/replica watermark
        assert_eq!(a.decide(&sig(50.0, 40, 0.1, 4, 0)), ScaleDecision::Up(2));
    }

    #[test]
    fn scales_up_on_hot_p99() {
        let mut a = ctl();
        assert_eq!(a.decide(&sig(50.0, 0, 0.9, 4, 0)), ScaleDecision::Up(2));
    }

    #[test]
    fn up_cooldown_throttles() {
        let mut a = ctl();
        // cooldowns run from t=0: hot at t=5 is still inside the window
        assert_eq!(a.decide(&sig(5.0, 100, 2.0, 2, 0)), ScaleDecision::Hold, "initial cooldown");
        assert_eq!(a.decide(&sig(10.0, 100, 2.0, 2, 0)), ScaleDecision::Up(2));
        assert_eq!(a.decide(&sig(15.0, 100, 2.0, 2, 2)), ScaleDecision::Hold, "cooling down");
        assert_eq!(a.decide(&sig(20.0, 100, 2.0, 2, 2)), ScaleDecision::Up(2));
    }

    #[test]
    fn provisioning_counts_toward_capacity_cap() {
        let mut a = ctl();
        // 6 live + 1 provisioning = 7; max 8 -> step clamps to 1
        assert_eq!(a.decide(&sig(50.0, 100, 2.0, 6, 1)), ScaleDecision::Up(1));
        // at the cap: hold even though hot
        assert_eq!(a.decide(&sig(70.0, 100, 2.0, 6, 2)), ScaleDecision::Hold);
    }

    #[test]
    fn floor_repair_ignores_cooldown() {
        let mut a = ctl();
        assert_eq!(a.decide(&sig(50.0, 100, 2.0, 2, 0)), ScaleDecision::Up(2));
        // a storm just killed everything: repair below-min immediately,
        // cooldown or not
        assert_eq!(a.decide(&sig(51.0, 0, 0.0, 0, 0)), ScaleDecision::Up(2));
    }

    #[test]
    fn scales_down_when_cold() {
        let mut a = ctl();
        assert_eq!(a.decide(&sig(100.0, 0, 0.01, 4, 0)), ScaleDecision::Down(1));
        assert_eq!(a.decide(&sig(110.0, 0, 0.01, 3, 0)), ScaleDecision::Hold, "down cooldown");
        assert_eq!(a.decide(&sig(130.0, 0, 0.01, 3, 0)), ScaleDecision::Down(1));
    }

    #[test]
    fn never_drains_below_min() {
        let mut a = ctl();
        assert_eq!(a.decide(&sig(100.0, 0, 0.0, 2, 0)), ScaleDecision::Hold);
    }

    #[test]
    fn warm_p99_holds() {
        let mut a = ctl();
        // between cold (0.3) and hot (0.8) fractions of the SLO: stable
        assert_eq!(a.decide(&sig(100.0, 1, 0.5, 4, 0)), ScaleDecision::Hold);
    }

    #[test]
    fn swap_follows_backlog_imbalance() {
        let mut a = ctl();
        let swap = SwapConfig::default();
        // model 1 starved (40 waiting), model 0 idle with 4 replicas
        assert_eq!(a.decide_swap(&swap, 50.0, &[0, 40], &[4, 0]), Some((0, 1)));
    }

    #[test]
    fn swap_cooldown_throttles() {
        let mut a = ctl();
        let swap = SwapConfig::default();
        // cooldowns run from t=0, like scale cooldowns
        assert_eq!(a.decide_swap(&swap, 1.0, &[0, 40], &[4, 0]), None, "initial cooldown");
        assert_eq!(a.decide_swap(&swap, 5.0, &[0, 40], &[4, 0]), Some((0, 1)));
        assert_eq!(a.decide_swap(&swap, 7.0, &[0, 40], &[4, 0]), None, "cooling down");
        assert_eq!(a.decide_swap(&swap, 10.0, &[0, 40], &[4, 0]), Some((0, 1)));
    }

    #[test]
    fn swap_needs_real_starvation_and_imbalance() {
        let mut a = ctl();
        let swap = SwapConfig::default();
        // below min_backlog: hold
        assert_eq!(a.decide_swap(&swap, 50.0, &[0, 7], &[4, 0]), None);
        // both models loaded within the imbalance factor: hold
        assert_eq!(a.decide_swap(&swap, 50.0, &[20, 40], &[2, 2]), None);
        // 4x imbalance at the boundary triggers
        assert_eq!(a.decide_swap(&swap, 50.0, &[10, 40], &[2, 2]), Some((0, 1)));
    }

    #[test]
    fn swap_needs_a_donor_replica() {
        let mut a = ctl();
        let swap = SwapConfig::default();
        // every replica already serves (or converts toward) the starved
        // model: nothing to donate, scale instead
        assert_eq!(a.decide_swap(&swap, 50.0, &[0, 40], &[0, 4]), None);
        // single-model fleets never swap
        assert_eq!(a.decide_swap(&swap, 50.0, &[40], &[4]), None);
    }

    #[test]
    fn swap_picks_the_least_loaded_donor() {
        let mut a = ctl();
        let swap = SwapConfig::default();
        // three models: 2 is starved; 0 (backlog 1) donates before 1
        assert_eq!(a.decide_swap(&swap, 50.0, &[1, 6, 60], &[2, 2, 1]), Some((0, 2)));
    }
}
