//! [`ServeSim`]: deterministic virtual-time serving simulation.
//!
//! This is where the serving layer meets the paper's §III.D claim — heavy
//! traffic on "unstable cheap resources" — at a scale the threaded
//! [`super::ServeStack`] cannot reach on one host. Replicas are nodes of
//! the shared [`crate::fleet::FleetEngine`] (provisioned, preempted by
//! the background market, a recorded price trace, or *scripted storms*,
//! and billed by the engine), requests arrive from an open- or
//! closed-loop generator ([`crate::sim`]), the dynamic batcher is the
//! shared [`BatchPolicy`], and the [`Autoscaler`] runs as a periodic
//! control tick over windowed p99 / queue-depth signals.
//!
//! Three hot-path mechanisms cooperate on top of that base:
//!
//! * **Priority classes** ([`Priority`]): arrivals carry a class drawn
//!   from [`ServeSimConfig::class_mix`]; the queue keeps per-class lanes,
//!   shed-at-admission displaces the lowest class first, and dispatch
//!   queue-jumps (a batch drains `paid` before `free` before `batch`).
//! * **Adaptive batching** ([`super::BatchController`]): with
//!   [`ServeSimConfig::adaptive`] set, the live [`BatchPolicy`] shrinks
//!   its close window as the tick-windowed p99 nears the SLO and widens
//!   it back under slack, trading amortization for tail headroom.
//! * **Multi-model replicas** ([`ServeSimConfig::models`] > 1): each
//!   replica serves one model (the fleet node's tag); converting it costs
//!   [`super::SwapConfig::swap_s`] virtual seconds of no service (a
//!   `serve.swap` span in the trace). The [`Autoscaler`] swaps idle
//!   capacity toward per-model backlog before it buys new capacity.
//!
//! Invariants the tests pin down:
//!
//! * **No admitted request is ever dropped.** Preempting a replica
//!   requeues its in-flight batch at the queue front (original admission
//!   timestamps preserved, class lanes and admission limit respected and
//!   bypassed respectively); the only way out of the system is a
//!   response or an admission-time shed (including displacement by a
//!   higher class while still queued — never once dispatched).
//! * **Determinism.** Same config + seed ⇒ bit-identical [`ServeReport`].
//!   Storms are scripted `(time, kills, notice)` triples timed from
//!   **engine start** (see [`crate::fleet`]), so a preemption storm is a
//!   reproducible experiment rather than an anecdote.

use std::collections::{BTreeMap, VecDeque};

use crate::cloud::InstanceType;
use crate::fleet::{FleetConfig, FleetEngine, FleetStats, FleetWorkload, LaunchSpec, NodeId,
                   PriceTraceConfig};
use crate::metrics::{Histogram, HistogramSnapshot};
use crate::obs::{FlightRecorder, SeriesSet, SloMonitor, SloSpec};
use crate::sim::{ClosedLoop, OpenLoop, RateSchedule, SimRng, SimTime};
use crate::Result;

use super::autoscaler::{Autoscaler, AutoscalerConfig, ScaleDecision, ScaleSignal, SwapConfig};
use super::batcher::{AdaptiveBatchConfig, BatchController, BatchPolicy};
use super::queue::Priority;

/// Client model driving the simulation.
#[derive(Debug, Clone)]
pub enum Load {
    /// Open loop: arrivals keep coming regardless of system state.
    Open(OpenLoop),
    /// Closed loop: each user thinks, issues, waits for the response.
    Closed(ClosedLoop),
    /// Open loop whose rate follows a piecewise-constant [`RateSchedule`]
    /// (ramps, flash crowds). Gaps are exponential at the rate in effect
    /// when each arrival is scheduled; a gap that crosses a phase
    /// boundary keeps its sampled length (boundary-exact thinning is not
    /// modeled).
    Scheduled(RateSchedule),
}

pub use crate::cloud::{ProvisionerConfig, SpotMarketConfig, StormEvent};

/// A scripted step in per-model demand: at `at_s` the arrival weights
/// switch to `mix`. Models the "demand moved from A to B" scenario that
/// makes swap-vs-scale an interesting decision (a static mix never
/// starves one model while the other holds idle replicas).
#[derive(Debug, Clone)]
pub struct ModelShift {
    /// Virtual time the new mix takes effect, seconds.
    pub at_s: f64,
    /// Per-model arrival weights from `at_s` on (len = `models`).
    pub mix: Vec<f64>,
}

/// Full serving-scenario configuration.
#[derive(Debug, Clone)]
pub struct ServeSimConfig {
    /// Dynamic batching rule (size / deadline). With
    /// [`ServeSimConfig::adaptive`] set this is only the *starting*
    /// policy; the controller then moves it inside the adaptive bounds.
    pub batch: BatchPolicy,
    /// Adaptive batch-window controller; `None` keeps `batch` fixed.
    /// Adjustments happen on the autoscaler control tick, reading the
    /// same windowed p99 the scaler sees.
    pub adaptive: Option<AdaptiveBatchConfig>,
    /// Arrival weights per priority class (`[paid, free, batch]` — see
    /// [`Priority`]); zero-weight classes never arrive. The default puts
    /// everything in `paid`, which is exactly the single-class stack.
    pub class_mix: [f64; Priority::COUNT],
    /// Distinct models replicas can serve (1 = classic single-model
    /// fleet; the model is the fleet node's tag).
    pub models: usize,
    /// Per-model arrival weights (must have `models` entries to take
    /// effect; anything else falls back to a uniform mix).
    pub model_mix: Vec<f64>,
    /// Scripted change of `model_mix` mid-run (demand migration).
    pub model_shift: Option<ModelShift>,
    /// Weight-swap policy, read when `models > 1`; `None` never swaps
    /// (starved models wait for scale-ups alone).
    pub swap: Option<SwapConfig>,
    /// Admission limit (requests beyond this are shed).
    pub queue_depth: usize,
    /// Replica batch service time: `base + per_item * n` seconds.
    pub service_base_s: f64,
    /// Marginal per-request service time, seconds.
    pub service_per_item_s: f64,
    /// Instance type replicas run on (pricing + provisioning profile).
    pub instance: InstanceType,
    /// Provision replicas on the spot market (vs on-demand).
    pub spot_replicas: bool,
    /// Fleet size at t=0.
    pub initial_replicas: usize,
    /// Initial replicas start Ready at t=0 (fleet provisioned before the
    /// traffic cutover). Autoscaled additions always pay provisioning.
    pub warm_start: bool,
    /// Replica controller configuration.
    pub autoscaler: AutoscalerConfig,
    /// Seconds between autoscaler control ticks.
    pub scale_interval_s: f64,
    /// Node provisioning model (boot time, jitter, warm-cache odds).
    pub provisioner: ProvisionerConfig,
    /// Background random preemptions; `None` = scripted storms only.
    pub spot_market: Option<SpotMarketConfig>,
    /// Price-trace-driven preemption (replayed `(t, price)` series vs a
    /// bid); overrides `spot_market` when set.
    pub price_trace: Option<PriceTraceConfig>,
    /// Scripted preemption waves (timed from engine start).
    pub storm: Vec<StormEvent>,
    /// RNG seed (same seed ⇒ bit-identical report).
    pub seed: u64,
    /// Record a per-tick timeline into [`ServeReport::trace`].
    pub trace: bool,
    /// Latency objective evaluated at every control tick: an
    /// [`crate::obs::SloMonitor`] over the windowed p99 emits
    /// `slo.breach` / `slo.recover` transitions onto the attached flight
    /// recorder. `None` (the default) monitors nothing. Purely an
    /// observer — it never influences scaling decisions.
    pub slo: Option<SloSpec>,
}

impl Default for ServeSimConfig {
    fn default() -> Self {
        Self {
            batch: BatchPolicy::default(),
            adaptive: None,
            class_mix: [1.0, 0.0, 0.0],
            models: 1,
            model_mix: vec![1.0],
            model_shift: None,
            swap: None,
            queue_depth: 256,
            service_base_s: 0.002,
            service_per_item_s: 0.001,
            instance: InstanceType::P3_2xlarge,
            spot_replicas: true,
            initial_replicas: 2,
            warm_start: true,
            autoscaler: AutoscalerConfig::default(),
            scale_interval_s: 5.0,
            provisioner: ProvisionerConfig::default(),
            spot_market: None,
            price_trace: None,
            storm: Vec::new(),
            seed: 0,
            trace: false,
            slo: None,
        }
    }
}

/// One autoscaler control-tick observation (when tracing is on).
#[derive(Debug, Clone, PartialEq)]
pub struct TickTrace {
    /// Tick timestamp, virtual seconds.
    pub t_s: f64,
    /// Replicas able to serve at the tick.
    pub live: usize,
    /// Replicas requested but not yet ready.
    pub provisioning: usize,
    /// Requests waiting at the tick.
    pub queue_depth: usize,
    /// p99 latency over the window since the previous tick, seconds.
    pub window_p99_s: f64,
    /// Cumulative completed responses at the tick.
    pub completed: u64,
    /// Cumulative shed requests at the tick.
    pub shed: u64,
}

/// Per-priority-class accounting of one run.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassReport {
    /// Class label (`paid` / `free` / `batch`).
    pub class: &'static str,
    /// Requests the load generator produced in this class.
    pub offered: u64,
    /// Requests of this class accepted past admission control.
    pub admitted: u64,
    /// Requests of this class shed — at the door or displaced from the
    /// queue by a higher class while waiting.
    pub shed: u64,
    /// Requests of this class answered.
    pub completed: u64,
    /// End-to-end latency of this class (admission → response), seconds.
    pub latency: HistogramSnapshot,
}

/// Outcome of one simulated serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    /// Load-generation horizon (drain continues past it).
    pub duration_s: f64,
    /// Virtual time when the last response left the system.
    pub makespan_s: f64,
    /// Requests the load generator produced.
    pub offered: u64,
    /// Requests accepted past admission control.
    pub admitted: u64,
    /// Requests rejected at the door.
    pub shed: u64,
    /// Requests answered (must equal `admitted` when nothing is lost).
    pub completed: u64,
    /// Requests re-queued out of preempted in-flight batches.
    pub requeued: u64,
    /// Replicas lost to storms, the price trace, or the background market.
    pub preemptions: u64,
    /// Replicas provisioned beyond the initial fleet.
    pub scale_ups: u64,
    /// Replicas drained by the autoscaler's cold path.
    pub scale_downs: u64,
    /// Total replicas provisioned over the run.
    pub replicas_launched: usize,
    /// Peak concurrently-live replicas.
    pub max_live: usize,
    /// Replicas still alive when the run ended.
    pub final_live: usize,
    /// End-to-end latency (admission → response), seconds.
    pub latency: HistogramSnapshot,
    /// Average requests per dispatched batch.
    pub mean_batch_fill: f64,
    /// Completions per second of load horizon.
    pub throughput_rps: f64,
    /// Instance-hours billed, USD.
    pub cost_usd: f64,
    /// Completed weight swaps (multi-model fleets only).
    pub swaps: u64,
    /// Per-class accounting, indexed like [`Priority::ALL`]. All-paid in
    /// the default single-class configuration.
    pub per_class: Vec<ClassReport>,
    /// Per-tick timeline (empty unless tracing was enabled).
    pub trace: Vec<TickTrace>,
}

#[derive(Debug, Clone, Copy)]
struct Req {
    admitted_at: SimTime,
    /// Closed-loop user to wake after the response (open loop: `None`).
    user: Option<u64>,
    /// Priority class lane index ([`Priority::index`]).
    class: u8,
    /// Model this request needs.
    model: u8,
}

// Timer-token space: the engine's `schedule_timer` carries one u64.
const TOK_TICK: u64 = 0;
const TOK_DEADLINE: u64 = 1;
const TOK_ARRIVE: u64 = 2;
/// Closed-loop user `u` arrives as token `TOK_USER0 + u`.
const TOK_USER0: u64 = 3;

// Work-token space (`schedule_work`, separate from timers): a batch
// completion vs a weight-swap completion on a replica.
const WORK_BATCH: u64 = 0;
const WORK_SWAP: u64 = 1;

/// Class-major priority lanes with per-model sub-lanes: lane `(c, m)` is
/// `c * models + m`. Dispatch drains class 0 first; within a class, FIFO
/// by admission. Preempted batches re-enter at the front of their own
/// lanes with original stamps, so restored work dispatches before later
/// same-class arrivals and still never jumps a higher class.
#[derive(Debug)]
struct PrioQueue {
    models: usize,
    lanes: Vec<VecDeque<Req>>,
    len: usize,
}

impl PrioQueue {
    fn new(models: usize) -> Self {
        let models = models.max(1);
        Self {
            models,
            lanes: (0..Priority::COUNT * models).map(|_| VecDeque::new()).collect(),
            len: 0,
        }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn lane(&self, class: usize, model: usize) -> usize {
        class * self.models + model
    }

    fn push_back(&mut self, req: Req) {
        let l = self.lane(req.class as usize, req.model as usize);
        self.lanes[l].push_back(req);
        self.len += 1;
    }

    /// Preemptive shed: remove the youngest waiter of the lowest class
    /// strictly below `class`, if any.
    fn evict_below(&mut self, class: usize) -> Option<Req> {
        for c in ((class + 1)..Priority::COUNT).rev() {
            // youngest within the class = latest admission among lane backs
            let mut best: Option<(usize, SimTime)> = None;
            for m in 0..self.models {
                let l = self.lane(c, m);
                if let Some(r) = self.lanes[l].back() {
                    if best.is_none_or(|(_, t)| r.admitted_at > t) {
                        best = Some((l, r.admitted_at));
                    }
                }
            }
            if let Some((l, _)) = best {
                self.len -= 1;
                return self.lanes[l].pop_back();
            }
        }
        None
    }

    /// Requests waiting for `model`, across all classes.
    fn model_depth(&self, model: usize) -> usize {
        (0..Priority::COUNT).map(|c| self.lanes[self.lane(c, model)].len()).sum()
    }

    /// Oldest admission stamp waiting for `model` (drives the batch
    /// close deadline).
    fn model_oldest(&self, model: usize) -> Option<SimTime> {
        (0..Priority::COUNT)
            .filter_map(|c| self.lanes[self.lane(c, model)].front().map(|r| r.admitted_at))
            .min()
    }

    /// Take up to `take` requests for `model`, highest class first.
    fn drain_model(&mut self, model: usize, take: usize) -> Vec<Req> {
        let mut out = Vec::with_capacity(take);
        for c in 0..Priority::COUNT {
            let l = self.lane(c, model);
            while out.len() < take {
                match self.lanes[l].pop_front() {
                    Some(r) => out.push(r),
                    None => break,
                }
            }
        }
        self.len -= out.len();
        out
    }

    /// Preempted in-flight work re-enters at the front of its own lanes,
    /// original order and admission stamps intact.
    fn requeue_front(&mut self, batch: Vec<Req>) {
        self.len += batch.len();
        for req in batch.into_iter().rev() {
            let l = self.lane(req.class as usize, req.model as usize);
            self.lanes[l].push_front(req);
        }
    }

    /// Per-model backlog vector for the swap-vs-scale decision.
    fn model_backlogs(&self) -> Vec<usize> {
        (0..self.models).map(|m| self.model_depth(m)).collect()
    }
}

/// The simulator. Construct, then [`ServeSim::run`] one scenario.
pub struct ServeSim {
    cfg: ServeSimConfig,
    stats: FleetStats,
    obs: FlightRecorder,
    series: SeriesSet,
}

impl ServeSim {
    /// Build a simulator for one scenario configuration.
    pub fn new(cfg: ServeSimConfig) -> Self {
        Self {
            cfg,
            stats: FleetStats::default(),
            obs: FlightRecorder::disabled(),
            series: SeriesSet::disabled(),
        }
    }

    /// Attach a flight recorder before [`ServeSim::run`]: the fleet
    /// engine records node lifecycle + work events into it, and the
    /// serving layer adds batch-execute spans (fill, close reason, oldest
    /// wait), shed events, and autoscaler decisions — all stamped with
    /// virtual time (one pid per replica).
    pub fn set_obs(&mut self, obs: FlightRecorder) {
        self.obs = obs;
    }

    /// Attach a time-series set before [`ServeSim::run`]: every
    /// autoscaler control tick pushes the windowed p99, live replica
    /// count, queue depth, and cumulative completions as virtual-time
    /// samples (`serve.window_p99_s`, `serve.live`, ...).
    pub fn set_series(&mut self, series: SeriesSet) {
        self.series = series;
    }

    /// Fleet-level counters of the last run (preemptions, storm firing
    /// times, deferred launches).
    pub fn fleet_stats(&self) -> &FleetStats {
        &self.stats
    }

    /// Run `load` for `duration_s` of virtual time (plus drain) and report.
    pub fn run(&mut self, load: Load, duration_s: f64) -> Result<ServeReport> {
        let mut engine = FleetEngine::new(FleetConfig {
            provisioner: self.cfg.provisioner.clone(),
            spot_market: self.cfg.spot_market.clone(),
            price_trace: self.cfg.price_trace.clone(),
            storm: self.cfg.storm.clone(),
            seed: self.cfg.seed,
            ..FleetConfig::default()
        });
        let models = self.cfg.models.max(1);
        let model_weights = if self.cfg.model_mix.len() == models {
            self.cfg.model_mix.clone()
        } else {
            vec![1.0; models]
        };
        let mut w = ServeWorkload {
            cfg: &self.cfg,
            rng: SimRng::new(self.cfg.seed ^ 0x5EE7_BA7C),
            // class/model sampling draws from its own stream so enabling
            // a mix never perturbs the arrival-time sequence
            mix_rng: SimRng::new(self.cfg.seed ^ 0xC1A5_51F5),
            load: Some(load),
            queue: PrioQueue::new(models),
            busy: BTreeMap::new(),
            deadline_at: None,
            latency: Histogram::new(),
            window: Histogram::new(),
            scaler: Autoscaler::new(self.cfg.autoscaler.clone()),
            policy: self.cfg.batch,
            ctrl: self
                .cfg
                .adaptive
                .clone()
                .map(|a| BatchController::new(a, self.cfg.batch)),
            single_class: self.cfg.class_mix[1..].iter().all(|&w| w <= 0.0),
            models,
            model_weights,
            model_shift: self.cfg.model_shift.clone(),
            replica_model: BTreeMap::new(),
            swapping: BTreeMap::new(),
            swaps: 0,
            offered: 0,
            admitted: 0,
            shed: 0,
            completed: 0,
            requeued: 0,
            offered_by: [0; Priority::COUNT],
            admitted_by: [0; Priority::COUNT],
            shed_by: [0; Priority::COUNT],
            completed_by: [0; Priority::COUNT],
            lat_by: std::array::from_fn(|_| Histogram::new()),
            scale_ups: 0,
            scale_downs: 0,
            batches: 0,
            batched_reqs: 0,
            tick_armed: false,
            load_end: SimTime::from_secs_f64(duration_s),
            think: None,
            open: None,
            sched: None,
            last_completion: SimTime::ZERO,
            trace: Vec::new(),
            obs: self.obs.clone(),
            slo: self.cfg.slo.clone().map(|s| SloMonitor::new(s, self.obs.clone())),
            series: self.series.clone(),
        };
        engine.set_obs(self.obs.clone());
        engine.run(&mut w)?;
        let end = engine.now().max(w.load_end);
        let final_live = engine.shutdown(end);
        self.stats = engine.stats().clone();

        Ok(ServeReport {
            duration_s,
            makespan_s: w.last_completion.as_secs_f64(),
            offered: w.offered,
            admitted: w.admitted,
            shed: w.shed,
            completed: w.completed,
            requeued: w.requeued,
            preemptions: self.stats.preemptions,
            scale_ups: w.scale_ups,
            scale_downs: w.scale_downs,
            replicas_launched: self.stats.nodes_launched,
            max_live: self.stats.max_live,
            final_live,
            latency: w.latency.snapshot(),
            mean_batch_fill: if w.batches == 0 {
                0.0
            } else {
                w.batched_reqs as f64 / w.batches as f64
            },
            throughput_rps: if duration_s > 0.0 {
                w.completed as f64 / duration_s
            } else {
                0.0
            },
            cost_usd: engine.ledger().total_usd(),
            swaps: w.swaps,
            per_class: (0..Priority::COUNT)
                .map(|c| ClassReport {
                    class: Priority::from_index(c).name(),
                    offered: w.offered_by[c],
                    admitted: w.admitted_by[c],
                    shed: w.shed_by[c],
                    completed: w.completed_by[c],
                    latency: w.lat_by[c].snapshot(),
                })
                .collect(),
            trace: std::mem::take(&mut w.trace),
        })
    }
}

/// The batching-replica workload behind [`ServeSim`].
struct ServeWorkload<'a> {
    cfg: &'a ServeSimConfig,
    rng: SimRng,
    /// Independent stream for class/model sampling (see `run`).
    mix_rng: SimRng,
    /// Taken at `on_start` to bootstrap the generator.
    load: Option<Load>,
    queue: PrioQueue,
    /// In-flight batch per replica; a kill requeues it at the front.
    busy: BTreeMap<NodeId, Vec<Req>>,
    deadline_at: Option<SimTime>,
    latency: Histogram,
    window: Histogram,
    scaler: Autoscaler,
    /// The batching policy in force right now — `cfg.batch` until the
    /// adaptive controller (if any) moves it.
    policy: BatchPolicy,
    ctrl: Option<BatchController>,
    /// Everything is `paid`: skip class sampling entirely.
    single_class: bool,
    /// Normalized model count (>= 1).
    models: usize,
    /// Per-model arrival weights currently in effect.
    model_weights: Vec<f64>,
    /// Pending scripted demand migration (applied lazily at sample time).
    model_shift: Option<ModelShift>,
    /// Model each ready replica serves (the node's tag, cached).
    replica_model: BTreeMap<NodeId, u32>,
    /// Replicas mid-swap and the model they are converting to.
    swapping: BTreeMap<NodeId, u32>,
    swaps: u64,
    // counters
    offered: u64,
    admitted: u64,
    shed: u64,
    completed: u64,
    requeued: u64,
    offered_by: [u64; Priority::COUNT],
    admitted_by: [u64; Priority::COUNT],
    shed_by: [u64; Priority::COUNT],
    completed_by: [u64; Priority::COUNT],
    lat_by: [Histogram; Priority::COUNT],
    scale_ups: u64,
    scale_downs: u64,
    batches: u64,
    batched_reqs: u64,
    /// A ScaleTick is in the event queue. The control loop must stay
    /// armed while admitted work can still appear (floor repair is what
    /// guarantees "no admitted request is ever dropped").
    tick_armed: bool,
    load_end: SimTime,
    think: Option<ClosedLoop>,
    open: Option<OpenLoop>,
    sched: Option<RateSchedule>,
    last_completion: SimTime,
    trace: Vec<TickTrace>,
    obs: FlightRecorder,
    /// Burn-rate monitor over the tick-windowed p99 (observer only).
    slo: Option<SloMonitor>,
    /// Per-tick virtual-time samples (observer only).
    series: SeriesSet,
}

impl ServeWorkload<'_> {
    /// Schedule the next control tick if none is pending.
    fn arm_tick(&mut self, fleet: &mut FleetEngine) {
        if !self.tick_armed {
            self.tick_armed = true;
            let at = fleet.now() + SimTime::from_secs_f64(self.cfg.scale_interval_s);
            fleet.schedule_timer(at, TOK_TICK);
        }
    }

    fn launch_replica(&mut self, fleet: &mut FleetEngine, warm: bool, model: u32) {
        let mut spec = LaunchSpec::new(self.cfg.instance, self.cfg.spot_replicas).tagged(model);
        if warm {
            spec = spec.warm();
        }
        fleet.launch(spec);
    }

    /// Weighted index for `frac` in `[0, 1)` over `weights` (degenerate
    /// weights fall back to index 0).
    fn bucket(weights: &[f64], frac: f64) -> usize {
        let total: f64 = weights.iter().map(|w| w.max(0.0)).sum();
        if total <= 0.0 {
            return 0;
        }
        let target = frac.clamp(0.0, 1.0) * total;
        let mut cum = 0.0;
        for (i, w) in weights.iter().enumerate() {
            cum += w.max(0.0);
            if target < cum {
                return i;
            }
        }
        weights.len().saturating_sub(1)
    }

    /// Apply a scripted demand migration once its time has come.
    fn apply_model_shift(&mut self, now: SimTime) {
        let due = self
            .model_shift
            .as_ref()
            .is_some_and(|s| now.as_secs_f64() >= s.at_s && s.mix.len() == self.models);
        if due {
            self.model_weights = self.model_shift.take().expect("due").mix;
        }
    }

    /// Sample `(class, model)` for one arrival. Open-loop arrivals draw
    /// from the dedicated mix stream; a closed-loop user keeps one class
    /// and model for life (deterministic buckets over the weights), which
    /// is how real user populations behave.
    fn sample_arrival(&mut self, now: SimTime, user: Option<u64>) -> (usize, usize) {
        let class = if self.single_class {
            0
        } else {
            match (self.think, user) {
                (Some(cl), Some(u)) => {
                    Self::bucket(&self.cfg.class_mix, (u as f64 + 0.5) / cl.users.max(1) as f64)
                }
                _ => Self::bucket(&self.cfg.class_mix, self.mix_rng.next_f64()),
            }
        };
        let model = if self.models <= 1 {
            0
        } else {
            self.apply_model_shift(now);
            match user {
                // golden-ratio hash decorrelates a user's model from the
                // class bucket above
                Some(u) => Self::bucket(
                    &self.model_weights,
                    ((u as f64 + 0.5) * 0.618_033_988_749_895).fract(),
                ),
                None => Self::bucket(&self.model_weights, self.mix_rng.next_f64()),
            }
        };
        (class, model)
    }

    fn admit(&mut self, fleet: &mut FleetEngine, now: SimTime, class: usize, model: usize, user: Option<u64>) {
        self.admitted += 1;
        self.admitted_by[class] += 1;
        self.queue.push_back(Req {
            admitted_at: now,
            user,
            class: class as u8,
            model: model as u8,
        });
        // admitted work must keep the control loop alive: a late
        // arrival after the tick chain wound down still deserves
        // floor repair if a kill then strands it
        self.arm_tick(fleet);
        self.try_dispatch(fleet);
    }

    fn record_shed(&mut self, fleet: &mut FleetEngine, now: SimTime, req_class: usize, user: Option<u64>, displaced: bool) {
        self.shed += 1;
        self.shed_by[req_class] += 1;
        if self.obs.is_enabled() {
            let mut args = vec![("class", Priority::from_index(req_class).name().into())];
            if displaced {
                args.push(("displaced", 1usize.into()));
            }
            self.obs.event_at("serve.shed", now.as_nanos(), 0, 0, args);
        }
        // a shed closed-loop user retries after thinking
        if let (Some(cl), Some(u)) = (self.think, user) {
            self.schedule_user(fleet, cl, u);
        }
    }

    fn on_arrive(&mut self, fleet: &mut FleetEngine, user: Option<u64>) {
        let now = fleet.now();
        self.offered += 1;
        let (class, model) = self.sample_arrival(now, user);
        self.offered_by[class] += 1;
        if self.queue.len() >= self.cfg.queue_depth {
            // overload: shed the lowest class first — the arrival
            // displaces the youngest strictly-lower-class waiter when one
            // exists, and is shed itself otherwise
            match self.queue.evict_below(class) {
                Some(victim) => {
                    self.record_shed(fleet, now, victim.class as usize, victim.user, true);
                    self.admit(fleet, now, class, model, user);
                }
                None => self.record_shed(fleet, now, class, user, false),
            }
        } else {
            self.admit(fleet, now, class, model, user);
        }
        if let Some(gen) = self.open {
            let next = now + SimTime::from_secs_f64(gen.gap_s(&mut self.rng));
            if next <= self.load_end {
                fleet.schedule_timer(next, TOK_ARRIVE);
            }
        } else if let Some(sched) = self.sched.as_ref() {
            if let Some(next) = Self::sched_next(sched, now, &mut self.rng, self.load_end) {
                fleet.schedule_timer(next, TOK_ARRIVE);
            }
        }
    }

    /// Next arrival under a piecewise-constant schedule: an exponential
    /// gap at the rate in effect now, or a jump to the next phase start
    /// while the current rate is zero. `None` past `load_end`.
    fn sched_next(
        sched: &RateSchedule,
        now: SimTime,
        rng: &mut SimRng,
        load_end: SimTime,
    ) -> Option<SimTime> {
        let mut t = now;
        loop {
            let rate = sched.rate_at(t.as_secs_f64());
            if rate > 0.0 {
                let next = t + SimTime::from_secs_f64(rng.gen_exp(1.0 / rate));
                return (next <= load_end).then_some(next);
            }
            let change = sched.next_change_after(t.as_secs_f64())?;
            t = SimTime::from_secs_f64(change);
            if t > load_end {
                return None;
            }
        }
    }

    fn schedule_user(&mut self, fleet: &mut FleetEngine, cl: ClosedLoop, user: u64) {
        let at = fleet.now() + SimTime::from_secs_f64(cl.think_s);
        if at <= self.load_end {
            fleet.schedule_timer(at, TOK_USER0 + user);
        }
    }

    fn on_scale_tick(&mut self, fleet: &mut FleetEngine) {
        let now = fleet.now();
        self.tick_armed = false;
        let snap = self.window.snapshot_and_reset();
        let live = fleet.live_count();
        // deferred spot launches (price above the bid) are capacity
        // already committed — counting them stops the controller from
        // re-ordering the same repair every tick of a long spike
        let provisioning = fleet.provisioning_count() + fleet.deferred_count();
        let sig = ScaleSignal {
            now_s: now.as_secs_f64(),
            queue_depth: self.queue.len(),
            window_p99_s: snap.p99,
            live,
            provisioning,
        };
        // SLO + time-series observers read the tick's windowed signals
        // and never touch the engine, so a monitored run is bit-identical
        // to a bare one. Empty windows carry no latency evidence and are
        // skipped by the monitor (a drained system is not "good", just
        // silent).
        if let Some(slo) = self.slo.as_mut() {
            if snap.count > 0 {
                slo.observe(now.as_nanos(), snap.p99);
            }
        }
        if self.series.is_enabled() {
            let t = now.as_nanos();
            self.series.push("serve.window_p99_s", t, snap.p99);
            self.series.push("serve.live", t, live as f64);
            self.series.push("serve.queue_depth", t, self.queue.len() as f64);
            self.series.push("serve.completed", t, self.completed as f64);
            self.series.push("serve.shed", t, self.shed as f64);
        }
        // adaptive batching reads the same windowed p99 as the scaler; a
        // shrunk close window can make a waiting partial batch closeable
        // right now, so re-run dispatch on any change
        if let Some(ctrl) = self.ctrl.as_mut() {
            if ctrl.observe(snap.p99, snap.count) {
                self.policy = ctrl.policy();
                if self.obs.is_enabled() {
                    self.obs.event_at("serve.batch_adapt", now.as_nanos(), 0, 0, vec![
                        ("max_batch", self.policy.max_batch.into()),
                        ("max_delay_s", self.policy.max_delay_s.into()),
                        ("window_p99_s", snap.p99.into()),
                    ]);
                }
                self.try_dispatch(fleet);
            }
        }
        // swap-vs-scale: converting an idle replica toward the starved
        // model reuses hardware already on the bill, so a swap this tick
        // suppresses the scale-up the same backlog would trigger (floor
        // repair is never suppressed)
        let swapped = self.maybe_swap(fleet, now);
        match self.scaler.decide(&sig) {
            ScaleDecision::Hold => {}
            ScaleDecision::Up(n) => {
                if swapped && live + provisioning >= self.cfg.autoscaler.min_replicas {
                    // the swap IS this tick's capacity action
                } else {
                    if self.obs.is_enabled() {
                        self.obs.event_at("serve.scale_up", now.as_nanos(), 0, 0, vec![
                            ("n", n.into()),
                            ("queue_depth", sig.queue_depth.into()),
                        ]);
                    }
                    for model in self.pick_scale_models(fleet, n) {
                        self.launch_replica(fleet, false, model);
                        self.scale_ups += 1;
                    }
                }
            }
            ScaleDecision::Down(n) => {
                if self.obs.is_enabled() {
                    self.obs.event_at("serve.scale_down", now.as_nanos(), 0, 0, vec![
                        ("n", n.into()),
                    ]);
                }
                // drain the newest live replicas first (LIFO release)
                let victims: Vec<NodeId> = fleet.serving_ids().rev().take(n).collect();
                for rid in victims {
                    self.scale_downs += 1;
                    fleet.drain(rid);
                    if !self.busy.contains_key(&rid) && !self.swapping.contains_key(&rid) {
                        self.replica_model.remove(&rid);
                        fleet.release(rid);
                    } // else: exits at its batch (or swap) completion
                }
            }
        }
        if self.cfg.trace {
            self.trace.push(TickTrace {
                t_s: now.as_secs_f64(),
                live,
                provisioning,
                queue_depth: self.queue.len(),
                window_p99_s: snap.p99,
                completed: self.completed,
                shed: self.shed,
            });
        }
        // keep ticking while load is running or admitted work remains —
        // floor repair must be reachable until the system drains (arrive
        // and kill hooks re-arm if work appears after the chain winds
        // down). Exception: a price trace that never returns to the bid
        // can leave queued work with no present or future capacity — no
        // tick can repair that fleet, so ticking on would spin forever.
        let next = now + SimTime::from_secs_f64(self.cfg.scale_interval_s);
        let work_pending = !self.queue.is_empty() || !self.busy.is_empty();
        let repairable = !self.busy.is_empty()
            || fleet.live_count() + fleet.provisioning_count() + fleet.deferred_count() > 0
            || !(self.cfg.spot_replicas && fleet.capacity_gone());
        if next <= self.load_end || (work_pending && repairable) {
            self.tick_armed = true;
            fleet.schedule_timer(next, TOK_TICK);
        }
    }

    /// Capacity committed per model: serving replicas at their current
    /// model, replicas mid-swap at the model they are converting to.
    fn committed_per_model(&self, fleet: &FleetEngine) -> Vec<usize> {
        let mut committed = vec![0usize; self.models];
        for id in fleet.serving_ids() {
            let m = match self.swapping.get(&id) {
                Some(&to) => to as usize,
                None => self.replica_model.get(&id).copied().unwrap_or(0) as usize,
            };
            if m < committed.len() {
                committed[m] += 1;
            }
        }
        committed
    }

    /// Models for `n` scale-up launches: each goes to the model with the
    /// most backlog per committed replica (counting this tick's earlier
    /// picks), so capacity lands where the starvation is.
    fn pick_scale_models(&self, fleet: &FleetEngine, n: usize) -> Vec<u32> {
        if self.models <= 1 {
            return vec![0; n];
        }
        let backlog = self.queue.model_backlogs();
        let mut committed = self.committed_per_model(fleet);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut best = 0;
            let mut best_score = -1.0;
            for m in 0..self.models {
                let score = backlog[m] as f64 / (committed[m] as f64 + 1.0);
                if score > best_score {
                    best = m;
                    best_score = score;
                }
            }
            committed[best] += 1;
            out.push(best as u32);
        }
        out
    }

    /// One weight swap per tick at most: if the [`Autoscaler`] finds a
    /// justified `(donor, starved)` model pair and an idle donor replica
    /// exists, start converting it (busy for `swap_s`, `serve.swap` span
    /// in the trace). Returns whether a swap was initiated.
    fn maybe_swap(&mut self, fleet: &mut FleetEngine, now: SimTime) -> bool {
        if self.models <= 1 {
            return false;
        }
        let Some(swap) = self.cfg.swap.as_ref() else { return false };
        let backlog = self.queue.model_backlogs();
        let committed = self.committed_per_model(fleet);
        let Some((from, to)) =
            self.scaler.decide_swap(swap, now.as_secs_f64(), &backlog, &committed)
        else {
            return false;
        };
        // donor: an idle replica currently serving `from`
        let Some(rid) = fleet.serving_ids().find(|id| {
            !self.busy.contains_key(id)
                && !self.swapping.contains_key(id)
                && self.replica_model.get(id).copied().unwrap_or(0) as usize == from
        }) else {
            return false;
        };
        self.swapping.insert(rid, to as u32);
        if self.obs.is_enabled() {
            let end = now + SimTime::from_secs_f64(swap.swap_s);
            self.obs.span_at("serve.swap", now.as_nanos(), end.as_nanos(), rid, 0, vec![
                ("from", from.into()),
                ("to", to.into()),
                ("backlog", backlog[to].into()),
            ]);
        }
        fleet.add_busy(rid, swap.swap_s);
        fleet.schedule_work(rid, now + SimTime::from_secs_f64(swap.swap_s), WORK_SWAP);
        true
    }

    /// Assign closed batches to idle replicas until neither the size nor
    /// the deadline rule can close one more; schedule the deadline
    /// wake-up for a partial batch. Each replica only takes work for its
    /// own model, and a batch drains the highest class first.
    fn try_dispatch(&mut self, fleet: &mut FleetEngine) {
        let now = fleet.now();
        loop {
            if self.queue.is_empty() {
                return;
            }
            let idle: Vec<NodeId> = fleet
                .serving_ids()
                .filter(|id| !self.busy.contains_key(id) && !self.swapping.contains_key(id))
                .collect();
            let mut dispatched = false;
            let mut earliest: Option<SimTime> = None;
            for rid in idle {
                let model = self.replica_model.get(&rid).copied().unwrap_or(0) as usize;
                let depth = self.queue.model_depth(model);
                if depth == 0 {
                    continue;
                }
                let oldest = self.queue.model_oldest(model).expect("depth > 0");
                if !self.policy.should_close(depth, oldest, now) {
                    let deadline = self.policy.close_at(oldest);
                    earliest = Some(earliest.map_or(deadline, |e| e.min(deadline)));
                    continue;
                }
                let closed_by_size = depth >= self.policy.max_batch;
                let take = self.policy.take(depth);
                let batch = self.queue.drain_model(model, take);
                self.batches += 1;
                self.batched_reqs += batch.len() as u64;
                let service = self.cfg.service_base_s
                    + self.cfg.service_per_item_s * batch.len() as f64;
                if self.obs.is_enabled() {
                    let end = now + SimTime::from_secs_f64(service);
                    self.obs.span_at("serve.batch", now.as_nanos(), end.as_nanos(), rid, 0, vec![
                        ("fill", batch.len().into()),
                        ("close", if closed_by_size { "size" } else { "deadline" }.into()),
                        ("oldest_wait_s", (now.as_secs_f64() - oldest.as_secs_f64()).into()),
                    ]);
                }
                self.busy.insert(rid, batch);
                fleet.add_busy(rid, service);
                fleet.schedule_work(rid, now + SimTime::from_secs_f64(service), WORK_BATCH);
                dispatched = true;
            }
            if !dispatched {
                // partial batches only: arm the earliest deadline if it
                // beats whatever is already armed
                if let Some(deadline) = earliest {
                    let rearm = match self.deadline_at {
                        Some(d) => deadline < d,
                        None => true,
                    };
                    if rearm {
                        self.deadline_at = Some(deadline);
                        fleet.schedule_timer(deadline, TOK_DEADLINE);
                    }
                }
                return;
            }
        }
    }
}

impl FleetWorkload for ServeWorkload<'_> {
    fn on_start(&mut self, fleet: &mut FleetEngine) -> Result<()> {
        for i in 0..self.cfg.initial_replicas {
            // multi-model fleets split the initial fleet proportionally
            // to the initial arrival weights
            let model = if self.models <= 1 {
                0
            } else {
                Self::bucket(
                    &self.model_weights,
                    (i as f64 + 0.5) / self.cfg.initial_replicas.max(1) as f64,
                ) as u32
            };
            self.launch_replica(fleet, self.cfg.warm_start, model);
        }
        match self.load.take().expect("load set before run") {
            Load::Open(gen) => {
                self.open = Some(gen);
                let first = SimTime::from_secs_f64(gen.gap_s(&mut self.rng));
                if first <= self.load_end {
                    fleet.schedule_timer(first, TOK_ARRIVE);
                }
            }
            Load::Closed(cl) => {
                self.think = Some(cl);
                for u in 0..cl.users as u64 {
                    // stagger first issues across one think time
                    let at = SimTime::from_secs_f64(self.rng.next_f64() * cl.think_s.max(1e-6));
                    if at <= self.load_end {
                        fleet.schedule_timer(at, TOK_USER0 + u);
                    }
                }
            }
            Load::Scheduled(sched) => {
                if let Some(first) =
                    Self::sched_next(&sched, SimTime::ZERO, &mut self.rng, self.load_end)
                {
                    fleet.schedule_timer(first, TOK_ARRIVE);
                }
                self.sched = Some(sched);
            }
        }
        self.arm_tick(fleet);
        Ok(())
    }

    /// The scenario is over once the load horizon has passed and every
    /// admitted request has been answered: remaining events are
    /// pre-sampled tails (spot kills hours out, idle provisioning) that
    /// would otherwise bill and count activity the scenario never
    /// observed.
    fn should_stop(&mut self, _fleet: &FleetEngine, next_at: SimTime) -> bool {
        next_at > self.load_end
            && self.queue.is_empty()
            && self.busy.is_empty()
            && self.swapping.is_empty()
    }

    fn on_node_ready(&mut self, fleet: &mut FleetEngine, node: NodeId) -> Result<()> {
        let model = fleet.node(node).map(|n| n.tag()).unwrap_or(0);
        self.replica_model.insert(node, model);
        self.try_dispatch(fleet);
        Ok(())
    }

    /// Two-minute-notice path: stop feeding the replica, let the in-flight
    /// batch (or swap) finish — it requeues at the hard kill if it
    /// overruns. The engine has already drained the node and counted the
    /// preemption.
    fn on_notice(&mut self, fleet: &mut FleetEngine, node: NodeId) -> Result<()> {
        if !self.busy.contains_key(&node) && !self.swapping.contains_key(&node) {
            self.replica_model.remove(&node);
            fleet.release(node);
        }
        Ok(())
    }

    fn on_kill(&mut self, fleet: &mut FleetEngine, node: NodeId) -> Result<()> {
        if let Some(batch) = self.busy.remove(&node) {
            // in-flight work returns to the FRONT of its class lanes in
            // original order, admission timestamps intact, admission
            // limit bypassed: admitted requests are never dropped
            self.requeued += batch.len() as u64;
            self.queue.requeue_front(batch);
        }
        // a kill mid-swap abandons the conversion (the work event is
        // stale via the epoch bump)
        self.swapping.remove(&node);
        self.replica_model.remove(&node);
        if !self.queue.is_empty() {
            // stranded work needs the control loop for floor repair
            self.arm_tick(fleet);
        }
        self.try_dispatch(fleet);
        Ok(())
    }

    fn on_work_done(&mut self, fleet: &mut FleetEngine, node: NodeId, token: u64) -> Result<()> {
        if token == WORK_SWAP {
            if let Some(to) = self.swapping.remove(&node) {
                self.swaps += 1;
                self.replica_model.insert(node, to);
                fleet.retag(node, to);
                let drained = fleet.node(node).map(|n| n.is_draining()).unwrap_or(false);
                if drained {
                    // noticed or scaled down mid-swap: exit now
                    self.replica_model.remove(&node);
                    fleet.release(node);
                } else {
                    self.try_dispatch(fleet);
                }
            }
            return Ok(());
        }
        let Some(batch) = self.busy.remove(&node) else { return Ok(()) };
        let now = fleet.now();
        for req in &batch {
            let lat = now.saturating_sub(req.admitted_at).as_secs_f64();
            self.latency.record(lat);
            self.window.record(lat);
            self.lat_by[req.class as usize].record(lat);
            self.completed += 1;
            self.completed_by[req.class as usize] += 1;
            self.last_completion = now;
            if let (Some(cl), Some(u)) = (self.think, req.user) {
                self.schedule_user(fleet, cl, u);
            }
        }
        // a draining replica (spot notice / scale-down) exits after its
        // final batch
        let drained = fleet.node(node).map(|n| n.is_draining()).unwrap_or(false);
        if drained {
            self.replica_model.remove(&node);
            fleet.release(node);
        }
        self.try_dispatch(fleet);
        Ok(())
    }

    fn on_timer(&mut self, fleet: &mut FleetEngine, token: u64) -> Result<()> {
        match token {
            TOK_TICK => self.on_scale_tick(fleet),
            TOK_DEADLINE => {
                if self.deadline_at == Some(fleet.now()) {
                    self.deadline_at = None;
                    self.try_dispatch(fleet);
                }
            }
            TOK_ARRIVE => self.on_arrive(fleet, None),
            user => self.on_arrive(fleet, Some(user - TOK_USER0)),
        }
        Ok(())
    }

    fn is_done(&self, _fleet: &FleetEngine) -> bool {
        false // the run ends via `should_stop` or queue exhaustion
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::PriceTrace;

    /// Hand-calculable scenario: jitter-free provisioning, metronome
    /// arrivals, 10-second batches, one scripted instant kill mid-batch.
    fn exact_cfg() -> ServeSimConfig {
        ServeSimConfig {
            batch: BatchPolicy { max_batch: 8, max_delay_s: 0.005 },
            queue_depth: 64,
            service_base_s: 10.0,
            service_per_item_s: 0.0,
            initial_replicas: 1,
            warm_start: false,
            // only floor repair may fire: hot/cold signals are pushed out
            // of reach so the timeline stays hand-calculable
            autoscaler: AutoscalerConfig {
                min_replicas: 1,
                max_replicas: 4,
                slo_p99_s: 1e9,
                backlog_per_replica: 1e9,
                up_cooldown_s: 5.0,
                down_cooldown_s: 1e9,
                ..Default::default()
            },
            scale_interval_s: 5.0,
            provisioner: ProvisionerConfig {
                warm_cache_prob: 1.0,
                jitter: 0.0,
                ..Default::default()
            },
            storm: vec![StormEvent { at_s: 60.0, kills: 1, notice_s: 0.0 }],
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn preempted_batch_requeues_and_completes_exactly() {
        // timeline: arrivals at t=1..=5; replica 0 ready at t=55
        // (45 boot + 8 warm pull + 2 mount, jitter 0); batch of 5 starts at
        // 55, would finish at 65; instant kill at 60 requeues all 5; floor
        // repair at the t=60 tick launches replica 1, ready at 115; the
        // redone batch completes at 125. Nothing is lost.
        let mut sim = ServeSim::new(exact_cfg());
        let r = sim.run(Load::Open(OpenLoop::metronome(1.0)), 5.0).unwrap();
        assert_eq!(r.offered, 5);
        assert_eq!(r.admitted, 5);
        assert_eq!(r.shed, 0);
        assert_eq!(r.completed, 5, "zero dropped despite the mid-batch kill");
        assert_eq!(r.requeued, 5, "whole in-flight batch came back");
        assert_eq!(r.preemptions, 1);
        assert_eq!(r.replicas_launched, 2, "initial + floor repair");
        assert_eq!(r.final_live, 1);
        assert!((r.makespan_s - 125.0).abs() < 1e-6, "makespan {}", r.makespan_s);
        // the oldest request (t=1) waited the whole saga: 124 s
        assert!((r.latency.max - 124.0).abs() < 1e-6, "max latency {}", r.latency.max);
        assert_eq!(r.latency.count, 5);
    }

    #[test]
    fn graceful_notice_lets_batch_finish_without_requeue() {
        // same scenario, but a 120 s notice instead of an instant kill:
        // the batch (55 → 65) finishes inside the notice window, the
        // replica drains, and nothing requeues
        let mut cfg = exact_cfg();
        cfg.storm = vec![StormEvent { at_s: 60.0, kills: 1, notice_s: 120.0 }];
        let mut sim = ServeSim::new(cfg);
        let r = sim.run(Load::Open(OpenLoop::metronome(1.0)), 5.0).unwrap();
        assert_eq!(r.completed, 5);
        assert_eq!(r.requeued, 0, "graceful drain: in-flight batch finished");
        assert_eq!(r.preemptions, 1);
        assert!((r.makespan_s - 65.0).abs() < 1e-6, "makespan {}", r.makespan_s);
    }

    fn storm_cfg() -> ServeSimConfig {
        ServeSimConfig {
            batch: BatchPolicy { max_batch: 8, max_delay_s: 0.005 },
            queue_depth: 128,
            service_base_s: 0.002,
            service_per_item_s: 0.001,
            initial_replicas: 8,
            warm_start: true,
            autoscaler: AutoscalerConfig {
                min_replicas: 2,
                max_replicas: 16,
                slo_p99_s: 0.25,
                up_step: 2,
                up_cooldown_s: 10.0,
                down_cooldown_s: 1e9, // storms only; no cold bleed
                ..Default::default()
            },
            scale_interval_s: 5.0,
            storm: vec![StormEvent { at_s: 60.0, kills: 7, notice_s: 0.0 }],
            seed: 42,
            ..Default::default()
        }
    }

    /// ISSUE 2 acceptance: the autoscaler holds the p99 SLO through a
    /// scripted preemption storm with zero dropped (non-shed) requests.
    #[test]
    fn autoscaler_holds_slo_through_preemption_storm() {
        let mut sim = ServeSim::new(storm_cfg());
        let r = sim.run(Load::Open(OpenLoop::poisson(1200.0)), 180.0).unwrap();
        assert_eq!(r.preemptions, 7, "the storm reclaimed 7 of 8 replicas");
        assert_eq!(
            r.completed, r.admitted,
            "zero dropped: every admitted request was answered ({r:?})"
        );
        assert!(
            r.latency.p99 <= 0.25,
            "p99 {}s blew the 0.25s SLO (shedding + scale-up must bound waits)",
            r.latency.p99
        );
        assert!(r.shed > 0, "overload during the capacity gap must shed, not queue");
        assert!(r.scale_ups > 0, "the autoscaler reacted to the storm");
        assert!(
            r.offered > 200_000,
            "open loop kept offering through the storm: {}",
            r.offered
        );
        // batching actually happened under load
        assert!(r.mean_batch_fill > 1.5, "mean fill {}", r.mean_batch_fill);
        // the storm fired at its scripted engine-start time
        assert_eq!(sim.fleet_stats().storms_fired_at_s, vec![60.0]);
    }

    #[test]
    fn storm_run_is_deterministic() {
        let run = || {
            let mut cfg = storm_cfg();
            cfg.trace = true;
            ServeSim::new(cfg).run(Load::Open(OpenLoop::poisson(1200.0)), 60.0).unwrap()
        };
        assert_eq!(run(), run(), "same seed, bit-identical report");
    }

    /// The flight recorder is a pure observer: attaching it must not
    /// move a single event, and the batch spans it captures must agree
    /// with the report's own counters.
    #[test]
    fn obs_does_not_perturb_the_run_and_batch_spans_are_well_formed() {
        use crate::obs::{FlightRecorder, RecordKind};

        let bare = ServeSim::new(storm_cfg())
            .run(Load::Open(OpenLoop::poisson(1200.0)), 60.0)
            .unwrap();

        let rec = FlightRecorder::sim(1 << 20, crate::sim::SimClock::new());
        let mut sim = ServeSim::new(storm_cfg());
        sim.set_obs(rec.clone());
        let traced = sim.run(Load::Open(OpenLoop::poisson(1200.0)), 60.0).unwrap();
        assert_eq!(bare, traced, "recording must not perturb the timeline");

        let records = rec.snapshot();
        assert_eq!(rec.dropped(), 0, "capacity sized to hold the whole run");
        let batches: Vec<_> =
            records.iter().filter(|r| r.name == "serve.batch").collect();
        assert!(!batches.is_empty());
        let mut fill_sum = 0;
        for b in &batches {
            assert!(matches!(b.kind, RecordKind::Span { .. }));
            assert!(b.end_ns() > b.ts_ns, "a batch always takes service time");
            let close = b.arg("close").expect("close reason").to_string();
            assert!(close == "size" || close == "deadline", "close={close}");
            fill_sum += b.arg("fill").and_then(|a| a.as_u64()).expect("fill");
        }
        // every admitted request is batched exactly once per dispatch;
        // requeued requests are dispatched again after the kill
        assert_eq!(fill_sum, traced.completed + traced.requeued);
        // the storm's seven reclaimed replicas all left kill records
        let kills = records.iter().filter(|r| r.name == "node.kill").count();
        assert_eq!(kills as u64, traced.preemptions);
        let sheds = records.iter().filter(|r| r.name == "serve.shed").count();
        assert_eq!(sheds as u64, traced.shed);
    }

    #[test]
    fn closed_loop_is_self_limiting() {
        let mut cfg = storm_cfg();
        cfg.storm = vec![];
        cfg.initial_replicas = 4;
        let cl = ClosedLoop { users: 64, think_s: 0.05 };
        let mut sim = ServeSim::new(cfg);
        let r = sim.run(Load::Closed(cl), 30.0).unwrap();
        assert_eq!(r.completed, r.admitted);
        assert_eq!(r.shed, 0, "64 users can never exceed a 128-deep queue");
        assert!(r.completed > 5_000, "completed {}", r.completed);
        // closed-loop law: throughput <= users / think
        assert!(
            r.throughput_rps <= cl.max_throughput_rps(0.0) * 1.01,
            "throughput {} exceeds the closed-loop bound",
            r.throughput_rps
        );
    }

    #[test]
    fn cold_autoscaler_drains_to_min() {
        let mut cfg = storm_cfg();
        cfg.storm = vec![];
        cfg.autoscaler.down_cooldown_s = 10.0;
        cfg.autoscaler.min_replicas = 2;
        let mut sim = ServeSim::new(cfg);
        // 100 rps against 8 replicas: cold from the first window
        let r = sim.run(Load::Open(OpenLoop::poisson(100.0)), 180.0).unwrap();
        assert_eq!(r.completed, r.admitted);
        assert_eq!(r.shed, 0);
        assert!(r.scale_downs > 0, "idle fleet must shrink");
        assert_eq!(r.final_live, 2, "drained to the floor: {r:?}");
        assert!(r.latency.p99 < 0.25, "scale-down must not break the SLO");
    }

    #[test]
    fn scheduled_flash_crowd_sheds_then_recovers() {
        let mut cfg = storm_cfg();
        cfg.storm = vec![];
        cfg.initial_replicas = 2; // 1600 req/s of capacity
        let sched =
            RateSchedule::new(vec![(0.0, 200.0), (30.0, 4000.0), (60.0, 200.0)]);
        let mut sim = ServeSim::new(cfg);
        let r = sim.run(Load::Scheduled(sched), 90.0).unwrap();
        assert_eq!(r.completed, r.admitted, "the crowd never drops admitted work");
        assert!(r.shed > 0, "a 4000 req/s crowd against 1600 req/s must shed: {r:?}");
        assert!(r.offered > 100_000, "offered {}", r.offered);
        assert!(r.scale_ups > 0, "the backlog during the crowd triggers scale-up");
    }

    #[test]
    fn background_spot_market_preempts_and_recovers() {
        let mut cfg = storm_cfg();
        cfg.storm = vec![];
        cfg.initial_replicas = 4;
        // floor at 3 so replica loss reliably dips below the minimum and
        // exercises floor repair regardless of which replicas the market
        // happens to reclaim first
        cfg.autoscaler.min_replicas = 3;
        // vicious market: mean 40 s to preemption, 10 s notice
        cfg.spot_market =
            Some(SpotMarketConfig { mean_ttp_s: 40.0, notice_s: 10.0 });
        let mut sim = ServeSim::new(cfg);
        let r = sim.run(Load::Open(OpenLoop::poisson(400.0)), 120.0).unwrap();
        assert!(r.preemptions > 0, "market this hostile must preempt: {r:?}");
        assert_eq!(r.completed, r.admitted, "churn never drops admitted work");
        assert!(r.replicas_launched > 4, "floor repair replaced lost replicas");
    }

    #[test]
    fn price_spike_reclaims_the_fleet_and_recovery_restores_it() {
        // traced price above a 0.10 bid over [30, 90): the whole fleet is
        // noticed at the crossing and killed 5 s later; floor repair's
        // replacement launches defer to t=90 — yet every admitted request
        // is still answered after the recovery
        let mut cfg = storm_cfg();
        cfg.storm = vec![];
        cfg.initial_replicas = 4;
        cfg.autoscaler.min_replicas = 2;
        let trace =
            PriceTrace::new(vec![(0.0, 0.05), (30.0, 0.90), (90.0, 0.06)]).unwrap();
        cfg.price_trace =
            Some(PriceTraceConfig { trace, bid_usd: 0.10, notice_s: 5.0 });
        let mut sim = ServeSim::new(cfg);
        let r = sim.run(Load::Open(OpenLoop::poisson(300.0)), 150.0).unwrap();
        assert_eq!(r.preemptions, 4, "every replica hit the price crossing: {r:?}");
        assert_eq!(r.completed, r.admitted, "zero dropped through the spike");
        assert!(
            sim.fleet_stats().launches_deferred >= 1,
            "mid-spike repairs deferred to the recovery: {:?}",
            sim.fleet_stats()
        );
        assert!(r.replicas_launched > 4, "the fleet was rebuilt after the spike");
        assert!(r.makespan_s > 90.0, "completions resumed after the recovery");
    }

    /// ISSUE 9 acceptance: the SLO monitor pages from the trace alone —
    /// `slo.breach` lands inside the storm's capacity gap, `slo.recover`
    /// only after replacement capacity refills the fleet, and the
    /// transitions strictly alternate.
    #[test]
    fn slo_monitor_pages_inside_the_storm_and_recovers_after_refill() {
        use crate::obs::FlightRecorder;

        let mut cfg = storm_cfg();
        // pre-storm windows sit well under 0.1 s; the post-storm
        // single-replica overload pushes the window p99 to ~0.16 s
        cfg.slo = Some(SloSpec::new("serve.window_p99_s", 0.1, 60.0));
        let rec = FlightRecorder::sim(1 << 20, crate::sim::SimClock::new());
        let mut sim = ServeSim::new(cfg);
        sim.set_obs(rec.clone());
        let r = sim.run(Load::Open(OpenLoop::poisson(1200.0)), 180.0).unwrap();
        assert_eq!(r.completed, r.admitted, "monitoring must not drop work");

        let records = rec.snapshot();
        let transitions: Vec<_> = records
            .iter()
            .filter(|x| x.name == "slo.breach" || x.name == "slo.recover")
            .collect();
        assert!(!transitions.is_empty(), "a 7-of-8 storm must page");
        assert_eq!(transitions[0].name, "slo.breach", "the page opens the incident");
        let breach_s = transitions[0].ts_ns as f64 / 1e9;
        assert!(
            (60.0..=80.0).contains(&breach_s),
            "first page inside the storm window, got t={breach_s}"
        );
        assert_eq!(
            transitions[0].arg("metric").unwrap().as_str(),
            Some("serve.window_p99_s")
        );
        assert!(transitions[0].arg("burn_short").unwrap().as_f64().unwrap() >= 2.0);
        let last = transitions.last().unwrap();
        assert_eq!(last.name, "slo.recover", "the refilled fleet clears the page");
        let recover_s = last.ts_ns as f64 / 1e9;
        assert!(
            recover_s > breach_s + 10.0,
            "recovery waits for replacement capacity, got t={recover_s}"
        );
        for pair in transitions.windows(2) {
            assert_ne!(pair[0].name, pair[1].name, "transitions strictly alternate");
        }
    }

    #[test]
    fn tick_series_capture_the_storm_for_the_windowed_reducers() {
        let mut sim = ServeSim::new(storm_cfg());
        let set = SeriesSet::new(4096);
        sim.set_series(set.clone());
        let r = sim.run(Load::Open(OpenLoop::poisson(1200.0)), 120.0).unwrap();
        assert_eq!(r.completed, r.admitted);

        let live = set.get("serve.live").expect("live series");
        assert!(!live.is_empty());
        // the storm knocks the live count below the starting 8...
        assert!(live.samples().iter().any(|(_, v)| *v < 8.0), "{:?}", live.samples());
        // ...and the capacity gap shows up in the p99 series
        let p99 = set.get("serve.window_p99_s").expect("p99 series");
        assert!(p99.percentile(1.0, u64::MAX).unwrap() > 0.1);
        // completions are cumulative: the windowed rate is a goodput
        let rate = set.get("serve.completed").unwrap().rate_per_s(u64::MAX).unwrap();
        assert!(rate > 0.0, "goodput rate {rate}");
        assert!(set.names().contains(&"serve.queue_depth".to_string()));
    }

    /// ISSUE 9 acceptance: `obs::analyze` reconciles the storm scenario
    /// exactly — per-node category times partition the billed lifetime,
    /// and attributed + wasted spend equals the engine's own ledger.
    #[test]
    fn analyzer_reconciles_storm_costs_and_node_partitions() {
        use crate::obs::analyze::analyze;
        use crate::obs::FlightRecorder;

        let rec = FlightRecorder::sim(1 << 20, crate::sim::SimClock::new());
        let mut sim = ServeSim::new(storm_cfg());
        sim.set_obs(rec.clone());
        let r = sim.run(Load::Open(OpenLoop::poisson(1200.0)), 60.0).unwrap();
        assert_eq!(rec.dropped(), 0, "the whole run fits the recorder");

        let a = analyze(&rec.snapshot());
        assert!(a.nodes.len() >= 8, "every replica surfaced: {}", a.nodes.len());
        for n in &a.nodes {
            assert_eq!(
                n.provisioning_ns + n.busy_ns + n.drain_ns + n.idle_ns,
                n.lifetime_ns,
                "node {}: category times must partition the billed lifetime",
                n.pid
            );
        }
        // the analyzer's cost model reconciles against the engine ledger
        let tol = 1e-9 * r.cost_usd.max(1.0);
        assert!(
            (a.total_usd - r.cost_usd).abs() <= tol,
            "trace-derived ${} vs ledger ${}",
            a.total_usd,
            r.cost_usd
        );
        assert!((a.attributed_usd + a.wasted_usd - a.total_usd).abs() <= tol);
        assert!(
            a.wasted_frac() > 0.0 && a.wasted_frac() < 1.0,
            "a storm both wastes and uses spend: {}",
            a.wasted_frac()
        );
        // event counters agree with the report
        assert_eq!(a.sheds, r.shed);
        assert_eq!(a.storms, 1);
        assert!(a.queue_wait_max_s > 0.0, "overload shows up in batch waits");
    }

    /// ISSUE 10 tentpole (priority classes): a 2.5x-over-capacity flood
    /// with a 20/40/40 paid/free/batch mix sheds thousands of best-effort
    /// requests while the paid tier loses nothing and keeps its SLO.
    #[test]
    fn priority_classes_protect_paid_through_overload() {
        let mut cfg = storm_cfg();
        cfg.storm = vec![];
        cfg.initial_replicas = 2; // 1600 req/s of capacity, pinned
        cfg.autoscaler.min_replicas = 2;
        cfg.autoscaler.max_replicas = 2;
        cfg.class_mix = [0.2, 0.4, 0.4]; // paid alone is 800 req/s
        let mut sim = ServeSim::new(cfg);
        let r = sim.run(Load::Open(OpenLoop::poisson(4000.0)), 30.0).unwrap();

        // conservation: displacement sheds previously-admitted requests,
        // so the clean global invariant is offered = completed + shed
        assert_eq!(r.completed, r.offered - r.shed, "{r:?}");
        assert!(r.shed > 10_000, "2.5x overload must shed heavily: {}", r.shed);
        // per-class accounting partitions the totals exactly
        assert_eq!(r.per_class.len(), 3);
        assert_eq!(r.per_class.iter().map(|c| c.offered).sum::<u64>(), r.offered);
        assert_eq!(r.per_class.iter().map(|c| c.shed).sum::<u64>(), r.shed);
        assert_eq!(r.per_class.iter().map(|c| c.completed).sum::<u64>(), r.completed);
        let paid = &r.per_class[0];
        let best_effort = &r.per_class[2];
        assert_eq!(paid.class, "paid");
        assert_eq!(paid.shed, 0, "paid is never shed while lower classes wait: {r:?}");
        assert_eq!(paid.completed, paid.admitted, "every paid request answered");
        assert!(
            paid.latency.p99 <= 0.25,
            "queue-jump holds the paid p99 through overload: {}",
            paid.latency.p99
        );
        assert!(best_effort.shed > 0, "the batch tier absorbs the shedding");
        // shed concentrates at the bottom of the priority order
        assert!(best_effort.shed > r.per_class[1].shed / 4, "{r:?}");
    }

    /// ISSUE 10 tentpole (adaptive batching): against the same 60 req/s
    /// trickle, a 50 ms fixed window pins the p99 at ~52 ms while the
    /// controller shrinks to its stable 25 ms point and roughly halves
    /// the tail — without giving up batching entirely.
    #[test]
    fn adaptive_window_beats_an_oversized_fixed_window() {
        let base = || {
            let mut cfg = storm_cfg();
            cfg.storm = vec![];
            cfg.batch = BatchPolicy { max_batch: 16, max_delay_s: 0.05 };
            cfg.service_per_item_s = 0.0001;
            cfg.initial_replicas = 1;
            cfg.autoscaler.min_replicas = 1;
            cfg.autoscaler.max_replicas = 1;
            cfg
        };
        let mut fixed_cfg = base();
        fixed_cfg.trace = false;
        let fixed = ServeSim::new(fixed_cfg)
            .run(Load::Open(OpenLoop::poisson(60.0)), 600.0)
            .unwrap();

        let mut adaptive_cfg = base();
        adaptive_cfg.adaptive = Some(AdaptiveBatchConfig {
            slo_p99_s: 0.06,
            min_delay_s: 0.01,
            max_delay_s: 0.05,
            min_batch: 4,
            max_batch: 16,
            ..Default::default()
        });
        let adaptive = ServeSim::new(adaptive_cfg)
            .run(Load::Open(OpenLoop::poisson(60.0)), 600.0)
            .unwrap();

        assert_eq!(fixed.completed, fixed.admitted);
        assert_eq!(adaptive.completed, adaptive.admitted);
        assert_eq!(adaptive.shed, 0);
        assert!(
            adaptive.latency.p99 < fixed.latency.p99 * 0.75,
            "shrunk window must cut the tail: adaptive {} vs fixed {}",
            adaptive.latency.p99,
            fixed.latency.p99
        );
        assert!(
            adaptive.mean_batch_fill > 1.0,
            "the controller narrows the window without abandoning batching: {}",
            adaptive.mean_batch_fill
        );
    }

    /// ISSUE 10 tentpole (weight swap): demand migrates wholly from model
    /// 0 to model 1 at t=60. Swapping converts the idle fleet within a
    /// few ticks and suppresses scale-ups; always-scale instead buys new
    /// replicas that spend a minute provisioning while paid-for hardware
    /// idles — more sheds and a strictly larger bill on the same trace.
    #[test]
    fn weight_swap_follows_demand_and_beats_always_scaling() {
        let base = || {
            let mut cfg = storm_cfg();
            cfg.storm = vec![];
            cfg.initial_replicas = 4;
            cfg.models = 2;
            cfg.model_mix = vec![1.0, 0.0];
            cfg.model_shift = Some(ModelShift { at_s: 60.0, mix: vec![0.0, 1.0] });
            cfg
        };
        let mut swap_cfg = base();
        swap_cfg.swap = Some(SwapConfig { swap_s: 10.0, ..Default::default() });
        let swap_run = ServeSim::new(swap_cfg)
            .run(Load::Open(OpenLoop::poisson(400.0)), 150.0)
            .unwrap();

        let scale_run = ServeSim::new(base())
            .run(Load::Open(OpenLoop::poisson(400.0)), 150.0)
            .unwrap();

        assert_eq!(swap_run.completed, swap_run.offered - swap_run.shed);
        assert_eq!(scale_run.completed, scale_run.offered - scale_run.shed);
        assert!(swap_run.swaps >= 2, "the fleet converts toward demand: {swap_run:?}");
        assert_eq!(
            swap_run.scale_ups, 0,
            "swaps absorb the migration; no new hardware: {swap_run:?}"
        );
        assert_eq!(scale_run.swaps, 0);
        assert!(scale_run.scale_ups > 0, "always-scale must buy replicas: {scale_run:?}");
        assert!(
            swap_run.cost_usd < scale_run.cost_usd,
            "converting idle replicas must be cheaper: swap ${} vs scale ${}",
            swap_run.cost_usd,
            scale_run.cost_usd
        );
        assert!(
            swap_run.shed < scale_run.shed,
            "a 10 s swap closes the capacity gap faster than a cold boot: {} vs {}",
            swap_run.shed,
            scale_run.shed
        );
    }

    /// Every hot-path feature at once stays bit-deterministic, and the
    /// recorder stays a pure observer of the new event types (shed class
    /// args, batch_adapt, swap spans, retag).
    #[test]
    fn hotpath_features_are_deterministic_and_unperturbed_by_obs() {
        use crate::obs::FlightRecorder;

        let cfg = || {
            let mut cfg = storm_cfg();
            cfg.class_mix = [0.3, 0.4, 0.3];
            cfg.models = 2;
            cfg.model_mix = vec![0.7, 0.3];
            cfg.model_shift = Some(ModelShift { at_s: 45.0, mix: vec![0.2, 0.8] });
            cfg.swap = Some(SwapConfig::default());
            cfg.adaptive = Some(AdaptiveBatchConfig::default());
            cfg.trace = true;
            cfg
        };
        // the crowd (55-75 s) straddles the 7-of-8 storm at t=60, so the
        // lone survivor faces 4x traffic: sheds are guaranteed
        let load = || Load::Scheduled(RateSchedule::flash_crowd(600.0, 4.0, 55.0, 20.0));
        let bare = ServeSim::new(cfg()).run(load(), 90.0).unwrap();
        let again = ServeSim::new(cfg()).run(load(), 90.0).unwrap();
        assert_eq!(bare, again, "same seed, bit-identical hot-path report");

        let rec = FlightRecorder::sim(1 << 20, crate::sim::SimClock::new());
        let mut sim = ServeSim::new(cfg());
        sim.set_obs(rec.clone());
        let traced = sim.run(load(), 90.0).unwrap();
        assert_eq!(bare, traced, "recording must not perturb the hot path");
        assert_eq!(traced.completed, traced.offered - traced.shed);

        let records = rec.snapshot();
        let sheds = records.iter().filter(|r| r.name == "serve.shed").count();
        assert_eq!(sheds as u64, traced.shed, "one shed event per shed, classes tagged");
        // the 7-of-8 storm lands mid-crowd: preempted mixed-class batches
        // requeue and still complete
        assert!(traced.requeued > 0, "{traced:?}");
        assert!(
            records.iter().any(|r| r.name == "serve.shed" && r.arg("class").is_some()),
            "shed events carry the priority class"
        );
    }
}
