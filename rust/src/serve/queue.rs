//! [`BoundedQueue`]: the serving layer's bounded MPMC request queue with
//! admission control and deadline-based batch collection.
//!
//! Overload policy is *reject at the door*: once `capacity` requests are
//! waiting, new arrivals are shed immediately (the caller sees
//! [`crate::Error::Shed`]) instead of queueing into latencies no client
//! would wait out. Everything admitted is eventually served — requeues
//! from preempted replicas re-enter at the *front*, above the admission
//! limit, because dropping admitted work is the one thing the layer must
//! never do.
//!
//! [`BoundedQueue::next_batch`] is the dynamic batcher's collection
//! primitive for real-time (threaded) serving: it blocks until work
//! exists, then closes a batch on `max_batch` OR a deadline, whichever
//! comes first.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer / multi-consumer queue.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    /// An empty queue admitting at most `capacity` waiting items.
    ///
    /// # Panics
    /// If `capacity` is zero.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "queue capacity must be positive");
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// The admission limit.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Items currently waiting (may exceed `capacity` after requeues).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// True when nothing is waiting.
    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().items.is_empty()
    }

    /// Admission-controlled enqueue: `Err(item)` hands the item back when
    /// the queue is at capacity (shed) or closed, without blocking.
    pub fn offer(&self, item: T) -> Result<(), T> {
        let mut q = self.inner.lock().unwrap();
        if q.closed || q.items.len() >= self.capacity {
            return Err(item);
        }
        q.items.push_back(item);
        drop(q);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Requeue path for preempted in-flight work: re-enters at the front
    /// (oldest first) and bypasses the admission limit — admitted requests
    /// are never dropped, even if a preemption lands while the queue is
    /// full. `items` must be in original queue order.
    pub fn requeue_front(&self, items: Vec<T>) {
        if items.is_empty() {
            return;
        }
        let mut q = self.inner.lock().unwrap();
        for item in items.into_iter().rev() {
            q.items.push_front(item);
        }
        drop(q);
        self.not_empty.notify_all();
    }

    /// Collect the next batch: blocks until at least one item exists, then
    /// waits up to `max_wait` (from the moment the batch opened) for it to
    /// fill to `max_batch`. Whichever limit trips first closes the batch.
    /// Returns `None` once the queue is closed *and* drained. Under
    /// collector contention a racing drain can leave a batch empty —
    /// callers skip those rather than treating them as work.
    pub fn next_batch(&self, max_batch: usize, max_wait: Duration) -> Option<Vec<T>> {
        let max_batch = max_batch.max(1);
        let mut q = self.inner.lock().unwrap();
        // phase 1: wait for the first item (or shutdown)
        loop {
            if !q.items.is_empty() {
                break;
            }
            if q.closed {
                return None;
            }
            q = self.not_empty.wait(q).unwrap();
        }
        // phase 2: batch window opens now; fill until size or deadline
        let deadline = Instant::now() + max_wait;
        while q.items.len() < max_batch && !q.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) = self.not_empty.wait_timeout(q, deadline - now).unwrap();
            q = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let n = q.items.len().min(max_batch);
        Some(q.items.drain(..n).collect())
    }

    /// Shut the queue: rejects new offers and wakes all collectors, which
    /// drain remaining items and then observe `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;

    #[test]
    fn offer_sheds_at_capacity() {
        let q = BoundedQueue::new(2);
        assert!(q.offer(1).is_ok());
        assert!(q.offer(2).is_ok());
        assert_eq!(q.offer(3), Err(3), "third is shed with the item back");
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn full_batch_closes_without_waiting() {
        let q = BoundedQueue::new(64);
        for i in 0..10 {
            q.offer(i).unwrap();
        }
        let t0 = Instant::now();
        // long deadline: must return immediately because size trips first
        let b = q.next_batch(4, Duration::from_secs(30)).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(5), "size-close must not wait");
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn deadline_closes_partial_batch() {
        let q = BoundedQueue::new(64);
        q.offer(7).unwrap();
        let t0 = Instant::now();
        let b = q.next_batch(16, Duration::from_millis(30)).unwrap();
        assert_eq!(b, vec![7], "partial batch after the window");
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(25), "waited {waited:?}");
        assert!(waited < Duration::from_secs(5));
    }

    #[test]
    fn requeue_front_preserves_order_and_ignores_capacity() {
        let q = BoundedQueue::new(2);
        q.offer(10).unwrap();
        q.offer(11).unwrap();
        // a preempted batch [1, 2] returns; queue already full
        q.requeue_front(vec![1, 2]);
        assert_eq!(q.len(), 4);
        let b = q.next_batch(4, Duration::from_millis(1)).unwrap();
        assert_eq!(b, vec![1, 2, 10, 11], "requeued work is oldest, in order");
    }

    #[test]
    fn close_drains_then_ends() {
        let q = Arc::new(BoundedQueue::new(8));
        q.offer(1).unwrap();
        q.close();
        assert_eq!(q.offer(2), Err(2), "closed queue rejects offers");
        assert_eq!(q.next_batch(4, Duration::from_millis(1)), Some(vec![1]));
        assert_eq!(q.next_batch(4, Duration::from_millis(1)), None);
    }

    #[test]
    fn close_wakes_blocked_collector() {
        let q = Arc::new(BoundedQueue::<u32>::new(8));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.next_batch(4, Duration::from_secs(60)));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None, "blocked collector observes shutdown");
    }

    #[test]
    fn concurrent_producers_and_collectors_lose_nothing() {
        let q = Arc::new(BoundedQueue::new(100_000));
        let producers = 4;
        let per = 1000;
        std::thread::scope(|s| {
            for p in 0..producers {
                let q = q.clone();
                s.spawn(move || {
                    for i in 0..per {
                        q.offer(p * per + i).unwrap();
                    }
                });
            }
            let mut seen = Vec::new();
            while seen.len() < producers * per {
                if let Some(b) = q.next_batch(64, Duration::from_millis(5)) {
                    seen.extend(b);
                }
            }
            seen.sort();
            assert_eq!(seen, (0..producers * per).collect::<Vec<_>>());
        });
    }
}
