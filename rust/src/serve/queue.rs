//! [`BoundedQueue`]: the serving layer's bounded MPMC request queue with
//! admission control, priority classes, and deadline-based batch
//! collection.
//!
//! Overload policy is *shed the lowest class first*: once `capacity`
//! requests are waiting, a new arrival either displaces the youngest
//! waiter of a strictly lower [`Priority`] class (preemptive shedding —
//! the caller answers the victim with [`crate::Error::Shed`]) or, when no
//! lower class is waiting, is shed itself instead of queueing into
//! latencies no client would wait out. Dispatch queue-jumps: a batch
//! drains `paid` before `free` before `batch`, FIFO within a class.
//! Everything admitted and not displaced is eventually served — requeues
//! from preempted replicas re-enter at the *front of their own class
//! lane*, above the admission limit, because dropping admitted work is
//! the one thing the layer must never do.
//!
//! [`BoundedQueue::next_batch`] is the dynamic batcher's collection
//! primitive for real-time (threaded) serving: it blocks until work
//! exists, then closes a batch on `max_batch` OR a deadline, whichever
//! comes first.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Request priority class; lower index is more important.
///
/// Shed-at-admission drops the lowest class first, dispatch drains the
/// highest class first. The names mirror the classic serving tiers: paid
/// interactive traffic, free interactive traffic, and offline batch
/// traffic that tolerates arbitrary delay.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Paid interactive tier: protected, shed last.
    Paid,
    /// Free interactive tier: shed before paid.
    Free,
    /// Offline/batch tier: best-effort, shed first.
    Batch,
}

impl Priority {
    /// Number of classes.
    pub const COUNT: usize = 3;
    /// All classes, most important first.
    pub const ALL: [Priority; Priority::COUNT] = [Priority::Paid, Priority::Free, Priority::Batch];

    /// Lane index (0 = most important).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Class for a lane index; out-of-range clamps to the last class.
    pub fn from_index(i: usize) -> Self {
        *Priority::ALL.get(i).unwrap_or(&Priority::Batch)
    }

    /// Stable lowercase label for metrics and trace args.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Paid => "paid",
            Priority::Free => "free",
            Priority::Batch => "batch",
        }
    }
}

/// Outcome of a successful priority admission.
#[derive(Debug, PartialEq)]
pub enum Admit<T> {
    /// Room existed; nothing was displaced.
    Queued,
    /// The queue was full: the youngest waiter of the lowest class below
    /// the arrival was shed to make room. The caller owns the victim and
    /// must answer it (typically with [`crate::Error::Shed`]).
    Displaced(T),
}

struct Inner<T> {
    lanes: [VecDeque<T>; Priority::COUNT],
    len: usize,
    closed: bool,
}

/// Bounded multi-producer / multi-consumer priority queue.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    /// An empty queue admitting at most `capacity` waiting items.
    ///
    /// # Panics
    /// If `capacity` is zero.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "queue capacity must be positive");
        Self {
            inner: Mutex::new(Inner {
                lanes: std::array::from_fn(|_| VecDeque::new()),
                len: 0,
                closed: false,
            }),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// The admission limit.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Items currently waiting (may exceed `capacity` after requeues).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    /// True when nothing is waiting.
    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().len == 0
    }

    /// Single-class enqueue at [`Priority::Paid`]: `Err(item)` hands the
    /// item back when the queue is at capacity (shed) or closed, without
    /// blocking and without displacing anyone.
    pub fn offer(&self, item: T) -> Result<(), T> {
        let mut q = self.inner.lock().unwrap();
        if q.closed || q.len >= self.capacity {
            return Err(item);
        }
        q.lanes[0].push_back(item);
        q.len += 1;
        drop(q);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Priority admission. With room, the item joins its class lane
    /// ([`Admit::Queued`]). At capacity, the youngest waiter of the
    /// lowest class *strictly below* `class` gives up its slot
    /// ([`Admit::Displaced`]); when no such waiter exists the arrival is
    /// the cheapest thing to shed and comes back as `Err(item)`.
    pub fn offer_at(&self, item: T, class: Priority) -> Result<Admit<T>, T> {
        let mut q = self.inner.lock().unwrap();
        if q.closed {
            return Err(item);
        }
        if q.len < self.capacity {
            q.lanes[class.index()].push_back(item);
            q.len += 1;
            drop(q);
            self.not_empty.notify_one();
            return Ok(Admit::Queued);
        }
        let victim = ((class.index() + 1)..Priority::COUNT)
            .rev()
            .find_map(|c| q.lanes[c].pop_back());
        match victim {
            Some(v) => {
                // one out, one in: len is unchanged
                q.lanes[class.index()].push_back(item);
                drop(q);
                self.not_empty.notify_one();
                Ok(Admit::Displaced(v))
            }
            None => Err(item),
        }
    }

    /// Requeue path for preempted in-flight work: re-enters at the front
    /// of the [`Priority::Paid`] lane (oldest first) and bypasses the
    /// admission limit — admitted requests are never dropped, even if a
    /// preemption lands while the queue is full. `items` must be in
    /// original queue order. Mixed-class batches use
    /// [`BoundedQueue::requeue_front_at`].
    pub fn requeue_front(&self, items: Vec<T>) {
        self.requeue_front_at(items.into_iter().map(|i| (Priority::Paid, i)).collect());
    }

    /// Mixed-class requeue: each item re-enters at the front of *its own*
    /// class lane, preserving both class and admission order — restored
    /// work dispatches before later same-class arrivals and still never
    /// jumps a higher class. Bypasses the admission limit like
    /// [`BoundedQueue::requeue_front`].
    pub fn requeue_front_at(&self, items: Vec<(Priority, T)>) {
        if items.is_empty() {
            return;
        }
        let mut q = self.inner.lock().unwrap();
        q.len += items.len();
        for (class, item) in items.into_iter().rev() {
            q.lanes[class.index()].push_front(item);
        }
        drop(q);
        self.not_empty.notify_all();
    }

    /// Collect the next batch: blocks until at least one item exists, then
    /// waits up to `max_wait` (from the moment the batch opened) for it to
    /// fill to `max_batch`. Whichever limit trips first closes the batch,
    /// which drains the highest class first (queue-jump at dispatch), FIFO
    /// within a class. Returns `None` once the queue is closed *and*
    /// drained. Under collector contention a racing drain can leave a
    /// batch empty — callers skip those rather than treating them as work.
    pub fn next_batch(&self, max_batch: usize, max_wait: Duration) -> Option<Vec<T>> {
        let max_batch = max_batch.max(1);
        let mut q = self.inner.lock().unwrap();
        // phase 1: wait for the first item (or shutdown)
        loop {
            if q.len > 0 {
                break;
            }
            if q.closed {
                return None;
            }
            q = self.not_empty.wait(q).unwrap();
        }
        // phase 2: batch window opens now; fill until size or deadline
        let deadline = Instant::now() + max_wait;
        while q.len < max_batch && !q.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) = self.not_empty.wait_timeout(q, deadline - now).unwrap();
            q = guard;
            if timeout.timed_out() {
                break;
            }
        }
        let n = q.len.min(max_batch);
        let mut out = Vec::with_capacity(n);
        for lane in q.lanes.iter_mut() {
            while out.len() < n {
                match lane.pop_front() {
                    Some(item) => out.push(item),
                    None => break,
                }
            }
        }
        q.len -= out.len();
        Some(out)
    }

    /// Shut the queue: rejects new offers and wakes all collectors, which
    /// drain remaining items and then observe `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;

    #[test]
    fn offer_sheds_at_capacity() {
        let q = BoundedQueue::new(2);
        assert!(q.offer(1).is_ok());
        assert!(q.offer(2).is_ok());
        assert_eq!(q.offer(3), Err(3), "third is shed with the item back");
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn full_batch_closes_without_waiting() {
        let q = BoundedQueue::new(64);
        for i in 0..10 {
            q.offer(i).unwrap();
        }
        let t0 = Instant::now();
        // long deadline: must return immediately because size trips first
        let b = q.next_batch(4, Duration::from_secs(30)).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(5), "size-close must not wait");
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn deadline_closes_partial_batch() {
        let q = BoundedQueue::new(64);
        q.offer(7).unwrap();
        let t0 = Instant::now();
        let b = q.next_batch(16, Duration::from_millis(30)).unwrap();
        assert_eq!(b, vec![7], "partial batch after the window");
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(25), "waited {waited:?}");
        assert!(waited < Duration::from_secs(5));
    }

    #[test]
    fn requeue_front_preserves_order_and_ignores_capacity() {
        let q = BoundedQueue::new(2);
        q.offer(10).unwrap();
        q.offer(11).unwrap();
        // a preempted batch [1, 2] returns; queue already full
        q.requeue_front(vec![1, 2]);
        assert_eq!(q.len(), 4);
        let b = q.next_batch(4, Duration::from_millis(1)).unwrap();
        assert_eq!(b, vec![1, 2, 10, 11], "requeued work is oldest, in order");
    }

    #[test]
    fn close_drains_then_ends() {
        let q = Arc::new(BoundedQueue::new(8));
        q.offer(1).unwrap();
        q.close();
        assert_eq!(q.offer(2), Err(2), "closed queue rejects offers");
        assert_eq!(q.next_batch(4, Duration::from_millis(1)), Some(vec![1]));
        assert_eq!(q.next_batch(4, Duration::from_millis(1)), None);
    }

    #[test]
    fn close_wakes_blocked_collector() {
        let q = Arc::new(BoundedQueue::<u32>::new(8));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.next_batch(4, Duration::from_secs(60)));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None, "blocked collector observes shutdown");
    }

    #[test]
    fn concurrent_producers_and_collectors_lose_nothing() {
        let q = Arc::new(BoundedQueue::new(100_000));
        let producers = 4;
        let per = 1000;
        std::thread::scope(|s| {
            for p in 0..producers {
                let q = q.clone();
                s.spawn(move || {
                    for i in 0..per {
                        q.offer(p * per + i).unwrap();
                    }
                });
            }
            let mut seen = Vec::new();
            while seen.len() < producers * per {
                if let Some(b) = q.next_batch(64, Duration::from_millis(5)) {
                    seen.extend(b);
                }
            }
            seen.sort();
            assert_eq!(seen, (0..producers * per).collect::<Vec<_>>());
        });
    }

    #[test]
    fn priority_classes_jump_the_dispatch_order() {
        let q = BoundedQueue::new(16);
        q.offer_at("b1", Priority::Batch).unwrap();
        q.offer_at("f1", Priority::Free).unwrap();
        q.offer_at("p1", Priority::Paid).unwrap();
        q.offer_at("f2", Priority::Free).unwrap();
        let b = q.next_batch(16, Duration::from_millis(1)).unwrap();
        assert_eq!(b, vec!["p1", "f1", "f2", "b1"], "class order, FIFO within a class");
    }

    #[test]
    fn full_queue_sheds_lowest_class_first() {
        let q = BoundedQueue::new(3);
        q.offer_at("p1", Priority::Paid).unwrap();
        q.offer_at("b1", Priority::Batch).unwrap();
        q.offer_at("b2", Priority::Batch).unwrap();
        // full: a paid arrival displaces the YOUNGEST batch waiter
        assert_eq!(q.offer_at("p2", Priority::Paid), Ok(Admit::Displaced("b2")));
        assert_eq!(q.len(), 3);
        // full again: free displaces the remaining batch waiter
        assert_eq!(q.offer_at("f1", Priority::Free), Ok(Admit::Displaced("b1")));
        // no class below batch: a batch arrival at capacity is shed itself
        assert_eq!(q.offer_at("b3", Priority::Batch), Err("b3"));
        // no class below paid left waiting except free: paid takes it
        assert_eq!(q.offer_at("p3", Priority::Paid), Ok(Admit::Displaced("f1")));
        // queue is now all paid: even paid arrivals shed at the door
        assert_eq!(q.offer_at("p4", Priority::Paid), Err("p4"));
        let b = q.next_batch(8, Duration::from_millis(1)).unwrap();
        assert_eq!(b, vec!["p1", "p2", "p3"]);
    }

    #[test]
    fn requeue_front_preserves_class_and_admission_order() {
        // a replica died holding the mixed-class batch [p0, f0]; meanwhile
        // later arrivals p1 and f1 are already waiting
        let q = BoundedQueue::new(2);
        q.offer_at("p1", Priority::Paid).unwrap();
        q.offer_at("f1", Priority::Free).unwrap();
        q.requeue_front_at(vec![(Priority::Paid, "p0"), (Priority::Free, "f0")]);
        assert_eq!(q.len(), 4, "requeue bypasses the admission limit");
        let b = q.next_batch(8, Duration::from_millis(1)).unwrap();
        // restored items dispatch before later same-class arrivals (p0
        // before p1, f0 before f1) and never jump a higher class (f0 does
        // not pass p1 even though p1 arrived after f0 was first admitted)
        assert_eq!(b, vec!["p0", "p1", "f0", "f1"]);
    }

    /// Wallclock stress: lock contention on the priority lanes is
    /// invisible in virtual time, so hammer the real Mutex/Condvar path.
    /// Gated behind `HYPER_STRESS=1` like the BENCH_SMOKE-guarded bench
    /// sections — seconds of wallclock, not unit-test material.
    #[test]
    fn stress_producers_preserve_per_class_fifo() {
        if std::env::var("HYPER_STRESS").is_err() {
            eprintln!("stress_producers_preserve_per_class_fifo: set HYPER_STRESS=1 to run");
            return;
        }
        let q = Arc::new(BoundedQueue::new(1_000_000));
        let producers = 8usize;
        let per = 20_000u64;
        std::thread::scope(|s| {
            for p in 0..producers {
                let q = q.clone();
                s.spawn(move || {
                    let class = Priority::from_index(p % Priority::COUNT);
                    for i in 0..per {
                        // payload encodes (producer, seq) so the collector
                        // can check per-producer FIFO within the class
                        match q.offer_at((p as u64, i), class) {
                            Ok(Admit::Queued) => {}
                            Ok(Admit::Displaced(_)) | Err(_) => {
                                panic!("capacity sized to admit everything")
                            }
                        }
                    }
                });
            }
            let total = producers as u64 * per;
            let mut seen = 0u64;
            let mut last_seq = vec![None::<u64>; producers];
            while seen < total {
                if let Some(b) = q.next_batch(128, Duration::from_millis(5)) {
                    for (p, i) in b {
                        let slot = &mut last_seq[p as usize];
                        if let Some(prev) = *slot {
                            assert!(i > prev, "producer {p}: seq {i} after {prev}");
                        }
                        *slot = Some(i);
                        seen += 1;
                    }
                }
            }
            assert_eq!(seen, total, "zero lost requests");
            assert!(q.is_empty());
        });
    }
}
