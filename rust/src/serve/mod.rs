//! The serving layer: dynamic batching, admission control, and
//! preemption-aware elastic replicas over the Hyper runtime.
//!
//! §IV.D of the paper demonstrates "large-scale inference" as one of
//! Hyper's four headline workloads (300 GPU spot instances fanning out
//! YOLO over ImageNet), and §III.D's economics rest on serving heavy
//! traffic from "unstable cheap resources". A one-shot
//! [`crate::runtime::InferSession`] cannot express any of that — serving
//! lives or dies on the *request path*: queueing, batching, and elastic
//! capacity. This module is that vertical slice:
//!
//! | component | paper hook |
//! |---|---|
//! | [`BoundedQueue`] — bounded MPMC queue, admission control | §III.B master/request fan-in; overload sheds instead of queueing unbounded |
//! | [`BatchPolicy`] — close a batch on size OR deadline | §IV.D batch fan-out: amortize the per-dispatch cost `base + per_item·n` |
//! | [`BatchBackend`] / [`PjrtBackend`] — replica model runner | Layer-3 PJRT execution of the AOT artifacts (batch-reuse [`crate::runtime::BatchSlot`]) |
//! | [`ServeStack`] — threaded queue → batcher → worker pool | single-node serving; the `serve_batching` bench measures the ≥3x batching win |
//! | [`Autoscaler`] — p99/backlog-driven replica controller | §III.D elasticity: capacity follows load *and* replaces preempted nodes |
//! | [`ServeSim`] — virtual-time serving with scripted preemption storms | §III.D "terminated anytime": in-flight batches requeue, admitted work never drops |
//!
//! Three hot-path mechanisms layer on top of that slice (see
//! [`sim::ServeSimConfig`] and the `serve_hotpath` bench): per-request
//! [`Priority`] classes with preemptive shed-at-admission, an adaptive
//! [`BatchController`] that trades the close window against p99 headroom,
//! and multi-model replicas that weight-swap toward per-model backlog
//! ([`SwapConfig`]) before buying new capacity.
//!
//! Two invariants define correctness here, and the tests pin both:
//!
//! 1. **Bounded latency under overload.** Admission control sheds at the
//!    door, so the p99 of *admitted* requests is bounded by
//!    `queue_depth / service_rate` no matter how long a capacity gap
//!    lasts.
//! 2. **Zero dropped requests.** Preemption (2-minute notice → drain, or
//!    instant kill → requeue at queue front) may delay admitted work,
//!    never lose it.
//!
//! The scenario family this opens (SLO sweeps, preemption storms,
//! overload shedding, cost-vs-SLO frontiers) runs deterministically in
//! virtual time — see `examples/serve_slo.rs` and the `serve_batching`
//! bench.
//!
//! Request flow through the threaded stack (the virtual-time sim mirrors
//! the same shape with simulated replicas):
//!
//! ```text
//!  clients ── submit ──► BoundedQueue ── next_batch ──► worker 0 ─► BatchBackend
//!               │        (admission     (close on size │
//!             shed       limit, shed    OR deadline)   ├► worker 1 ─► BatchBackend
//!           (Error::Shed) at the door)                 │      │
//!                            ▲                         │   response
//!                            │ requeue_front           ▼      ▼
//!                            └───── preempted batch ── ServeSim / ResponseHandle
//!                                                           ▲
//!                              Autoscaler ── Up/Down ───────┘
//!                              (p99 + backlog per control tick)
//! ```

#![warn(missing_docs)]

pub mod autoscaler;
pub mod backend;
pub mod batcher;
pub mod queue;
pub mod server;
pub mod sim;

pub use autoscaler::{Autoscaler, AutoscalerConfig, ScaleDecision, ScaleSignal, SwapConfig};
pub use backend::{BatchBackend, MultiModelBackend, PjrtBackend, SyntheticBackend};
pub use batcher::{AdaptiveBatchConfig, BatchController, BatchPolicy};
pub use queue::{Admit, BoundedQueue, Priority};
pub use server::{ResponseHandle, ServeStack, ServeStats, ServerConfig};
pub use sim::{ClassReport, Load, ModelShift, ServeReport, ServeSim, ServeSimConfig, StormEvent,
              TickTrace};
