//! Dynamic batching policy: close on size OR deadline, whichever first.
//!
//! GPU inference cost is `base + per_item * n`: the fixed per-dispatch
//! overhead (kernel launch, weight streaming, framework bookkeeping)
//! dominates at small `n`, so serving one request per dispatch wastes most
//! of the accelerator. Batching amortizes `base` across up to `max_batch`
//! requests — but an unbounded wait for a full batch turns low-traffic
//! latency pathological. The policy therefore closes a batch when it
//! reaches `max_batch` *or* when the oldest waiting request has aged
//! `max_delay_s`, whichever trips first.
//!
//! This struct is pure decision logic (no queues, no I/O). The
//! virtual-time [`super::ServeSim`] drives it through the exact
//! [`SimTime`] form ([`BatchPolicy::should_close`] /
//! [`BatchPolicy::close_at`] — nanosecond arithmetic, so a deadline
//! event at the exact instant always closes); the threaded
//! [`super::BoundedQueue::next_batch`] applies the same size-or-deadline
//! rule as a wallclock window. Either way the rule itself lives here.

use crate::sim::SimTime;

/// When does a batch close?
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Largest batch a replica accepts (the artifact's compiled batch).
    pub max_batch: usize,
    /// Longest the oldest request may wait for co-riders, in seconds.
    pub max_delay_s: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 16, max_delay_s: 0.005 }
    }
}

impl BatchPolicy {
    /// Should a batch close *now*, given the queue depth and the age of
    /// the oldest waiting request?
    pub fn close_now(&self, depth: usize, oldest_age_s: f64) -> bool {
        depth > 0 && (depth >= self.max_batch || oldest_age_s >= self.max_delay_s)
    }

    /// Seconds until the deadline would close a (non-empty, non-full)
    /// batch whose oldest member has waited `oldest_age_s`.
    pub fn deadline_in_s(&self, oldest_age_s: f64) -> f64 {
        (self.max_delay_s - oldest_age_s).max(0.0)
    }

    /// How many requests the next batch takes from a queue of `depth`.
    pub fn take(&self, depth: usize) -> usize {
        depth.min(self.max_batch.max(1))
    }

    /// Virtual-time deadline of a batch whose oldest member was admitted
    /// at `oldest_admitted` (exact nanosecond arithmetic — an f64 seconds
    /// round-trip can miss an exact deadline event by half a nanosecond).
    pub fn close_at(&self, oldest_admitted: SimTime) -> SimTime {
        oldest_admitted + SimTime::from_secs_f64(self.max_delay_s)
    }

    /// Virtual-time close decision: size limit reached, or the oldest
    /// member's deadline has arrived.
    pub fn should_close(&self, depth: usize, oldest_admitted: SimTime, now: SimTime) -> bool {
        depth > 0 && (depth >= self.max_batch || self.close_at(oldest_admitted) <= now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closes_on_size() {
        let p = BatchPolicy { max_batch: 8, max_delay_s: 1.0 };
        assert!(p.close_now(8, 0.0));
        assert!(p.close_now(20, 0.0));
        assert!(!p.close_now(7, 0.5));
    }

    #[test]
    fn closes_on_deadline() {
        let p = BatchPolicy { max_batch: 8, max_delay_s: 0.01 };
        assert!(p.close_now(1, 0.01));
        assert!(p.close_now(1, 5.0));
        assert!(!p.close_now(1, 0.0099));
    }

    #[test]
    fn empty_queue_never_closes() {
        let p = BatchPolicy::default();
        assert!(!p.close_now(0, 100.0));
    }

    #[test]
    fn deadline_countdown_saturates() {
        let p = BatchPolicy { max_batch: 8, max_delay_s: 0.01 };
        assert!((p.deadline_in_s(0.004) - 0.006).abs() < 1e-12);
        assert_eq!(p.deadline_in_s(0.02), 0.0);
    }

    #[test]
    fn simtime_close_matches_exact_deadline() {
        let p = BatchPolicy { max_batch: 8, max_delay_s: 0.005 };
        let t0 = SimTime::from_secs(10);
        let deadline = p.close_at(t0);
        assert_eq!(deadline, t0 + SimTime::from_micros(5000));
        assert!(!p.should_close(1, t0, SimTime(deadline.0 - 1)));
        assert!(p.should_close(1, t0, deadline), "exact instant closes");
        assert!(p.should_close(8, t0, t0), "size closes regardless of age");
        assert!(!p.should_close(0, t0, deadline + SimTime::from_secs(1)));
    }

    #[test]
    fn take_clamps_to_batch() {
        let p = BatchPolicy { max_batch: 8, max_delay_s: 1.0 };
        assert_eq!(p.take(3), 3);
        assert_eq!(p.take(100), 8);
        let degenerate = BatchPolicy { max_batch: 0, max_delay_s: 1.0 };
        assert_eq!(degenerate.take(5), 1, "max_batch 0 behaves as 1");
    }
}
