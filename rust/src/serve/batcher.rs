//! Dynamic batching policy: close on size OR deadline, whichever first.
//!
//! GPU inference cost is `base + per_item * n`: the fixed per-dispatch
//! overhead (kernel launch, weight streaming, framework bookkeeping)
//! dominates at small `n`, so serving one request per dispatch wastes most
//! of the accelerator. Batching amortizes `base` across up to `max_batch`
//! requests — but an unbounded wait for a full batch turns low-traffic
//! latency pathological. The policy therefore closes a batch when it
//! reaches `max_batch` *or* when the oldest waiting request has aged
//! `max_delay_s`, whichever trips first.
//!
//! This struct is pure decision logic (no queues, no I/O). The
//! virtual-time [`super::ServeSim`] drives it through the exact
//! [`SimTime`] form ([`BatchPolicy::should_close`] /
//! [`BatchPolicy::close_at`] — nanosecond arithmetic, so a deadline
//! event at the exact instant always closes); the threaded
//! [`super::BoundedQueue::next_batch`] applies the same size-or-deadline
//! rule as a wallclock window. Either way the rule itself lives here.

use crate::sim::SimTime;

/// When does a batch close?
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchPolicy {
    /// Largest batch a replica accepts (the artifact's compiled batch).
    pub max_batch: usize,
    /// Longest the oldest request may wait for co-riders, in seconds.
    pub max_delay_s: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 16, max_delay_s: 0.005 }
    }
}

impl BatchPolicy {
    /// Should a batch close *now*, given the queue depth and the age of
    /// the oldest waiting request?
    pub fn close_now(&self, depth: usize, oldest_age_s: f64) -> bool {
        depth > 0 && (depth >= self.max_batch || oldest_age_s >= self.max_delay_s)
    }

    /// Seconds until the deadline would close a (non-empty, non-full)
    /// batch whose oldest member has waited `oldest_age_s`.
    pub fn deadline_in_s(&self, oldest_age_s: f64) -> f64 {
        (self.max_delay_s - oldest_age_s).max(0.0)
    }

    /// How many requests the next batch takes from a queue of `depth`.
    pub fn take(&self, depth: usize) -> usize {
        depth.min(self.max_batch.max(1))
    }

    /// Virtual-time deadline of a batch whose oldest member was admitted
    /// at `oldest_admitted` (exact nanosecond arithmetic — an f64 seconds
    /// round-trip can miss an exact deadline event by half a nanosecond).
    pub fn close_at(&self, oldest_admitted: SimTime) -> SimTime {
        oldest_admitted + SimTime::from_secs_f64(self.max_delay_s)
    }

    /// Virtual-time close decision: size limit reached, or the oldest
    /// member's deadline has arrived.
    pub fn should_close(&self, depth: usize, oldest_admitted: SimTime, now: SimTime) -> bool {
        depth > 0 && (depth >= self.max_batch || self.close_at(oldest_admitted) <= now)
    }
}

/// Bounds and thresholds for the adaptive batch-window controller.
///
/// [`BatchController`] moves a live [`BatchPolicy`] between these bounds
/// from the windowed p99 observed at each control tick: as the tail
/// approaches the SLO the close window shrinks (requests stop waiting for
/// co-riders) and the batch ceiling halves; with ample slack the window
/// widens back so throughput recovers the amortization. Multiplicative
/// steps in both directions keep the controller stable across the three
/// orders of magnitude a window can usefully span.
#[derive(Debug, Clone)]
pub struct AdaptiveBatchConfig {
    /// The p99 latency objective the controller defends, seconds.
    pub slo_p99_s: f64,
    /// Close-window floor the shrink path cannot pass, seconds.
    pub min_delay_s: f64,
    /// Close-window ceiling the widen path cannot pass, seconds.
    pub max_delay_s: f64,
    /// Batch-size floor (shrinking halves down to this, never below 1).
    pub min_batch: usize,
    /// Batch-size ceiling (widening doubles up to this).
    pub max_batch: usize,
    /// Shrink when the windowed p99 reaches this fraction of the SLO.
    pub shrink_frac: f64,
    /// Widen when the windowed p99 is at or below this fraction of the
    /// SLO. Must sit below `shrink_frac` or the controller oscillates
    /// every tick.
    pub widen_frac: f64,
    /// Multiplicative window step per adjustment (>= 1).
    pub step: f64,
    /// Control cadence of the threaded stack's controller thread, in
    /// wallclock seconds. The virtual-time sim ignores this and adjusts
    /// on its autoscaler tick instead, where the p99 window already
    /// resets.
    pub tick_s: f64,
}

impl Default for AdaptiveBatchConfig {
    fn default() -> Self {
        Self {
            slo_p99_s: 0.25,
            min_delay_s: 0.0005,
            max_delay_s: 0.02,
            min_batch: 4,
            max_batch: 64,
            shrink_frac: 0.7,
            widen_frac: 0.35,
            step: 2.0,
            tick_s: 0.1,
        }
    }
}

/// Latency-aware controller over a [`BatchPolicy`].
///
/// Feed it one `(windowed p99, sample count)` observation per control
/// tick via [`BatchController::observe`]; read the policy to apply via
/// [`BatchController::policy`]. An empty window holds the current policy:
/// silence means no traffic, not slack, and widening on it would greet
/// the next burst with the largest possible window.
#[derive(Debug, Clone)]
pub struct BatchController {
    cfg: AdaptiveBatchConfig,
    cur: BatchPolicy,
}

impl BatchController {
    /// Start from `initial`, clamped into the config's bounds.
    pub fn new(cfg: AdaptiveBatchConfig, initial: BatchPolicy) -> Self {
        let cur = BatchPolicy {
            max_batch: initial.max_batch.clamp(cfg.min_batch.max(1), cfg.max_batch.max(1)),
            max_delay_s: initial.max_delay_s.clamp(cfg.min_delay_s, cfg.max_delay_s),
        };
        Self { cfg, cur }
    }

    /// The policy currently in force.
    pub fn policy(&self) -> BatchPolicy {
        self.cur
    }

    /// The bounds this controller operates within.
    pub fn config(&self) -> &AdaptiveBatchConfig {
        &self.cfg
    }

    /// Feed one control-tick window; returns true when the policy moved.
    pub fn observe(&mut self, window_p99_s: f64, samples: u64) -> bool {
        if samples == 0 {
            return false;
        }
        let step = self.cfg.step.max(1.0);
        let before = self.cur;
        if window_p99_s >= self.cfg.shrink_frac * self.cfg.slo_p99_s {
            self.cur.max_delay_s = (self.cur.max_delay_s / step).max(self.cfg.min_delay_s);
            self.cur.max_batch = (self.cur.max_batch / 2).max(self.cfg.min_batch.max(1));
        } else if window_p99_s <= self.cfg.widen_frac * self.cfg.slo_p99_s {
            self.cur.max_delay_s = (self.cur.max_delay_s * step).min(self.cfg.max_delay_s);
            self.cur.max_batch =
                self.cur.max_batch.saturating_mul(2).min(self.cfg.max_batch.max(1));
        }
        self.cur != before
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closes_on_size() {
        let p = BatchPolicy { max_batch: 8, max_delay_s: 1.0 };
        assert!(p.close_now(8, 0.0));
        assert!(p.close_now(20, 0.0));
        assert!(!p.close_now(7, 0.5));
    }

    #[test]
    fn closes_on_deadline() {
        let p = BatchPolicy { max_batch: 8, max_delay_s: 0.01 };
        assert!(p.close_now(1, 0.01));
        assert!(p.close_now(1, 5.0));
        assert!(!p.close_now(1, 0.0099));
    }

    #[test]
    fn empty_queue_never_closes() {
        let p = BatchPolicy::default();
        assert!(!p.close_now(0, 100.0));
    }

    #[test]
    fn deadline_countdown_saturates() {
        let p = BatchPolicy { max_batch: 8, max_delay_s: 0.01 };
        assert!((p.deadline_in_s(0.004) - 0.006).abs() < 1e-12);
        assert_eq!(p.deadline_in_s(0.02), 0.0);
    }

    #[test]
    fn simtime_close_matches_exact_deadline() {
        let p = BatchPolicy { max_batch: 8, max_delay_s: 0.005 };
        let t0 = SimTime::from_secs(10);
        let deadline = p.close_at(t0);
        assert_eq!(deadline, t0 + SimTime::from_micros(5000));
        assert!(!p.should_close(1, t0, SimTime(deadline.0 - 1)));
        assert!(p.should_close(1, t0, deadline), "exact instant closes");
        assert!(p.should_close(8, t0, t0), "size closes regardless of age");
        assert!(!p.should_close(0, t0, deadline + SimTime::from_secs(1)));
    }

    #[test]
    fn take_clamps_to_batch() {
        let p = BatchPolicy { max_batch: 8, max_delay_s: 1.0 };
        assert_eq!(p.take(3), 3);
        assert_eq!(p.take(100), 8);
        let degenerate = BatchPolicy { max_batch: 0, max_delay_s: 1.0 };
        assert_eq!(degenerate.take(5), 1, "max_batch 0 behaves as 1");
    }

    fn ctl() -> BatchController {
        BatchController::new(
            AdaptiveBatchConfig::default(),
            BatchPolicy { max_batch: 16, max_delay_s: 0.005 },
        )
    }

    #[test]
    fn controller_shrinks_to_floor_under_pressure() {
        let mut c = ctl();
        // p99 pinned at the SLO: every tick shrinks until both floors hit
        for _ in 0..16 {
            c.observe(0.25, 100);
        }
        let p = c.policy();
        assert_eq!(p.max_delay_s, 0.0005, "window stops at min_delay_s");
        assert_eq!(p.max_batch, 4, "batch stops at min_batch");
        assert!(!c.observe(0.25, 100), "at the floor nothing moves");
    }

    #[test]
    fn controller_widens_to_ceiling_with_slack() {
        let mut c = ctl();
        for _ in 0..16 {
            c.observe(0.001, 100);
        }
        let p = c.policy();
        assert_eq!(p.max_delay_s, 0.02, "window stops at max_delay_s");
        assert_eq!(p.max_batch, 64, "batch stops at max_batch");
    }

    #[test]
    fn controller_holds_in_the_dead_band_and_on_silence() {
        let mut c = ctl();
        let before = c.policy();
        // between widen (0.0875) and shrink (0.175) thresholds: hold
        assert!(!c.observe(0.12, 100));
        assert_eq!(c.policy(), before);
        // an empty window is no evidence of slack: hold
        assert!(!c.observe(0.0, 0));
        assert_eq!(c.policy(), before);
    }

    #[test]
    fn controller_clamps_the_initial_policy() {
        let cfg = AdaptiveBatchConfig { min_batch: 8, max_delay_s: 0.002, ..Default::default() };
        let c = BatchController::new(cfg, BatchPolicy { max_batch: 2, max_delay_s: 0.5 });
        assert_eq!(c.policy().max_batch, 8);
        assert_eq!(c.policy().max_delay_s, 0.002);
    }

    #[test]
    fn controller_single_step_moves_one_notch() {
        let mut c = ctl();
        assert!(c.observe(0.2, 10), "p99 at 80% of SLO shrinks");
        assert_eq!(c.policy(), BatchPolicy { max_batch: 8, max_delay_s: 0.0025 });
        assert!(c.observe(0.01, 10), "deep slack widens back");
        assert_eq!(c.policy(), BatchPolicy { max_batch: 16, max_delay_s: 0.005 });
    }
}
