//! [`BatchBackend`]: what a replica worker runs a closed batch against.
//!
//! Two implementations:
//!
//! * [`SyntheticBackend`] — deterministic cost model (`base + per_item·n`
//!   seconds, optionally slept for wallclock serving) producing
//!   deterministic tokens. Drives the benches, the CLI demo without
//!   artifacts, and every test.
//! * [`PjrtBackend`] — wraps a real [`InferSession`] plus its reusable
//!   [`BatchSlot`]; used when AOT artifacts and real PJRT bindings are
//!   present (offline builds construct it but execution errors in the
//!   vendored stub).

use crate::runtime::{BatchSlot, InferSession};
use crate::Result;

/// A model replica that serves one closed batch at a time.
pub trait BatchBackend: Send {
    /// Serve `rows` (each one request's token window), returning one
    /// output token per row, in order.
    fn infer(&mut self, rows: &[&[i32]]) -> Result<Vec<i32>>;

    /// Largest batch this backend accepts per call.
    fn max_batch(&self) -> usize;
}

/// Deterministic synthetic model with a linear batch cost profile.
#[derive(Debug, Clone)]
pub struct SyntheticBackend {
    /// Fixed per-dispatch overhead, seconds (kernel launch, weights).
    pub base_s: f64,
    /// Marginal per-request cost, seconds.
    pub per_item_s: f64,
    max_batch: usize,
    /// Sleep out the modeled service time (wallclock mode). Off in
    /// virtual-time / pure-logic tests.
    sleep: bool,
}

impl SyntheticBackend {
    /// A synthetic replica with service time `base_s + per_item_s * n`;
    /// `sleep` selects wallclock mode (the modeled time is slept out).
    ///
    /// # Panics
    /// If `max_batch` is zero.
    pub fn new(base_s: f64, per_item_s: f64, max_batch: usize, sleep: bool) -> Self {
        assert!(max_batch > 0, "max_batch must be positive");
        Self { base_s, per_item_s, max_batch, sleep }
    }

    /// Modeled service time for a batch of `n`.
    pub fn service_s(&self, n: usize) -> f64 {
        if n == 0 {
            0.0
        } else {
            self.base_s + self.per_item_s * n as f64
        }
    }

    /// The output token for one row: a cheap deterministic digest, so
    /// tests can verify responses end-to-end without a real model.
    pub fn token_for(row: &[i32]) -> i32 {
        let mut acc = 0x9E37u32;
        for &t in row {
            acc = acc.wrapping_mul(31).wrapping_add(t as u32);
        }
        (acc % 32_768) as i32
    }
}

impl BatchBackend for SyntheticBackend {
    fn infer(&mut self, rows: &[&[i32]]) -> Result<Vec<i32>> {
        if self.sleep && !rows.is_empty() {
            std::thread::sleep(std::time::Duration::from_secs_f64(self.service_s(rows.len())));
        }
        Ok(rows.iter().map(|r| Self::token_for(r)).collect())
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }
}

/// A replica that can host any of several models but serves exactly one
/// at a time: switching pays `swap_s` of service blackout (slept out in
/// wallclock mode) — the threaded analogue of the virtual-time sim's
/// weight-swap cost ([`super::SwapConfig`]).
#[derive(Debug, Clone)]
pub struct MultiModelBackend {
    models: Vec<SyntheticBackend>,
    active: usize,
    swap_s: f64,
    swaps: u64,
    sleep: bool,
}

impl MultiModelBackend {
    /// A replica hosting `models`, serving `models[0]` first; switching
    /// costs `swap_s` seconds (`sleep` selects wallclock mode).
    ///
    /// # Panics
    /// If `models` is empty.
    pub fn new(models: Vec<SyntheticBackend>, swap_s: f64, sleep: bool) -> Self {
        assert!(!models.is_empty(), "at least one model");
        Self { models, active: 0, swap_s, swaps: 0, sleep }
    }

    /// Index of the model currently served.
    pub fn active(&self) -> usize {
        self.active
    }

    /// Completed weight swaps so far.
    pub fn swaps(&self) -> u64 {
        self.swaps
    }

    /// Switch the replica to `model` (no-op when already active, clamped
    /// into range otherwise), paying the swap blackout.
    pub fn swap_to(&mut self, model: usize) {
        let model = model.min(self.models.len() - 1);
        if model == self.active {
            return;
        }
        if self.sleep && self.swap_s > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(self.swap_s));
        }
        self.active = model;
        self.swaps += 1;
    }
}

impl BatchBackend for MultiModelBackend {
    fn infer(&mut self, rows: &[&[i32]]) -> Result<Vec<i32>> {
        self.models[self.active].infer(rows)
    }

    fn max_batch(&self) -> usize {
        self.models[self.active].max_batch()
    }
}

/// A real replica: PJRT inference through the batch-reuse slot API.
pub struct PjrtBackend {
    sess: InferSession,
    slot: BatchSlot,
}

impl PjrtBackend {
    /// Wrap an inference session, allocating its reusable batch slot.
    pub fn new(sess: InferSession) -> Self {
        let slot = sess.new_slot();
        Self { sess, slot }
    }
}

impl BatchBackend for PjrtBackend {
    fn infer(&mut self, rows: &[&[i32]]) -> Result<Vec<i32>> {
        self.slot.clear();
        for row in rows {
            self.slot.push_row(row)?;
        }
        self.sess.run_slot(&self.slot)
    }

    fn max_batch(&self) -> usize {
        self.slot.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_is_deterministic_and_ordered() {
        let mut b = SyntheticBackend::new(0.0, 0.0, 8, false);
        let rows: Vec<&[i32]> = vec![&[1, 2, 3], &[4, 5, 6], &[1, 2, 3]];
        let out = b.infer(&rows).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], out[2], "same row, same token");
        assert_ne!(out[0], out[1]);
        assert_eq!(out, b.infer(&rows).unwrap());
    }

    #[test]
    fn multi_model_swaps_route_and_count() {
        let mut b = MultiModelBackend::new(
            vec![
                SyntheticBackend::new(0.001, 0.0, 8, false),
                SyntheticBackend::new(0.010, 0.0, 32, false),
            ],
            0.0,
            false,
        );
        assert_eq!(b.active(), 0);
        assert_eq!(b.max_batch(), 8, "serves model 0's profile");
        b.swap_to(0);
        assert_eq!(b.swaps(), 0, "swapping to the active model is free");
        b.swap_to(1);
        assert_eq!((b.active(), b.swaps()), (1, 1));
        assert_eq!(b.max_batch(), 32, "now serves model 1's profile");
        b.swap_to(99);
        assert_eq!(b.active(), 1, "out-of-range clamps; still model 1");
        assert_eq!(b.swaps(), 1);
        let rows: Vec<&[i32]> = vec![&[7, 8]];
        assert_eq!(b.infer(&rows).unwrap(), vec![SyntheticBackend::token_for(&[7, 8])]);
    }

    #[test]
    fn synthetic_cost_model_amortizes_base() {
        let b = SyntheticBackend::new(0.002, 0.0001, 16, false);
        let single_16 = 16.0 * b.service_s(1);
        let batched_16 = b.service_s(16);
        assert!(
            single_16 / batched_16 > 3.0,
            "batching must amortize the base cost: {single_16} vs {batched_16}"
        );
        assert_eq!(b.service_s(0), 0.0);
    }
}
