//! S3 timing model over a real backend.
//!
//! Calibration targets the paper's own measurements (Fig 2): a p3.2xlarge
//! ("up to 10 Gbps" NIC) saturates at ~875 MB/s with multithreading +
//! multiprocessing, a single S3 connection streams at tens of MB/s, and
//! per-request first-byte latency is tens of milliseconds — which is
//! exactly why the paper recommends 12–100 MB chunks.


use std::sync::Mutex;

use super::{ObjectStore, StoreHandle};
use crate::metrics::Counter;
use crate::sim::{SimClock, SimRng, SimTime};
use crate::Result;

/// Timing parameters of the modeled object store.
#[derive(Debug, Clone)]
pub struct S3Profile {
    /// Time to first byte per GET/PUT request (seconds).
    pub first_byte_latency_s: f64,
    /// Sustained bandwidth of a single connection (bytes/s).
    pub per_conn_bw: f64,
    /// Node NIC ceiling shared by all concurrent connections (bytes/s).
    pub nic_bw: f64,
    /// Aggregate service-side ceiling (S3 scales ~linearly; effectively
    /// unbounded for one node, finite for a 110-node fleet per prefix).
    pub service_bw: f64,
    /// Multiplicative jitter half-range (0.05 => ±5%).
    pub jitter: f64,
}

impl Default for S3Profile {
    /// Same-region S3 from a p3.2xlarge, as in the paper's Figs 2–4.
    fn default() -> Self {
        Self {
            first_byte_latency_s: 0.030,
            per_conn_bw: 55.0 * 1e6,       // ~55 MB/s per stream
            nic_bw: 1.15e9,                // 10 Gbps-class NIC (~1150 MB/s)
            service_bw: 80.0 * 1e9,        // fleet-level S3 prefix ceiling
            jitter: 0.05,
        }
    }
}

impl S3Profile {
    /// Effective bandwidth of one stream when `concurrent` streams share
    /// the NIC (max-min fair share, capped by the per-connection limit).
    pub fn stream_bw(&self, concurrent: usize) -> f64 {
        let n = concurrent.max(1) as f64;
        self.per_conn_bw.min(self.nic_bw / n)
    }

    /// Modeled duration of one transfer of `bytes` with `concurrent`
    /// streams active on this node (no jitter — the deterministic core).
    pub fn transfer_time(&self, bytes: u64, concurrent: usize) -> f64 {
        self.first_byte_latency_s + bytes as f64 / self.stream_bw(concurrent)
    }

    /// Aggregate node throughput achievable with `lanes` parallel streams
    /// fetching `chunk_bytes` objects back to back — the Fig-2 quantity.
    pub fn aggregate_throughput(&self, chunk_bytes: u64, lanes: usize) -> f64 {
        let per_stream = chunk_bytes as f64 / self.transfer_time(chunk_bytes, lanes);
        (per_stream * lanes as f64).min(self.nic_bw)
    }
}

/// [`ObjectStore`] decorator that carries real bytes through an inner
/// backend while advancing a shared [`SimClock`] by the modeled duration
/// of each request. Sequential callers therefore observe S3-like virtual
/// timing; parallel fetch pools use [`S3Profile`] directly (they know
/// their own concurrency).
pub struct SimStore {
    inner: StoreHandle,
    profile: S3Profile,
    clock: SimClock,
    rng: Mutex<SimRng>,
    pub requests: Counter,
    pub bytes_down: Counter,
    pub bytes_up: Counter,
}

impl SimStore {
    pub fn new(inner: StoreHandle, profile: S3Profile, clock: SimClock) -> Self {
        Self {
            inner,
            profile,
            clock,
            rng: Mutex::new(SimRng::new(0x5EED)),
            requests: Counter::default(),
            bytes_down: Counter::default(),
            bytes_up: Counter::default(),
        }
    }

    pub fn profile(&self) -> &S3Profile {
        &self.profile
    }

    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    /// Jittered modeled duration for a transfer of `bytes` (1 stream).
    fn charge(&self, bytes: u64) {
        let base = self.profile.transfer_time(bytes, 1);
        let j = {
            let mut rng = self.rng.lock().unwrap();
            1.0 + self.profile.jitter * (2.0 * rng.next_f64() - 1.0)
        };
        self.clock.advance_by(SimTime::from_secs_f64(base * j));
        self.requests.inc();
    }
}

impl ObjectStore for SimStore {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        self.charge(data.len() as u64);
        self.bytes_up.add(data.len() as u64);
        self.inner.put(key, data)
    }

    fn get(&self, key: &str) -> Result<Vec<u8>> {
        let out = self.inner.get(key)?;
        self.charge(out.len() as u64);
        self.bytes_down.add(out.len() as u64);
        Ok(out)
    }

    fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        let out = self.inner.get_range(key, offset, len)?;
        self.charge(out.len() as u64);
        self.bytes_down.add(out.len() as u64);
        Ok(out)
    }

    fn head(&self, key: &str) -> Result<u64> {
        // metadata request: latency only
        self.clock
            .advance_by(SimTime::from_secs_f64(self.profile.first_byte_latency_s));
        self.requests.inc();
        self.inner.head(key)
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        self.clock
            .advance_by(SimTime::from_secs_f64(self.profile.first_byte_latency_s));
        self.requests.inc();
        self.inner.list(prefix)
    }

    fn delete(&self, key: &str) -> Result<()> {
        self.clock
            .advance_by(SimTime::from_secs_f64(self.profile.first_byte_latency_s));
        self.requests.inc();
        self.inner.delete(key)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::storage::MemStore;

    #[test]
    fn chunk_size_throughput_shape() {
        // The Fig-2 shape: throughput grows with chunk size (latency
        // amortization) and with lanes, saturating at the NIC.
        let p = S3Profile::default();
        let t_small = p.aggregate_throughput(1 << 20, 16); // 1 MB chunks
        let t_mid = p.aggregate_throughput(32 << 20, 16); // 32 MB
        let t_big = p.aggregate_throughput(128 << 20, 16); // 128 MB
        assert!(t_small < t_mid && t_mid <= t_big * 1.01);
        // saturates below NIC cap
        assert!(t_big <= p.nic_bw);
        // single lane is per-conn-bound
        assert!(p.aggregate_throughput(64 << 20, 1) < 1.1 * p.per_conn_bw);
    }

    #[test]
    fn sim_clock_advances_on_io() {
        let clock = SimClock::new();
        let s = SimStore::new(Arc::new(MemStore::new()), S3Profile::default(), clock.clone());
        s.put("k", &vec![0u8; 55_000_000]).unwrap(); // ~1 s at 55 MB/s
        let t = clock.now().as_secs_f64();
        assert!(t > 0.8 && t < 1.3, "modeled put took {t}s");
        s.get("k").unwrap();
        assert!(clock.now().as_secs_f64() > 1.6);
        assert_eq!(s.requests.get(), 2);
    }

    #[test]
    fn stream_bw_fair_share() {
        let p = S3Profile::default();
        assert_eq!(p.stream_bw(1), p.per_conn_bw);
        // 64 streams: NIC-bound
        assert!(p.stream_bw(64) < p.per_conn_bw);
        assert!((p.stream_bw(64) - p.nic_bw / 64.0).abs() < 1.0);
    }
}
