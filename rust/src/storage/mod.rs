//! Object storage substrates.
//!
//! The paper stores file-system chunks in cloud object storage (AWS S3,
//! or a self-hosted Minio). This module provides:
//!
//! * [`ObjectStore`] — the S3-like trait (put/get/get_range/list/delete).
//! * [`MemStore`] — in-memory backend (tests, fast benches).
//! * [`DiskStore`] — directory-backed backend (real bytes on disk; used by
//!   the end-to-end training example).
//! * [`SimStore`] — wraps any backend with the calibrated S3 latency /
//!   bandwidth / concurrency model that drives the Fig-2/3/4 benches, and
//!   advances a shared [`crate::sim::SimClock`].
//! * [`CountingStore`] — transparent wrapper counting backend calls
//!   (tests/benches; proves single-flight coalescing).
//!
//! The timing model is the substitution documented in DESIGN.md §1: it
//! preserves the latency-vs-throughput trade-off that makes chunk sizing
//! matter, without owning an S3 deployment.

mod counting;
mod disk;
mod mem;
mod simstore;

pub use counting::CountingStore;
pub use disk::DiskStore;
pub use mem::MemStore;
pub use simstore::{S3Profile, SimStore};

use std::sync::Arc;

use crate::Result;

/// S3-like object store: keyed blobs with range reads.
pub trait ObjectStore: Send + Sync {
    /// Store `data` under `key`, overwriting any previous object.
    fn put(&self, key: &str, data: &[u8]) -> Result<()>;

    /// Fetch the whole object.
    fn get(&self, key: &str) -> Result<Vec<u8>>;

    /// Fetch `[offset, offset+len)`; short reads only at object end.
    fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>>;

    /// Object size in bytes.
    fn head(&self, key: &str) -> Result<u64>;

    /// Keys with the given prefix, sorted.
    fn list(&self, prefix: &str) -> Result<Vec<String>>;

    fn delete(&self, key: &str) -> Result<()>;

    /// True if the object exists.
    fn exists(&self, key: &str) -> bool {
        self.head(key).is_ok()
    }
}

/// Shared handle to a store.
pub type StoreHandle = Arc<dyn ObjectStore>;

#[cfg(test)]
mod tests {
    use super::*;

    /// Conformance suite run against every backend.
    pub(crate) fn conformance(store: &dyn ObjectStore) {
        store.put("a/b/one", b"hello world").unwrap();
        store.put("a/b/two", b"0123456789").unwrap();
        store.put("a/c/three", b"x").unwrap();

        assert_eq!(store.get("a/b/one").unwrap(), b"hello world");
        assert_eq!(store.head("a/b/two").unwrap(), 10);
        assert_eq!(store.get_range("a/b/two", 2, 3).unwrap(), b"234");
        // short read at end
        assert_eq!(store.get_range("a/b/two", 8, 100).unwrap(), b"89");
        assert_eq!(
            store.list("a/b/").unwrap(),
            vec!["a/b/one".to_string(), "a/b/two".to_string()]
        );
        assert!(store.exists("a/c/three"));
        store.delete("a/c/three").unwrap();
        assert!(!store.exists("a/c/three"));
        assert!(store.get("missing").is_err());

        // overwrite
        store.put("a/b/one", b"bye").unwrap();
        assert_eq!(store.get("a/b/one").unwrap(), b"bye");
    }

    #[test]
    fn mem_conformance() {
        conformance(&MemStore::new());
    }

    #[test]
    fn disk_conformance() {
        let dir = crate::util::TempDir::new().unwrap();
        conformance(&DiskStore::new(dir.path()).unwrap());
    }

    #[test]
    fn sim_conformance() {
        let clock = crate::sim::SimClock::new();
        conformance(&SimStore::new(
            Arc::new(MemStore::new()),
            S3Profile::default(),
            clock,
        ));
    }
}
