//! In-memory object store (tests and fast simulations).

use std::collections::BTreeMap;
use std::sync::Arc;

use std::sync::RwLock;

use super::ObjectStore;
use crate::{Error, Result};

/// BTreeMap-backed store; `list` is a range scan, objects are `Arc`'d so
/// `get` of large chunks is a cheap clone-on-read of the refcount only
/// when callers keep the returned Vec (we still copy for API uniformity).
#[derive(Debug, Default)]
pub struct MemStore {
    objects: RwLock<BTreeMap<String, Arc<Vec<u8>>>>,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of stored objects.
    pub fn len(&self) -> usize {
        self.objects.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.objects.read().unwrap().is_empty()
    }

    /// Total stored bytes.
    pub fn total_bytes(&self) -> u64 {
        self.objects.read().unwrap().values().map(|v| v.len() as u64).sum()
    }
}

impl ObjectStore for MemStore {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        self.objects.write().unwrap().insert(key.to_string(), Arc::new(data.to_vec()));
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Vec<u8>> {
        self.objects
            .read().unwrap()
            .get(key)
            .map(|v| v.as_ref().clone())
            .ok_or_else(|| Error::NotFound(key.to_string()))
    }

    fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        let objects = self.objects.read().unwrap();
        let v = objects.get(key).ok_or_else(|| Error::NotFound(key.to_string()))?;
        let start = (offset as usize).min(v.len());
        let end = (offset.saturating_add(len) as usize).min(v.len());
        Ok(v[start..end].to_vec())
    }

    fn head(&self, key: &str) -> Result<u64> {
        self.objects
            .read().unwrap()
            .get(key)
            .map(|v| v.len() as u64)
            .ok_or_else(|| Error::NotFound(key.to_string()))
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        Ok(self
            .objects
            .read().unwrap()
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, _)| k.clone())
            .collect())
    }

    fn delete(&self, key: &str) -> Result<()> {
        self.objects
            .write().unwrap()
            .remove(key)
            .map(|_| ())
            .ok_or_else(|| Error::NotFound(key.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_accounting() {
        let s = MemStore::new();
        assert!(s.is_empty());
        s.put("k1", &[0u8; 100]).unwrap();
        s.put("k2", &[0u8; 50]).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.total_bytes(), 150);
        s.put("k1", &[0u8; 10]).unwrap(); // overwrite shrinks
        assert_eq!(s.total_bytes(), 60);
    }
}
