//! [`CountingStore`]: a transparent wrapper that counts backend calls.
//!
//! Used by tests and benches to make I/O behavior observable — e.g. the
//! HFS single-flight test proves that 32 concurrent cold readers of one
//! chunk issue exactly one backend GET.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::Result;

use super::{ObjectStore, StoreHandle};

/// Wraps any [`ObjectStore`], counting `get` / `get_range` / `put` calls
/// (total and per key) while delegating all behavior to the inner store.
pub struct CountingStore {
    inner: StoreHandle,
    total_gets: AtomicU64,
    total_puts: AtomicU64,
    /// Range GETs only (subset of `total_gets`).
    total_range_gets: AtomicU64,
    /// Bytes actually returned by get/get_range (transfer accounting).
    total_get_bytes: AtomicU64,
    /// Bytes handed to `put` (upload-transfer accounting; counted even if
    /// the inner store then fails the write).
    total_put_bytes: AtomicU64,
    gets_by_key: Mutex<BTreeMap<String, u64>>,
}

impl CountingStore {
    pub fn new(inner: StoreHandle) -> Self {
        Self {
            inner,
            total_gets: AtomicU64::new(0),
            total_puts: AtomicU64::new(0),
            total_range_gets: AtomicU64::new(0),
            total_get_bytes: AtomicU64::new(0),
            total_put_bytes: AtomicU64::new(0),
            gets_by_key: Mutex::new(BTreeMap::new()),
        }
    }

    fn record_get(&self, key: &str) {
        self.total_gets.fetch_add(1, Ordering::SeqCst);
        *self.gets_by_key.lock().unwrap().entry(key.to_string()).or_default() += 1;
    }

    /// Total whole-object and range GETs issued so far.
    pub fn total_gets(&self) -> u64 {
        self.total_gets.load(Ordering::SeqCst)
    }

    /// GETs that used a byte range rather than fetching the whole object.
    pub fn total_range_gets(&self) -> u64 {
        self.total_range_gets.load(Ordering::SeqCst)
    }

    /// Bytes transferred out of the store by successful get/get_range.
    pub fn total_get_bytes(&self) -> u64 {
        self.total_get_bytes.load(Ordering::SeqCst)
    }

    pub fn total_puts(&self) -> u64 {
        self.total_puts.load(Ordering::SeqCst)
    }

    /// Bytes pushed into the store by `put` calls.
    pub fn total_put_bytes(&self) -> u64 {
        self.total_put_bytes.load(Ordering::SeqCst)
    }

    /// GETs issued for one exact key.
    pub fn gets_for(&self, key: &str) -> u64 {
        self.gets_by_key.lock().unwrap().get(key).copied().unwrap_or(0)
    }

    /// Per-key GET counts (sorted by key).
    pub fn gets_by_key(&self) -> BTreeMap<String, u64> {
        self.gets_by_key.lock().unwrap().clone()
    }

    pub fn reset(&self) {
        self.total_gets.store(0, Ordering::SeqCst);
        self.total_puts.store(0, Ordering::SeqCst);
        self.total_range_gets.store(0, Ordering::SeqCst);
        self.total_get_bytes.store(0, Ordering::SeqCst);
        self.total_put_bytes.store(0, Ordering::SeqCst);
        self.gets_by_key.lock().unwrap().clear();
    }
}

impl ObjectStore for CountingStore {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        self.total_puts.fetch_add(1, Ordering::SeqCst);
        self.total_put_bytes.fetch_add(data.len() as u64, Ordering::SeqCst);
        self.inner.put(key, data)
    }

    fn get(&self, key: &str) -> Result<Vec<u8>> {
        self.record_get(key);
        let out = self.inner.get(key)?;
        self.total_get_bytes.fetch_add(out.len() as u64, Ordering::SeqCst);
        Ok(out)
    }

    fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        self.record_get(key);
        self.total_range_gets.fetch_add(1, Ordering::SeqCst);
        let out = self.inner.get_range(key, offset, len)?;
        self.total_get_bytes.fetch_add(out.len() as u64, Ordering::SeqCst);
        Ok(out)
    }

    fn head(&self, key: &str) -> Result<u64> {
        self.inner.head(key)
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        self.inner.list(prefix)
    }

    fn delete(&self, key: &str) -> Result<()> {
        self.inner.delete(key)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::MemStore;
    use super::*;

    #[test]
    fn counts_and_delegates() {
        let s = CountingStore::new(Arc::new(MemStore::new()));
        s.put("k1", b"abc").unwrap();
        s.put("k2", b"defg").unwrap();
        assert_eq!(s.get("k1").unwrap(), b"abc");
        assert_eq!(s.get("k1").unwrap(), b"abc");
        assert_eq!(s.get_range("k2", 1, 2).unwrap(), b"ef");
        assert_eq!(s.total_puts(), 2);
        assert_eq!(s.total_put_bytes(), 3 + 4, "k1 + k2 payloads");
        assert_eq!(s.total_gets(), 3);
        assert_eq!(s.total_range_gets(), 1);
        assert_eq!(s.total_get_bytes(), 3 + 3 + 2, "two full k1 gets + 2-byte range");
        assert_eq!(s.gets_for("k1"), 2);
        assert_eq!(s.gets_for("k2"), 1);
        assert_eq!(s.gets_for("missing"), 0);
        // misses still count as attempts and still error (no bytes moved)
        assert!(s.get("nope").is_err());
        assert_eq!(s.gets_for("nope"), 1);
        assert_eq!(s.total_get_bytes(), 8);
        s.reset();
        assert_eq!(s.total_gets(), 0);
        assert_eq!(s.total_get_bytes(), 0);
        assert_eq!(s.total_put_bytes(), 0);
        assert!(s.gets_by_key().is_empty());
    }

    #[test]
    fn conformance_through_the_wrapper() {
        let s = CountingStore::new(Arc::new(MemStore::new()));
        s.put("a/x", b"1").unwrap();
        assert_eq!(s.head("a/x").unwrap(), 1);
        assert_eq!(s.list("a/").unwrap(), vec!["a/x".to_string()]);
        assert!(s.exists("a/x"));
        s.delete("a/x").unwrap();
        assert!(!s.exists("a/x"));
    }
}
