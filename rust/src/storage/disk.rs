//! Directory-backed object store — real bytes on the local filesystem.
//!
//! Used by the end-to-end examples so that HFS chunks physically exist and
//! checkpoint/restore crosses a process boundary. Keys map to file paths
//! with `/` as directory separator; key components are sanitized against
//! path escape.

use std::fs;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use super::ObjectStore;
use crate::{Error, Result};

#[derive(Debug)]
pub struct DiskStore {
    root: PathBuf,
}

impl DiskStore {
    pub fn new(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        fs::create_dir_all(&root)?;
        Ok(Self { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn path_for(&self, key: &str) -> Result<PathBuf> {
        if key.is_empty() || key.split('/').any(|c| c == ".." || c.is_empty() && key != "/") {
            return Err(Error::Storage(format!("invalid key: {key:?}")));
        }
        Ok(self.root.join(key))
    }
}

impl ObjectStore for DiskStore {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        let path = self.path_for(key)?;
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        // write-then-rename for atomicity under concurrent readers
        let tmp = path.with_extension("tmp~");
        fs::write(&tmp, data)?;
        fs::rename(&tmp, &path)?;
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Vec<u8>> {
        let path = self.path_for(key)?;
        fs::read(&path).map_err(|_| Error::NotFound(key.to_string()))
    }

    fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        let path = self.path_for(key)?;
        let mut f = fs::File::open(&path).map_err(|_| Error::NotFound(key.to_string()))?;
        let size = f.metadata()?.len();
        let start = offset.min(size);
        let end = offset.saturating_add(len).min(size);
        f.seek(SeekFrom::Start(start))?;
        let mut buf = vec![0u8; (end - start) as usize];
        f.read_exact(&mut buf)?;
        Ok(buf)
    }

    fn head(&self, key: &str) -> Result<u64> {
        let path = self.path_for(key)?;
        fs::metadata(&path)
            .map(|m| m.len())
            .map_err(|_| Error::NotFound(key.to_string()))
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let mut out = Vec::new();
        let mut stack = vec![self.root.clone()];
        while let Some(dir) = stack.pop() {
            let entries = match fs::read_dir(&dir) {
                Ok(e) => e,
                Err(_) => continue,
            };
            for entry in entries.flatten() {
                let path = entry.path();
                if path.extension().is_some_and(|e| e == "tmp~") {
                    continue;
                }
                if path.is_dir() {
                    stack.push(path);
                } else if let Ok(rel) = path.strip_prefix(&self.root) {
                    let key = rel.to_string_lossy().replace('\\', "/");
                    if key.starts_with(prefix) {
                        out.push(key);
                    }
                }
            }
        }
        out.sort();
        Ok(out)
    }

    fn delete(&self, key: &str) -> Result<()> {
        let path = self.path_for(key)?;
        fs::remove_file(&path).map_err(|_| Error::NotFound(key.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_path_escape() {
        let dir = crate::util::TempDir::new().unwrap();
        let s = DiskStore::new(dir.path()).unwrap();
        assert!(s.put("../evil", b"x").is_err());
        assert!(s.put("a/../../evil", b"x").is_err());
        assert!(s.put("", b"x").is_err());
    }

    #[test]
    fn persists_across_instances() {
        let dir = crate::util::TempDir::new().unwrap();
        {
            let s = DiskStore::new(dir.path()).unwrap();
            s.put("data/chunk0", b"persisted").unwrap();
        }
        let s2 = DiskStore::new(dir.path()).unwrap();
        assert_eq!(s2.get("data/chunk0").unwrap(), b"persisted");
    }
}
