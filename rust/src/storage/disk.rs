//! Directory-backed object store — real bytes on the local filesystem.
//!
//! Used by the end-to-end examples so that HFS chunks physically exist and
//! checkpoint/restore crosses a process boundary. Keys map to file paths
//! with `/` as directory separator; key components are sanitized against
//! path escape.

use std::fs;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::ObjectStore;
use crate::{Error, Result};

/// Per-process sequence distinguishing in-flight temp files; combined
/// with the pid in the temp name, concurrent `put`s on keys sharing a
/// file stem (or on the same key) — from this process or another one
/// sharing the store root — never rename each other's half-written
/// temp away.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

#[derive(Debug)]
pub struct DiskStore {
    root: PathBuf,
}

impl DiskStore {
    pub fn new(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        fs::create_dir_all(&root)?;
        Ok(Self { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn path_for(&self, key: &str) -> Result<PathBuf> {
        if key.is_empty() || key.split('/').any(|c| c == ".." || c.is_empty() && key != "/") {
            return Err(Error::Storage(format!("invalid key: {key:?}")));
        }
        Ok(self.root.join(key))
    }

    /// Filesystem path behind `key` (validated, not checked for
    /// existence). Lets zero-copy consumers — the HFS spill tier's mmap
    /// read path — open the backing file directly; `put` is
    /// write-then-rename and `delete` is unlink, so a file opened through
    /// this path stays byte-stable even if the key is later overwritten
    /// or removed.
    pub fn path_of(&self, key: &str) -> Result<PathBuf> {
        self.path_for(key)
    }

    /// Delete stranded temp files under `prefix` — litter from writers
    /// that crashed between write and rename. `list()` hides temp files,
    /// so without this sweep they would accumulate invisibly and escape
    /// any caller-side byte accounting. Callers that own a directory
    /// (e.g. the HFS spill tier) run this once at open; racing a
    /// concurrently *live* writer can at worst fail that writer's rename,
    /// which best-effort writers tolerate as a skipped put.
    pub fn sweep_temp(&self, prefix: &str) -> usize {
        let mut removed = 0;
        let mut stack = vec![self.root.clone()];
        while let Some(dir) = stack.pop() {
            let entries = match fs::read_dir(&dir) {
                Ok(e) => e,
                Err(_) => continue,
            };
            for entry in entries.flatten() {
                let path = entry.path();
                if path.is_dir() {
                    stack.push(path);
                    continue;
                }
                let is_tmp = path
                    .extension()
                    .is_some_and(|e| e.to_string_lossy().starts_with("tmp~"));
                if !is_tmp {
                    continue;
                }
                if let Ok(rel) = path.strip_prefix(&self.root) {
                    let key = rel.to_string_lossy().replace('\\', "/");
                    if key.starts_with(prefix) && fs::remove_file(&path).is_ok() {
                        removed += 1;
                    }
                }
            }
        }
        removed
    }
}

impl ObjectStore for DiskStore {
    fn put(&self, key: &str, data: &[u8]) -> Result<()> {
        let path = self.path_for(key)?;
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        // write-then-rename for atomicity under concurrent readers; the
        // temp name is unique per call (pid + seq), so two writers
        // racing on one stem — even from different processes — each
        // rename their own complete bytes. A failed write/rename must
        // clean its own temp up: unique names mean nobody else will
        // (high-frequency best-effort callers like the spill tier would
        // otherwise litter a nearly-full disk on every ENOSPC).
        let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
        let tmp = path.with_extension(format!("tmp~{}-{seq}", std::process::id()));
        if let Err(e) = fs::write(&tmp, data).and_then(|()| fs::rename(&tmp, &path)) {
            let _ = fs::remove_file(&tmp);
            return Err(e.into());
        }
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Vec<u8>> {
        let path = self.path_for(key)?;
        fs::read(&path).map_err(|_| Error::NotFound(key.to_string()))
    }

    fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        let path = self.path_for(key)?;
        let mut f = fs::File::open(&path).map_err(|_| Error::NotFound(key.to_string()))?;
        let size = f.metadata()?.len();
        let start = offset.min(size);
        let end = offset.saturating_add(len).min(size);
        f.seek(SeekFrom::Start(start))?;
        let mut buf = vec![0u8; (end - start) as usize];
        f.read_exact(&mut buf)?;
        Ok(buf)
    }

    fn head(&self, key: &str) -> Result<u64> {
        let path = self.path_for(key)?;
        fs::metadata(&path)
            .map(|m| m.len())
            .map_err(|_| Error::NotFound(key.to_string()))
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>> {
        let mut out = Vec::new();
        let mut stack = vec![self.root.clone()];
        while let Some(dir) = stack.pop() {
            let entries = match fs::read_dir(&dir) {
                Ok(e) => e,
                Err(_) => continue,
            };
            for entry in entries.flatten() {
                let path = entry.path();
                if path
                    .extension()
                    .is_some_and(|e| e.to_string_lossy().starts_with("tmp~"))
                {
                    continue;
                }
                if path.is_dir() {
                    stack.push(path);
                } else if let Ok(rel) = path.strip_prefix(&self.root) {
                    let key = rel.to_string_lossy().replace('\\', "/");
                    if key.starts_with(prefix) {
                        out.push(key);
                    }
                }
            }
        }
        out.sort();
        Ok(out)
    }

    fn delete(&self, key: &str) -> Result<()> {
        let path = self.path_for(key)?;
        fs::remove_file(&path).map_err(|_| Error::NotFound(key.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_path_escape() {
        let dir = crate::util::TempDir::new().unwrap();
        let s = DiskStore::new(dir.path()).unwrap();
        assert!(s.put("../evil", b"x").is_err());
        assert!(s.put("a/../../evil", b"x").is_err());
        assert!(s.put("", b"x").is_err());
    }

    #[test]
    fn concurrent_puts_on_sibling_keys_do_not_collide() {
        // keys sharing a stem ("k.1", "k.2") used to share one "k.tmp~"
        // temp file, so racing writers could rename each other's partial
        // bytes into place; unique temp names make every rename whole
        let dir = crate::util::TempDir::new().unwrap();
        let s = std::sync::Arc::new(DiskStore::new(dir.path()).unwrap());
        std::thread::scope(|scope| {
            for t in 0..4u8 {
                let s = s.clone();
                scope.spawn(move || {
                    for round in 0..50 {
                        s.put(&format!("k.{}", t % 2), &vec![t; 64 + round]).unwrap();
                    }
                });
            }
        });
        for key in ["k.0", "k.1"] {
            let got = s.get(key).unwrap();
            assert!(!got.is_empty());
            assert!(got.iter().all(|&b| b == got[0]), "no torn write for {key}");
        }
        // no temp litter survives, and list() hides nothing real
        assert_eq!(s.list("k").unwrap(), vec!["k.0".to_string(), "k.1".to_string()]);
    }

    #[test]
    fn sweep_temp_removes_only_stranded_temps_under_prefix() {
        let dir = crate::util::TempDir::new().unwrap();
        let s = DiskStore::new(dir.path()).unwrap();
        s.put("spill/ns/chunk0", b"real").unwrap();
        // simulate writers that died between write and rename
        std::fs::write(dir.path().join("spill/ns/chunk1.tmp~123-0"), b"half").unwrap();
        std::fs::write(dir.path().join("spill/ns/chunk2.tmp~9-44"), b"half").unwrap();
        std::fs::create_dir_all(dir.path().join("other")).unwrap();
        std::fs::write(dir.path().join("other/x.tmp~1-1"), b"half").unwrap();
        assert_eq!(s.sweep_temp("spill/ns/"), 2, "both stranded temps removed");
        assert_eq!(s.get("spill/ns/chunk0").unwrap(), b"real", "real data untouched");
        assert!(dir.path().join("other/x.tmp~1-1").exists(), "outside prefix: kept");
        assert_eq!(s.sweep_temp("spill/ns/"), 0, "idempotent");
    }

    #[test]
    fn persists_across_instances() {
        let dir = crate::util::TempDir::new().unwrap();
        {
            let s = DiskStore::new(dir.path()).unwrap();
            s.put("data/chunk0", b"persisted").unwrap();
        }
        let s2 = DiskStore::new(dir.path()).unwrap();
        assert_eq!(s2.get("data/chunk0").unwrap(), b"persisted");
    }
}
