//! RAII temporary directories (tempfile stand-in).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory under the system temp root, removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new() -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!("hyper-dist-{pid}-{nanos}-{n}"));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Create (if needed) and return a named child directory — handy for
    /// giving one test separate roots, e.g. an object store and an HFS
    /// spill tier, that are cleaned up together.
    pub fn subdir(&self, name: &str) -> std::io::Result<PathBuf> {
        let dir = self.path.join(name);
        std::fs::create_dir_all(&dir)?;
        Ok(dir)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let kept;
        {
            let d = TempDir::new().unwrap();
            kept = d.path().to_path_buf();
            std::fs::write(d.path().join("f"), b"x").unwrap();
            assert!(kept.exists());
        }
        assert!(!kept.exists(), "removed on drop");
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new().unwrap();
        let b = TempDir::new().unwrap();
        assert_ne!(a.path(), b.path());
    }

    #[test]
    fn subdir_creates_and_is_idempotent() {
        let d = TempDir::new().unwrap();
        let s = d.subdir("store").unwrap();
        assert!(s.is_dir());
        assert_eq!(d.subdir("store").unwrap(), s);
        assert_ne!(d.subdir("spill").unwrap(), s);
    }
}
