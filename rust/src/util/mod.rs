//! From-scratch utility substrates.
//!
//! This build environment is offline and carries only the `xla` crate's
//! dependency tree, so the ecosystem crates a project like this would
//! normally pull in are implemented here instead (DESIGN.md
//! §Substitutions):
//!
//! * [`json`]   — JSON value, parser and serializer (serde_json stand-in);
//!   also the wire format shared with `python/compile/aot.py`.
//! * [`yamlite`] — the YAML subset used by recipes (serde_yaml stand-in).
//! * [`tempdir`] — RAII temporary directories for tests (tempfile).
//! * [`bench`]  — measurement harness used by `rust/benches/*` (criterion).
//! * [`prop`]   — tiny property-testing loop over [`crate::sim::SimRng`]
//!   (proptest stand-in).

pub mod bench;
pub mod json;
pub mod prop;
pub mod tempdir;
pub mod yamlite;

pub use json::Json;
pub use tempdir::TempDir;
