//! YAML subset parser — enough for Hyper recipes (serde_yaml stand-in).
//!
//! Supported:
//! * block maps (`key: value`, nesting by 2+-space indentation)
//! * block lists (`- item`, including `- key: value` list-of-maps)
//! * inline maps `{ a: 1, b: x }` and lists `[1, two, 3.0]`
//! * scalars: bool / int / float (incl. `1.0e-4`) / quoted + bare strings
//! * `#` comments and blank lines
//!
//! Parses into [`Json`] so recipes and manifests share one value type.

use std::collections::BTreeMap;

use crate::{Error, Result};

use super::json::Json;

/// Parse a YAML-subset document into a [`Json`] value.
pub fn parse(text: &str) -> Result<Json> {
    let lines: Vec<Line> = text
        .lines()
        .enumerate()
        .filter_map(|(no, raw)| Line::lex(no + 1, raw))
        .collect();
    let mut p = P { lines: &lines, pos: 0 };
    let v = p.block(0)?;
    if p.pos != lines.len() {
        return Err(Error::Yaml(format!(
            "line {}: unexpected content (bad indentation?)",
            lines[p.pos].no
        )));
    }
    Ok(v)
}

#[derive(Debug)]
struct Line {
    no: usize,
    indent: usize,
    text: String,
}

impl Line {
    fn lex(no: usize, raw: &str) -> Option<Line> {
        let without_comment = strip_comment(raw);
        let text = without_comment.trim_end();
        let trimmed = text.trim_start();
        if trimmed.is_empty() {
            return None;
        }
        let indent = text.len() - trimmed.len();
        Some(Line { no, indent, text: trimmed.to_string() })
    }
}

/// Strip a `#` comment that is not inside quotes.
fn strip_comment(s: &str) -> &str {
    let mut in_sq = false;
    let mut in_dq = false;
    for (i, c) in s.char_indices() {
        match c {
            '\'' if !in_dq => in_sq = !in_sq,
            '"' if !in_sq => in_dq = !in_dq,
            '#' if !in_sq && !in_dq => return &s[..i],
            _ => {}
        }
    }
    s
}

struct P<'a> {
    lines: &'a [Line],
    pos: usize,
}

impl<'a> P<'a> {
    /// Parse a block (map or list) whose items are indented at least `min`.
    fn block(&mut self, min: usize) -> Result<Json> {
        let Some(first) = self.lines.get(self.pos) else {
            return Ok(Json::Null);
        };
        if first.indent < min {
            return Ok(Json::Null);
        }
        let indent = first.indent;
        if first.text.starts_with("- ") || first.text == "-" {
            self.list(indent)
        } else {
            self.map(indent)
        }
    }

    fn list(&mut self, indent: usize) -> Result<Json> {
        let mut items = Vec::new();
        while let Some(line) = self.lines.get(self.pos) {
            if line.indent != indent || !(line.text.starts_with("- ") || line.text == "-") {
                break;
            }
            let no = line.no;
            let rest = line.text[1..].trim_start().to_string();
            self.pos += 1;
            if rest.is_empty() {
                // nested block under the dash
                items.push(self.block(indent + 1)?);
            } else if let Some((k, v)) = split_key(&rest) {
                // "- key: value" — first entry of an inline-started map
                let mut map = BTreeMap::new();
                map.insert(k.to_string(), self.entry_value(v, indent + 1, no)?);
                // following lines more-indented than the dash belong here
                while let Some(l2) = self.lines.get(self.pos) {
                    if l2.indent <= indent || l2.text.starts_with("- ") {
                        break;
                    }
                    let (k2, v2) = split_key(&l2.text)
                        .ok_or_else(|| Error::Yaml(format!("line {}: expected key", l2.no)))?;
                    let k2 = k2.to_string();
                    let v2 = v2.to_string();
                    let ind2 = l2.indent;
                    let no2 = l2.no;
                    self.pos += 1;
                    let value = self.entry_value(&v2, ind2 + 1, no2)?;
                    if map.insert(k2.clone(), value).is_some() {
                        return Err(Error::Yaml(format!("line {no2}: duplicate key {k2:?}")));
                    }
                }
                items.push(Json::Obj(map));
            } else {
                items.push(scalar(&rest));
            }
        }
        Ok(Json::Arr(items))
    }

    fn map(&mut self, indent: usize) -> Result<Json> {
        let mut map = BTreeMap::new();
        while let Some(line) = self.lines.get(self.pos) {
            if line.indent != indent || line.text.starts_with("- ") {
                break;
            }
            let (k, v) = split_key(&line.text)
                .ok_or_else(|| Error::Yaml(format!("line {}: expected 'key:'", line.no)))?;
            let k = k.to_string();
            let v = v.to_string();
            let no = line.no;
            self.pos += 1;
            let value = self.entry_value(&v, indent + 1, no)?;
            if map.insert(k.clone(), value).is_some() {
                return Err(Error::Yaml(format!("line {no}: duplicate key {k:?}")));
            }
        }
        Ok(Json::Obj(map))
    }

    /// Value after `key:` — inline scalar/flow, or a nested block.
    fn entry_value(&mut self, inline: &str, min_child: usize, no: usize) -> Result<Json> {
        let inline = inline.trim();
        if !inline.is_empty() {
            return flow_or_scalar(inline)
                .map_err(|e| Error::Yaml(format!("line {no}: {e}")));
        }
        // nested block (or empty value)
        match self.lines.get(self.pos) {
            Some(next) if next.indent >= min_child => self.block(next.indent),
            _ => Ok(Json::Null),
        }
    }
}

/// Split `key: rest` (the colon must be followed by space/EOL).
fn split_key(s: &str) -> Option<(&str, &str)> {
    let mut in_sq = false;
    let mut in_dq = false;
    for (i, c) in s.char_indices() {
        match c {
            '\'' if !in_dq => in_sq = !in_sq,
            '"' if !in_sq => in_dq = !in_dq,
            ':' if !in_sq && !in_dq => {
                let rest = &s[i + 1..];
                if rest.is_empty() || rest.starts_with(' ') {
                    return Some((s[..i].trim(), rest.trim_start()));
                }
            }
            _ => {}
        }
    }
    None
}

/// Inline flow value (`{…}` / `[…]`) or scalar.
fn flow_or_scalar(s: &str) -> Result<Json> {
    let s = s.trim();
    if s.starts_with('{') || s.starts_with('[') {
        let (v, used) = flow(s)?;
        if s[used..].trim().is_empty() {
            Ok(v)
        } else {
            Err(Error::Yaml(format!("trailing content after flow value: {:?}", &s[used..])))
        }
    } else {
        Ok(scalar(s))
    }
}

/// Parse a flow value, returning (value, bytes consumed).
fn flow(s: &str) -> Result<(Json, usize)> {
    let bytes = s.as_bytes();
    match bytes.first() {
        Some(b'{') => {
            let mut map = BTreeMap::new();
            let mut i = 1;
            loop {
                i += ws(&s[i..]);
                if bytes.get(i) == Some(&b'}') {
                    return Ok((Json::Obj(map), i + 1));
                }
                let rest = &s[i..];
                let colon = rest
                    .find(':')
                    .ok_or_else(|| Error::Yaml(format!("flow map missing ':' in {rest:?}")))?;
                let key = rest[..colon].trim().trim_matches(['"', '\'']).to_string();
                i += colon + 1;
                i += ws(&s[i..]);
                let (v, used) = flow_item(&s[i..])?;
                i += used;
                if map.insert(key.clone(), v).is_some() {
                    return Err(Error::Yaml(format!("duplicate key {key:?} in flow map")));
                }
                i += ws(&s[i..]);
                match bytes.get(i) {
                    Some(b',') => i += 1,
                    Some(b'}') => return Ok((Json::Obj(map), i + 1)),
                    _ => return Err(Error::Yaml(format!("bad flow map near {:?}", &s[i..]))),
                }
            }
        }
        Some(b'[') => {
            let mut arr = Vec::new();
            let mut i = 1;
            loop {
                i += ws(&s[i..]);
                if bytes.get(i) == Some(&b']') {
                    return Ok((Json::Arr(arr), i + 1));
                }
                let (v, used) = flow_item(&s[i..])?;
                i += used;
                arr.push(v);
                i += ws(&s[i..]);
                match bytes.get(i) {
                    Some(b',') => i += 1,
                    Some(b']') => return Ok((Json::Arr(arr), i + 1)),
                    _ => return Err(Error::Yaml(format!("bad flow list near {:?}", &s[i..]))),
                }
            }
        }
        _ => Err(Error::Yaml(format!("not a flow value: {s:?}"))),
    }
}

/// One item inside a flow collection: nested flow or scalar up to , } ].
fn flow_item(s: &str) -> Result<(Json, usize)> {
    if s.starts_with('{') || s.starts_with('[') {
        return flow(s);
    }
    let end = s
        .char_indices()
        .find(|(_, c)| matches!(c, ',' | '}' | ']'))
        .map(|(i, _)| i)
        .unwrap_or(s.len());
    Ok((scalar(s[..end].trim()), end))
}

fn ws(s: &str) -> usize {
    s.len() - s.trim_start().len()
}

/// Scalar typing: bool / null / number / string (quotes stripped).
fn scalar(s: &str) -> Json {
    let t = s.trim();
    if (t.starts_with('"') && t.ends_with('"') && t.len() >= 2)
        || (t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2)
    {
        return Json::Str(t[1..t.len() - 1].to_string());
    }
    match t {
        "true" | "True" => return Json::Bool(true),
        "false" | "False" => return Json::Bool(false),
        "null" | "~" | "" => return Json::Null,
        _ => {}
    }
    if let Ok(x) = t.parse::<f64>() {
        // bare numbers only (avoid "1.2.3" -> parse::<f64> fails anyway)
        return Json::Num(x);
    }
    Json::Str(t.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    const RECIPE: &str = r#"
# a demo recipe
name: demo
version: 1
experiments:
  - name: prep
    instance: m5.24xlarge
    workers: 4
    command: "prep --shard {shard}"
    params:
      shard: { range: [0, 7] }
    work: { duration_s: 10.0, input_bytes: 1000000 }
  - name: train
    instance: p3.2xlarge
    spot: true
    command: 'train --lr {lr}'
    samples: 4
    params:
      lr: { log_uniform: [1.0e-4, 1.0e-2] }
    depends_on: [prep]
"#;

    #[test]
    fn parses_recipe_shape() {
        let v = parse(RECIPE).unwrap();
        assert_eq!(v.req_str("name").unwrap(), "demo");
        let exps = v.req_arr("experiments").unwrap();
        assert_eq!(exps.len(), 2);
        assert_eq!(exps[0].req_str("command").unwrap(), "prep --shard {shard}");
        assert_eq!(exps[0].req_u64("workers").unwrap(), 4);
        let range = exps[0].get("params").unwrap().get("shard").unwrap().req_arr("range").unwrap();
        assert_eq!(range[1].as_u64(), Some(7));
        assert_eq!(exps[1].get("spot").unwrap().as_bool(), Some(true));
        let lu = exps[1].get("params").unwrap().get("lr").unwrap().req_arr("log_uniform").unwrap();
        assert!((lu[0].as_f64().unwrap() - 1e-4).abs() < 1e-12);
        assert_eq!(exps[1].req_arr("depends_on").unwrap()[0].as_str(), Some("prep"));
    }

    #[test]
    fn inline_collections() {
        let v = parse("a: { x: 1, y: [2, 3], z: { w: ok } }").unwrap();
        let a = v.get("a").unwrap();
        assert_eq!(a.req_u64("x").unwrap(), 1);
        assert_eq!(a.req_arr("y").unwrap().len(), 2);
        assert_eq!(a.get("z").unwrap().req_str("w").unwrap(), "ok");
    }

    #[test]
    fn scalars_typed() {
        let v = parse("i: 42\nf: -2.5e3\nb: true\nn: null\ns: plain words\nq: \"quoted: x\"")
            .unwrap();
        assert_eq!(v.req_u64("i").unwrap(), 42);
        assert_eq!(v.req_f64("f").unwrap(), -2500.0);
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("n"), Some(&Json::Null));
        assert_eq!(v.req_str("s").unwrap(), "plain words");
        assert_eq!(v.req_str("q").unwrap(), "quoted: x");
    }

    #[test]
    fn comments_stripped_safely() {
        let v = parse("a: 1 # trailing\n# whole line\nb: \"keep # this\"").unwrap();
        assert_eq!(v.req_u64("a").unwrap(), 1);
        assert_eq!(v.req_str("b").unwrap(), "keep # this");
    }

    #[test]
    fn list_of_scalars() {
        let v = parse("xs:\n  - 1\n  - two\n  - 3.5").unwrap();
        let xs = v.req_arr("xs").unwrap();
        assert_eq!(xs[0].as_u64(), Some(1));
        assert_eq!(xs[1].as_str(), Some("two"));
        assert_eq!(xs[2].as_f64(), Some(3.5));
    }

    #[test]
    fn bad_yaml_errors() {
        assert!(parse("a: { unclosed").is_err());
        assert!(parse("key_without_colon_value\n  nested: 1").is_err());
    }

    #[test]
    fn duplicate_keys_rejected_everywhere() {
        // block map
        let e = parse("a: 1\nb: 2\na: 3").unwrap_err();
        assert!(e.to_string().contains("duplicate key \"a\""), "{e}");
        // flow map
        let e = parse("m: { x: 1, x: 2 }").unwrap_err();
        assert!(e.to_string().contains("duplicate key \"x\""), "{e}");
        // list-of-maps entry
        let e = parse("xs:\n  - k: 1\n    v: 2\n    v: 3").unwrap_err();
        assert!(e.to_string().contains("duplicate key \"v\""), "{e}");
        // nested block maps keep their own namespaces
        assert!(parse("a:\n  x: 1\nb:\n  x: 2").is_ok());
    }

    #[test]
    fn deep_nesting() {
        let v = parse("a:\n  b:\n    c:\n      - d: 1\n        e: 2\n      - d: 3").unwrap();
        let list = v.get("a").unwrap().get("b").unwrap().req_arr("c").unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].req_u64("e").unwrap(), 2);
        assert_eq!(list[1].req_u64("d").unwrap(), 3);
    }
}
