//! Tiny property-testing loop (proptest stand-in).
//!
//! `run_prop` executes a property against `cases` randomized inputs drawn
//! through a [`crate::sim::SimRng`]; on failure it reports the seed so
//! the case replays deterministically. No shrinking — failures print the
//! generating seed instead, which for these state-machine properties is
//! enough to reproduce and debug.

use crate::sim::SimRng;

/// Run `prop` against `cases` random inputs. `gen` draws an input from
/// the RNG; `prop` panics (assert!) on violation.
pub fn run_prop<T, G, P>(name: &str, cases: u64, mut gen: G, mut prop: P)
where
    G: FnMut(&mut SimRng) -> T,
    P: FnMut(T),
    T: std::fmt::Debug,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = SimRng::new(seed);
        let input = gen(&mut rng);
        let desc = format!("{input:?}");
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(input)));
        if let Err(e) = result {
            eprintln!(
                "property {name:?} failed on case {case} (seed {seed:#x})\ninput: {desc}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        run_prop("sort idempotent", 50, |rng| {
            let n = rng.gen_range(20) as usize;
            (0..n).map(|_| rng.gen_range(100)).collect::<Vec<_>>()
        }, |mut v| {
            v.sort();
            let w = { let mut w = v.clone(); w.sort(); w };
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic]
    fn catches_bad_property() {
        run_prop("always small", 100, |rng| rng.gen_range(1000), |x| {
            assert!(x < 500, "found counterexample {x}");
        });
    }
}
