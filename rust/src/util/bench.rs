//! Measurement harness for `rust/benches/*` (criterion stand-in).
//!
//! Wallclock benches: warmup + N timed iterations, reporting mean / p50 /
//! min with a stable text format the EXPERIMENTS.md tables are pasted
//! from. Virtual-time benches print their own tables and only use
//! [`section`]/[`row`] for formatting.

use std::time::Instant;

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
}

impl Measurement {
    pub fn print(&self) {
        println!(
            "  {:40} {:>10.4} ms/iter (p50 {:>10.4}, min {:>10.4}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.min_s * 1e3,
            self.iters
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let m = Measurement {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean,
        p50_s: samples[samples.len() / 2],
        min_s: samples[0],
    };
    m.print();
    m
}

/// Section banner.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Aligned table row: label + columns.
pub fn row(label: &str, cols: &[String]) {
    print!("  {label:32}");
    for c in cols {
        print!(" {c:>14}");
    }
    println!();
}

/// Header row.
pub fn header(label: &str, cols: &[&str]) {
    row(label, &cols.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    println!("  {}", "-".repeat(32 + cols.len() * 15));
}

/// Keep the optimizer honest (std::hint::black_box re-export for benches).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// `true` when the benches should skip their slow wallclock sections
/// (`BENCH_SMOKE=1`; CI's `scripts/bench_summary --smoke` sets it so the
/// deterministic virtual-time metrics still land in `BENCH_fleet.json`).
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Append one machine-readable metrics record for `bench` to the
/// JSON-lines file named by the `BENCH_JSON` env var (no-op when unset).
/// `scripts/bench_summary` runs the virtual-time benches with it set and
/// assembles the lines into `BENCH_fleet.json`, so the perf trajectory
/// is tracked in-repo per bench.
pub fn emit_json(bench: &str, metrics: &[(&str, f64)]) {
    let Ok(path) = std::env::var("BENCH_JSON") else { return };
    if path.is_empty() {
        return;
    }
    let line = crate::util::Json::obj(vec![
        ("bench", crate::util::Json::str(bench)),
        (
            "metrics",
            crate::util::Json::Obj(
                metrics.iter().map(|&(k, v)| (k.to_string(), crate::util::Json::Num(v))).collect(),
            ),
        ),
    ]);
    use std::io::Write;
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            let _ = writeln!(f, "{}", line.to_string());
        }
        Err(e) => eprintln!("BENCH_JSON: cannot open {path}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let m = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert_eq!(m.iters, 5);
        assert!(m.min_s <= m.mean_s);
        assert!(m.mean_s > 0.0);
    }
}
