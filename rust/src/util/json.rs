//! Minimal JSON: value type, recursive-descent parser, serializer.
//!
//! Covers everything this crate and `artifacts/manifest.json` need:
//! objects, arrays, strings (with escapes), numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------- accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|x| x.fract() == 0.0).map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required typed field helpers (errors carry the key name).
    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| Error::Json(format!("missing/invalid u64 field {key:?}")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| Error::Json(format!("missing/invalid f64 field {key:?}")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Json(format!("missing/invalid string field {key:?}")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Json(format!("missing/invalid array field {key:?}")))
    }

    pub fn req_obj(&self, key: &str) -> Result<&BTreeMap<String, Json>> {
        self.get(key)
            .and_then(Json::as_obj)
            .ok_or_else(|| Error::Json(format!("missing/invalid object field {key:?}")))
    }

    // ----------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ------------------------------------------------------- serialize

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        self.to_string().into_bytes()
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ----------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!("trailing garbage at byte {}", p.pos)));
        }
        Ok(v)
    }

    pub fn parse_bytes(data: &[u8]) -> Result<Json> {
        Self::parse(std::str::from_utf8(data).map_err(|e| Error::Json(e.to_string()))?)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self
                .bytes
                .get(self.pos)
                .ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self
                        .bytes
                        .get(self.pos)
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad hex"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad hex"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // copy the full UTF-8 scalar
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let slice = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| self.err("truncated utf8"))?;
                    out.push_str(
                        std::str::from_utf8(slice).map_err(|_| self.err("invalid utf8"))?,
                    );
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number {text:?}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().req_str("c").unwrap(), "x\ny");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn integers_serialize_without_point() {
        assert_eq!(Json::num(42).to_string(), "42");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
    }

    #[test]
    fn escapes() {
        let v = Json::Str("quote\" slash\\ nl\n".into());
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_passthrough_and_escape() {
        let v = Json::parse(r#""café naïve""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café naïve");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "f": 1.5, "neg": -2}"#).unwrap();
        assert_eq!(v.req_u64("n").unwrap(), 7);
        assert!(v.req_u64("f").is_err());
        assert!(v.req_u64("neg").is_err());
        assert_eq!(v.get("neg").unwrap().as_i64(), Some(-2));
        assert!(v.req_str("missing").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let dir = crate::config::default_artifacts_dir();
        let path = dir.join("manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("presets").is_some());
        }
    }
}
