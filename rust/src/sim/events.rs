//! Generic event queue for discrete-event simulation.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::SimTime;

/// Min-heap of timestamped events with deterministic FIFO tie-breaking.
///
/// Two events scheduled for the same instant pop in insertion order, which
/// keeps every simulation in this crate fully deterministic for a fixed
/// seed — a property the proptest suites rely on.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
}

#[derive(Debug)]
struct Entry<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0 }
    }
}

impl<E> EventQueue<E> {
    /// An empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `event` at absolute time `at`.
    pub fn push(&mut self, at: SimTime, event: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Entry { at, seq, event }));
    }

    /// Pop the earliest event, if any.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|Reverse(e)| (e.at, e.event))
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }

    /// Pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// No events pending?
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_secs(3), "c");
        q.push(SimTime::from_secs(1), "a");
        q.push(SimTime::from_secs(2), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn same_time_is_fifo() {
        let mut q = EventQueue::new();
        let t = SimTime::from_secs(1);
        for i in 0..100 {
            q.push(t, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_millis(7), ());
        assert_eq!(q.peek_time(), Some(SimTime::from_millis(7)));
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
