//! Small deterministic RNG (xoshiro256**) — no external dependency, stable
//! across platforms, seedable per-experiment so every simulated run in the
//! benches and tests reproduces bit-for-bit.

/// Deterministic PRNG used throughout the simulators.
#[derive(Debug, Clone)]
pub struct SimRng {
    s: [u64; 4],
}

impl SimRng {
    /// Seed via splitmix64 so nearby seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for the ranges used here
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform float in `[lo, hi)`.
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponentially-distributed sample with mean `mean` (Poisson arrivals —
    /// used by the spot-preemption process).
    pub fn gen_exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = SimRng::new(42);
        let mut b = SimRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = SimRng::new(43);
        assert_ne!(SimRng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SimRng::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = SimRng::new(9);
        for _ in 0..1000 {
            assert!(r.gen_range(10) < 10);
        }
    }

    #[test]
    fn exp_mean_approx() {
        let mut r = SimRng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.gen_exp(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SimRng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
