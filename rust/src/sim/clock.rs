//! Virtual time: nanosecond-resolution simulated clock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A point in simulated time, in nanoseconds since simulation start.
///
/// `SimTime` is ordered, copyable and cheap; arithmetic helpers keep the
/// call sites readable (`t + SimTime::from_secs_f64(0.5)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(
    /// Nanoseconds since simulation start.
    pub u64,
);

impl SimTime {
    /// Simulation start.
    pub const ZERO: SimTime = SimTime(0);

    /// A point `n` nanoseconds after simulation start.
    pub fn from_nanos(n: u64) -> Self {
        SimTime(n)
    }

    /// A point `us` microseconds after simulation start.
    pub fn from_micros(us: u64) -> Self {
        SimTime(us * 1_000)
    }

    /// A point `ms` milliseconds after simulation start.
    pub fn from_millis(ms: u64) -> Self {
        SimTime(ms * 1_000_000)
    }

    /// A point `s` whole seconds after simulation start.
    pub fn from_secs(s: u64) -> Self {
        SimTime(s * 1_000_000_000)
    }

    /// A point `s` (fractional) seconds after simulation start, rounded
    /// to the nearest nanosecond.
    pub fn from_secs_f64(s: f64) -> Self {
        debug_assert!(s >= 0.0, "negative sim duration: {s}");
        SimTime((s.max(0.0) * 1e9).round() as u64)
    }

    /// Nanoseconds since simulation start.
    pub fn as_nanos(self) -> u64 {
        self.0
    }

    /// Seconds since simulation start.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    /// Saturating difference (durations are also `SimTime`).
    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl std::ops::Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl std::ops::Mul<u64> for SimTime {
    type Output = SimTime;
    fn mul(self, rhs: u64) -> SimTime {
        SimTime(self.0 * rhs)
    }
}

impl std::fmt::Display for SimTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.3}s", self.as_secs_f64())
    }
}

/// Shared monotonically-advancing virtual clock.
///
/// Cloned handles observe the same time; only the simulation driver should
/// call [`SimClock::advance_to`]. Thread-safe so worker-pool code can read
/// the clock from any thread.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    now_ns: Arc<AtomicU64>,
}

impl SimClock {
    /// A clock at t=0.
    pub fn new() -> Self {
        Self::default()
    }

    /// The current virtual time.
    pub fn now(&self) -> SimTime {
        SimTime(self.now_ns.load(Ordering::Acquire))
    }

    /// The current virtual time in raw nanoseconds (the form the
    /// [`crate::obs`] flight recorder timestamps records with).
    pub fn now_ns(&self) -> u64 {
        self.now_ns.load(Ordering::Acquire)
    }

    /// Advance to `t`. Time never goes backwards; a stale `t` is a no-op.
    pub fn advance_to(&self, t: SimTime) {
        self.now_ns.fetch_max(t.0, Ordering::AcqRel);
    }

    /// Advance by a duration, returning the new now.
    pub fn advance_by(&self, d: SimTime) -> SimTime {
        SimTime(self.now_ns.fetch_add(d.0, Ordering::AcqRel) + d.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_roundtrip() {
        assert_eq!(SimTime::from_secs(3).as_secs_f64(), 3.0);
        assert_eq!(SimTime::from_millis(1500).as_secs_f64(), 1.5);
        assert_eq!(SimTime::from_secs_f64(0.25).as_nanos(), 250_000_000);
    }

    #[test]
    fn arithmetic() {
        let t = SimTime::from_secs(1) + SimTime::from_millis(500);
        assert_eq!(t.as_secs_f64(), 1.5);
        assert_eq!(t.saturating_sub(SimTime::from_secs(2)), SimTime::ZERO);
        assert_eq!((SimTime::from_secs(2) * 3).as_secs_f64(), 6.0);
    }

    #[test]
    fn clock_is_monotone() {
        let c = SimClock::new();
        c.advance_to(SimTime::from_secs(5));
        c.advance_to(SimTime::from_secs(3)); // stale — ignored
        assert_eq!(c.now(), SimTime::from_secs(5));
        let c2 = c.clone();
        c2.advance_by(SimTime::from_secs(1));
        assert_eq!(c.now(), SimTime::from_secs(6));
    }
}
