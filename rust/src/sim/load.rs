//! Deterministic load generators for the serving scenario family.
//!
//! Two canonical client models drive every serving experiment:
//!
//! * **Open loop** — requests arrive on their own schedule (Poisson or
//!   metronome), regardless of how the system is doing. This is internet
//!   traffic: overload does not slow the clients down, which is exactly
//!   why admission control exists.
//! * **Closed loop** — a fixed population of users, each with at most one
//!   request outstanding, re-issuing after a think time. Throughput is
//!   self-limiting (`users / (think + latency)`), the classic
//!   interactive-session model.
//!
//! Both are pure samplers over [`SimRng`], so a seeded run reproduces
//! bit-for-bit. A [`RateSchedule`] composes piecewise-constant open-loop
//! phases for scripted scenarios (ramps, flash crowds, overload storms).

use super::SimRng;

/// Open-loop arrival process at a target rate.
#[derive(Debug, Clone, Copy)]
pub struct OpenLoop {
    /// Mean arrival rate, requests per second. Must be > 0.
    pub rate_rps: f64,
    /// Poisson (exponential gaps) when true; a fixed-gap metronome when
    /// false (useful for hand-calculable tests).
    pub poisson: bool,
}

impl OpenLoop {
    /// Poisson arrivals at `rate_rps` (exponential gaps).
    pub fn poisson(rate_rps: f64) -> Self {
        Self { rate_rps, poisson: true }
    }

    /// Fixed-gap arrivals at `rate_rps` (hand-calculable timelines).
    pub fn metronome(rate_rps: f64) -> Self {
        Self { rate_rps, poisson: false }
    }

    /// Seconds until the next arrival.
    pub fn gap_s(&self, rng: &mut SimRng) -> f64 {
        debug_assert!(self.rate_rps > 0.0);
        let mean = 1.0 / self.rate_rps;
        if self.poisson {
            rng.gen_exp(mean)
        } else {
            mean
        }
    }
}

/// Closed-loop population: `users` clients, one request in flight each,
/// re-issuing `think_s` after the previous response (or shed decision).
#[derive(Debug, Clone, Copy)]
pub struct ClosedLoop {
    /// Client population size.
    pub users: usize,
    /// Seconds a user waits between a response and the next request.
    pub think_s: f64,
}

impl ClosedLoop {
    /// Upper bound on sustained throughput for a given mean latency.
    pub fn max_throughput_rps(&self, latency_s: f64) -> f64 {
        self.users as f64 / (self.think_s + latency_s).max(1e-9)
    }
}

/// Piecewise-constant open-loop rate over time: `(start_s, rate_rps)`
/// phases, sorted by start. Rate before the first phase is 0. A periodic
/// schedule ([`RateSchedule::diurnal`]) repeats its phase pattern every
/// `repeat_every_s` seconds instead of holding the last rate forever.
#[derive(Debug, Clone, Default)]
pub struct RateSchedule {
    phases: Vec<(f64, f64)>,
    repeat_every_s: Option<f64>,
}

/// Steps per period in the diurnal piecewise-constant approximation.
const DIURNAL_STEPS: usize = 12;

impl RateSchedule {
    /// Build from phases; sorts by start time.
    pub fn new(mut phases: Vec<(f64, f64)>) -> Self {
        phases.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite phase starts"));
        Self { phases, repeat_every_s: None }
    }

    /// A single constant rate from t=0.
    pub fn constant(rate_rps: f64) -> Self {
        Self { phases: vec![(0.0, rate_rps)], repeat_every_s: None }
    }

    /// A repeating day/night cycle: a raised-cosine between `trough_rps`
    /// (at t=0, the quiet phase) and `peak_rps` (half a period later),
    /// approximated by 12 piecewise-constant steps per `period_s` and
    /// repeated forever. Step `k` holds the cosine's midpoint-sampled
    /// value, so the steps bracket the continuous curve symmetrically.
    pub fn diurnal(peak_rps: f64, trough_rps: f64, period_s: f64) -> Self {
        assert!(period_s > 0.0, "diurnal period must be positive");
        let phases = (0..DIURNAL_STEPS)
            .map(|k| {
                let frac = (k as f64 + 0.5) / DIURNAL_STEPS as f64;
                let swing = (1.0 - (std::f64::consts::TAU * frac).cos()) / 2.0;
                (period_s * k as f64 / DIURNAL_STEPS as f64, trough_rps + (peak_rps - trough_rps) * swing)
            })
            .collect();
        Self { phases, repeat_every_s: Some(period_s) }
    }

    /// A flash crowd: `base_rps` everywhere except a `spike_x` multiplier
    /// during `[at_s, at_s + dur_s)`.
    pub fn flash_crowd(base_rps: f64, spike_x: f64, at_s: f64, dur_s: f64) -> Self {
        Self::new(vec![(0.0, base_rps), (at_s, base_rps * spike_x), (at_s + dur_s, base_rps)])
    }

    /// `t_s` folded into the first period of a periodic schedule.
    fn fold(&self, t_s: f64) -> f64 {
        match self.repeat_every_s {
            Some(p) if t_s >= 0.0 => t_s % p,
            _ => t_s,
        }
    }

    /// The rate in effect at time `t_s`.
    pub fn rate_at(&self, t_s: f64) -> f64 {
        let t_s = self.fold(t_s);
        let mut rate = 0.0;
        for &(start, r) in &self.phases {
            if start <= t_s {
                rate = r;
            } else {
                break;
            }
        }
        rate
    }

    /// First phase boundary strictly after `t_s` (arrival generators jump
    /// here when the current rate is zero). Periodic schedules always have
    /// a next boundary — the fold into the following period.
    pub fn next_change_after(&self, t_s: f64) -> Option<f64> {
        let Some(p) = self.repeat_every_s else {
            return self.phases.iter().map(|&(start, _)| start).find(|&start| start > t_s);
        };
        let folded = self.fold(t_s);
        match self.phases.iter().map(|&(start, _)| start).find(|&start| start > folded) {
            Some(start) => Some(t_s + (start - folded)),
            None => {
                // wrap to the first boundary of the next period
                let first = self.phases.first().map(|&(start, _)| start).unwrap_or(0.0);
                Some(t_s + (p - folded) + first)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_gap_mean_matches_rate() {
        let gen = OpenLoop::poisson(50.0);
        let mut rng = SimRng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| gen.gap_s(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 0.02).abs() < 0.001, "mean gap {mean}");
    }

    #[test]
    fn metronome_is_exact() {
        let gen = OpenLoop::metronome(4.0);
        let mut rng = SimRng::new(1);
        assert_eq!(gen.gap_s(&mut rng), 0.25);
        assert_eq!(gen.gap_s(&mut rng), 0.25);
    }

    #[test]
    fn closed_loop_throughput_bound() {
        let cl = ClosedLoop { users: 100, think_s: 0.9 };
        assert!((cl.max_throughput_rps(0.1) - 100.0).abs() < 1e-9);
        // zero think + zero latency stays finite
        let hot = ClosedLoop { users: 1, think_s: 0.0 };
        assert!(hot.max_throughput_rps(0.0).is_finite());
    }

    #[test]
    fn schedule_lookup() {
        let s = RateSchedule::new(vec![(60.0, 500.0), (0.0, 100.0), (120.0, 0.0)]);
        assert_eq!(s.rate_at(0.0), 100.0);
        assert_eq!(s.rate_at(59.9), 100.0);
        assert_eq!(s.rate_at(60.0), 500.0);
        assert_eq!(s.rate_at(119.0), 500.0);
        assert_eq!(s.rate_at(1e9), 0.0);
        assert_eq!(RateSchedule::default().rate_at(5.0), 0.0);
        assert_eq!(RateSchedule::constant(7.0).rate_at(1e6), 7.0);
    }

    #[test]
    fn flash_crowd_phase_boundaries() {
        let s = RateSchedule::flash_crowd(300.0, 10.0, 120.0, 60.0);
        assert_eq!(s.rate_at(0.0), 300.0);
        assert_eq!(s.rate_at(119.999), 300.0);
        assert_eq!(s.rate_at(120.0), 3000.0, "spike starts exactly at at_s");
        assert_eq!(s.rate_at(179.999), 3000.0);
        assert_eq!(s.rate_at(180.0), 300.0, "spike ends exactly at at_s + dur_s");
        assert_eq!(s.next_change_after(0.0), Some(120.0));
        assert_eq!(s.next_change_after(120.0), Some(180.0));
        assert_eq!(s.next_change_after(180.0), None);
    }

    #[test]
    fn diurnal_phase_boundaries_and_wrap() {
        let s = RateSchedule::diurnal(400.0, 100.0, 1200.0);
        // t=0 opens the trough-side step; midpoint sampling keeps it
        // strictly inside (trough, peak)
        let first = s.rate_at(0.0);
        assert!(first > 100.0 && first < 400.0, "first step rate {first}");
        // the peak-side step straddles period/2 and its midpoint-sampled
        // rate brackets the true peak within one step's swing
        let peak_step = s.rate_at(600.0);
        assert!(peak_step > 390.0 && peak_step <= 400.0, "peak step rate {peak_step}");
        // raised cosine is symmetric about the peak: step k mirrors step
        // 11-k (step 1 spans [100, 200), step 10 spans [1000, 1100))
        assert!((s.rate_at(100.0) - s.rate_at(1000.0)).abs() < 1e-9);
        assert!((s.rate_at(300.0) - s.rate_at(800.0)).abs() < 1e-9);
        // the pattern repeats: a full period later the same step rules
        assert_eq!(s.rate_at(1200.0), s.rate_at(0.0));
        assert_eq!(s.rate_at(1800.0 + 1200.0), s.rate_at(600.0));
        // boundary stepping walks every period edge, including the wrap
        assert_eq!(s.next_change_after(0.0), Some(100.0));
        assert_eq!(s.next_change_after(1100.0), Some(1200.0), "wraps into the next period");
        assert_eq!(s.next_change_after(1200.0), Some(1300.0));
        // never a zero-rate dead zone: the generator can always arm
        assert!(s.rate_at(1e7) > 0.0);
    }

    #[test]
    fn schedule_next_change() {
        let s = RateSchedule::new(vec![(0.0, 100.0), (60.0, 500.0), (120.0, 0.0)]);
        assert_eq!(s.next_change_after(0.0), Some(60.0));
        assert_eq!(s.next_change_after(60.0), Some(120.0));
        assert_eq!(s.next_change_after(120.0), None);
        assert_eq!(RateSchedule::default().next_change_after(0.0), None);
    }
}
