//! Deterministic discrete-event simulation core.
//!
//! Everything cloud-scale in this reproduction (110-node ETL fleets, 300-GPU
//! inference, spot preemptions) runs on *virtual time*: benches advance a
//! [`SimClock`] through an [`EventQueue`] instead of sleeping, so a
//! 28.4-day hyperparameter sweep simulates in milliseconds while remaining
//! deterministic and seedable. [`OpenLoop`] / [`ClosedLoop`] /
//! [`RateSchedule`] supply the canonical client models for the serving
//! scenarios.

#![warn(missing_docs)]

mod clock;
mod events;
mod load;
mod rng;

pub use clock::{SimClock, SimTime};
pub use events::EventQueue;
pub use load::{ClosedLoop, OpenLoop, RateSchedule};
pub use rng::SimRng;
