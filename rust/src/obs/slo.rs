//! Declarative SLOs with multi-window burn-rate alerting.
//!
//! An [`SloSpec`] states an objective — "`serve.p99_s` ≤ 0.25 over a
//! 60 s window, with a 5 % error budget" — and an [`SloMonitor`]
//! evaluates a stream of observations against it the SRE-workbook way:
//! an alert fires only when **both** a short window (1/6 of the long
//! one) and the long window burn the error budget faster than their
//! thresholds. The short window makes the alert fast *and* lets it
//! reset quickly after recovery; the long window keeps one bad blip
//! from paging.
//!
//! Transitions are emitted as `slo.breach` / `slo.recover` instant
//! events on the run's [`FlightRecorder`] (pid 0 — the controller
//! lane), so storm tests assert alert **timing** from the trace alone,
//! exactly like every other lifecycle invariant in this repo, and
//! `hyper report` renders a verdict table from the same records.
//!
//! The monitor is deliberately clock-agnostic: observations carry their
//! own `t_ns`, so virtual-time drivers feed it on engine timers (the
//! serve autoscaler tick does) and wallclock layers feed it from a
//! sampler thread.

use std::collections::VecDeque;

use crate::obs::FlightRecorder;

/// One service-level objective: a threshold on an observed metric over
/// a rolling window, with burn-rate alert thresholds.
#[derive(Debug, Clone)]
pub struct SloSpec {
    /// Name of the observed metric (attached to the breach/recover
    /// events as the `metric` arg), e.g. `"serve.p99_s"`.
    pub metric: String,
    /// Objective threshold: an observation strictly above it is "bad".
    pub target: f64,
    /// Long evaluation window, seconds. The fast window is 1/6 of it.
    pub window_s: f64,
    /// Error budget: the fraction of observations allowed to be bad
    /// (burn rate = bad fraction / budget).
    pub budget: f64,
    /// Short-window burn rate required to open a breach (fast signal).
    pub fast_burn: f64,
    /// Long-window burn rate required to open a breach (sustained
    /// signal); also the short-window rate a recovery must drop below.
    pub slow_burn: f64,
}

impl SloSpec {
    /// An objective with the standard alert shape: 5 % budget, breach
    /// at short-window burn ≥ 2 **and** long-window burn ≥ 1, recover
    /// when the short-window burn falls back below 1.
    pub fn new(metric: impl Into<String>, target: f64, window_s: f64) -> Self {
        Self {
            metric: metric.into(),
            target,
            window_s,
            budget: 0.05,
            fast_burn: 2.0,
            slow_burn: 1.0,
        }
    }
}

/// Evaluates observations against an [`SloSpec`], emitting breach /
/// recover transitions onto a [`FlightRecorder`].
#[derive(Debug)]
pub struct SloMonitor {
    spec: SloSpec,
    obs: FlightRecorder,
    /// `(t_ns, bad)` observations inside the long window.
    window: VecDeque<(u64, bool)>,
    breached: bool,
    breaches: u64,
    recoveries: u64,
}

impl SloMonitor {
    /// A monitor over `spec`, emitting transitions to `obs` (pass
    /// [`FlightRecorder::disabled`] to just track state).
    pub fn new(spec: SloSpec, obs: FlightRecorder) -> Self {
        Self { spec, obs, window: VecDeque::new(), breached: false, breaches: 0, recoveries: 0 }
    }

    /// The objective under evaluation.
    pub fn spec(&self) -> &SloSpec {
        &self.spec
    }

    /// Currently in breach?
    pub fn is_breached(&self) -> bool {
        self.breached
    }

    /// Breach transitions so far.
    pub fn breaches(&self) -> u64 {
        self.breaches
    }

    /// Recovery transitions so far.
    pub fn recoveries(&self) -> u64 {
        self.recoveries
    }

    /// Fraction of windowed observations at `t >= cutoff` that were bad.
    fn bad_frac(&self, cutoff: u64) -> f64 {
        let (mut bad, mut n) = (0u64, 0u64);
        for (t, b) in self.window.iter().rev() {
            if *t < cutoff {
                break;
            }
            n += 1;
            bad += *b as u64;
        }
        if n == 0 {
            0.0
        } else {
            bad as f64 / n as f64
        }
    }

    /// Feed one observation at `t_ns` (non-decreasing). Evaluates both
    /// burn windows and emits `slo.breach` / `slo.recover` on a state
    /// change.
    pub fn observe(&mut self, t_ns: u64, value: f64) {
        let bad = value > self.spec.target;
        self.window.push_back((t_ns, bad));
        let long_ns = (self.spec.window_s.max(0.0) * 1e9) as u64;
        let short_ns = long_ns / 6;
        let long_cutoff = t_ns.saturating_sub(long_ns);
        while self.window.front().is_some_and(|(t, _)| *t < long_cutoff) {
            self.window.pop_front();
        }
        let budget = self.spec.budget.max(1e-12);
        let burn_long = self.bad_frac(long_cutoff) / budget;
        let burn_short = self.bad_frac(t_ns.saturating_sub(short_ns)) / budget;

        if !self.breached {
            if burn_short >= self.spec.fast_burn && burn_long >= self.spec.slow_burn {
                self.breached = true;
                self.breaches += 1;
                self.obs.event_at("slo.breach", t_ns, 0, 0, vec![
                    ("metric", self.spec.metric.clone().into()),
                    ("value", value.into()),
                    ("burn_short", burn_short.into()),
                    ("burn_long", burn_long.into()),
                ]);
            }
        } else if burn_short < self.spec.slow_burn {
            self.breached = false;
            self.recoveries += 1;
            self.obs.event_at("slo.recover", t_ns, 0, 0, vec![
                ("metric", self.spec.metric.clone().into()),
                ("value", value.into()),
                ("burn_short", burn_short.into()),
            ]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::FlightRecorder;
    use crate::sim::SimClock;

    const S: u64 = 1_000_000_000;

    fn monitor(rec: &FlightRecorder) -> SloMonitor {
        // p99 ≤ 0.25 over 60 s: short window 10 s; with 5 s ticks the
        // short window holds 2-3 observations
        SloMonitor::new(SloSpec::new("p99_s", 0.25, 60.0), rec.clone())
    }

    #[test]
    fn breach_needs_both_windows_and_recover_needs_a_clean_short_window() {
        let rec = FlightRecorder::sim(64, SimClock::new());
        let mut m = monitor(&rec);
        // 12 good ticks (5 s apart): no breach
        for i in 0..12u64 {
            m.observe(i * 5 * S, 0.01);
        }
        assert!(!m.is_breached());
        assert_eq!(rec.len(), 0);
        // latency blows past the target: 1 bad of 3 in the short
        // window burns 6.7x, 1 of 13 in the long window burns 1.5x —
        // both gates pass on the first bad tick at t=60
        m.observe(60 * S, 0.9);
        assert!(m.is_breached());
        m.observe(65 * S, 0.9);
        assert_eq!(m.breaches(), 1);
        // stays breached through the incident: no duplicate events
        m.observe(70 * S, 0.9);
        m.observe(75 * S, 0.9);
        assert_eq!(m.breaches(), 1);
        // recovery: good ticks age the bad ones out of the short window
        m.observe(80 * S, 0.01);
        m.observe(85 * S, 0.01);
        m.observe(90 * S, 0.01);
        assert!(!m.is_breached());
        assert_eq!(m.recoveries(), 1);

        // the transitions are in the trace, in order, with timing
        let snap = rec.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].name, "slo.breach");
        assert_eq!(snap[0].ts_ns, 60 * S);
        assert_eq!(snap[0].arg("metric").unwrap().as_str(), Some("p99_s"));
        assert!(snap[0].arg("burn_short").unwrap().as_f64().unwrap() >= 2.0);
        assert_eq!(snap[1].name, "slo.recover");
        assert_eq!(snap[1].ts_ns, 90 * S);
    }

    #[test]
    fn one_bad_blip_in_a_healthy_run_does_not_page() {
        let rec = FlightRecorder::sim(64, SimClock::new());
        let mut m = monitor(&rec);
        for i in 0..40u64 {
            // one isolated bad observation at t=100
            let v = if i == 20 { 0.9 } else { 0.01 };
            m.observe(i * 5 * S, v);
        }
        // 1 bad of 3 in the short window = burn 6.7 ≥ 2, but it takes
        // the long window too: 1 of 13 = burn 1.5 ≥ 1... both gates
        // pass here, so shrink the budget story: what must NOT happen
        // is a breach with zero bad observations — and a breach that
        // did fire recovers as soon as the short window is clean again.
        if m.breaches() > 0 {
            assert_eq!(m.recoveries(), m.breaches(), "recovered by the end");
            assert!(!m.is_breached());
        }
    }

    #[test]
    fn sustained_low_grade_badness_breaches_the_long_window() {
        let rec = FlightRecorder::sim(256, SimClock::new());
        let mut m = monitor(&rec);
        // every observation bad: both windows saturate immediately —
        // the very first observation opens the breach and it never
        // recovers
        for i in 0..24u64 {
            m.observe(i * 5 * S, 1.0);
        }
        assert!(m.is_breached());
        assert_eq!(m.breaches(), 1);
        assert_eq!(m.recoveries(), 0);
    }

    #[test]
    fn disabled_recorder_still_tracks_state() {
        let mut m = SloMonitor::new(SloSpec::new("x", 1.0, 10.0), FlightRecorder::disabled());
        for i in 0..10u64 {
            m.observe(i * S, 2.0);
        }
        assert!(m.is_breached());
        assert_eq!(m.breaches(), 1);
    }
}
