//! Structured tracing: spans, point events, and the flight recorder.
//!
//! The paper's master aggregates application/utilization/OS logs into
//! Logstash and surfaces them in Kibana (§III.C); this module is the
//! repo's equivalent for *lifecycle* visibility. Subsystems record
//! [`Record`]s — spans (an interval with a duration) and instant events —
//! into a bounded [`FlightRecorder`] that keeps the newest N records
//! (oldest evicted, drops counted), so the end of a run is always
//! reconstructible: which node got a spot notice when, how long the drain
//! lasted, which trial resumed with which command hash, which HFS read
//! hit which cache tier.
//!
//! # Span taxonomy
//!
//! Names are dotted `subsystem.verb` literals; `docs/OBSERVABILITY.md`
//! lists the full taxonomy. The attribute model is deliberately flat:
//! every record carries a `pid` (node id; 0 = the controller/driver) and
//! a `tid` (task / trial / replica / request lane; 0 = the main lane),
//! plus a small list of named [`ArgValue`]s. That pid/tid pair maps 1:1
//! onto the Chrome trace-event process/thread axes (see [`chrome`]), so
//! an export opens in Perfetto with one track group per node and one
//! track per task.
//!
//! # Clocks
//!
//! Records are timestamped by a [`Clock`]: wallclock ([`WallClock`],
//! nanoseconds since recorder construction) for the threaded layers
//! (`ServeStack`, HFS reads), or virtual time ([`crate::sim::SimClock`])
//! for the fleet drivers. Virtual-time call sites usually know their
//! timestamps exactly and use the `*_at` forms; the scoped [`SpanGuard`]
//! reads the clock and is meant for wallclock code.
//!
//! # Consumers
//!
//! Three layers consume the recorded stream (all surfaced by
//! `hyper report`): [`analyze`] extracts the critical path and the cost
//! attribution from a snapshot, [`timeseries`] keeps bounded
//! `(t, value)` series with windowed reducers, and [`slo`] evaluates
//! declarative objectives with multi-window burn-rate alerting, feeding
//! its breach/recover transitions *back into* the recorder so alert
//! timing is assertable from the trace. [`chrome`] exports (and
//! re-imports) the Perfetto-loadable JSON.

mod ring;

pub mod analyze;
pub mod chrome;
pub mod slo;
pub mod timeseries;

pub use ring::Ring;
pub use slo::{SloMonitor, SloSpec};
pub use timeseries::{Sampler, SeriesRing, SeriesSet, SeriesSummary};

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::ObsConfig;
use crate::sim::SimClock;

/// Source of record timestamps, in nanoseconds on some monotone axis.
///
/// Implemented by [`WallClock`] (nanoseconds since construction) and
/// [`crate::sim::SimClock`] (virtual nanoseconds since sim start), so one
/// recorder type serves both the threaded and the virtual-time layers.
pub trait Clock: Send + Sync {
    /// Current time in nanoseconds since the clock's epoch.
    fn now_ns(&self) -> u64;
}

/// Wallclock [`Clock`]: monotone nanoseconds since construction.
#[derive(Debug, Clone)]
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    /// A wallclock whose epoch is now.
    pub fn new() -> Self {
        Self { epoch: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }
}

impl Clock for SimClock {
    fn now_ns(&self) -> u64 {
        self.now_ns()
    }
}

/// One record attribute value.
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    /// Unsigned integer (ids, counts, steps, hashes).
    U64(u64),
    /// Float (seconds, fills, losses).
    F64(f64),
    /// Short string (tier names, close reasons, instance types).
    Str(String),
}

impl ArgValue {
    /// The integer payload, if this is a [`ArgValue::U64`].
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            ArgValue::U64(v) => Some(*v),
            _ => None,
        }
    }

    /// The numeric payload widened to f64 (`None` for strings).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            ArgValue::U64(v) => Some(*v as f64),
            ArgValue::F64(v) => Some(*v),
            ArgValue::Str(_) => None,
        }
    }

    /// The string payload, if this is a [`ArgValue::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            ArgValue::Str(v) => Some(v),
            _ => None,
        }
    }
}

impl std::fmt::Display for ArgValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgValue::U64(v) => write!(f, "{v}"),
            ArgValue::F64(v) => write!(f, "{v:.6}"),
            ArgValue::Str(v) => write!(f, "{v}"),
        }
    }
}

impl From<u64> for ArgValue {
    fn from(v: u64) -> Self {
        ArgValue::U64(v)
    }
}

impl From<u32> for ArgValue {
    fn from(v: u32) -> Self {
        ArgValue::U64(v as u64)
    }
}

impl From<usize> for ArgValue {
    fn from(v: usize) -> Self {
        ArgValue::U64(v as u64)
    }
}

impl From<f64> for ArgValue {
    fn from(v: f64) -> Self {
        ArgValue::F64(v)
    }
}

impl From<&str> for ArgValue {
    fn from(v: &str) -> Self {
        ArgValue::Str(v.to_string())
    }
}

impl From<String> for ArgValue {
    fn from(v: String) -> Self {
        ArgValue::Str(v)
    }
}

/// Span (interval) or instant (point) — the two Chrome trace phases the
/// exporter emits (`"X"` and `"i"`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecordKind {
    /// An interval starting at `ts_ns` lasting `dur_ns`.
    Span {
        /// Interval length in nanoseconds.
        dur_ns: u64,
    },
    /// A point in time.
    Instant,
}

/// Attribute list: small, name-value, names are `&'static str` literals.
pub type Args = Vec<(&'static str, ArgValue)>;

/// One recorded span or event.
#[derive(Debug, Clone)]
pub struct Record {
    /// Monotone sequence number (total-order tiebreak for equal `ts_ns`,
    /// which virtual time produces routinely: notice and kill can share
    /// an instant but never a sequence number).
    pub seq: u64,
    /// Dotted `subsystem.verb` name (see `docs/OBSERVABILITY.md`).
    pub name: &'static str,
    /// Span-with-duration or instant.
    pub kind: RecordKind,
    /// Start (span) or occurrence (instant) time, clock nanoseconds.
    pub ts_ns: u64,
    /// Node id; 0 is the controller/driver itself.
    pub pid: u32,
    /// Task / trial / replica lane within the node; 0 is the main lane.
    pub tid: u64,
    /// Named attributes.
    pub args: Args,
}

impl Record {
    /// End time: `ts_ns` for instants, `ts_ns + dur_ns` for spans.
    pub fn end_ns(&self) -> u64 {
        match self.kind {
            RecordKind::Span { dur_ns } => self.ts_ns.saturating_add(dur_ns),
            RecordKind::Instant => self.ts_ns,
        }
    }

    /// The attribute named `key`, if present.
    pub fn arg(&self, key: &str) -> Option<&ArgValue> {
        self.args.iter().find(|(k, _)| *k == key).map(|(_, v)| v)
    }
}

struct RecorderInner {
    enabled: bool,
    clock: Box<dyn Clock>,
    ring: Mutex<Ring<Record>>,
}

/// Bounded tracing sink: records spans and events into a [`Ring`] that
/// keeps the newest `capacity` records.
///
/// Clones share state (`Arc` inside), so one recorder threads through an
/// engine, its workload, and worker threads. Lock cost per record is one
/// short `Mutex` critical section (index bump + slot write — the record
/// itself is built outside the lock); a disabled recorder short-circuits
/// before building anything, so leaving instrumentation compiled in is
/// free when tracing is off.
#[derive(Clone)]
pub struct FlightRecorder {
    inner: Arc<RecorderInner>,
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlightRecorder")
            .field("enabled", &self.inner.enabled)
            .field("len", &self.len())
            .field("dropped", &self.dropped())
            .finish()
    }
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::disabled()
    }
}

impl FlightRecorder {
    /// A recorder over an arbitrary clock.
    pub fn new(capacity: usize, clock: impl Clock + 'static) -> Self {
        Self {
            inner: Arc::new(RecorderInner {
                enabled: true,
                clock: Box::new(clock),
                ring: Mutex::new(Ring::new(capacity)),
            }),
        }
    }

    /// A wallclock recorder (epoch = now) for the threaded layers.
    pub fn wallclock(capacity: usize) -> Self {
        Self::new(capacity, WallClock::new())
    }

    /// A virtual-time recorder sharing `clock` with a sim/fleet engine.
    pub fn sim(capacity: usize, clock: SimClock) -> Self {
        Self::new(capacity, clock)
    }

    /// A recorder that records nothing (the default everywhere tracing
    /// was not explicitly attached).
    pub fn disabled() -> Self {
        Self {
            inner: Arc::new(RecorderInner {
                enabled: false,
                clock: Box::new(WallClock::new()),
                ring: Mutex::new(Ring::new(1)),
            }),
        }
    }

    /// Build from [`ObsConfig`]: wallclock recorder, or disabled.
    pub fn from_config(cfg: &ObsConfig) -> Self {
        if cfg.enabled {
            Self::wallclock(cfg.capacity)
        } else {
            Self::disabled()
        }
    }

    /// Is this recorder recording?
    pub fn is_enabled(&self) -> bool {
        self.inner.enabled
    }

    /// Current clock reading, nanoseconds.
    pub fn now_ns(&self) -> u64 {
        self.inner.clock.now_ns()
    }

    fn push(&self, name: &'static str, kind: RecordKind, ts_ns: u64, pid: u32, tid: u64, args: Args) {
        let mut ring = self.inner.ring.lock().unwrap();
        let seq = ring.pushed();
        ring.push(Record { seq, name, kind, ts_ns, pid, tid, args });
    }

    /// Record an instant event stamped by the recorder's clock.
    pub fn event(&self, name: &'static str, pid: u32, tid: u64, args: Args) {
        if !self.inner.enabled {
            return;
        }
        self.push(name, RecordKind::Instant, self.now_ns(), pid, tid, args);
    }

    /// Record an instant event at an explicit timestamp (virtual-time
    /// call sites stamp with the engine's own `now`).
    pub fn event_at(&self, name: &'static str, ts_ns: u64, pid: u32, tid: u64, args: Args) {
        if !self.inner.enabled {
            return;
        }
        self.push(name, RecordKind::Instant, ts_ns, pid, tid, args);
    }

    /// Record a completed span over `[start_ns, end_ns]` (an inverted
    /// interval records with zero duration rather than panicking).
    pub fn span_at(
        &self,
        name: &'static str,
        start_ns: u64,
        end_ns: u64,
        pid: u32,
        tid: u64,
        args: Args,
    ) {
        if !self.inner.enabled {
            return;
        }
        let dur_ns = end_ns.saturating_sub(start_ns);
        self.push(name, RecordKind::Span { dur_ns }, start_ns, pid, tid, args);
    }

    /// Open a scoped span that records on drop (wallclock call sites).
    pub fn span(&self, name: &'static str, pid: u32, tid: u64, args: Args) -> SpanGuard {
        if !self.inner.enabled {
            return SpanGuard { rec: None, name, start_ns: 0, pid, tid, args: Vec::new() };
        }
        SpanGuard { start_ns: self.now_ns(), rec: Some(self.clone()), name, pid, tid, args }
    }

    /// Total records ever submitted (retained + dropped).
    pub fn recorded(&self) -> u64 {
        self.inner.ring.lock().unwrap().pushed()
    }

    /// Records evicted by the ring to bound memory.
    pub fn dropped(&self) -> u64 {
        self.inner.ring.lock().unwrap().dropped()
    }

    /// Records currently retained.
    pub fn len(&self) -> usize {
        self.inner.ring.lock().unwrap().len()
    }

    /// No records retained?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Clone of the retained records, oldest → newest by sequence.
    pub fn snapshot(&self) -> Vec<Record> {
        self.inner.ring.lock().unwrap().snapshot()
    }

    /// Drop all retained records and reset the drop accounting.
    pub fn clear(&self) {
        self.inner.ring.lock().unwrap().clear();
    }
}

/// Scoped span: opened by [`FlightRecorder::span`], records its interval
/// when dropped. Attributes added via [`SpanGuard::arg`] after opening
/// (e.g. a batch's close reason, known only at close) ride along.
pub struct SpanGuard {
    rec: Option<FlightRecorder>,
    name: &'static str,
    start_ns: u64,
    pid: u32,
    tid: u64,
    args: Args,
}

impl SpanGuard {
    /// Attach an attribute discovered while the span was open.
    pub fn arg(&mut self, key: &'static str, value: impl Into<ArgValue>) {
        if self.rec.is_some() {
            self.args.push((key, value.into()));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(rec) = self.rec.take() {
            let end = rec.now_ns();
            rec.span_at(self.name, self.start_ns, end, self.pid, self.tid, std::mem::take(&mut self.args));
        }
    }
}

/// FNV-1a 64-bit hash of a string — the stable "command hash" attached to
/// trial run/resume spans so a resume can be checked (from the trace
/// alone) to continue the byte-identical command of the original attempt.
pub fn hash64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A stable per-thread track id for wallclock spans: distinct OS threads
/// get distinct non-zero tids (cached thread-locally), so concurrent
/// reads render as parallel tracks instead of one self-overlapping one.
pub fn thread_tid() -> u64 {
    use std::cell::Cell;
    thread_local! {
        static TID: Cell<u64> = const { Cell::new(0) };
    }
    TID.with(|t| {
        let mut v = t.get();
        if v == 0 {
            v = hash64(&format!("{:?}", std::thread::current().id())).max(1);
            t.set(v);
        }
        v
    })
}

/// Render records as a human-readable merged timeline, sorted by start
/// time (sequence number breaks virtual-time ties): one line per record,
/// `[seconds] pid/tid name (+duration) key=value ...`.
pub fn render_timeline(records: &[Record]) -> String {
    let mut sorted: Vec<&Record> = records.iter().collect();
    sorted.sort_by_key(|r| (r.ts_ns, r.seq));
    let mut out = String::new();
    for r in sorted {
        let ts_s = r.ts_ns as f64 / 1e9;
        out.push_str(&format!("[{ts_s:>12.6}s] p{:<4} t{:<4} {:<26}", r.pid, r.tid, r.name));
        if let RecordKind::Span { dur_ns } = r.kind {
            out.push_str(&format!(" +{:.6}s", dur_ns as f64 / 1e9));
        }
        for (k, v) in &r.args {
            out.push_str(&format!(" {k}={v}"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_and_spans_record_in_order() {
        let rec = FlightRecorder::sim(16, SimClock::new());
        rec.event_at("a", 10, 1, 0, vec![]);
        rec.span_at("b", 20, 50, 2, 7, vec![("tier", "ram".into())]);
        let snap = rec.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].name, "a");
        assert_eq!(snap[0].kind, RecordKind::Instant);
        assert_eq!(snap[1].kind, RecordKind::Span { dur_ns: 30 });
        assert_eq!(snap[1].end_ns(), 50);
        assert_eq!(snap[1].pid, 2);
        assert_eq!(snap[1].tid, 7);
        assert_eq!(snap[1].arg("tier"), Some(&ArgValue::Str("ram".into())));
        assert_eq!(snap[1].arg("missing"), None);
        assert!(snap[0].seq < snap[1].seq);
    }

    #[test]
    fn flight_recorder_bounded_at_10x_capacity() {
        // ISSUE acceptance: emitting 10x capacity retains exactly the
        // newest `capacity` records and reports the drop count
        let cap = 32;
        let rec = FlightRecorder::sim(cap, SimClock::new());
        for i in 0..(10 * cap as u64) {
            rec.event_at("tick", i, 0, 0, vec![("i", i.into())]);
        }
        assert_eq!(rec.len(), cap);
        assert_eq!(rec.recorded(), 10 * cap as u64);
        assert_eq!(rec.dropped(), 9 * cap as u64);
        let snap = rec.snapshot();
        assert_eq!(snap.first().unwrap().ts_ns, 9 * cap as u64, "oldest survivor");
        assert_eq!(snap.last().unwrap().ts_ns, 10 * cap as u64 - 1, "newest");
        // order preserved across the wrap
        for w in snap.windows(2) {
            assert!(w[0].seq + 1 == w[1].seq);
        }
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let rec = FlightRecorder::disabled();
        assert!(!rec.is_enabled());
        rec.event("e", 0, 0, vec![]);
        rec.span_at("s", 0, 10, 0, 0, vec![]);
        {
            let mut g = rec.span("scoped", 0, 0, vec![]);
            g.arg("k", 1u64);
        }
        assert!(rec.is_empty());
        assert_eq!(rec.recorded(), 0);
        assert_eq!(rec.dropped(), 0);
    }

    #[test]
    fn from_config_respects_enabled_flag() {
        let off = FlightRecorder::from_config(&ObsConfig { enabled: false, ..Default::default() });
        off.event("e", 0, 0, vec![]);
        assert_eq!(off.recorded(), 0);
        let on = FlightRecorder::from_config(&ObsConfig::default());
        on.event("e", 0, 0, vec![]);
        assert_eq!(on.recorded(), 1);
    }

    #[test]
    fn scoped_span_records_on_drop_with_late_args() {
        let rec = FlightRecorder::wallclock(8);
        {
            let mut g = rec.span("work", 3, 9, vec![("a", 1u64.into())]);
            g.arg("close", "deadline");
        }
        let snap = rec.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].name, "work");
        assert!(matches!(snap[0].kind, RecordKind::Span { .. }));
        assert_eq!(snap[0].arg("close"), Some(&ArgValue::Str("deadline".into())));
        assert_eq!(snap[0].arg("a"), Some(&ArgValue::U64(1)));
    }

    #[test]
    fn sim_clock_drives_timestamps() {
        let clk = SimClock::new();
        let rec = FlightRecorder::sim(8, clk.clone());
        clk.advance_to(crate::sim::SimTime::from_secs(3));
        rec.event("e", 0, 0, vec![]);
        assert_eq!(rec.snapshot()[0].ts_ns, 3_000_000_000);
    }

    #[test]
    fn hash64_is_stable_and_discriminating() {
        assert_eq!(hash64("train --lr 0.01"), hash64("train --lr 0.01"));
        assert_ne!(hash64("train --lr 0.01"), hash64("train --lr 0.02"));
        assert_ne!(hash64(""), hash64(" "));
    }

    #[test]
    fn timeline_sorts_by_time_then_seq() {
        let rec = FlightRecorder::sim(8, SimClock::new());
        rec.event_at("later", 2_000_000_000, 1, 0, vec![]);
        rec.event_at("notice", 1_000_000_000, 2, 0, vec![]);
        rec.event_at("kill", 1_000_000_000, 2, 0, vec![("cause", "storm".into())]);
        let text = render_timeline(&rec.snapshot());
        let notice = text.find("notice").unwrap();
        let kill = text.find("kill").unwrap();
        let later = text.find("later").unwrap();
        assert!(notice < kill, "same instant orders by seq");
        assert!(kill < later);
        assert!(text.contains("cause=storm"));
    }

    #[test]
    fn concurrent_recording_conserves_counts() {
        let rec = FlightRecorder::wallclock(256);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let rec = rec.clone();
                s.spawn(move || {
                    for i in 0..100u64 {
                        rec.event("e", 0, t, vec![("i", i.into())]);
                    }
                });
            }
        });
        assert_eq!(rec.recorded(), 400);
        assert_eq!(rec.len() as u64 + rec.dropped(), 400);
        assert_eq!(rec.len(), 256);
    }

    #[test]
    fn hammer_concurrent_push_with_snapshotting_reader_conserves_counts() {
        // ISSUE satellite: dropped-count exactness across wraparound
        // while a reader snapshots. 4 writers push 4 * 2000 records
        // through a 64-slot ring while a reader snapshots continuously;
        // every snapshot must be internally consistent (contiguous
        // ascending seqs, <= capacity) and the final accounting exact.
        let cap = 64;
        let writers = 4u64;
        let per = 2000u64;
        let rec = FlightRecorder::wallclock(cap);
        std::thread::scope(|s| {
            for t in 0..writers {
                let rec = rec.clone();
                s.spawn(move || {
                    for i in 0..per {
                        rec.event("w", 0, t, vec![("i", i.into())]);
                    }
                });
            }
            let reader = rec.clone();
            s.spawn(move || {
                loop {
                    let snap = reader.snapshot();
                    assert!(snap.len() <= cap, "snapshot over capacity: {}", snap.len());
                    for w in snap.windows(2) {
                        assert_eq!(
                            w[0].seq + 1,
                            w[1].seq,
                            "snapshot seqs must be contiguous ascending"
                        );
                    }
                    if reader.recorded() >= writers * per {
                        break;
                    }
                    std::thread::yield_now();
                }
            });
        });
        assert_eq!(rec.recorded(), writers * per);
        assert_eq!(rec.len(), cap);
        assert_eq!(rec.len() as u64 + rec.dropped(), rec.recorded(), "conservation");
        // the survivors are exactly the newest `cap` seqs
        let snap = rec.snapshot();
        assert_eq!(snap.first().unwrap().seq, writers * per - cap as u64);
        assert_eq!(snap.last().unwrap().seq, writers * per - 1);
    }

    #[test]
    fn thread_tids_are_stable_and_distinct_across_threads() {
        let here = thread_tid();
        assert_ne!(here, 0);
        assert_eq!(here, thread_tid(), "cached per thread");
        let other = std::thread::spawn(thread_tid).join().unwrap();
        assert_ne!(other, 0);
        assert_ne!(here, other);
    }
}
