//! Bounded time-series: `(t, value)` sample rings with windowed reducers.
//!
//! The flight recorder answers "what happened, in order"; this layer
//! answers "how did X move over the run" — goodput over time, live nodes
//! over time, p99 over time. A [`SeriesRing`] is a [`Ring`] of
//! `(t_ns, value)` samples (newest retained, drops counted, exactly the
//! flight-recorder overflow policy), and a [`SeriesSet`] is a named,
//! share-by-clone collection of them that both time domains feed:
//!
//! * **virtual time** — drivers push samples on engine timers with the
//!   sim's own timestamps ([`SeriesSet::push`]), so a traced run stays
//!   bit-identical to an untraced one;
//! * **wallclock** — a [`Sampler`] thread polls a live
//!   [`MetricsRegistry`] every period and records each counter, gauge,
//!   and histogram percentile as a sample
//!   ([`SeriesSet::sample_registry`]).
//!
//! Reducers are windowed over the *trailing* `window_ns` of the newest
//! sample — mean, nearest-rank percentile, per-second rate (for
//! cumulative counters), and an irregular-interval EWMA — so "p99 over
//! the last 60 s" works identically for both clocks.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::metrics::MetricsRegistry;
use crate::obs::ring::Ring;
use crate::obs::{Clock, WallClock};

/// A bounded series of `(t_ns, value)` samples with windowed reducers.
///
/// Samples must be pushed in non-decreasing time order (both feeders
/// are monotone); reducers assume it.
#[derive(Debug, Clone)]
pub struct SeriesRing {
    ring: Ring<(u64, f64)>,
}

impl SeriesRing {
    /// An empty series retaining at most `capacity` samples (min 1).
    pub fn new(capacity: usize) -> Self {
        Self { ring: Ring::new(capacity) }
    }

    /// Append a sample.
    pub fn push(&mut self, t_ns: u64, value: f64) {
        self.ring.push((t_ns, value));
    }

    /// Samples retained.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    /// No samples retained?
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Samples evicted to bound memory.
    pub fn dropped(&self) -> u64 {
        self.ring.dropped()
    }

    /// The newest sample.
    pub fn last(&self) -> Option<(u64, f64)> {
        self.ring.iter().last().copied()
    }

    /// Clone of the retained samples, oldest → newest.
    pub fn samples(&self) -> Vec<(u64, f64)> {
        self.ring.snapshot()
    }

    /// Retained samples inside the trailing window: `t` within
    /// `window_ns` of the newest sample (inclusive).
    fn window(&self, window_ns: u64) -> impl Iterator<Item = (u64, f64)> + '_ {
        let cutoff = self.last().map(|(t, _)| t.saturating_sub(window_ns)).unwrap_or(0);
        self.ring.iter().copied().filter(move |(t, _)| *t >= cutoff)
    }

    /// Mean value over the trailing window; `None` when empty.
    pub fn mean(&self, window_ns: u64) -> Option<f64> {
        let (mut sum, mut n) = (0.0, 0u64);
        for (_, v) in self.window(window_ns) {
            sum += v;
            n += 1;
        }
        (n > 0).then(|| sum / n as f64)
    }

    /// Nearest-rank percentile (`q` in `[0, 1]`) over the trailing
    /// window; `None` when empty.
    pub fn percentile(&self, q: f64, window_ns: u64) -> Option<f64> {
        let mut vals: Vec<f64> = self.window(window_ns).map(|(_, v)| v).collect();
        if vals.is_empty() {
            return None;
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let idx = ((q.clamp(0.0, 1.0)) * (vals.len() - 1) as f64).round() as usize;
        Some(vals[idx])
    }

    /// Per-second growth rate of a cumulative series over the trailing
    /// window: `(v_last - v_first) / (t_last - t_first)`. `None` with
    /// fewer than two samples or zero elapsed time.
    pub fn rate_per_s(&self, window_ns: u64) -> Option<f64> {
        let mut it = self.window(window_ns);
        let first = it.next()?;
        let last = it.last()?;
        let dt_s = (last.0.saturating_sub(first.0)) as f64 / 1e9;
        (dt_s > 0.0).then(|| (last.1 - first.1) / dt_s)
    }

    /// Irregular-interval EWMA over the whole retained series: each
    /// step decays the running value by `0.5^(dt / half_life_ns)`, so
    /// unevenly spaced samples weight by age, not by count. `None` when
    /// empty.
    pub fn ewma(&self, half_life_ns: u64) -> Option<f64> {
        let hl = half_life_ns.max(1) as f64;
        let mut it = self.ring.iter().copied();
        let (mut t_prev, mut acc) = it.next()?;
        for (t, v) in it {
            let w = 0.5f64.powf((t.saturating_sub(t_prev)) as f64 / hl);
            acc = acc * w + v * (1.0 - w);
            t_prev = t;
        }
        Some(acc)
    }
}

/// One row of [`SeriesSet::summaries`]: the windowed reducers of one
/// named series, ready to render.
#[derive(Debug, Clone)]
pub struct SeriesSummary {
    /// Series name.
    pub name: String,
    /// Samples retained.
    pub len: usize,
    /// Samples evicted.
    pub dropped: u64,
    /// Newest value.
    pub last: f64,
    /// Windowed mean.
    pub mean: f64,
    /// Windowed nearest-rank p99.
    pub p99: f64,
}

struct SeriesSetInner {
    enabled: bool,
    capacity: usize,
    series: Mutex<std::collections::BTreeMap<String, SeriesRing>>,
}

/// A named collection of [`SeriesRing`]s. Clones share state (`Arc`
/// inside), mirroring [`crate::obs::FlightRecorder`]: one set threads
/// through a driver and its sampler. A disabled set
/// ([`SeriesSet::disabled`]) drops every push on one boolean check.
#[derive(Clone)]
pub struct SeriesSet {
    inner: Arc<SeriesSetInner>,
}

impl std::fmt::Debug for SeriesSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SeriesSet")
            .field("enabled", &self.inner.enabled)
            .field("names", &self.names())
            .finish()
    }
}

impl Default for SeriesSet {
    fn default() -> Self {
        Self::disabled()
    }
}

impl SeriesSet {
    /// An enabled set whose series each retain `capacity` samples.
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Arc::new(SeriesSetInner {
                enabled: true,
                capacity: capacity.max(1),
                series: Mutex::new(std::collections::BTreeMap::new()),
            }),
        }
    }

    /// A set that records nothing (the default everywhere a series set
    /// was not explicitly attached).
    pub fn disabled() -> Self {
        Self {
            inner: Arc::new(SeriesSetInner {
                enabled: false,
                capacity: 1,
                series: Mutex::new(std::collections::BTreeMap::new()),
            }),
        }
    }

    /// Is this set recording?
    pub fn is_enabled(&self) -> bool {
        self.inner.enabled
    }

    /// Append a sample to the series named `name` (created on first
    /// touch). No-op when disabled.
    pub fn push(&self, name: &str, t_ns: u64, value: f64) {
        if !self.inner.enabled {
            return;
        }
        let mut map = self.inner.series.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| SeriesRing::new(self.inner.capacity))
            .push(t_ns, value);
    }

    /// Names of every recorded series, sorted.
    pub fn names(&self) -> Vec<String> {
        self.inner.series.lock().unwrap().keys().cloned().collect()
    }

    /// Clone of the series named `name`, if recorded.
    pub fn get(&self, name: &str) -> Option<SeriesRing> {
        self.inner.series.lock().unwrap().get(name).cloned()
    }

    /// Sample every value a [`MetricsRegistry`] currently exposes
    /// (counters, gauges, float gauges, and histogram `p50`/`p99`/
    /// `count` — see [`MetricsRegistry::sample_values`]) at time `t_ns`.
    pub fn sample_registry(&self, t_ns: u64, reg: &MetricsRegistry) {
        if !self.inner.enabled {
            return;
        }
        for (name, value) in reg.sample_values() {
            self.push(&name, t_ns, value);
        }
    }

    /// Windowed reducer summary of every series, sorted by name.
    pub fn summaries(&self, window_ns: u64) -> Vec<SeriesSummary> {
        let map = self.inner.series.lock().unwrap();
        map.iter()
            .filter_map(|(name, s)| {
                let (_, last) = s.last()?;
                Some(SeriesSummary {
                    name: name.clone(),
                    len: s.len(),
                    dropped: s.dropped(),
                    last,
                    mean: s.mean(window_ns).unwrap_or(last),
                    p99: s.percentile(0.99, window_ns).unwrap_or(last),
                })
            })
            .collect()
    }
}

/// Wallclock feeder: a background thread that polls a
/// [`MetricsRegistry`] into a [`SeriesSet`] every `period` until
/// stopped (or dropped). The virtual-time drivers never need this —
/// they push on engine timers — but the threaded layers (`ServeStack`,
/// HFS) have no timer loop of their own.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Sampler {
    /// Start sampling `reg` into `set` every `period`. One sample is
    /// taken immediately; timestamps are wallclock nanoseconds since
    /// the sampler started.
    pub fn start(set: SeriesSet, reg: MetricsRegistry, period: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let clock = WallClock::new();
            while !stop2.load(Ordering::Relaxed) {
                set.sample_registry(clock.now_ns(), &reg);
                std::thread::sleep(period);
            }
        });
        Self { stop, handle: Some(handle) }
    }

    /// Stop the sampling thread and wait for it to exit.
    pub fn stop(mut self) {
        self.join();
    }

    fn join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series(samples: &[(u64, f64)]) -> SeriesRing {
        let mut s = SeriesRing::new(1024);
        for (t, v) in samples {
            s.push(*t, *v);
        }
        s
    }

    #[test]
    fn windowed_mean_and_percentile() {
        // samples at 0..10 s, values 0..10; a 4 s window sees 6..10
        let s = series(&(0..=10).map(|i| (i * 1_000_000_000, i as f64)).collect::<Vec<_>>());
        assert_eq!(s.mean(4_000_000_000), Some(8.0));
        assert_eq!(s.percentile(1.0, 4_000_000_000), Some(10.0));
        assert_eq!(s.percentile(0.0, 4_000_000_000), Some(6.0));
        // whole-series reducers via a huge window
        assert_eq!(s.mean(u64::MAX), Some(5.0));
        assert_eq!(s.last(), Some((10_000_000_000, 10.0)));
    }

    #[test]
    fn rate_of_a_cumulative_counter() {
        // a counter climbing 7/s sampled every second
        let s = series(&(0..=10).map(|i| (i * 1_000_000_000, (7 * i) as f64)).collect::<Vec<_>>());
        let r = s.rate_per_s(u64::MAX).unwrap();
        assert!((r - 7.0).abs() < 1e-9, "{r}");
        // windowed rate uses only the trailing samples
        let r4 = s.rate_per_s(4_000_000_000).unwrap();
        assert!((r4 - 7.0).abs() < 1e-9, "{r4}");
        assert_eq!(series(&[(0, 1.0)]).rate_per_s(u64::MAX), None, "one sample has no rate");
    }

    #[test]
    fn ewma_decays_toward_recent_values() {
        let s = series(&[(0, 0.0), (1_000_000_000, 100.0)]);
        // dt == half-life: acc = 0*0.5 + 100*0.5
        assert_eq!(s.ewma(1_000_000_000), Some(50.0));
        // a long gap forgets the old value almost entirely
        let s = series(&[(0, 1000.0), (100_000_000_000, 1.0)]);
        let e = s.ewma(1_000_000_000).unwrap();
        assert!(e < 1.001, "{e}");
    }

    #[test]
    fn ring_bound_applies_per_series() {
        let set = SeriesSet::new(4);
        for i in 0..10u64 {
            set.push("x", i, i as f64);
        }
        let s = set.get("x").unwrap();
        assert_eq!(s.len(), 4);
        assert_eq!(s.dropped(), 6);
        assert_eq!(s.samples(), vec![(6, 6.0), (7, 7.0), (8, 8.0), (9, 9.0)]);
    }

    #[test]
    fn disabled_set_records_nothing() {
        let set = SeriesSet::disabled();
        assert!(!set.is_enabled());
        set.push("x", 0, 1.0);
        set.sample_registry(0, &MetricsRegistry::new());
        assert!(set.names().is_empty());
        assert!(set.get("x").is_none());
    }

    #[test]
    fn registry_sampling_records_counters_gauges_and_histogram_percentiles() {
        let reg = MetricsRegistry::new();
        reg.counter("reqs").add(42);
        reg.gauge("live").set(3);
        reg.float_gauge("frac").set(0.5);
        for i in 1..=100 {
            reg.histogram("lat").record(i as f64);
        }
        let set = SeriesSet::new(16);
        set.sample_registry(1_000, &reg);
        assert_eq!(set.get("reqs").unwrap().last(), Some((1_000, 42.0)));
        assert_eq!(set.get("live").unwrap().last(), Some((1_000, 3.0)));
        assert_eq!(set.get("frac").unwrap().last(), Some((1_000, 0.5)));
        assert_eq!(set.get("lat.count").unwrap().last(), Some((1_000, 100.0)));
        let (_, p99) = set.get("lat.p99").unwrap().last().unwrap();
        assert!(p99 >= 90.0, "{p99}");
        // summaries cover every series
        let sums = set.summaries(u64::MAX);
        assert_eq!(sums.len(), set.names().len());
        assert!(sums.iter().any(|s| s.name == "reqs" && s.last == 42.0));
    }

    #[test]
    fn sampler_thread_feeds_the_set_until_stopped() {
        let reg = MetricsRegistry::new();
        reg.counter("ticks").inc();
        let set = SeriesSet::new(1024);
        let sampler = Sampler::start(set.clone(), reg.clone(), Duration::from_millis(2));
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while set.get("ticks").map(|s| s.len()).unwrap_or(0) < 3 {
            assert!(std::time::Instant::now() < deadline, "sampler never sampled");
            std::thread::sleep(Duration::from_millis(2));
        }
        sampler.stop();
        let n = set.get("ticks").unwrap().len();
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(set.get("ticks").unwrap().len(), n, "stopped sampler stays stopped");
    }
}
