//! Bounded ring buffer with flight-recorder semantics: when full, the
//! *oldest* item is evicted so the buffer always holds the newest
//! `capacity` items — the end of a run (the part you debug) survives, the
//! beginning ages out. A `dropped` counter records how many items were
//! evicted, so "the trace is truncated" is a visible fact, not a silent
//! lie.
//!
//! This is the storage primitive under both [`super::FlightRecorder`]
//! (span/event records) and [`crate::cluster::LogCollector`] (the
//! Logstash stand-in), which share the same overflow policy.

/// Fixed-capacity ring keeping the newest `capacity` items pushed.
///
/// Not internally synchronized — wrap in a `Mutex` for shared use (the
/// callers above do). Push is O(1) and allocation-free once the buffer
/// has filled.
#[derive(Debug, Clone)]
pub struct Ring<T> {
    /// Backing storage; grows up to `capacity`, then slots are reused.
    slots: Vec<T>,
    /// Maximum retained items (>= 1).
    capacity: usize,
    /// Total items ever pushed; `pushed % capacity` is the next slot.
    pushed: u64,
}

impl<T> Ring<T> {
    /// An empty ring retaining at most `capacity` items (min 1).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self { slots: Vec::new(), capacity, pushed: 0 }
    }

    /// Append `item`, evicting the oldest retained item if full. Returns
    /// the item's sequence number (0-based, monotone across evictions).
    pub fn push(&mut self, item: T) -> u64 {
        let seq = self.pushed;
        if self.slots.len() < self.capacity {
            // growth phase: pushed == slots.len(), so the orders agree
            self.slots.push(item);
        } else {
            self.slots[(seq % self.capacity as u64) as usize] = item;
        }
        self.pushed += 1;
        seq
    }

    /// Retained items (<= capacity).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// No items retained?
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Maximum retained items.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total items ever pushed (retained + dropped).
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// Items evicted to make room (flight-recorder drop count).
    pub fn dropped(&self) -> u64 {
        self.pushed - self.slots.len() as u64
    }

    /// Iterate retained items oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        // once wrapped, the oldest retained item sits at the next write
        // slot; before wrapping that index is 0
        let split = if self.slots.len() < self.capacity {
            0
        } else {
            (self.pushed % self.capacity as u64) as usize
        };
        self.slots[split..].iter().chain(self.slots[..split].iter())
    }

    /// Drop every retained item and reset the push/drop accounting.
    pub fn clear(&mut self) {
        self.slots.clear();
        self.pushed = 0;
    }
}

impl<T: Clone> Ring<T> {
    /// Clone of the retained items, oldest → newest.
    pub fn snapshot(&self) -> Vec<T> {
        self.iter().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_everything_under_capacity() {
        let mut r = Ring::new(8);
        for i in 0..5 {
            assert_eq!(r.push(i), i);
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.dropped(), 0);
        assert_eq!(r.snapshot(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn wraparound_keeps_exactly_newest_n_with_drop_count() {
        // 10x capacity: retain exactly the newest `capacity`, count drops
        let mut r = Ring::new(4);
        for i in 0..40u64 {
            r.push(i);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.pushed(), 40);
        assert_eq!(r.dropped(), 36);
        assert_eq!(r.snapshot(), vec![36, 37, 38, 39], "newest, in order");
    }

    #[test]
    fn order_is_oldest_to_newest_at_every_fill_level() {
        let mut r = Ring::new(3);
        let mut expect = Vec::new();
        for i in 0..10 {
            r.push(i);
            expect.push(i);
            let keep = expect.len().saturating_sub(3);
            assert_eq!(r.snapshot(), expect[keep..].to_vec(), "after push {i}");
        }
    }

    #[test]
    fn capacity_zero_behaves_as_one() {
        let mut r = Ring::new(0);
        r.push("a");
        r.push("b");
        assert_eq!(r.capacity(), 1);
        assert_eq!(r.snapshot(), vec!["b"]);
        assert_eq!(r.dropped(), 1);
    }

    #[test]
    fn clear_resets_accounting() {
        let mut r = Ring::new(2);
        for i in 0..5 {
            r.push(i);
        }
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.pushed(), 0);
        assert_eq!(r.dropped(), 0);
        r.push(9);
        assert_eq!(r.snapshot(), vec![9]);
    }
}
