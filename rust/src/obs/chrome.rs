//! Chrome trace-event export: turn flight-recorder records into the JSON
//! object format `chrome://tracing` and Perfetto load directly.
//!
//! Mapping (see `docs/OBSERVABILITY.md` for the viewing walkthrough):
//!
//! * one **pid** per node (pid 0 = the controller/driver), named via
//!   `process_name` metadata events so Perfetto's track groups read
//!   `node-3`, not `3`;
//! * one **tid** per task / trial / replica lane within the node;
//! * [`RecordKind::Span`] → phase `"X"` (complete event, `ts` + `dur`);
//! * [`RecordKind::Instant`] → phase `"i"`, thread-scoped;
//! * timestamps are microseconds (the trace-event unit), converted from
//!   the recorder's nanoseconds — always finite and non-negative because
//!   the source is `u64`.

use std::path::Path;

use crate::obs::{ArgValue, Record, RecordKind};
use crate::util::Json;
use crate::Result;

fn arg_json(v: &ArgValue) -> Json {
    match v {
        ArgValue::U64(n) => Json::num(*n as f64),
        ArgValue::F64(n) if n.is_finite() => Json::num(*n),
        // non-finite floats would poison the JSON; stringify them
        ArgValue::F64(n) => Json::str(format!("{n}")),
        ArgValue::Str(s) => Json::str(s.clone()),
    }
}

/// Build the Chrome trace-event JSON document for `records`.
///
/// Returns `{"displayTimeUnit": "ms", "traceEvents": [...]}` with one
/// `process_name` metadata event per distinct pid followed by the records
/// sorted by start time (sequence number breaks ties).
pub fn chrome_trace(records: &[Record]) -> Json {
    let mut sorted: Vec<&Record> = records.iter().collect();
    sorted.sort_by_key(|r| (r.ts_ns, r.seq));

    let mut events = Vec::new();
    let mut pids: Vec<u32> = sorted.iter().map(|r| r.pid).collect();
    pids.sort_unstable();
    pids.dedup();
    for pid in pids {
        let name = if pid == 0 { "controller".to_string() } else { format!("node-{pid}") };
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("process_name")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("name", Json::str(name))])),
        ]));
    }

    for r in sorted {
        let ts_us = r.ts_ns as f64 / 1e3;
        let args =
            Json::Obj(r.args.iter().map(|(k, v)| (k.to_string(), arg_json(v))).collect());
        let mut fields = vec![
            ("name", Json::str(r.name)),
            ("cat", Json::str(category(r.name))),
            ("ts", Json::num(ts_us)),
            ("pid", Json::num(r.pid as f64)),
            ("tid", Json::num(r.tid as f64)),
            ("args", args),
        ];
        match r.kind {
            RecordKind::Span { dur_ns } => {
                fields.push(("ph", Json::str("X")));
                fields.push(("dur", Json::num(dur_ns as f64 / 1e3)));
            }
            RecordKind::Instant => {
                fields.push(("ph", Json::str("i")));
                fields.push(("s", Json::str("t")));
            }
        }
        events.push(Json::obj(fields));
    }

    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Category = the leading `subsystem.` segment of the record name (the
/// whole name when undotted); Perfetto filters on it.
fn category(name: &str) -> &str {
    name.split('.').next().unwrap_or(name)
}

/// Serialize [`chrome_trace`] for `records` and write it to `path`.
pub fn write_chrome_trace(path: &Path, records: &[Record]) -> Result<()> {
    std::fs::write(path, chrome_trace(records).to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::FlightRecorder;
    use crate::sim::SimClock;

    fn sample() -> Vec<Record> {
        let rec = FlightRecorder::sim(16, SimClock::new());
        rec.event_at("node.notice", 60_000_000_000, 3, 0, vec![("cause", "storm".into())]);
        rec.span_at(
            "node.drain",
            60_000_000_000,
            61_500_000_000,
            3,
            0,
            vec![("checkpointed", 1u64.into())],
        );
        rec.event_at("node.kill", 61_500_000_000, 3, 0, vec![]);
        rec.span_at("trial.run", 10_000_000_000, 30_000_000_000, 2, 7, vec![
            ("command_hash", 0xdeadbeefu64.into()),
            ("loss", 0.73.into()),
        ]);
        rec.snapshot()
    }

    #[test]
    fn export_roundtrips_through_util_json_with_finite_nonneg_times() {
        // ISSUE satellite: the export must survive a parse round-trip and
        // every ts/dur must be finite and non-negative
        let doc = chrome_trace(&sample());
        let text = doc.to_string();
        let back = Json::parse(&text).expect("exporter emits valid JSON");
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        let mut spans = 0;
        let mut instants = 0;
        for e in events {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            let ts = e.get("ts").map(|t| t.as_f64().unwrap());
            match ph {
                "M" => continue,
                "X" => {
                    spans += 1;
                    let dur = e.get("dur").unwrap().as_f64().unwrap();
                    assert!(dur.is_finite() && dur >= 0.0, "dur={dur}");
                }
                "i" => {
                    instants += 1;
                    assert_eq!(e.get("s").unwrap().as_str().unwrap(), "t");
                }
                other => panic!("unexpected phase {other}"),
            }
            let ts = ts.expect("every non-metadata event has ts");
            assert!(ts.is_finite() && ts >= 0.0, "ts={ts}");
            assert!(e.get("pid").unwrap().as_u64().is_some());
            assert!(e.get("tid").unwrap().as_u64().is_some());
        }
        assert_eq!(spans, 2);
        assert_eq!(instants, 2);
    }

    #[test]
    fn pid_metadata_names_every_node() {
        let doc = chrome_trace(&sample());
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let names: Vec<String> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
            .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(names, vec!["node-2".to_string(), "node-3".to_string()]);
    }

    #[test]
    fn microsecond_conversion_and_categories() {
        let doc = chrome_trace(&sample());
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let notice = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("node.notice"))
            .unwrap();
        assert_eq!(notice.get("ts").unwrap().as_f64().unwrap(), 60_000_000.0, "ns -> us");
        assert_eq!(notice.get("cat").unwrap().as_str().unwrap(), "node");
        let run =
            events.iter().find(|e| e.get("name").unwrap().as_str() == Some("trial.run")).unwrap();
        assert_eq!(run.get("dur").unwrap().as_f64().unwrap(), 20_000_000.0);
        assert_eq!(run.get("args").unwrap().get("command_hash").unwrap().as_u64(), Some(0xdeadbeef));
    }

    #[test]
    fn write_export_to_disk() {
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("trace.json");
        write_chrome_trace(&path, &sample()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
    }
}
