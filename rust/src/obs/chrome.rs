//! Chrome trace-event export: turn flight-recorder records into the JSON
//! object format `chrome://tracing` and Perfetto load directly.
//!
//! Mapping (see `docs/OBSERVABILITY.md` for the viewing walkthrough):
//!
//! * one **pid** per node (pid 0 = the controller/driver), named via
//!   `process_name` metadata events so Perfetto's track groups read
//!   `node-3`, not `3`;
//! * one **tid** per task / trial / replica lane within the node;
//! * [`RecordKind::Span`] → phase `"X"` (complete event, `ts` + `dur`);
//! * [`RecordKind::Instant`] → phase `"i"`, thread-scoped;
//! * timestamps are microseconds (the trace-event unit), converted from
//!   the recorder's nanoseconds — always finite and non-negative because
//!   the source is `u64`.

use std::path::Path;

use crate::obs::{ArgValue, Record, RecordKind};
use crate::util::Json;
use crate::{Error, Result};

fn arg_json(v: &ArgValue) -> Json {
    match v {
        ArgValue::U64(n) => Json::num(*n as f64),
        ArgValue::F64(n) if n.is_finite() => Json::num(*n),
        // non-finite floats would poison the JSON; stringify them
        ArgValue::F64(n) => Json::str(format!("{n}")),
        ArgValue::Str(s) => Json::str(s.clone()),
    }
}

/// Build the Chrome trace-event JSON document for `records`.
///
/// Returns `{"displayTimeUnit": "ms", "traceEvents": [...]}` with one
/// `process_name` metadata event per distinct pid followed by the records
/// sorted by start time (sequence number breaks ties).
pub fn chrome_trace(records: &[Record]) -> Json {
    let mut sorted: Vec<&Record> = records.iter().collect();
    sorted.sort_by_key(|r| (r.ts_ns, r.seq));

    let mut events = Vec::new();
    let mut pids: Vec<u32> = sorted.iter().map(|r| r.pid).collect();
    pids.sort_unstable();
    pids.dedup();
    for pid in pids {
        let name = if pid == 0 { "controller".to_string() } else { format!("node-{pid}") };
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("process_name")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("name", Json::str(name))])),
        ]));
    }

    for r in sorted {
        let ts_us = r.ts_ns as f64 / 1e3;
        let args =
            Json::Obj(r.args.iter().map(|(k, v)| (k.to_string(), arg_json(v))).collect());
        let mut fields = vec![
            ("name", Json::str(r.name)),
            ("cat", Json::str(category(r.name))),
            ("ts", Json::num(ts_us)),
            ("pid", Json::num(r.pid as f64)),
            ("tid", Json::num(r.tid as f64)),
            ("args", args),
        ];
        match r.kind {
            RecordKind::Span { dur_ns } => {
                fields.push(("ph", Json::str("X")));
                fields.push(("dur", Json::num(dur_ns as f64 / 1e3)));
            }
            RecordKind::Instant => {
                fields.push(("ph", Json::str("i")));
                fields.push(("s", Json::str("t")));
            }
        }
        events.push(Json::obj(fields));
    }

    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Category = the leading `subsystem.` segment of the record name (the
/// whole name when undotted); Perfetto filters on it.
fn category(name: &str) -> &str {
    name.split('.').next().unwrap_or(name)
}

/// Serialize [`chrome_trace`] for `records` and write it to `path`.
pub fn write_chrome_trace(path: &Path, records: &[Record]) -> Result<()> {
    std::fs::write(path, chrome_trace(records).to_string())?;
    Ok(())
}

/// Intern a string into a `&'static str` (record names and arg keys are
/// static in the live taxonomy; re-imported traces go through this
/// pool). Deduplicated process-wide, so repeated imports of the same
/// trace never grow memory — the pool is bounded by the distinct names
/// ever seen.
fn intern(s: &str) -> &'static str {
    use std::collections::BTreeMap;
    use std::sync::{Mutex, OnceLock};
    static POOL: OnceLock<Mutex<BTreeMap<String, &'static str>>> = OnceLock::new();
    let pool = POOL.get_or_init(|| Mutex::new(BTreeMap::new()));
    let mut map = pool.lock().unwrap();
    if let Some(v) = map.get(s) {
        return v;
    }
    let leaked: &'static str = Box::leak(s.to_string().into_boxed_str());
    map.insert(s.to_string(), leaked);
    leaked
}

/// Parse a Chrome trace-event document (as produced by
/// [`chrome_trace`]) back into records, so an exported run can be
/// re-analyzed offline (`hyper report --load trace.json`).
///
/// Metadata (`"M"`) events are skipped; `"X"` becomes a span, `"i"` an
/// instant; numeric args come back as [`ArgValue::F64`] (the export
/// does not distinguish integer from float). Sequence numbers are
/// assigned in file order, which the exporter made `(ts, seq)`-sorted —
/// so same-instant ordering survives the round trip.
pub fn parse_chrome_trace(text: &str) -> Result<Vec<Record>> {
    let doc = Json::parse(text)?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Json("chrome trace: missing traceEvents array".into()))?;
    let mut out = Vec::new();
    for e in events {
        let ph = e.req_str("ph")?;
        if ph == "M" {
            continue;
        }
        let ts_ns = (e.req_f64("ts")? * 1e3).round().max(0.0) as u64;
        let kind = match ph {
            "X" => RecordKind::Span {
                dur_ns: (e.get("dur").and_then(Json::as_f64).unwrap_or(0.0) * 1e3).round().max(0.0)
                    as u64,
            },
            "i" => RecordKind::Instant,
            other => return Err(Error::Json(format!("chrome trace: unknown phase {other:?}"))),
        };
        let mut args = Vec::new();
        if let Some(obj) = e.get("args").and_then(Json::as_obj) {
            for (k, v) in obj {
                let val = match v {
                    Json::Num(n) => ArgValue::F64(*n),
                    Json::Str(s) => ArgValue::Str(s.clone()),
                    _ => continue,
                };
                args.push((intern(k), val));
            }
        }
        out.push(Record {
            seq: out.len() as u64,
            name: intern(e.req_str("name")?),
            kind,
            ts_ns,
            pid: e.req_u64("pid")? as u32,
            tid: e.req_u64("tid")?,
            args,
        });
    }
    Ok(out)
}

/// Read and [`parse_chrome_trace`] the file at `path`.
pub fn read_chrome_trace(path: &Path) -> Result<Vec<Record>> {
    parse_chrome_trace(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::FlightRecorder;
    use crate::sim::SimClock;

    fn sample() -> Vec<Record> {
        let rec = FlightRecorder::sim(16, SimClock::new());
        rec.event_at("node.notice", 60_000_000_000, 3, 0, vec![("cause", "storm".into())]);
        rec.span_at(
            "node.drain",
            60_000_000_000,
            61_500_000_000,
            3,
            0,
            vec![("checkpointed", 1u64.into())],
        );
        rec.event_at("node.kill", 61_500_000_000, 3, 0, vec![]);
        rec.span_at("trial.run", 10_000_000_000, 30_000_000_000, 2, 7, vec![
            ("command_hash", 0xdeadbeefu64.into()),
            ("loss", 0.73.into()),
        ]);
        rec.snapshot()
    }

    #[test]
    fn export_roundtrips_through_util_json_with_finite_nonneg_times() {
        // ISSUE satellite: the export must survive a parse round-trip and
        // every ts/dur must be finite and non-negative
        let doc = chrome_trace(&sample());
        let text = doc.to_string();
        let back = Json::parse(&text).expect("exporter emits valid JSON");
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        let mut spans = 0;
        let mut instants = 0;
        for e in events {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            let ts = e.get("ts").map(|t| t.as_f64().unwrap());
            match ph {
                "M" => continue,
                "X" => {
                    spans += 1;
                    let dur = e.get("dur").unwrap().as_f64().unwrap();
                    assert!(dur.is_finite() && dur >= 0.0, "dur={dur}");
                }
                "i" => {
                    instants += 1;
                    assert_eq!(e.get("s").unwrap().as_str().unwrap(), "t");
                }
                other => panic!("unexpected phase {other}"),
            }
            let ts = ts.expect("every non-metadata event has ts");
            assert!(ts.is_finite() && ts >= 0.0, "ts={ts}");
            assert!(e.get("pid").unwrap().as_u64().is_some());
            assert!(e.get("tid").unwrap().as_u64().is_some());
        }
        assert_eq!(spans, 2);
        assert_eq!(instants, 2);
    }

    #[test]
    fn pid_metadata_names_every_node() {
        let doc = chrome_trace(&sample());
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let names: Vec<String> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
            .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(names, vec!["node-2".to_string(), "node-3".to_string()]);
    }

    #[test]
    fn microsecond_conversion_and_categories() {
        let doc = chrome_trace(&sample());
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let notice = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("node.notice"))
            .unwrap();
        assert_eq!(notice.get("ts").unwrap().as_f64().unwrap(), 60_000_000.0, "ns -> us");
        assert_eq!(notice.get("cat").unwrap().as_str().unwrap(), "node");
        let run =
            events.iter().find(|e| e.get("name").unwrap().as_str() == Some("trial.run")).unwrap();
        assert_eq!(run.get("dur").unwrap().as_f64().unwrap(), 20_000_000.0);
        assert_eq!(run.get("args").unwrap().get("command_hash").unwrap().as_u64(), Some(0xdeadbeef));
    }

    #[test]
    fn same_instant_events_export_in_record_order_even_from_shuffled_input() {
        // ISSUE satellite: deterministic tiebreak — events sharing a
        // timestamp (notice/kill pairs do, routinely, in virtual time)
        // must export in sequence order regardless of slice order
        let rec = FlightRecorder::sim(16, SimClock::new());
        rec.event_at("node.notice", 60_000_000_000, 3, 0, vec![]);
        rec.event_at("node.kill", 60_000_000_000, 3, 0, vec![]);
        rec.event_at("node.request", 60_000_000_000, 4, 0, vec![]);
        let mut records = rec.snapshot();
        records.reverse();
        let doc = chrome_trace(&records);
        let names: Vec<String> = doc
            .get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() != Some("M"))
            .map(|e| e.get("name").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(names, vec!["node.notice", "node.kill", "node.request"]);
        // the timeline renderer applies the same tiebreak
        let text = crate::obs::render_timeline(&records);
        let notice = text.find("node.notice").unwrap();
        let kill = text.find("node.kill").unwrap();
        let request = text.find("node.request").unwrap();
        assert!(notice < kill && kill < request, "{text}");
    }

    #[test]
    fn parse_back_round_trips_records() {
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("trace.json");
        let records = sample();
        write_chrome_trace(&path, &records).unwrap();
        let back = read_chrome_trace(&path).unwrap();
        assert_eq!(back.len(), records.len());
        // the exporter sorted by (ts, seq); compare against that order
        let mut sorted: Vec<&Record> = records.iter().collect();
        sorted.sort_by_key(|r| (r.ts_ns, r.seq));
        for (orig, re) in sorted.iter().zip(&back) {
            assert_eq!(orig.name, re.name);
            assert_eq!(orig.ts_ns, re.ts_ns);
            assert_eq!(orig.pid, re.pid);
            assert_eq!(orig.tid, re.tid);
            match (orig.kind, re.kind) {
                (RecordKind::Span { dur_ns: a }, RecordKind::Span { dur_ns: b }) => {
                    assert_eq!(a, b)
                }
                (RecordKind::Instant, RecordKind::Instant) => {}
                other => panic!("kind mismatch: {other:?}"),
            }
            for (k, v) in &orig.args {
                let rv = re.arg(k).expect("arg survives the round trip");
                match v {
                    // integers come back as floats; values must agree
                    ArgValue::U64(_) | ArgValue::F64(_) => {
                        assert_eq!(v.as_f64(), rv.as_f64(), "arg {k}")
                    }
                    ArgValue::Str(s) => assert_eq!(rv.as_str(), Some(s.as_str())),
                }
            }
        }
        // seq numbers are freshly contiguous
        for (i, r) in back.iter().enumerate() {
            assert_eq!(r.seq, i as u64);
        }
    }

    #[test]
    fn parse_back_rejects_garbage() {
        assert!(parse_chrome_trace("not json").is_err());
        assert!(parse_chrome_trace("{}").is_err(), "missing traceEvents");
        assert!(parse_chrome_trace(r#"{"traceEvents": [{"ph": "?"}]}"#).is_err());
    }

    #[test]
    fn write_export_to_disk() {
        let dir = crate::util::TempDir::new().unwrap();
        let path = dir.path().join("trace.json");
        write_chrome_trace(&path, &sample()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
    }
}
