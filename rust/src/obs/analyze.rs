//! Trace analytics: critical-path categories and cost attribution.
//!
//! [`analyze`] walks one run's flight-recorder records and decomposes
//! each node's **billed lifetime** into four exclusive categories, in
//! integer nanoseconds so they sum back exactly:
//!
//! | category | rule |
//! |---|---|
//! | provisioning | `node.request` → `node.ready` (the whole lifetime if the node never became ready) |
//! | compute | the merged union of work intervals — `serve.batch`, `serve.batch_execute`, and `trial.run` spans plus `work.dispatch`→`work.done`/`work.stale_drop` pairs — clipped to the serving window |
//! | drain | `node.notice` → termination, minus whatever compute overlapped it (in-flight work during a notice still counts as compute) |
//! | idle | everything else: ready but unoccupied capacity |
//!
//! Termination is the node's first `node.kill`, `node.release`, or
//! `node.shutdown` record (the engine emits the last of these for
//! survivors billed at run end); a node with none — possible when the
//! ring evicted it — ends at the trace's last timestamp. Work intervals
//! whose completion aged out of the ring are closed at the dispatch's
//! recorded `eta_s`.
//!
//! **Cost attribution** prices each node's lifetime at its catalog rate
//! (the identical formula [`crate::fleet::FleetEngine`] bills with, so
//! the per-node costs reconcile against the run's
//! [`crate::metrics::CostLedger`] total), splits it into *attributed*
//! (compute seconds) and *wasted* (everything else: provisioning gap,
//! drain tax, idle over-provisioning), and joins spans back onto node
//! rates for $/trial (`trial.run`), $/gang-step (`gang.step` ×
//! `world_size`), and $/tag (the `node.request` launch tag). By
//! construction `attributed + wasted == total` — the reconciliation
//! invariant the driver tests pin.
//!
//! Voluntary drains (`node.drain_voluntary`, e.g. autoscaler
//! scale-downs) are *not* drain: the tail of a voluntarily released
//! node is idle over-provisioning and stays in the wasted column.

use std::collections::BTreeMap;

use crate::cloud::InstanceType;
use crate::obs::{Record, RecordKind};

/// One node's lifetime decomposition and bill.
#[derive(Debug, Clone)]
pub struct NodeBreakdown {
    /// Node id (trace pid).
    pub pid: u32,
    /// Catalog instance name from `node.request`.
    pub instance: String,
    /// Spot-priced?
    pub spot: bool,
    /// Launch tag (workload label) from `node.request`.
    pub tag: String,
    /// Launch request time.
    pub request_ns: u64,
    /// Ready time (`None`: still provisioning at termination).
    pub ready_ns: Option<u64>,
    /// First preemption notice, if any.
    pub notice_ns: Option<u64>,
    /// Termination (kill / release / shutdown) time.
    pub end_ns: u64,
    /// Billed lifetime: `end - request`.
    pub lifetime_ns: u64,
    /// Exclusive category times; they sum to `lifetime_ns` exactly.
    pub provisioning_ns: u64,
    /// Merged work-span occupancy inside the serving window.
    pub busy_ns: u64,
    /// Notice→termination time not covered by work.
    pub drain_ns: u64,
    /// Ready, unoccupied, not draining.
    pub idle_ns: u64,
    /// Catalog $/hour this node billed at.
    pub rate_usd_hr: f64,
    /// Lifetime bill (the engine's formula: rate × lifetime hours).
    pub cost_usd: f64,
    /// The bill's compute share (rate × busy hours).
    pub attributed_usd: f64,
    /// `cost - attributed`: the provisioning/drain/idle tax.
    pub wasted_usd: f64,
}

/// Whole-run analysis: per-node breakdowns, fleet-wide category sums,
/// cost attribution, and the workload-specific extracts (allreduce
/// share, queue wait, SLO transitions).
#[derive(Debug, Clone, Default)]
pub struct Analysis {
    /// Last timestamp in the trace (virtual t=0 is engine start).
    pub makespan_ns: u64,
    /// Per-node breakdowns, ordered by pid.
    pub nodes: Vec<NodeBreakdown>,
    /// Σ node provisioning.
    pub provisioning_ns: u64,
    /// Σ node compute occupancy.
    pub busy_ns: u64,
    /// Σ node drain.
    pub drain_ns: u64,
    /// Σ node idle.
    pub idle_ns: u64,
    /// Σ node lifetime (= the four categories above, exactly).
    pub lifetime_ns: u64,
    /// Σ node bills (reconciles with `CostLedger::total_usd`).
    pub total_usd: f64,
    /// Σ node compute shares.
    pub attributed_usd: f64,
    /// Σ node wasted shares (`attributed + wasted == total`).
    pub wasted_usd: f64,
    /// Bill per launch tag (workload attribution).
    pub per_tag_usd: BTreeMap<String, f64>,
    /// Bill per trial id: `trial.run` span time × its node's rate.
    pub per_trial_usd: BTreeMap<u64, f64>,
    /// Bill per committed gang step: span time × `world_size` × the
    /// fleet's mean node rate.
    pub per_step_usd: BTreeMap<u64, f64>,
    /// Σ `gang.step` span time.
    pub step_ns: u64,
    /// Σ `allreduce_us` across `gang.step` spans.
    pub allreduce_ns: u64,
    /// Σ `hfs.backend_get` span time.
    pub backend_get_ns: u64,
    /// Mean `serve.batch` head-of-queue wait, seconds.
    pub queue_wait_mean_s: f64,
    /// Max `serve.batch` head-of-queue wait, seconds.
    pub queue_wait_max_s: f64,
    /// Checkpoint saves (`gang.checkpoint` + `trial.checkpoint`).
    pub checkpoints: u64,
    /// Restores (`gang.restore` + `trial.resume`).
    pub restores: u64,
    /// Admission-control sheds.
    pub sheds: u64,
    /// Scripted storms fired.
    pub storms: u64,
    /// Completions dropped for racing a preemption.
    pub stale_drops: u64,
    /// `slo.breach` transitions: `(t_ns, metric)`.
    pub slo_breaches: Vec<(u64, String)>,
    /// `slo.recover` transitions: `(t_ns, metric)`.
    pub slo_recoveries: Vec<(u64, String)>,
}

impl Analysis {
    /// Wasted spend as a fraction of the total bill (0 when free).
    pub fn wasted_frac(&self) -> f64 {
        if self.total_usd > 0.0 {
            self.wasted_usd / self.total_usd
        } else {
            0.0
        }
    }

    /// Allreduce share of committed gang-step time (0 with no steps).
    pub fn allreduce_frac(&self) -> f64 {
        if self.step_ns > 0 {
            self.allreduce_ns as f64 / self.step_ns as f64
        } else {
            0.0
        }
    }

    /// The breakdown for node `pid`, if it appears in the trace.
    pub fn node(&self, pid: u32) -> Option<&NodeBreakdown> {
        self.nodes.iter().find(|n| n.pid == pid)
    }
}

/// Merge intervals in place and return their union length. Inverted
/// inputs are dropped.
fn union_len(intervals: &mut Vec<(u64, u64)>) -> u64 {
    intervals.retain(|(s, e)| e > s);
    intervals.sort_unstable();
    let mut total = 0u64;
    let mut cur: Option<(u64, u64)> = None;
    let mut merged = Vec::with_capacity(intervals.len());
    for &(s, e) in intervals.iter() {
        match cur {
            Some((cs, ce)) if s <= ce => cur = Some((cs, ce.max(e))),
            Some(done) => {
                total += done.1 - done.0;
                merged.push(done);
                cur = Some((s, e));
            }
            None => cur = Some((s, e)),
        }
    }
    if let Some(done) = cur {
        total += done.1 - done.0;
        merged.push(done);
    }
    *intervals = merged;
    total
}

/// Length of `merged ∩ [lo, hi]` for already-merged disjoint intervals.
fn overlap_len(merged: &[(u64, u64)], lo: u64, hi: u64) -> u64 {
    merged
        .iter()
        .map(|&(s, e)| e.min(hi).saturating_sub(s.max(lo)))
        .sum()
}

#[derive(Default)]
struct NodeAcc {
    request_ns: u64,
    ready_ns: Option<u64>,
    notice_ns: Option<u64>,
    end_ns: Option<u64>,
    instance: String,
    spot: bool,
    tag: String,
    work: Vec<(u64, u64)>,
}

/// Analyze one run's records (a [`crate::obs::FlightRecorder`]
/// snapshot, or a re-imported Chrome trace — see
/// [`crate::obs::chrome::read_chrome_trace`]).
pub fn analyze(records: &[Record]) -> Analysis {
    let mut order: Vec<&Record> = records.iter().collect();
    order.sort_by_key(|r| (r.ts_ns, r.seq));

    let mut a = Analysis::default();
    let mut nodes: BTreeMap<u32, NodeAcc> = BTreeMap::new();
    // open work: (pid, tid) -> (dispatch ts, eta close time)
    let mut open: BTreeMap<(u32, u64), (u64, u64)> = BTreeMap::new();
    // trial.run joins: (trial, node, dur)
    let mut trial_spans: Vec<(u64, u32, u64)> = Vec::new();
    // gang.step joins: (step, world_size, dur)
    let mut gang_steps: Vec<(u64, f64, u64)> = Vec::new();
    let (mut wait_sum, mut wait_n) = (0.0f64, 0u64);

    for r in &order {
        a.makespan_ns = a.makespan_ns.max(r.end_ns());
        let farg = |key: &str| r.arg(key).and_then(|v| v.as_f64());
        match r.name {
            "node.request" => {
                let acc = nodes.entry(r.pid).or_default();
                acc.request_ns = r.ts_ns;
                acc.instance =
                    r.arg("instance").and_then(|v| v.as_str()).unwrap_or("").to_string();
                acc.spot = farg("spot").unwrap_or(0.0) != 0.0;
                acc.tag = r.arg("tag").and_then(|v| v.as_str()).unwrap_or("").to_string();
            }
            "node.ready" => {
                if let Some(acc) = nodes.get_mut(&r.pid) {
                    acc.ready_ns.get_or_insert(r.ts_ns);
                }
            }
            "node.notice" => {
                if let Some(acc) = nodes.get_mut(&r.pid) {
                    acc.notice_ns.get_or_insert(r.ts_ns);
                }
            }
            "node.kill" | "node.release" | "node.shutdown" => {
                if let Some(acc) = nodes.get_mut(&r.pid) {
                    acc.end_ns.get_or_insert(r.ts_ns);
                }
            }
            "work.dispatch" => {
                let eta = farg("eta_s").map(|s| (s * 1e9) as u64).unwrap_or(r.ts_ns);
                open.insert((r.pid, r.tid), (r.ts_ns, eta));
            }
            "work.done" | "work.stale_drop" => {
                if r.name == "work.stale_drop" {
                    a.stale_drops += 1;
                }
                if let Some((start, _)) = open.remove(&(r.pid, r.tid)) {
                    if let Some(acc) = nodes.get_mut(&r.pid) {
                        acc.work.push((start, r.ts_ns));
                    }
                }
            }
            "serve.batch" | "serve.batch_execute" | "trial.run" => {
                if let Some(acc) = nodes.get_mut(&r.pid) {
                    acc.work.push((r.ts_ns, r.end_ns()));
                }
                if r.name == "serve.batch" {
                    if let Some(w) = farg("oldest_wait_s") {
                        wait_sum += w;
                        wait_n += 1;
                        a.queue_wait_max_s = a.queue_wait_max_s.max(w);
                    }
                }
                if r.name == "trial.run" {
                    if let RecordKind::Span { dur_ns } = r.kind {
                        trial_spans.push((r.tid, r.pid, dur_ns));
                    }
                }
            }
            "gang.step" => {
                if let RecordKind::Span { dur_ns } = r.kind {
                    a.step_ns += dur_ns;
                    let ar = (farg("allreduce_us").unwrap_or(0.0) * 1e3) as u64;
                    a.allreduce_ns += ar;
                    gang_steps.push((r.tid, farg("world_size").unwrap_or(0.0), dur_ns));
                }
            }
            "hfs.backend_get" => {
                if let RecordKind::Span { dur_ns } = r.kind {
                    a.backend_get_ns += dur_ns;
                }
            }
            "gang.checkpoint" | "trial.checkpoint" => a.checkpoints += 1,
            "gang.restore" | "trial.resume" => a.restores += 1,
            "serve.shed" => a.sheds += 1,
            "fleet.storm" => a.storms += 1,
            "slo.breach" | "slo.recover" => {
                let metric =
                    r.arg("metric").and_then(|v| v.as_str()).unwrap_or("").to_string();
                if r.name == "slo.breach" {
                    a.slo_breaches.push((r.ts_ns, metric));
                } else {
                    a.slo_recoveries.push((r.ts_ns, metric));
                }
            }
            _ => {}
        }
    }
    // a dispatch whose completion aged out of the ring (or raced run
    // end) closes at its recorded eta
    for ((pid, _), (start, eta)) in open {
        if let Some(acc) = nodes.get_mut(&pid) {
            acc.work.push((start, eta.max(start)));
        }
    }
    if wait_n > 0 {
        a.queue_wait_mean_s = wait_sum / wait_n as f64;
    }

    let mut rate_sum = 0.0f64;
    for (pid, mut acc) in nodes {
        let end = acc.end_ns.unwrap_or(a.makespan_ns).max(acc.request_ns);
        let lifetime = end - acc.request_ns;
        let prov_end = acc.ready_ns.unwrap_or(end).clamp(acc.request_ns, end);
        let provisioning = prov_end - acc.request_ns;
        // clip work to the serving window, then merge
        for iv in acc.work.iter_mut() {
            iv.0 = iv.0.clamp(prov_end, end);
            iv.1 = iv.1.clamp(prov_end, end);
        }
        let busy = union_len(&mut acc.work);
        let drain = match acc.notice_ns {
            Some(n) => {
                let s = n.clamp(prov_end, end);
                (end - s) - overlap_len(&acc.work, s, end)
            }
            None => 0,
        };
        let idle = lifetime - provisioning - busy - drain;

        let rate = InstanceType::by_name(&acc.instance).map(|s| s.price(acc.spot)).unwrap_or(0.0);
        // the engine's bill_at formula, term for term
        let hours = (lifetime as f64 / 1e9) / 3600.0;
        let cost = rate * hours;
        let attributed = rate * ((busy as f64 / 1e9) / 3600.0);
        let wasted = cost - attributed;
        rate_sum += rate;

        a.provisioning_ns += provisioning;
        a.busy_ns += busy;
        a.drain_ns += drain;
        a.idle_ns += idle;
        a.lifetime_ns += lifetime;
        a.total_usd += cost;
        a.attributed_usd += attributed;
        a.wasted_usd += wasted;
        *a.per_tag_usd.entry(acc.tag.clone()).or_default() += cost;
        a.nodes.push(NodeBreakdown {
            pid,
            instance: acc.instance,
            spot: acc.spot,
            tag: acc.tag,
            request_ns: acc.request_ns,
            ready_ns: acc.ready_ns,
            notice_ns: acc.notice_ns,
            end_ns: end,
            lifetime_ns: lifetime,
            provisioning_ns: provisioning,
            busy_ns: busy,
            drain_ns: drain,
            idle_ns: idle,
            rate_usd_hr: rate,
            cost_usd: cost,
            attributed_usd: attributed,
            wasted_usd: wasted,
        });
    }

    let node_rate = |pid: u32| a.node(pid).map(|n| n.rate_usd_hr).unwrap_or(0.0);
    for (trial, pid, dur_ns) in trial_spans {
        *a.per_trial_usd.entry(trial).or_default() +=
            node_rate(pid) * ((dur_ns as f64 / 1e9) / 3600.0);
    }
    let mean_rate =
        if a.nodes.is_empty() { 0.0 } else { rate_sum / a.nodes.len() as f64 };
    for (step, world, dur_ns) in gang_steps {
        *a.per_step_usd.entry(step).or_default() +=
            mean_rate * world * ((dur_ns as f64 / 1e9) / 3600.0);
    }
    a
}

fn secs(ns: u64) -> f64 {
    ns as f64 / 1e9
}

fn pct(part: u64, whole: u64) -> f64 {
    if whole > 0 {
        100.0 * part as f64 / whole as f64
    } else {
        0.0
    }
}

/// Render an [`Analysis`] as the `hyper report` text: the category
/// breakdown, the per-node table, the cost attribution, and the SLO
/// verdicts.
pub fn render_report(a: &Analysis) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "== critical path (makespan {:.3} s) ==", secs(a.makespan_ns));
    let lt = a.lifetime_ns;
    let _ = writeln!(out, "{:<14} {:>12} {:>8}", "category", "node-secs", "share");
    for (name, ns) in [
        ("provisioning", a.provisioning_ns),
        ("compute", a.busy_ns),
        ("drain", a.drain_ns),
        ("idle", a.idle_ns),
    ] {
        let _ = writeln!(out, "{:<14} {:>12.3} {:>7.1}%", name, secs(ns), pct(ns, lt));
    }
    let _ = writeln!(out, "{:<14} {:>12.3} {:>7.1}%", "lifetime", secs(lt), 100.0);
    if a.step_ns > 0 {
        let _ = writeln!(
            out,
            "allreduce      {:>12.3} {:>7.1}% of {} gang-step secs",
            secs(a.allreduce_ns),
            100.0 * a.allreduce_frac(),
            format!("{:.3}", secs(a.step_ns)),
        );
    }
    if a.backend_get_ns > 0 {
        let _ = writeln!(out, "backend GETs   {:>12.3}", secs(a.backend_get_ns));
    }

    let _ = writeln!(out, "\n== nodes ({}) ==", a.nodes.len());
    let _ = writeln!(
        out,
        "{:<5} {:<12} {:<6} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "pid", "instance", "tag", "life(s)", "prov(s)", "busy(s)", "drain(s)", "idle(s)", "cost($)"
    );
    for n in &a.nodes {
        let _ = writeln!(
            out,
            "{:<5} {:<12} {:<6} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>9.4}",
            n.pid,
            n.instance,
            n.tag,
            secs(n.lifetime_ns),
            secs(n.provisioning_ns),
            secs(n.busy_ns),
            secs(n.drain_ns),
            secs(n.idle_ns),
            n.cost_usd,
        );
    }

    let _ = writeln!(out, "\n== cost attribution ==");
    let _ = writeln!(
        out,
        "total ${:.4} = attributed ${:.4} + wasted ${:.4} ({:.1}% wasted)",
        a.total_usd,
        a.attributed_usd,
        a.wasted_usd,
        100.0 * a.wasted_frac(),
    );
    for (tag, usd) in &a.per_tag_usd {
        let tag = if tag.is_empty() { "(untagged)" } else { tag };
        let _ = writeln!(out, "  tag {tag:<12} ${usd:.4}");
    }
    if !a.per_trial_usd.is_empty() {
        let mut trials: Vec<(&u64, &f64)> = a.per_trial_usd.iter().collect();
        trials.sort_by(|x, y| y.1.partial_cmp(x.1).unwrap_or(std::cmp::Ordering::Equal));
        let _ = writeln!(out, "  {} trials, top by cost:", trials.len());
        for (t, usd) in trials.iter().take(5) {
            let _ = writeln!(out, "    trial {t:<6} ${usd:.5}");
        }
    }
    if !a.per_step_usd.is_empty() {
        let n = a.per_step_usd.len() as f64;
        let sum: f64 = a.per_step_usd.values().sum();
        let _ = writeln!(
            out,
            "  {} gang steps, mean ${:.6}/step, allreduce {:.1}%",
            a.per_step_usd.len(),
            sum / n,
            100.0 * a.allreduce_frac(),
        );
    }

    let _ = writeln!(
        out,
        "\n== events == storms {} · sheds {} · stale drops {} · checkpoints {} · restores {}",
        a.storms, a.sheds, a.stale_drops, a.checkpoints, a.restores
    );
    if a.queue_wait_max_s > 0.0 {
        let _ = writeln!(
            out,
            "queue wait: mean {:.4} s, max {:.4} s",
            a.queue_wait_mean_s, a.queue_wait_max_s
        );
    }

    let _ = writeln!(out, "\n== slo ==");
    if a.slo_breaches.is_empty() && a.slo_recoveries.is_empty() {
        let _ = writeln!(out, "no transitions (met throughout, or no monitor attached)");
    }
    for (t, m) in &a.slo_breaches {
        let _ = writeln!(out, "BREACH  {m} at {:.3} s", secs(*t));
    }
    for (t, m) in &a.slo_recoveries {
        let _ = writeln!(out, "RECOVER {m} at {:.3} s", secs(*t));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::FlightRecorder;
    use crate::sim::SimClock;

    const S: u64 = 1_000_000_000;

    fn rate(name: &str, spot: bool) -> f64 {
        InstanceType::by_name(name).unwrap().price(spot)
    }

    /// request 0, ready 10, work [10,20], notice 25, kill 30.
    fn one_node_trace() -> Vec<Record> {
        let rec = FlightRecorder::sim(64, SimClock::new());
        rec.event_at("node.request", 0, 1, 0, vec![
            ("instance", "m5.xlarge".into()),
            ("spot", 0u64.into()),
            ("tag", "serve".into()),
        ]);
        rec.event_at("node.ready", 10 * S, 1, 0, vec![]);
        rec.event_at("work.dispatch", 10 * S, 1, 7, vec![("eta_s", 20.0.into())]);
        rec.event_at("work.done", 20 * S, 1, 7, vec![]);
        rec.event_at("node.notice", 25 * S, 1, 0, vec![]);
        rec.event_at("node.kill", 30 * S, 1, 0, vec![]);
        rec.snapshot()
    }

    #[test]
    fn single_node_partition_is_exact() {
        let a = analyze(&one_node_trace());
        let n = a.node(1).unwrap();
        assert_eq!(n.lifetime_ns, 30 * S);
        assert_eq!(n.provisioning_ns, 10 * S);
        assert_eq!(n.busy_ns, 10 * S);
        assert_eq!(n.drain_ns, 5 * S);
        assert_eq!(n.idle_ns, 5 * S);
        assert_eq!(
            n.provisioning_ns + n.busy_ns + n.drain_ns + n.idle_ns,
            n.lifetime_ns,
            "categories partition the lifetime exactly"
        );
        let r = rate("m5.xlarge", false);
        let expect = r * ((30.0) / 3600.0);
        assert!((n.cost_usd - expect).abs() < 1e-12, "{} vs {expect}", n.cost_usd);
        assert!((n.attributed_usd + n.wasted_usd - n.cost_usd).abs() < 1e-15);
        assert_eq!(a.per_tag_usd.len(), 1);
        assert!((a.per_tag_usd["serve"] - n.cost_usd).abs() < 1e-15);
        assert_eq!(a.makespan_ns, 30 * S);
    }

    #[test]
    fn overlapping_work_records_do_not_double_count() {
        // the same interval seen as a dispatch/done pair AND a
        // serve.batch span, plus a second batch overlapping it
        let rec = FlightRecorder::sim(64, SimClock::new());
        rec.event_at("node.request", 0, 2, 0, vec![
            ("instance", "m5.xlarge".into()),
            ("spot", 1u64.into()),
            ("tag", "serve".into()),
        ]);
        rec.event_at("node.ready", 5 * S, 2, 0, vec![]);
        rec.event_at("work.dispatch", 10 * S, 2, 1, vec![("eta_s", 14.0.into())]);
        rec.span_at("serve.batch", 10 * S, 14 * S, 2, 1, vec![("oldest_wait_s", 0.5.into())]);
        rec.span_at("serve.batch", 12 * S, 18 * S, 2, 2, vec![("oldest_wait_s", 1.5.into())]);
        rec.event_at("work.done", 14 * S, 2, 1, vec![]);
        rec.event_at("node.shutdown", 20 * S, 2, 0, vec![]);
        let a = analyze(&rec.snapshot());
        let n = a.node(2).unwrap();
        assert_eq!(n.busy_ns, 8 * S, "union of [10,14] and [12,18]");
        assert_eq!(n.provisioning_ns, 5 * S);
        assert_eq!(n.drain_ns, 0);
        assert_eq!(n.idle_ns, 7 * S);
        assert!(n.spot);
        assert!((n.rate_usd_hr - rate("m5.xlarge", true)).abs() < 1e-12);
        assert!((a.queue_wait_mean_s - 1.0).abs() < 1e-12);
        assert!((a.queue_wait_max_s - 1.5).abs() < 1e-12);
    }

    #[test]
    fn unmatched_dispatch_closes_at_its_eta_and_clips_to_termination() {
        // completion evicted/never delivered: the eta says 50 s but the
        // node died at 40 — busy clips to the kill
        let rec = FlightRecorder::sim(64, SimClock::new());
        rec.event_at("node.request", 3, 1, 0, vec![
            ("instance", "p3.2xlarge".into()),
            ("spot", 1u64.into()),
            ("tag", "train".into()),
        ]);
        rec.event_at("node.ready", 10 * S, 1, 0, vec![]);
        rec.event_at("work.dispatch", 20 * S, 1, 0, vec![("eta_s", 50.0.into())]);
        rec.event_at("node.kill", 40 * S, 1, 0, vec![]);
        let a = analyze(&rec.snapshot());
        let n = a.node(1).unwrap();
        assert_eq!(n.busy_ns, 20 * S, "[20,50] clipped to kill at 40");
        assert_eq!(n.lifetime_ns, 40 * S - 3);
        assert_eq!(
            n.provisioning_ns + n.busy_ns + n.drain_ns + n.idle_ns,
            n.lifetime_ns
        );
    }

    #[test]
    fn gang_steps_surface_allreduce_share_and_per_step_cost() {
        let rec = FlightRecorder::sim(64, SimClock::new());
        rec.event_at("node.request", 0, 1, 0, vec![
            ("instance", "p3.2xlarge".into()),
            ("spot", 0u64.into()),
            ("tag", "train".into()),
        ]);
        rec.event_at("node.ready", 0, 1, 0, vec![]);
        rec.event_at("node.shutdown", 100 * S, 1, 0, vec![]);
        // two 10 s steps, 2 s of allreduce each, world 4
        for step in 0..2u64 {
            rec.span_at("gang.step", step * 10 * S, (step + 1) * 10 * S, 0, step, vec![
                ("world_size", 4u64.into()),
                ("allreduce_us", 2_000_000.0.into()),
            ]);
        }
        let a = analyze(&rec.snapshot());
        assert_eq!(a.step_ns, 20 * S);
        assert_eq!(a.allreduce_ns, 4 * S);
        assert!((a.allreduce_frac() - 0.2).abs() < 1e-12);
        assert_eq!(a.per_step_usd.len(), 2);
        let expect = rate("p3.2xlarge", false) * 4.0 * (10.0 / 3600.0);
        assert!((a.per_step_usd[&0] - expect).abs() < 1e-9, "{}", a.per_step_usd[&0]);
    }

    #[test]
    fn trial_spans_bill_against_their_nodes_rate() {
        let rec = FlightRecorder::sim(64, SimClock::new());
        for pid in [1u32, 2] {
            rec.event_at("node.request", 0, pid, 0, vec![
                ("instance", "m5.xlarge".into()),
                ("spot", 1u64.into()),
                ("tag", "search".into()),
            ]);
            rec.event_at("node.ready", 0, pid, 0, vec![]);
        }
        rec.span_at("trial.run", 0, 30 * S, 1, 9, vec![("from_step", 0u64.into())]);
        rec.span_at("trial.run", 40 * S, 70 * S, 2, 9, vec![("from_step", 10u64.into())]);
        rec.event_at("node.shutdown", 80 * S, 1, 0, vec![]);
        rec.event_at("node.shutdown", 80 * S, 2, 0, vec![]);
        let a = analyze(&rec.snapshot());
        let expect = rate("m5.xlarge", true) * (60.0 / 3600.0);
        assert!((a.per_trial_usd[&9] - expect).abs() < 1e-12);
        // fleet totals still reconcile
        assert!((a.attributed_usd + a.wasted_usd - a.total_usd).abs() < 1e-12);
    }

    #[test]
    fn slo_transitions_and_event_counters_surface() {
        let rec = FlightRecorder::sim(64, SimClock::new());
        rec.event_at("fleet.storm", 60 * S, 0, 0, vec![("kills", 7u64.into())]);
        rec.event_at("serve.shed", 61 * S, 0, 0, vec![]);
        rec.event_at("slo.breach", 65 * S, 0, 0, vec![("metric", "p99_s".into())]);
        rec.event_at("slo.recover", 140 * S, 0, 0, vec![("metric", "p99_s".into())]);
        let a = analyze(&rec.snapshot());
        assert_eq!(a.storms, 1);
        assert_eq!(a.sheds, 1);
        assert_eq!(a.slo_breaches, vec![(65 * S, "p99_s".to_string())]);
        assert_eq!(a.slo_recoveries, vec![(140 * S, "p99_s".to_string())]);
        let text = render_report(&a);
        assert!(text.contains("BREACH  p99_s at 65.000 s"), "{text}");
        assert!(text.contains("RECOVER p99_s at 140.000 s"), "{text}");
    }

    #[test]
    fn report_renders_every_section() {
        let a = analyze(&one_node_trace());
        let text = render_report(&a);
        for needle in ["critical path", "provisioning", "== nodes (1) ==", "cost attribution",
                       "wasted", "== slo =="] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }
}
