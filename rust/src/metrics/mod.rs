//! Lightweight metrics: counters, gauges, histograms, cost accounting.
//!
//! The paper's master collects "client application logs, CPU/GPU
//! utilization logs and operating system logs" into Logstash; here a
//! [`MetricsRegistry`] plays that role for the coordinator, and
//! [`CostLedger`] implements the spot/on-demand cost accounting the
//! paper's §IV.B cost claims rest on.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;

use std::sync::Mutex;

/// Monotonic counter, cheap to clone and update from any thread.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n` (relaxed ordering; counters are statistics, not sync).
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current count.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Up/down gauge for instantaneous levels (in-flight fetches, queue
/// depths, live connections). Cheap to clone and update from any thread.
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    /// Add 1 to the level.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Subtract 1 from the level.
    pub fn dec(&self) {
        self.add(-1);
    }

    /// Shift the level by `n` (may be negative).
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite the level.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current level.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Gauge over `f64` levels (best loss so far, current rate, ...) where an
/// integer [`Gauge`] would lose the fraction. Stores the value's bits in an
/// `AtomicU64`; cheap to clone and update from any thread.
#[derive(Debug, Clone)]
pub struct FloatGauge(Arc<AtomicU64>);

impl Default for FloatGauge {
    fn default() -> Self {
        Self(Arc::new(AtomicU64::new(0f64.to_bits())))
    }
}

impl FloatGauge {
    /// A gauge at level 0.0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Overwrite the level.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current level.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Lowest tracked exponent: values below 2^-30 (~1 ns in seconds) share
/// bucket 0.
const HIST_MIN_EXP: i32 = -30;
/// Highest tracked exponent: values at/above 2^33 share the last octave.
const HIST_MAX_EXP: i32 = 32;
/// Linear sub-buckets per octave; bounds relative quantile error by 1/16.
const HIST_SUBS: usize = 16;
const HIST_BUCKETS: usize = ((HIST_MAX_EXP - HIST_MIN_EXP + 1) as usize) * HIST_SUBS;

/// Streaming histogram over log-linear buckets (values in arbitrary units —
/// callers document their unit; the serving layer records seconds).
///
/// Each power-of-two octave from 2^-30 to 2^32 is split into 16 linear
/// sub-buckets, so quantiles resolve to ~6% relative error across the whole
/// range — fine enough that a p99 latency SLO check on millisecond-scale
/// values is meaningful. Count/sum/min/max are tracked exactly.
///
/// `record` is lock-free: buckets and count are relaxed atomic adds,
/// sum/min/max are CAS loops over `f64` bits, so the serve hot path never
/// serializes behind a reader. A `Mutex` is held only by
/// `snapshot`/`reset` (and `snapshot_and_reset`, which drains the window
/// with atomic swaps so every recorded value lands in exactly one
/// window). A record racing a snapshot may straddle the fields it has
/// already written — count and bucket totals can disagree by in-flight
/// records for the duration of that race — which quantile handling
/// tolerates; once writers quiesce the totals are exact.
#[derive(Debug, Clone)]
pub struct Histogram {
    inner: Arc<HistInner>,
}

/// Point-in-time summary of a [`Histogram`] (quantiles are upper bucket
/// edges, clamped to the observed min/max).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

#[derive(Debug)]
struct HistInner {
    /// Per-bucket occupancy; relaxed `fetch_add` on the record path.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// `f64` bits; updated with CAS loops (no atomic f64 in std).
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
    /// Serializes snapshot/reset against each other (never `record`).
    window: Mutex<()>,
}

impl HistInner {
    fn fresh() -> Self {
        Self {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
            window: Mutex::new(()),
        }
    }

    /// Plain-value read of the live window (caller holds `window` when
    /// consistency against reset matters).
    fn view(&self) -> HistView {
        HistView {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
            min: f64::from_bits(self.min_bits.load(Ordering::Relaxed)),
            max: f64::from_bits(self.max_bits.load(Ordering::Relaxed)),
        }
    }

    /// Read-and-zero the live window in one pass of atomic swaps: each
    /// bucket increment lands in exactly one window, so windowed
    /// accounting conserves counts even with writers mid-flight.
    fn drain(&self) -> HistView {
        HistView {
            buckets: self.buckets.iter().map(|b| b.swap(0, Ordering::Relaxed)).collect(),
            count: self.count.swap(0, Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.swap(0f64.to_bits(), Ordering::Relaxed)),
            min: f64::from_bits(self.min_bits.swap(f64::INFINITY.to_bits(), Ordering::Relaxed)),
            max: f64::from_bits(
                self.max_bits.swap(f64::NEG_INFINITY.to_bits(), Ordering::Relaxed),
            ),
        }
    }
}

/// CAS-loop `+=` over `f64` bits.
fn atomic_f64_add(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// CAS-loop running extremum over `f64` bits (`min` or `max` via `pick`).
fn atomic_f64_extremum(cell: &AtomicU64, v: f64, pick: fn(f64, f64) -> f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let cur_v = f64::from_bits(cur);
        if pick(cur_v, v) == cur_v {
            return; // already the extremum
        }
        match cell.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Point-in-time plain-value copy of a histogram window.
#[derive(Debug)]
struct HistView {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl HistView {
    fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return bucket_upper_edge(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    fn snapshot(&self) -> HistogramSnapshot {
        if self.count == 0 {
            return HistogramSnapshot::default();
        }
        HistogramSnapshot {
            count: self.count,
            mean: self.sum / self.count as f64,
            min: self.min,
            max: self.max,
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
        }
    }
}

/// Bucket index for a finite positive value (clamped into the tracked
/// range); `record` filters non-finite input before calling this.
fn bucket_index(v: f64) -> usize {
    if v <= 0.0 || v < 2f64.powi(HIST_MIN_EXP) {
        return 0;
    }
    let exp = (v.log2().floor() as i32).clamp(HIST_MIN_EXP, HIST_MAX_EXP);
    let frac = v / 2f64.powi(exp); // in [1, 2) modulo fp rounding
    let sub = (((frac - 1.0) * HIST_SUBS as f64) as usize).min(HIST_SUBS - 1);
    ((exp - HIST_MIN_EXP) as usize) * HIST_SUBS + sub
}

/// Upper edge of bucket `i`: `2^exp * (1 + (sub+1)/16)`.
fn bucket_upper_edge(i: usize) -> f64 {
    let exp = (i / HIST_SUBS) as i32 + HIST_MIN_EXP;
    let sub = i % HIST_SUBS;
    2f64.powi(exp) * (1.0 + (sub + 1) as f64 / HIST_SUBS as f64)
}

impl Default for Histogram {
    fn default() -> Self {
        Self { inner: Arc::new(HistInner::fresh()) }
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one value — lock-free (atomic bucket/count adds, CAS loops
    /// for sum/min/max). Non-finite values are ignored: NaN/inf would
    /// corrupt min/max (and thus the clamp in `quantile`) while meaning
    /// nothing as a measurement.
    pub fn record(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let h = &*self.inner;
        h.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        h.count.fetch_add(1, Ordering::Relaxed);
        atomic_f64_add(&h.sum_bits, v);
        atomic_f64_extremum(&h.min_bits, v, f64::min);
        atomic_f64_extremum(&h.max_bits, v, f64::max);
    }

    /// Values recorded so far.
    pub fn count(&self) -> u64 {
        self.inner.count.load(Ordering::Relaxed)
    }

    /// Exact mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        let v = self.inner.view();
        if v.count == 0 { 0.0 } else { v.sum / v.count as f64 }
    }

    /// Exact minimum recorded value (0 when empty).
    pub fn min(&self) -> f64 {
        let v = self.inner.view();
        if v.count == 0 { 0.0 } else { v.min }
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max(&self) -> f64 {
        let v = self.inner.view();
        if v.count == 0 { 0.0 } else { v.max }
    }

    /// Approximate quantile (upper bucket edge, clamped to observed range).
    pub fn quantile(&self, q: f64) -> f64 {
        let _w = self.inner.window.lock().unwrap();
        self.inner.view().quantile(q)
    }

    /// Snapshot of count/mean/min/max and p50/p90/p95/p99 (the autoscaler
    /// samples this per control tick). Takes the window lock so it never
    /// interleaves with a concurrent reset half-way through the buckets.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let _w = self.inner.window.lock().unwrap();
        self.inner.view().snapshot()
    }

    /// Drop all recorded values (windowed use: snapshot, then reset).
    pub fn reset(&self) {
        let _w = self.inner.window.lock().unwrap();
        self.inner.drain();
    }

    /// Snapshot the current window and start a new one. The window is
    /// drained with atomic swaps, so every recorded value is counted in
    /// exactly one window — windowed totals conserve the record count.
    pub fn snapshot_and_reset(&self) -> HistogramSnapshot {
        let _w = self.inner.window.lock().unwrap();
        self.inner.drain().snapshot()
    }
}

/// Named metrics registry shared across a run.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: Arc<Mutex<BTreeMap<String, Counter>>>,
    gauges: Arc<Mutex<BTreeMap<String, Gauge>>>,
    float_gauges: Arc<Mutex<BTreeMap<String, FloatGauge>>>,
    histograms: Arc<Mutex<BTreeMap<String, Histogram>>>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// The counter registered under `name` (created on first use).
    pub fn counter(&self, name: &str) -> Counter {
        self.counters.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Register an externally-owned counter under `name` (e.g. the HFS
    /// read-path counters), replacing any counter previously there.
    pub fn register_counter(&self, name: &str, counter: Counter) {
        self.counters.lock().unwrap().insert(name.to_string(), counter);
    }

    /// The gauge registered under `name` (created on first use).
    pub fn gauge(&self, name: &str) -> Gauge {
        self.gauges.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Register an externally-owned gauge under `name`, replacing any
    /// gauge previously there.
    pub fn register_gauge(&self, name: &str, gauge: Gauge) {
        self.gauges.lock().unwrap().insert(name.to_string(), gauge);
    }

    /// The float gauge registered under `name` (created on first use).
    pub fn float_gauge(&self, name: &str) -> FloatGauge {
        self.float_gauges.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Register an externally-owned float gauge under `name`, replacing
    /// any float gauge previously there.
    pub fn register_float_gauge(&self, name: &str, gauge: FloatGauge) {
        self.float_gauges.lock().unwrap().insert(name.to_string(), gauge);
    }

    /// The histogram registered under `name` (created on first use).
    pub fn histogram(&self, name: &str) -> Histogram {
        self.histograms.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Register an externally-owned histogram under `name` (e.g. the
    /// serve stack's latency window), replacing any histogram previously
    /// there.
    pub fn register_histogram(&self, name: &str, histogram: Histogram) {
        self.histograms.lock().unwrap().insert(name.to_string(), histogram);
    }

    /// Render a sorted `name value` report (used by the CLI `status`).
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name} {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name} {}\n", g.get()));
        }
        for (name, g) in self.float_gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name} {:.6}\n", g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            let s = h.snapshot();
            out.push_str(&format!(
                "{name} count={} mean={:.3} min={:.3} max={:.3} p50={:.3} p90={:.3} \
                 p95={:.3} p99={:.3}\n",
                s.count, s.mean, s.min, s.max, s.p50, s.p90, s.p95, s.p99
            ));
        }
        out
    }

    /// Render the Prometheus text exposition format (`hyper status
    /// --prometheus`): `# TYPE` line per metric, gauges/counters as bare
    /// samples, histograms as summaries (`quantile` labels plus `_sum`
    /// and `_count` series). Metric names are sanitized to the Prometheus
    /// charset (dots and dashes become underscores).
    pub fn report_prometheus(&self) -> String {
        fn sanitize(name: &str) -> String {
            name.chars()
                .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
                .collect()
        }
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            let name = sanitize(name);
            out.push_str(&format!("# TYPE {name} counter\n{name} {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            let name = sanitize(name);
            out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", g.get()));
        }
        for (name, g) in self.float_gauges.lock().unwrap().iter() {
            let name = sanitize(name);
            out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            let name = sanitize(name);
            let s = h.snapshot();
            out.push_str(&format!("# TYPE {name} summary\n"));
            for (q, v) in
                [("0.5", s.p50), ("0.9", s.p90), ("0.95", s.p95), ("0.99", s.p99)]
            {
                out.push_str(&format!("{name}{{quantile=\"{q}\"}} {v}\n"));
            }
            out.push_str(&format!("{name}_sum {}\n", s.mean * s.count as f64));
            out.push_str(&format!("{name}_count {}\n", s.count));
        }
        out
    }

    /// Flatten every registered metric to `(name, value)` samples for
    /// the time-series layer ([`crate::obs::SeriesSet::sample_registry`]):
    /// counters, gauges, and float gauges at their current level,
    /// histograms as `{name}.p50` / `{name}.p99` / `{name}.count`. The
    /// histogram read is the non-destructive snapshot, so sampling never
    /// perturbs windowed consumers (`snapshot_and_reset` users keep
    /// their own windows).
    pub fn sample_values(&self) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push((name.clone(), c.get() as f64));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push((name.clone(), g.get() as f64));
        }
        for (name, g) in self.float_gauges.lock().unwrap().iter() {
            out.push((name.clone(), g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            let s = h.snapshot();
            out.push((format!("{name}.p50"), s.p50));
            out.push((format!("{name}.p99"), s.p99));
            out.push((format!("{name}.count"), s.count as f64));
        }
        out
    }
}

/// Cost accounting: accumulates instance-hours at on-demand or spot rates.
///
/// Mirrors the paper's headline economics: spot/preemptible instances are
/// "usually 2 or 3 times cheaper but can be terminated anytime".
#[derive(Debug, Clone, Default)]
pub struct CostLedger {
    inner: Arc<Mutex<CostInner>>,
}

#[derive(Debug, Default)]
struct CostInner {
    on_demand_usd: f64,
    spot_usd: f64,
    by_type: BTreeMap<String, f64>,
}

impl CostLedger {
    /// An empty ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `hours` of an instance at `usd_per_hour`.
    pub fn charge(&self, instance_type: &str, spot: bool, usd_per_hour: f64, hours: f64) {
        let mut c = self.inner.lock().unwrap();
        let usd = usd_per_hour * hours;
        if spot {
            c.spot_usd += usd;
        } else {
            c.on_demand_usd += usd;
        }
        *c.by_type.entry(instance_type.to_string()).or_default() += usd;
    }

    /// Everything charged so far, USD.
    pub fn total_usd(&self) -> f64 {
        let c = self.inner.lock().unwrap();
        c.on_demand_usd + c.spot_usd
    }

    /// Spot-rate charges, USD.
    pub fn spot_usd(&self) -> f64 {
        self.inner.lock().unwrap().spot_usd
    }

    /// On-demand charges, USD.
    pub fn on_demand_usd(&self) -> f64 {
        self.inner.lock().unwrap().on_demand_usd
    }

    /// Charges grouped by instance type, USD.
    pub fn by_type(&self) -> BTreeMap<String, f64> {
        self.inner.lock().unwrap().by_type.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let r = MetricsRegistry::new();
        r.counter("tasks").add(5);
        r.counter("tasks").inc();
        assert_eq!(r.counter("tasks").get(), 6);
    }

    #[test]
    fn register_counter_shares_external_state() {
        let r = MetricsRegistry::new();
        let owned = Counter::default();
        owned.add(3);
        r.register_counter("hfs.ds.reads", owned.clone());
        assert_eq!(r.counter("hfs.ds.reads").get(), 3, "registry sees owner's count");
        owned.inc();
        assert!(r.report().contains("hfs.ds.reads 4"), "live view, not a copy");
    }

    #[test]
    fn gauges_go_up_and_down() {
        let r = MetricsRegistry::new();
        let g = r.gauge("inflight");
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(r.gauge("inflight").get(), 1);
        g.set(-3);
        assert_eq!(g.get(), -3);
        assert!(r.report().contains("inflight -3"));
    }

    #[test]
    fn float_gauges_hold_fractions() {
        let r = MetricsRegistry::new();
        let g = r.float_gauge("best_loss");
        assert_eq!(g.get(), 0.0);
        g.set(0.731);
        assert_eq!(r.float_gauge("best_loss").get(), 0.731);
        g.set(-1.5);
        assert_eq!(g.get(), -1.5);
        assert!(r.report().contains("best_loss -1.5"));
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::new();
        for v in [1.0, 2.0, 4.0, 8.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 3.75).abs() < 1e-9);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 8.0);
        assert!(h.quantile(0.5) >= 2.0);
    }

    #[test]
    fn histogram_resolves_sub_second_quantiles() {
        // latency-style values in seconds: the old power-of-two buckets
        // collapsed everything below 1.0 into one bin
        let h = Histogram::new();
        for i in 0..1000 {
            // 1 ms .. 10 ms uniform, plus a 2% tail at 100 ms straddling p99
            let v = if i < 980 { 0.001 + 0.009 * (i as f64 / 980.0) } else { 0.1 };
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        // p50 ~ 5.5 ms within bucket error (6.25%) + discretization
        assert!(s.p50 > 0.004 && s.p50 < 0.007, "p50={}", s.p50);
        // the 1% tail at 100 ms must surface in p99
        assert!(s.p99 > 0.08, "p99={}", s.p99);
        assert!(s.p90 < s.p95 + 1e-12 && s.p95 <= s.p99);
        assert!(s.min > 0.0009 && s.max < 0.11);
    }

    #[test]
    fn histogram_quantiles_monotone_and_clamped() {
        let h = Histogram::new();
        for v in [3.0, 3.0, 3.0] {
            h.record(v);
        }
        // one bucket: every quantile clamps into [min, max] = [3, 3]
        assert_eq!(h.quantile(0.5), 3.0);
        assert_eq!(h.quantile(0.99), 3.0);
        // zero and negative values land in bucket 0 without panicking
        h.record(0.0);
        h.record(-1.0);
        assert_eq!(h.count(), 5);
        // non-finite values are ignored, not recorded
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 5);
        let nan_only = Histogram::new();
        nan_only.record(f64::NAN);
        assert_eq!(nan_only.snapshot(), HistogramSnapshot::default(), "no panic, no data");
    }

    #[test]
    fn histogram_snapshot_and_reset_windows() {
        let h = Histogram::new();
        h.record(1.0);
        h.record(2.0);
        let w1 = h.snapshot_and_reset();
        assert_eq!(w1.count, 2);
        assert_eq!(h.count(), 0, "window cleared");
        assert_eq!(h.snapshot(), HistogramSnapshot::default());
        h.record(8.0);
        let w2 = h.snapshot_and_reset();
        assert_eq!(w2.count, 1);
        assert_eq!(w2.max, 8.0);
    }

    #[test]
    fn histogram_bucket_edges_cover_range() {
        // extremes index into valid buckets
        assert_eq!(bucket_index(1e-12), 0);
        assert!(bucket_index(1e12) < HIST_BUCKETS);
        // upper edge of a value's bucket is >= the value (within an octave)
        for v in [0.001, 0.37, 1.0, 7.3, 1000.0] {
            let edge = bucket_upper_edge(bucket_index(v));
            assert!(edge >= v * 0.999, "edge {edge} < value {v}");
            assert!(edge <= v * 2.0, "edge {edge} too far above {v}");
        }
    }

    #[test]
    fn cost_ledger_accumulates() {
        let l = CostLedger::new();
        l.charge("p3.2xlarge", false, 3.06, 2.0);
        l.charge("p3.2xlarge", true, 0.95, 2.0);
        assert!((l.total_usd() - (6.12 + 1.90)).abs() < 1e-9);
        assert!((l.spot_usd() - 1.90).abs() < 1e-9);
        assert_eq!(l.by_type().len(), 1);
    }

    #[test]
    fn report_contains_names() {
        let r = MetricsRegistry::new();
        r.counter("x").inc();
        r.histogram("y").record(3.0);
        let rep = r.report();
        assert!(rep.contains("x 1") && rep.contains("y count=1"));
    }

    #[test]
    fn report_includes_p90_p95() {
        let r = MetricsRegistry::new();
        for i in 0..100 {
            r.histogram("lat").record(0.001 * (i + 1) as f64);
        }
        let rep = r.report();
        assert!(rep.contains("p50="), "{rep}");
        assert!(rep.contains("p90="), "{rep}");
        assert!(rep.contains("p95="), "{rep}");
        assert!(rep.contains("p99="), "{rep}");
    }

    #[test]
    fn register_gauge_histogram_float_gauge_share_external_state() {
        let r = MetricsRegistry::new();
        let g = Gauge::default();
        g.set(7);
        r.register_gauge("depth", g.clone());
        assert_eq!(r.gauge("depth").get(), 7);
        g.dec();
        assert_eq!(r.gauge("depth").get(), 6, "live view, not a copy");

        let fg = FloatGauge::new();
        fg.set(0.25);
        r.register_float_gauge("fill", fg.clone());
        assert_eq!(r.float_gauge("fill").get(), 0.25);

        let h = Histogram::new();
        h.record(2.0);
        r.register_histogram("wait", h.clone());
        assert_eq!(r.histogram("wait").count(), 1);
        h.record(4.0);
        assert_eq!(r.histogram("wait").count(), 2, "live view, not a copy");
    }

    #[test]
    fn prometheus_exposition_format() {
        let r = MetricsRegistry::new();
        r.counter("hfs.ds.reads").add(4);
        r.gauge("queue-depth").set(3);
        r.float_gauge("best_loss").set(-1.5);
        for v in [1.0, 2.0, 4.0, 8.0] {
            r.histogram("serve.latency_s").record(v);
        }
        let text = r.report_prometheus();
        assert!(text.contains("# TYPE hfs_ds_reads counter\nhfs_ds_reads 4\n"), "{text}");
        assert!(text.contains("# TYPE queue_depth gauge\nqueue_depth 3\n"), "{text}");
        assert!(text.contains("best_loss -1.5\n"), "{text}");
        assert!(text.contains("# TYPE serve_latency_s summary\n"), "{text}");
        assert!(text.contains("serve_latency_s{quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("serve_latency_s{quantile=\"0.99\"}"), "{text}");
        assert!(text.contains("serve_latency_s_sum 15\n"), "{text}");
        assert!(text.contains("serve_latency_s_count 4\n"), "{text}");
        // no unsanitized names leak through
        assert!(!text.contains("hfs.ds"), "{text}");
    }

    #[test]
    fn prometheus_exposition_golden_document() {
        // pin the whole document: group order (counters, gauges, float
        // gauges), BTreeMap name order within a group, sanitization of
        // every non-[a-zA-Z0-9_:] byte, and integer formatting
        let r = MetricsRegistry::new();
        r.counter("serve.reqs").add(7);
        r.counter("a-b c").inc();
        // per-priority-class serving counters ride the same dotted-name
        // convention: `serve.shed.<class>` lands as `serve_shed_<class>`
        r.counter("serve.shed.batch").add(2);
        r.counter("serve.shed.paid").add(0);
        r.gauge("fleet.live").set(3);
        r.float_gauge("train.loss").set(-1.5);
        let expect = "# TYPE a_b_c counter\n\
                      a_b_c 1\n\
                      # TYPE serve_reqs counter\n\
                      serve_reqs 7\n\
                      # TYPE serve_shed_batch counter\n\
                      serve_shed_batch 2\n\
                      # TYPE serve_shed_paid counter\n\
                      serve_shed_paid 0\n\
                      # TYPE fleet_live gauge\n\
                      fleet_live 3\n\
                      # TYPE train_loss gauge\n\
                      train_loss -1.5\n";
        assert_eq!(r.report_prometheus(), expect);
    }

    #[test]
    fn sample_values_flattens_every_metric_kind() {
        let r = MetricsRegistry::new();
        r.counter("reqs").add(42);
        r.gauge("live").set(-3);
        r.float_gauge("frac").set(0.5);
        for v in [1.0, 2.0, 4.0, 8.0] {
            r.histogram("lat").record(v);
        }
        let samples: std::collections::BTreeMap<String, f64> =
            r.sample_values().into_iter().collect();
        assert_eq!(samples["reqs"], 42.0);
        assert_eq!(samples["live"], -3.0);
        assert_eq!(samples["frac"], 0.5);
        assert_eq!(samples["lat.count"], 4.0);
        assert!(samples["lat.p50"] >= 1.0 && samples["lat.p99"] <= 8.5);
        // the histogram read is non-destructive: sampling twice sees
        // the same window
        let again: std::collections::BTreeMap<String, f64> =
            r.sample_values().into_iter().collect();
        assert_eq!(again["lat.count"], 4.0);
    }

    #[test]
    fn histogram_hammer_conserves_counts_across_threads() {
        // the atomic-bucket record path must not lose updates under
        // contention: 8 threads x 5000 records, exact conservation
        const THREADS: usize = 8;
        const PER: usize = 5_000;
        let h = Histogram::new();
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..PER {
                        h.record(0.001 + ((t * PER + i) % 97) as f64 / 97.0);
                    }
                });
            }
        });
        let snap = h.snapshot();
        assert_eq!(snap.count as usize, THREADS * PER, "no record lost");
        // bucket occupancy agrees with the count once writers quiesce
        let bucket_total: u64 = h.inner.view().buckets.iter().sum();
        assert_eq!(bucket_total as usize, THREADS * PER);
        assert!(snap.min >= 0.001 && snap.max <= 1.001);
        // mean of the uniform residue pattern, within float-add reorder noise
        assert!((snap.mean - (0.001 + 48.0 / 97.0)).abs() < 1e-3, "mean={}", snap.mean);
    }

    #[test]
    fn histogram_windowed_hammer_conserves_across_resets() {
        // snapshot_and_reset drains with atomic swaps: every record lands
        // in exactly one window even while writers are mid-flight
        use std::sync::atomic::AtomicBool;
        const THREADS: usize = 4;
        const PER: usize = 10_000;
        let h = Histogram::new();
        let done = AtomicBool::new(false);
        let windowed = AtomicU64::new(0);
        std::thread::scope(|s| {
            let writers: Vec<_> = (0..THREADS)
                .map(|_| {
                    s.spawn(|| {
                        for _ in 0..PER {
                            h.record(0.5);
                        }
                    })
                })
                .collect();
            let reaper = s.spawn(|| {
                while !done.load(Ordering::Acquire) {
                    windowed.fetch_add(h.snapshot_and_reset().count, Ordering::Relaxed);
                    std::thread::yield_now();
                }
            });
            for w in writers {
                w.join().unwrap();
            }
            done.store(true, Ordering::Release);
            reaper.join().unwrap();
        });
        let total = windowed.load(Ordering::Relaxed) + h.snapshot().count;
        assert_eq!(total as usize, THREADS * PER, "windows partition the records");
    }
}
