//! Lightweight metrics: counters, gauges, histograms, cost accounting.
//!
//! The paper's master collects "client application logs, CPU/GPU
//! utilization logs and operating system logs" into Logstash; here a
//! [`MetricsRegistry`] plays that role for the coordinator, and
//! [`CostLedger`] implements the spot/on-demand cost accounting the
//! paper's §IV.B cost claims rest on.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;

use std::sync::Mutex;

/// Monotonic counter, cheap to clone and update from any thread.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n` (relaxed ordering; counters are statistics, not sync).
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current count.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Up/down gauge for instantaneous levels (in-flight fetches, queue
/// depths, live connections). Cheap to clone and update from any thread.
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    /// Add 1 to the level.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Subtract 1 from the level.
    pub fn dec(&self) {
        self.add(-1);
    }

    /// Shift the level by `n` (may be negative).
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite the level.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current level.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Gauge over `f64` levels (best loss so far, current rate, ...) where an
/// integer [`Gauge`] would lose the fraction. Stores the value's bits in an
/// `AtomicU64`; cheap to clone and update from any thread.
#[derive(Debug, Clone)]
pub struct FloatGauge(Arc<AtomicU64>);

impl Default for FloatGauge {
    fn default() -> Self {
        Self(Arc::new(AtomicU64::new(0f64.to_bits())))
    }
}

impl FloatGauge {
    /// A gauge at level 0.0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Overwrite the level.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current level.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Lowest tracked exponent: values below 2^-30 (~1 ns in seconds) share
/// bucket 0.
const HIST_MIN_EXP: i32 = -30;
/// Highest tracked exponent: values at/above 2^33 share the last octave.
const HIST_MAX_EXP: i32 = 32;
/// Linear sub-buckets per octave; bounds relative quantile error by 1/16.
const HIST_SUBS: usize = 16;
const HIST_BUCKETS: usize = ((HIST_MAX_EXP - HIST_MIN_EXP + 1) as usize) * HIST_SUBS;

/// Streaming histogram over log-linear buckets (values in arbitrary units —
/// callers document their unit; the serving layer records seconds).
///
/// Each power-of-two octave from 2^-30 to 2^32 is split into 16 linear
/// sub-buckets, so quantiles resolve to ~6% relative error across the whole
/// range — fine enough that a p99 latency SLO check on millisecond-scale
/// values is meaningful. Count/sum/min/max are tracked exactly.
#[derive(Debug, Clone)]
pub struct Histogram {
    inner: Arc<Mutex<HistInner>>,
}

/// Point-in-time summary of a [`Histogram`] (quantiles are upper bucket
/// edges, clamped to the observed min/max).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

#[derive(Debug)]
struct HistInner {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl HistInner {
    fn fresh() -> Self {
        Self {
            buckets: vec![0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return bucket_upper_edge(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    fn snapshot(&self) -> HistogramSnapshot {
        if self.count == 0 {
            return HistogramSnapshot::default();
        }
        HistogramSnapshot {
            count: self.count,
            mean: self.sum / self.count as f64,
            min: self.min,
            max: self.max,
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
        }
    }
}

/// Bucket index for a finite positive value (clamped into the tracked
/// range); `record` filters non-finite input before calling this.
fn bucket_index(v: f64) -> usize {
    if v <= 0.0 || v < 2f64.powi(HIST_MIN_EXP) {
        return 0;
    }
    let exp = (v.log2().floor() as i32).clamp(HIST_MIN_EXP, HIST_MAX_EXP);
    let frac = v / 2f64.powi(exp); // in [1, 2) modulo fp rounding
    let sub = (((frac - 1.0) * HIST_SUBS as f64) as usize).min(HIST_SUBS - 1);
    ((exp - HIST_MIN_EXP) as usize) * HIST_SUBS + sub
}

/// Upper edge of bucket `i`: `2^exp * (1 + (sub+1)/16)`.
fn bucket_upper_edge(i: usize) -> f64 {
    let exp = (i / HIST_SUBS) as i32 + HIST_MIN_EXP;
    let sub = i % HIST_SUBS;
    2f64.powi(exp) * (1.0 + (sub + 1) as f64 / HIST_SUBS as f64)
}

impl Default for Histogram {
    fn default() -> Self {
        Self { inner: Arc::new(Mutex::new(HistInner::fresh())) }
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one value. Non-finite values are ignored: NaN/inf would
    /// corrupt min/max (and thus the clamp in `quantile`) while meaning
    /// nothing as a measurement.
    pub fn record(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let mut h = self.inner.lock().unwrap();
        let idx = bucket_index(v);
        h.buckets[idx] += 1;
        h.count += 1;
        h.sum += v;
        h.min = h.min.min(v);
        h.max = h.max.max(v);
    }

    /// Values recorded so far.
    pub fn count(&self) -> u64 {
        self.inner.lock().unwrap().count
    }

    /// Exact mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        let h = self.inner.lock().unwrap();
        if h.count == 0 {
            0.0
        } else {
            h.sum / h.count as f64
        }
    }

    /// Exact minimum recorded value (0 when empty).
    pub fn min(&self) -> f64 {
        let h = self.inner.lock().unwrap();
        if h.count == 0 { 0.0 } else { h.min }
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max(&self) -> f64 {
        let h = self.inner.lock().unwrap();
        if h.count == 0 { 0.0 } else { h.max }
    }

    /// Approximate quantile (upper bucket edge, clamped to observed range).
    pub fn quantile(&self, q: f64) -> f64 {
        self.inner.lock().unwrap().quantile(q)
    }

    /// Consistent snapshot of count/mean/min/max and p50/p90/p95/p99 under
    /// one lock acquisition (the autoscaler samples this per control tick).
    pub fn snapshot(&self) -> HistogramSnapshot {
        self.inner.lock().unwrap().snapshot()
    }

    /// Drop all recorded values (windowed use: snapshot, then reset).
    pub fn reset(&self) {
        *self.inner.lock().unwrap() = HistInner::fresh();
    }

    /// Snapshot the current window and atomically start a new one.
    pub fn snapshot_and_reset(&self) -> HistogramSnapshot {
        let mut h = self.inner.lock().unwrap();
        let snap = h.snapshot();
        *h = HistInner::fresh();
        snap
    }
}

/// Named metrics registry shared across a run.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: Arc<Mutex<BTreeMap<String, Counter>>>,
    gauges: Arc<Mutex<BTreeMap<String, Gauge>>>,
    float_gauges: Arc<Mutex<BTreeMap<String, FloatGauge>>>,
    histograms: Arc<Mutex<BTreeMap<String, Histogram>>>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// The counter registered under `name` (created on first use).
    pub fn counter(&self, name: &str) -> Counter {
        self.counters.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Register an externally-owned counter under `name` (e.g. the HFS
    /// read-path counters), replacing any counter previously there.
    pub fn register_counter(&self, name: &str, counter: Counter) {
        self.counters.lock().unwrap().insert(name.to_string(), counter);
    }

    /// The gauge registered under `name` (created on first use).
    pub fn gauge(&self, name: &str) -> Gauge {
        self.gauges.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// The float gauge registered under `name` (created on first use).
    pub fn float_gauge(&self, name: &str) -> FloatGauge {
        self.float_gauges.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// The histogram registered under `name` (created on first use).
    pub fn histogram(&self, name: &str) -> Histogram {
        self.histograms.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Render a sorted `name value` report (used by the CLI `status`).
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name} {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name} {}\n", g.get()));
        }
        for (name, g) in self.float_gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name} {:.6}\n", g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            let s = h.snapshot();
            out.push_str(&format!(
                "{name} count={} mean={:.3} min={:.3} max={:.3} p50={:.3} p99={:.3}\n",
                s.count, s.mean, s.min, s.max, s.p50, s.p99
            ));
        }
        out
    }
}

/// Cost accounting: accumulates instance-hours at on-demand or spot rates.
///
/// Mirrors the paper's headline economics: spot/preemptible instances are
/// "usually 2 or 3 times cheaper but can be terminated anytime".
#[derive(Debug, Clone, Default)]
pub struct CostLedger {
    inner: Arc<Mutex<CostInner>>,
}

#[derive(Debug, Default)]
struct CostInner {
    on_demand_usd: f64,
    spot_usd: f64,
    by_type: BTreeMap<String, f64>,
}

impl CostLedger {
    /// An empty ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `hours` of an instance at `usd_per_hour`.
    pub fn charge(&self, instance_type: &str, spot: bool, usd_per_hour: f64, hours: f64) {
        let mut c = self.inner.lock().unwrap();
        let usd = usd_per_hour * hours;
        if spot {
            c.spot_usd += usd;
        } else {
            c.on_demand_usd += usd;
        }
        *c.by_type.entry(instance_type.to_string()).or_default() += usd;
    }

    /// Everything charged so far, USD.
    pub fn total_usd(&self) -> f64 {
        let c = self.inner.lock().unwrap();
        c.on_demand_usd + c.spot_usd
    }

    /// Spot-rate charges, USD.
    pub fn spot_usd(&self) -> f64 {
        self.inner.lock().unwrap().spot_usd
    }

    /// On-demand charges, USD.
    pub fn on_demand_usd(&self) -> f64 {
        self.inner.lock().unwrap().on_demand_usd
    }

    /// Charges grouped by instance type, USD.
    pub fn by_type(&self) -> BTreeMap<String, f64> {
        self.inner.lock().unwrap().by_type.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let r = MetricsRegistry::new();
        r.counter("tasks").add(5);
        r.counter("tasks").inc();
        assert_eq!(r.counter("tasks").get(), 6);
    }

    #[test]
    fn register_counter_shares_external_state() {
        let r = MetricsRegistry::new();
        let owned = Counter::default();
        owned.add(3);
        r.register_counter("hfs.ds.reads", owned.clone());
        assert_eq!(r.counter("hfs.ds.reads").get(), 3, "registry sees owner's count");
        owned.inc();
        assert!(r.report().contains("hfs.ds.reads 4"), "live view, not a copy");
    }

    #[test]
    fn gauges_go_up_and_down() {
        let r = MetricsRegistry::new();
        let g = r.gauge("inflight");
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(r.gauge("inflight").get(), 1);
        g.set(-3);
        assert_eq!(g.get(), -3);
        assert!(r.report().contains("inflight -3"));
    }

    #[test]
    fn float_gauges_hold_fractions() {
        let r = MetricsRegistry::new();
        let g = r.float_gauge("best_loss");
        assert_eq!(g.get(), 0.0);
        g.set(0.731);
        assert_eq!(r.float_gauge("best_loss").get(), 0.731);
        g.set(-1.5);
        assert_eq!(g.get(), -1.5);
        assert!(r.report().contains("best_loss -1.5"));
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::new();
        for v in [1.0, 2.0, 4.0, 8.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 3.75).abs() < 1e-9);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 8.0);
        assert!(h.quantile(0.5) >= 2.0);
    }

    #[test]
    fn histogram_resolves_sub_second_quantiles() {
        // latency-style values in seconds: the old power-of-two buckets
        // collapsed everything below 1.0 into one bin
        let h = Histogram::new();
        for i in 0..1000 {
            // 1 ms .. 10 ms uniform, plus a 2% tail at 100 ms straddling p99
            let v = if i < 980 { 0.001 + 0.009 * (i as f64 / 980.0) } else { 0.1 };
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        // p50 ~ 5.5 ms within bucket error (6.25%) + discretization
        assert!(s.p50 > 0.004 && s.p50 < 0.007, "p50={}", s.p50);
        // the 1% tail at 100 ms must surface in p99
        assert!(s.p99 > 0.08, "p99={}", s.p99);
        assert!(s.p90 < s.p95 + 1e-12 && s.p95 <= s.p99);
        assert!(s.min > 0.0009 && s.max < 0.11);
    }

    #[test]
    fn histogram_quantiles_monotone_and_clamped() {
        let h = Histogram::new();
        for v in [3.0, 3.0, 3.0] {
            h.record(v);
        }
        // one bucket: every quantile clamps into [min, max] = [3, 3]
        assert_eq!(h.quantile(0.5), 3.0);
        assert_eq!(h.quantile(0.99), 3.0);
        // zero and negative values land in bucket 0 without panicking
        h.record(0.0);
        h.record(-1.0);
        assert_eq!(h.count(), 5);
        // non-finite values are ignored, not recorded
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 5);
        let nan_only = Histogram::new();
        nan_only.record(f64::NAN);
        assert_eq!(nan_only.snapshot(), HistogramSnapshot::default(), "no panic, no data");
    }

    #[test]
    fn histogram_snapshot_and_reset_windows() {
        let h = Histogram::new();
        h.record(1.0);
        h.record(2.0);
        let w1 = h.snapshot_and_reset();
        assert_eq!(w1.count, 2);
        assert_eq!(h.count(), 0, "window cleared");
        assert_eq!(h.snapshot(), HistogramSnapshot::default());
        h.record(8.0);
        let w2 = h.snapshot_and_reset();
        assert_eq!(w2.count, 1);
        assert_eq!(w2.max, 8.0);
    }

    #[test]
    fn histogram_bucket_edges_cover_range() {
        // extremes index into valid buckets
        assert_eq!(bucket_index(1e-12), 0);
        assert!(bucket_index(1e12) < HIST_BUCKETS);
        // upper edge of a value's bucket is >= the value (within an octave)
        for v in [0.001, 0.37, 1.0, 7.3, 1000.0] {
            let edge = bucket_upper_edge(bucket_index(v));
            assert!(edge >= v * 0.999, "edge {edge} < value {v}");
            assert!(edge <= v * 2.0, "edge {edge} too far above {v}");
        }
    }

    #[test]
    fn cost_ledger_accumulates() {
        let l = CostLedger::new();
        l.charge("p3.2xlarge", false, 3.06, 2.0);
        l.charge("p3.2xlarge", true, 0.95, 2.0);
        assert!((l.total_usd() - (6.12 + 1.90)).abs() < 1e-9);
        assert!((l.spot_usd() - 1.90).abs() < 1e-9);
        assert_eq!(l.by_type().len(), 1);
    }

    #[test]
    fn report_contains_names() {
        let r = MetricsRegistry::new();
        r.counter("x").inc();
        r.histogram("y").record(3.0);
        let rep = r.report();
        assert!(rep.contains("x 1") && rep.contains("y count=1"));
    }
}
