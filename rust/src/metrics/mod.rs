//! Lightweight metrics: counters, gauges, histograms, cost accounting.
//!
//! The paper's master collects "client application logs, CPU/GPU
//! utilization logs and operating system logs" into Logstash; here a
//! [`MetricsRegistry`] plays that role for the coordinator, and
//! [`CostLedger`] implements the spot/on-demand cost accounting the
//! paper's §IV.B cost claims rest on.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;

use std::sync::Mutex;

/// Monotonic counter, cheap to clone and update from any thread.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Up/down gauge for instantaneous levels (in-flight fetches, queue
/// depths, live connections). Cheap to clone and update from any thread.
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn dec(&self) {
        self.add(-1);
    }

    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Streaming histogram with fixed log-spaced buckets (values in arbitrary
/// units — callers document their unit). Tracks count/sum/min/max exactly.
#[derive(Debug, Clone)]
pub struct Histogram {
    inner: Arc<Mutex<HistInner>>,
}

#[derive(Debug)]
struct HistInner {
    buckets: Vec<u64>, // log2 buckets
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            inner: Arc::new(Mutex::new(HistInner {
                buckets: vec![0; 64],
                count: 0,
                sum: 0.0,
                min: f64::INFINITY,
                max: f64::NEG_INFINITY,
            })),
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, v: f64) {
        let mut h = self.inner.lock().unwrap();
        let idx = if v <= 1.0 { 0 } else { (v.log2().floor() as usize).min(63) };
        h.buckets[idx] += 1;
        h.count += 1;
        h.sum += v;
        h.min = h.min.min(v);
        h.max = h.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.inner.lock().unwrap().count
    }

    pub fn mean(&self) -> f64 {
        let h = self.inner.lock().unwrap();
        if h.count == 0 {
            0.0
        } else {
            h.sum / h.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        let h = self.inner.lock().unwrap();
        if h.count == 0 { 0.0 } else { h.min }
    }

    pub fn max(&self) -> f64 {
        let h = self.inner.lock().unwrap();
        if h.count == 0 { 0.0 } else { h.max }
    }

    /// Approximate quantile from the log buckets (upper bucket edge).
    pub fn quantile(&self, q: f64) -> f64 {
        let h = self.inner.lock().unwrap();
        if h.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * h.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, c) in h.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 2f64.powi(i as i32 + 1);
            }
        }
        h.max
    }
}

/// Named metrics registry shared across a run.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: Arc<Mutex<BTreeMap<String, Counter>>>,
    gauges: Arc<Mutex<BTreeMap<String, Gauge>>>,
    histograms: Arc<Mutex<BTreeMap<String, Histogram>>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Counter {
        self.counters.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        self.gauges.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Histogram {
        self.histograms.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Render a sorted `name value` report (used by the CLI `status`).
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name} {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name} {}\n", g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "{name} count={} mean={:.3} min={:.3} max={:.3}\n",
                h.count(),
                h.mean(),
                h.min(),
                h.max()
            ));
        }
        out
    }
}

/// Cost accounting: accumulates instance-hours at on-demand or spot rates.
///
/// Mirrors the paper's headline economics: spot/preemptible instances are
/// "usually 2 or 3 times cheaper but can be terminated anytime".
#[derive(Debug, Clone, Default)]
pub struct CostLedger {
    inner: Arc<Mutex<CostInner>>,
}

#[derive(Debug, Default)]
struct CostInner {
    on_demand_usd: f64,
    spot_usd: f64,
    by_type: BTreeMap<String, f64>,
}

impl CostLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `hours` of an instance at `usd_per_hour`.
    pub fn charge(&self, instance_type: &str, spot: bool, usd_per_hour: f64, hours: f64) {
        let mut c = self.inner.lock().unwrap();
        let usd = usd_per_hour * hours;
        if spot {
            c.spot_usd += usd;
        } else {
            c.on_demand_usd += usd;
        }
        *c.by_type.entry(instance_type.to_string()).or_default() += usd;
    }

    pub fn total_usd(&self) -> f64 {
        let c = self.inner.lock().unwrap();
        c.on_demand_usd + c.spot_usd
    }

    pub fn spot_usd(&self) -> f64 {
        self.inner.lock().unwrap().spot_usd
    }

    pub fn on_demand_usd(&self) -> f64 {
        self.inner.lock().unwrap().on_demand_usd
    }

    pub fn by_type(&self) -> BTreeMap<String, f64> {
        self.inner.lock().unwrap().by_type.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters() {
        let r = MetricsRegistry::new();
        r.counter("tasks").add(5);
        r.counter("tasks").inc();
        assert_eq!(r.counter("tasks").get(), 6);
    }

    #[test]
    fn gauges_go_up_and_down() {
        let r = MetricsRegistry::new();
        let g = r.gauge("inflight");
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(r.gauge("inflight").get(), 1);
        g.set(-3);
        assert_eq!(g.get(), -3);
        assert!(r.report().contains("inflight -3"));
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::new();
        for v in [1.0, 2.0, 4.0, 8.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 3.75).abs() < 1e-9);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 8.0);
        assert!(h.quantile(0.5) >= 2.0);
    }

    #[test]
    fn cost_ledger_accumulates() {
        let l = CostLedger::new();
        l.charge("p3.2xlarge", false, 3.06, 2.0);
        l.charge("p3.2xlarge", true, 0.95, 2.0);
        assert!((l.total_usd() - (6.12 + 1.90)).abs() < 1e-9);
        assert!((l.spot_usd() - 1.90).abs() < 1e-9);
        assert_eq!(l.by_type().len(), 1);
    }

    #[test]
    fn report_contains_names() {
        let r = MetricsRegistry::new();
        r.counter("x").inc();
        r.histogram("y").record(3.0);
        let rep = r.report();
        assert!(rep.contains("x 1") && rep.contains("y count=1"));
    }
}
