//! Crate-level configuration: artifact locations, run options, and the
//! HFS mount tunables.
//!
//! Every knob here is documented (defaults and the subsystem that reads
//! it) in `docs/CONFIG.md`.

use std::path::{Path, PathBuf};

use crate::Error;

/// Where the AOT artifacts live and which preset to run.
///
/// Read by [`crate::runtime`] (artifact loading) and the CLI entry points.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Directory holding `manifest.json` and the lowered HLO artifacts.
    pub artifacts_dir: PathBuf,
    /// Preset name (`tiny`, ...) selecting which artifact set to execute.
    pub preset: String,
    /// RNG seed threaded through deterministic runs.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self { artifacts_dir: default_artifacts_dir(), preset: "tiny".into(), seed: 0 }
    }
}

/// Tunables of one mounted HFS namespace: the RAM cache tier, the
/// optional local-disk spill tier, and adaptive prefetch.
///
/// Read by [`crate::hfs::HyperFs::mount_cfg`]. The convenience
/// constructors `mount` / `mount_with` cover the common cases (defaults;
/// explicit RAM budget + prefetch cap); this struct is the full surface.
#[derive(Debug, Clone)]
pub struct HfsConfig {
    /// Byte budget of the in-RAM chunk cache (models instance memory).
    pub cache_bytes: u64,
    /// Directory for the local-disk spill tier; `None` disables spilling
    /// (RAM evictions are dropped, as on diskless nodes).
    pub spill_dir: Option<PathBuf>,
    /// Byte budget of the spill tier's on-disk LRU (only read when
    /// `spill_dir` is set).
    pub spill_bytes: u64,
    /// Cap on the adaptive prefetch depth, in chunks (0 disables
    /// readahead). The working depth moves within `[0, cap]` with the
    /// observed access pattern; this is the ceiling, not a fixed depth.
    pub prefetch_max_depth: u32,
    /// Serve spill-tier hits as mmap-backed views instead of copying the
    /// chunk through a heap buffer (only read when `spill_dir` is set;
    /// no-op on non-unix targets). The digest is verified over the mapped
    /// pages on first map, so corruption detection is unchanged.
    pub spill_mmap: bool,
    /// Run readahead and spill writes on background fetch lanes. Turn off
    /// for deterministic tests/benches (all I/O inline) and virtual-time
    /// sims (no threads at all).
    pub background_prefetch: bool,
}

impl Default for HfsConfig {
    fn default() -> Self {
        Self {
            cache_bytes: 1 << 30,
            spill_dir: None,
            spill_bytes: 8 << 30,
            spill_mmap: true,
            prefetch_max_depth: 8,
            background_prefetch: true,
        }
    }
}

/// Tunables of one HFS namespace upload: chunk geometry, manifest
/// sharding, and small-file packing.
///
/// Read by [`crate::hfs::Uploader`]. Defaults produce the sharded
/// (format-2) content-addressed layout; `legacy_layout` writes the
/// pre-shard monolithic manifest for back-compat tests and old readers.
#[derive(Debug, Clone)]
pub struct UploadConfig {
    /// Target chunk size in bytes; files are packed/split against this.
    pub chunk_size: u64,
    /// File entries per manifest shard. Mount cost is O(files/shard_files)
    /// root entries; readers page shards in lazily on first path touch.
    pub shard_files: usize,
    /// Files at or below this many bytes are packed into shared archive
    /// chunks instead of occupying chunk space alone (0 disables packing).
    pub pack_threshold: u64,
    /// Write the pre-shard monolithic `manifest.json` and `(ns, id)` chunk
    /// keys instead of the sharded content-addressed layout.
    pub legacy_layout: bool,
}

impl Default for UploadConfig {
    fn default() -> Self {
        Self {
            chunk_size: crate::hfs::DEFAULT_CHUNK_SIZE,
            shard_files: 4096,
            pack_threshold: 0,
            legacy_layout: false,
        }
    }
}

/// Which early-stopping policy a hyperparameter search runs under.
///
/// Read by [`crate::search`] (`make_scheduler`) and the `search:` stanza of
/// workflow recipes. `Grid` is the no-early-stopping baseline the paper's
/// §IV.C sweep corresponds to; the other three trade exhaustiveness for
/// trial-steps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchAlgo {
    /// Run every trial to `max_steps` (the §IV.C full sweep).
    Grid,
    /// Asynchronous successive halving: geometric rungs, top-`1/eta`
    /// promotion.
    Asha,
    /// Hyperband-style sweep of ASHA brackets with staggered first rungs.
    Hyperband,
    /// Median stopping rule: stop a trial whose milestone loss is above
    /// the median of all losses reported at that milestone.
    Median,
}

impl std::str::FromStr for SearchAlgo {
    type Err = Error;

    fn from_str(s: &str) -> std::result::Result<Self, Error> {
        match s.to_ascii_lowercase().as_str() {
            "grid" => Ok(SearchAlgo::Grid),
            "asha" => Ok(SearchAlgo::Asha),
            "hyperband" => Ok(SearchAlgo::Hyperband),
            "median" => Ok(SearchAlgo::Median),
            other => Err(Error::Recipe(format!("unknown search algo {other:?}"))),
        }
    }
}

impl std::fmt::Display for SearchAlgo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SearchAlgo::Grid => "grid",
            SearchAlgo::Asha => "asha",
            SearchAlgo::Hyperband => "hyperband",
            SearchAlgo::Median => "median",
        })
    }
}

/// Tunables of one hyperparameter search run: trial budget, rung geometry,
/// virtual-time step cost, checkpoint cadence, and the fleet it runs on.
///
/// Read by [`crate::search::SearchDriver`]; recipes populate it from their
/// `search:` stanza. Every knob is documented (defaults and the subsystem
/// that reads it) in `docs/CONFIG.md`.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Trials to sample from the parameter space (§II.C `n`); `0` means
    /// the full discrete Cartesian grid.
    pub trials: usize,
    /// Steps a trial must complete to count as finished (`R`).
    pub max_steps: u64,
    /// First rung milestone in steps (`r`); later rungs are `r * eta^k`.
    pub rung_first_steps: u64,
    /// Successive-halving reduction factor (promote the top `1/eta`).
    pub eta: u32,
    /// Virtual seconds one training step takes on a fleet node.
    pub step_time_s: f64,
    /// Save a `TrainCheckpoint` every this many steps while inside a rung
    /// (`0` = checkpoint only at rung milestones). Milestones and
    /// preemption-notice drains always checkpoint.
    pub checkpoint_every_steps: u64,
    /// Keep only the newest `k` checkpoint blobs per trial (`0` =
    /// unbounded, not recommended for thousand-trial searches).
    pub keep_last_k: usize,
    /// Fleet size (one trial runs per node at a time).
    pub workers: usize,
    /// Provision fleet nodes on the spot market (vs on-demand).
    pub spot: bool,
    /// Instance type name from the catalog (e.g. `"m5.xlarge"`).
    pub instance: String,
    /// Early-stopping policy.
    pub algo: SearchAlgo,
    /// Seed for assignment sampling, learning curves, and the cloud models.
    pub seed: u64,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            trials: 64,
            max_steps: 81,
            rung_first_steps: 3,
            eta: 3,
            step_time_s: 1.0,
            checkpoint_every_steps: 3,
            keep_last_k: 2,
            workers: 8,
            spot: true,
            instance: "m5.xlarge".into(),
            algo: SearchAlgo::Asha,
            seed: 0,
        }
    }
}

/// How a gang-scheduled training job reacts to losing members.
///
/// Read by [`crate::train`] and the `train:` stanza of workflow recipes.
/// `Elastic` is the paper's preemptible-fleet posture (FfDL-style
/// recovery: shrink, keep stepping, grow back); `Rigid` is the classic
/// HPC gang that blocks until full capacity returns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GangMode {
    /// Re-form at the surviving world size (≥ `gang_min`) and keep
    /// committing steps; grow back when replacements arrive.
    Elastic,
    /// Block after any member loss until the gang is back at
    /// `world_size`.
    Rigid,
}

impl std::str::FromStr for GangMode {
    type Err = Error;

    fn from_str(s: &str) -> std::result::Result<Self, Error> {
        match s.to_ascii_lowercase().as_str() {
            "elastic" => Ok(GangMode::Elastic),
            "rigid" => Ok(GangMode::Rigid),
            other => Err(Error::Recipe(format!("unknown gang mode {other:?}"))),
        }
    }
}

impl std::fmt::Display for GangMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            GangMode::Elastic => "elastic",
            GangMode::Rigid => "rigid",
        })
    }
}

/// Tunables of one gang-scheduled distributed training run: gang
/// geometry, the data partition, the step-cost inputs, checkpoint
/// cadence, and the fleet it runs on.
///
/// Read by [`crate::train::TrainDriver`]; recipes populate it from their
/// `train:` stanza. Every knob is documented (defaults and the subsystem
/// that reads it) in `docs/CONFIG.md`.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Full gang size (data-parallel world size, N).
    pub world_size: usize,
    /// Smallest world size an [`GangMode::Elastic`] gang re-forms at
    /// after member loss (1..=`world_size`; ignored by `Rigid`).
    pub gang_min: usize,
    /// Steps the job must commit to finish.
    pub total_steps: u64,
    /// Data partitions resharded over the gang every step (each step
    /// covers every partition exactly once).
    pub partitions: u64,
    /// Virtual seconds one node spends computing one partition.
    pub sample_time_s: f64,
    /// Gradient/model bytes exchanged by the per-step ring allreduce.
    pub model_bytes: u64,
    /// Save a `TrainCheckpoint` every this many committed steps (`0` =
    /// only preemption-notice drain checkpoints).
    pub checkpoint_every_steps: u64,
    /// Keep only the newest `k` checkpoint blobs (`0` = unbounded).
    pub keep_last_k: usize,
    /// Elastic (shrink/grow) vs rigid (block at full capacity) recovery.
    pub mode: GangMode,
    /// Provision gang nodes on the spot market (vs on-demand).
    pub spot: bool,
    /// Instance type name from the catalog (e.g. `"p3.2xlarge"`).
    pub instance: String,
    /// Seed for the loss trajectory and the cloud models.
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            world_size: 8,
            gang_min: 2,
            total_steps: 100,
            partitions: 512,
            sample_time_s: 0.02,
            model_bytes: 100 << 20,
            checkpoint_every_steps: 10,
            keep_last_k: 2,
            mode: GangMode::Elastic,
            spot: true,
            instance: "p3.2xlarge".into(),
            seed: 0,
        }
    }
}

/// Tunables of the serving hot path: the priority-class traffic mix,
/// the adaptive batch-window controller, and multi-model weight
/// swapping.
///
/// Read by the CLI `serve` / `report` paths, which translate it onto
/// [`crate::serve::ServeSimConfig`] (virtual-time fleet) and
/// [`crate::serve::ServerConfig`] (threaded stack). The defaults
/// reproduce the classic single-class, single-model, fixed-window
/// behavior exactly. Every knob is documented in `docs/CONFIG.md`.
#[derive(Debug, Clone)]
pub struct ServeHotConfig {
    /// Arrival weights per priority class, `[paid, free, batch]` order
    /// (matches `crate::serve::Priority::ALL`); zero-weight classes never
    /// arrive. The default routes everything `paid`.
    pub class_mix: [f64; 3],
    /// Run the adaptive batch-window controller (shrink the close window
    /// toward the SLO, widen it under slack) instead of a fixed policy.
    pub adaptive: bool,
    /// Latency objective the adaptive controller defends, seconds.
    pub slo_p99_s: f64,
    /// Distinct models the replica fleet serves (1 = classic
    /// single-model fleet).
    pub models: usize,
    /// Seconds of service blackout one weight swap costs (read when
    /// `models > 1`).
    pub swap_s: f64,
}

impl Default for ServeHotConfig {
    fn default() -> Self {
        Self {
            class_mix: [1.0, 0.0, 0.0],
            adaptive: false,
            slo_p99_s: 0.25,
            models: 1,
            swap_s: 8.0,
        }
    }
}

/// Tunables of the observability layer: the [`crate::obs`] flight
/// recorder's bound, the master switch, and where `hyper trace` (and the
/// instrumented benches) write Chrome-trace exports.
///
/// Read by [`crate::obs::FlightRecorder::from_config`] and the CLI entry
/// points. Every knob is documented in `docs/CONFIG.md`; the sizing
/// discussion ("how many records is a storm?") lives in
/// `docs/OBSERVABILITY.md`.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Record spans/events at all. Off means every instrumentation point
    /// short-circuits before building a record (zero retained entries).
    pub enabled: bool,
    /// Flight-recorder bound: the newest `capacity` records are retained,
    /// older ones are evicted and counted as dropped.
    pub capacity: usize,
    /// Where to write the Chrome trace-event JSON export; `None` means
    /// export only when a caller (CLI `--out`) asks.
    pub export_path: Option<PathBuf>,
    /// Per-series bound of the [`crate::obs::SeriesSet`] time-series
    /// layer: each named series keeps its newest `series_capacity`
    /// samples (older ones are evicted and counted as dropped, same
    /// discipline as the flight recorder).
    pub series_capacity: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self { enabled: true, capacity: 65_536, export_path: None, series_capacity: 4096 }
    }
}

/// `artifacts/` next to the workspace root (env `HYPER_ARTIFACTS` wins).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("HYPER_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // walk up from CWD looking for artifacts/manifest.json
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    for _ in 0..5 {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            break;
        }
    }
    PathBuf::from("artifacts")
}

/// True if the artifacts for `preset` exist under `dir`.
pub fn artifacts_available(dir: &Path, preset: &str) -> bool {
    dir.join("manifest.json").exists() && dir.join(format!("{preset}_train.hlo.txt")).exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config() {
        let c = RunConfig::default();
        assert_eq!(c.preset, "tiny");
    }

    #[test]
    fn default_hfs_config_spills_nowhere() {
        let c = HfsConfig::default();
        assert!(c.spill_dir.is_none());
        assert!(c.spill_mmap, "mmap spill reads are the default");
        assert!(c.prefetch_max_depth > 0);
        assert!(c.background_prefetch);
    }

    #[test]
    fn default_upload_config_is_sharded_cas() {
        let c = UploadConfig::default();
        assert!(!c.legacy_layout, "new namespaces get the sharded layout");
        assert_eq!(c.pack_threshold, 0, "packing is opt-in");
        assert!(c.shard_files >= 1);
        assert_eq!(c.chunk_size, crate::hfs::DEFAULT_CHUNK_SIZE);
    }

    #[test]
    fn search_algo_parses_and_displays() {
        for (s, a) in [
            ("grid", SearchAlgo::Grid),
            ("ASHA", SearchAlgo::Asha),
            ("hyperband", SearchAlgo::Hyperband),
            ("median", SearchAlgo::Median),
        ] {
            assert_eq!(s.parse::<SearchAlgo>().unwrap(), a);
        }
        assert_eq!(SearchAlgo::Asha.to_string(), "asha");
        assert!(matches!("annealing".parse::<SearchAlgo>(), Err(Error::Recipe(_))));
    }

    #[test]
    fn default_search_config_is_coherent() {
        let c = SearchConfig::default();
        assert!(c.eta >= 2);
        assert!(c.rung_first_steps >= 1);
        assert!(c.max_steps >= c.rung_first_steps);
        assert!(c.step_time_s > 0.0);
        assert_eq!(c.algo, SearchAlgo::Asha);
    }

    #[test]
    fn gang_mode_parses_and_displays() {
        for (s, m) in [("elastic", GangMode::Elastic), ("RIGID", GangMode::Rigid)] {
            assert_eq!(s.parse::<GangMode>().unwrap(), m);
        }
        assert_eq!(GangMode::Elastic.to_string(), "elastic");
        assert!(matches!("gangnam".parse::<GangMode>(), Err(Error::Recipe(_))));
    }

    #[test]
    fn default_train_config_is_coherent() {
        let c = TrainConfig::default();
        assert!(c.world_size >= 1);
        assert!((1..=c.world_size).contains(&c.gang_min));
        assert!(c.total_steps >= 1);
        assert!(c.partitions >= c.world_size as u64, "every rank gets a shard");
        assert!(c.sample_time_s > 0.0);
        assert_eq!(c.mode, GangMode::Elastic);
        assert!(c.spot, "the paper's headline fleet is preemptible");
    }

    #[test]
    fn default_serve_hot_config_is_the_classic_stack() {
        let c = ServeHotConfig::default();
        assert_eq!(c.class_mix, [1.0, 0.0, 0.0], "single-class by default");
        assert!(!c.adaptive, "fixed batch window by default");
        assert_eq!(c.models, 1, "single-model fleet by default");
        assert!(c.slo_p99_s > 0.0);
        assert!(c.swap_s > 0.0);
    }

    #[test]
    fn default_obs_config_is_on_and_bounded() {
        let c = ObsConfig::default();
        assert!(c.enabled, "tracing is cheap enough to leave on");
        assert!(c.capacity >= 1024);
        assert!(c.export_path.is_none());
        assert!(c.series_capacity >= 256, "series hold a useful window");
    }

    #[test]
    fn availability_check() {
        let dir = crate::util::TempDir::new().unwrap();
        assert!(!artifacts_available(dir.path(), "tiny"));
        std::fs::write(dir.path().join("manifest.json"), "{}").unwrap();
        std::fs::write(dir.path().join("tiny_train.hlo.txt"), "x").unwrap();
        assert!(artifacts_available(dir.path(), "tiny"));
    }
}
