//! Crate-level configuration: artifact locations and run options.

use std::path::{Path, PathBuf};

/// Where the AOT artifacts live and which preset to run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub artifacts_dir: PathBuf,
    pub preset: String,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self { artifacts_dir: default_artifacts_dir(), preset: "tiny".into(), seed: 0 }
    }
}

/// `artifacts/` next to the workspace root (env `HYPER_ARTIFACTS` wins).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("HYPER_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // walk up from CWD looking for artifacts/manifest.json
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    for _ in 0..5 {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            break;
        }
    }
    PathBuf::from("artifacts")
}

/// True if the artifacts for `preset` exist under `dir`.
pub fn artifacts_available(dir: &Path, preset: &str) -> bool {
    dir.join("manifest.json").exists() && dir.join(format!("{preset}_train.hlo.txt")).exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config() {
        let c = RunConfig::default();
        assert_eq!(c.preset, "tiny");
    }

    #[test]
    fn availability_check() {
        let dir = crate::util::TempDir::new().unwrap();
        assert!(!artifacts_available(dir.path(), "tiny"));
        std::fs::write(dir.path().join("manifest.json"), "{}").unwrap();
        std::fs::write(dir.path().join("tiny_train.hlo.txt"), "x").unwrap();
        assert!(artifacts_available(dir.path(), "tiny"));
    }
}
