//! Crate-level configuration: artifact locations, run options, and the
//! HFS mount tunables.
//!
//! Every knob here is documented (defaults and the subsystem that reads
//! it) in `docs/CONFIG.md`.

use std::path::{Path, PathBuf};

/// Where the AOT artifacts live and which preset to run.
///
/// Read by [`crate::runtime`] (artifact loading) and the CLI entry points.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Directory holding `manifest.json` and the lowered HLO artifacts.
    pub artifacts_dir: PathBuf,
    /// Preset name (`tiny`, ...) selecting which artifact set to execute.
    pub preset: String,
    /// RNG seed threaded through deterministic runs.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self { artifacts_dir: default_artifacts_dir(), preset: "tiny".into(), seed: 0 }
    }
}

/// Tunables of one mounted HFS namespace: the RAM cache tier, the
/// optional local-disk spill tier, and adaptive prefetch.
///
/// Read by [`crate::hfs::HyperFs::mount_cfg`]. The convenience
/// constructors `mount` / `mount_with` cover the common cases (defaults;
/// explicit RAM budget + prefetch cap); this struct is the full surface.
#[derive(Debug, Clone)]
pub struct HfsConfig {
    /// Byte budget of the in-RAM chunk cache (models instance memory).
    pub cache_bytes: u64,
    /// Directory for the local-disk spill tier; `None` disables spilling
    /// (RAM evictions are dropped, as on diskless nodes).
    pub spill_dir: Option<PathBuf>,
    /// Byte budget of the spill tier's on-disk LRU (only read when
    /// `spill_dir` is set).
    pub spill_bytes: u64,
    /// Cap on the adaptive prefetch depth, in chunks (0 disables
    /// readahead). The working depth moves within `[0, cap]` with the
    /// observed access pattern; this is the ceiling, not a fixed depth.
    pub prefetch_max_depth: u32,
    /// Run readahead and spill writes on background fetch lanes. Turn off
    /// for deterministic tests/benches (all I/O inline) and virtual-time
    /// sims (no threads at all).
    pub background_prefetch: bool,
}

impl Default for HfsConfig {
    fn default() -> Self {
        Self {
            cache_bytes: 1 << 30,
            spill_dir: None,
            spill_bytes: 8 << 30,
            prefetch_max_depth: 8,
            background_prefetch: true,
        }
    }
}

/// `artifacts/` next to the workspace root (env `HYPER_ARTIFACTS` wins).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("HYPER_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // walk up from CWD looking for artifacts/manifest.json
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    for _ in 0..5 {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            break;
        }
    }
    PathBuf::from("artifacts")
}

/// True if the artifacts for `preset` exist under `dir`.
pub fn artifacts_available(dir: &Path, preset: &str) -> bool {
    dir.join("manifest.json").exists() && dir.join(format!("{preset}_train.hlo.txt")).exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config() {
        let c = RunConfig::default();
        assert_eq!(c.preset, "tiny");
    }

    #[test]
    fn default_hfs_config_spills_nowhere() {
        let c = HfsConfig::default();
        assert!(c.spill_dir.is_none());
        assert!(c.prefetch_max_depth > 0);
        assert!(c.background_prefetch);
    }

    #[test]
    fn availability_check() {
        let dir = crate::util::TempDir::new().unwrap();
        assert!(!artifacts_available(dir.path(), "tiny"));
        std::fs::write(dir.path().join("manifest.json"), "{}").unwrap();
        std::fs::write(dir.path().join("tiny_train.hlo.txt"), "x").unwrap();
        assert!(artifacts_available(dir.path(), "tiny"));
    }
}
