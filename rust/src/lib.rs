//! The repository README below is the front page of this documentation
//! (`#![doc = include_str!(...)]` keeps the two in lockstep); the module
//! list in the sidebar is the same map with live links.
#![doc = include_str!(concat!("../", env!("CARGO_PKG_README")))]

pub mod baselines;
pub mod cloud;
pub mod cluster;
pub mod config;
pub mod dataloader;
pub mod error;
pub mod etl;
pub mod fleet;
pub mod hfs;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod scheduler;
pub mod search;
pub mod serve;
pub mod sim;
pub mod storage;
pub mod train;
pub mod util;
pub mod workflow;

pub use error::{Error, Result};
