//! # hyper-dist — reproduction of *Hyper: Distributed Cloud Processing for
//! Large-Scale Deep Learning Tasks* (Buniatyan, 2019).
//!
//! Hyper is a hybrid distributed cloud framework: a chunked distributed
//! file system backed by object storage (HFS), a fault-tolerant workflow /
//! task scheduler driven by YAML recipes, spot-instance cost optimization,
//! and the four evaluation workloads (ETL preprocessing, distributed
//! training, hyperparameter search, large-scale inference).
//!
//! The crate is the **Layer-3 rust coordinator** of a three-layer stack:
//! Layer 2 (JAX model) and Layer 1 (Pallas kernels) live in `python/` and
//! are AOT-lowered to HLO text in `artifacts/`, which [`runtime`] loads
//! and executes through the PJRT C API. Python is never on the request
//! path.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`sim`] — deterministic discrete-event simulation core (virtual time).
//! * [`storage`] — object stores: in-memory, disk, and the S3 latency model.
//! * [`hfs`] — the Hyper File System: chunking, caching, prefetch.
//! * [`cloud`] — instance catalog, provisioner, spot market, network model.
//! * [`cluster`] — master, node servers, KV store, log collection.
//! * [`workflow`] — YAML recipes -> DAG of experiments -> tasks, §II.C params.
//! * [`scheduler`] — fault-tolerant task scheduling state machine + drivers.
//! * [`runtime`] — PJRT executor for the AOT artifacts (train/eval/infer).
//! * [`serve`] — inference serving: dynamic batching, admission control,
//!   preemption-aware replica autoscaling (§IV.D at request granularity).
//! * [`dataloader`] — async prefetching data pipeline over HFS.
//! * [`etl`] — the §IV.A text preprocessing pipeline.
//! * [`metrics`] — counters, histograms, cost accounting.
//! * [`baselines`] — download-first FS, NFS model, sequential scheduler.
//! * [`util`] — from-scratch JSON / YAML / bench / property-test
//!   substrates (this image is offline; see DESIGN.md §Substitutions).

pub mod baselines;
pub mod cloud;
pub mod cluster;
pub mod config;
pub mod dataloader;
pub mod error;
pub mod etl;
pub mod hfs;
pub mod metrics;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod sim;
pub mod storage;
pub mod util;
pub mod workflow;

pub use error::{Error, Result};
