//! Synthetic-but-configurable learning curves.
//!
//! Every trial needs a loss trajectory the schedulers can rank without a
//! real training run: an exponential decay `floor + (l0 - floor)·e^(-s/τ)`
//! whose floor and time constant are deterministic functions of the
//! trial's [`Assignment`] and the search seed. Two properties matter:
//!
//! 1. **Determinism across resumes.** A trial preempted at step 40 and
//!    resumed on another node reports the exact same losses it would have
//!    reported uninterrupted — the curve is a pure function of
//!    `(assignment, seed, step)`, mirroring §III.D's "training can be
//!    continued without any additional code modifications".
//! 2. **Configurable rank stability.** With `tau` pinned to a single
//!    value and `noise = 0`, the loss ranking of any two trials is the
//!    same at every step, so ASHA provably never cuts the eventual best
//!    trial — the `search_asha` bench's equal-best guarantee rests on
//!    this. Widening `tau` and adding noise makes early rungs deceptive,
//!    which is the regime the median-rule baseline is for.

use crate::sim::SimRng;
use crate::workflow::{Assignment, ParamValue};

/// Shape of the synthetic loss curves a search runs against.
#[derive(Debug, Clone)]
pub struct CurveConfig {
    /// Loss every trial starts from at step 0.
    pub loss_start: f64,
    /// Final-loss (`floor`) sampling range per trial, `[lo, hi)`.
    pub floor: [f64; 2],
    /// Decay time-constant sampling range in steps, `[lo, hi)`. Equal
    /// endpoints pin τ and make trial rankings step-invariant.
    pub tau: [f64; 2],
    /// Uniform per-step observation noise amplitude (0 = noiseless).
    pub noise: f64,
    /// When set and the assignment carries a float `lr`, the floor is
    /// determined by the squared log10-distance to this optimum instead
    /// of being sampled — gives the space a structure worth searching.
    pub lr_optimum: Option<f64>,
    /// Floor added per unit of squared log10-distance from `lr_optimum`.
    pub lr_penalty: f64,
}

impl Default for CurveConfig {
    fn default() -> Self {
        Self {
            loss_start: 4.0,
            floor: [0.5, 2.5],
            tau: [10.0, 40.0],
            noise: 0.02,
            lr_optimum: None,
            lr_penalty: 0.8,
        }
    }
}

/// Factory turning assignments into [`LearningCurve`]s.
#[derive(Debug, Clone)]
pub struct CurveModel {
    cfg: CurveConfig,
    seed: u64,
}

impl CurveModel {
    /// A model over `cfg`, keyed by the search seed.
    pub fn new(cfg: CurveConfig, seed: u64) -> Self {
        Self { cfg, seed }
    }

    /// The deterministic curve of one assignment.
    pub fn curve(&self, a: &Assignment) -> LearningCurve {
        let key = assignment_key(a) ^ self.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = SimRng::new(key);
        let sampled_floor = rng.gen_range_f64(self.cfg.floor[0], self.cfg.floor[1]);
        let floor = match (self.cfg.lr_optimum, a.get("lr")) {
            (Some(opt), Some(ParamValue::Float(lr))) if *lr > 0.0 && opt > 0.0 => {
                let d = lr.log10() - opt.log10();
                self.cfg.floor[0] + self.cfg.lr_penalty * d * d
            }
            _ => sampled_floor,
        };
        let tau = rng.gen_range_f64(self.cfg.tau[0], self.cfg.tau[1]).max(1e-9);
        LearningCurve {
            l0: self.cfg.loss_start.max(floor),
            floor,
            tau,
            noise: self.cfg.noise,
            key,
        }
    }
}

/// One trial's loss trajectory: `floor + (l0 - floor)·e^(-step/τ)` plus
/// optional deterministic per-step noise.
#[derive(Debug, Clone, PartialEq)]
pub struct LearningCurve {
    /// Loss at step 0.
    pub l0: f64,
    /// Asymptotic loss as steps → ∞.
    pub floor: f64,
    /// Decay time constant, steps.
    pub tau: f64,
    /// Observation-noise amplitude.
    pub noise: f64,
    key: u64,
}

impl LearningCurve {
    /// Observed loss after `step` completed steps. Pure: the same
    /// `(curve, step)` always yields the same value, so a resumed trial
    /// replays its history bit-for-bit.
    pub fn loss_at(&self, step: u64) -> f64 {
        let base = self.floor + (self.l0 - self.floor) * (-(step as f64) / self.tau).exp();
        if self.noise == 0.0 {
            return base;
        }
        let mut rng = SimRng::new(self.key ^ step.wrapping_mul(0xA076_1D64_78BD_642F));
        base + self.noise * (2.0 * rng.next_f64() - 1.0)
    }
}

/// Digest of the canonical `k=v;` rendering (BTreeMap order is stable),
/// via the crate's one FNV-1a implementation.
fn assignment_key(a: &Assignment) -> u64 {
    let mut canonical = String::new();
    for (k, v) in a {
        canonical.push_str(k);
        canonical.push('=');
        canonical.push_str(&v.to_string());
        canonical.push(';');
    }
    crate::hfs::chunk::fnv1a64(canonical.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn asg(pairs: &[(&str, ParamValue)]) -> Assignment {
        pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()
    }

    #[test]
    fn noiseless_curve_decays_monotonically_to_floor() {
        let cfg = CurveConfig { noise: 0.0, ..Default::default() };
        let c = CurveModel::new(cfg, 7).curve(&asg(&[("x", ParamValue::Int(1))]));
        let mut prev = f64::INFINITY;
        for s in 0..200 {
            let l = c.loss_at(s);
            assert!(l <= prev + 1e-12, "loss rose at step {s}");
            assert!(l >= c.floor - 1e-12);
            prev = l;
        }
        assert!((c.loss_at(100_000) - c.floor).abs() < 1e-6);
        assert_eq!(c.loss_at(0), c.l0);
    }

    #[test]
    fn deterministic_per_assignment_and_seed() {
        let a = asg(&[("lr", ParamValue::Float(0.01)), ("bs", ParamValue::Int(64))]);
        let m = CurveModel::new(CurveConfig::default(), 3);
        let (c1, c2) = (m.curve(&a), m.curve(&a));
        assert_eq!(c1, c2);
        for s in [0u64, 1, 17, 999] {
            assert_eq!(c1.loss_at(s), c2.loss_at(s), "same observation at step {s}");
        }
        // a different assignment or seed moves the curve
        let b = asg(&[("lr", ParamValue::Float(0.02)), ("bs", ParamValue::Int(64))]);
        assert_ne!(m.curve(&b), c1);
        assert_ne!(CurveModel::new(CurveConfig::default(), 4).curve(&a), c1);
    }

    #[test]
    fn lr_shaping_rewards_the_optimum() {
        let cfg = CurveConfig {
            lr_optimum: Some(1e-2),
            lr_penalty: 1.0,
            noise: 0.0,
            ..Default::default()
        };
        let m = CurveModel::new(cfg, 0);
        let floor_of = |lr: f64| m.curve(&asg(&[("lr", ParamValue::Float(lr))])).floor;
        assert!(floor_of(1e-2) < floor_of(1e-3));
        assert!(floor_of(1e-3) < floor_of(1e-4), "floor grows with log-distance");
        assert!((floor_of(1e-2) - 0.5).abs() < 1e-12, "optimum sits at the floor minimum");
    }

    #[test]
    fn pinned_tau_makes_rankings_step_invariant() {
        // the search_asha bench's "ASHA best == grid best" guarantee
        let cfg = CurveConfig { tau: [25.0, 25.0], noise: 0.0, ..Default::default() };
        let m = CurveModel::new(cfg, 11);
        let curves: Vec<LearningCurve> = (0..20)
            .map(|i| m.curve(&asg(&[("p", ParamValue::Int(i))])))
            .collect();
        for s in [1u64, 3, 9, 27, 81] {
            for x in &curves {
                for y in &curves {
                    let final_order = x.loss_at(10_000) <= y.loss_at(10_000);
                    let early_order = x.loss_at(s) <= y.loss_at(s);
                    assert_eq!(final_order, early_order, "rank flip at step {s}");
                }
            }
        }
    }

    #[test]
    fn noise_is_bounded_and_replayable() {
        let cfg = CurveConfig { noise: 0.05, ..Default::default() };
        let c = CurveModel::new(cfg, 5).curve(&asg(&[("x", ParamValue::Int(0))]));
        let clean =
            CurveModel::new(CurveConfig { noise: 0.0, ..Default::default() }, 5)
                .curve(&asg(&[("x", ParamValue::Int(0))]));
        for s in 0..100 {
            assert!((c.loss_at(s) - clean.loss_at(s)).abs() <= 0.05 + 1e-12);
            assert_eq!(c.loss_at(s), c.loss_at(s), "replay is exact");
        }
    }
}
