//! Early-stopping schedulers: ASHA, Hyperband brackets, median rule, grid.
//!
//! All four speak one protocol: the driver asks for a trial's first
//! milestone, runs it there, reports `(step, loss)`, and gets back
//! [`Decision::Continue`] with the next milestone or [`Decision::Stop`].
//! Schedulers never see virtual time or nodes — preemptions are invisible
//! to them (a paused trial simply reports later), which is exactly the
//! asynchrony ASHA was designed for.
//!
//! * [`AshaScheduler`] — asynchronous successive halving (Li et al.,
//!   arXiv:1810.05934), stopping variant: at rung `r·eta^k` a trial
//!   continues iff its loss ranks in the top `ceil(n/eta)` of all reports
//!   that rung has seen so far (itself included). No synchronization
//!   barrier: the first reporter at a rung always continues.
//! * [`HyperbandSweep`] — a fixed set of ASHA brackets with staggered
//!   first rungs (`r·eta^b`); trials are spread across brackets by a
//!   weighted round-robin, so part of the budget hedges against
//!   slow-starting curves that aggressive early rungs would cut.
//! * [`MedianStoppingRule`] — the classic baseline: stop a trial whose
//!   milestone loss is above the median of all losses reported at that
//!   milestone (once enough trials have reported to form one).
//! * [`GridScheduler`] — no early stopping; every trial runs to
//!   `max_steps`. The §IV.C full sweep, and the cost baseline the
//!   `search_asha` bench compares against.

use std::collections::BTreeMap;

use crate::config::{SearchAlgo, SearchConfig};

/// What a trial should do after reporting at a milestone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Keep training until the given step (promotion to the next rung).
    Continue(u64),
    /// Early-stop the trial; its node goes back to the pool.
    Stop,
}

/// The scheduling protocol between the driver and an early-stopping
/// policy. `idx` is the trial's index in the driver's trial list.
pub trait TrialScheduler {
    /// Short policy name for reports.
    fn name(&self) -> &'static str;

    /// First milestone (in steps) for trial `idx`. Must be `>= 1`.
    fn first_milestone(&mut self, idx: usize) -> u64;

    /// Called when trial `idx` reaches a milestone with its observed
    /// loss; decides promotion or stopping. `step` is always a milestone
    /// this scheduler previously handed out and below `max_steps`
    /// (reaching `max_steps` completes the trial without asking).
    fn on_report(&mut self, idx: usize, step: u64, loss: f64) -> Decision;
}

/// Build the scheduler a [`SearchConfig`] asks for.
pub fn make_scheduler(cfg: &SearchConfig) -> Box<dyn TrialScheduler> {
    match cfg.algo {
        SearchAlgo::Grid => Box::new(GridScheduler::new(cfg.max_steps)),
        SearchAlgo::Asha => {
            Box::new(AshaScheduler::new(cfg.rung_first_steps, cfg.eta, cfg.max_steps))
        }
        SearchAlgo::Hyperband => {
            Box::new(HyperbandSweep::new(cfg.rung_first_steps, cfg.eta, cfg.max_steps))
        }
        SearchAlgo::Median => {
            Box::new(MedianStoppingRule::new(cfg.rung_first_steps, cfg.eta, cfg.max_steps, 5))
        }
    }
}

// ------------------------------------------------------------------ ASHA

/// Asynchronous successive halving (stopping variant).
#[derive(Debug)]
pub struct AshaScheduler {
    r0: u64,
    eta: u32,
    max_steps: u64,
    /// Losses reported so far at each rung milestone.
    rungs: BTreeMap<u64, Vec<f64>>,
}

impl AshaScheduler {
    /// Rungs at `r0·eta^k`, capped by `max_steps`. `eta >= 2`, `r0 >= 1`.
    pub fn new(r0: u64, eta: u32, max_steps: u64) -> Self {
        Self {
            r0: r0.clamp(1, max_steps.max(1)),
            eta: eta.max(2),
            max_steps: max_steps.max(1),
            rungs: BTreeMap::new(),
        }
    }

    /// The rung after `step` (capped at `max_steps`).
    fn next_rung(&self, step: u64) -> u64 {
        step.saturating_mul(self.eta as u64).min(self.max_steps)
    }

    /// Top-`1/eta` test over everything this rung has seen (including the
    /// loss just reported): rank `<= ceil(n/eta)` continues.
    fn promotes(&mut self, step: u64, loss: f64) -> bool {
        let losses = self.rungs.entry(step).or_default();
        losses.push(loss);
        let n = losses.len();
        let k = n.div_ceil(self.eta as usize).max(1);
        let mut sorted = losses.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite loss"));
        loss <= sorted[k - 1]
    }
}

impl TrialScheduler for AshaScheduler {
    fn name(&self) -> &'static str {
        "asha"
    }

    fn first_milestone(&mut self, _idx: usize) -> u64 {
        self.r0
    }

    fn on_report(&mut self, _idx: usize, step: u64, loss: f64) -> Decision {
        if self.promotes(step, loss) {
            Decision::Continue(self.next_rung(step))
        } else {
            Decision::Stop
        }
    }
}

// ------------------------------------------------------------- Hyperband

/// A Hyperband-style sweep: several ASHA brackets whose first rungs are
/// staggered geometrically, with more trials routed to the aggressive
/// brackets (weight `eta^(s_max - b)` for bracket `b`).
#[derive(Debug)]
pub struct HyperbandSweep {
    brackets: Vec<AshaScheduler>,
    /// Cumulative routing weights (bracket `b` owns the residue classes
    /// below `cum[b]` modulo the total weight).
    cum: Vec<u64>,
}

impl HyperbandSweep {
    /// Brackets `b = 0..=s_max` with first rung `r0·eta^b`, where `s_max`
    /// is the largest exponent keeping the first rung below `max_steps`.
    pub fn new(r0: u64, eta: u32, max_steps: u64) -> Self {
        let r0 = r0.clamp(1, max_steps.max(1));
        let eta = eta.max(2);
        let mut brackets = Vec::new();
        let mut first = r0;
        while first < max_steps.max(1) && brackets.len() < 8 {
            brackets.push(AshaScheduler::new(first, eta, max_steps));
            first = first.saturating_mul(eta as u64);
        }
        if brackets.is_empty() {
            brackets.push(AshaScheduler::new(r0, eta, max_steps));
        }
        let s_max = brackets.len() as u32 - 1;
        let mut cum = Vec::with_capacity(brackets.len());
        let mut acc = 0u64;
        for b in 0..brackets.len() as u32 {
            acc += (eta as u64).pow(s_max - b).max(1);
            cum.push(acc);
        }
        Self { brackets, cum }
    }

    /// Deterministic weighted round-robin assignment of trials to
    /// brackets.
    pub fn bracket_of(&self, idx: usize) -> usize {
        let total = *self.cum.last().expect("at least one bracket");
        let pos = idx as u64 % total;
        self.cum.iter().position(|&c| pos < c).expect("pos < total")
    }

    /// Number of brackets in the sweep.
    pub fn n_brackets(&self) -> usize {
        self.brackets.len()
    }
}

impl TrialScheduler for HyperbandSweep {
    fn name(&self) -> &'static str {
        "hyperband"
    }

    fn first_milestone(&mut self, idx: usize) -> u64 {
        let b = self.bracket_of(idx);
        self.brackets[b].first_milestone(idx)
    }

    fn on_report(&mut self, idx: usize, step: u64, loss: f64) -> Decision {
        let b = self.bracket_of(idx);
        self.brackets[b].on_report(idx, step, loss)
    }
}

// ----------------------------------------------------------- median rule

/// Median stopping rule over geometric milestones.
#[derive(Debug)]
pub struct MedianStoppingRule {
    r0: u64,
    eta: u32,
    max_steps: u64,
    /// Minimum reports a milestone needs before the rule can stop anyone.
    min_reports: usize,
    records: BTreeMap<u64, Vec<f64>>,
}

impl MedianStoppingRule {
    /// Milestones at `r0·eta^k` (same grid as ASHA, so step budgets
    /// compare apples to apples); stops a trial whose loss exceeds the
    /// milestone median once `min_reports` trials have reported there.
    pub fn new(r0: u64, eta: u32, max_steps: u64, min_reports: usize) -> Self {
        Self {
            r0: r0.clamp(1, max_steps.max(1)),
            eta: eta.max(2),
            max_steps: max_steps.max(1),
            min_reports: min_reports.max(2),
            records: BTreeMap::new(),
        }
    }
}

impl TrialScheduler for MedianStoppingRule {
    fn name(&self) -> &'static str {
        "median"
    }

    fn first_milestone(&mut self, _idx: usize) -> u64 {
        self.r0
    }

    fn on_report(&mut self, _idx: usize, step: u64, loss: f64) -> Decision {
        let losses = self.records.entry(step).or_default();
        losses.push(loss);
        if losses.len() >= self.min_reports {
            let mut sorted = losses.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite loss"));
            let median = sorted[sorted.len() / 2];
            if loss > median {
                return Decision::Stop;
            }
        }
        Decision::Continue(step.saturating_mul(self.eta as u64).min(self.max_steps))
    }
}

// ------------------------------------------------------------------ grid

/// No early stopping: every trial runs straight to `max_steps`.
#[derive(Debug)]
pub struct GridScheduler {
    max_steps: u64,
}

impl GridScheduler {
    /// A grid run to `max_steps`.
    pub fn new(max_steps: u64) -> Self {
        Self { max_steps: max_steps.max(1) }
    }
}

impl TrialScheduler for GridScheduler {
    fn name(&self) -> &'static str {
        "grid"
    }

    fn first_milestone(&mut self, _idx: usize) -> u64 {
        self.max_steps
    }

    fn on_report(&mut self, _idx: usize, _step: u64, _loss: f64) -> Decision {
        Decision::Continue(self.max_steps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asha_first_reporter_continues_then_threshold_tightens() {
        let mut s = AshaScheduler::new(1, 3, 27);
        // first report at rung 1 is optimistically promoted
        assert_eq!(s.on_report(0, 1, 0.9), Decision::Continue(3));
        // second and third reports: top ceil(n/3) = 1 slot, held by 0.5
        assert_eq!(s.on_report(1, 1, 0.5), Decision::Continue(3));
        assert_eq!(s.on_report(2, 1, 0.7), Decision::Stop);
        // fourth report: ceil(4/3) = 2 slots, threshold is 2nd best (0.6)
        assert_eq!(s.on_report(3, 1, 0.6), Decision::Continue(3));
        // rungs are independent
        assert_eq!(s.on_report(1, 3, 0.4), Decision::Continue(9));
    }

    #[test]
    fn asha_best_so_far_always_survives() {
        // the running best at a rung is rank 1 <= ceil(n/eta) for any n,
        // so a strictly-improving report stream promotes every time
        let mut s = AshaScheduler::new(2, 4, 100);
        for i in 0..50 {
            let loss = 5.0 - i as f64 * 0.07;
            assert_eq!(s.on_report(i, 2, loss), Decision::Continue(8), "new best stopped at {i}");
        }
        // and a clearly-worst report into that crowded rung is cut
        assert_eq!(s.on_report(50, 2, 9.0), Decision::Stop);
    }

    #[test]
    fn asha_rungs_are_geometric_and_capped() {
        let mut s = AshaScheduler::new(3, 3, 81);
        assert_eq!(s.first_milestone(0), 3);
        assert_eq!(s.on_report(0, 3, 0.1), Decision::Continue(9));
        assert_eq!(s.on_report(0, 9, 0.1), Decision::Continue(27));
        assert_eq!(s.on_report(0, 27, 0.1), Decision::Continue(81));
        // a rung above max_steps/eta caps at max_steps
        let mut t = AshaScheduler::new(50, 3, 81);
        assert_eq!(t.on_report(0, 50, 0.1), Decision::Continue(81));
    }

    #[test]
    fn grid_never_stops() {
        let mut g = GridScheduler::new(10);
        assert_eq!(g.first_milestone(5), 10);
        assert_eq!(g.on_report(5, 10, 99.0), Decision::Continue(10));
    }

    #[test]
    fn median_rule_needs_quorum_then_stops_above_median() {
        let mut m = MedianStoppingRule::new(1, 2, 16, 3);
        // below quorum: everything continues
        assert_eq!(m.on_report(0, 1, 5.0), Decision::Continue(2));
        assert_eq!(m.on_report(1, 1, 1.0), Decision::Continue(2));
        // third report forms a median; sorted [1, 3, 5], median 3:
        // a 3.0 report is not above it -> continues
        assert_eq!(m.on_report(2, 1, 3.0), Decision::Continue(2));
        // 4.0 > median of [1, 3, 4, 5] (= 4? sorted[2] = 4) -> not above
        assert_eq!(m.on_report(3, 1, 4.0), Decision::Continue(2));
        // 6.0 is above the median of [1, 3, 4, 5, 6] (= 4) -> stop
        assert_eq!(m.on_report(4, 1, 6.0), Decision::Stop);
    }

    #[test]
    fn hyperband_brackets_stagger_first_rungs() {
        let mut h = HyperbandSweep::new(1, 3, 27);
        // brackets at r0 = 1, 3, 9 (27 would not be < max_steps)
        assert_eq!(h.n_brackets(), 3);
        let firsts: std::collections::BTreeSet<u64> =
            (0..100).map(|i| h.first_milestone(i)).collect();
        assert_eq!(firsts, [1u64, 3, 9].into_iter().collect());
        // weighted routing: bracket 0 (weight 9) gets most trials
        let counts = (0..130).fold([0usize; 3], |mut acc, i| {
            acc[h.bracket_of(i)] += 1;
            acc
        });
        assert!(counts[0] > counts[1] && counts[1] > counts[2], "{counts:?}");
        // deterministic
        assert_eq!(h.bracket_of(42), h.bracket_of(42));
    }

    #[test]
    fn make_scheduler_honors_the_algo_knob() {
        let mut cfg = SearchConfig::default();
        for (algo, name) in [
            (SearchAlgo::Grid, "grid"),
            (SearchAlgo::Asha, "asha"),
            (SearchAlgo::Hyperband, "hyperband"),
            (SearchAlgo::Median, "median"),
        ] {
            cfg.algo = algo;
            assert_eq!(make_scheduler(&cfg).name(), name);
        }
    }
}
