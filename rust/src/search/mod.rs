//! Large-scale hyperparameter search on the preemptible fleet (§IV.C).
//!
//! The paper's third headline workload: "trying out all those 4096
//! combinations sequentially would take 28.4 days. Using our system, we
//! made the experiments run in 10 minutes by linearly increasing the
//! cluster size without source code modification." This module upgrades
//! that fixed-duration sweep into a *trial-based* search subsystem in the
//! style of multi-tenant DL platforms (FfDL, arXiv:1909.06526): trials
//! are checkpointable units of training that the platform pauses on spot
//! preemption and resumes from their last checkpoint on another node with
//! identical arguments (§III.D) — zero trials lost, partial rung progress
//! banked.
//!
//! | component | role |
//! |---|---|
//! | [`Trial`] — sampled [`crate::workflow::Assignment`] + step counter | the unit of search work; command rendered once, byte-identical across resumes |
//! | [`LearningCurve`] / [`CurveModel`] — synthetic loss trajectories | deterministic per `(assignment, seed, step)`, so resumed trials replay history exactly |
//! | [`AshaScheduler`] — asynchronous successive halving | rungs at `r·eta^k`; a report continues iff in the top `1/eta` of its rung so far |
//! | [`HyperbandSweep`] / [`MedianStoppingRule`] / [`GridScheduler`] | bracket sweep, classic baseline, and the no-stopping §IV.C grid |
//! | [`SearchDriver`] — virtual-time executor | multiplexes trials onto provisioned nodes, checkpoints via [`crate::scheduler::CheckpointStore`], survives scripted [`crate::cloud::StormEvent`]s and the seeded [`crate::cloud::SpotMarket`] |
//!
//! Trial flow through the driver:
//!
//! ```text
//!  params (§II.C sampling) ──► Trial queue ──► idle fleet node
//!        │                        ▲  front         │ run segment
//!   TrialScheduler                │                ▼
//!   (ASHA rungs)  ◄── report ── milestone / periodic checkpoint
//!        │                        │                     │
//!   Continue(next) / Stop         │            CheckpointStore.save
//!        │                 pause (spot notice: drain-checkpoint;
//!        ▼                        kill: lose tail since last save)
//!   complete at max_steps         └── resume from latest checkpoint
//!                                     on a DIFFERENT node (§III.D)
//! ```
//!
//! Entry points: `hyper search` (CLI), the `search:` recipe stanza via
//! [`SearchDriver::from_experiment`], the `hyperparam_search` example,
//! and the `search_asha` bench (ASHA ≤ 40% of grid's trial-steps at an
//! equal-or-better best loss; a mid-search storm kills most of the fleet
//! with zero trials lost).

#![warn(missing_docs)]

pub mod asha;
pub mod curve;
pub mod driver;
pub mod trial;

pub use asha::{make_scheduler, AshaScheduler, Decision, GridScheduler, HyperbandSweep,
               MedianStoppingRule, TrialScheduler};
pub use curve::{CurveConfig, CurveModel, LearningCurve};
pub use driver::{SearchDriver, SearchDriverConfig, SearchReport};
pub use trial::{Trial, TrialState};
