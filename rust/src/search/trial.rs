//! Trial lifecycle: one sampled configuration training toward `max_steps`.
//!
//! A trial is the search-side twin of a workflow task: a rendered command
//! (byte-identical across every resume, per §III.D), an [`Assignment`],
//! and a step counter that only moves forward through checkpoints. The
//! driver parks the whole state machine here so preemption handling reads
//! as transitions: `Running → Paused` (notice/kill) and `Paused → Running`
//! (resume from the last [`crate::scheduler::TrainCheckpoint`] on a
//! different node).

use crate::scheduler::TrainCheckpoint;
use crate::sim::SimTime;
use crate::util::Json;
use crate::workflow::{render_command, Assignment, TaskId};
use crate::{Error, Result};

/// Where a trial is in its life.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrialState {
    /// Waiting in the queue for a node (also the initial state).
    Pending,
    /// Training on a node.
    Running,
    /// Preempted mid-run; queued to resume from its last checkpoint.
    Paused,
    /// Reached `max_steps`.
    Completed,
    /// Early-stopped by the scheduler.
    Stopped,
}

/// One hyperparameter configuration working through the rungs.
#[derive(Debug, Clone, PartialEq)]
pub struct Trial {
    /// Index into the driver's trial list.
    pub id: u32,
    /// Checkpoint-store identity (experiment 0, index = trial id).
    pub task: TaskId,
    /// The sampled parameter binding.
    pub assignment: Assignment,
    /// Rendered command; never re-rendered, so resumes are byte-identical.
    pub command: String,
    /// Lifecycle state.
    pub state: TrialState,
    /// Completed (and durable) training steps.
    pub step: u64,
    /// Next step the scheduler wants a report at.
    pub next_milestone: u64,
    /// Loss at the last report (or checkpoint).
    pub last_loss: f64,
    /// Step of the newest saved checkpoint, if any.
    pub ckpt_step: Option<u64>,
    /// Times this trial was preempted off a node.
    pub pauses: u32,
    /// Times it came back from a checkpoint.
    pub resumes: u32,
    /// Steps executed across all attempts, including work a hard kill
    /// later threw away (`lifetime_steps - step` = replayed so far).
    pub lifetime_steps: u64,
    /// Node of the current/most recent attempt.
    pub last_node: Option<u32>,
    /// Step the in-flight segment started from.
    pub(crate) seg_start_step: u64,
    /// Virtual time the in-flight segment started.
    pub(crate) seg_started_at: SimTime,
    /// Step the in-flight segment runs to.
    pub(crate) seg_target: u64,
}

impl Trial {
    /// Materialize trial `id` from a command template and an assignment.
    pub fn new(id: u32, template: &str, assignment: Assignment, first_milestone: u64) -> Self {
        Self {
            id,
            task: TaskId { experiment: 0, index: id },
            command: render_command(template, &assignment),
            assignment,
            state: TrialState::Pending,
            step: 0,
            next_milestone: first_milestone.max(1),
            last_loss: f64::INFINITY,
            ckpt_step: None,
            pauses: 0,
            resumes: 0,
            lifetime_steps: 0,
            last_node: None,
            seg_start_step: 0,
            seg_started_at: SimTime::ZERO,
            seg_target: 0,
        }
    }

    /// Terminal (no more work)?
    pub fn is_terminal(&self) -> bool {
        matches!(self.state, TrialState::Completed | TrialState::Stopped)
    }

    /// Serialize the checkpoint blob: step, loss, and the command the
    /// checkpoint belongs to (so a resume can prove it is continuing the
    /// exact same arguments).
    pub fn blob(&self, step: u64, loss: f64) -> Vec<u8> {
        Json::obj(vec![
            ("step", Json::num(step as f64)),
            ("loss", Json::num(loss)),
            ("command", Json::str(self.command.clone())),
        ])
        .to_bytes()
    }

    /// Validate a checkpoint blob against this trial and return the step
    /// it restores to. Errors if the blob belongs to different arguments
    /// or disagrees with the checkpoint metadata — a resumed trial must
    /// continue the §III.D way: same command, last checkpointed step.
    pub fn restore(&self, ckpt: &TrainCheckpoint, blob: &[u8]) -> Result<u64> {
        let v = Json::parse_bytes(blob)?;
        let step = v.req_u64("step")?;
        let command = v.req_str("command")?;
        if command != self.command {
            return Err(Error::Search(format!(
                "trial {}: checkpoint belongs to {command:?}, not {:?}",
                self.id, self.command
            )));
        }
        if step != ckpt.step {
            return Err(Error::Search(format!(
                "trial {}: blob step {step} != checkpoint step {}",
                self.id, ckpt.step
            )));
        }
        Ok(step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::ParamValue;

    fn trial() -> Trial {
        let mut a = Assignment::new();
        a.insert("lr".into(), ParamValue::Float(0.01));
        Trial::new(3, "train --lr {lr}", a, 9)
    }

    #[test]
    fn materializes_rendered_command_and_task_id() {
        let t = trial();
        assert_eq!(t.command, "train --lr 0.01");
        assert_eq!(t.task, TaskId { experiment: 0, index: 3 });
        assert_eq!(t.state, TrialState::Pending);
        assert_eq!(t.next_milestone, 9);
        assert!(!t.is_terminal());
    }

    #[test]
    fn blob_roundtrips_through_restore() {
        let t = trial();
        let blob = t.blob(42, 1.25);
        let ckpt = TrainCheckpoint { task: t.task, step: 42, blob_key: "k".into(), loss: 1.25 };
        assert_eq!(t.restore(&ckpt, &blob).unwrap(), 42);
    }

    #[test]
    fn restore_rejects_foreign_or_inconsistent_blobs() {
        let t = trial();
        // a blob rendered from different arguments
        let mut other = Assignment::new();
        other.insert("lr".into(), ParamValue::Float(0.5));
        let foreign = Trial::new(4, "train --lr {lr}", other, 9).blob(42, 1.0);
        let ckpt = TrainCheckpoint { task: t.task, step: 42, blob_key: "k".into(), loss: 1.0 };
        assert!(matches!(t.restore(&ckpt, &foreign), Err(Error::Search(_))));
        // a blob whose step disagrees with the metadata pointer
        let stale = t.blob(41, 1.0);
        assert!(matches!(t.restore(&ckpt, &stale), Err(Error::Search(_))));
    }
}
