//! [`SearchDriver`]: checkpointable trials on the preemptible virtual fleet.
//!
//! The third end-to-end scenario over the shared
//! [`crate::fleet::FleetEngine`] (after the ETL fan-out and the serving
//! layer): hundreds-to-thousands of trials multiplexed onto provisioned
//! nodes, early-stopped by a [`TrialScheduler`], checkpointed through
//! [`CheckpointStore`], and carried through spot preemptions the §III.D
//! way — a preempted trial pauses, re-queues at the front, and resumes
//! *from its last checkpoint on a different node with byte-identical
//! arguments*. The engine owns the event loop, node lifecycle, storms /
//! market / price-trace preemption, and billing; this driver supplies
//! only the trial policy.
//!
//! Invariants the tests pin down:
//!
//! * **Zero lost trials.** Every trial ends `Completed` or `Stopped`
//!   (scheduler's call); preemption can only delay one. A killed fleet
//!   is replaced (`replace_preempted`), so even a storm that reclaims
//!   most nodes mid-search leaves no trial stranded.
//! * **No duplicate full restarts.** A resume reads the newest
//!   [`crate::scheduler::TrainCheckpoint`] (observable as exactly one
//!   metadata GET + one blob GET per resume on a counting store) and
//!   continues from its step; [`SearchReport::full_restarts`] counts the
//!   only legitimate exception — a kill before the first checkpoint.
//! * **Determinism.** Same config + store ⇒ bit-identical
//!   [`SearchReport`]. Storms are scripted [`StormEvent`]s timed from
//!   engine start; the optional background [`crate::cloud::SpotMarket`]
//!   is seeded; a price trace is exactly reproducible.

use std::collections::{BTreeMap, VecDeque};

use crate::cloud::{InstanceType, ProvisionerConfig, SpotMarketConfig, StormEvent};
use crate::config::SearchConfig;
use crate::fleet::{FleetConfig, FleetEngine, FleetStats, FleetWorkload, LaunchSpec, NodeId,
                   PriceTraceConfig};
use crate::metrics::MetricsRegistry;
use crate::obs::{hash64, FlightRecorder};
use crate::scheduler::CheckpointStore;
use crate::sim::SimTime;
use crate::storage::StoreHandle;
use crate::workflow::{sample_assignments, Assignment, ExperimentSpec, ParamSpec};
use crate::{Error, Result};

use super::asha::{make_scheduler, Decision, TrialScheduler};
use super::curve::{CurveConfig, CurveModel, LearningCurve};
use super::trial::{Trial, TrialState};

/// Full search-scenario configuration: the [`SearchConfig`] knobs plus
/// the cloud models and fault injection.
#[derive(Debug, Clone)]
pub struct SearchDriverConfig {
    /// Algorithm + trial + fleet knobs (see `docs/CONFIG.md`).
    pub search: SearchConfig,
    /// Synthetic learning-curve shape.
    pub curve: CurveConfig,
    /// Node provisioning model (boot time, jitter, warm-cache odds).
    pub provisioner: ProvisionerConfig,
    /// Background random preemptions of spot nodes; `None` = scripted
    /// storms only (deterministic fault timing).
    pub spot_market: Option<SpotMarketConfig>,
    /// Price-trace-driven preemption (replayed `(t, price)` series vs a
    /// bid); overrides `spot_market` when set.
    pub price_trace: Option<PriceTraceConfig>,
    /// Scripted preemption waves (timed from engine start).
    pub storm: Vec<StormEvent>,
    /// Launch a replacement when a node is reclaimed.
    pub replace_preempted: bool,
}

impl Default for SearchDriverConfig {
    fn default() -> Self {
        Self {
            search: SearchConfig::default(),
            curve: CurveConfig::default(),
            provisioner: ProvisionerConfig::default(),
            spot_market: None,
            price_trace: None,
            storm: Vec::new(),
            replace_preempted: true,
        }
    }
}

/// Outcome of one search run.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchReport {
    /// Scheduler that ran (`asha`, `grid`, ...).
    pub algo: &'static str,
    /// Trials sampled.
    pub trials: usize,
    /// Trials that reached `max_steps`.
    pub completed: usize,
    /// Trials early-stopped by the scheduler.
    pub stopped: usize,
    /// Trials left non-terminal (must be 0: zero lost trials).
    pub lost: usize,
    /// Virtual time until the last trial went terminal, seconds.
    pub makespan_s: f64,
    /// Instance-hours billed, USD.
    pub cost_usd: f64,
    /// Training steps executed, including work later thrown away.
    pub total_steps: u64,
    /// Steps re-executed because a hard kill lost them (0 when every
    /// preemption came with a notice-drain checkpoint).
    pub replayed_steps: u64,
    /// Nodes reclaimed (storms, price trace, background spot market).
    pub preemptions: u64,
    /// Trial pauses caused by preemptions.
    pub pauses: u64,
    /// Trial resumes (each reads the latest checkpoint once).
    pub resumes: u64,
    /// Resumes that found no checkpoint after real progress — genuine
    /// restarts from step 0.
    pub full_restarts: u64,
    /// Resumes landing on the node they were preempted from (§III.D
    /// wants a *different* node; preempted nodes never take work again,
    /// so this stays 0).
    pub resumed_same_node: u64,
    /// Checkpoints saved (periodic + milestone + drain).
    pub checkpoints: u64,
    /// Scheduler promotions past a rung.
    pub promotions: u64,
    /// Nodes provisioned over the run.
    pub nodes_launched: usize,
    /// Best final loss among completed trials (`inf` if none completed).
    pub best_loss: f64,
    /// Assignment of the best completed trial.
    pub best_assignment: Option<Assignment>,
    /// Best loss observed at any report (completed or not).
    pub best_observed_loss: f64,
}

/// The virtual-time search executor. Construct, then [`SearchDriver::run`]
/// once.
pub struct SearchDriver {
    cfg: SearchDriverConfig,
    instance: InstanceType,
    trials: Vec<Trial>,
    curves: Vec<LearningCurve>,
    sched: Box<dyn TrialScheduler>,
    ckpts: CheckpointStore,
    queue: VecDeque<usize>,
    /// Trial currently running on each node.
    running: BTreeMap<NodeId, usize>,
    /// Counters + best-loss gauge (`search.*` names).
    pub metrics: MetricsRegistry,
    stats: FleetStats,
    terminal: usize,
    pauses: u64,
    resumes: u64,
    full_restarts: u64,
    resumed_same_node: u64,
    total_steps: u64,
    replayed_steps: u64,
    checkpoints: u64,
    promotions: u64,
    best_loss: f64,
    best_idx: Option<usize>,
    best_observed: f64,
    ran: bool,
    obs: FlightRecorder,
}

impl SearchDriver {
    /// Build a driver: sample `cfg.search.trials` assignments from
    /// `space` (0 = the full discrete grid), materialize trials over
    /// `command`, and checkpoint into `store` under the `search/` prefix.
    pub fn new(
        cfg: SearchDriverConfig,
        store: StoreHandle,
        space: &BTreeMap<String, ParamSpec>,
        command: &str,
    ) -> Result<Self> {
        let sc = &cfg.search;
        let instance = InstanceType::by_name(&sc.instance)
            .map(|s| s.ty)
            .ok_or_else(|| Error::Search(format!("unknown instance type {:?}", sc.instance)))?;
        if sc.max_steps == 0 || sc.rung_first_steps == 0 {
            return Err(Error::Search("max_steps and rung_first_steps must be > 0".into()));
        }
        if sc.step_time_s <= 0.0 || sc.step_time_s.is_nan() {
            return Err(Error::Search("step_time_s must be > 0".into()));
        }
        let n = if sc.trials == 0 { None } else { Some(sc.trials) };
        let assignments = sample_assignments(space, n, sc.seed);
        if assignments.is_empty() {
            return Err(Error::Search("no trials sampled from the parameter space".into()));
        }
        let mut sched = make_scheduler(sc);
        let model = CurveModel::new(cfg.curve.clone(), sc.seed);
        let mut trials = Vec::with_capacity(assignments.len());
        let mut curves = Vec::with_capacity(assignments.len());
        for (i, a) in assignments.into_iter().enumerate() {
            let first = sched.first_milestone(i).clamp(1, sc.max_steps);
            curves.push(model.curve(&a));
            trials.push(Trial::new(i as u32, command, a, first));
        }
        let ckpts = if sc.keep_last_k == 0 {
            CheckpointStore::new(store, "search")
        } else {
            CheckpointStore::with_keep_last(store, "search", sc.keep_last_k)
        };
        Ok(Self {
            instance,
            trials,
            curves,
            sched,
            ckpts,
            cfg,
            queue: VecDeque::new(),
            running: BTreeMap::new(),
            metrics: MetricsRegistry::new(),
            stats: FleetStats::default(),
            terminal: 0,
            pauses: 0,
            resumes: 0,
            full_restarts: 0,
            resumed_same_node: 0,
            total_steps: 0,
            replayed_steps: 0,
            checkpoints: 0,
            promotions: 0,
            best_loss: f64::INFINITY,
            best_idx: None,
            best_observed: f64::INFINITY,
            ran: false,
            obs: FlightRecorder::disabled(),
        })
    }

    /// Attach a flight recorder before [`SearchDriver::run`]: the fleet
    /// engine records node lifecycle + work events, and the driver adds
    /// `trial.run` segment spans, `trial.pause` / `trial.resume` /
    /// `trial.checkpoint` events (pid = node, tid = trial index). Run and
    /// resume records carry a `command_hash` so a trace alone proves a
    /// resume continued the byte-identical command it paused with.
    pub fn set_obs(&mut self, obs: FlightRecorder) {
        self.obs = obs;
    }

    /// The [`SearchDriverConfig`] a recipe experiment describes: the
    /// `search:` stanza supplies the algorithm knobs, the experiment
    /// supplies the fleet (`workers`/`spot`/`instance`) and trial count
    /// (`samples`, default = full grid); everything else defaults.
    /// Errors if the experiment has no `search:` stanza.
    pub fn config_for_experiment(spec: &ExperimentSpec, seed: u64) -> Result<SearchDriverConfig> {
        let s = spec.search.as_ref().ok_or_else(|| {
            Error::Search(format!("experiment {:?} has no search: stanza", spec.name))
        })?;
        let search = SearchConfig {
            trials: spec.samples.unwrap_or(0),
            max_steps: s.max_steps,
            rung_first_steps: s.rung_steps,
            eta: s.eta,
            step_time_s: s.step_time_s,
            checkpoint_every_steps: s.checkpoint_every_steps,
            workers: spec.workers,
            spot: spec.spot,
            instance: spec.instance.clone(),
            algo: s.algo,
            seed,
            ..SearchConfig::default()
        };
        Ok(SearchDriverConfig { search, ..Default::default() })
    }

    /// Build a driver straight from a recipe experiment carrying a
    /// `search:` stanza (see [`SearchDriver::config_for_experiment`]).
    pub fn from_experiment(spec: &ExperimentSpec, store: StoreHandle, seed: u64) -> Result<Self> {
        let cfg = Self::config_for_experiment(spec, seed)?;
        Self::new(cfg, store, &spec.params, &spec.command)
    }

    /// The materialized trials (inspect states/steps after `run`).
    pub fn trials(&self) -> &[Trial] {
        &self.trials
    }

    /// Fleet-level counters of the last run (preemptions, storm firing
    /// times, deferred launches).
    pub fn fleet_stats(&self) -> &FleetStats {
        &self.stats
    }

    /// Run the search to completion and report. Single-use.
    pub fn run(&mut self) -> Result<SearchReport> {
        if std::mem::replace(&mut self.ran, true) {
            return Err(Error::Search("SearchDriver::run is single-use".into()));
        }
        let mut engine = FleetEngine::new(FleetConfig {
            provisioner: self.cfg.provisioner.clone(),
            spot_market: self.cfg.spot_market.clone(),
            price_trace: self.cfg.price_trace.clone(),
            storm: self.cfg.storm.clone(),
            seed: self.cfg.search.seed,
            ..FleetConfig::default()
        });
        engine.set_obs(self.obs.clone());
        engine.run(&mut TrialWorkload { d: self })?;
        // bill whatever is still alive at the last processed event
        let end = engine.now();
        engine.shutdown(end);
        self.stats = engine.stats().clone();

        let completed = self.trials.iter().filter(|t| t.state == TrialState::Completed).count();
        let stopped = self.trials.iter().filter(|t| t.state == TrialState::Stopped).count();
        Ok(SearchReport {
            algo: self.sched.name(),
            trials: self.trials.len(),
            completed,
            stopped,
            lost: self.trials.len() - completed - stopped,
            makespan_s: end.as_secs_f64(),
            cost_usd: engine.ledger().total_usd(),
            total_steps: self.total_steps,
            replayed_steps: self.replayed_steps,
            preemptions: self.stats.preemptions,
            pauses: self.pauses,
            resumes: self.resumes,
            full_restarts: self.full_restarts,
            resumed_same_node: self.resumed_same_node,
            checkpoints: self.checkpoints,
            promotions: self.promotions,
            nodes_launched: self.stats.nodes_launched,
            best_loss: self.best_loss,
            best_assignment: self.best_idx.map(|i| self.trials[i].assignment.clone()),
            best_observed_loss: self.best_observed,
        })
    }

    // ------------------------------------------------------- dispatching

    /// Fill idle nodes from the queue (paused trials sit at the front,
    /// §III.D: preempted work resumes first).
    fn dispatch(&mut self, fleet: &mut FleetEngine) -> Result<()> {
        loop {
            if self.queue.is_empty() {
                return Ok(());
            }
            let Some(nid) = fleet.serving_ids().find(|id| !self.running.contains_key(id)) else {
                return Ok(());
            };
            let ti = self.queue.pop_front().expect("non-empty");
            self.start_attempt(fleet, ti, nid)?;
        }
    }

    /// Start (or resume) a trial on a node. A resume reads the latest
    /// checkpoint from the store — exactly one metadata GET and one blob
    /// GET — and verifies it belongs to the same byte-identical command.
    fn start_attempt(&mut self, fleet: &mut FleetEngine, ti: usize, nid: NodeId) -> Result<()> {
        let resuming = self.trials[ti].pauses > 0;
        if resuming {
            self.resumes += 1;
            self.metrics.counter("search.resumes").inc();
            let task = self.trials[ti].task;
            match self.ckpts.latest(task)? {
                Some(ckpt) => {
                    let blob = self.ckpts.load_blob(&ckpt)?;
                    let step = self.trials[ti].restore(&ckpt, &blob)?;
                    self.trials[ti].step = step;
                }
                None => {
                    // killed before the first checkpoint ever landed
                    if self.trials[ti].lifetime_steps > 0 {
                        self.full_restarts += 1;
                    }
                    self.trials[ti].step = 0;
                }
            }
            if self.trials[ti].last_node == Some(nid) {
                self.resumed_same_node += 1;
            }
            if self.obs.is_enabled() {
                let t = &self.trials[ti];
                self.obs.event_at("trial.resume", fleet.now().as_nanos(), nid, ti as u64, vec![
                    ("step", t.step.into()),
                    ("command_hash", hash64(&t.command).into()),
                ]);
            }
        } else if self.trials[ti].state == TrialState::Pending {
            self.metrics.counter("search.trials_started").inc();
        }
        self.trials[ti].last_node = Some(nid);
        self.start_segment(fleet, ti, nid);
        Ok(())
    }

    /// Schedule the next run segment: up to the next periodic checkpoint
    /// or the next scheduler milestone, whichever is nearer.
    fn start_segment(&mut self, fleet: &mut FleetEngine, ti: usize, nid: NodeId) {
        let now = fleet.now();
        let target = self.segment_target(ti);
        let dur_steps = {
            let t = &mut self.trials[ti];
            t.state = TrialState::Running;
            t.seg_start_step = t.step;
            t.seg_started_at = now;
            t.seg_target = target;
            target - t.step
        };
        self.running.insert(nid, ti);
        let dur = dur_steps as f64 * self.cfg.search.step_time_s;
        fleet.add_busy(nid, dur);
        fleet.schedule_work(nid, now + SimTime::from_secs_f64(dur), ti as u64);
    }

    fn segment_target(&self, ti: usize) -> u64 {
        let t = &self.trials[ti];
        let ms = t.next_milestone.min(self.cfg.search.max_steps).max(t.step);
        let ck = self.cfg.search.checkpoint_every_steps;
        if ck == 0 {
            ms
        } else {
            ((t.step / ck + 1) * ck).min(ms)
        }
    }

    /// Whole steps the in-flight segment completed by `now`.
    fn partial_steps(&self, now: SimTime, ti: usize) -> u64 {
        let t = &self.trials[ti];
        let elapsed = now.saturating_sub(t.seg_started_at).as_secs_f64();
        let raw = (elapsed / self.cfg.search.step_time_s + 1e-9).floor() as u64;
        raw.min(t.seg_target.saturating_sub(t.seg_start_step))
    }

    fn save_checkpoint(&mut self, now: SimTime, ti: usize, step: u64, loss: f64) -> Result<()> {
        let blob = self.trials[ti].blob(step, loss);
        self.ckpts.save(self.trials[ti].task, step, loss as f32, &blob)?;
        self.trials[ti].ckpt_step = Some(step);
        self.checkpoints += 1;
        self.metrics.counter("search.checkpoints").inc();
        if self.obs.is_enabled() {
            let pid = self.trials[ti].last_node.unwrap_or(0);
            self.obs.event_at("trial.checkpoint", now.as_nanos(), pid, ti as u64, vec![
                ("step", step.into()),
                ("loss", loss.into()),
            ]);
        }
        Ok(())
    }

    /// Record the just-ended run segment `[seg_started_at, now]` of trial
    /// `ti` as a `trial.run` span (no-op when the recorder is off).
    fn record_segment(&self, now: SimTime, ti: usize, nid: NodeId) {
        if !self.obs.is_enabled() {
            return;
        }
        let t = &self.trials[ti];
        self.obs.span_at(
            "trial.run",
            t.seg_started_at.as_nanos(),
            now.as_nanos(),
            nid,
            ti as u64,
            vec![
                ("from_step", t.seg_start_step.into()),
                ("command_hash", hash64(&t.command).into()),
            ],
        );
    }
}

/// The checkpointable-trial workload behind [`SearchDriver`].
struct TrialWorkload<'a> {
    d: &'a mut SearchDriver,
}

impl FleetWorkload for TrialWorkload<'_> {
    fn on_start(&mut self, fleet: &mut FleetEngine) -> Result<()> {
        let d = &mut *self.d;
        d.queue = (0..d.trials.len()).collect();
        for _ in 0..d.cfg.search.workers.max(1) {
            fleet.launch(LaunchSpec::new(d.instance, d.cfg.search.spot));
        }
        Ok(())
    }

    fn on_node_ready(&mut self, fleet: &mut FleetEngine, _node: NodeId) -> Result<()> {
        self.d.dispatch(fleet)
    }

    fn on_work_done(&mut self, fleet: &mut FleetEngine, nid: NodeId, token: u64) -> Result<()> {
        let d = &mut *self.d;
        let ti = token as usize;
        // stale if the node has since been handed a different trial
        if d.running.get(&nid) != Some(&ti) {
            return Ok(());
        }
        let now = fleet.now();
        let (step, executed) = {
            let t = &mut d.trials[ti];
            let executed = t.seg_target - t.seg_start_step;
            t.step = t.seg_target;
            t.lifetime_steps += executed;
            (t.step, executed)
        };
        d.record_segment(now, ti, nid);
        d.total_steps += executed;
        let loss = d.curves[ti].loss_at(step);
        d.save_checkpoint(now, ti, step, loss)?;
        d.trials[ti].last_loss = loss;
        if loss < d.best_observed {
            d.best_observed = loss;
        }

        let max_steps = d.cfg.search.max_steps;
        if step >= max_steps {
            // trial done: the top rung is completion
            d.trials[ti].state = TrialState::Completed;
            d.terminal += 1;
            d.metrics.counter("search.trials_completed").inc();
            if loss < d.best_loss {
                d.best_loss = loss;
                d.best_idx = Some(ti);
                d.metrics.float_gauge("search.best_loss").set(loss);
            }
            d.running.remove(&nid);
            return d.dispatch(fleet);
        }
        if step >= d.trials[ti].next_milestone {
            match d.sched.on_report(ti, step, loss) {
                Decision::Continue(next) => {
                    d.promotions += 1;
                    d.metrics.counter("search.promotions").inc();
                    d.trials[ti].next_milestone = next.clamp(step + 1, max_steps);
                    d.start_segment(fleet, ti, nid);
                }
                Decision::Stop => {
                    d.trials[ti].state = TrialState::Stopped;
                    d.terminal += 1;
                    d.metrics.counter("search.early_stops").inc();
                    d.running.remove(&nid);
                    return d.dispatch(fleet);
                }
            }
        } else {
            // mid-rung periodic checkpoint: keep going on the same node
            d.start_segment(fleet, ti, nid);
        }
        Ok(())
    }

    /// Spot notice / storm warning: the engine has drained the node (it
    /// takes no further work). Bank the running trial's partial progress
    /// in a checkpoint and re-queue it at the front.
    fn on_notice(&mut self, fleet: &mut FleetEngine, nid: NodeId) -> Result<()> {
        let d = &mut *self.d;
        // the recalled segment's in-flight completion must go stale
        fleet.invalidate(nid);
        if let Some(ti) = d.running.remove(&nid) {
            let now = fleet.now();
            let done = d.partial_steps(now, ti);
            let step = {
                let t = &mut d.trials[ti];
                t.step = t.seg_start_step + done;
                t.lifetime_steps += done;
                t.step
            };
            d.record_segment(now, ti, nid);
            d.total_steps += done;
            let loss = d.curves[ti].loss_at(step);
            d.save_checkpoint(now, ti, step, loss)?;
            let t = &mut d.trials[ti];
            t.last_loss = loss;
            t.state = TrialState::Paused;
            t.pauses += 1;
            d.pauses += 1;
            d.metrics.counter("search.pauses").inc();
            if d.obs.is_enabled() {
                d.obs.event_at("trial.pause", now.as_nanos(), nid, ti as u64, vec![
                    ("reason", "notice".into()),
                    ("step", step.into()),
                ]);
            }
            d.queue.push_front(ti);
        }
        d.dispatch(fleet)
    }

    /// Hard kill (the engine has already billed the node and staled its
    /// in-flight completion): work since the last checkpoint is lost; the
    /// trial will resume from that checkpoint (step 0 if none existed yet).
    fn on_kill(&mut self, fleet: &mut FleetEngine, nid: NodeId) -> Result<()> {
        let d = &mut *self.d;
        if let Some(ti) = d.running.remove(&nid) {
            let now = fleet.now();
            let done = d.partial_steps(now, ti);
            d.record_segment(now, ti, nid);
            let t = &mut d.trials[ti];
            let reached = t.seg_start_step + done;
            t.lifetime_steps += done;
            d.total_steps += done;
            let resume_from = t.ckpt_step.unwrap_or(0);
            d.replayed_steps += reached - resume_from;
            t.step = resume_from;
            t.state = TrialState::Paused;
            t.pauses += 1;
            d.pauses += 1;
            d.metrics.counter("search.pauses").inc();
            if d.obs.is_enabled() {
                d.obs.event_at("trial.pause", now.as_nanos(), nid, ti as u64, vec![
                    ("reason", "kill".into()),
                    ("lost_steps", (reached - resume_from).into()),
                ]);
            }
            d.queue.push_front(ti);
        }
        if d.cfg.replace_preempted && d.terminal < d.trials.len() {
            fleet.launch(LaunchSpec::new(d.instance, d.cfg.search.spot));
        }
        d.dispatch(fleet)
    }

    fn is_done(&self, _fleet: &FleetEngine) -> bool {
        self.d.terminal == self.d.trials.len()
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::cloud::PriceTrace;
    use crate::config::SearchAlgo;
    use crate::storage::MemStore;
    use crate::workflow::Recipe;

    fn lr_space() -> BTreeMap<String, ParamSpec> {
        let mut m = BTreeMap::new();
        m.insert("lr".to_string(), ParamSpec::LogUniform([1e-4, 1e-1]));
        m
    }

    fn grid_space(card: i64) -> BTreeMap<String, ParamSpec> {
        let mut m = BTreeMap::new();
        m.insert("p".to_string(), ParamSpec::Range([0, card - 1]));
        m
    }

    /// Deterministic fleet: jitter-free warm provisioning (node ready at
    /// exactly t=55), noiseless pinned-τ curves, storms only.
    fn exact_cfg(algo: SearchAlgo) -> SearchDriverConfig {
        SearchDriverConfig {
            search: SearchConfig {
                trials: 0, // full grid of the discrete space
                max_steps: 27,
                rung_first_steps: 1,
                eta: 3,
                step_time_s: 1.0,
                checkpoint_every_steps: 10,
                keep_last_k: 2,
                workers: 4,
                spot: false,
                algo,
                seed: 5,
                ..SearchConfig::default()
            },
            curve: CurveConfig { tau: [30.0, 30.0], noise: 0.0, ..Default::default() },
            provisioner: ProvisionerConfig {
                warm_cache_prob: 1.0,
                jitter: 0.0,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    fn store() -> StoreHandle {
        Arc::new(MemStore::new())
    }

    #[test]
    fn grid_completes_every_trial() {
        let mut cfg = exact_cfg(SearchAlgo::Grid);
        cfg.search.trials = 8;
        let mut d = SearchDriver::new(cfg, store(), &lr_space(), "train --lr {lr}").unwrap();
        let r = d.run().unwrap();
        assert_eq!(r.algo, "grid");
        assert_eq!((r.trials, r.completed, r.stopped, r.lost), (8, 8, 0, 0));
        assert_eq!(r.total_steps, 8 * 27);
        assert_eq!(r.preemptions, 0);
        assert_eq!(r.resumes, 0);
        assert_eq!(r.replayed_steps, 0);
        assert!(r.best_loss.is_finite());
        // the report's best really is the minimum over completed trials
        let min = d
            .trials()
            .iter()
            .map(|t| t.last_loss)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(r.best_loss, min);
        assert_eq!(
            r.best_assignment.as_ref(),
            d.trials().iter().find(|t| t.last_loss == min).map(|t| &t.assignment)
        );
        assert!(r.cost_usd > 0.0);
        // 8 trials × 27 s on 4 nodes from t=55: two waves, done at 109
        assert!((r.makespan_s - 109.0).abs() < 1e-6, "{}", r.makespan_s);
    }

    #[test]
    fn asha_matches_grid_best_on_rank_stable_curves_with_far_fewer_steps() {
        // τ pinned + zero noise ⇒ trial rankings are identical at every
        // rung, so ASHA can never cut the eventual winner: equal best
        // loss is guaranteed, at a fraction of the grid's trial-steps.
        let grid = SearchDriver::new(exact_cfg(SearchAlgo::Grid), store(), &grid_space(27), "t {p}")
            .unwrap()
            .run()
            .unwrap();
        let asha = SearchDriver::new(exact_cfg(SearchAlgo::Asha), store(), &grid_space(27), "t {p}")
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(grid.trials, 27);
        assert_eq!(asha.trials, 27);
        assert_eq!(grid.total_steps, 27 * 27);
        assert_eq!(asha.lost, 0);
        assert_eq!(
            asha.best_loss, grid.best_loss,
            "rank-stable curves: ASHA keeps the winner ({asha:?})"
        );
        assert!(
            asha.total_steps * 2 < grid.total_steps,
            "asha spent {} of grid's {} steps",
            asha.total_steps,
            grid.total_steps
        );
        assert!(asha.stopped > 0, "halving must have cut someone");
        assert!(asha.promotions > 0);
        assert!(asha.makespan_s <= grid.makespan_s, "less work, same fleet");
    }

    #[test]
    fn notice_storm_pauses_resume_elsewhere_zero_lost() {
        // 8 grid trials × 40 steps on 4 nodes (ready t=55); a storm at
        // t=70 drains 2 nodes with a 3 s notice. The 2 running trials
        // checkpoint their 15 banked steps and resume on other nodes —
        // nothing is lost and nothing replays.
        let mut cfg = exact_cfg(SearchAlgo::Grid);
        cfg.search.trials = 8;
        cfg.search.max_steps = 40;
        cfg.storm = vec![StormEvent { at_s: 70.0, kills: 2, notice_s: 3.0 }];
        let s = store();
        let mut d = SearchDriver::new(cfg, s.clone(), &lr_space(), "train --lr {lr}").unwrap();
        let r = d.run().unwrap();
        assert_eq!((r.completed, r.stopped, r.lost), (8, 0, 0), "{r:?}");
        assert_eq!(r.preemptions, 2);
        assert_eq!(r.pauses, 2);
        assert_eq!(r.resumes, 2);
        assert_eq!(r.full_restarts, 0, "drain checkpoints mean no restart from 0");
        assert_eq!(r.resumed_same_node, 0, "§III.D: resumed on a different node");
        assert_eq!(r.replayed_steps, 0, "graceful drain banks every step");
        assert_eq!(r.total_steps, 8 * 40, "exactly the nominal work was executed");
        assert!(r.nodes_launched > 4, "replacements for the killed nodes");
        // the storm fired at its scripted engine-start time
        assert_eq!(d.fleet_stats().storms_fired_at_s, vec![70.0]);
        // keep-last-k pruning held during the run
        for t in d.trials() {
            let blobs = s.list(&format!("search/ckpt/{}/step", t.task)).unwrap();
            assert!(blobs.len() <= 2, "task {} kept {} blobs", t.task, blobs.len());
        }
    }

    #[test]
    fn hard_kill_replays_only_since_last_checkpoint() {
        // one 40-step trial, checkpoints every 10 steps; instant kill at
        // t=70 (step 15): resume must come from step 10 — 5 replayed
        // steps, no full restart. Exact timeline: ready 55, ckpt@65
        // (step 10), kill@70, replacement ready 125, done 125+30=155.
        let mut cfg = exact_cfg(SearchAlgo::Grid);
        cfg.search.trials = 1;
        cfg.search.max_steps = 40;
        cfg.search.workers = 1;
        cfg.storm = vec![StormEvent { at_s: 70.0, kills: 1, notice_s: 0.0 }];
        let mut d = SearchDriver::new(cfg, store(), &lr_space(), "train --lr {lr}").unwrap();
        let r = d.run().unwrap();
        assert_eq!((r.completed, r.lost), (1, 0), "{r:?}");
        assert_eq!(r.preemptions, 1);
        assert_eq!(r.resumes, 1);
        assert_eq!(r.full_restarts, 0);
        assert_eq!(r.replayed_steps, 5);
        assert_eq!(r.total_steps, 45, "40 nominal + 5 replayed");
        assert!((r.makespan_s - 155.0).abs() < 1e-6, "{}", r.makespan_s);
        let t = &d.trials()[0];
        assert_eq!(t.pauses, 1);
        assert_eq!(t.lifetime_steps, 45);
    }

    #[test]
    fn price_trace_pauses_the_search_and_resumes_after_recovery() {
        // one long trial on one spot node bidding 0.10 against a trace
        // that spikes over [70, 400): noticed at exactly 70 (a drain
        // checkpoint banks step 15), killed at 75, and the replacement
        // launch waits out the spike — the trial still completes with
        // zero lost steps (graceful drain) after the recovery.
        let mut cfg = exact_cfg(SearchAlgo::Grid);
        cfg.search.trials = 1;
        cfg.search.max_steps = 40;
        cfg.search.workers = 1;
        cfg.search.spot = true;
        let trace =
            PriceTrace::new(vec![(0.0, 0.05), (70.0, 0.90), (400.0, 0.06)]).unwrap();
        cfg.price_trace = Some(PriceTraceConfig { trace, bid_usd: 0.10, notice_s: 5.0 });
        let mut d = SearchDriver::new(cfg, store(), &lr_space(), "train --lr {lr}").unwrap();
        let r = d.run().unwrap();
        assert_eq!((r.completed, r.lost), (1, 0), "{r:?}");
        assert_eq!(r.preemptions, 1, "the node hit the price crossing");
        assert_eq!(r.pauses, 1);
        assert_eq!(r.resumes, 1);
        assert_eq!(r.replayed_steps, 0, "the 5 s notice banked the segment");
        assert!(
            d.fleet_stats().launches_deferred >= 1,
            "the replacement waited out the spike: {:?}",
            d.fleet_stats()
        );
        // replacement provisions from t=400 (ready 455) and runs the
        // remaining 25 steps: done at 480
        assert!((r.makespan_s - 480.0).abs() < 1e-6, "{}", r.makespan_s);
    }

    #[test]
    fn same_seed_bit_identical_reports() {
        let run = || {
            let mut cfg = exact_cfg(SearchAlgo::Asha);
            cfg.search.spot = true;
            cfg.spot_market = Some(SpotMarketConfig { mean_ttp_s: 200.0, notice_s: 20.0 });
            cfg.storm = vec![StormEvent { at_s: 90.0, kills: 2, notice_s: 0.0 }];
            SearchDriver::new(cfg, store(), &grid_space(27), "t {p}").unwrap().run().unwrap()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn metrics_mirror_the_report() {
        let mut cfg = exact_cfg(SearchAlgo::Asha);
        cfg.search.trials = 0;
        cfg.storm = vec![StormEvent { at_s: 70.0, kills: 2, notice_s: 3.0 }];
        let mut d = SearchDriver::new(cfg, store(), &grid_space(27), "t {p}").unwrap();
        let r = d.run().unwrap();
        assert_eq!(d.metrics.counter("search.trials_started").get(), 27);
        assert_eq!(d.metrics.counter("search.pauses").get(), r.pauses);
        assert_eq!(d.metrics.counter("search.resumes").get(), r.resumes);
        assert_eq!(d.metrics.counter("search.promotions").get(), r.promotions);
        assert_eq!(d.metrics.counter("search.checkpoints").get(), r.checkpoints);
        assert_eq!(
            d.metrics.counter("search.trials_completed").get() as usize
                + d.metrics.counter("search.early_stops").get() as usize,
            r.completed + r.stopped
        );
        assert_eq!(d.metrics.float_gauge("search.best_loss").get(), r.best_loss);
    }

    /// ISSUE 9 acceptance: the analyzer reconciles the search-storm
    /// trace exactly — node category partitions, ledger totals, and
    /// per-trial costs (every trial ran exactly its 40 step-seconds of
    /// segments, pause + resume included, so all eight bill identically).
    #[test]
    fn analyzer_reconciles_per_trial_costs_and_the_ledger() {
        use crate::obs::analyze::analyze;
        use crate::obs::FlightRecorder;
        use crate::sim::SimClock;

        let mut cfg = exact_cfg(SearchAlgo::Grid);
        cfg.search.trials = 8;
        cfg.search.max_steps = 40;
        cfg.storm = vec![StormEvent { at_s: 70.0, kills: 2, notice_s: 3.0 }];
        let mut d = SearchDriver::new(cfg, store(), &lr_space(), "train --lr {lr}").unwrap();
        let rec = FlightRecorder::sim(1 << 16, SimClock::new());
        d.set_obs(rec.clone());
        let r = d.run().unwrap();
        assert_eq!((r.completed, r.lost), (8, 0));
        assert_eq!(rec.dropped(), 0);

        let a = analyze(&rec.snapshot());
        for n in &a.nodes {
            assert_eq!(
                n.provisioning_ns + n.busy_ns + n.drain_ns + n.idle_ns,
                n.lifetime_ns,
                "node {}: category times must partition the billed lifetime",
                n.pid
            );
        }
        let tol = 1e-9 * r.cost_usd.max(1.0);
        assert!(
            (a.total_usd - r.cost_usd).abs() <= tol,
            "trace-derived ${} vs ledger ${}",
            a.total_usd,
            r.cost_usd
        );
        assert!((a.attributed_usd + a.wasted_usd - a.total_usd).abs() <= tol);
        // zero replayed steps ⇒ every trial ran exactly 40 segment-secs,
        // so all eight bill the same 40 s at the on-demand m5.xlarge rate
        assert_eq!(a.per_trial_usd.len(), 8);
        let rate = crate::cloud::InstanceType::by_name("m5.xlarge").unwrap().price(false);
        let expect = rate * (40.0 / 3600.0);
        for (trial, usd) in &a.per_trial_usd {
            assert!(
                (usd - expect).abs() < 1e-9,
                "trial {trial}: ${usd} vs ${expect}"
            );
        }
        // trace counters agree with the report
        assert_eq!(a.restores, r.resumes);
        assert_eq!(a.checkpoints, r.checkpoints);
        assert_eq!(a.storms, 1);
        assert!(a.drain_ns > 0, "the noticed nodes drained");
    }

    #[test]
    fn builds_and_runs_from_a_recipe_search_stanza() {
        let yaml = r#"
name: sweep
experiments:
  - name: tune
    instance: m5.xlarge
    workers: 4
    spot: true
    command: "train --lr {lr} --wd {wd}"
    samples: 9
    params:
      lr: { log_uniform: [1.0e-4, 1.0e-1] }
      wd: { choice: [0.0, 0.1] }
    search: { algo: asha, max_steps: 27, rung_steps: 3, eta: 3 }
"#;
        let recipe = Recipe::from_yaml(yaml).unwrap();
        let spec = recipe.experiment("tune").unwrap();
        let mut d = SearchDriver::from_experiment(spec, store(), 3).unwrap();
        let r = d.run().unwrap();
        assert_eq!(r.algo, "asha");
        assert_eq!(r.trials, 9);
        assert_eq!(r.lost, 0);
        assert!(r.completed >= 1, "{r:?}");
        // the stanza-less experiment is rejected
        let mut no_stanza = spec.clone();
        no_stanza.search = None;
        assert!(matches!(
            SearchDriver::from_experiment(&no_stanza, store(), 3),
            Err(Error::Search(_))
        ));
    }

    #[test]
    fn driver_is_single_use_and_validates_inputs() {
        let mut d =
            SearchDriver::new(exact_cfg(SearchAlgo::Grid), store(), &grid_space(2), "t {p}")
                .unwrap();
        d.run().unwrap();
        assert!(matches!(d.run(), Err(Error::Search(_))));
        let mut bad = exact_cfg(SearchAlgo::Grid);
        bad.search.instance = "quantum.9000".into();
        assert!(matches!(
            SearchDriver::new(bad, store(), &grid_space(2), "t {p}"),
            Err(Error::Search(_))
        ));
    }
}
