//! Cluster plane: master, node servers, KV store, log collection.
//!
//! §III.C: "Master is responsible for receiving the recipe … The objects
//! are stored in-memory key-value cache Redis. As a backup alternative,
//! the system stores the state into DynamoDB. … each compute worker runs
//! a node server that listens to commands executed by the workflow
//! manager."

pub mod kvstore;
pub mod logs;
pub mod master;
pub mod node;

pub use kvstore::KvStore;
pub use logs::{LogCollector, LogKind, LogRecord};
pub use master::Master;
pub use node::{NodeServer, TaskOutcome};
