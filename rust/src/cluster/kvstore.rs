//! In-process KV store standing in for Redis, with a snapshot "backup"
//! path standing in for DynamoDB (DESIGN.md §7).
//!
//! Versioned writes + watch counters give the master the same primitives
//! the paper gets from Redis: workflow objects as JSON values, cheap
//! polling, and a dump that can be restored after a master restart.

use std::collections::BTreeMap;

use std::sync::RwLock;

use crate::storage::StoreHandle;
use crate::util::Json;
use crate::{Error, Result};

/// A versioned value.
#[derive(Debug, Clone)]
struct Versioned {
    value: Vec<u8>,
    version: u64,
}

/// Redis-like in-memory KV with JSON typed accessors.
#[derive(Debug, Default)]
pub struct KvStore {
    map: RwLock<BTreeMap<String, Versioned>>,
}

impl KvStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set raw bytes; returns the new version (1 for a fresh key).
    pub fn set(&self, key: &str, value: Vec<u8>) -> u64 {
        let mut map = self.map.write().unwrap();
        let version = map.get(key).map_or(1, |v| v.version + 1);
        map.insert(key.to_string(), Versioned { value, version });
        version
    }

    pub fn get(&self, key: &str) -> Option<Vec<u8>> {
        self.map.read().unwrap().get(key).map(|v| v.value.clone())
    }

    /// Current version of a key (0 = absent). Pollers compare versions —
    /// the "watch" primitive.
    pub fn version(&self, key: &str) -> u64 {
        self.map.read().unwrap().get(key).map_or(0, |v| v.version)
    }

    pub fn delete(&self, key: &str) -> bool {
        self.map.write().unwrap().remove(key).is_some()
    }

    pub fn keys(&self, prefix: &str) -> Vec<String> {
        self.map
            .read().unwrap()
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// JSON-valued set.
    pub fn set_json(&self, key: &str, value: &Json) -> u64 {
        self.set(key, value.to_bytes())
    }

    /// JSON-valued get.
    pub fn get_json(&self, key: &str) -> Result<Json> {
        let bytes = self.get(key).ok_or_else(|| Error::Kv(format!("missing key {key}")))?;
        Json::parse_bytes(&bytes)
    }

    /// String convenience accessors (recipes, names).
    pub fn set_str(&self, key: &str, value: &str) -> u64 {
        self.set(key, value.as_bytes().to_vec())
    }

    pub fn get_str(&self, key: &str) -> Result<String> {
        let bytes = self.get(key).ok_or_else(|| Error::Kv(format!("missing key {key}")))?;
        String::from_utf8(bytes).map_err(|e| Error::Kv(e.to_string()))
    }

    /// Compare-and-set on version; returns new version or None on conflict.
    pub fn cas(&self, key: &str, expected_version: u64, value: Vec<u8>) -> Option<u64> {
        let mut map = self.map.write().unwrap();
        let cur = map.get(key).map_or(0, |v| v.version);
        if cur != expected_version {
            return None;
        }
        let version = cur + 1;
        map.insert(key.to_string(), Versioned { value, version });
        Some(version)
    }

    /// Snapshot every key to the backup object store (the DynamoDB path).
    /// Values are hex-encoded (they may be arbitrary bytes).
    pub fn backup(&self, store: &StoreHandle, prefix: &str) -> Result<usize> {
        let map = self.map.read().unwrap();
        let snapshot = Json::Obj(
            map.iter().map(|(k, v)| (k.clone(), Json::Str(hex_encode(&v.value)))).collect(),
        );
        let n = map.len();
        store.put(&format!("{prefix}/kv_backup.json"), &snapshot.to_bytes())?;
        Ok(n)
    }

    /// Restore from a backup written by [`KvStore::backup`]. All restored
    /// keys start at version 1.
    pub fn restore(store: &StoreHandle, prefix: &str) -> Result<Self> {
        let blob = store.get(&format!("{prefix}/kv_backup.json"))?;
        let snapshot = Json::parse_bytes(&blob)?;
        let obj = snapshot.as_obj().ok_or_else(|| Error::Kv("backup is not an object".into()))?;
        let kv = Self::new();
        for (k, v) in obj {
            let hex = v.as_str().ok_or_else(|| Error::Kv(format!("bad backup value for {k}")))?;
            kv.set(k, hex_decode(hex)?);
        }
        Ok(kv)
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn hex_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len() * 2);
    for b in data {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

fn hex_decode(s: &str) -> Result<Vec<u8>> {
    if s.len() % 2 != 0 {
        return Err(Error::Kv("odd-length hex".into()));
    }
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16).map_err(|e| Error::Kv(format!("bad hex: {e}")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::storage::MemStore;

    #[test]
    fn set_get_versions() {
        let kv = KvStore::new();
        assert_eq!(kv.version("k"), 0);
        assert_eq!(kv.set("k", b"v1".to_vec()), 1);
        assert_eq!(kv.set("k", b"v2".to_vec()), 2);
        assert_eq!(kv.get("k").unwrap(), b"v2");
        assert!(kv.delete("k"));
        assert!(!kv.delete("k"));
    }

    #[test]
    fn json_roundtrip() {
        let kv = KvStore::new();
        kv.set_json("cfg", &Json::Arr(vec![Json::num(1), Json::num(2)]));
        let v = kv.get_json("cfg").unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 2);
        assert!(kv.get_json("missing").is_err());
        kv.set_str("s", "recipe text");
        assert_eq!(kv.get_str("s").unwrap(), "recipe text");
    }

    #[test]
    fn cas_detects_conflicts() {
        let kv = KvStore::new();
        kv.set("k", b"a".to_vec());
        assert_eq!(kv.cas("k", 1, b"b".to_vec()), Some(2));
        assert_eq!(kv.cas("k", 1, b"c".to_vec()), None); // stale
        assert_eq!(kv.get("k").unwrap(), b"b");
    }

    #[test]
    fn prefix_scan() {
        let kv = KvStore::new();
        kv.set("task/1", vec![]);
        kv.set("task/2", vec![]);
        kv.set("node/1", vec![]);
        assert_eq!(kv.keys("task/"), vec!["task/1", "task/2"]);
    }

    #[test]
    fn backup_restore_roundtrip() {
        let kv = KvStore::new();
        kv.set("a", b"1".to_vec());
        kv.set("b", b"2".to_vec());
        let store: StoreHandle = Arc::new(MemStore::new());
        assert_eq!(kv.backup(&store, "wf0").unwrap(), 2);
        let restored = KvStore::restore(&store, "wf0").unwrap();
        assert_eq!(restored.get("a").unwrap(), b"1");
        assert_eq!(restored.len(), 2);
    }
}
