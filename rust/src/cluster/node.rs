//! Node server (§III.C): the per-worker agent that "listens to commands
//! executed by the workflow manager", pulls the container, mounts HFS and
//! runs client tasks.
//!
//! In this reproduction a *local* node server executes real tasks (PJRT
//! training steps, ETL shards) on the local machine with a thread pool;
//! fleet-scale execution is simulated by [`crate::scheduler::SimDriver`].

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::workflow::Task;

/// Result of running one task.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TaskOutcome {
    Success,
    /// Task-level error (consumes a retry).
    Error(String),
}

/// A local worker that executes tasks with `slots` of parallelism.
pub struct NodeServer {
    pub id: u32,
    slots: usize,
}

impl NodeServer {
    pub fn new(id: u32, slots: usize) -> Self {
        Self { id, slots: slots.max(1) }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Execute `tasks` with the given runner, `slots`-wide. Returns
    /// outcomes in input order. The runner must be `Sync` (it is shared
    /// across worker threads), mirroring the paper's stateless container
    /// entrypoint.
    pub fn run_batch<F>(&self, tasks: &[Task], runner: F) -> Vec<TaskOutcome>
    where
        F: Fn(&Task) -> TaskOutcome + Sync,
    {
        if tasks.is_empty() {
            return Vec::new();
        }
        let results: Vec<std::sync::Mutex<Option<TaskOutcome>>> =
            tasks.iter().map(|_| std::sync::Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let runner = &runner; // &F is Send because F: Sync
        std::thread::scope(|s| {
            for _ in 0..self.slots.min(tasks.len()) {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= tasks.len() {
                        break;
                    }
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        runner(&tasks[i])
                    }))
                    .unwrap_or_else(|_| TaskOutcome::Error("task panicked".into()));
                    *results[i].lock().unwrap() = Some(out);
                });
            }
        });
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("every slot filled"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::{ExperimentSpec, WorkSpec};

    fn mk_tasks(n: u32) -> Vec<Task> {
        let spec = ExperimentSpec {
            name: "e".into(),
            image: "i".into(),
            instance: "m5.xlarge".into(),
            workers: 1,
            spot: false,
            command: "c".into(),
            samples: None,
            params: Default::default(),
            depends_on: vec![],
            max_retries: 0,
            work: WorkSpec::default(),
            search: None,
        };
        (0..n).map(|i| Task::materialize(0, i, &spec, Default::default())).collect()
    }

    #[test]
    fn runs_all_tasks_in_order() {
        let node = NodeServer::new(0, 4);
        let tasks = mk_tasks(32);
        let out = node.run_batch(&tasks, |_| TaskOutcome::Success);
        assert_eq!(out.len(), 32);
        assert!(out.iter().all(|o| *o == TaskOutcome::Success));
    }

    #[test]
    fn per_task_errors_reported() {
        let node = NodeServer::new(0, 2);
        let tasks = mk_tasks(10);
        let out = node.run_batch(&tasks, |t| {
            if t.id.index % 3 == 0 {
                TaskOutcome::Error("boom".into())
            } else {
                TaskOutcome::Success
            }
        });
        let errors = out.iter().filter(|o| matches!(o, TaskOutcome::Error(_))).count();
        assert_eq!(errors, 4); // indices 0,3,6,9
    }

    #[test]
    fn panics_become_errors() {
        let node = NodeServer::new(0, 2);
        let tasks = mk_tasks(4);
        let out = node.run_batch(&tasks, |t| {
            if t.id.index == 2 {
                panic!("kaboom");
            }
            TaskOutcome::Success
        });
        assert!(matches!(out[2], TaskOutcome::Error(_)));
        assert_eq!(out.iter().filter(|o| **o == TaskOutcome::Success).count(), 3);
    }

    #[test]
    fn empty_batch() {
        let node = NodeServer::new(0, 2);
        assert!(node.run_batch(&[], |_| TaskOutcome::Success).is_empty());
    }
}
