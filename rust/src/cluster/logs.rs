//! Log collection (§III.C): "three types of logs are collected into
//! Elastic Logstash: client application logs, CPU/GPU utilization logs
//! and operating system logs."

use std::sync::Arc;

use std::sync::Mutex;

use crate::obs::Ring;
use crate::sim::SimTime;

/// Which of the paper's three streams a record belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LogKind {
    Application,
    Utilization,
    Os,
}

/// One collected record.
#[derive(Debug, Clone)]
pub struct LogRecord {
    pub at: SimTime,
    pub node: u32,
    pub kind: LogKind,
    pub message: String,
}

/// Bounded in-memory collector (the Logstash stand-in), backed by the
/// [`crate::obs`] flight-recorder ring: when full, the *oldest* record is
/// evicted so the newest `capacity` records — the end of the run, the
/// part you debug — always survive. Evictions are counted in `dropped`.
///
/// (Earlier versions had the inverse policy — keep the oldest, drop new
/// arrivals — which preserved exactly the part of a long run nobody asks
/// about.)
#[derive(Clone)]
pub struct LogCollector {
    inner: Arc<Mutex<Ring<LogRecord>>>,
}

impl LogCollector {
    pub fn new(capacity: usize) -> Self {
        Self { inner: Arc::new(Mutex::new(Ring::new(capacity))) }
    }

    pub fn log(&self, at: SimTime, node: u32, kind: LogKind, message: impl Into<String>) {
        self.inner.lock().unwrap().push(LogRecord { at, node, kind, message: message.into() });
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Records evicted to stay within capacity.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped()
    }

    /// Records matching a filter (node and/or kind), oldest first.
    pub fn query(&self, node: Option<u32>, kind: Option<LogKind>) -> Vec<LogRecord> {
        self.inner
            .lock().unwrap()
            .iter()
            .filter(|r| node.is_none_or(|n| r.node == n) && kind.is_none_or(|k| r.kind == k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collect_and_query() {
        let c = LogCollector::new(100);
        c.log(SimTime::ZERO, 1, LogKind::Application, "train started");
        c.log(SimTime::from_secs(1), 1, LogKind::Utilization, "gpu=87%");
        c.log(SimTime::from_secs(2), 2, LogKind::Os, "oom-killer");
        assert_eq!(c.len(), 3);
        assert_eq!(c.query(Some(1), None).len(), 2);
        assert_eq!(c.query(None, Some(LogKind::Os)).len(), 1);
        assert_eq!(c.query(Some(2), Some(LogKind::Application)).len(), 0);
    }

    #[test]
    fn bounded_with_drop_counter() {
        let c = LogCollector::new(2);
        for i in 0..5 {
            c.log(SimTime::ZERO, 0, LogKind::Application, format!("m{i}"));
        }
        assert_eq!(c.len(), 2);
        assert_eq!(c.dropped(), 3);
    }

    #[test]
    fn overflow_keeps_the_newest_records() {
        // flight-recorder semantics: the survivors are the most recent
        // messages, not the first ones ever logged
        let c = LogCollector::new(3);
        for i in 0..10 {
            c.log(SimTime::from_secs(i), 0, LogKind::Application, format!("m{i}"));
        }
        let kept: Vec<String> =
            c.query(None, None).into_iter().map(|r| r.message).collect();
        assert_eq!(kept, vec!["m7", "m8", "m9"]);
        assert_eq!(c.dropped(), 7);
    }
}
