//! The Master (§III.C): receives recipes, compiles workflows, stores the
//! objects in the KV cache, and exposes status.
//!
//! Workflow objects are stored as (recipe text, seed) — compilation is
//! deterministic, so recompiling on fetch is equivalent to deserializing
//! the object graph and keeps the KV payload small (what the paper's
//! Redis holds is exactly the recipe-derived objects).

use std::sync::Arc;
use std::sync::Mutex;

use crate::storage::StoreHandle;
use crate::util::Json;
use crate::workflow::{Recipe, Workflow};
use crate::{Error, Result};

use super::kvstore::KvStore;
use super::logs::LogCollector;

/// Master node: recipe intake + workflow object storage.
pub struct Master {
    pub kv: Arc<KvStore>,
    pub logs: LogCollector,
    backup: Option<StoreHandle>,
    workflows: Mutex<Vec<String>>,
}

impl Master {
    pub fn new() -> Self {
        Self {
            kv: Arc::new(KvStore::new()),
            logs: LogCollector::new(100_000),
            backup: None,
            workflows: Mutex::new(Vec::new()),
        }
    }

    /// Attach a DynamoDB-style backup target; every submit snapshots the KV.
    pub fn with_backup(mut self, store: StoreHandle) -> Self {
        self.backup = Some(store);
        self
    }

    /// Parse, compile and register a workflow. Returns its name.
    pub fn submit(&self, recipe_yaml: &str, seed: u64) -> Result<String> {
        let recipe = Recipe::from_yaml(recipe_yaml)?;
        let wf = Workflow::compile(recipe, seed)?;
        let name = wf.name.clone();
        self.kv.set_str(&format!("wf/{name}/recipe"), recipe_yaml);
        self.kv.set_json(&format!("wf/{name}/seed"), &Json::num(seed as f64));
        self.kv.set_json(
            &format!("wf/{name}/meta"),
            &Json::obj(vec![
                ("experiments", Json::num(wf.n_experiments() as f64)),
                ("tasks", Json::num(wf.total_tasks() as f64)),
            ]),
        );
        self.workflows.lock().unwrap().push(name.clone());
        if let Some(store) = &self.backup {
            self.kv.backup(store, &format!("backup/{name}"))?;
        }
        Ok(name)
    }

    /// Fetch a workflow back out of the KV store (recompiled — identical
    /// to the submitted one since compilation is seed-deterministic).
    pub fn workflow(&self, name: &str) -> Result<Workflow> {
        let yaml = self.kv.get_str(&format!("wf/{name}/recipe"))?;
        let seed = self
            .kv
            .get_json(&format!("wf/{name}/seed"))?
            .as_u64()
            .ok_or_else(|| Error::Kv("bad seed".into()))?;
        Workflow::compile(Recipe::from_yaml(&yaml)?, seed)
    }

    /// Persist a run outcome summary for `status`.
    pub fn record_run(&self, name: &str, summary: &Json) {
        self.kv.set_json(&format!("wf/{name}/last_run"), summary);
    }

    pub fn last_run(&self, name: &str) -> Result<Json> {
        self.kv.get_json(&format!("wf/{name}/last_run"))
    }

    pub fn list_workflows(&self) -> Vec<String> {
        self.workflows.lock().unwrap().clone()
    }

    /// Recover a master from a KV backup (the DynamoDB restore path).
    pub fn recover(store: StoreHandle, workflow_name: &str) -> Result<Self> {
        let kv = KvStore::restore(&store, &format!("backup/{workflow_name}"))
            .map_err(|e| Error::Kv(format!("recover failed: {e}")))?;
        let master = Self {
            kv: Arc::new(kv),
            logs: LogCollector::new(100_000),
            backup: Some(store),
            workflows: Mutex::new(vec![workflow_name.to_string()]),
        };
        // sanity: the workflow must recompile
        master.workflow(workflow_name)?;
        Ok(master)
    }
}

impl Default for Master {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::storage::MemStore;

    const YAML: &str = r#"
name: demo
experiments:
  - name: prep
    instance: m5.xlarge
    workers: 2
    command: "prep {i}"
    params: { i: { range: [0, 9] } }
"#;

    #[test]
    fn submit_and_fetch() {
        let m = Master::new();
        let name = m.submit(YAML, 0).unwrap();
        assert_eq!(name, "demo");
        let wf = m.workflow("demo").unwrap();
        assert_eq!(wf.total_tasks(), 10);
        assert_eq!(m.list_workflows(), vec!["demo"]);
    }

    #[test]
    fn refetch_is_deterministic() {
        let m = Master::new();
        m.submit(YAML, 7).unwrap();
        let a = m.workflow("demo").unwrap();
        let b = m.workflow("demo").unwrap();
        for (ta, tb) in a.tasks[0].iter().zip(&b.tasks[0]) {
            assert_eq!(ta.command, tb.command);
        }
    }

    #[test]
    fn invalid_recipe_rejected() {
        let m = Master::new();
        assert!(m.submit("not: [valid", 0).is_err());
        assert!(m.list_workflows().is_empty());
    }

    #[test]
    fn run_summary_roundtrip() {
        let m = Master::new();
        m.submit(YAML, 0).unwrap();
        m.record_run("demo", &Json::obj(vec![("makespan_s", Json::num(12.5))]));
        assert_eq!(m.last_run("demo").unwrap().req_f64("makespan_s").unwrap(), 12.5);
    }

    #[test]
    fn backup_and_recover() {
        let store: StoreHandle = Arc::new(MemStore::new());
        let m = Master::new().with_backup(store.clone());
        m.submit(YAML, 0).unwrap();
        drop(m); // master dies
        let recovered = Master::recover(store, "demo").unwrap();
        let wf = recovered.workflow("demo").unwrap();
        assert_eq!(wf.total_tasks(), 10);
    }
}
