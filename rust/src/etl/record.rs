//! tfrecord-like record framing: `[u32 little-endian length][payload]*`
//! with a trailing crc of the whole shard for corruption detection.

use crate::{Error, Result};

/// Append-only record shard writer.
#[derive(Debug, Default)]
pub struct RecordWriter {
    buf: Vec<u8>,
    count: u32,
}

impl RecordWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, payload: &[u8]) {
        self.buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        self.buf.extend_from_slice(payload);
        self.count += 1;
    }

    pub fn count(&self) -> u32 {
        self.count
    }

    /// Finish: append `[record count][fnv1a checksum]`.
    pub fn finish(mut self) -> Vec<u8> {
        let crc = fnv1a(&self.buf);
        self.buf.extend_from_slice(&self.count.to_le_bytes());
        self.buf.extend_from_slice(&crc.to_le_bytes());
        self.buf
    }
}

/// Iterator over records in a shard; validates the checksum up front.
pub struct RecordReader<'a> {
    data: &'a [u8],
    pos: usize,
    end: usize,
    valid: bool,
}

impl<'a> RecordReader<'a> {
    pub fn new(shard: &'a [u8]) -> Self {
        if shard.len() < 8 {
            return Self { data: shard, pos: 0, end: 0, valid: false };
        }
        let body_end = shard.len() - 8;
        let crc_stored = u32::from_le_bytes(shard[shard.len() - 4..].try_into().expect("4 bytes"));
        let valid = fnv1a(&shard[..body_end]) == crc_stored;
        Self { data: shard, pos: 0, end: body_end, valid }
    }

    /// Number of records recorded in the trailer.
    pub fn trailer_count(shard: &[u8]) -> Option<u32> {
        if shard.len() < 8 {
            return None;
        }
        Some(u32::from_le_bytes(
            shard[shard.len() - 8..shard.len() - 4].try_into().expect("4 bytes"),
        ))
    }
}

impl<'a> Iterator for RecordReader<'a> {
    type Item = Result<&'a [u8]>;

    fn next(&mut self) -> Option<Self::Item> {
        if !self.valid {
            if self.pos == 0 {
                self.pos = 1; // emit the error once
                return Some(Err(Error::Storage("record shard checksum mismatch".into())));
            }
            return None;
        }
        if self.pos >= self.end {
            return None;
        }
        if self.pos + 4 > self.end {
            self.valid = false;
            return Some(Err(Error::Storage("truncated record header".into())));
        }
        let len =
            u32::from_le_bytes(self.data[self.pos..self.pos + 4].try_into().expect("4 bytes"))
                as usize;
        self.pos += 4;
        if self.pos + len > self.end {
            self.valid = false;
            return Some(Err(Error::Storage("truncated record payload".into())));
        }
        let payload = &self.data[self.pos..self.pos + len];
        self.pos += len;
        Some(Ok(payload))
    }
}

fn fnv1a(data: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in data {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut w = RecordWriter::new();
        w.push(b"alpha");
        w.push(b"");
        w.push(b"gamma rays");
        assert_eq!(w.count(), 3);
        let shard = w.finish();
        assert_eq!(RecordReader::trailer_count(&shard), Some(3));
        let records: Vec<&[u8]> = RecordReader::new(&shard).map(|r| r.unwrap()).collect();
        assert_eq!(records, vec![&b"alpha"[..], &b""[..], &b"gamma rays"[..]]);
    }

    #[test]
    fn corruption_detected() {
        let mut w = RecordWriter::new();
        w.push(b"payload");
        let mut shard = w.finish();
        shard[2] ^= 0xFF;
        let mut reader = RecordReader::new(&shard);
        assert!(reader.next().unwrap().is_err());
        assert!(reader.next().is_none());
    }

    #[test]
    fn empty_shard() {
        let shard = RecordWriter::new().finish();
        assert_eq!(RecordReader::trailer_count(&shard), Some(0));
        assert_eq!(RecordReader::new(&shard).count(), 0);
    }

    #[test]
    fn garbage_input() {
        let mut r = RecordReader::new(b"xy");
        assert!(r.next().unwrap().is_err());
    }
}
