//! The §IV.A preprocessing pipeline: text files -> filtered / tokenized /
//! paragraph-split records (the spaCy job, rebuilt in rust per DESIGN.md
//! §6), framed into tfrecord-like shards.

mod record;
mod tokenizer;

pub use record::{RecordReader, RecordWriter};
pub use tokenizer::{split_paragraphs, tokenize, TokenStats};

use crate::hfs::HyperFs;
use crate::Result;

/// Output of preprocessing one batch of input files.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EtlReport {
    pub files_in: usize,
    pub paragraphs: usize,
    pub tokens: usize,
    pub records_out: usize,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Inputs dropped by the length/quality filter.
    pub filtered: usize,
}

/// Preprocess every file under `prefix` in the mounted fs into a shard.
///
/// Pipeline per file (mirrors the paper's spaCy script): split paragraphs
/// -> filter short/garbage paragraphs -> tokenize -> emit one record per
/// paragraph with whitespace-normalized tokens.
///
/// Inputs are consumed as zero-copy [`crate::hfs::ByteView`]s straight
/// out of the chunk cache; the only copies on the hot path are the ones
/// the records themselves require.
pub fn preprocess_shard(fs: &HyperFs, prefix: &str, min_tokens: usize) -> Result<(Vec<u8>, EtlReport)> {
    let mut report = EtlReport::default();
    let mut writer = RecordWriter::new();
    for path in fs.list(prefix)? {
        let data = fs.read_file(&path)?;
        report.files_in += 1;
        report.bytes_in += data.len() as u64;
        let text = String::from_utf8_lossy(&data);
        for para in split_paragraphs(&text) {
            let tokens = tokenize(para);
            if tokens.len() < min_tokens {
                report.filtered += 1;
                continue;
            }
            report.paragraphs += 1;
            report.tokens += tokens.len();
            writer.push(tokens.join(" ").as_bytes());
            report.records_out += 1;
        }
    }
    let shard = writer.finish();
    report.bytes_out = shard.len() as u64;
    Ok((shard, report))
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::hfs::Uploader;
    use crate::storage::{MemStore, StoreHandle};

    #[test]
    fn end_to_end_shard() {
        let store: StoreHandle = Arc::new(MemStore::new());
        let mut up = Uploader::new(store.clone(), "corpus", 1 << 20);
        up.add_file(
            "docs/a.txt",
            b"First paragraph with enough tokens here.\n\nshort\n\nSecond good paragraph, also long enough to pass!",
        )
        .unwrap();
        up.add_file("docs/b.txt", b"Third paragraph of the corpus, with plenty of words inside.")
            .unwrap();
        up.seal().unwrap();
        let fs = HyperFs::mount(store, "corpus", 1 << 20).unwrap();
        let (shard, report) = preprocess_shard(&fs, "docs/", 5).unwrap();
        assert_eq!(report.files_in, 2);
        assert_eq!(report.paragraphs, 3);
        assert_eq!(report.filtered, 1, "the 'short' paragraph is dropped");
        assert_eq!(report.records_out, 3);
        // records round-trip
        let texts: Vec<String> = RecordReader::new(&shard)
            .map(|r| String::from_utf8(r.unwrap().to_vec()).unwrap())
            .collect();
        assert_eq!(texts.len(), 3);
        assert!(texts[0].starts_with("first paragraph"));
    }
}
