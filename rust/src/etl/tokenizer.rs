//! Tokenization + paragraph splitting (the spaCy substitute).
//!
//! Deliberately simple and fast: lowercasing, unicode-whitespace word
//! splits, punctuation stripping at token edges — enough to preserve the
//! ETL cost structure (CPU-bound per-byte work) without a model download.

/// Paragraphs = runs of non-empty lines separated by blank lines.
pub fn split_paragraphs(text: &str) -> Vec<&str> {
    text.split("\n\n")
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .collect()
}

/// Lowercased word tokens with edge punctuation stripped; pure-punctuation
/// and empty tokens are dropped.
pub fn tokenize(text: &str) -> Vec<String> {
    text.split_whitespace()
        .filter_map(|raw| {
            let t = raw.trim_matches(|c: char| !c.is_alphanumeric());
            if t.is_empty() {
                None
            } else {
                Some(t.to_lowercase())
            }
        })
        .collect()
}

/// Corpus-level statistics the §IV.A bench reports.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TokenStats {
    pub tokens: usize,
    pub unique_estimate: usize,
    pub mean_token_len: f64,
}

impl TokenStats {
    pub fn from_tokens(tokens: &[String]) -> Self {
        if tokens.is_empty() {
            return Self::default();
        }
        let mut set: std::collections::HashSet<&str> =
            std::collections::HashSet::with_capacity(tokens.len());
        let mut total_len = 0usize;
        for t in tokens {
            set.insert(t.as_str());
            total_len += t.len();
        }
        Self {
            tokens: tokens.len(),
            unique_estimate: set.len(),
            mean_token_len: total_len as f64 / tokens.len() as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paragraphs_split_on_blank_lines() {
        let ps = split_paragraphs("one\ntwo\n\nthree\n\n\n  \n\nfour");
        assert_eq!(ps, vec!["one\ntwo", "three", "four"]);
        assert!(split_paragraphs("").is_empty());
    }

    #[test]
    fn tokenize_strips_punct_and_lowercases() {
        assert_eq!(
            tokenize("Hello, World! (nested-word) 42..."),
            vec!["hello", "world", "nested-word", "42"]
        );
        assert_eq!(tokenize("!!! ... ---"), Vec::<String>::new());
    }

    #[test]
    fn keeps_inner_punctuation() {
        assert_eq!(tokenize("state-of-the-art's"), vec!["state-of-the-art's"]);
    }

    #[test]
    fn stats() {
        let toks = tokenize("a b a c a");
        let s = TokenStats::from_tokens(&toks);
        assert_eq!(s.tokens, 5);
        assert_eq!(s.unique_estimate, 3);
        assert!((s.mean_token_len - 1.0).abs() < 1e-9);
        assert_eq!(TokenStats::from_tokens(&[]), TokenStats::default());
    }
}
