//! [`HyperFs`]: the mounted read layer of the Hyper File System.
//!
//! "Within the program's context, files that are stored in remote chunked
//! object storage appear to be local files" (§III.A). `read_file` is the
//! POSIX-read analogue; chunk fetches go through the LRU cache and the
//! sequential prefetcher keeps the next chunks warm in a background
//! thread, so a compute-bound loader never waits on the network.

use std::sync::Arc;

use crate::metrics::Counter;
use crate::storage::StoreHandle;
use crate::{Error, Result};

use super::cache::ChunkCache;
use super::chunk::FsManifest;
use super::prefetch::{PrefetchPolicy, Prefetcher};

/// Counters exposed for tests / benches / the CLI `status` view.
#[derive(Debug, Clone, Default)]
pub struct HyperFsStats {
    pub reads: Counter,
    pub cache_hits: Counter,
    pub cache_misses: Counter,
    pub prefetch_issued: Counter,
    pub prefetch_hits: Counter,
    pub bytes_read: Counter,
}

impl HyperFsStats {
    pub fn hit_rate(&self) -> f64 {
        let h = self.cache_hits.get() as f64;
        let m = self.cache_misses.get() as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

/// A mounted HFS namespace on one node.
pub struct HyperFs {
    store: StoreHandle,
    ns: String,
    manifest: Arc<FsManifest>,
    cache: ChunkCache,
    prefetcher: Prefetcher,
    /// Run prefetches on background threads (true in real mode; false in
    /// virtual-time benches where overlap is accounted analytically).
    background_prefetch: bool,
    pub stats: HyperFsStats,
}

impl HyperFs {
    /// Mount namespace `ns` from `store` with a cache of `cache_bytes`.
    pub fn mount(store: StoreHandle, ns: &str, cache_bytes: u64) -> Result<Self> {
        Self::mount_with(store, ns, cache_bytes, PrefetchPolicy::default(), true)
    }

    pub fn mount_with(
        store: StoreHandle,
        ns: &str,
        cache_bytes: u64,
        policy: PrefetchPolicy,
        background_prefetch: bool,
    ) -> Result<Self> {
        let manifest_bytes = store
            .get(&FsManifest::manifest_key(ns))
            .map_err(|_| Error::Storage(format!("namespace {ns:?} has no manifest")))?;
        let manifest = Arc::new(FsManifest::from_json(&manifest_bytes)?);
        Ok(Self {
            store,
            ns: ns.to_string(),
            manifest,
            cache: ChunkCache::new(cache_bytes),
            prefetcher: Prefetcher::new(policy),
            background_prefetch,
            stats: HyperFsStats::default(),
        })
    }

    pub fn manifest(&self) -> &FsManifest {
        &self.manifest
    }

    pub fn namespace(&self) -> &str {
        &self.ns
    }

    /// Read a whole file by path (the POSIX open+read+close analogue).
    pub fn read_file(&self, path: &str) -> Result<Vec<u8>> {
        let idx = self.manifest.find(path)?;
        let entry = self.manifest.files[idx].clone();
        self.stats.reads.inc();
        self.stats.bytes_read.add(entry.len);

        let chunk = self.chunk_data(entry.chunk)?;
        // fire readahead for the predicted next chunks
        for target in self
            .prefetcher
            .on_access(entry.chunk, self.manifest.chunks.len() as u32)
        {
            self.issue_prefetch(target);
        }
        let start = entry.offset as usize;
        let end = start + entry.len as usize;
        Ok(chunk[start..end].to_vec())
    }

    /// File size without fetching data.
    pub fn stat(&self, path: &str) -> Result<u64> {
        Ok(self.manifest.files[self.manifest.find(path)?].len)
    }

    /// Paths under a prefix.
    pub fn list(&self, prefix: &str) -> Vec<String> {
        self.manifest.list(prefix).into_iter().map(|f| f.path.clone()).collect()
    }

    /// Chunk bytes via cache.
    fn chunk_data(&self, id: u32) -> Result<Arc<Vec<u8>>> {
        if let Some(hit) = self.cache.get(id) {
            self.stats.cache_hits.inc();
            return Ok(hit);
        }
        self.stats.cache_misses.inc();
        let data = Arc::new(self.store.get(&FsManifest::chunk_key(&self.ns, id))?);
        self.cache.insert(id, data.clone());
        Ok(data)
    }

    fn issue_prefetch(&self, id: u32) {
        if self.cache.contains(id) {
            return;
        }
        self.stats.prefetch_issued.inc();
        let store = self.store.clone();
        let cache = self.cache.clone();
        let key = FsManifest::chunk_key(&self.ns, id);
        let hits = self.stats.prefetch_hits.clone();
        let work = move || {
            if let Ok(data) = store.get(&key) {
                cache.insert(id, Arc::new(data));
                hits.inc();
            }
        };
        if self.background_prefetch {
            std::thread::spawn(work);
        } else {
            work();
        }
    }

    /// Expose the cache for tests / warm-start scenarios.
    pub fn cache(&self) -> &ChunkCache {
        &self.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hfs::Uploader;
    use crate::storage::MemStore;

    fn setup(n_files: usize, file_size: usize, chunk_size: u64) -> (StoreHandle, Vec<String>) {
        let store: StoreHandle = Arc::new(MemStore::new());
        let mut up = Uploader::new(store.clone(), "ds", chunk_size);
        let mut paths = Vec::new();
        for i in 0..n_files {
            let path = format!("data/{i:05}.bin");
            up.add_file(&path, &vec![(i % 251) as u8; file_size]).unwrap();
            paths.push(path);
        }
        up.seal().unwrap();
        (store, paths)
    }

    #[test]
    fn read_roundtrip() {
        let (store, paths) = setup(10, 100, 350);
        let fs = HyperFs::mount(store, "ds", 10 << 20).unwrap();
        for (i, p) in paths.iter().enumerate() {
            assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 100]);
        }
        assert_eq!(fs.stats.reads.get(), 10);
    }

    #[test]
    fn sequential_reads_hit_cache_within_chunk() {
        // 3 files per chunk -> at least 2/3 of reads are cache hits
        let (store, paths) = setup(30, 100, 300);
        let fs = HyperFs::mount_with(
            store,
            "ds",
            10 << 20,
            PrefetchPolicy { depth: 0 },
            false,
        )
        .unwrap();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        assert_eq!(fs.stats.cache_misses.get(), 10); // one per chunk
        assert_eq!(fs.stats.cache_hits.get(), 20);
    }

    #[test]
    fn prefetch_warms_next_chunk_synchronously() {
        let (store, paths) = setup(30, 100, 300);
        let fs = HyperFs::mount_with(
            store,
            "ds",
            10 << 20,
            PrefetchPolicy { depth: 1 },
            false, // synchronous prefetch for determinism
        )
        .unwrap();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        // after the run is sequential, every later chunk came from readahead
        assert!(fs.stats.prefetch_issued.get() >= 7, "{:?}", fs.stats);
        assert!(fs.stats.cache_misses.get() <= 3, "{:?}", fs.stats);
    }

    #[test]
    fn stat_and_list() {
        let (store, _) = setup(5, 42, 1000);
        let fs = HyperFs::mount(store, "ds", 1 << 20).unwrap();
        assert_eq!(fs.stat("data/00003.bin").unwrap(), 42);
        assert_eq!(fs.list("data/").len(), 5);
        assert_eq!(fs.list("nope/").len(), 0);
        assert!(fs.stat("missing").is_err());
    }

    #[test]
    fn missing_namespace_fails_to_mount() {
        let store: StoreHandle = Arc::new(MemStore::new());
        assert!(HyperFs::mount(store, "ghost", 1 << 20).is_err());
    }

    #[test]
    fn tiny_cache_still_correct() {
        let (store, paths) = setup(20, 100, 300);
        let fs = HyperFs::mount_with(store, "ds", 300, PrefetchPolicy { depth: 0 }, false)
            .unwrap();
        for (i, p) in paths.iter().enumerate() {
            assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 100]);
        }
    }
}
