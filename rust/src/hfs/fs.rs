//! [`HyperFs`]: the mounted read layer of the Hyper File System.
//!
//! "Within the program's context, files that are stored in remote chunked
//! object storage appear to be local files" (§III.A). `read_file` is the
//! POSIX-read analogue, rebuilt for throughput under many concurrent
//! readers:
//!
//! * **Zero-copy** — reads return a [`ByteView`] into the cached chunk:
//!   a cache hit does no allocation and no memcpy.
//! * **Sharded cache** — the LRU is sharded by chunk id with O(1)
//!   get/insert/evict, so readers of different chunks never contend on
//!   one mutex.
//! * **Single-flight** — concurrent misses (and prefetches) of the same
//!   chunk coalesce into exactly one backend GET.
//! * **Bounded readahead** — prefetch jobs run on the shared
//!   [`FetchPool`] worker lanes instead of one spawned thread per chunk,
//!   and are dropped (not queued unboundedly) when the lanes are saturated.

use std::sync::Arc;

use crate::metrics::Counter;
use crate::storage::StoreHandle;
use crate::{Error, Result};

use super::cache::ChunkCache;
use super::chunk::FsManifest;
use super::fetch::FetchPool;
use super::prefetch::{PrefetchPolicy, Prefetcher};
use super::singleflight::{FetchError, SingleFlight};
use super::view::{ByteView, ChunkData};

/// Preserve the not-found / storage distinction across the cloneable
/// single-flight boundary.
fn to_fetch_error(e: Error) -> FetchError {
    match e {
        Error::NotFound(s) => FetchError::NotFound(s),
        other => FetchError::Storage(other.to_string()),
    }
}

fn from_fetch_error(e: FetchError) -> Error {
    match e {
        FetchError::NotFound(s) => Error::NotFound(s),
        FetchError::Storage(s) => Error::Storage(s),
    }
}

/// Worker lanes of the per-mount readahead pool.
const PREFETCH_LANES: usize = 4;

/// Counters exposed for tests / benches / the CLI `status` view.
#[derive(Debug, Clone, Default)]
pub struct HyperFsStats {
    pub reads: Counter,
    pub cache_hits: Counter,
    pub cache_misses: Counter,
    pub prefetch_issued: Counter,
    pub prefetch_hits: Counter,
    pub bytes_read: Counter,
    /// Actual GETs issued to the backing store (per-chunk, post-coalescing).
    pub backend_gets: Counter,
    /// Misses that piggybacked on another reader's in-flight GET.
    pub coalesced_reads: Counter,
    /// Readahead jobs dropped because the fetch lanes were saturated.
    pub prefetch_dropped: Counter,
}

impl HyperFsStats {
    pub fn hit_rate(&self) -> f64 {
        let h = self.cache_hits.get() as f64;
        let m = self.cache_misses.get() as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

/// A mounted HFS namespace on one node.
pub struct HyperFs {
    store: StoreHandle,
    ns: String,
    manifest: Arc<FsManifest>,
    cache: ChunkCache,
    prefetcher: Prefetcher,
    /// Readahead worker pool; `None` in synchronous mode (virtual-time
    /// benches where overlap is accounted analytically), so sim-mode
    /// mounts spawn no threads at all.
    fetch_pool: Option<Arc<FetchPool>>,
    inflight: Arc<SingleFlight>,
    pub stats: HyperFsStats,
}

impl HyperFs {
    /// Mount namespace `ns` from `store` with a cache of `cache_bytes`.
    pub fn mount(store: StoreHandle, ns: &str, cache_bytes: u64) -> Result<Self> {
        Self::mount_with(store, ns, cache_bytes, PrefetchPolicy::default(), true)
    }

    pub fn mount_with(
        store: StoreHandle,
        ns: &str,
        cache_bytes: u64,
        policy: PrefetchPolicy,
        background_prefetch: bool,
    ) -> Result<Self> {
        let manifest_bytes = store
            .get(&FsManifest::manifest_key(ns))
            .map_err(|_| Error::Storage(format!("namespace {ns:?} has no manifest")))?;
        let manifest = Arc::new(FsManifest::from_json(&manifest_bytes)?);
        // size shards to the namespace's actual chunks so the largest
        // chunk always fits one shard's slice of the budget
        let max_chunk = manifest
            .chunks
            .iter()
            .map(|c| c.len)
            .max()
            .unwrap_or(manifest.chunk_size)
            .max(1);
        let fetch_pool = background_prefetch
            .then(|| Arc::new(FetchPool::new(store.clone(), PREFETCH_LANES)));
        Ok(Self {
            store,
            ns: ns.to_string(),
            manifest,
            cache: ChunkCache::with_chunk_hint(cache_bytes, max_chunk),
            prefetcher: Prefetcher::new(policy),
            fetch_pool,
            inflight: Arc::new(SingleFlight::new()),
            stats: HyperFsStats::default(),
        })
    }

    pub fn manifest(&self) -> &FsManifest {
        &self.manifest
    }

    pub fn namespace(&self) -> &str {
        &self.ns
    }

    /// Read a whole file by path (the POSIX open+read+close analogue).
    ///
    /// Returns a zero-copy [`ByteView`] into the cached chunk: on a cache
    /// hit this is one shard lock and one `Arc` clone — no allocation, no
    /// memcpy. Call `.to_vec()` on the view if owned bytes are needed.
    pub fn read_file(&self, path: &str) -> Result<ByteView> {
        let idx = self.manifest.find(path)?;
        let entry = &self.manifest.files[idx];
        self.stats.reads.inc();
        self.stats.bytes_read.add(entry.len);

        let chunk = self.chunk_data(entry.chunk)?;
        // fire readahead for the predicted next chunks
        for target in self
            .prefetcher
            .on_access(entry.chunk, self.manifest.chunks.len() as u32)
        {
            self.issue_prefetch(target);
        }
        Ok(ByteView::new(chunk, entry.offset as usize, entry.len as usize))
    }

    /// File size without fetching data.
    pub fn stat(&self, path: &str) -> Result<u64> {
        Ok(self.manifest.files[self.manifest.find(path)?].len)
    }

    /// Paths under a prefix.
    pub fn list(&self, prefix: &str) -> Vec<String> {
        self.manifest.list(prefix).into_iter().map(|f| f.path.clone()).collect()
    }

    /// Chunk bytes via cache, coalescing concurrent misses of the same
    /// chunk into exactly one backend GET.
    fn chunk_data(&self, id: u32) -> Result<ChunkData> {
        if let Some(hit) = self.cache.get(id) {
            self.stats.cache_hits.inc();
            return Ok(hit);
        }
        self.stats.cache_misses.inc();
        let (outcome, leader) = self.inflight.run(id, || self.fetch_into_cache(id));
        if !leader {
            self.stats.coalesced_reads.inc();
        }
        outcome.map_err(from_fetch_error)
    }

    /// Leader path of a single-flight fetch: re-check the cache (the
    /// chunk may have landed between our miss and winning leadership),
    /// then GET and insert *before* the flight retires, so "no cache
    /// entry and no flight" always implies "no fetch outstanding".
    fn fetch_into_cache(&self, id: u32) -> std::result::Result<ChunkData, FetchError> {
        if let Some(hit) = self.cache.get(id) {
            // raced with a completed fetch: served without our own GET
            self.stats.coalesced_reads.inc();
            return Ok(hit);
        }
        self.stats.backend_gets.inc();
        let data = self
            .store
            .get(&FsManifest::chunk_key(&self.ns, id))
            .map(Arc::new)
            .map_err(to_fetch_error)?;
        self.cache.insert(id, data.clone());
        Ok(data)
    }

    fn issue_prefetch(&self, id: u32) {
        if self.cache.contains(id) {
            self.prefetcher.complete(id);
            return;
        }
        self.stats.prefetch_issued.inc();
        let store = self.store.clone();
        let cache = self.cache.clone();
        let inflight = self.inflight.clone();
        let prefetcher = self.prefetcher.clone();
        let key = FsManifest::chunk_key(&self.ns, id);
        let hits = self.stats.prefetch_hits.clone();
        let gets = self.stats.backend_gets.clone();
        let work = move || {
            // skip without waiting if a reader is already fetching it
            if !cache.contains(id) {
                let _ = inflight.run_if_absent(id, || {
                    // re-check under flight ownership: a reader may have
                    // cached it between our contains() and leading. The
                    // insert also happens inside the flight, upholding the
                    // "no cache entry + no flight => no fetch outstanding"
                    // invariant for prefetched chunks too.
                    if let Some(hit) = cache.get(id) {
                        return Ok(hit);
                    }
                    gets.inc();
                    let data = store.get(&key).map(Arc::new).map_err(to_fetch_error)?;
                    cache.insert(id, data.clone());
                    hits.inc();
                    Ok(data)
                });
            }
            // queued-or-in-flight marker is now stale either way
            prefetcher.complete(id);
        };
        match &self.fetch_pool {
            Some(pool) => {
                if !pool.try_submit(Box::new(work)) {
                    self.stats.prefetch_dropped.inc();
                    self.prefetcher.complete(id);
                }
            }
            None => work(),
        }
    }

    /// Expose the cache for tests / warm-start scenarios.
    pub fn cache(&self) -> &ChunkCache {
        &self.cache
    }

    /// Chunk fetches currently in flight (misses + readahead).
    pub fn in_flight(&self) -> i64 {
        self.inflight.in_flight()
    }

    /// Drop all cached chunks and forget prefetch state together, so the
    /// predictor cannot suppress re-prefetch of evicted chunks.
    pub fn clear_cache(&self) {
        self.cache.clear();
        self.prefetcher.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hfs::Uploader;
    use crate::storage::{CountingStore, MemStore};

    fn setup(n_files: usize, file_size: usize, chunk_size: u64) -> (StoreHandle, Vec<String>) {
        let store: StoreHandle = Arc::new(MemStore::new());
        let mut up = Uploader::new(store.clone(), "ds", chunk_size);
        let mut paths = Vec::new();
        for i in 0..n_files {
            let path = format!("data/{i:05}.bin");
            up.add_file(&path, &vec![(i % 251) as u8; file_size]).unwrap();
            paths.push(path);
        }
        up.seal().unwrap();
        (store, paths)
    }

    #[test]
    fn read_roundtrip() {
        let (store, paths) = setup(10, 100, 350);
        let fs = HyperFs::mount(store, "ds", 10 << 20).unwrap();
        for (i, p) in paths.iter().enumerate() {
            assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 100]);
        }
        assert_eq!(fs.stats.reads.get(), 10);
    }

    #[test]
    fn sequential_reads_hit_cache_within_chunk() {
        // 3 files per chunk -> at least 2/3 of reads are cache hits
        let (store, paths) = setup(30, 100, 300);
        let fs = HyperFs::mount_with(
            store,
            "ds",
            10 << 20,
            PrefetchPolicy { depth: 0 },
            false,
        )
        .unwrap();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        assert_eq!(fs.stats.cache_misses.get(), 10); // one per chunk
        assert_eq!(fs.stats.cache_hits.get(), 20);
        assert_eq!(fs.stats.backend_gets.get(), 10);
    }

    #[test]
    fn prefetch_warms_next_chunk_synchronously() {
        let (store, paths) = setup(30, 100, 300);
        let fs = HyperFs::mount_with(
            store,
            "ds",
            10 << 20,
            PrefetchPolicy { depth: 1 },
            false, // synchronous prefetch for determinism
        )
        .unwrap();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        // after the run is sequential, every later chunk came from readahead
        assert!(fs.stats.prefetch_issued.get() >= 7, "{:?}", fs.stats);
        assert!(fs.stats.cache_misses.get() <= 3, "{:?}", fs.stats);
    }

    #[test]
    fn stat_and_list() {
        let (store, _) = setup(5, 42, 1000);
        let fs = HyperFs::mount(store, "ds", 1 << 20).unwrap();
        assert_eq!(fs.stat("data/00003.bin").unwrap(), 42);
        assert_eq!(fs.list("data/").len(), 5);
        assert_eq!(fs.list("nope/").len(), 0);
        assert!(fs.stat("missing").is_err());
    }

    #[test]
    fn missing_namespace_fails_to_mount() {
        let store: StoreHandle = Arc::new(MemStore::new());
        assert!(HyperFs::mount(store, "ghost", 1 << 20).is_err());
    }

    #[test]
    fn tiny_cache_still_correct() {
        let (store, paths) = setup(20, 100, 300);
        let fs = HyperFs::mount_with(store, "ds", 300, PrefetchPolicy { depth: 0 }, false)
            .unwrap();
        for (i, p) in paths.iter().enumerate() {
            assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 100]);
        }
    }

    #[test]
    fn cache_hit_reads_share_one_allocation() {
        let (store, paths) = setup(6, 64, 400);
        let fs = HyperFs::mount_with(store, "ds", 1 << 20, PrefetchPolicy { depth: 0 }, false)
            .unwrap();
        let a = fs.read_file(&paths[0]).unwrap();
        let b = fs.read_file(&paths[1]).unwrap(); // same chunk, different file
        assert!(
            Arc::ptr_eq(a.chunk(), b.chunk()),
            "views into one chunk must share the cached allocation"
        );
        assert_ne!(&a[..], &b[..]);
    }

    #[test]
    fn view_survives_eviction() {
        // a ByteView handed out must stay valid even after the cache
        // evicts its chunk (the Arc keeps the payload alive)
        let (store, paths) = setup(20, 100, 300);
        let fs = HyperFs::mount_with(store, "ds", 300, PrefetchPolicy { depth: 0 }, false)
            .unwrap();
        let first = fs.read_file(&paths[0]).unwrap();
        for p in &paths {
            fs.read_file(p).unwrap(); // thrashes the 1-chunk cache
        }
        assert_eq!(first, vec![0u8; 100]);
    }

    #[test]
    fn clear_cache_resets_prefetch_state_too() {
        let (store, paths) = setup(30, 100, 300);
        let fs = HyperFs::mount_with(store, "ds", 10 << 20, PrefetchPolicy { depth: 2 }, false)
            .unwrap();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        fs.clear_cache();
        assert!(fs.cache().is_empty());
        // a second epoch re-prefetches instead of being suppressed by
        // stale pending state
        let issued_before = fs.stats.prefetch_issued.get();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        assert!(
            fs.stats.prefetch_issued.get() > issued_before,
            "second epoch must prefetch again: {:?}",
            fs.stats
        );
    }

    #[test]
    fn concurrent_cold_reads_issue_one_get_per_chunk() {
        // 32 threads cold-read files that all live in one chunk: the
        // single-flight table must collapse them into exactly 1 GET
        let (inner, paths) = setup(8, 100, 8 * 100);
        let counting = Arc::new(CountingStore::new(inner));
        let store: StoreHandle = counting.clone();
        let fs = Arc::new(
            HyperFs::mount_with(store, "ds", 10 << 20, PrefetchPolicy { depth: 0 }, false)
                .unwrap(),
        );
        let barrier = Arc::new(std::sync::Barrier::new(32));
        std::thread::scope(|s| {
            for t in 0..32usize {
                let fs = fs.clone();
                let paths = paths.clone();
                let barrier = barrier.clone();
                s.spawn(move || {
                    barrier.wait();
                    let p = &paths[t % paths.len()];
                    let expect = vec![((t % paths.len()) % 251) as u8; 100];
                    assert_eq!(fs.read_file(p).unwrap(), expect);
                });
            }
        });
        assert_eq!(
            counting.gets_for(&FsManifest::chunk_key("ds", 0)),
            1,
            "thundering herd must coalesce to one backend GET"
        );
        assert_eq!(fs.stats.backend_gets.get(), 1);
        assert_eq!(
            fs.stats.cache_misses.get(),
            fs.stats.backend_gets.get() + fs.stats.coalesced_reads.get(),
            "every miss either led or coalesced"
        );
    }
}
