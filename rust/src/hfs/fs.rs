//! [`HyperFs`]: the mounted read layer of the Hyper File System.
//!
//! "Within the program's context, files that are stored in remote chunked
//! object storage appear to be local files" (§III.A). `read_file` is the
//! POSIX-read analogue, rebuilt for throughput under many concurrent
//! readers:
//!
//! * **Zero-copy** — reads return a [`ByteView`] into the cached chunk:
//!   a cache hit does no allocation and no memcpy.
//! * **Lazy sharded metadata** — a format-2 namespace mounts by parsing
//!   only the small root manifest; per-range file-table shards and the
//!   chunk table load on first touch (single-flighted behind an
//!   `RwLock`, then cached for the life of the mount), so mount cost
//!   scales with the shards a workload actually touches, not with the
//!   file count. Legacy monolithic manifests still mount, with an O(1)
//!   path index built at parse time.
//! * **Content-addressed tiers** — the RAM cache, spill tier, and
//!   single-flight table key chunks by content digest, so chunks with
//!   identical bytes share one cached copy and one fetch regardless of
//!   chunk id; on CAS-layout namespaces the backend object key is the
//!   digest too (`cas/chunks/…`). Manifests that predate digests fall
//!   back to `(ns, id)` keying.
//! * **Sharded cache** — the RAM LRU is sharded by content key with O(1)
//!   get/insert/evict, so readers of different chunks never contend on
//!   one mutex.
//! * **Disk spill tier** — RAM evictions flow down into a bounded
//!   on-disk [`SpillTier`] (when mounted with a spill directory) instead
//!   of being dropped; a later miss promotes the chunk back into RAM
//!   without touching the object store. Spill writes run on the fetch
//!   lanes so they never block readers, and spill hits can be served as
//!   digest-verified mmap views instead of read copies.
//! * **Single-flight** — concurrent misses (and prefetches) of the same
//!   content coalesce into exactly one load, whether it comes from the
//!   spill tier or the backend.
//! * **Adaptive, bounded readahead** — prefetch depth follows the
//!   observed access pattern (deep on scans, zero under shuffle; the
//!   config knob is only a cap); jobs run on the shared [`FetchPool`]
//!   worker lanes instead of one spawned thread per chunk, and are
//!   dropped (not queued unboundedly) when the lanes are saturated.
//! * **Range-GET fast path** — a cold, non-sequential read of a file much
//!   smaller than its chunk (`len * 4 < chunk_len`) fetches only the
//!   file's byte range; whole-chunk fetching (and its cache/prefetch
//!   locality) is reserved for scans — and for packed archive chunks,
//!   whose many tiny members make the whole archive the right transfer
//!   unit.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::config::HfsConfig;
use crate::metrics::{Counter, MetricsRegistry};
use crate::obs::{self, FlightRecorder};
use crate::storage::StoreHandle;
use crate::util::Json;
use crate::{Error, Result};

use super::cache::ChunkCache;
use super::chunk::{
    cas_chunk_key, chunk_table_from_json, fnv1a64, shard_from_json, ChunkRef, FileEntry,
    FsManifest, PathIndex, RootManifest, SHARDED_FORMAT,
};
use super::fetch::FetchPool;
use super::prefetch::{PrefetchPolicy, Prefetcher};
use super::singleflight::{FetchError, SingleFlight};
use super::spill::SpillTier;
use super::view::{ByteView, ChunkBytes, ChunkData};

/// Preserve the not-found / storage distinction across the cloneable
/// single-flight boundary.
fn to_fetch_error(e: Error) -> FetchError {
    match e {
        Error::NotFound(s) => FetchError::NotFound(s),
        other => FetchError::Storage(other.to_string()),
    }
}

fn from_fetch_error(e: FetchError) -> Error {
    match e {
        FetchError::NotFound(s) => Error::NotFound(s),
        FetchError::Storage(s) => Error::Storage(s),
    }
}

/// Two-tier admission shared by the demand and prefetch paths: insert
/// into the RAM tier, then route every eviction victim — and, when
/// `respill_self` is set, the chunk itself if the RAM tier cannot hold
/// it — down to the spill tier via `spill_write`. Callers pass
/// `respill_self: false` when the data was just read *from* the spill
/// tier: it is already on disk with fresh recency, and re-putting it
/// would only re-hash the payload to discover that. How the write
/// executes (pooled job vs inline on the current fetch lane) is the
/// caller's choice; the policy lives here so the paths cannot drift.
fn admit_two_tier(
    cache: &ChunkCache,
    spill: Option<&Arc<SpillTier>>,
    key: u64,
    data: &ChunkData,
    respill_self: bool,
    mut spill_write: impl FnMut(&Arc<SpillTier>, u64, ChunkData),
) {
    let evicted = cache.insert_evicting(key, data.clone());
    let Some(spill) = spill else { return };
    for (ekey, edata) in evicted {
        spill_write(spill, ekey, edata);
    }
    if respill_self && !cache.contains(key) {
        spill_write(spill, key, data.clone());
    }
}

/// Content key used by the RAM cache, spill tier, and single-flight
/// table: the chunk's content digest when the manifest records one
/// (identical bytes then share one entry across chunk ids), else a hash
/// of `(ns, id)` so pre-digest manifests still key uniquely.
fn tier_key(ns: &str, id: u32, hash: u64) -> u64 {
    if hash != 0 {
        hash
    } else {
        fnv1a64(format!("{ns}/{id}").as_bytes())
    }
}

/// Worker lanes of the per-mount readahead pool.
const PREFETCH_LANES: usize = 4;

/// Range-GET fast path threshold: a cold, *non-sequential* read of a file
/// more than this many times smaller than its chunk fetches just the
/// file's byte range instead of the whole chunk. Sequential scans keep the
/// whole-chunk fetch (neighbors will want the rest of the chunk, and the
/// prefetcher amortizes it); isolated small reads stop paying a
/// chunk-sized transfer for a file-sized answer.
const RANGE_GET_RATIO: u64 = 4;

/// After this many range-GET serves from one chunk, the next small read
/// *invests* in the whole chunk (fetch + cache) instead — repeated random
/// access over the same chunk (e.g. shuffled epochs) must converge to
/// cache hits, not re-transfer the dataset per epoch. Promotion only
/// happens when the cache could plausibly retain the chunk.
const RANGE_PROMOTE_AFTER: u32 = 2;

/// One lazily-loaded slice of the sharded file table, with its O(1)
/// path index (built once, at load).
struct ShardTable {
    files: Vec<FileEntry>,
    index: PathIndex,
}

/// The mount's metadata plane: either the whole legacy manifest held in
/// RAM, or a sharded root whose file shards and chunk table fill in on
/// demand.
enum Table {
    Legacy {
        manifest: Arc<FsManifest>,
        index: PathIndex,
    },
    Sharded {
        root: RootManifest,
        shards: Vec<RwLock<Option<Arc<ShardTable>>>>,
        chunk_table: RwLock<Option<Arc<Vec<ChunkRef>>>>,
    },
}

/// A path resolved against the metadata plane — everything `read_file`
/// needs, copied out so no shard lock is held across data I/O.
#[derive(Clone, Copy)]
struct ResolvedFile {
    chunk: u32,
    offset: u64,
    len: u64,
    /// Distinguishes files for the range-GET single-flight table: the
    /// global file index (legacy) or `(shard << 32) | index-in-shard`.
    file_key: u64,
}

/// Counters exposed for tests / benches / the CLI `status` view.
#[derive(Debug, Clone, Default)]
pub struct HyperFsStats {
    /// `read_file` calls.
    pub reads: Counter,
    /// Reads served from the RAM chunk cache.
    pub cache_hits: Counter,
    /// Reads that missed the RAM tier (spill hits still count as misses
    /// here; see [`HyperFsStats::spill_hits`]).
    pub cache_misses: Counter,
    /// Readahead jobs handed to the fetch lanes.
    pub prefetch_issued: Counter,
    /// Prefetched chunks that landed in the cache.
    pub prefetch_hits: Counter,
    /// Payload bytes returned to readers.
    pub bytes_read: Counter,
    /// Actual GETs issued to the backing store (per-chunk, post-coalescing).
    pub backend_gets: Counter,
    /// Misses that piggybacked on another reader's in-flight GET.
    pub coalesced_reads: Counter,
    /// Readahead jobs dropped because the fetch lanes were saturated.
    pub prefetch_dropped: Counter,
    /// Cold non-sequential small-file reads served by `get_range` instead
    /// of a whole-chunk fetch.
    pub range_gets: Counter,
    /// Bytes those range GETs transferred (vs. the chunk bytes they avoided).
    pub range_bytes: Counter,
    /// RAM misses served from the local-disk spill tier — each one is a
    /// backend GET (and a chunk of network transfer) that never happened.
    pub spill_hits: Counter,
    /// RAM misses that also missed the spill tier and went to the store.
    pub spill_misses: Counter,
    /// Eviction write jobs executed against the spill tier.
    pub spill_writes: Counter,
    /// Eviction writes dropped because the fetch lanes were saturated
    /// (the chunk is simply not spilled; a future miss refetches).
    pub spill_drops: Counter,
    /// Lazy metadata loads on a sharded mount — file-table shards plus
    /// the chunk table, each counted once when first fetched and parsed.
    /// A legacy mount never increments this.
    pub shard_loads: Counter,
    /// First-touch reads of a chunk served from RAM because a chunk with
    /// identical bytes (same content digest) was already cached — backend
    /// GETs that content-addressed dedup made unnecessary.
    pub dedup_hits: Counter,
    /// Reads of files stored inside packed archive chunks.
    pub packed_reads: Counter,
}

impl HyperFsStats {
    /// RAM-tier hit rate over all reads so far (0 before any read).
    pub fn hit_rate(&self) -> f64 {
        let h = self.cache_hits.get() as f64;
        let m = self.cache_misses.get() as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

/// A mounted HFS namespace on one node.
pub struct HyperFs {
    store: StoreHandle,
    ns: String,
    table: Table,
    /// One flag per chunk id: set on the chunk's first demand access (or
    /// successful prefetch). A *first* touch that is already a RAM hit
    /// means another chunk with identical bytes paid the fetch — that is
    /// what [`HyperFsStats::dedup_hits`] counts.
    touched: Arc<Vec<AtomicBool>>,
    cache: ChunkCache,
    cache_bytes: u64,
    /// Local-disk second tier; `None` on diskless mounts.
    spill: Option<Arc<SpillTier>>,
    prefetcher: Prefetcher,
    /// Readahead worker pool; `None` in synchronous mode (virtual-time
    /// benches where overlap is accounted analytically), so sim-mode
    /// mounts spawn no threads at all.
    fetch_pool: Option<Arc<FetchPool>>,
    inflight: Arc<SingleFlight>,
    /// Single-flight table for the range-GET fast path, keyed by *file*
    /// (different files of one chunk fetch independently; identical
    /// files coalesce).
    range_inflight: Arc<SingleFlight>,
    /// Range-GET serves per chunk since its last whole fetch (promotion
    /// counter for the fast path).
    range_served: Mutex<HashMap<u32, u32>>,
    /// Read-path counters (cheap to clone; shared with fetch workers).
    pub stats: HyperFsStats,
    /// Flight recorder for read-path spans (disabled unless attached
    /// with [`HyperFs::set_obs`] before the mount is shared).
    obs: FlightRecorder,
}

impl HyperFs {
    /// Mount namespace `ns` from `store` with a RAM cache of
    /// `cache_bytes` and default policy (adaptive prefetch, no spill).
    pub fn mount(store: StoreHandle, ns: &str, cache_bytes: u64) -> Result<Self> {
        Self::mount_with(store, ns, cache_bytes, PrefetchPolicy::default(), true)
    }

    /// Mount with an explicit prefetch cap and threading mode (no spill
    /// tier). `background_prefetch: false` runs all readahead inline —
    /// deterministic for tests and virtual-time benches.
    pub fn mount_with(
        store: StoreHandle,
        ns: &str,
        cache_bytes: u64,
        policy: PrefetchPolicy,
        background_prefetch: bool,
    ) -> Result<Self> {
        Self::mount_inner(store, ns, cache_bytes, policy, background_prefetch, None)
    }

    /// Mount with the full [`HfsConfig`] surface, including the
    /// local-disk spill tier (with optional mmap reads) and the
    /// adaptive-prefetch cap.
    pub fn mount_cfg(store: StoreHandle, ns: &str, cfg: &HfsConfig) -> Result<Self> {
        let spill = match &cfg.spill_dir {
            Some(dir) => {
                Some(Arc::new(SpillTier::open_with(dir, ns, cfg.spill_bytes, cfg.spill_mmap)?))
            }
            None => None,
        };
        Self::mount_inner(
            store,
            ns,
            cfg.cache_bytes,
            PrefetchPolicy { max_depth: cfg.prefetch_max_depth },
            cfg.background_prefetch,
            spill,
        )
    }

    fn mount_inner(
        store: StoreHandle,
        ns: &str,
        cache_bytes: u64,
        policy: PrefetchPolicy,
        background_prefetch: bool,
        spill: Option<Arc<SpillTier>>,
    ) -> Result<Self> {
        let manifest_bytes = store
            .get(&FsManifest::manifest_key(ns))
            .map_err(|_| Error::Storage(format!("namespace {ns:?} has no manifest")))?;
        // format >= 2 -> sharded root manifest; anything else (including
        // format-less pre-sharding manifests) -> legacy monolithic
        let sharded = Json::parse_bytes(&manifest_bytes)
            .ok()
            .and_then(|v| v.get("format").and_then(Json::as_u64))
            .is_some_and(|f| f >= SHARDED_FORMAT);
        let table = if sharded {
            let root = RootManifest::from_json(&manifest_bytes)?;
            let shards = (0..root.shards.len()).map(|_| RwLock::new(None)).collect();
            Table::Sharded { root, shards, chunk_table: RwLock::new(None) }
        } else {
            let manifest = Arc::new(FsManifest::from_json(&manifest_bytes)?);
            let index = PathIndex::build(&manifest.files);
            Table::Legacy { manifest, index }
        };
        // size cache shards so the largest chunk always fits one shard's
        // slice of the budget; the sharded root records the max up front
        // precisely so this works without loading the chunk table
        let max_chunk = match &table {
            Table::Legacy { manifest, .. } => {
                manifest.chunks.iter().map(|c| c.len).max().unwrap_or(manifest.chunk_size)
            }
            Table::Sharded { root, .. } => {
                if root.max_chunk_len > 0 {
                    root.max_chunk_len
                } else {
                    root.chunk_size
                }
            }
        }
        .max(1);
        let chunk_count = match &table {
            Table::Legacy { manifest, .. } => manifest.chunks.len(),
            Table::Sharded { root, .. } => root.chunk_count as usize,
        };
        let touched = Arc::new((0..chunk_count).map(|_| AtomicBool::new(false)).collect());
        let fetch_pool =
            background_prefetch.then(|| Arc::new(FetchPool::new(store.clone(), PREFETCH_LANES)));
        Ok(Self {
            store,
            ns: ns.to_string(),
            table,
            touched,
            cache: ChunkCache::with_chunk_hint(cache_bytes, max_chunk),
            cache_bytes,
            spill,
            prefetcher: Prefetcher::new(policy),
            fetch_pool,
            inflight: Arc::new(SingleFlight::new()),
            range_inflight: Arc::new(SingleFlight::new()),
            range_served: Mutex::new(HashMap::new()),
            stats: HyperFsStats::default(),
            obs: FlightRecorder::disabled(),
        })
    }

    /// Attach a flight recorder (before sharing the mount): reads record
    /// `hfs.read` spans tagged with the serving tier, plus shard loads,
    /// single-flight waits, spill promotes, backend GETs and range-GETs.
    /// One track per reader thread (pid 0, tid = [`obs::thread_tid`]).
    pub fn set_obs(&mut self, obs: FlightRecorder) {
        self.obs = obs;
    }

    /// The monolithic manifest behind a legacy mount. `None` on sharded
    /// mounts, whose file table lives in lazily-loaded shards instead.
    pub fn manifest(&self) -> Option<&FsManifest> {
        match &self.table {
            Table::Legacy { manifest, .. } => Some(manifest),
            Table::Sharded { .. } => None,
        }
    }

    /// The namespace name this mount serves.
    pub fn namespace(&self) -> &str {
        &self.ns
    }

    /// Whether this mount serves a sharded (format 2) namespace.
    pub fn is_sharded(&self) -> bool {
        matches!(self.table, Table::Sharded { .. })
    }

    /// Chunks in the namespace (root-recorded on sharded mounts, so no
    /// chunk-table load is needed to answer).
    pub fn chunk_count(&self) -> usize {
        match &self.table {
            Table::Legacy { manifest, .. } => manifest.chunks.len(),
            Table::Sharded { root, .. } => root.chunk_count as usize,
        }
    }

    /// Files in the namespace.
    pub fn file_count(&self) -> u64 {
        match &self.table {
            Table::Legacy { manifest, .. } => manifest.file_count() as u64,
            Table::Sharded { root, .. } => root.file_count,
        }
    }

    /// Total payload bytes across all files.
    pub fn total_bytes(&self) -> u64 {
        match &self.table {
            Table::Legacy { manifest, .. } => manifest.total_bytes(),
            Table::Sharded { root, .. } => root.total_bytes,
        }
    }

    /// Does this mount's layout store chunks under content-addressed
    /// keys? (Sharded namespaces written by the current uploader do;
    /// legacy namespaces keep `<ns>/chunks/<id>` objects.)
    fn content_addressed(&self) -> bool {
        match &self.table {
            Table::Legacy { .. } => false,
            Table::Sharded { root, .. } => root.content_addressed,
        }
    }

    /// Target chunk size the namespace was packed with.
    fn chunk_size(&self) -> u64 {
        match &self.table {
            Table::Legacy { manifest, .. } => manifest.chunk_size,
            Table::Sharded { root, .. } => root.chunk_size,
        }
    }

    /// Backend object key of chunk `id`: content-addressed on CAS-layout
    /// namespaces, namespace-scoped otherwise. On sharded mounts this
    /// loads the chunk table if it is not resident yet.
    pub fn chunk_object_key(&self, id: u32) -> Result<String> {
        let (_, hash, _) = self.chunk_meta(id)?;
        Ok(self.object_key(id, hash))
    }

    fn object_key(&self, id: u32, hash: u64) -> String {
        if self.content_addressed() && hash != 0 {
            cas_chunk_key(hash)
        } else {
            FsManifest::chunk_key(&self.ns, id)
        }
    }

    /// Load (or fetch from the resident copy) file-table shard `i`.
    /// Holding the slot's write lock across the store GET single-flights
    /// concurrent loads of the same shard.
    fn load_shard(&self, i: usize) -> Result<Arc<ShardTable>> {
        let Table::Sharded { shards, .. } = &self.table else {
            return Err(Error::Storage("legacy mounts have no file-table shards".into()));
        };
        if let Some(t) = shards[i].read().unwrap().as_ref() {
            return Ok(t.clone());
        }
        let mut slot = shards[i].write().unwrap();
        if let Some(t) = slot.as_ref() {
            return Ok(t.clone());
        }
        let _load_span = self.obs.is_enabled().then(|| {
            self.obs.span("hfs.shard_load", 0, obs::thread_tid(), vec![("shard", i.into())])
        });
        let bytes = self.store.get(&RootManifest::shard_key(&self.ns, i))?;
        let files = shard_from_json(&bytes)?;
        let index = PathIndex::build(&files);
        let table = Arc::new(ShardTable { files, index });
        self.stats.shard_loads.inc();
        *slot = Some(table.clone());
        Ok(table)
    }

    /// The chunk table of a sharded mount, loaded on first use (same
    /// write-lock single-flighting as [`HyperFs::load_shard`]).
    fn chunk_table(&self) -> Result<Arc<Vec<ChunkRef>>> {
        let Table::Sharded { chunk_table, .. } = &self.table else {
            return Err(Error::Storage("legacy mounts have no separate chunk table".into()));
        };
        if let Some(t) = chunk_table.read().unwrap().as_ref() {
            return Ok(t.clone());
        }
        let mut slot = chunk_table.write().unwrap();
        if let Some(t) = slot.as_ref() {
            return Ok(t.clone());
        }
        let bytes = self.store.get(&RootManifest::chunk_table_key(&self.ns))?;
        let table = Arc::new(chunk_table_from_json(&bytes)?);
        self.stats.shard_loads.inc();
        *slot = Some(table.clone());
        Ok(table)
    }

    /// Manifest-recorded `(len, digest, packed)` of chunk `id` (ids the
    /// manifest does not know fall back to the namespace chunk size and
    /// an unknown digest, so spill reads skip the digest check).
    fn chunk_meta(&self, id: u32) -> Result<(u64, u64, bool)> {
        match &self.table {
            Table::Legacy { manifest, .. } => Ok(manifest
                .chunks
                .get(id as usize)
                .map_or((manifest.chunk_size, 0, false), |c| (c.len, c.hash, c.packed))),
            Table::Sharded { .. } => {
                let table = self.chunk_table()?;
                Ok(table
                    .get(id as usize)
                    .map_or((self.chunk_size(), 0, false), |c| (c.len, c.hash, c.packed)))
            }
        }
    }

    /// Resolve a path to its chunk coordinates through the metadata
    /// plane, loading at most one file-table shard.
    fn resolve(&self, path: &str) -> Result<ResolvedFile> {
        match &self.table {
            Table::Legacy { manifest, index } => {
                let idx = index
                    .find(&manifest.files, path)
                    .ok_or_else(|| Error::FileNotFound(path.to_string()))?;
                let e = &manifest.files[idx];
                Ok(ResolvedFile {
                    chunk: e.chunk,
                    offset: e.offset,
                    len: e.len,
                    file_key: idx as u64,
                })
            }
            Table::Sharded { root, .. } => {
                let si = root
                    .shard_for(path)
                    .ok_or_else(|| Error::FileNotFound(path.to_string()))?;
                let shard = self.load_shard(si)?;
                let idx = shard
                    .index
                    .find(&shard.files, path)
                    .ok_or_else(|| Error::FileNotFound(path.to_string()))?;
                let e = &shard.files[idx];
                Ok(ResolvedFile {
                    chunk: e.chunk,
                    offset: e.offset,
                    len: e.len,
                    file_key: ((si as u64) << 32) | idx as u64,
                })
            }
        }
    }

    /// Mark chunk `id` as accessed; returns whether this was the first
    /// touch since mount. Unknown ids never count as first touches.
    fn mark_touched(&self, id: u32) -> bool {
        self.touched
            .get(id as usize)
            .map(|t| !t.swap(true, Ordering::Relaxed))
            .unwrap_or(false)
    }

    /// Does the spill tier hold a (possibly unverified) copy of `key`?
    fn spill_contains(&self, key: u64) -> bool {
        self.spill.as_ref().is_some_and(|s| s.contains(key))
    }

    /// Read a whole file by path (the POSIX open+read+close analogue).
    ///
    /// Returns a zero-copy [`ByteView`] into the cached chunk: on a cache
    /// hit this is one shard lock and one `Arc` clone — no allocation, no
    /// memcpy. Call `.to_vec()` on the view if owned bytes are needed.
    pub fn read_file(&self, path: &str) -> Result<ByteView> {
        let mut read_span = self
            .obs
            .is_enabled()
            .then(|| self.obs.span("hfs.read", 0, obs::thread_tid(), vec![]));
        let f = self.resolve(path)?;
        if let Some(s) = read_span.as_mut() {
            s.arg("chunk", f.chunk);
            s.arg("bytes", f.len);
        }
        self.stats.reads.inc();
        self.stats.bytes_read.add(f.len);
        let (chunk_len, chunk_hash, packed) = self.chunk_meta(f.chunk)?;
        if packed {
            self.stats.packed_reads.inc();
        }
        let key = tier_key(&self.ns, f.chunk, chunk_hash);

        // Range-GET fast path: a cold read of a small file during a
        // non-sequential access pattern fetches just the file's bytes.
        // The result is NOT cached (the cache stores whole chunks), so
        // after RANGE_PROMOTE_AFTER range serves a chunk is *promoted* —
        // the next small read falls through to the cached whole-chunk
        // path, so repeated random access (shuffled epochs) converges to
        // cache hits instead of re-transferring the dataset each epoch.
        // Promotion is skipped when the cache could not plausibly retain
        // the chunk anyway (thrashing budgets keep ranging: strictly
        // fewer bytes). Concurrent readers of the SAME file coalesce
        // through their own single-flight table. Packed archive chunks
        // never range: every member is tiny, so the archive itself is
        // the right transfer + cache unit.
        // Guard order matters: the sharded cache probe short-circuits the
        // global prefetcher mutex away from every cache-hit read. A chunk
        // already sitting in the local-disk spill tier is never "cold"
        // enough to range-GET: the whole-chunk path below serves it from
        // disk for free instead of paying an object-store round trip.
        if !packed
            && f.len.saturating_mul(RANGE_GET_RATIO) < chunk_len
            && !self.cache.contains(key)
            && !self.spill_contains(key)
            && !self.prefetcher.is_sequential()
        {
            let retainable = chunk_len.saturating_mul(4) <= self.cache_bytes;
            let promote = retainable && {
                let mut served = self.range_served.lock().unwrap();
                let n = served.entry(f.chunk).or_insert(0);
                if *n >= RANGE_PROMOTE_AFTER {
                    served.remove(&f.chunk);
                    true // invest: whole-chunk fetch + cache below
                } else {
                    *n += 1;
                    false
                }
            };
            if !promote {
                let obj_key = self.object_key(f.chunk, chunk_hash);
                let (offset, len) = (f.offset, f.len);
                let (outcome, leader) = self.range_inflight.run(f.file_key, || {
                    let data =
                        self.store.get_range(&obj_key, offset, len).map_err(to_fetch_error)?;
                    if data.len() as u64 != len {
                        return Err(FetchError::Storage(format!(
                            "range GET for {obj_key:?} returned {} bytes, expected {len}",
                            data.len()
                        )));
                    }
                    Ok(Arc::new(ChunkBytes::ram(data)))
                });
                if leader {
                    self.stats.range_gets.inc();
                    self.stats.range_bytes.add(len);
                } else {
                    self.stats.coalesced_reads.inc();
                }
                if let Some(s) = read_span.as_mut() {
                    s.arg("tier", "range_get");
                    s.arg("coalesced", u64::from(!leader));
                }
                self.stats.cache_misses.inc();
                // still feed the predictor: if this turns into a scan,
                // the next reads go back to whole chunks + readahead
                for target in
                    self.prefetcher.on_access(f.chunk, self.chunk_count() as u32, false)
                {
                    self.issue_prefetch(target);
                }
                return Ok(ByteView::full(outcome.map_err(from_fetch_error)?));
            }
        }

        let (chunk, ram_hit) = self.chunk_data(f.chunk, key, chunk_len, chunk_hash)?;
        if let Some(s) = read_span.as_mut() {
            s.arg("tier", if ram_hit { "ram" } else { "fetch" });
        }
        // feed the adaptive predictor and fire readahead for the
        // predicted next chunks
        for target in self.prefetcher.on_access(f.chunk, self.chunk_count() as u32, ram_hit) {
            self.issue_prefetch(target);
        }
        Ok(ByteView::new(chunk, f.offset as usize, f.len as usize))
    }

    /// File size without fetching data.
    pub fn stat(&self, path: &str) -> Result<u64> {
        Ok(self.resolve(path)?.len)
    }

    /// Paths under a prefix. On sharded mounts this loads exactly the
    /// shards whose path range can intersect the prefix.
    pub fn list(&self, prefix: &str) -> Result<Vec<String>> {
        match &self.table {
            Table::Legacy { manifest, .. } => {
                Ok(manifest.list(prefix).into_iter().map(|f| f.path.clone()).collect())
            }
            Table::Sharded { root, shards, .. } => {
                let mut out = Vec::new();
                let s0 = root.shard_for(prefix).unwrap_or(0);
                for i in s0..shards.len() {
                    // shards partition the sorted path space: once a
                    // shard *starts* past the prefix interval, no later
                    // shard can re-enter it
                    if i > s0 && !root.shards[i].start.starts_with(prefix) {
                        break;
                    }
                    let shard = self.load_shard(i)?;
                    let lo = shard.files.partition_point(|f| f.path.as_str() < prefix);
                    for f in shard.files[lo..].iter().take_while(|f| f.path.starts_with(prefix))
                    {
                        out.push(f.path.clone());
                    }
                }
                Ok(out)
            }
        }
    }

    /// Chunk bytes via the cache tiers, coalescing concurrent misses of
    /// the same content into exactly one load. Returns the payload and
    /// whether it was a RAM-tier hit.
    fn chunk_data(
        &self,
        id: u32,
        key: u64,
        expected_len: u64,
        expected_hash: u64,
    ) -> Result<(ChunkData, bool)> {
        let first_touch = self.mark_touched(id);
        if let Some(hit) = self.cache.get(key) {
            self.stats.cache_hits.inc();
            if first_touch {
                // never fetched this chunk, yet its bytes are resident:
                // an identical-content twin paid the transfer
                self.stats.dedup_hits.inc();
            }
            return Ok((hit, true));
        }
        self.stats.cache_misses.inc();
        let (outcome, leader) = self
            .inflight
            .run(key, || self.fetch_into_cache(id, key, expected_len, expected_hash, first_touch));
        if !leader {
            self.stats.coalesced_reads.inc();
            if first_touch {
                self.stats.dedup_hits.inc();
            }
            if self.obs.is_enabled() {
                self.obs.event("hfs.singleflight_wait", 0, obs::thread_tid(), vec![
                    ("chunk", id.into()),
                ]);
            }
        }
        Ok((outcome.map_err(from_fetch_error)?, false))
    }

    /// Leader path of a single-flight fetch: re-check the RAM cache (the
    /// content may have landed between our miss and winning leadership),
    /// probe the spill tier, then GET — and admit *before* the flight
    /// retires, so "no cache entry and no flight" always implies "no
    /// fetch outstanding". The single-flight key covers the disk tier
    /// too: concurrent misses issue at most one spill load.
    fn fetch_into_cache(
        &self,
        id: u32,
        key: u64,
        expected_len: u64,
        expected_hash: u64,
        first_touch: bool,
    ) -> std::result::Result<ChunkData, FetchError> {
        if let Some(hit) = self.cache.get(key) {
            // raced with a completed fetch: served without our own GET
            self.stats.coalesced_reads.inc();
            if first_touch {
                self.stats.dedup_hits.inc();
            }
            return Ok(hit);
        }
        if let Some(spill) = &self.spill {
            if let Some(data) = spill.get(key, expected_len, expected_hash) {
                // promoted back into RAM without touching the object
                // store; no respill — the bytes are already on disk
                self.stats.spill_hits.inc();
                if self.obs.is_enabled() {
                    self.obs.event("hfs.spill_promote", 0, obs::thread_tid(), vec![
                        ("chunk", id.into()),
                        ("bytes", expected_len.into()),
                    ]);
                }
                self.admit(key, &data, false);
                return Ok(data);
            }
            self.stats.spill_misses.inc();
        }
        self.stats.backend_gets.inc();
        let data = {
            let _get_span = self.obs.is_enabled().then(|| {
                self.obs.span("hfs.backend_get", 0, obs::thread_tid(), vec![
                    ("chunk", id.into()),
                ])
            });
            self.store
                .get(&self.object_key(id, expected_hash))
                .map(|v| Arc::new(ChunkBytes::ram(v)))
                .map_err(to_fetch_error)?
        };
        self.admit(key, &data, true);
        Ok(data)
    }

    /// Admit a chunk to the RAM tier. With a spill tier mounted, RAM
    /// victims are demoted to disk (on the fetch lanes, so the reader is
    /// never blocked on spill I/O), and — when `respill_self` is set — a
    /// chunk the RAM tier cannot hold at all is spilled directly, so
    /// repeated reads of an oversized chunk converge to disk speed
    /// instead of network speed.
    fn admit(&self, key: u64, data: &ChunkData, respill_self: bool) {
        admit_two_tier(
            &self.cache,
            self.spill.as_ref(),
            key,
            data,
            respill_self,
            |spill, ekey, edata| self.spill_out(spill, ekey, edata),
        );
    }

    /// Hand one RAM-evicted chunk down to the spill tier: a background
    /// job on the fetch lanes in threaded mode, inline in sync mode.
    /// When the lanes are saturated the write is dropped — spilling is
    /// best-effort and must never apply backpressure to readers.
    fn spill_out(&self, spill: &Arc<SpillTier>, key: u64, data: ChunkData) {
        let spill = spill.clone();
        let writes = self.stats.spill_writes.clone();
        let work = move || {
            writes.inc();
            spill.put(key, &data);
        };
        match &self.fetch_pool {
            Some(pool) => {
                if !pool.try_submit(Box::new(work)) {
                    self.stats.spill_drops.inc();
                }
            }
            None => work(),
        }
    }

    fn issue_prefetch(&self, id: u32) {
        let Ok((expected_len, expected_hash, _)) = self.chunk_meta(id) else {
            self.prefetcher.complete(id);
            return;
        };
        let key = tier_key(&self.ns, id, expected_hash);
        if self.cache.contains(key) {
            self.prefetcher.complete(id);
            return;
        }
        self.stats.prefetch_issued.inc();
        let store = self.store.clone();
        let cache = self.cache.clone();
        let inflight = self.inflight.clone();
        let prefetcher = self.prefetcher.clone();
        let spill = self.spill.clone();
        let obj_key = self.object_key(id, expected_hash);
        let touched = self.touched.clone();
        let hits = self.stats.prefetch_hits.clone();
        let gets = self.stats.backend_gets.clone();
        let spill_hits = self.stats.spill_hits.clone();
        let spill_misses = self.stats.spill_misses.clone();
        let spill_writes = self.stats.spill_writes.clone();
        let work = move || {
            // same two-tier admission as the demand path, but run on the
            // fetch lane itself: we are already on background I/O
            // threads, so victim spills happen inline, not re-queued
            let admit = |data: &ChunkData, respill_self: bool| {
                admit_two_tier(&cache, spill.as_ref(), key, data, respill_self, |s, ek, ed| {
                    spill_writes.inc();
                    s.put(ek, &ed);
                });
            };
            // skip without waiting if a reader is already fetching it
            if !cache.contains(key) {
                let outcome = inflight.run_if_absent(key, || {
                    // re-check under flight ownership: a reader may have
                    // cached it between our contains() and leading. The
                    // admission also happens inside the flight, upholding
                    // the "no cache entry + no flight => no fetch
                    // outstanding" invariant for prefetched chunks too.
                    if let Some(hit) = cache.get(key) {
                        return Ok(hit);
                    }
                    if let Some(s) = &spill {
                        if let Some(data) = s.get(key, expected_len, expected_hash) {
                            spill_hits.inc();
                            admit(&data, false);
                            hits.inc();
                            return Ok(data);
                        }
                        spill_misses.inc();
                    }
                    gets.inc();
                    let data = store
                        .get(&obj_key)
                        .map(|v| Arc::new(ChunkBytes::ram(v)))
                        .map_err(to_fetch_error)?;
                    admit(&data, true);
                    hits.inc();
                    Ok(data)
                });
                // a prefetched chunk counts as touched: its later demand
                // hit is readahead paying off, not a content-dedup win
                if let Some(Ok(_)) = outcome {
                    if let Some(t) = touched.get(id as usize) {
                        t.store(true, Ordering::Relaxed);
                    }
                }
            }
            // queued-or-in-flight marker is now stale either way
            prefetcher.complete(id);
        };
        match &self.fetch_pool {
            Some(pool) => {
                if !pool.try_submit(Box::new(work)) {
                    self.stats.prefetch_dropped.inc();
                    self.prefetcher.complete(id);
                }
            }
            None => work(),
        }
    }

    /// Expose the cache for tests / warm-start scenarios.
    pub fn cache(&self) -> &ChunkCache {
        &self.cache
    }

    /// The local-disk spill tier, when this mount has one.
    pub fn spill(&self) -> Option<&SpillTier> {
        self.spill.as_deref()
    }

    /// Current adaptive prefetch depth (see [`Prefetcher::depth`]).
    pub fn prefetch_depth(&self) -> u32 {
        self.prefetcher.depth()
    }

    /// Chunk fetches currently in flight (misses + readahead).
    pub fn in_flight(&self) -> i64 {
        self.inflight.in_flight()
    }

    /// Register this mount's read-path counters under `hfs.<ns>.*` so
    /// they appear in [`MetricsRegistry::report`] next to the
    /// coordinator's metrics. Counters are shared, not copied: the
    /// report always renders live values.
    pub fn register_metrics(&self, reg: &MetricsRegistry) {
        let s = &self.stats;
        let named: [(&str, &Counter); 9] = [
            ("reads", &s.reads),
            ("bytes_read", &s.bytes_read),
            ("cache_hits", &s.cache_hits),
            ("cache_misses", &s.cache_misses),
            ("backend_gets", &s.backend_gets),
            ("spill_hits", &s.spill_hits),
            ("shard_loads", &s.shard_loads),
            ("dedup_hits", &s.dedup_hits),
            ("packed_reads", &s.packed_reads),
        ];
        for (name, c) in named {
            reg.register_counter(&format!("hfs.{}.{name}", self.ns), c.clone());
        }
    }

    /// Drop every cached chunk from *both* tiers (RAM and disk spill) and
    /// reset prefetch state — the sequential run, the adaptive depth, and
    /// the hit/miss window — so the predictor cannot suppress re-prefetch
    /// of dropped chunks and stale spill files cannot outlive the clear.
    /// Resident metadata (file-table shards, the chunk table) and the
    /// first-touch bitmap stay: they describe the immutable sealed
    /// namespace, not cached payload, and the dedup counter is
    /// documented as "since mount".
    ///
    /// Queued background work (readahead, spill writes) is drained
    /// *before* the tiers are cleared, so nothing enqueued by earlier
    /// reads can repopulate them afterwards: once this returns — and
    /// absent concurrent `read_file` calls, which are new work and may
    /// cache again — the next read of anything is a full backend fetch.
    pub fn clear_cache(&self) {
        if let Some(pool) = &self.fetch_pool {
            pool.drain();
        }
        self.cache.clear();
        if let Some(spill) = &self.spill {
            spill.clear();
        }
        self.prefetcher.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hfs::Uploader;
    use crate::storage::{CountingStore, MemStore};

    fn setup(n_files: usize, file_size: usize, chunk_size: u64) -> (StoreHandle, Vec<String>) {
        let store: StoreHandle = Arc::new(MemStore::new());
        let mut up = Uploader::new(store.clone(), "ds", chunk_size);
        let mut paths = Vec::new();
        for i in 0..n_files {
            let path = format!("data/{i:05}.bin");
            up.add_file(&path, &vec![(i % 251) as u8; file_size]).unwrap();
            paths.push(path);
        }
        up.seal().unwrap();
        (store, paths)
    }

    /// Pre-load the path shard and chunk table so the byte/GET accounting
    /// below sees only data traffic, not lazy metadata loads.
    fn warm_meta(fs: &HyperFs, path: &str) {
        fs.stat(path).unwrap();
        fs.chunk_object_key(0).unwrap();
    }

    #[test]
    fn read_roundtrip() {
        let (store, paths) = setup(10, 100, 350);
        let fs = HyperFs::mount(store, "ds", 10 << 20).unwrap();
        for (i, p) in paths.iter().enumerate() {
            assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 100]);
        }
        assert_eq!(fs.stats.reads.get(), 10);
    }

    #[test]
    fn sequential_reads_hit_cache_within_chunk() {
        // 3 files per chunk -> at least 2/3 of reads are cache hits
        let (store, paths) = setup(30, 100, 300);
        let fs = HyperFs::mount_with(
            store,
            "ds",
            10 << 20,
            PrefetchPolicy { max_depth: 0 },
            false,
        )
        .unwrap();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        assert_eq!(fs.stats.cache_misses.get(), 10); // one per chunk
        assert_eq!(fs.stats.cache_hits.get(), 20);
        assert_eq!(fs.stats.backend_gets.get(), 10);
        assert_eq!(fs.stats.dedup_hits.get(), 0, "all chunks are distinct content");
    }

    #[test]
    fn prefetch_warms_next_chunk_synchronously() {
        let (store, paths) = setup(30, 100, 300);
        let fs = HyperFs::mount_with(
            store,
            "ds",
            10 << 20,
            PrefetchPolicy { max_depth: 1 },
            false, // synchronous prefetch for determinism
        )
        .unwrap();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        // after the run is sequential, every later chunk came from readahead
        assert!(fs.stats.prefetch_issued.get() >= 7, "{:?}", fs.stats);
        assert!(fs.stats.cache_misses.get() <= 3, "{:?}", fs.stats);
        assert_eq!(fs.stats.dedup_hits.get(), 0, "prefetched hits are not dedup wins");
    }

    #[test]
    fn stat_and_list() {
        let (store, _) = setup(5, 42, 1000);
        let fs = HyperFs::mount(store, "ds", 1 << 20).unwrap();
        assert_eq!(fs.stat("data/00003.bin").unwrap(), 42);
        assert_eq!(fs.list("data/").unwrap().len(), 5);
        assert_eq!(fs.list("nope/").unwrap().len(), 0);
        assert!(fs.stat("missing").is_err());
    }

    #[test]
    fn missing_namespace_fails_to_mount() {
        let store: StoreHandle = Arc::new(MemStore::new());
        assert!(HyperFs::mount(store, "ghost", 1 << 20).is_err());
    }

    #[test]
    fn tiny_cache_still_correct() {
        let (store, paths) = setup(20, 100, 300);
        let fs = HyperFs::mount_with(store, "ds", 300, PrefetchPolicy { max_depth: 0 }, false)
            .unwrap();
        for (i, p) in paths.iter().enumerate() {
            assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 100]);
        }
    }

    #[test]
    fn cache_hit_reads_share_one_allocation() {
        // files at 1/2 of the chunk: big enough that the range-GET fast
        // path stays out of the way and the whole chunk is cached
        let (store, paths) = setup(6, 150, 400);
        let fs = HyperFs::mount_with(store, "ds", 1 << 20, PrefetchPolicy { max_depth: 0 }, false)
            .unwrap();
        let a = fs.read_file(&paths[0]).unwrap();
        let b = fs.read_file(&paths[1]).unwrap(); // same chunk, different file
        assert!(
            Arc::ptr_eq(a.chunk(), b.chunk()),
            "views into one chunk must share the cached allocation"
        );
        assert_ne!(&a[..], &b[..]);
    }

    #[test]
    fn view_survives_eviction() {
        // a ByteView handed out must stay valid even after the cache
        // evicts its chunk (the Arc keeps the payload alive)
        let (store, paths) = setup(20, 100, 300);
        let fs = HyperFs::mount_with(store, "ds", 300, PrefetchPolicy { max_depth: 0 }, false)
            .unwrap();
        let first = fs.read_file(&paths[0]).unwrap();
        for p in &paths {
            fs.read_file(p).unwrap(); // thrashes the 1-chunk cache
        }
        assert_eq!(first, vec![0u8; 100]);
    }

    #[test]
    fn clear_cache_resets_prefetch_state_too() {
        let (store, paths) = setup(30, 100, 300);
        let fs = HyperFs::mount_with(store, "ds", 10 << 20, PrefetchPolicy { max_depth: 2 }, false)
            .unwrap();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        fs.clear_cache();
        assert!(fs.cache().is_empty());
        // a second epoch re-prefetches instead of being suppressed by
        // stale pending state
        let issued_before = fs.stats.prefetch_issued.get();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        assert!(
            fs.stats.prefetch_issued.get() > issued_before,
            "second epoch must prefetch again: {:?}",
            fs.stats
        );
    }

    #[test]
    fn concurrent_cold_reads_issue_one_get_per_chunk() {
        // 32 threads cold-read files that all live in one chunk: the
        // single-flight table must collapse them into exactly 1 GET.
        // Files fill a third of the chunk each, so the small-file
        // range-GET fast path does not reroute these reads.
        let (inner, paths) = setup(3, 100, 300);
        let counting = Arc::new(CountingStore::new(inner));
        let store: StoreHandle = counting.clone();
        let fs = Arc::new(
            HyperFs::mount_with(store, "ds", 10 << 20, PrefetchPolicy { max_depth: 0 }, false)
                .unwrap(),
        );
        let barrier = Arc::new(std::sync::Barrier::new(32));
        std::thread::scope(|s| {
            for t in 0..32usize {
                let fs = fs.clone();
                let paths = paths.clone();
                let barrier = barrier.clone();
                s.spawn(move || {
                    barrier.wait();
                    let p = &paths[t % paths.len()];
                    let expect = vec![((t % paths.len()) % 251) as u8; 100];
                    assert_eq!(fs.read_file(p).unwrap(), expect);
                });
            }
        });
        assert_eq!(
            counting.gets_for(&fs.chunk_object_key(0).unwrap()),
            1,
            "thundering herd must coalesce to one backend GET"
        );
        assert_eq!(fs.stats.backend_gets.get(), 1);
        assert_eq!(
            fs.stats.cache_misses.get(),
            fs.stats.backend_gets.get() + fs.stats.coalesced_reads.get(),
            "every miss either led or coalesced"
        );
    }

    // ------------------------------------------- range-GET fast path

    /// One tiny file packed with big siblings into a large chunk.
    fn small_file_setup() -> (Arc<CountingStore>, StoreHandle) {
        let inner: StoreHandle = Arc::new(MemStore::new());
        let mut up = Uploader::new(inner.clone(), "ds", 8192);
        up.add_file("tiny.bin", &[42u8; 100]).unwrap();
        up.add_file("big1.bin", &[1u8; 3000]).unwrap();
        up.add_file("big2.bin", &[2u8; 3000]).unwrap();
        up.seal().unwrap(); // one 6100-byte chunk
        let counting = Arc::new(CountingStore::new(inner));
        let handle: StoreHandle = counting.clone();
        (counting, handle)
    }

    #[test]
    fn cold_small_read_uses_range_get_and_moves_fewer_bytes() {
        let (counting, store) = small_file_setup();
        let fs = HyperFs::mount_with(store, "ds", 1 << 20, PrefetchPolicy { max_depth: 0 }, false)
            .unwrap();
        warm_meta(&fs, "tiny.bin");
        counting.reset(); // ignore mount + metadata GETs
        let view = fs.read_file("tiny.bin").unwrap();
        assert_eq!(view, vec![42u8; 100], "byte-for-byte equality");
        assert_eq!(counting.total_range_gets(), 1, "served by get_range");
        assert_eq!(
            counting.total_get_bytes(),
            100,
            "transferred the file, not the 6100-byte chunk"
        );
        assert_eq!(fs.stats.range_gets.get(), 1);
        assert_eq!(fs.stats.range_bytes.get(), 100);
        assert_eq!(fs.stats.backend_gets.get(), 0, "no whole-chunk fetch");
        assert!(fs.cache().is_empty(), "partial data is never cached");
    }

    #[test]
    fn big_file_in_same_chunk_still_fetches_whole_chunk() {
        let (counting, store) = small_file_setup();
        let fs = HyperFs::mount_with(store, "ds", 1 << 20, PrefetchPolicy { max_depth: 0 }, false)
            .unwrap();
        warm_meta(&fs, "tiny.bin");
        counting.reset();
        // 3000 * 4 >= 6100: not "much smaller" than its chunk
        assert_eq!(fs.read_file("big1.bin").unwrap(), vec![1u8; 3000]);
        assert_eq!(counting.total_range_gets(), 0);
        assert_eq!(fs.stats.backend_gets.get(), 1);
        // ...and now the chunk is cached, so the tiny neighbor is a hit
        assert_eq!(fs.read_file("tiny.bin").unwrap(), vec![42u8; 100]);
        assert_eq!(fs.stats.cache_hits.get(), 1);
        assert_eq!(counting.total_gets(), 1, "no second backend call");
    }

    #[test]
    fn sequential_scan_keeps_whole_chunk_fetches() {
        // 20 small files per 2000-byte chunk: a scan must settle into
        // whole-chunk fetches (locality pays), with at most the first two
        // probing reads allowed to take the range path
        let (inner, paths) = setup(60, 100, 2000);
        let counting = Arc::new(CountingStore::new(inner));
        let store: StoreHandle = counting.clone();
        let fs = HyperFs::mount_with(store, "ds", 1 << 20, PrefetchPolicy { max_depth: 0 }, false)
            .unwrap();
        warm_meta(&fs, &paths[0]);
        counting.reset();
        for (i, p) in paths.iter().enumerate() {
            assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 100]);
        }
        assert!(
            fs.stats.range_gets.get() <= 2,
            "scan must not degrade into per-file range GETs: {:?}",
            fs.stats
        );
        assert_eq!(fs.stats.backend_gets.get(), 3, "one GET per chunk");
        // transfer accounting: ~3 chunks + 2 probe files, nowhere near
        // 60 files' worth of chunk fetches
        assert!(counting.total_get_bytes() <= 3 * 2000 + 2 * 100);
    }

    #[test]
    fn repeated_random_small_reads_promote_to_cached_chunks() {
        // shuffled epochs with an ample cache: after <=2 range probes per
        // chunk the path must invest in whole chunks, so later epochs are
        // pure cache hits instead of re-transferring the dataset
        let (inner, paths) = setup(40, 100, 1000);
        let counting = Arc::new(CountingStore::new(inner));
        let store: StoreHandle = counting.clone();
        let fs = HyperFs::mount_with(store, "ds", 1 << 20, PrefetchPolicy { max_depth: 0 }, false)
            .unwrap();
        warm_meta(&fs, &paths[0]);
        counting.reset();
        let n = paths.len();
        let order: Vec<String> = (0..n).map(|i| paths[(i * 17) % n].clone()).collect();
        for p in &order {
            fs.read_file(p).unwrap();
        }
        let after_first_epoch = counting.total_get_bytes();
        // epoch 1: at most 2 range probes (100 B) + 1 whole fetch
        // (1000 B) per chunk
        assert!(
            after_first_epoch <= 4 * (1000 + 2 * 100),
            "epoch 1 moved {after_first_epoch} bytes"
        );
        for _ in 0..2 {
            for p in &order {
                fs.read_file(p).unwrap();
            }
        }
        assert_eq!(
            counting.total_get_bytes(),
            after_first_epoch,
            "later epochs must be served from cache, not re-fetched"
        );
        assert!(fs.stats.cache_hits.get() >= 80, "{:?}", fs.stats);
    }

    /// Delegating store whose `get_range` stalls, widening the race
    /// window so concurrent small-file readers really pile onto one
    /// in-flight range GET.
    struct SlowRangeStore(StoreHandle);

    impl crate::storage::ObjectStore for SlowRangeStore {
        fn put(&self, key: &str, data: &[u8]) -> Result<()> {
            self.0.put(key, data)
        }
        fn get(&self, key: &str) -> Result<Vec<u8>> {
            self.0.get(key)
        }
        fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
            std::thread::sleep(std::time::Duration::from_millis(50));
            self.0.get_range(key, offset, len)
        }
        fn head(&self, key: &str) -> Result<u64> {
            self.0.head(key)
        }
        fn list(&self, prefix: &str) -> Result<Vec<String>> {
            self.0.list(prefix)
        }
        fn delete(&self, key: &str) -> Result<()> {
            self.0.delete(key)
        }
    }

    #[test]
    fn concurrent_small_cold_reads_coalesce_range_gets() {
        // 16 threads cold-read the SAME small file: the range single-flight
        // table must collapse them into one backend range GET
        let inner: StoreHandle = Arc::new(MemStore::new());
        let mut up = Uploader::new(inner.clone(), "ds", 8192);
        up.add_file("tiny.bin", &[42u8; 100]).unwrap();
        up.add_file("pad.bin", &[1u8; 3000]).unwrap();
        up.seal().unwrap();
        let counting = Arc::new(CountingStore::new(inner));
        let slow: StoreHandle = Arc::new(SlowRangeStore(counting.clone()));
        // cache too small to retain the chunk: promotion stays off, so
        // every thread is on the pure range path and must coalesce
        let fs = Arc::new(
            HyperFs::mount_with(slow, "ds", 2048, PrefetchPolicy { max_depth: 0 }, false)
                .unwrap(),
        );
        warm_meta(&fs, "tiny.bin");
        counting.reset();
        let barrier = Arc::new(std::sync::Barrier::new(16));
        std::thread::scope(|s| {
            for _ in 0..16 {
                let fs = fs.clone();
                let barrier = barrier.clone();
                s.spawn(move || {
                    barrier.wait();
                    assert_eq!(fs.read_file("tiny.bin").unwrap(), vec![42u8; 100]);
                });
            }
        });
        assert_eq!(
            counting.total_range_gets(),
            1,
            "concurrent same-file readers must coalesce: {:?}",
            counting.gets_by_key()
        );
        assert_eq!(fs.stats.range_gets.get(), 1);
        // nearly all riders shared the flight (a severely descheduled
        // thread may legitimately arrive after the predictor flipped)
        assert!(fs.stats.coalesced_reads.get() >= 10, "{:?}", fs.stats);
    }

    #[test]
    fn shuffled_small_reads_transfer_fewer_bytes_than_chunk_fetches() {
        // worst case for the old path: random access over small files
        // (10 per 1000-byte chunk) with a one-chunk cache that thrashes.
        // the seed path paid a whole chunk per cold read; the fast path
        // pays the file
        let (inner, mut paths) = setup(40, 100, 1000);
        let counting = Arc::new(CountingStore::new(inner));
        let store: StoreHandle = counting.clone();
        let fs = HyperFs::mount_with(store, "ds", 1000, PrefetchPolicy { max_depth: 0 }, false)
            .unwrap();
        warm_meta(&fs, &paths[0]);
        counting.reset();
        // deterministic stride-17 shuffle: chunk order rarely steps +1,
        // so the scan detector stays off for almost every read
        let n = paths.len();
        paths = (0..n).map(|i| paths[(i * 17) % n].clone()).collect();
        for p in &paths {
            let i: usize = p["data/".len()..p.len() - 4].parse().unwrap();
            assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 100]);
        }
        let moved = counting.total_get_bytes();
        assert!(
            moved < 40 * 1000 / RANGE_GET_RATIO,
            "random small reads moved {moved} bytes; whole-chunk fetching \
             would have moved up to {} through this thrashing cache",
            40 * 1000
        );
        assert!(fs.stats.range_gets.get() > 0);
    }

    // ------------------------------------------- two-tier spill cache

    /// Spill-enabled mount config: sync mode so every spill read/write
    /// happens inline (deterministic), prefetch off unless a test arms
    /// it, mmap reads on so spill hits exercise the mapped path.
    fn spill_cfg(dir: &std::path::Path, cache_bytes: u64) -> HfsConfig {
        HfsConfig {
            cache_bytes,
            spill_dir: Some(dir.to_path_buf()),
            spill_bytes: 64 << 20,
            spill_mmap: true,
            prefetch_max_depth: 0,
            background_prefetch: false,
        }
    }

    /// 32 files x 100 B, 4 per 400-byte chunk (files are 1/4 of the chunk,
    /// so the range-GET fast path stays out of the way), behind a counter.
    fn spill_setup() -> (Arc<CountingStore>, StoreHandle, Vec<String>) {
        let (inner, paths) = setup(32, 100, 400);
        let counting = Arc::new(CountingStore::new(inner));
        let handle: StoreHandle = counting.clone();
        (counting, handle, paths)
    }

    #[test]
    fn ram_evicted_chunk_promotes_from_spill_without_backend_get() {
        let dir = crate::util::TempDir::new().unwrap();
        let (counting, store, paths) = spill_setup();
        // RAM holds 2 of the 8 chunks; the spill tier catches the rest
        let fs = HyperFs::mount_cfg(store, "ds", &spill_cfg(dir.path(), 800)).unwrap();
        counting.reset();
        for (i, p) in paths.iter().enumerate() {
            assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 100]);
        }
        assert_eq!(fs.stats.backend_gets.get(), 8, "cold epoch: one GET per chunk");
        assert!(fs.spill().unwrap().len() >= 6, "evictions landed on disk");
        let cold_gets = counting.total_gets();
        let cold_bytes = counting.total_get_bytes();
        for (i, p) in paths.iter().enumerate() {
            assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 100]);
        }
        assert_eq!(
            counting.total_gets(),
            cold_gets,
            "epoch 2 must not touch the object store at all"
        );
        assert_eq!(counting.total_get_bytes(), cold_bytes, "zero bytes transferred");
        assert_eq!(fs.stats.spill_hits.get(), 8, "every chunk promoted from disk");
    }

    #[test]
    fn flight_recorder_tags_reads_with_their_serving_tier() {
        let dir = crate::util::TempDir::new().unwrap();
        let (_counting, store, paths) = spill_setup();
        let rec = crate::obs::FlightRecorder::wallclock(1 << 16);
        let mut fs = HyperFs::mount_cfg(store, "ds", &spill_cfg(dir.path(), 800)).unwrap();
        fs.set_obs(rec.clone());
        for p in paths.iter().chain(paths.iter()) {
            fs.read_file(p).unwrap();
        }
        assert_eq!(rec.dropped(), 0);
        let records = rec.snapshot();
        let tier_count = |t: &str| {
            records
                .iter()
                .filter(|r| {
                    r.name == "hfs.read" && r.arg("tier").and_then(|a| a.as_str()) == Some(t)
                })
                .count() as u64
        };
        let count = |n: &str| records.iter().filter(|r| r.name == n).count() as u64;
        assert_eq!(count("hfs.read"), 64, "one span per read_file call");
        // the span's tier tag agrees with the counter plane, read by read
        assert_eq!(tier_count("ram"), fs.stats.cache_hits.get());
        assert_eq!(tier_count("fetch"), fs.stats.cache_misses.get());
        assert_eq!(count("hfs.backend_get"), fs.stats.backend_gets.get());
        assert_eq!(count("hfs.spill_promote"), fs.stats.spill_hits.get());
        assert!(fs.stats.spill_hits.get() > 0, "epoch 2 promoted from disk");
    }

    #[test]
    fn clear_cache_purges_spill_tier_and_refetches_from_backend() {
        let dir = crate::util::TempDir::new().unwrap();
        let (counting, store, paths) = spill_setup();
        let fs = HyperFs::mount_cfg(store, "ds", &spill_cfg(dir.path(), 800)).unwrap();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        assert!(!fs.spill().unwrap().is_empty());
        let gets_before = counting.total_gets();
        fs.clear_cache();
        assert!(fs.cache().is_empty(), "RAM tier cleared");
        assert!(fs.spill().unwrap().is_empty(), "disk tier cleared too");
        assert_eq!(fs.prefetch_depth(), 0, "adaptive window reset");
        for (i, p) in paths.iter().enumerate() {
            assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 100]);
        }
        assert_eq!(
            counting.total_gets(),
            gets_before + 8,
            "a cleared cache must re-fetch every chunk from the backend"
        );
        assert_eq!(counting.gets_for(&fs.chunk_object_key(0).unwrap()), 2);
    }

    #[test]
    fn clear_cache_in_background_mode_drains_queued_spill_writes() {
        // spill writes ride the fetch lanes in threaded mode; clear_cache
        // must drain them first or a queued put lands *after* the clear
        // and resurrects the chunk
        let dir = crate::util::TempDir::new().unwrap();
        let (counting, store, paths) = spill_setup();
        let mut cfg = spill_cfg(dir.path(), 800);
        cfg.background_prefetch = true;
        let fs = HyperFs::mount_cfg(store, "ds", &cfg).unwrap();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        fs.clear_cache();
        assert!(fs.cache().is_empty());
        assert!(
            fs.spill().unwrap().is_empty(),
            "no queued spill write may outlive the clear"
        );
        let gets = counting.total_gets();
        fs.read_file(&paths[0]).unwrap();
        assert!(counting.total_gets() > gets, "post-clear read hits the backend");
    }

    #[test]
    fn fresh_mount_reuses_valid_spill_dir() {
        let dir = crate::util::TempDir::new().unwrap();
        let (counting, store, paths) = spill_setup();
        {
            let fs =
                HyperFs::mount_cfg(store.clone(), "ds", &spill_cfg(dir.path(), 800)).unwrap();
            for p in &paths {
                fs.read_file(p).unwrap();
            }
            // chunks 0..=5 were evicted to disk; 6 and 7 die with the mount
        }
        let fs = HyperFs::mount_cfg(store, "ds", &spill_cfg(dir.path(), 800)).unwrap();
        counting.reset();
        for (i, p) in paths.iter().enumerate() {
            assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 100]);
        }
        assert_eq!(
            fs.stats.backend_gets.get(),
            2,
            "only the chunks that never spilled (they were still in RAM at \
             shutdown) go back to the store: {:?}",
            counting.gets_by_key()
        );
        assert_eq!(fs.stats.spill_hits.get(), 6, "the rest restart from disk");
        assert_eq!(fs.spill().unwrap().rejected(), 0);
        assert_eq!(
            fs.stats.dedup_hits.get(),
            0,
            "cross-mount spill reuse is not a content-dedup win"
        );
    }

    #[test]
    fn fresh_mount_never_serves_corrupt_spill_bytes() {
        let dir = crate::util::TempDir::new().unwrap();
        let (counting, store, paths) = spill_setup();
        {
            let fs =
                HyperFs::mount_cfg(store.clone(), "ds", &spill_cfg(dir.path(), 800)).unwrap();
            for p in &paths {
                fs.read_file(p).unwrap();
            }
        }
        // corrupt every spilled file in place (same length, wrong bytes,
        // so only the content digest can tell)
        let spill_dir = dir.path().join("spill/ds");
        let mut corrupted = 0usize;
        for entry in std::fs::read_dir(&spill_dir).unwrap() {
            let path = entry.unwrap().path();
            let len = std::fs::metadata(&path).unwrap().len() as usize;
            std::fs::write(&path, vec![0xAAu8; len]).unwrap();
            corrupted += 1;
        }
        assert!(corrupted >= 6);
        // spill_mmap is on in spill_cfg: the digest check runs over the
        // mapped pages, and must reject every corrupt file
        let fs = HyperFs::mount_cfg(store, "ds", &spill_cfg(dir.path(), 800)).unwrap();
        counting.reset();
        for (i, p) in paths.iter().enumerate() {
            assert_eq!(
                fs.read_file(p).unwrap(),
                vec![(i % 251) as u8; 100],
                "corrupt spill data must never reach a reader"
            );
        }
        assert_eq!(fs.stats.backend_gets.get(), 8, "all chunks re-fetched");
        assert_eq!(fs.spill().unwrap().rejected() as usize, corrupted);
        assert_eq!(fs.stats.spill_hits.get(), 0);
    }

    #[test]
    fn rebuilt_namespace_with_same_sizes_never_serves_stale_spill() {
        // the nasty case for name-keyed caching: the namespace is
        // re-uploaded with byte-identical LAYOUT (same paths, sizes,
        // chunk lengths) but different content. Under content-addressed
        // keying the rebuilt chunks get brand-new digests, so v1 spill
        // files are simply unreachable — and the identical chunks
        // *within* each upload collapse to a single fetched object.
        let dir = crate::util::TempDir::new().unwrap();
        let store: StoreHandle = Arc::new(MemStore::new());
        let upload = |byte: u8| {
            let mut up = Uploader::new(store.clone(), "ds", 400);
            for i in 0..32 {
                up.add_file(&format!("data/{i:05}.bin"), &vec![byte; 100]).unwrap();
            }
            up.seal().unwrap();
        };
        upload(1);
        {
            let fs =
                HyperFs::mount_cfg(store.clone(), "ds", &spill_cfg(dir.path(), 800)).unwrap();
            for i in 0..32 {
                fs.read_file(&format!("data/{i:05}.bin")).unwrap();
            }
            assert_eq!(fs.stats.backend_gets.get(), 1, "8 identical chunks, 1 GET");
            assert_eq!(fs.stats.dedup_hits.get(), 7, "the other 7 were twins");
        }
        upload(2); // rebuild: same sizes, different bytes
        let fs = HyperFs::mount_cfg(store, "ds", &spill_cfg(dir.path(), 800)).unwrap();
        for i in 0..32 {
            assert_eq!(
                fs.read_file(&format!("data/{i:05}.bin")).unwrap(),
                vec![2u8; 100],
                "v1 bytes must never be served for the rebuilt namespace"
            );
        }
        assert_eq!(fs.stats.backend_gets.get(), 1, "v2 content fetched fresh, once");
        assert_eq!(fs.stats.spill_hits.get(), 0, "no stale v1 spill data served");
        assert_eq!(fs.spill().unwrap().rejected(), 0, "stale files unreachable, not re-keyed");
        assert_eq!(fs.stats.dedup_hits.get(), 7);
    }

    #[test]
    fn oversized_chunks_are_served_from_spill_not_network() {
        // chunks bigger than the whole RAM budget are uncacheable in RAM;
        // with a spill tier they still converge to local-disk reads
        let dir = crate::util::TempDir::new().unwrap();
        let (inner, paths) = setup(3, 400, 400); // 1 file per 400-byte chunk
        let counting = Arc::new(CountingStore::new(inner));
        let store: StoreHandle = counting.clone();
        let fs = HyperFs::mount_cfg(store, "ds", &spill_cfg(dir.path(), 300)).unwrap();
        counting.reset();
        for _ in 0..3 {
            for (i, p) in paths.iter().enumerate() {
                assert_eq!(fs.read_file(p).unwrap(), vec![(i % 251) as u8; 400]);
            }
        }
        assert!(fs.cache().is_empty(), "RAM tier cannot hold these chunks");
        assert_eq!(fs.stats.backend_gets.get(), 3, "one GET per chunk, ever");
        assert_eq!(fs.stats.spill_hits.get(), 6, "epochs 2 and 3 came from disk");
    }

    #[test]
    fn small_cold_reads_prefer_spill_over_range_gets() {
        // a chunk already on local disk must be served from the spill
        // tier, not re-fetched (even partially) over the network — the
        // range-GET fast path only applies to chunks in neither tier
        let dir = crate::util::TempDir::new().unwrap();
        let inner: StoreHandle = Arc::new(MemStore::new());
        let mut up = Uploader::new(inner.clone(), "ds", 8192);
        up.add_file("tiny.bin", &[42u8; 100]).unwrap();
        up.add_file("big1.bin", &[1u8; 3000]).unwrap();
        up.add_file("big2.bin", &[2u8; 3000]).unwrap();
        up.seal().unwrap(); // one 6100-byte chunk
        let counting = Arc::new(CountingStore::new(inner));
        let store: StoreHandle = counting.clone();
        // RAM too small for the chunk: it spills directly on first fetch
        let fs = HyperFs::mount_cfg(store, "ds", &spill_cfg(dir.path(), 2048)).unwrap();
        warm_meta(&fs, "tiny.bin");
        counting.reset();
        assert_eq!(fs.read_file("big1.bin").unwrap(), vec![1u8; 3000]);
        assert_eq!(fs.stats.backend_gets.get(), 1);
        assert_eq!(fs.spill().unwrap().len(), 1, "uncacheable chunk hit the disk tier");
        // cold small read of the same chunk: without the spill guard this
        // would pay an object-store range GET despite the local copy
        assert_eq!(fs.read_file("tiny.bin").unwrap(), vec![42u8; 100]);
        assert_eq!(counting.total_range_gets(), 0, "no network range GET");
        assert_eq!(fs.stats.spill_hits.get(), 1, "served from local disk");
        assert_eq!(counting.total_gets(), 1, "exactly the one cold chunk GET, ever");
    }

    #[test]
    fn adaptive_prefetch_deepens_on_scan_and_collapses_on_shuffle() {
        let (store, paths) = setup(64, 100, 400); // 16 chunks, 4 files each
        let fs = HyperFs::mount_with(
            store,
            "ds",
            10 << 20,
            PrefetchPolicy { max_depth: 8 },
            false,
        )
        .unwrap();
        for p in &paths {
            fs.read_file(p).unwrap();
        }
        assert!(
            fs.prefetch_depth() >= 2,
            "a sequential scan must reach at least the old static depth: {}",
            fs.prefetch_depth()
        );
        let n = paths.len();
        for i in 0..n {
            fs.read_file(&paths[(i * 17) % n]).unwrap();
        }
        assert!(
            fs.prefetch_depth() <= 1,
            "shuffled access must collapse readahead: {}",
            fs.prefetch_depth()
        );
    }

    // ------------------------------------------- sharded metadata plane

    #[test]
    fn legacy_and_sharded_mounts_read_byte_identical() {
        let legacy_store: StoreHandle = Arc::new(MemStore::new());
        let sharded_store: StoreHandle = Arc::new(MemStore::new());
        let mut a = Uploader::legacy(legacy_store.clone(), "ds", 300);
        let mut b = Uploader::new(sharded_store.clone(), "ds", 300);
        let mut paths = Vec::new();
        for i in 0..12 {
            let path = format!("data/{i:05}.bin");
            let body = vec![(i % 251) as u8; 100];
            a.add_file(&path, &body).unwrap();
            b.add_file(&path, &body).unwrap();
            paths.push(path);
        }
        a.seal().unwrap();
        b.seal().unwrap();
        let old = HyperFs::mount(legacy_store, "ds", 1 << 20).unwrap();
        let new = HyperFs::mount(sharded_store, "ds", 1 << 20).unwrap();
        assert!(!old.is_sharded() && old.manifest().is_some());
        assert!(new.is_sharded() && new.manifest().is_none());
        for p in &paths {
            assert_eq!(&old.read_file(p).unwrap()[..], &new.read_file(p).unwrap()[..]);
        }
        assert_eq!(old.file_count(), 12);
        assert_eq!(new.file_count(), 12);
        assert_eq!(new.total_bytes(), 1200);
        assert_eq!(new.chunk_count(), old.chunk_count());
        assert!(new.stats.shard_loads.get() > 0, "sharded metadata loaded lazily");
        assert_eq!(old.stats.shard_loads.get(), 0, "legacy mounts load nothing lazily");
        assert_eq!(old.list("data/").unwrap(), new.list("data/").unwrap());
    }

    #[test]
    fn sharded_mount_parses_root_only_and_loads_shards_on_demand() {
        let inner: StoreHandle = Arc::new(MemStore::new());
        let cfg = crate::config::UploadConfig {
            chunk_size: 400,
            shard_files: 16,
            ..Default::default()
        };
        let mut up = Uploader::with_config(inner.clone(), "ds", cfg);
        for i in 0..64 {
            up.add_file(&format!("data/{i:05}.bin"), &vec![(i % 251) as u8; 100]).unwrap();
        }
        up.seal().unwrap(); // 4 shards of 16 files
        let counting = Arc::new(CountingStore::new(inner));
        let store: StoreHandle = counting.clone();
        let fs = HyperFs::mount(store, "ds", 1 << 20).unwrap();
        assert_eq!(counting.total_gets(), 1, "mount reads only the root manifest");
        assert_eq!(fs.stats.shard_loads.get(), 0);
        fs.read_file("data/00000.bin").unwrap();
        assert_eq!(
            fs.stats.shard_loads.get(),
            2,
            "first read pulls its path shard + the chunk table"
        );
        fs.read_file("data/00001.bin").unwrap();
        assert_eq!(fs.stats.shard_loads.get(), 2, "same shard: no more metadata traffic");
        fs.read_file("data/00063.bin").unwrap();
        assert_eq!(fs.stats.shard_loads.get(), 3, "a far file pulls exactly its own shard");
        assert_eq!(fs.list("data/0006").unwrap().len(), 4, "00060..00063");
    }

    #[test]
    fn content_dedup_collapses_backend_traffic() {
        let inner: StoreHandle = Arc::new(MemStore::new());
        let counting = Arc::new(CountingStore::new(inner));
        let store: StoreHandle = counting.clone();
        // 64 single-chunk files but only 8 distinct contents
        let mut up = Uploader::new(store.clone(), "ds", 64);
        for i in 0..64 {
            up.add_file(&format!("data/{i:05}.bin"), &vec![(i % 8) as u8; 64]).unwrap();
        }
        let (_, ustats) = up.seal_with_stats().unwrap();
        assert_eq!(ustats.chunks_written, 8, "8 distinct contents -> 8 chunk PUTs");
        assert_eq!(ustats.chunks_deduped, 56);
        assert_eq!(counting.total_puts(), 8 + 3, "8 chunks + root/shard/chunk-table");
        let fs = HyperFs::mount(store, "ds", 1 << 20).unwrap();
        warm_meta(&fs, "data/00000.bin");
        counting.reset();
        for i in 0..64 {
            assert_eq!(
                fs.read_file(&format!("data/{i:05}.bin")).unwrap(),
                vec![(i % 8) as u8; 64]
            );
        }
        assert_eq!(fs.stats.backend_gets.get(), 8, "one GET per distinct content");
        assert_eq!(fs.stats.dedup_hits.get(), 56, "56 chunks served by a cached twin");
        assert_eq!(counting.total_gets(), 8);
        assert_eq!(counting.total_get_bytes(), 8 * 64, "transfer scales with unique bytes");
    }

    #[test]
    fn packed_small_files_read_back_without_range_gets() {
        let inner: StoreHandle = Arc::new(MemStore::new());
        let counting = Arc::new(CountingStore::new(inner));
        let store: StoreHandle = counting.clone();
        let cfg = crate::config::UploadConfig {
            chunk_size: 256,
            pack_threshold: 32,
            ..Default::default()
        };
        let mut up = Uploader::with_config(store.clone(), "ds", cfg);
        for i in 0..10 {
            up.add_file(&format!("f/{i}.bin"), &vec![i as u8; 16]).unwrap();
        }
        let (m, ustats) = up.seal_with_stats().unwrap();
        assert_eq!(ustats.files_packed, 10);
        assert!(m.chunks.iter().all(|c| c.packed), "every chunk is an archive");
        let fs = HyperFs::mount(store, "ds", 1 << 20).unwrap();
        counting.reset();
        for i in 0..10 {
            assert_eq!(fs.read_file(&format!("f/{i}.bin")).unwrap(), vec![i as u8; 16]);
        }
        assert_eq!(fs.stats.packed_reads.get(), 10);
        // 29-byte archive entries, 8 per 256-byte chunk -> 2 archive chunks
        assert_eq!(fs.stats.backend_gets.get(), 2, "archive chunks amortize the fetches");
        assert_eq!(counting.total_range_gets(), 0, "tiny packed members never range-GET");
    }

    #[test]
    fn pre_digest_legacy_manifest_mounts_and_reads() {
        // hand-written v1 manifest with no hash fields at all — the shape
        // a pre-digest writer produced; tier keys fall back to (ns, id)
        let store: StoreHandle = Arc::new(MemStore::new());
        let manifest = concat!(
            r#"{"chunk_size":8,"files":["#,
            r#"{"path":"a.bin","chunk":0,"offset":0,"len":3},"#,
            r#"{"path":"b.bin","chunk":0,"offset":3,"len":2}],"#,
            r#""chunks":[{"id":0,"len":5}]}"#
        );
        store.put(&FsManifest::manifest_key("old"), manifest.as_bytes()).unwrap();
        store.put(&FsManifest::chunk_key("old", 0), b"hello").unwrap();
        let fs = HyperFs::mount(store, "old", 1 << 20).unwrap();
        assert!(!fs.is_sharded());
        assert_eq!(fs.read_file("a.bin").unwrap(), b"hel".to_vec());
        assert_eq!(fs.read_file("b.bin").unwrap(), b"lo".to_vec());
        assert_eq!(fs.stats.backend_gets.get(), 1);
        assert_eq!(fs.chunk_object_key(0).unwrap(), "old/chunks/00000000");
        assert_eq!(fs.list("").unwrap().len(), 2);
    }

    #[test]
    fn stats_register_into_metrics_registry() {
        let (store, paths) = setup(4, 100, 200);
        let fs = HyperFs::mount(store, "ds", 1 << 20).unwrap();
        fs.read_file(&paths[0]).unwrap();
        let reg = MetricsRegistry::new();
        fs.register_metrics(&reg);
        let report = reg.report();
        assert!(report.contains("hfs.ds.reads 1"), "{report}");
        assert!(report.contains("hfs.ds.shard_loads"), "{report}");
        assert!(report.contains("hfs.ds.dedup_hits 0"), "{report}");
        fs.read_file(&paths[1]).unwrap();
        assert!(reg.report().contains("hfs.ds.reads 2"), "registered counters are live");
    }
}
