//! On-store layout of an HFS namespace.
//!
//! A namespace `ns` occupies:
//!
//! ```text
//! <ns>/manifest.json      — FsManifest: file table + chunk table
//! <ns>/chunks/<id>        — packed chunk objects
//! ```
//!
//! Files are packed *in upload order*, which for deep-learning datasets is
//! the order loaders will read them — that locality is what makes the
//! next-file-in-same-chunk lookahead (§III.A) effective.


use crate::util::Json;
use crate::{Error, Result};

/// A file inside the namespace: where it lives in which chunk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileEntry {
    /// Full path within the namespace.
    pub path: String,
    /// Id of the chunk holding this file's bytes.
    pub chunk: u32,
    /// Byte offset of the file within its chunk.
    pub offset: u64,
    /// File length in bytes.
    pub len: u64,
}

/// A chunk object, its total size, and its content digest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkRef {
    /// Chunk id (also its position in the chunk table).
    pub id: u32,
    /// Packed size of the chunk object in bytes.
    pub len: u64,
    /// FNV-1a 64 digest of the chunk bytes, recorded at upload time; the
    /// spill tier verifies spilled files against it so a rebuilt
    /// namespace invalidates stale disk data even at identical lengths.
    /// `0` = unknown (manifest written before digests existed): length
    /// checks still apply, digest checks are skipped.
    pub hash: u64,
}

/// 64-bit FNV-1a — the chunk content digest recorded in manifests at
/// upload time and re-verified by the spill tier before serving.
pub(crate) fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The namespace manifest: ordered file table plus chunk table.
#[derive(Debug, Clone, Default)]
pub struct FsManifest {
    /// Target chunk size the namespace was packed with.
    pub chunk_size: u64,
    /// Files, sorted by path after seal (upload order before).
    pub files: Vec<FileEntry>,
    /// Chunk table, in id order.
    pub chunks: Vec<ChunkRef>,
}

impl FsManifest {
    /// An empty manifest packing into `chunk_size`-byte chunks.
    pub fn new(chunk_size: u64) -> Self {
        Self { chunk_size, files: Vec::new(), chunks: Vec::new() }
    }

    /// Index of the file with exactly this path.
    pub fn find(&self, path: &str) -> Result<usize> {
        // file table is sorted by path at seal time -> binary search
        self.files
            .binary_search_by(|f| f.path.as_str().cmp(path))
            .map_err(|_| Error::FileNotFound(path.to_string()))
    }

    /// Files under a directory prefix.
    pub fn list(&self, prefix: &str) -> Vec<&FileEntry> {
        let start = self.files.partition_point(|f| f.path.as_str() < prefix);
        self.files[start..]
            .iter()
            .take_while(|f| f.path.starts_with(prefix))
            .collect()
    }

    /// Total payload bytes across all files.
    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|f| f.len).sum()
    }

    /// Number of files in the namespace.
    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    /// Key of a chunk object within the namespace.
    pub fn chunk_key(ns: &str, id: u32) -> String {
        format!("{ns}/chunks/{id:08}")
    }

    /// Key of the namespace's manifest object.
    pub fn manifest_key(ns: &str) -> String {
        format!("{ns}/manifest.json")
    }

    /// Sort the file table by path (called once at seal time) while
    /// recording the upload order needed by the sequential prefetcher.
    /// Returns `read_order[i] = index into files` for the i-th uploaded file.
    pub(crate) fn seal(&mut self) -> Vec<u32> {
        let mut order: Vec<u32> = (0..self.files.len() as u32).collect();
        order.sort_by(|&a, &b| self.files[a as usize].path.cmp(&self.files[b as usize].path));
        // order maps sorted-pos -> upload-pos; invert to upload-pos -> sorted-pos
        let mut sorted_files = Vec::with_capacity(self.files.len());
        let mut upload_to_sorted = vec![0u32; self.files.len()];
        for (sorted_pos, &upload_pos) in order.iter().enumerate() {
            upload_to_sorted[upload_pos as usize] = sorted_pos as u32;
            sorted_files.push(self.files[upload_pos as usize].clone());
        }
        self.files = sorted_files;
        upload_to_sorted
    }

    /// Serialize to the on-store JSON form.
    pub fn to_json(&self) -> Result<Vec<u8>> {
        let files: Vec<Json> = self
            .files
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("path", Json::str(f.path.clone())),
                    ("chunk", Json::num(f.chunk as f64)),
                    ("offset", Json::num(f.offset as f64)),
                    ("len", Json::num(f.len as f64)),
                ])
            })
            .collect();
        let chunks: Vec<Json> = self
            .chunks
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("id", Json::num(c.id as f64)),
                    ("len", Json::num(c.len as f64)),
                    // hex string: a u64 digest does not survive the f64
                    // round-trip JSON numbers take
                    ("hash", Json::str(format!("{:016x}", c.hash))),
                ])
            })
            .collect();
        Ok(Json::obj(vec![
            ("chunk_size", Json::num(self.chunk_size as f64)),
            ("files", Json::Arr(files)),
            ("chunks", Json::Arr(chunks)),
        ])
        .to_bytes())
    }

    /// Parse the on-store JSON form back into a manifest.
    pub fn from_json(data: &[u8]) -> Result<Self> {
        let v = Json::parse_bytes(data)?;
        let files = v
            .req_arr("files")?
            .iter()
            .map(|f| {
                Ok(FileEntry {
                    path: f.req_str("path")?.to_string(),
                    chunk: f.req_u64("chunk")? as u32,
                    offset: f.req_u64("offset")?,
                    len: f.req_u64("len")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let chunks = v
            .req_arr("chunks")?
            .iter()
            .map(|c| {
                // digest is optional: manifests written before it existed
                // (or by other tools) parse with hash 0 = "unknown"
                let hash = c
                    .get("hash")
                    .and_then(|h| h.as_str())
                    .and_then(|h| u64::from_str_radix(h, 16).ok())
                    .unwrap_or(0);
                Ok(ChunkRef { id: c.req_u64("id")? as u32, len: c.req_u64("len")?, hash })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(FsManifest { chunk_size: v.req_u64("chunk_size")?, files, chunks })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(path: &str, chunk: u32) -> FileEntry {
        FileEntry { path: path.into(), chunk, offset: 0, len: 1 }
    }

    #[test]
    fn find_and_list_after_seal() {
        let mut m = FsManifest::new(1024);
        m.files = vec![entry("b/2", 0), entry("a/1", 0), entry("b/1", 1)];
        m.seal();
        assert!(m.find("a/1").is_ok());
        assert!(m.find("missing").is_err());
        let listed: Vec<_> = m.list("b/").iter().map(|f| f.path.clone()).collect();
        assert_eq!(listed, vec!["b/1", "b/2"]);
    }

    #[test]
    fn seal_preserves_upload_order_mapping() {
        let mut m = FsManifest::new(1024);
        m.files = vec![entry("c", 0), entry("a", 1), entry("b", 2)];
        let upload_to_sorted = m.seal();
        // upload order was c, a, b; sorted is a, b, c
        assert_eq!(upload_to_sorted, vec![2, 0, 1]);
        assert_eq!(m.files[0].path, "a");
    }

    #[test]
    fn json_roundtrip() {
        let mut m = FsManifest::new(4096);
        m.files = vec![entry("x", 0)];
        m.chunks = vec![ChunkRef { id: 0, len: 1, hash: 0xdead_beef_dead_beef }];
        let j = m.to_json().unwrap();
        let back = FsManifest::from_json(&j).unwrap();
        assert_eq!(back.files, m.files);
        assert_eq!(back.chunks, m.chunks, "digest survives the JSON round-trip");
        assert_eq!(back.chunk_size, 4096);
    }

    #[test]
    fn manifest_without_digests_parses_with_hash_zero() {
        // manifests written before chunk digests existed must still mount
        let j = br#"{"chunk_size": 64, "files": [], "chunks": [{"id": 0, "len": 10}]}"#;
        let m = FsManifest::from_json(j).unwrap();
        assert_eq!(m.chunks[0].hash, 0, "absent digest reads as unknown");
    }

    #[test]
    fn digest_is_stable_and_content_sensitive() {
        assert_eq!(fnv1a64(b"abc"), fnv1a64(b"abc"));
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"abd"));
        assert_ne!(fnv1a64(b""), fnv1a64(b"\0"));
    }

    #[test]
    fn keys() {
        assert_eq!(FsManifest::chunk_key("ns", 3), "ns/chunks/00000003");
        assert_eq!(FsManifest::manifest_key("ns"), "ns/manifest.json");
    }
}
