//! On-store layout of an HFS namespace.
//!
//! A namespace `ns` occupies:
//!
//! ```text
//! <ns>/manifest.json      — FsManifest: file table + chunk table
//! <ns>/chunks/<id>        — packed chunk objects
//! ```
//!
//! Files are packed *in upload order*, which for deep-learning datasets is
//! the order loaders will read them — that locality is what makes the
//! next-file-in-same-chunk lookahead (§III.A) effective.


use crate::util::Json;
use crate::{Error, Result};

/// A file inside the namespace: where it lives in which chunk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileEntry {
    pub path: String,
    pub chunk: u32,
    pub offset: u64,
    pub len: u64,
}

/// A chunk object and its total size.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkRef {
    pub id: u32,
    pub len: u64,
}

/// The namespace manifest: ordered file table plus chunk table.
#[derive(Debug, Clone, Default)]
pub struct FsManifest {
    pub chunk_size: u64,
    /// Files in upload (≈ read) order.
    pub files: Vec<FileEntry>,
    pub chunks: Vec<ChunkRef>,
}

impl FsManifest {
    pub fn new(chunk_size: u64) -> Self {
        Self { chunk_size, files: Vec::new(), chunks: Vec::new() }
    }

    /// Index of the file with exactly this path.
    pub fn find(&self, path: &str) -> Result<usize> {
        // file table is sorted by path at seal time -> binary search
        self.files
            .binary_search_by(|f| f.path.as_str().cmp(path))
            .map_err(|_| Error::FileNotFound(path.to_string()))
    }

    /// Files under a directory prefix.
    pub fn list(&self, prefix: &str) -> Vec<&FileEntry> {
        let start = self.files.partition_point(|f| f.path.as_str() < prefix);
        self.files[start..]
            .iter()
            .take_while(|f| f.path.starts_with(prefix))
            .collect()
    }

    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|f| f.len).sum()
    }

    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    /// Key of a chunk object within the namespace.
    pub fn chunk_key(ns: &str, id: u32) -> String {
        format!("{ns}/chunks/{id:08}")
    }

    pub fn manifest_key(ns: &str) -> String {
        format!("{ns}/manifest.json")
    }

    /// Sort the file table by path (called once at seal time) while
    /// recording the upload order needed by the sequential prefetcher.
    /// Returns `read_order[i] = index into files` for the i-th uploaded file.
    pub(crate) fn seal(&mut self) -> Vec<u32> {
        let mut order: Vec<u32> = (0..self.files.len() as u32).collect();
        order.sort_by(|&a, &b| self.files[a as usize].path.cmp(&self.files[b as usize].path));
        // order maps sorted-pos -> upload-pos; invert to upload-pos -> sorted-pos
        let mut sorted_files = Vec::with_capacity(self.files.len());
        let mut upload_to_sorted = vec![0u32; self.files.len()];
        for (sorted_pos, &upload_pos) in order.iter().enumerate() {
            upload_to_sorted[upload_pos as usize] = sorted_pos as u32;
            sorted_files.push(self.files[upload_pos as usize].clone());
        }
        self.files = sorted_files;
        upload_to_sorted
    }

    pub fn to_json(&self) -> Result<Vec<u8>> {
        let files: Vec<Json> = self
            .files
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("path", Json::str(f.path.clone())),
                    ("chunk", Json::num(f.chunk as f64)),
                    ("offset", Json::num(f.offset as f64)),
                    ("len", Json::num(f.len as f64)),
                ])
            })
            .collect();
        let chunks: Vec<Json> = self
            .chunks
            .iter()
            .map(|c| {
                Json::obj(vec![("id", Json::num(c.id as f64)), ("len", Json::num(c.len as f64))])
            })
            .collect();
        Ok(Json::obj(vec![
            ("chunk_size", Json::num(self.chunk_size as f64)),
            ("files", Json::Arr(files)),
            ("chunks", Json::Arr(chunks)),
        ])
        .to_bytes())
    }

    pub fn from_json(data: &[u8]) -> Result<Self> {
        let v = Json::parse_bytes(data)?;
        let files = v
            .req_arr("files")?
            .iter()
            .map(|f| {
                Ok(FileEntry {
                    path: f.req_str("path")?.to_string(),
                    chunk: f.req_u64("chunk")? as u32,
                    offset: f.req_u64("offset")?,
                    len: f.req_u64("len")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let chunks = v
            .req_arr("chunks")?
            .iter()
            .map(|c| Ok(ChunkRef { id: c.req_u64("id")? as u32, len: c.req_u64("len")? }))
            .collect::<Result<Vec<_>>>()?;
        Ok(FsManifest { chunk_size: v.req_u64("chunk_size")?, files, chunks })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(path: &str, chunk: u32) -> FileEntry {
        FileEntry { path: path.into(), chunk, offset: 0, len: 1 }
    }

    #[test]
    fn find_and_list_after_seal() {
        let mut m = FsManifest::new(1024);
        m.files = vec![entry("b/2", 0), entry("a/1", 0), entry("b/1", 1)];
        m.seal();
        assert!(m.find("a/1").is_ok());
        assert!(m.find("missing").is_err());
        let listed: Vec<_> = m.list("b/").iter().map(|f| f.path.clone()).collect();
        assert_eq!(listed, vec!["b/1", "b/2"]);
    }

    #[test]
    fn seal_preserves_upload_order_mapping() {
        let mut m = FsManifest::new(1024);
        m.files = vec![entry("c", 0), entry("a", 1), entry("b", 2)];
        let upload_to_sorted = m.seal();
        // upload order was c, a, b; sorted is a, b, c
        assert_eq!(upload_to_sorted, vec![2, 0, 1]);
        assert_eq!(m.files[0].path, "a");
    }

    #[test]
    fn json_roundtrip() {
        let mut m = FsManifest::new(4096);
        m.files = vec![entry("x", 0)];
        m.chunks = vec![ChunkRef { id: 0, len: 1 }];
        let j = m.to_json().unwrap();
        let back = FsManifest::from_json(&j).unwrap();
        assert_eq!(back.files, m.files);
        assert_eq!(back.chunk_size, 4096);
    }

    #[test]
    fn keys() {
        assert_eq!(FsManifest::chunk_key("ns", 3), "ns/chunks/00000003");
        assert_eq!(FsManifest::manifest_key("ns"), "ns/manifest.json");
    }
}
