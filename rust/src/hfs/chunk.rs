//! On-store layout of an HFS namespace.
//!
//! A namespace `ns` written by the current uploader (format 2) occupies:
//!
//! ```text
//! <ns>/manifest.json                — RootManifest: counts + shard map (small)
//! <ns>/manifest/shard-<i>.json      — one file-table shard (lazy-loaded)
//! <ns>/manifest/chunks.json         — the chunk table (lazy-loaded)
//! cas/chunks/<digest>               — content-addressed chunk objects
//! ```
//!
//! Mounting parses only the root, so mount cost is O(shards touched), not
//! O(files); the file-table shards and the chunk table load on first
//! touch. Chunk objects are keyed by their FNV-1a content digest, so
//! identical chunks across files and namespaces are stored once.
//!
//! A *legacy* (format 1) namespace is one monolithic manifest plus
//! namespace-keyed chunks, still fully supported for reading:
//!
//! ```text
//! <ns>/manifest.json      — FsManifest: file table + chunk table
//! <ns>/chunks/<id>        — packed chunk objects
//! ```
//!
//! Files are packed *in upload order*, which for deep-learning datasets is
//! the order loaders will read them — that locality is what makes the
//! next-file-in-same-chunk lookahead (§III.A) effective. Files below the
//! configured packing threshold can additionally be packed into tar-like
//! archive chunks (see [`iter_archive`]); their [`FileEntry`] offsets
//! point directly at the payload inside the archive, so reads need no
//! archive parsing.

use std::collections::HashMap;

use crate::util::Json;
use crate::{Error, Result};

/// Manifest format written by the sharded uploader. A root manifest
/// carries `"format": 2`; the field's *presence* (with value >= 2) is
/// what legacy readers trip over, loudly.
pub const SHARDED_FORMAT: u64 = 2;

/// A file inside the namespace: where it lives in which chunk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileEntry {
    /// Full path within the namespace.
    pub path: String,
    /// Id of the chunk holding this file's bytes.
    pub chunk: u32,
    /// Byte offset of the file within its chunk. For a file packed into
    /// an archive chunk this points directly at the payload, past the
    /// in-archive header.
    pub offset: u64,
    /// File length in bytes.
    pub len: u64,
}

/// A chunk object, its total size, and its content digest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkRef {
    /// Chunk id (also its position in the chunk table).
    pub id: u32,
    /// Packed size of the chunk object in bytes.
    pub len: u64,
    /// FNV-1a 64 digest of the chunk bytes, recorded at upload time; the
    /// spill tier verifies spilled files against it so a rebuilt
    /// namespace invalidates stale disk data even at identical lengths.
    /// `0` = unknown (manifest written before digests existed): length
    /// checks still apply, digest checks are skipped.
    pub hash: u64,
    /// True for an archive chunk holding many small packed files. Packed
    /// chunks are always fetched whole (the archive is the locality
    /// unit), never via byte-range GETs.
    pub packed: bool,
}

/// 64-bit FNV-1a — the chunk content digest recorded in manifests at
/// upload time and re-verified by the spill tier before serving. Also
/// the hash behind [`PathIndex`] and the content-addressed chunk keys.
pub(crate) fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Key of a content-addressed chunk object. All namespaces share the
/// `cas/` tree, so identical chunks uploaded through different
/// namespaces land on one stored object.
pub fn cas_chunk_key(digest: u64) -> String {
    format!("cas/chunks/{digest:016x}")
}

/// The namespace manifest: ordered file table plus chunk table.
///
/// This is the in-RAM form; on-store it is either one monolithic legacy
/// JSON object or a [`RootManifest`] plus shard files.
#[derive(Debug, Clone, Default)]
pub struct FsManifest {
    /// Target chunk size the namespace was packed with.
    pub chunk_size: u64,
    /// Files, sorted by path after seal (upload order before).
    pub files: Vec<FileEntry>,
    /// Chunk table, in id order.
    pub chunks: Vec<ChunkRef>,
}

impl FsManifest {
    /// An empty manifest packing into `chunk_size`-byte chunks.
    pub fn new(chunk_size: u64) -> Self {
        Self { chunk_size, files: Vec::new(), chunks: Vec::new() }
    }

    /// Index of the file with exactly this path.
    pub fn find(&self, path: &str) -> Result<usize> {
        // file table is sorted by path at seal time -> binary search
        self.files
            .binary_search_by(|f| f.path.as_str().cmp(path))
            .map_err(|_| Error::FileNotFound(path.to_string()))
    }

    /// Files under a directory prefix.
    pub fn list(&self, prefix: &str) -> Vec<&FileEntry> {
        let start = self.files.partition_point(|f| f.path.as_str() < prefix);
        self.files[start..]
            .iter()
            .take_while(|f| f.path.starts_with(prefix))
            .collect()
    }

    /// Total payload bytes across all files.
    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|f| f.len).sum()
    }

    /// Number of files in the namespace.
    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    /// Key of a legacy namespace-scoped chunk object.
    pub fn chunk_key(ns: &str, id: u32) -> String {
        format!("{ns}/chunks/{id:08}")
    }

    /// Key of the namespace's manifest object (root or legacy).
    pub fn manifest_key(ns: &str) -> String {
        format!("{ns}/manifest.json")
    }

    /// Sort the file table by path (called once at seal time) while
    /// recording the upload order needed by the sequential prefetcher.
    /// Returns `read_order[i] = index into files` for the i-th uploaded file.
    pub(crate) fn seal(&mut self) -> Vec<u32> {
        let mut order: Vec<u32> = (0..self.files.len() as u32).collect();
        order.sort_by(|&a, &b| self.files[a as usize].path.cmp(&self.files[b as usize].path));
        // order maps sorted-pos -> upload-pos; invert to upload-pos -> sorted-pos
        let mut sorted_files = Vec::with_capacity(self.files.len());
        let mut upload_to_sorted = vec![0u32; self.files.len()];
        for (sorted_pos, &upload_pos) in order.iter().enumerate() {
            upload_to_sorted[upload_pos as usize] = sorted_pos as u32;
            sorted_files.push(self.files[upload_pos as usize].clone());
        }
        self.files = sorted_files;
        upload_to_sorted
    }

    /// Serialize to the monolithic (legacy, format 1) on-store JSON form.
    pub fn to_json(&self) -> Result<Vec<u8>> {
        let files: Vec<Json> = self.files.iter().map(file_to_json).collect();
        let chunks: Vec<Json> = self.chunks.iter().map(chunk_to_json).collect();
        Ok(Json::obj(vec![
            ("chunk_size", Json::num(self.chunk_size as f64)),
            ("files", Json::Arr(files)),
            ("chunks", Json::Arr(chunks)),
        ])
        .to_bytes())
    }

    /// Parse the monolithic on-store JSON form back into a manifest.
    ///
    /// A sharded (format 2) root manifest is rejected with an explicit
    /// error, never silently parsed as an empty namespace.
    pub fn from_json(data: &[u8]) -> Result<Self> {
        let v = Json::parse_bytes(data)?;
        if let Some(format) = v.get("format").and_then(Json::as_u64) {
            if format >= SHARDED_FORMAT {
                return Err(Error::Json(format!(
                    "manifest format {format} is sharded; a legacy monolithic reader cannot \
                     mount it — use a sharded-manifest-capable reader (HyperFs::mount)"
                )));
            }
        }
        let files = v
            .req_arr("files")?
            .iter()
            .map(file_from_json)
            .collect::<Result<Vec<_>>>()?;
        let chunks = v
            .req_arr("chunks")?
            .iter()
            .map(chunk_from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(FsManifest { chunk_size: v.req_u64("chunk_size")?, files, chunks })
    }
}

fn file_to_json(f: &FileEntry) -> Json {
    Json::obj(vec![
        ("path", Json::str(f.path.clone())),
        ("chunk", Json::num(f.chunk as f64)),
        ("offset", Json::num(f.offset as f64)),
        ("len", Json::num(f.len as f64)),
    ])
}

fn file_from_json(f: &Json) -> Result<FileEntry> {
    Ok(FileEntry {
        path: f.req_str("path")?.to_string(),
        chunk: f.req_u64("chunk")? as u32,
        offset: f.req_u64("offset")?,
        len: f.req_u64("len")?,
    })
}

fn chunk_to_json(c: &ChunkRef) -> Json {
    let mut pairs = vec![
        ("id", Json::num(c.id as f64)),
        ("len", Json::num(c.len as f64)),
        // hex string: a u64 digest does not survive the f64 round-trip
        // JSON numbers take
        ("hash", Json::str(format!("{:016x}", c.hash))),
    ];
    if c.packed {
        // only archive chunks carry the flag, keeping plain manifests
        // byte-identical to what pre-packing writers produced
        pairs.push(("packed", Json::Bool(true)));
    }
    Json::obj(pairs)
}

fn chunk_from_json(c: &Json) -> Result<ChunkRef> {
    // digest is optional: manifests written before it existed (or by
    // other tools) parse with hash 0 = "unknown"
    let hash = c
        .get("hash")
        .and_then(|h| h.as_str())
        .and_then(|h| u64::from_str_radix(h, 16).ok())
        .unwrap_or(0);
    let packed = c.get("packed").and_then(Json::as_bool).unwrap_or(false);
    Ok(ChunkRef { id: c.req_u64("id")? as u32, len: c.req_u64("len")?, hash, packed })
}

/// Serialize one file-table shard (`<ns>/manifest/shard-<i>.json`).
pub(crate) fn shard_to_json(files: &[FileEntry]) -> Vec<u8> {
    Json::obj(vec![("files", Json::Arr(files.iter().map(file_to_json).collect()))]).to_bytes()
}

/// Parse one file-table shard.
pub(crate) fn shard_from_json(data: &[u8]) -> Result<Vec<FileEntry>> {
    Json::parse_bytes(data)?.req_arr("files")?.iter().map(file_from_json).collect()
}

/// Serialize the chunk table (`<ns>/manifest/chunks.json`).
pub(crate) fn chunk_table_to_json(chunks: &[ChunkRef]) -> Vec<u8> {
    Json::obj(vec![("chunks", Json::Arr(chunks.iter().map(chunk_to_json).collect()))]).to_bytes()
}

/// Parse the chunk table.
pub(crate) fn chunk_table_from_json(data: &[u8]) -> Result<Vec<ChunkRef>> {
    Json::parse_bytes(data)?.req_arr("chunks")?.iter().map(chunk_from_json).collect()
}

/// One file-table shard in the root manifest's shard map.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardRef {
    /// First (lexicographically smallest) path in the shard. Shards
    /// partition the sorted file table, so shard `i` covers paths in
    /// `[start_i, start_{i+1})`.
    pub start: String,
    /// Number of files in the shard.
    pub files: u64,
}

/// The small root manifest of a sharded (format 2) namespace: aggregate
/// counts plus the shard map. Parsing it is all a mount pays up front.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RootManifest {
    /// Target chunk size the namespace was packed with.
    pub chunk_size: u64,
    /// Files across all shards.
    pub file_count: u64,
    /// Payload bytes across all files.
    pub total_bytes: u64,
    /// Entries in the (lazily loaded) chunk table.
    pub chunk_count: u64,
    /// Largest chunk object length — the mount-time cache sizing hint,
    /// available without loading the chunk table.
    pub max_chunk_len: u64,
    /// True when chunk objects live under content-addressed
    /// [`cas_chunk_key`] keys rather than legacy `<ns>/chunks/` keys.
    pub content_addressed: bool,
    /// The shard map, ordered by `start`.
    pub shards: Vec<ShardRef>,
}

impl RootManifest {
    /// Key of file-table shard `i` within the namespace.
    pub fn shard_key(ns: &str, i: usize) -> String {
        format!("{ns}/manifest/shard-{i:05}.json")
    }

    /// Key of the namespace's chunk table.
    pub fn chunk_table_key(ns: &str) -> String {
        format!("{ns}/manifest/chunks.json")
    }

    /// Index of the shard that would contain `path`, or `None` when
    /// `path` sorts before every shard (and thus cannot exist).
    pub fn shard_for(&self, path: &str) -> Option<usize> {
        self.shards.partition_point(|s| s.start.as_str() <= path).checked_sub(1)
    }

    /// Serialize to the on-store root JSON. Deliberately carries no
    /// `"files"` key: a legacy reader fed this object must fail its
    /// required-field check rather than mount an empty namespace.
    pub fn to_json(&self) -> Vec<u8> {
        let shards: Vec<Json> = self
            .shards
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("start", Json::str(s.start.clone())),
                    ("files", Json::num(s.files as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("format", Json::num(SHARDED_FORMAT as f64)),
            ("chunk_size", Json::num(self.chunk_size as f64)),
            ("file_count", Json::num(self.file_count as f64)),
            ("total_bytes", Json::num(self.total_bytes as f64)),
            ("chunk_count", Json::num(self.chunk_count as f64)),
            ("max_chunk_len", Json::num(self.max_chunk_len as f64)),
            ("content_addressed", Json::Bool(self.content_addressed)),
            ("shards", Json::Arr(shards)),
        ])
        .to_bytes()
    }

    /// Parse the on-store root JSON (requires `"format" >= 2`).
    pub fn from_json(data: &[u8]) -> Result<Self> {
        let v = Json::parse_bytes(data)?;
        let format = v.req_u64("format")?;
        if format < SHARDED_FORMAT {
            return Err(Error::Json(format!("not a sharded root manifest (format {format})")));
        }
        let shards = v
            .req_arr("shards")?
            .iter()
            .map(|s| {
                Ok(ShardRef { start: s.req_str("start")?.to_string(), files: s.req_u64("files")? })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RootManifest {
            chunk_size: v.req_u64("chunk_size")?,
            file_count: v.req_u64("file_count")?,
            total_bytes: v.req_u64("total_bytes")?,
            chunk_count: v.req_u64("chunk_count")?,
            max_chunk_len: v.req_u64("max_chunk_len")?,
            content_addressed: v.get("content_addressed").and_then(Json::as_bool).unwrap_or(false),
            shards,
        })
    }
}

/// O(1) expected-time path lookup over a sorted file table — built once
/// at parse time per shard (and for whole legacy manifests), replacing
/// per-read binary searches with one hash probe.
///
/// Collisions (two paths sharing an FNV-1a hash) are handled by an
/// overflow list verified by full path comparison, so a lookup can never
/// return the wrong file.
#[derive(Debug, Default)]
pub struct PathIndex {
    map: HashMap<u64, u32>,
    /// Indices whose path hash collided with an earlier entry.
    overflow: Vec<u32>,
}

impl PathIndex {
    /// Build the index over `files`.
    pub fn build(files: &[FileEntry]) -> Self {
        let mut map = HashMap::with_capacity(files.len());
        let mut overflow = Vec::new();
        for (i, f) in files.iter().enumerate() {
            match map.entry(fnv1a64(f.path.as_bytes())) {
                std::collections::hash_map::Entry::Vacant(slot) => {
                    slot.insert(i as u32);
                }
                std::collections::hash_map::Entry::Occupied(_) => overflow.push(i as u32),
            }
        }
        Self { map, overflow }
    }

    /// Index of `path` in the `files` slice the index was built over.
    pub fn find(&self, files: &[FileEntry], path: &str) -> Option<usize> {
        let i = *self.map.get(&fnv1a64(path.as_bytes()))? as usize;
        if files[i].path == path {
            return Some(i);
        }
        // hash collision: fall back to the (near-empty) overflow list
        self.overflow
            .iter()
            .map(|&j| j as usize)
            .find(|&j| files[j].path == path)
    }
}

// ---------------------------------------------------------------- packing

/// Fixed bytes of one in-archive header: `[u32 LE payload len]`
/// `[u16 LE path len]`, followed by the path bytes, then the payload.
pub(crate) const PACK_HEADER_FIXED: usize = 6;

/// Append one small file to an archive chunk buffer, returning the byte
/// offset *of the payload* within the archive — the offset recorded in
/// the file's [`FileEntry`], so reads index straight into the payload
/// with no archive parsing. The interleaved headers make the archive
/// self-describing for recovery tooling (see [`iter_archive`]).
pub(crate) fn pack_append(buf: &mut Vec<u8>, path: &str, data: &[u8]) -> u64 {
    debug_assert!(path.len() <= u16::MAX as usize, "pack path too long");
    buf.extend_from_slice(&(data.len() as u32).to_le_bytes());
    buf.extend_from_slice(&(path.len() as u16).to_le_bytes());
    buf.extend_from_slice(path.as_bytes());
    let payload_offset = buf.len() as u64;
    buf.extend_from_slice(data);
    payload_offset
}

/// Iterate `(path, payload offset, payload)` entries of an archive chunk
/// written by the uploader's packer. Iteration stops at the first malformed
/// header (truncated archive). Used by tests and recovery tooling — the
/// read path never parses archives, it indexes via [`FileEntry`].
pub fn iter_archive(chunk: &[u8]) -> ArchiveIter<'_> {
    ArchiveIter { chunk, pos: 0 }
}

/// Iterator over the packed entries of an archive chunk.
pub struct ArchiveIter<'a> {
    chunk: &'a [u8],
    pos: usize,
}

impl<'a> Iterator for ArchiveIter<'a> {
    type Item = (String, u64, &'a [u8]);

    fn next(&mut self) -> Option<Self::Item> {
        let rest = &self.chunk[self.pos..];
        if rest.len() < PACK_HEADER_FIXED {
            return None;
        }
        let data_len = u32::from_le_bytes(rest[0..4].try_into().ok()?) as usize;
        let path_len = u16::from_le_bytes(rest[4..6].try_into().ok()?) as usize;
        let header = PACK_HEADER_FIXED;
        if rest.len() < header + path_len + data_len {
            return None;
        }
        let path = std::str::from_utf8(&rest[header..header + path_len]).ok()?.to_string();
        let payload_offset = (self.pos + header + path_len) as u64;
        let payload = &rest[header + path_len..header + path_len + data_len];
        self.pos += header + path_len + data_len;
        Some((path, payload_offset, payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(path: &str, chunk: u32) -> FileEntry {
        FileEntry { path: path.into(), chunk, offset: 0, len: 1 }
    }

    #[test]
    fn find_and_list_after_seal() {
        let mut m = FsManifest::new(1024);
        m.files = vec![entry("b/2", 0), entry("a/1", 0), entry("b/1", 1)];
        m.seal();
        assert!(m.find("a/1").is_ok());
        assert!(m.find("missing").is_err());
        let listed: Vec<_> = m.list("b/").iter().map(|f| f.path.clone()).collect();
        assert_eq!(listed, vec!["b/1", "b/2"]);
    }

    #[test]
    fn seal_preserves_upload_order_mapping() {
        let mut m = FsManifest::new(1024);
        m.files = vec![entry("c", 0), entry("a", 1), entry("b", 2)];
        let upload_to_sorted = m.seal();
        // upload order was c, a, b; sorted is a, b, c
        assert_eq!(upload_to_sorted, vec![2, 0, 1]);
        assert_eq!(m.files[0].path, "a");
    }

    #[test]
    fn json_roundtrip() {
        let mut m = FsManifest::new(4096);
        m.files = vec![entry("x", 0)];
        m.chunks =
            vec![ChunkRef { id: 0, len: 1, hash: 0xdead_beef_dead_beef, packed: false }];
        let j = m.to_json().unwrap();
        let back = FsManifest::from_json(&j).unwrap();
        assert_eq!(back.files, m.files);
        assert_eq!(back.chunks, m.chunks, "digest survives the JSON round-trip");
        assert_eq!(back.chunk_size, 4096);
    }

    #[test]
    fn packed_flag_roundtrips_and_defaults_off() {
        let mut m = FsManifest::new(4096);
        m.chunks = vec![
            ChunkRef { id: 0, len: 1, hash: 1, packed: true },
            ChunkRef { id: 1, len: 1, hash: 2, packed: false },
        ];
        let back = FsManifest::from_json(&m.to_json().unwrap()).unwrap();
        assert!(back.chunks[0].packed);
        assert!(!back.chunks[1].packed);
        // pre-packing manifests (no "packed" key at all) parse as unpacked
        let j = br#"{"chunk_size": 64, "files": [], "chunks": [{"id": 0, "len": 10}]}"#;
        assert!(!FsManifest::from_json(j).unwrap().chunks[0].packed);
    }

    #[test]
    fn manifest_without_digests_parses_with_hash_zero() {
        // manifests written before chunk digests existed must still mount
        let j = br#"{"chunk_size": 64, "files": [], "chunks": [{"id": 0, "len": 10}]}"#;
        let m = FsManifest::from_json(j).unwrap();
        assert_eq!(m.chunks[0].hash, 0, "absent digest reads as unknown");
    }

    #[test]
    fn digest_is_stable_and_content_sensitive() {
        assert_eq!(fnv1a64(b"abc"), fnv1a64(b"abc"));
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"abd"));
        assert_ne!(fnv1a64(b""), fnv1a64(b"\0"));
    }

    #[test]
    fn keys() {
        assert_eq!(FsManifest::chunk_key("ns", 3), "ns/chunks/00000003");
        assert_eq!(FsManifest::manifest_key("ns"), "ns/manifest.json");
        assert_eq!(RootManifest::shard_key("ns", 3), "ns/manifest/shard-00003.json");
        assert_eq!(RootManifest::chunk_table_key("ns"), "ns/manifest/chunks.json");
        assert_eq!(cas_chunk_key(0xdead_beef), "cas/chunks/00000000deadbeef");
    }

    fn sample_root() -> RootManifest {
        RootManifest {
            chunk_size: 1024,
            file_count: 5,
            total_bytes: 999,
            chunk_count: 2,
            max_chunk_len: 700,
            content_addressed: true,
            shards: vec![
                ShardRef { start: "a/0".into(), files: 3 },
                ShardRef { start: "m/0".into(), files: 2 },
            ],
        }
    }

    #[test]
    fn root_manifest_roundtrip() {
        let root = sample_root();
        let back = RootManifest::from_json(&root.to_json()).unwrap();
        assert_eq!(back, root);
    }

    #[test]
    fn legacy_reader_rejects_sharded_root_loudly() {
        let err = FsManifest::from_json(&sample_root().to_json()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("sharded"), "error must name the format problem: {msg}");
    }

    #[test]
    fn sharded_reader_rejects_legacy_manifest() {
        let legacy = FsManifest::new(64).to_json().unwrap();
        assert!(RootManifest::from_json(&legacy).is_err());
    }

    #[test]
    fn shard_routing() {
        let root = sample_root();
        assert_eq!(root.shard_for("a/0"), Some(0));
        assert_eq!(root.shard_for("c/9"), Some(0));
        assert_eq!(root.shard_for("m/0"), Some(1));
        assert_eq!(root.shard_for("z/z"), Some(1));
        assert_eq!(root.shard_for("A-sorts-first"), None);
    }

    #[test]
    fn shard_and_chunk_table_roundtrip() {
        let files = vec![entry("a", 0), entry("b", 1)];
        assert_eq!(shard_from_json(&shard_to_json(&files)).unwrap(), files);
        let chunks = vec![ChunkRef { id: 0, len: 9, hash: 42, packed: true }];
        assert_eq!(chunk_table_from_json(&chunk_table_to_json(&chunks)).unwrap(), chunks);
    }

    #[test]
    fn path_index_finds_exactly_the_right_file() {
        let files: Vec<FileEntry> =
            (0..100).map(|i| entry(&format!("train/{i:06}.bin"), 0)).collect();
        let idx = PathIndex::build(&files);
        for (i, f) in files.iter().enumerate() {
            assert_eq!(idx.find(&files, &f.path), Some(i));
        }
        assert_eq!(idx.find(&files, "train/000100.bin"), None);
        assert_eq!(idx.find(&files, ""), None);
    }

    #[test]
    fn archive_roundtrip() {
        let mut buf = Vec::new();
        let off_a = pack_append(&mut buf, "small/a", b"aaaa");
        let off_b = pack_append(&mut buf, "small/b", b"bb");
        // FileEntry-style direct indexing hits the payloads
        assert_eq!(&buf[off_a as usize..off_a as usize + 4], b"aaaa");
        assert_eq!(&buf[off_b as usize..off_b as usize + 2], b"bb");
        // the self-describing walk recovers paths and payloads
        let entries: Vec<_> = iter_archive(&buf).collect();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0], ("small/a".to_string(), off_a, &b"aaaa"[..]));
        assert_eq!(entries[1], ("small/b".to_string(), off_b, &b"bb"[..]));
        // a truncated archive stops cleanly instead of panicking
        let cut = &buf[..buf.len() - 1];
        assert_eq!(iter_archive(cut).count(), 1);
    }
}
