//! [`SpillTier`]: the bounded local-disk second tier of the chunk cache.
//!
//! The paper's cost story depends on cheap unstable nodes staying fed
//! from object storage; that only works if hot data stays *near* compute.
//! The RAM [`super::ChunkCache`] used to be the whole story — an evicted
//! chunk was simply dropped and the next read paid a full network fetch.
//! This tier catches RAM evictions on node-local disk (the FfDL-style
//! NVMe tier between object storage and workers), so a later miss
//! promotes the chunk back into RAM at disk speed without touching the
//! object store:
//!
//! ```text
//!   read_file ── RAM LRU hit ──────────────► ByteView      (ns)
//!        │ miss
//!        ├──── SpillTier hit ─► promote to RAM ─► ByteView (disk, ~100 µs)
//!        │ miss                      │ RAM eviction
//!        └──── ObjectStore GET ──────┴─► SpillTier::put    (network, ~100 ms)
//! ```
//!
//! Design points:
//!
//! * **Content-addressed by `(namespace, chunk id)`.** Every spill file
//!   name carries the chunk id, its byte length, and an FNV-1a 64-bit
//!   digest of its content. A read verifies three things before a single
//!   byte is served: the length against the caller's manifest, the bytes
//!   against the digest in the file's own name (truncation, bit rot),
//!   and that digest against the *manifest-recorded* chunk digest (a
//!   namespace rebuilt with identical chunk sizes but different content
//!   must not serve yesterday's bytes). Any mismatch purges the entry
//!   and falls back to the object store. The tier can therefore be
//!   pointed at a *pre-existing* spill directory after a crash/restart
//!   and either reuse valid chunks or safely ignore stale ones.
//! * **Bounded, LRU by file size.** A byte budget caps the directory;
//!   eviction removes least-recently-used files first.
//! * **fsync-free, atomic writes.** Files appear via write-then-rename
//!   (through [`DiskStore`]), so readers never observe partial writes;
//!   durability is *not* promised — this is a cache, and a lost spill
//!   file is just a future miss.
//! * **Best-effort.** I/O errors on the spill path never fail a read;
//!   they only cost the fallback fetch.
//!
//! Concurrency: callers ([`super::HyperFs`]) route demand-miss probes
//! through the same single-flight table as object-store fetches, so
//! concurrent misses issue at most one disk load, and eviction writes run
//! on the shared fetch lanes so they never block readers.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::metrics::Counter;
use crate::storage::{DiskStore, ObjectStore};
use crate::Result;

use super::chunk::fnv1a64;
use super::view::{ChunkBytes, ChunkData};

/// Index entry for one spilled chunk (the bytes live on disk).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Entry {
    len: u64,
    hash: u64,
    /// Monotonic recency stamp; the smallest stamp is the LRU victim.
    stamp: u64,
}

/// In-RAM index over the spill directory. `by_stamp` mirrors `entries`
/// in recency order (stamps are unique), so the LRU victim is O(log n)
/// instead of a full-table scan under the mutex.
#[derive(Default)]
struct Index {
    entries: HashMap<u64, Entry>,
    /// stamp -> id; the first key is the LRU victim.
    by_stamp: BTreeMap<u64, u64>,
    used_bytes: u64,
    clock: u64,
}

impl Index {
    fn next_stamp(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Insert or replace `id`, returning the displaced entry, if any.
    fn insert(&mut self, id: u64, len: u64, hash: u64) -> Option<Entry> {
        let stamp = self.next_stamp();
        let old = self.entries.insert(id, Entry { len, hash, stamp });
        if let Some(o) = &old {
            self.by_stamp.remove(&o.stamp);
            self.used_bytes -= o.len;
        }
        self.by_stamp.insert(stamp, id);
        self.used_bytes += len;
        old
    }

    fn touch(&mut self, id: u64) {
        let stamp = self.next_stamp();
        if let Some(e) = self.entries.get_mut(&id) {
            self.by_stamp.remove(&e.stamp);
            e.stamp = stamp;
            self.by_stamp.insert(stamp, id);
        }
    }

    fn remove(&mut self, id: u64) -> Option<Entry> {
        let e = self.entries.remove(&id)?;
        self.by_stamp.remove(&e.stamp);
        self.used_bytes -= e.len;
        Some(e)
    }

    /// Least-recently-used id, O(log n).
    fn lru(&self) -> Option<u64> {
        self.by_stamp.first_key_value().map(|(_, id)| *id)
    }
}

/// Outcome of loading and verifying one spill file.
enum Load {
    /// Bytes verified against the index entry; safe to serve.
    Ok(ChunkData),
    /// The file disappeared (external cleanup); not a corruption event.
    Vanished,
    /// Length or digest mismatch; the entry must be purged.
    Corrupt,
}

/// Bounded on-disk LRU of chunks, keyed by the same `u64` content key as
/// the RAM cache (chunk digest, or a `(ns, id)` hash for legacy chunks).
pub struct SpillTier {
    store: DiskStore,
    ns: String,
    capacity_bytes: u64,
    /// Serve hits as mmap-backed views instead of read-copies (unix).
    #[cfg_attr(not(unix), allow(dead_code))]
    use_mmap: bool,
    index: Mutex<Index>,
    hits: Counter,
    writes: Counter,
    evictions: Counter,
    /// Entries purged because they failed the length/identity check.
    rejected: Counter,
}

impl SpillTier {
    /// Open (or create) the spill tier for namespace `ns` under `dir`,
    /// serving hits through the plain read-copy path.
    ///
    /// An existing directory is scanned: files whose names parse and whose
    /// ids are unique are adopted into the index (their integrity is
    /// verified lazily, on first read); everything else — junk names,
    /// duplicate ids from an interrupted rewrite — is deleted. The scan
    /// then enforces the byte budget, so shrinking `capacity_bytes`
    /// across a restart trims the directory immediately.
    pub fn open(dir: &Path, ns: &str, capacity_bytes: u64) -> Result<Self> {
        Self::open_with(dir, ns, capacity_bytes, false)
    }

    /// [`SpillTier::open`] with an explicit serving mode: `use_mmap`
    /// serves hits as mmap-backed [`ChunkBytes`] straight from page cache
    /// (digest-verified over the mapped bytes before a single byte is
    /// handed out; ignored on non-unix targets, and any mapping failure
    /// falls back to the read-copy path).
    pub fn open_with(dir: &Path, ns: &str, capacity_bytes: u64, use_mmap: bool) -> Result<Self> {
        let tier = Self {
            store: DiskStore::new(dir)?,
            ns: ns.to_string(),
            capacity_bytes,
            use_mmap,
            index: Mutex::new(Index::default()),
            hits: Counter::default(),
            writes: Counter::default(),
            evictions: Counter::default(),
            rejected: Counter::default(),
        };
        let prefix = format!("spill/{ns}/");
        // crash litter first: temp files from writers killed mid-spill
        // are invisible to list() and to the byte budget, so they must
        // be reclaimed here or they accumulate across preemption cycles
        tier.store.sweep_temp(&prefix);
        let mut junk = Vec::new();
        {
            let mut idx = tier.index.lock().unwrap();
            for key in tier.store.list(&prefix)? {
                match Self::parse_name(&key[prefix.len()..]) {
                    Some((id, len, hash)) if !idx.entries.contains_key(&id) => {
                        idx.insert(id, len, hash);
                    }
                    _ => junk.push(key),
                }
            }
        }
        for key in junk {
            let _ = tier.store.delete(&key);
        }
        tier.enforce_capacity();
        Ok(tier)
    }

    /// On-store key of one spilled chunk. The name is the whole identity:
    /// `spill/<ns>/<key hex>_<len>_<fnv1a64 hex>`.
    fn key(&self, id: u64, len: u64, hash: u64) -> String {
        format!("spill/{}/{id:016x}_{len}_{hash:016x}", self.ns)
    }

    /// Parse `<key hex>_<len>_<hash hex>` back out of a file name.
    fn parse_name(name: &str) -> Option<(u64, u64, u64)> {
        let mut parts = name.split('_');
        let id = u64::from_str_radix(parts.next()?, 16).ok()?;
        let len = parts.next()?.parse::<u64>().ok()?;
        let hash = u64::from_str_radix(parts.next()?, 16).ok()?;
        parts.next().is_none().then_some((id, len, hash))
    }

    /// Fetch a spilled chunk, refreshing its recency.
    ///
    /// `expected_len` and `expected_hash` are what the caller's manifest
    /// records for the chunk (`expected_hash` 0 = manifest predates
    /// digests; the digest check is skipped). An entry that disagrees
    /// with either is stale — the namespace was rebuilt — and is purged;
    /// the bytes read from disk must additionally match the digest in
    /// the file's own name (truncation, corruption), or the entry is
    /// purged and `None` returned. Stale or corrupt spill files are
    /// never served.
    pub fn get(&self, id: u64, expected_len: u64, expected_hash: u64) -> Option<ChunkData> {
        let entry = {
            let mut idx = self.index.lock().unwrap();
            let e = *idx.entries.get(&id)?;
            if e.len != expected_len || (expected_hash != 0 && e.hash != expected_hash) {
                idx.remove(id);
                drop(idx);
                self.rejected.inc();
                let _ = self.store.delete(&self.key(id, e.len, e.hash));
                return None;
            }
            idx.touch(id);
            e
        };
        let key = self.key(id, entry.len, entry.hash);
        let data = match self.load_verified(&key, &entry) {
            Load::Ok(data) => data,
            Load::Vanished => {
                // file vanished underneath us (external cleanup)
                self.forget_if_current(id, &entry);
                return None;
            }
            Load::Corrupt => {
                self.rejected.inc();
                // drop only OUR entry: a concurrent put may have replaced
                // it with a fresh one that must survive (its file has a
                // different name, so the delete below cannot touch it)
                self.forget_if_current(id, &entry);
                let _ = self.store.delete(&key);
                return None;
            }
        };
        // a clear() may have raced the disk read; do not resurrect
        match self.index.lock().unwrap().entries.get(&id) {
            Some(e) if e.len == entry.len && e.hash == entry.hash => {}
            _ => return None,
        }
        self.hits.inc();
        Some(data)
    }

    /// Load the payload behind `key` and verify length + digest against
    /// the index entry before anything is served. On unix with
    /// `use_mmap`, the bytes come back as an mmap-backed [`ChunkBytes`]
    /// (the digest is computed over the mapped pages — same guarantee,
    /// no heap copy); otherwise, or when mapping fails, a read-copy.
    fn load_verified(&self, key: &str, entry: &Entry) -> Load {
        #[cfg(unix)]
        if self.use_mmap {
            if let Ok(path) = self.store.path_of(key) {
                match ChunkBytes::map_file(&path) {
                    Ok(mapped) => {
                        if mapped.len() as u64 == entry.len && fnv1a64(&mapped) == entry.hash {
                            return Load::Ok(Arc::new(mapped));
                        }
                        return Load::Corrupt;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Load::Vanished,
                    // zero-length file, mmap exhaustion, …: read-copy below
                    Err(_) => {}
                }
            }
        }
        let bytes = match self.store.get(key) {
            Ok(b) => b,
            Err(_) => return Load::Vanished,
        };
        if bytes.len() as u64 != entry.len || fnv1a64(&bytes) != entry.hash {
            return Load::Corrupt;
        }
        Load::Ok(Arc::new(ChunkBytes::ram(bytes)))
    }

    /// Remove `id` from the index only if it still refers to the same
    /// payload as `entry` — failure paths must not clobber an entry a
    /// concurrent `put` just replaced.
    fn forget_if_current(&self, id: u64, entry: &Entry) {
        let mut idx = self.index.lock().unwrap();
        let current = idx
            .entries
            .get(&id)
            .is_some_and(|e| e.len == entry.len && e.hash == entry.hash);
        if current {
            idx.remove(id);
        }
    }

    /// Spill a chunk to disk (best-effort; failures are future misses).
    ///
    /// Identical bytes already on disk only refresh recency — re-evicting
    /// a chunk that round-tripped through RAM costs no I/O. A different
    /// payload for the same id (the namespace was rebuilt) replaces the
    /// old file.
    pub fn put(&self, id: u64, data: &ChunkData) {
        let len = data.len() as u64;
        if len == 0 || len > self.capacity_bytes {
            return;
        }
        let hash = fnv1a64(data);
        {
            let mut idx = self.index.lock().unwrap();
            if let Some(e) = idx.entries.get(&id) {
                if e.len == len && e.hash == hash {
                    idx.touch(id);
                    return;
                }
            }
        }
        let key = self.key(id, len, hash);
        if self.store.put(&key, data).is_err() {
            return;
        }
        self.writes.inc();
        let stale = self.index.lock().unwrap().insert(id, len, hash);
        if let Some(o) = stale {
            if o.len != len || o.hash != hash {
                // a racing identical put cannot delete the file just written
                let _ = self.store.delete(&self.key(id, o.len, o.hash));
            }
        }
        self.enforce_capacity();
    }

    /// Evict LRU entries (deleting their files) until within budget.
    /// Victim selection is O(log n) via the recency index; file deletion
    /// happens outside the lock.
    fn enforce_capacity(&self) {
        loop {
            let victim = {
                let mut idx = self.index.lock().unwrap();
                if idx.used_bytes <= self.capacity_bytes {
                    return;
                }
                match idx.lru() {
                    Some(id) => idx.remove(id).map(|e| (id, e)),
                    None => return,
                }
            };
            let Some((id, e)) = victim else { return };
            self.evictions.inc();
            let _ = self.store.delete(&self.key(id, e.len, e.hash));
        }
    }

    /// Drop every spilled chunk and delete its file.
    pub fn clear(&self) {
        let victims: Vec<(u64, Entry)> = {
            let mut idx = self.index.lock().unwrap();
            idx.used_bytes = 0;
            idx.by_stamp.clear();
            idx.entries.drain().collect()
        };
        for (id, e) in victims {
            let _ = self.store.delete(&self.key(id, e.len, e.hash));
        }
    }

    /// Is a (possibly unverified) entry for `id` present?
    pub fn contains(&self, id: u64) -> bool {
        self.index.lock().unwrap().entries.contains_key(&id)
    }

    /// Spilled chunks currently indexed.
    pub fn len(&self) -> usize {
        self.index.lock().unwrap().entries.len()
    }

    /// True when nothing is spilled.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of spilled payload currently indexed.
    pub fn used_bytes(&self) -> u64 {
        self.index.lock().unwrap().used_bytes
    }

    /// The configured byte budget.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Reads served from disk since open.
    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    /// Chunk files written since open (dedup-refreshes not counted).
    pub fn writes(&self) -> u64 {
        self.writes.get()
    }

    /// Entries evicted to stay within the byte budget since open.
    pub fn evictions(&self) -> u64 {
        self.evictions.get()
    }

    /// Entries purged by the length/identity check since open.
    pub fn rejected(&self) -> u64 {
        self.rejected.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    fn chunk(byte: u8, n: usize) -> ChunkData {
        Arc::new(ChunkBytes::ram(vec![byte; n]))
    }

    #[test]
    fn roundtrip_and_recency() {
        let dir = TempDir::new().unwrap();
        let t = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
        assert!(t.is_empty());
        t.put(3, &chunk(7, 100));
        assert!(t.contains(3));
        assert_eq!(t.used_bytes(), 100);
        assert_eq!(*t.get(3, 100, 0).unwrap(), vec![7u8; 100]);
        assert_eq!(t.hits(), 1);
        assert!(t.get(4, 100, 0).is_none(), "absent id misses");
    }

    #[test]
    fn lru_eviction_by_bytes() {
        let dir = TempDir::new().unwrap();
        let t = SpillTier::open(dir.path(), "ds", 250).unwrap();
        t.put(1, &chunk(1, 100));
        t.put(2, &chunk(2, 100));
        t.get(1, 100, 0).unwrap(); // refresh 1 -> 2 is LRU
        t.put(3, &chunk(3, 100)); // evicts 2
        assert!(t.contains(1) && t.contains(3));
        assert!(!t.contains(2));
        assert_eq!(t.evictions(), 1);
        assert_eq!(t.used_bytes(), 200);
    }

    #[test]
    fn oversized_chunk_not_spilled() {
        let dir = TempDir::new().unwrap();
        let t = SpillTier::open(dir.path(), "ds", 50).unwrap();
        t.put(1, &chunk(1, 100));
        assert!(t.is_empty());
        t.put(2, &Arc::new(ChunkBytes::ram(Vec::new())));
        assert!(t.is_empty(), "empty payloads are not spilled");
    }

    #[test]
    fn dedup_put_skips_rewrite() {
        let dir = TempDir::new().unwrap();
        let t = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
        let data = chunk(9, 500);
        t.put(1, &data);
        t.put(1, &data); // identical bytes: recency refresh only
        assert_eq!(t.writes(), 1);
        // different bytes for the same id replace the file
        t.put(1, &chunk(8, 500));
        assert_eq!(t.writes(), 2);
        assert_eq!(t.len(), 1);
        assert_eq!(*t.get(1, 500, 0).unwrap(), vec![8u8; 500]);
    }

    #[test]
    fn restart_reuses_valid_chunks() {
        let dir = TempDir::new().unwrap();
        {
            let t = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
            t.put(5, &chunk(5, 300));
            t.put(6, &chunk(6, 300));
        }
        let t2 = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
        assert_eq!(t2.len(), 2);
        assert_eq!(t2.used_bytes(), 600);
        assert_eq!(*t2.get(5, 300, 0).unwrap(), vec![5u8; 300]);
        assert_eq!(t2.rejected(), 0);
    }

    #[test]
    fn restart_deletes_junk_and_respects_smaller_budget() {
        let dir = TempDir::new().unwrap();
        {
            let t = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
            t.put(1, &chunk(1, 300));
            t.put(2, &chunk(2, 300));
        }
        // junk the directory: a name that does not parse, plus a temp
        // file stranded by a writer killed between write and rename
        let junk = dir.path().join("spill/ds/not_a_chunk");
        std::fs::write(&junk, b"garbage").unwrap();
        let stranded = dir.path().join("spill/ds/0000000000000009_300_0badc0de.tmp~1-2");
        std::fs::write(&stranded, vec![9u8; 300]).unwrap();
        let t2 = SpillTier::open(dir.path(), "ds", 350).unwrap();
        assert!(!junk.exists(), "unparseable files are removed at open");
        assert!(!stranded.exists(), "crash-stranded temp files are swept at open");
        assert_eq!(t2.len(), 1, "budget shrank: one chunk had to go");
        assert!(t2.used_bytes() <= 350);
    }

    #[test]
    fn corrupt_content_is_never_served() {
        let dir = TempDir::new().unwrap();
        {
            let t = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
            t.put(1, &chunk(1, 300));
        }
        // flip bytes in place (same length, so only the digest can tell)
        let file = std::fs::read_dir(dir.path().join("spill/ds"))
            .unwrap()
            .next()
            .unwrap()
            .unwrap()
            .path();
        std::fs::write(&file, vec![2u8; 300]).unwrap();
        let t2 = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
        assert!(t2.contains(1), "adopted before verification");
        assert!(t2.get(1, 300, 0).is_none(), "digest mismatch must not serve");
        assert_eq!(t2.rejected(), 1);
        assert!(!t2.contains(1), "purged after the failed check");
        assert!(!file.exists(), "the corrupt file is deleted");
    }

    #[test]
    fn truncated_file_is_never_served() {
        let dir = TempDir::new().unwrap();
        {
            let t = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
            t.put(1, &chunk(1, 300));
        }
        let file = std::fs::read_dir(dir.path().join("spill/ds"))
            .unwrap()
            .next()
            .unwrap()
            .unwrap()
            .path();
        std::fs::write(&file, vec![1u8; 100]).unwrap(); // truncate
        let t2 = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
        assert!(t2.get(1, 300, 0).is_none(), "length mismatch must not serve");
        assert_eq!(t2.rejected(), 1);
    }

    #[test]
    fn manifest_digest_disagreement_purges() {
        let dir = TempDir::new().unwrap();
        let t = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
        let data = chunk(1, 300);
        let h = fnv1a64(&data);
        t.put(1, &data);
        assert!(t.get(1, 300, h).is_some(), "matching manifest digest serves");
        assert!(t.get(1, 300, 0).is_some(), "digest-less manifest skips the check");
        // the namespace was rebuilt: same length, different content
        assert!(t.get(1, 300, h ^ 1).is_none(), "stale spill must not serve");
        assert_eq!(t.rejected(), 1);
        assert!(!t.contains(1));
    }

    #[test]
    fn manifest_length_disagreement_purges() {
        let dir = TempDir::new().unwrap();
        let t = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
        t.put(1, &chunk(1, 300));
        // the namespace was rebuilt with a different chunk layout
        assert!(t.get(1, 400, 0).is_none());
        assert_eq!(t.rejected(), 1);
        assert!(!t.contains(1));
    }

    #[test]
    fn clear_removes_files() {
        let dir = TempDir::new().unwrap();
        let t = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
        t.put(1, &chunk(1, 100));
        t.put(2, &chunk(2, 100));
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.used_bytes(), 0);
        let left = std::fs::read_dir(dir.path().join("spill/ds")).unwrap().count();
        assert_eq!(left, 0, "files deleted, not just forgotten");
    }

    #[test]
    fn namespaces_do_not_collide() {
        let dir = TempDir::new().unwrap();
        let a = SpillTier::open(dir.path(), "ns-a", 1 << 20).unwrap();
        let b = SpillTier::open(dir.path(), "ns-b", 1 << 20).unwrap();
        a.put(1, &chunk(1, 100));
        b.put(1, &chunk(2, 100));
        assert_eq!(*a.get(1, 100, 0).unwrap(), vec![1u8; 100]);
        assert_eq!(*b.get(1, 100, 0).unwrap(), vec![2u8; 100]);
    }

    #[test]
    fn name_parsing() {
        assert_eq!(
            SpillTier::parse_name("00000000000000a7_100_00000000deadbeef"),
            Some((0xa7, 100, 0xdead_beef))
        );
        assert_eq!(SpillTier::parse_name("junk"), None);
        assert_eq!(SpillTier::parse_name("1_2_3_4"), None);
        assert_eq!(SpillTier::parse_name("x_2_3"), None);
    }

    #[cfg(unix)]
    mod mmap_mode {
        use super::*;

        #[test]
        fn hits_are_served_from_mapped_pages() {
            let dir = TempDir::new().unwrap();
            let t = SpillTier::open_with(dir.path(), "ds", 1 << 20, true).unwrap();
            t.put(1, &chunk(7, 300));
            let data = t.get(1, 300, 0).unwrap();
            assert!(data.is_mapped(), "mmap mode must serve mapped bytes");
            assert_eq!(*data, vec![7u8; 300]);
            assert_eq!(t.hits(), 1);
        }

        #[test]
        fn mapped_reads_are_still_digest_verified() {
            let dir = TempDir::new().unwrap();
            {
                let t = SpillTier::open(dir.path(), "ds", 1 << 20).unwrap();
                t.put(1, &chunk(1, 300));
            }
            // flip bytes in place (same length: only the digest can tell)
            let file = std::fs::read_dir(dir.path().join("spill/ds"))
                .unwrap()
                .next()
                .unwrap()
                .unwrap()
                .path();
            std::fs::write(&file, vec![2u8; 300]).unwrap();
            let t2 = SpillTier::open_with(dir.path(), "ds", 1 << 20, true).unwrap();
            assert!(t2.get(1, 300, 0).is_none(), "corrupt mapped bytes must not serve");
            assert_eq!(t2.rejected(), 1);
            assert!(!file.exists(), "the corrupt file is deleted");
        }

        #[test]
        fn mapped_hit_survives_eviction_of_its_file() {
            // a reader holding a view while capacity eviction deletes the
            // file must keep seeing valid bytes (unlink semantics)
            let dir = TempDir::new().unwrap();
            let t = SpillTier::open_with(dir.path(), "ds", 250, true).unwrap();
            t.put(1, &chunk(1, 200));
            let held = t.get(1, 200, 0).unwrap();
            t.put(2, &chunk(2, 200)); // evicts id 1, deleting its file
            assert!(!t.contains(1));
            assert_eq!(*held, vec![1u8; 200], "mapped pages outlive the unlink");
        }
    }
}
