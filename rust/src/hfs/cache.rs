//! Node-local chunk cache: sharded LRU with per-shard byte budgets.
//!
//! Every node mounting HFS holds recently-used chunks in RAM (the paper's
//! "caching … mechanisms across all nodes"). The seed kept one global
//! mutex around a `HashMap` and found eviction victims with an O(n) scan;
//! under many concurrent readers every cache hit serialized on that lock.
//! This version shards by chunk id so readers of different chunks take
//! different locks, and each shard keeps an intrusive doubly-linked
//! recency list over a slab, making get / insert / evict all O(1).
//!
//! The total byte budget models instance memory and is split evenly
//! across shards; small budgets collapse to a single shard so strict LRU
//! semantics (and the seed's tests) hold exactly when the cache is tiny.

use std::collections::HashMap;
use std::sync::Arc;

use std::sync::Mutex;

use crate::metrics::Counter;

use super::view::{ChunkBytes, ChunkData};

/// Shards stop multiplying once each would hold less than this budget.
const MIN_SHARD_BYTES: u64 = 1 << 20;

/// Hard ceiling on shard count.
const MAX_SHARDS: usize = 16;

/// Sentinel slab index for "no slot".
const NIL: usize = usize::MAX;

/// Thread-safe sharded LRU of chunk key -> bytes.
///
/// Keys are `u64` content digests (or a `(ns, id)` hash for digest-less
/// legacy chunks — see `HyperFs`), so identical chunks reached through
/// different namespaces or chunk ids share one cache entry.
#[derive(Clone)]
pub struct ChunkCache {
    shards: Arc<Vec<Mutex<Shard>>>,
    /// Total evictions across all shards (contention-free counter).
    evictions: Counter,
}

struct Slot {
    id: u64,
    data: ChunkData,
    prev: usize,
    next: usize,
}

struct Shard {
    capacity_bytes: u64,
    used_bytes: u64,
    map: HashMap<u64, usize>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    /// Most-recently-used slot, or NIL.
    head: usize,
    /// Least-recently-used slot (eviction victim), or NIL.
    tail: usize,
}

impl Shard {
    fn new(capacity_bytes: u64) -> Self {
        Self {
            capacity_bytes,
            used_bytes: 0,
            map: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    /// Unlink `slot` from the recency list (O(1)).
    fn detach(&mut self, slot: usize) {
        let (prev, next) = (self.slots[slot].prev, self.slots[slot].next);
        if prev != NIL {
            self.slots[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next].prev = prev;
        } else {
            self.tail = prev;
        }
        self.slots[slot].prev = NIL;
        self.slots[slot].next = NIL;
    }

    /// Link `slot` at the MRU head (O(1)).
    fn push_front(&mut self, slot: usize) {
        self.slots[slot].prev = NIL;
        self.slots[slot].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = slot;
        }
        self.head = slot;
        if self.tail == NIL {
            self.tail = slot;
        }
    }

    /// Remove the entry in `slot` entirely, returning its payload (so an
    /// evicted chunk can flow to a lower cache tier instead of dropping).
    fn remove_slot(&mut self, slot: usize) -> ChunkData {
        self.detach(slot);
        let size = self.slots[slot].data.len() as u64;
        let id = self.slots[slot].id;
        self.map.remove(&id);
        self.used_bytes -= size;
        // hand the payload out now; the slab slot is recycled
        let data = std::mem::replace(
            &mut self.slots[slot].data,
            Arc::new(ChunkBytes::ram(Vec::new())),
        );
        self.free.push(slot);
        data
    }

    fn alloc_slot(&mut self, id: u64, data: ChunkData) -> usize {
        match self.free.pop() {
            Some(slot) => {
                self.slots[slot] = Slot { id, data, prev: NIL, next: NIL };
                slot
            }
            None => {
                self.slots.push(Slot { id, data, prev: NIL, next: NIL });
                self.slots.len() - 1
            }
        }
    }
}

impl ChunkCache {
    /// Cache with `capacity_bytes` total budget and an automatic shard
    /// count: one shard per [`MIN_SHARD_BYTES`] of budget, capped at
    /// [`MAX_SHARDS`]. Tiny budgets get exactly one shard (strict LRU).
    ///
    /// Callers that know their chunk size should prefer
    /// [`ChunkCache::with_chunk_hint`]: over-sharding a small budget
    /// would make large chunks uncacheable (each shard only admits
    /// chunks within its own slice of the budget).
    pub fn new(capacity_bytes: u64) -> Self {
        let shards = ((capacity_bytes / MIN_SHARD_BYTES) as usize).clamp(1, MAX_SHARDS);
        Self::with_shards(capacity_bytes, shards)
    }

    /// Cache sized so that every shard can hold at least a few chunks of
    /// `max_chunk_bytes`: shards = budget / (4 * chunk), capped at
    /// [`MAX_SHARDS`], minimum 1. With fewer than 4 chunks of budget the
    /// cache collapses to a single shard, reproducing the seed's strict
    /// LRU (a chunk is cacheable iff it fits the whole budget).
    pub fn with_chunk_hint(capacity_bytes: u64, max_chunk_bytes: u64) -> Self {
        let per_shard_floor = 4 * max_chunk_bytes.max(1);
        let shards = ((capacity_bytes / per_shard_floor) as usize).clamp(1, MAX_SHARDS);
        Self::with_shards(capacity_bytes, shards)
    }

    /// Cache with an explicit shard count (`n_shards >= 1`). The byte
    /// budget is split evenly; chunks larger than one shard's budget are
    /// served but not cached.
    pub fn with_shards(capacity_bytes: u64, n_shards: usize) -> Self {
        let n = n_shards.max(1);
        let per_shard = capacity_bytes / n as u64;
        Self {
            shards: Arc::new((0..n).map(|_| Mutex::new(Shard::new(per_shard))).collect()),
            evictions: Counter::default(),
        }
    }

    fn shard(&self, id: u64) -> &Mutex<Shard> {
        &self.shards[id as usize % self.shards.len()]
    }

    /// Number of independent LRU shards (1 for tiny budgets).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total evictions since creation.
    pub fn evictions(&self) -> u64 {
        self.evictions.get()
    }

    /// Look up a chunk, refreshing its recency. O(1).
    pub fn get(&self, id: u64) -> Option<ChunkData> {
        let mut s = self.shard(id).lock().unwrap();
        let slot = *s.map.get(&id)?;
        s.detach(slot);
        s.push_front(slot);
        Some(s.slots[slot].data.clone())
    }

    /// Insert a chunk, evicting LRU entries of its shard to fit. O(1) per
    /// evicted entry. Chunks bigger than the shard budget are not cached.
    pub fn insert(&self, id: u64, data: ChunkData) {
        self.insert_evicting(id, data);
    }

    /// Like [`ChunkCache::insert`], but returns the `(id, payload)` pairs
    /// evicted to make room, so the caller can demote them to a lower tier
    /// (the disk spill tier) instead of dropping them. Replacing an
    /// existing entry for `id` is not an eviction and is not reported.
    pub fn insert_evicting(&self, id: u64, data: ChunkData) -> Vec<(u64, ChunkData)> {
        let size = data.len() as u64;
        let mut evicted = Vec::new();
        let mut s = self.shard(id).lock().unwrap();
        if size > s.capacity_bytes {
            return evicted;
        }
        let existing = s.map.get(&id).copied();
        if let Some(slot) = existing {
            s.remove_slot(slot);
        }
        while s.used_bytes + size > s.capacity_bytes {
            let victim = s.tail;
            if victim == NIL {
                break;
            }
            let victim_id = s.slots[victim].id;
            evicted.push((victim_id, s.remove_slot(victim)));
            self.evictions.inc();
        }
        let slot = s.alloc_slot(id, data);
        s.map.insert(id, slot);
        s.used_bytes += size;
        s.push_front(slot);
        evicted
    }

    /// Is `id` currently cached? Does not refresh recency.
    pub fn contains(&self, id: u64) -> bool {
        self.shard(id).lock().unwrap().map.contains_key(&id)
    }

    /// Bytes of chunk payload currently held, summed across shards.
    pub fn used_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().used_bytes).sum()
    }

    /// Cached chunk count, summed across shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    /// True when no chunk is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached chunk (shard by shard; not atomic across shards).
    pub fn clear(&self) {
        for shard in self.shards.iter() {
            let mut s = shard.lock().unwrap();
            s.map.clear();
            s.slots.clear();
            s.free.clear();
            s.head = NIL;
            s.tail = NIL;
            s.used_bytes = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(n: usize) -> ChunkData {
        Arc::new(ChunkBytes::ram(vec![0u8; n]))
    }

    // ---- strict-LRU semantics on a single shard (seed behavior) --------

    #[test]
    fn lru_eviction_order() {
        let c = ChunkCache::with_shards(300, 1);
        c.insert(1, chunk(100));
        c.insert(2, chunk(100));
        c.insert(3, chunk(100));
        c.get(1); // refresh 1 -> 2 is now LRU
        c.insert(4, chunk(100));
        assert!(c.contains(1) && c.contains(3) && c.contains(4));
        assert!(!c.contains(2));
        assert_eq!(c.used_bytes(), 300);
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn oversized_not_cached() {
        let c = ChunkCache::new(50);
        c.insert(1, chunk(100));
        assert!(c.is_empty());
    }

    #[test]
    fn reinsert_same_id_replaces() {
        let c = ChunkCache::with_shards(300, 1);
        c.insert(1, chunk(100));
        c.insert(1, chunk(50));
        assert_eq!(c.used_bytes(), 50);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn multiple_evictions_to_fit() {
        let c = ChunkCache::with_shards(100, 1);
        c.insert(1, chunk(40));
        c.insert(2, chunk(40));
        c.insert(3, chunk(90)); // must evict both
        assert_eq!(c.len(), 1);
        assert!(c.contains(3));
        assert_eq!(c.evictions(), 2);
    }

    #[test]
    fn insert_evicting_hands_out_victims_in_lru_order() {
        let c = ChunkCache::with_shards(100, 1);
        c.insert(1, chunk(40));
        c.insert(2, chunk(40));
        let evicted = c.insert_evicting(3, chunk(90));
        let ids: Vec<u64> = evicted.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![1, 2], "oldest first");
        assert_eq!(evicted[0].1.len(), 40, "payload travels with the id");
        // replacing an entry is not an eviction
        assert!(c.insert_evicting(3, chunk(50)).is_empty());
        // an uncacheable chunk evicts nothing
        assert!(c.insert_evicting(4, chunk(500)).is_empty());
        assert!(c.contains(3));
    }

    #[test]
    fn clear_resets() {
        let c = ChunkCache::with_shards(100, 2);
        c.insert(1, chunk(10));
        c.insert(2, chunk(10));
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
    }

    // ---- sharding behavior ---------------------------------------------

    #[test]
    fn tiny_budget_collapses_to_one_shard() {
        assert_eq!(ChunkCache::new(300).shard_count(), 1);
        assert_eq!(ChunkCache::new(64 << 20).shard_count(), MAX_SHARDS);
    }

    #[test]
    fn chunk_hint_keeps_big_chunks_cacheable() {
        // 128 MiB budget with 64 MiB chunks: must not over-shard into
        // slices too small to admit a single chunk
        let c = ChunkCache::with_chunk_hint(128 << 20, 64 << 20);
        assert_eq!(c.shard_count(), 1);
        c.insert(0, chunk(64 << 20));
        assert!(c.contains(0), "a default-size chunk must be cacheable");
        // plentiful budget relative to chunk size shards out
        assert_eq!(ChunkCache::with_chunk_hint(1 << 30, 32 << 20).shard_count(), 8);
        assert_eq!(ChunkCache::with_chunk_hint(1 << 30, 1 << 20).shard_count(), MAX_SHARDS);
    }

    #[test]
    fn shards_isolate_ids() {
        // 4 shards x 100 bytes; ids 0..4 land in distinct shards, so all
        // four fit even though each shard only holds one
        let c = ChunkCache::with_shards(400, 4);
        for id in 0..4 {
            c.insert(id, chunk(100));
        }
        assert_eq!(c.len(), 4);
        // id 4 maps to shard 0 and evicts id 0, never ids 1..3
        c.insert(4, chunk(100));
        assert!(!c.contains(0));
        assert!(c.contains(1) && c.contains(2) && c.contains(3) && c.contains(4));
    }

    #[test]
    fn slab_recycles_slots() {
        let c = ChunkCache::with_shards(100, 1);
        for round in 0..1000u64 {
            c.insert(round % 7, chunk(60)); // each insert evicts the last
        }
        // one live entry, slab did not grow without bound
        assert_eq!(c.len(), 1);
        let s = c.shards[0].lock().unwrap();
        assert!(s.slots.len() <= 2, "slab grew to {}", s.slots.len());
    }

    #[test]
    fn long_recency_chain_stays_consistent() {
        let c = ChunkCache::with_shards(1000, 1);
        for id in 0..10 {
            c.insert(id, chunk(100));
        }
        // refresh in a scrambled order, then insert to evict exactly the LRU
        for &id in &[3u64, 1, 4, 1, 5, 9, 2, 6] {
            c.get(id);
        }
        // LRU order now: 0, 7, 8, 3, 4, 1, 5, 9, 2, 6 (0 least recent)
        c.insert(10, chunk(100));
        assert!(!c.contains(0));
        c.insert(11, chunk(100));
        assert!(!c.contains(7));
        assert_eq!(c.len(), 10);
        assert_eq!(c.used_bytes(), 1000);
    }

    #[test]
    fn concurrent_hammering_is_consistent() {
        let c = ChunkCache::with_shards(8 << 20, 8);
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let c = c.clone();
                s.spawn(move || {
                    for i in 0..2000u64 {
                        let id = (t * 31 + i) % 64;
                        if c.get(id).is_none() {
                            c.insert(id, chunk(4096));
                        }
                    }
                });
            }
        });
        assert!(c.len() <= 64);
        assert!(c.used_bytes() <= 8 << 20);
    }
}
