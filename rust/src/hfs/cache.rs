//! Node-local LRU chunk cache with a byte budget.
//!
//! Every node mounting HFS holds recently-used chunks in RAM (the paper's
//! "caching … mechanisms across all nodes"); the budget models instance
//! memory, and eviction is strict LRU.

use std::collections::HashMap;
use std::sync::Arc;

use std::sync::Mutex;

/// Thread-safe LRU of chunk id -> bytes.
#[derive(Clone)]
pub struct ChunkCache {
    inner: Arc<Mutex<CacheInner>>,
}

struct CacheInner {
    capacity_bytes: u64,
    used_bytes: u64,
    tick: u64,
    entries: HashMap<u32, Entry>,
}

struct Entry {
    data: Arc<Vec<u8>>,
    last_used: u64,
}

impl ChunkCache {
    pub fn new(capacity_bytes: u64) -> Self {
        Self {
            inner: Arc::new(Mutex::new(CacheInner {
                capacity_bytes,
                used_bytes: 0,
                tick: 0,
                entries: HashMap::new(),
            })),
        }
    }

    /// Look up a chunk, refreshing its recency.
    pub fn get(&self, id: u32) -> Option<Arc<Vec<u8>>> {
        let mut c = self.inner.lock().unwrap();
        c.tick += 1;
        let tick = c.tick;
        c.entries.get_mut(&id).map(|e| {
            e.last_used = tick;
            e.data.clone()
        })
    }

    /// Insert a chunk, evicting LRU entries to fit. Oversized chunks
    /// (bigger than the whole budget) are not cached.
    pub fn insert(&self, id: u32, data: Arc<Vec<u8>>) {
        let size = data.len() as u64;
        let mut c = self.inner.lock().unwrap();
        if size > c.capacity_bytes {
            return;
        }
        if let Some(old) = c.entries.remove(&id) {
            c.used_bytes -= old.data.len() as u64;
        }
        while c.used_bytes + size > c.capacity_bytes {
            let Some((&victim, _)) = c.entries.iter().min_by_key(|(_, e)| e.last_used) else {
                break;
            };
            let e = c.entries.remove(&victim).expect("victim exists");
            c.used_bytes -= e.data.len() as u64;
        }
        c.tick += 1;
        let tick = c.tick;
        c.used_bytes += size;
        c.entries.insert(id, Entry { data, last_used: tick });
    }

    pub fn contains(&self, id: u32) -> bool {
        self.inner.lock().unwrap().entries.contains_key(&id)
    }

    pub fn used_bytes(&self) -> u64 {
        self.inner.lock().unwrap().used_bytes
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        let mut c = self.inner.lock().unwrap();
        c.entries.clear();
        c.used_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(n: usize) -> Arc<Vec<u8>> {
        Arc::new(vec![0u8; n])
    }

    #[test]
    fn lru_eviction_order() {
        let c = ChunkCache::new(300);
        c.insert(1, chunk(100));
        c.insert(2, chunk(100));
        c.insert(3, chunk(100));
        c.get(1); // refresh 1 -> 2 is now LRU
        c.insert(4, chunk(100));
        assert!(c.contains(1) && c.contains(3) && c.contains(4));
        assert!(!c.contains(2));
        assert_eq!(c.used_bytes(), 300);
    }

    #[test]
    fn oversized_not_cached() {
        let c = ChunkCache::new(50);
        c.insert(1, chunk(100));
        assert!(c.is_empty());
    }

    #[test]
    fn reinsert_same_id_replaces() {
        let c = ChunkCache::new(300);
        c.insert(1, chunk(100));
        c.insert(1, chunk(50));
        assert_eq!(c.used_bytes(), 50);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn multiple_evictions_to_fit() {
        let c = ChunkCache::new(100);
        c.insert(1, chunk(40));
        c.insert(2, chunk(40));
        c.insert(3, chunk(90)); // must evict both
        assert_eq!(c.len(), 1);
        assert!(c.contains(3));
    }

    #[test]
    fn clear_resets() {
        let c = ChunkCache::new(100);
        c.insert(1, chunk(10));
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
    }
}
