//! HFS upload path: pack files into chunks, write chunks + manifest.
//!
//! Mirrors the paper's interface flow: "Interface uploads the training
//! data … Source files are chunked and uploaded to Object Storage."
//!
//! The default layout is the sharded, content-addressed format 2 (see
//! [`super::chunk`]): the file table is split into shard objects under a
//! small root manifest, chunk objects are keyed by content digest (a
//! digest the store already holds is **not** re-uploaded), and files
//! below [`crate::config::UploadConfig::pack_threshold`] can be packed
//! into tar-like archive chunks. [`Uploader::legacy`] writes the old
//! monolithic format 1 for compatibility with pre-shard readers.

use std::collections::{BTreeSet, HashSet};

use crate::config::UploadConfig;
use crate::storage::StoreHandle;
use crate::{Error, Result};

use super::chunk::{
    cas_chunk_key, chunk_table_to_json, fnv1a64, pack_append, shard_to_json, ChunkRef, FileEntry,
    FsManifest, RootManifest, ShardRef, PACK_HEADER_FIXED,
};

/// What one upload session actually moved, for dedup/packing accounting.
#[derive(Debug, Clone, Default)]
pub struct UploadStats {
    /// Chunk objects PUT to the store.
    pub chunks_written: u64,
    /// Chunk PUTs skipped because the store already held the digest.
    pub chunks_deduped: u64,
    /// Payload bytes actually transferred in chunk PUTs.
    pub bytes_written: u64,
    /// Payload bytes saved by dedup-skipped PUTs.
    pub bytes_deduped: u64,
    /// Files routed into packed archive chunks.
    pub files_packed: u64,
    /// File-table shard objects written at seal.
    pub shards_written: u64,
}

/// Streaming chunker: add files, then `seal()` to flush the tail chunk and
/// write the manifest. Files larger than the chunk size span a dedicated
/// oversized chunk (kept whole so a single GET serves the file).
pub struct Uploader {
    store: StoreHandle,
    ns: String,
    cfg: UploadConfig,
    manifest: FsManifest,
    buf: Vec<u8>,
    /// Open archive chunk for files below the packing threshold.
    pack_buf: Vec<u8>,
    /// Indices into `manifest.files` whose entries live in `pack_buf`
    /// and still need their chunk id assigned at pack flush.
    pack_pending: Vec<usize>,
    next_chunk: u32,
    sealed: bool,
    /// Digests this session already PUT (or probed present), so repeated
    /// identical chunks skip both the PUT and the exists() round-trip.
    written_digests: HashSet<u64>,
    stats: UploadStats,
    /// Paths seen so far: duplicates must error, not silently shadow
    /// (the sealed file table is binary-searched by path, so a duplicate
    /// would make one copy unreachable forever).
    seen_paths: BTreeSet<String>,
}

impl Uploader {
    /// Start uploading `namespace` to `store` with `chunk_size`-byte
    /// chunks, in the default sharded content-addressed layout (no
    /// small-file packing).
    ///
    /// # Panics
    /// If `chunk_size` is zero.
    pub fn new(store: StoreHandle, namespace: &str, chunk_size: u64) -> Self {
        Self::with_config(store, namespace, UploadConfig { chunk_size, ..UploadConfig::default() })
    }

    /// Start uploading `namespace` in the legacy monolithic layout
    /// (format 1: one manifest object, `<ns>/chunks/` keys, no dedup) —
    /// for namespaces that must stay readable by pre-shard tooling.
    ///
    /// # Panics
    /// If `chunk_size` is zero.
    pub fn legacy(store: StoreHandle, namespace: &str, chunk_size: u64) -> Self {
        Self::with_config(
            store,
            namespace,
            UploadConfig { chunk_size, legacy_layout: true, ..UploadConfig::default() },
        )
    }

    /// Start uploading `namespace` with full layout control.
    ///
    /// # Panics
    /// If `cfg.chunk_size` is zero.
    pub fn with_config(store: StoreHandle, namespace: &str, cfg: UploadConfig) -> Self {
        assert!(cfg.chunk_size > 0, "chunk_size must be positive");
        let chunk_size = cfg.chunk_size;
        Self {
            store,
            ns: namespace.to_string(),
            cfg,
            manifest: FsManifest::new(chunk_size),
            buf: Vec::with_capacity(chunk_size as usize),
            pack_buf: Vec::new(),
            pack_pending: Vec::new(),
            next_chunk: 0,
            sealed: false,
            written_digests: HashSet::new(),
            stats: UploadStats::default(),
            seen_paths: BTreeSet::new(),
        }
    }

    /// Append one file to the namespace.
    pub fn add_file(&mut self, path: &str, data: &[u8]) -> Result<()> {
        if self.sealed {
            return Err(Error::Storage("uploader already sealed".into()));
        }
        if path.is_empty() {
            return Err(Error::Storage("empty file path".into()));
        }
        if !self.seen_paths.insert(path.to_string()) {
            return Err(Error::Storage(format!(
                "duplicate path {path:?} in namespace {:?}",
                self.ns
            )));
        }
        if self.packable(path, data) {
            return self.add_packed(path, data);
        }
        // would overflow current chunk -> flush first (keeps files whole)
        if !self.buf.is_empty()
            && self.buf.len() as u64 + data.len() as u64 > self.manifest.chunk_size
        {
            self.flush_chunk()?;
        }
        self.manifest.files.push(FileEntry {
            path: path.to_string(),
            chunk: self.next_chunk,
            offset: self.buf.len() as u64,
            len: data.len() as u64,
        });
        self.buf.extend_from_slice(data);
        // oversized single file: flush immediately as its own chunk
        if self.buf.len() as u64 >= self.manifest.chunk_size {
            self.flush_chunk()?;
        }
        Ok(())
    }

    /// Should this file go into a packed archive chunk?
    fn packable(&self, path: &str, data: &[u8]) -> bool {
        self.cfg.pack_threshold > 0
            && (data.len() as u64) < self.cfg.pack_threshold
            && path.len() <= u16::MAX as usize
    }

    /// Route a small file into the open archive chunk.
    fn add_packed(&mut self, path: &str, data: &[u8]) -> Result<()> {
        let entry_bytes = (PACK_HEADER_FIXED + path.len() + data.len()) as u64;
        if !self.pack_buf.is_empty()
            && self.pack_buf.len() as u64 + entry_bytes > self.manifest.chunk_size
        {
            self.flush_pack()?;
        }
        let offset = pack_append(&mut self.pack_buf, path, data);
        self.manifest.files.push(FileEntry {
            // real id assigned when the archive flushes
            path: path.to_string(),
            chunk: u32::MAX,
            offset,
            len: data.len() as u64,
        });
        self.pack_pending.push(self.manifest.files.len() - 1);
        self.stats.files_packed += 1;
        if self.pack_buf.len() as u64 >= self.manifest.chunk_size {
            self.flush_pack()?;
        }
        Ok(())
    }

    /// Upload one chunk object, dedup-skipping the PUT in
    /// content-addressed mode, and append its [`ChunkRef`].
    fn put_chunk(&mut self, bytes: &[u8], packed: bool) -> Result<()> {
        let len = bytes.len() as u64;
        let hash = fnv1a64(bytes);
        let id = self.next_chunk;
        self.next_chunk += 1;
        if self.cfg.legacy_layout {
            self.store.put(&FsManifest::chunk_key(&self.ns, id), bytes)?;
            self.stats.chunks_written += 1;
            self.stats.bytes_written += len;
        } else {
            let key = cas_chunk_key(hash);
            let already = self.written_digests.contains(&hash) || self.store.exists(&key);
            if already {
                self.stats.chunks_deduped += 1;
                self.stats.bytes_deduped += len;
            } else {
                self.store.put(&key, bytes)?;
                self.stats.chunks_written += 1;
                self.stats.bytes_written += len;
            }
            self.written_digests.insert(hash);
        }
        self.manifest.chunks.push(ChunkRef { id, len, hash, packed });
        Ok(())
    }

    fn flush_chunk(&mut self) -> Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let buf = std::mem::take(&mut self.buf);
        self.put_chunk(&buf, false)?;
        Ok(())
    }

    fn flush_pack(&mut self) -> Result<()> {
        if self.pack_buf.is_empty() {
            return Ok(());
        }
        let buf = std::mem::take(&mut self.pack_buf);
        self.put_chunk(&buf, true)?;
        let id = self.next_chunk - 1;
        for &fi in &self.pack_pending {
            self.manifest.files[fi].chunk = id;
        }
        self.pack_pending.clear();
        Ok(())
    }

    /// Flush open chunks, sort the file table, write the manifest
    /// (root + shards + chunk table, or one legacy object). Returns the
    /// sealed manifest.
    pub fn seal(self) -> Result<FsManifest> {
        Ok(self.seal_with_stats()?.0)
    }

    /// [`Uploader::seal`], also returning the session's transfer
    /// accounting (dedup and packing savings).
    pub fn seal_with_stats(mut self) -> Result<(FsManifest, UploadStats)> {
        self.flush_chunk()?;
        self.flush_pack()?;
        self.manifest.seal();
        if self.cfg.legacy_layout {
            let key = FsManifest::manifest_key(&self.ns);
            self.store.put(&key, &self.manifest.to_json()?)?;
        } else {
            self.write_sharded_manifest()?;
        }
        self.sealed = true;
        Ok((self.manifest, self.stats))
    }

    /// Write the format-2 metadata plane: file-table shards, the chunk
    /// table, then the root (root last, so a mountable root implies its
    /// shards exist).
    fn write_sharded_manifest(&mut self) -> Result<()> {
        let shard_files = self.cfg.shard_files.max(1);
        let mut shards = Vec::new();
        for (i, window) in self.manifest.files.chunks(shard_files).enumerate() {
            self.store.put(&RootManifest::shard_key(&self.ns, i), &shard_to_json(window))?;
            shards.push(ShardRef { start: window[0].path.clone(), files: window.len() as u64 });
            self.stats.shards_written += 1;
        }
        let table = chunk_table_to_json(&self.manifest.chunks);
        self.store.put(&RootManifest::chunk_table_key(&self.ns), &table)?;
        let root = RootManifest {
            chunk_size: self.manifest.chunk_size,
            file_count: self.manifest.files.len() as u64,
            total_bytes: self.manifest.total_bytes(),
            chunk_count: self.manifest.chunks.len() as u64,
            max_chunk_len: self.manifest.chunks.iter().map(|c| c.len).max().unwrap_or(0),
            content_addressed: true,
            shards,
        };
        self.store.put(&FsManifest::manifest_key(&self.ns), &root.to_json())
    }
}

/// Synthesize a deterministic `n_files`-file namespace into `store` —
/// the shared generator behind the `hfs_metadata` bench, the `hfs_synth`
/// example, and `scripts/hfs_synth`. Returns the uploaded paths (in
/// upload order) and the session stats.
///
/// `distinct_contents` controls dedup pressure: file `i` carries content
/// variant `i % distinct_contents`, so `distinct_contents < n_files`
/// yields duplicate chunks a content-addressed upload stores only once.
/// Pass `distinct_contents >= n_files` (or 0) for all-distinct files.
pub fn synthesize_namespace(
    store: &StoreHandle,
    ns: &str,
    n_files: usize,
    file_bytes: usize,
    distinct_contents: usize,
    cfg: UploadConfig,
) -> Result<(Vec<String>, UploadStats)> {
    let mut up = Uploader::with_config(store.clone(), ns, cfg);
    let mut paths = Vec::with_capacity(n_files);
    let variants = if distinct_contents == 0 { n_files.max(1) } else { distinct_contents };
    for i in 0..n_files {
        let variant = i % variants;
        let body: Vec<u8> =
            (0..file_bytes).map(|k| ((variant * 131 + k * 7) & 0xff) as u8).collect();
        let path = format!("train/{i:06}.bin");
        up.add_file(&path, &body)?;
        paths.push(path);
    }
    let (_, stats) = up.seal_with_stats()?;
    Ok((paths, stats))
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::storage::{CountingStore, MemStore};

    fn store() -> StoreHandle {
        Arc::new(MemStore::new())
    }

    #[test]
    fn packs_files_into_chunks() {
        let s = store();
        let mut up = Uploader::new(s.clone(), "ds", 100);
        up.add_file("f1", &[1u8; 40]).unwrap();
        up.add_file("f2", &[2u8; 40]).unwrap();
        up.add_file("f3", &[3u8; 40]).unwrap(); // spills to chunk 1
        let m = up.seal().unwrap();
        assert_eq!(m.chunks.len(), 2);
        assert_eq!(m.files.len(), 3);
        let f3 = &m.files[m.find("f3").unwrap()];
        assert_eq!(f3.chunk, 1);
        // chunk objects live under content-addressed keys
        assert_eq!(s.get(&cas_chunk_key(m.chunks[0].hash)).unwrap().len(), 80);
    }

    #[test]
    fn legacy_layout_writes_namespace_keys() {
        let s = store();
        let mut up = Uploader::legacy(s.clone(), "ds", 100);
        up.add_file("f1", &[1u8; 40]).unwrap();
        up.add_file("f2", &[2u8; 40]).unwrap();
        let m = up.seal().unwrap();
        assert_eq!(s.get(&FsManifest::chunk_key("ds", 0)).unwrap().len(), 80);
        // and a monolithic manifest the old reader parses
        let back = FsManifest::from_json(&s.get("ds/manifest.json").unwrap()).unwrap();
        assert_eq!(back.file_count(), 2);
        assert_eq!(back.chunks, m.chunks);
    }

    #[test]
    fn oversized_file_gets_own_chunk() {
        let s = store();
        let mut up = Uploader::new(s.clone(), "ds", 100);
        up.add_file("small", &[0u8; 10]).unwrap();
        up.add_file("huge", &[9u8; 350]).unwrap();
        up.add_file("tail", &[7u8; 10]).unwrap();
        let m = up.seal().unwrap();
        let huge = &m.files[m.find("huge").unwrap()];
        assert_eq!(huge.offset, 0, "oversized file starts its own chunk");
        assert_eq!(m.chunks[huge.chunk as usize].len, 350);
        assert_eq!(m.total_bytes(), 370);
    }

    #[test]
    fn manifest_written_to_store() {
        let s = store();
        let mut up = Uploader::new(s.clone(), "ds", 64);
        up.add_file("a", b"data").unwrap();
        up.seal().unwrap();
        let root = RootManifest::from_json(&s.get("ds/manifest.json").unwrap()).unwrap();
        assert_eq!(root.file_count, 1);
        assert_eq!(root.shards.len(), 1);
        assert!(root.content_addressed);
        // the root is NOT parseable as a legacy manifest — old readers
        // must fail loudly, not mount an empty namespace
        assert!(FsManifest::from_json(&s.get("ds/manifest.json").unwrap()).is_err());
    }

    #[test]
    fn empty_namespace_ok() {
        let m = Uploader::new(store(), "empty", 64).seal().unwrap();
        assert_eq!(m.file_count(), 0);
        assert!(m.chunks.is_empty());
    }

    #[test]
    fn empty_namespace_manifest_round_trips_and_mounts() {
        // seal() with zero files must still write a manifest good enough
        // to mount: list is empty, reads fail cleanly, nothing panics
        let s = store();
        Uploader::new(s.clone(), "empty", 64).seal().unwrap();
        let root = RootManifest::from_json(&s.get("empty/manifest.json").unwrap()).unwrap();
        assert_eq!(root.file_count, 0);
        assert_eq!(root.chunk_size, 64);
        let fs = crate::hfs::HyperFs::mount(s, "empty", 1 << 20).unwrap();
        assert!(fs.list("").unwrap().is_empty());
        assert!(matches!(fs.read_file("anything"), Err(Error::FileNotFound(_))));
        assert!(fs.stat("anything").is_err());
    }

    #[test]
    fn duplicate_path_errors_instead_of_shadowing() {
        let s = store();
        let mut up = Uploader::new(s, "ds", 100);
        up.add_file("a/same", &[1u8; 10]).unwrap();
        up.add_file("a/other", &[2u8; 10]).unwrap();
        let err = up.add_file("a/same", &[3u8; 10]).unwrap_err();
        assert!(err.to_string().contains("duplicate path"), "{err}");
        // the uploader remains usable and the first copy is intact
        up.add_file("a/third", &[4u8; 10]).unwrap();
        let m = up.seal().unwrap();
        assert_eq!(m.file_count(), 3);
        let same = &m.files[m.find("a/same").unwrap()];
        assert_eq!(same.len, 10);
    }

    #[test]
    fn duplicates_across_chunk_boundaries_also_error() {
        let s = store();
        let mut up = Uploader::new(s, "ds", 20);
        up.add_file("x", &[1u8; 15]).unwrap(); // fills chunk 0
        up.add_file("y", &[2u8; 15]).unwrap(); // chunk 1
        assert!(up.add_file("x", &[3u8; 5]).is_err(), "dup in a later chunk");
    }

    #[test]
    fn rejects_after_double_add_of_sealed() {
        let s = store();
        let mut up = Uploader::new(s, "ds", 64);
        up.add_file("", b"x").unwrap_err();
    }

    #[test]
    fn shard_split_respects_shard_files() {
        let s = store();
        let cfg = UploadConfig { chunk_size: 1 << 20, shard_files: 4, ..UploadConfig::default() };
        let mut up = Uploader::with_config(s.clone(), "ds", cfg);
        for i in 0..10 {
            up.add_file(&format!("f/{i:02}"), &[i as u8; 8]).unwrap();
        }
        let (_, stats) = up.seal_with_stats().unwrap();
        assert_eq!(stats.shards_written, 3, "10 files / 4 per shard");
        let root = RootManifest::from_json(&s.get("ds/manifest.json").unwrap()).unwrap();
        assert_eq!(root.shards.iter().map(|sh| sh.files).collect::<Vec<_>>(), vec![4, 4, 2]);
        assert_eq!(root.shards[0].start, "f/00");
        assert_eq!(root.shards[1].start, "f/04");
        // every shard object exists and parses
        for i in 0..3 {
            let bytes = s.get(&RootManifest::shard_key("ds", i)).unwrap();
            let files = super::super::chunk::shard_from_json(&bytes).unwrap();
            assert_eq!(files.len(), root.shards[i].files as usize);
        }
    }

    #[test]
    fn duplicate_chunks_are_uploaded_once() {
        let counting = Arc::new(CountingStore::new(Arc::new(MemStore::new())));
        let s: StoreHandle = counting.clone();
        let mut up = Uploader::new(s.clone(), "ds", 64);
        // 8 files x 64 B = 8 chunks, but only 2 distinct contents
        for i in 0..8 {
            up.add_file(&format!("f{i}"), &[(i % 2) as u8; 64]).unwrap();
        }
        let (m, stats) = up.seal_with_stats().unwrap();
        assert_eq!(m.chunks.len(), 8, "logical chunk table keeps all ids");
        assert_eq!(stats.chunks_written, 2, "only distinct contents are PUT");
        assert_eq!(stats.chunks_deduped, 6);
        assert_eq!(stats.bytes_written, 2 * 64);
        assert_eq!(stats.bytes_deduped, 6 * 64);
        assert_eq!(s.list("cas/chunks/").unwrap().len(), 2, "one object per digest");
    }

    #[test]
    fn dedup_skips_puts_across_sessions_via_exists_probe() {
        let s = store();
        let mut up = Uploader::new(s.clone(), "a", 64);
        up.add_file("x", &[7u8; 64]).unwrap();
        up.seal().unwrap();
        // same content uploaded under another namespace: no new PUT
        let mut up2 = Uploader::new(s.clone(), "b", 64);
        up2.add_file("y", &[7u8; 64]).unwrap();
        let (_, stats) = up2.seal_with_stats().unwrap();
        assert_eq!(stats.chunks_written, 0);
        assert_eq!(stats.chunks_deduped, 1);
        assert_eq!(s.list("cas/chunks/").unwrap().len(), 1);
    }

    #[test]
    fn small_files_pack_into_archive_chunks() {
        let s = store();
        let cfg = UploadConfig {
            chunk_size: 100,
            pack_threshold: 32,
            ..UploadConfig::default()
        };
        let mut up = Uploader::with_config(s.clone(), "ds", cfg);
        for i in 0..6 {
            up.add_file(&format!("small/{i}"), &[i as u8; 16]).unwrap();
        }
        up.add_file("big", &[9u8; 64]).unwrap(); // above threshold: regular
        let (m, stats) = up.seal_with_stats().unwrap();
        assert_eq!(stats.files_packed, 6);
        // archive chunks are flagged; the big file's chunk is not
        let big = &m.files[m.find("big").unwrap()];
        assert!(!m.chunks[big.chunk as usize].packed);
        let packed_chunks: Vec<_> = m.chunks.iter().filter(|c| c.packed).collect();
        assert!(!packed_chunks.is_empty());
        // each entry is 6 B fixed header + 7 B path + 16 B payload =
        // 29 B; only three fit a 100 B chunk, so the archive split
        assert_eq!(packed_chunks.len(), 2);
        // every packed file's (offset, len) indexes straight into its
        // archive chunk bytes
        for i in 0..6 {
            let f = &m.files[m.find(&format!("small/{i}")).unwrap()];
            let chunk_ref = &m.chunks[f.chunk as usize];
            assert!(chunk_ref.packed);
            let bytes = s.get(&cas_chunk_key(chunk_ref.hash)).unwrap();
            let got = &bytes[f.offset as usize..(f.offset + f.len) as usize];
            assert_eq!(got, &[i as u8; 16]);
        }
        // the archive is self-describing for recovery
        let first_packed = packed_chunks[0];
        let bytes = s.get(&cas_chunk_key(first_packed.hash)).unwrap();
        let walked: Vec<String> =
            super::super::chunk::iter_archive(&bytes).map(|(p, _, _)| p).collect();
        assert!(!walked.is_empty());
        assert!(walked.iter().all(|p| p.starts_with("small/")));
    }
}
