//! HFS upload path: pack files into chunks, write chunks + manifest.
//!
//! Mirrors the paper's interface flow: "Interface uploads the training
//! data … Source files are chunked and uploaded to Object Storage."

use std::collections::BTreeSet;

use crate::storage::StoreHandle;
use crate::{Error, Result};

use super::chunk::{ChunkRef, FileEntry, FsManifest};

/// Streaming chunker: add files, then `seal()` to flush the tail chunk and
/// write the manifest. Files larger than the chunk size span a dedicated
/// oversized chunk (kept whole so a single GET serves the file).
pub struct Uploader {
    store: StoreHandle,
    ns: String,
    manifest: FsManifest,
    buf: Vec<u8>,
    next_chunk: u32,
    sealed: bool,
    /// Paths seen so far: duplicates must error, not silently shadow
    /// (the sealed file table is binary-searched by path, so a duplicate
    /// would make one copy unreachable forever).
    seen_paths: BTreeSet<String>,
}

impl Uploader {
    /// Start uploading `namespace` to `store` with `chunk_size`-byte
    /// chunks.
    ///
    /// # Panics
    /// If `chunk_size` is zero.
    pub fn new(store: StoreHandle, namespace: &str, chunk_size: u64) -> Self {
        assert!(chunk_size > 0, "chunk_size must be positive");
        Self {
            store,
            ns: namespace.to_string(),
            manifest: FsManifest::new(chunk_size),
            buf: Vec::with_capacity(chunk_size as usize),
            next_chunk: 0,
            sealed: false,
            seen_paths: BTreeSet::new(),
        }
    }

    /// Append one file to the namespace.
    pub fn add_file(&mut self, path: &str, data: &[u8]) -> Result<()> {
        if self.sealed {
            return Err(Error::Storage("uploader already sealed".into()));
        }
        if path.is_empty() {
            return Err(Error::Storage("empty file path".into()));
        }
        if !self.seen_paths.insert(path.to_string()) {
            return Err(Error::Storage(format!(
                "duplicate path {path:?} in namespace {:?}",
                self.ns
            )));
        }
        // would overflow current chunk -> flush first (keeps files whole)
        if !self.buf.is_empty()
            && self.buf.len() as u64 + data.len() as u64 > self.manifest.chunk_size
        {
            self.flush_chunk()?;
        }
        self.manifest.files.push(FileEntry {
            path: path.to_string(),
            chunk: self.next_chunk,
            offset: self.buf.len() as u64,
            len: data.len() as u64,
        });
        self.buf.extend_from_slice(data);
        // oversized single file: flush immediately as its own chunk
        if self.buf.len() as u64 >= self.manifest.chunk_size {
            self.flush_chunk()?;
        }
        Ok(())
    }

    fn flush_chunk(&mut self) -> Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let key = FsManifest::chunk_key(&self.ns, self.next_chunk);
        self.store.put(&key, &self.buf)?;
        self.manifest.chunks.push(ChunkRef {
            id: self.next_chunk,
            len: self.buf.len() as u64,
            hash: super::chunk::fnv1a64(&self.buf),
        });
        self.next_chunk += 1;
        self.buf.clear();
        Ok(())
    }

    /// Flush the tail chunk, sort the file table, write the manifest.
    /// Returns the sealed manifest.
    pub fn seal(mut self) -> Result<FsManifest> {
        self.flush_chunk()?;
        self.manifest.seal();
        let key = FsManifest::manifest_key(&self.ns);
        self.store.put(&key, &self.manifest.to_json()?)?;
        self.sealed = true;
        Ok(self.manifest)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::storage::MemStore;

    fn store() -> StoreHandle {
        Arc::new(MemStore::new())
    }

    #[test]
    fn packs_files_into_chunks() {
        let s = store();
        let mut up = Uploader::new(s.clone(), "ds", 100);
        up.add_file("f1", &[1u8; 40]).unwrap();
        up.add_file("f2", &[2u8; 40]).unwrap();
        up.add_file("f3", &[3u8; 40]).unwrap(); // spills to chunk 1
        let m = up.seal().unwrap();
        assert_eq!(m.chunks.len(), 2);
        assert_eq!(m.files.len(), 3);
        let f3 = &m.files[m.find("f3").unwrap()];
        assert_eq!(f3.chunk, 1);
        assert_eq!(s.get(&FsManifest::chunk_key("ds", 0)).unwrap().len(), 80);
    }

    #[test]
    fn oversized_file_gets_own_chunk() {
        let s = store();
        let mut up = Uploader::new(s.clone(), "ds", 100);
        up.add_file("small", &[0u8; 10]).unwrap();
        up.add_file("huge", &[9u8; 350]).unwrap();
        up.add_file("tail", &[7u8; 10]).unwrap();
        let m = up.seal().unwrap();
        let huge = &m.files[m.find("huge").unwrap()];
        assert_eq!(huge.offset, 0, "oversized file starts its own chunk");
        assert_eq!(m.chunks[huge.chunk as usize].len, 350);
        assert_eq!(m.total_bytes(), 370);
    }

    #[test]
    fn manifest_written_to_store() {
        let s = store();
        let mut up = Uploader::new(s.clone(), "ds", 64);
        up.add_file("a", b"data").unwrap();
        up.seal().unwrap();
        let m = FsManifest::from_json(&s.get("ds/manifest.json").unwrap()).unwrap();
        assert_eq!(m.file_count(), 1);
    }

    #[test]
    fn empty_namespace_ok() {
        let m = Uploader::new(store(), "empty", 64).seal().unwrap();
        assert_eq!(m.file_count(), 0);
        assert!(m.chunks.is_empty());
    }

    #[test]
    fn empty_namespace_manifest_round_trips_and_mounts() {
        // seal() with zero files must still write a manifest good enough
        // to mount: list is empty, reads fail cleanly, nothing panics
        let s = store();
        Uploader::new(s.clone(), "empty", 64).seal().unwrap();
        let m = FsManifest::from_json(&s.get("empty/manifest.json").unwrap()).unwrap();
        assert_eq!(m.file_count(), 0);
        assert_eq!(m.chunk_size, 64);
        let fs = crate::hfs::HyperFs::mount(s, "empty", 1 << 20).unwrap();
        assert!(fs.list("").is_empty());
        assert!(matches!(fs.read_file("anything"), Err(Error::FileNotFound(_))));
        assert!(fs.stat("anything").is_err());
    }

    #[test]
    fn duplicate_path_errors_instead_of_shadowing() {
        let s = store();
        let mut up = Uploader::new(s, "ds", 100);
        up.add_file("a/same", &[1u8; 10]).unwrap();
        up.add_file("a/other", &[2u8; 10]).unwrap();
        let err = up.add_file("a/same", &[3u8; 10]).unwrap_err();
        assert!(err.to_string().contains("duplicate path"), "{err}");
        // the uploader remains usable and the first copy is intact
        up.add_file("a/third", &[4u8; 10]).unwrap();
        let m = up.seal().unwrap();
        assert_eq!(m.file_count(), 3);
        let same = &m.files[m.find("a/same").unwrap()];
        assert_eq!(same.len, 10);
    }

    #[test]
    fn duplicates_across_chunk_boundaries_also_error() {
        let s = store();
        let mut up = Uploader::new(s, "ds", 20);
        up.add_file("x", &[1u8; 15]).unwrap(); // fills chunk 0
        up.add_file("y", &[2u8; 15]).unwrap(); // chunk 1
        assert!(up.add_file("x", &[3u8; 5]).is_err(), "dup in a later chunk");
    }

    #[test]
    fn rejects_after_double_add_of_sealed() {
        let s = store();
        let mut up = Uploader::new(s, "ds", 64);
        up.add_file("", b"x").unwrap_err();
    }
}
